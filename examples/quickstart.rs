//! Quickstart: auto-tune the Minimum problem's Promela model with the
//! counterexample method and print the optimal (WG, TS).
//!
//! Run: `cargo run --release --example quickstart`

use mcautotune::checker::CheckOptions;
use mcautotune::platform::MinModel;
use mcautotune::swarm::SwarmConfig;
use mcautotune::tuner::{tune, Method};

fn main() -> mcautotune::util::error::Result<()> {
    // Step 1 (paper §2): the model — Minimum problem, 256 elements on a
    // unit with 64 processing elements (the paper's Table-3 setup).
    let model = MinModel::paper(256, 64)?;

    // Steps 2-4: Φo = G(FIN -> time > T), bisection over T, parameter
    // extraction from the minimal-time counterexample.
    let result = tune(&model, Method::Exhaustive, &CheckOptions::default(), &SwarmConfig::default(), None)?;

    println!("bisection iterations:");
    for line in &result.log {
        println!("  {}", line);
    }
    println!();
    println!(
        "optimal tuning: WG={} TS={} (model time {})",
        result.optimal.wg, result.optimal.ts, result.t_min
    );
    println!(
        "explored {} states in {:?}",
        result.states_explored, result.elapsed
    );

    // sanity: the tuner's answer must match the model's analytic optimum
    let (opt_time, _) = model.optimum();
    assert_eq!(result.t_min, opt_time as i64);
    println!("matches the analytic optimum — OK");
    Ok(())
}
