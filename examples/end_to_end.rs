//! END-TO-END DRIVER — proves all three layers compose on a real workload.
//!
//! 1. L3 model checking: auto-tune the Minimum model (the paper's method,
//!    Φo + bisection + counterexample extraction) to get the optimal
//!    (WG, TS) *without touching hardware*.
//! 2. L1/L2 execution: load the AOT-compiled Pallas min-reduction
//!    artifacts (python is NOT on this path) and run the full Table-2
//!    sweep on the PJRT CPU client over a 16 MiB array, verifying every
//!    result against the host reduction.
//! 3. Compare: the model's predicted tuning preferences (larger WG wins,
//!    TS flat) against the measured sweep, as the paper does in §7.3.
//!
//! Run: `make artifacts && cargo run --release --example end_to_end`
//! The run is recorded in EXPERIMENTS.md §End-to-end.

use mcautotune::checker::CheckOptions;
use mcautotune::opencl::run_sweep;
use mcautotune::platform::{MinModel, Tuning};
use mcautotune::runtime::Engine;
use mcautotune::swarm::SwarmConfig;
use mcautotune::tuner::{tune, Method};
use mcautotune::util::fmt::human_bytes;

fn main() -> mcautotune::util::error::Result<()> {
    // ---- 1. tune the model (no hardware involved) ---------------------
    // Model a device with 64 PEs per unit (the artifact sweep's WG range).
    let model = MinModel::paper(1024, 64)?;
    let tuned = tune(
        &model,
        Method::Exhaustive,
        &CheckOptions::default(),
        &SwarmConfig::default(),
        None,
    )?;
    println!(
        "[model]  optimal tuning by model checking: WG={} TS={} (model time {}, {} states)",
        tuned.optimal.wg, tuned.optimal.ts, tuned.t_min, tuned.states_explored
    );

    // model's qualitative prediction: time improves with WG, ~flat in TS
    let t_small_wg = model.predicted_time(Tuning { wg: 2, ts: 4 });
    let t_big_wg = model.predicted_time(Tuning { wg: 64, ts: 4 });
    println!(
        "[model]  WG effect: WG=2 -> {} vs WG=64 -> {} ({}x)",
        t_small_wg,
        t_big_wg,
        t_small_wg / t_big_wg.max(1)
    );

    // ---- 2. execute the compiled kernels (python-free hot path) -------
    let dir = Engine::default_dir();
    let mut engine = Engine::new(&dir)?;
    println!(
        "[kernel] PJRT platform: {}, {} artifacts",
        engine.platform(),
        engine.manifest().entries.len()
    );
    let sweep = run_sweep(&mut engine, 3, 42)?;
    println!(
        "[kernel] sweep over {} of i32 data, {} configurations:",
        human_bytes(sweep.data_bytes),
        sweep.rows.len()
    );
    println!(
        "         {:>12} {:>5} {:>6} {:>10} {:>10} {:>8}",
        "global", "WG", "TS", "ms", "GB/s", "correct"
    );
    for r in &sweep.rows {
        println!(
            "         {:>12} {:>5} {:>6} {:>10.2} {:>10.2} {:>8}",
            r.global_size, r.wg, r.ts, r.best_ms, r.bandwidth_gbs, r.correct
        );
    }
    mcautotune::ensure!(sweep.rows.iter().all(|r| r.correct), "kernel results must be correct");

    // ---- 3. compare model prediction vs measurement --------------------
    // paper §7.3 finding: WG drives performance, TS does not. Check the
    // measured sweep for the same *shape*: best-WG mean beats worst-WG
    // mean, and TS variation at fixed WG is small.
    let mean_bw = |f: &dyn Fn(&&mcautotune::opencl::SweepRow) -> bool| -> f64 {
        let v: Vec<f64> =
            sweep.rows.iter().filter(|r| f(r)).map(|r| r.bandwidth_gbs).collect();
        v.iter().sum::<f64>() / v.len().max(1) as f64
    };
    let bw_wg64 = mean_bw(&|r| r.wg == 64);
    let bw_wg512 = mean_bw(&|r| r.wg == 512);
    println!(
        "[compare] mean bandwidth: WG=64 -> {:.2} GB/s, WG=512 -> {:.2} GB/s",
        bw_wg64, bw_wg512
    );
    let best = sweep
        .rows
        .iter()
        .max_by(|a, b| a.bandwidth_gbs.total_cmp(&b.bandwidth_gbs))
        .unwrap();
    println!(
        "[compare] fastest measured config: WG={} TS={} ({:.2} GB/s) — model predicted larger WG preferred: {}",
        best.wg,
        best.ts,
        best.bandwidth_gbs,
        if tuned.optimal.wg >= 4 { "consistent" } else { "inconsistent" }
    );
    println!("\nEND-TO-END OK: model-checking tuner + AOT Pallas kernels + PJRT runtime compose.");
    Ok(())
}
