//! Swarm verification for inputs beyond the exhaustive-mode budget —
//! the paper's §5 scenario: cap the "machine" at a small memory budget,
//! show exhaustive verification trip the ceiling, then tune with the
//! fixed-memory bitstate swarm (Fig. 5).
//!
//! Run: `cargo run --release --example swarm_large`

use mcautotune::checker::CheckOptions;
use mcautotune::platform::{AbstractModel, Granularity, PlatformConfig};
use mcautotune::swarm::SwarmConfig;
use mcautotune::tuner::{tune, Method};
use mcautotune::util::fmt::human_bytes;
use std::time::Duration;

fn main() -> mcautotune::util::error::Result<()> {
    // Tick granularity inflates the state space like the paper's
    // tick-faithful Promela model.
    let model = AbstractModel::new(1024, PlatformConfig::default(), Granularity::Tick)?;

    // A 4 MB "machine": exhaustive search must hit the memory ceiling.
    let mut tight = CheckOptions::default();
    tight.memory_budget = 4 << 20;
    let swarm = SwarmConfig {
        workers: 4,
        log2_bits: 23, // 1 MB bitstate per worker: 4 MB total
        time_budget: Duration::from_secs(20),
        ..Default::default()
    };

    println!("exhaustive tuning under a {} budget:", human_bytes(tight.memory_budget));
    match tune(&model, Method::Exhaustive, &tight, &swarm, None) {
        Ok(_) => println!("  unexpectedly fit in memory"),
        Err(e) => println!("  failed as expected: {:#}", e),
    }

    println!("\nswarm tuning (fixed-size bitstate, {} workers):", swarm.workers);
    let r = tune(&model, Method::Swarm, &tight, &swarm, None)?;
    for line in &r.log {
        println!("  {}", line);
    }
    println!(
        "\noptimal tuning: WG={} TS={} (model time {}), peak memory {}",
        r.optimal.wg,
        r.optimal.ts,
        r.t_min,
        human_bytes(r.peak_bytes)
    );
    let (opt, _) = model.optimum();
    println!(
        "analytic optimum: {} -> swarm answer is {}",
        opt,
        if r.t_min == opt as i64 { "exact" } else { "approximate" }
    );
    Ok(())
}
