//! BATCH TUNING DRIVER — the coordinator layer end to end.
//!
//! Parses a multi-job spec (several input sizes and both search methods),
//! runs it through the sharded work-stealing batch runner with a
//! persistent result cache, then runs the *same* batch again to show
//! every job served from the cache with zero additional states explored.
//! Finally replays the batch through **worker mode**: the plan serialized
//! as durable task manifests, drained by two concurrent workers (stand-ins
//! for two terminals — or two machines sharing the directory), and merged
//! into the identical report.
//!
//! Run: `cargo run --release --example batch_tune`

use mcautotune::coordinator::{run_batch, BatchOptions, ResultCache, TaskDir, TuningJob};
use mcautotune::swarm::SwarmConfig;
use std::time::Duration;

const SPEC: &str = "\
# the paper's Minimum model at three sizes, an abstract-model job, and the
# paper's actual artifact: the Promela model itself, batch-tuned through
# the full-interleaving front end (shards left unset = adaptive count)
job minimum size=64 np=4 gmt=3 shards=4
job minimum size=128 np=4 gmt=3 shards=4
job minimum size=64 np=64 gmt=3 name=min64-np64
job abstract size=32 gmt=10 shards=2
job minimum size=16 np=4 gmt=3 engine=promela name=min16-promela
";

fn main() -> mcautotune::util::error::Result<()> {
    let cache_path = std::env::temp_dir()
        .join(format!("mcat_batch_tune_example_{}.json", std::process::id()));
    std::fs::remove_file(&cache_path).ok();

    let jobs = TuningJob::parse_spec(SPEC)?;
    let mut opts = BatchOptions { workers: 4, ..BatchOptions::default() };
    opts.swarm = SwarmConfig { workers: 2, time_budget: Duration::from_secs(5), ..SwarmConfig::default() };

    println!("[batch] {} jobs -> sharded work-stealing queue ({} workers)", jobs.len(), opts.workers);
    let mut cache = ResultCache::open(&cache_path)?;
    let cold = run_batch(&jobs, &opts, &mut cache)?;
    print!("{}", cold.render());

    // every optimum must equal the model's closed-form ground truth — the
    // Promela job included (its template is pinned to the native model)
    for o in &cold.outcomes {
        assert_eq!(o.result.t_min, o.job.optimum_time()? as i64, "job {}", o.job.name);
    }

    println!("\n[batch] second invocation against the persisted cache ({}):", cache_path.display());
    let mut cache = ResultCache::open(&cache_path)?;
    let warm = run_batch(&jobs, &opts, &mut cache)?;
    print!("{}", warm.render());
    mcautotune::ensure!(warm.cache_hits == jobs.len() as u64, "warm run must hit on every job");
    mcautotune::ensure!(warm.total_states() == 0, "warm run must explore zero states");

    // ---- worker mode: the same batch drained across processes --------
    //
    // In production this is three commands on any machines that share the
    // directory (the planner participates too unless --plan-only):
    //
    //   terminal 0:  mcautotune batch jobs.spec --task-dir tasks/ --plan-only
    //   terminal 1:  mcautotune worker tasks/
    //   terminal 2:  mcautotune worker tasks/
    //   any:         mcautotune merge tasks/
    //
    // Here the two "terminals" are two threads, each draining through the
    // same public API the CLI uses.
    let task_dir = std::env::temp_dir()
        .join(format!("mcat_batch_tune_tasks_{}", std::process::id()));
    std::fs::remove_dir_all(&task_dir).ok();
    let fresh_cache = std::env::temp_dir()
        .join(format!("mcat_batch_tune_dist_{}.json", std::process::id()));
    std::fs::remove_file(&fresh_cache).ok();

    let td = TaskDir::new(&task_dir);
    let mut dist_cache = ResultCache::open(&fresh_cache)?;
    let summary = td.plan(&jobs, &opts, &mut dist_cache)?;
    println!(
        "\n[worker mode] planned {} durable task(s) into {}",
        summary.tasks,
        task_dir.display()
    );
    std::thread::scope(|s| {
        let w1 = s.spawn(|| TaskDir::new(&task_dir).drain(1, false));
        let w2 = s.spawn(|| TaskDir::new(&task_dir).drain(1, false));
        let s1 = w1.join().expect("worker 1 panicked").expect("worker 1 failed");
        let s2 = w2.join().expect("worker 2 panicked").expect("worker 2 failed");
        println!(
            "[worker mode] worker 1 drained {} task(s), worker 2 drained {} task(s)",
            s1.executed, s2.executed
        );
        assert_eq!(s1.executed + s2.executed, summary.tasks as u64);
    });
    let dist = td.merge(&mut dist_cache)?;
    for (a, b) in cold.outcomes.iter().zip(&dist.outcomes) {
        assert_eq!(a.result.t_min, b.result.t_min, "job {}", a.job.name);
        assert_eq!(
            (a.result.optimal.wg, a.result.optimal.ts),
            (b.result.optimal.wg, b.result.optimal.ts),
            "job {}",
            a.job.name
        );
    }
    println!("[worker mode] merged report matches the single-process run.");

    std::fs::remove_dir_all(&task_dir).ok();
    std::fs::remove_file(&fresh_cache).ok();
    std::fs::remove_file(&cache_path).ok();
    println!("\nBATCH OK: {} jobs tuned once, replayed from the cache for free.", jobs.len());
    Ok(())
}
