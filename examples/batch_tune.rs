//! BATCH TUNING DRIVER — the coordinator layer end to end.
//!
//! Parses a multi-job spec (several input sizes and both search methods),
//! runs it through the sharded work-stealing batch runner with a
//! persistent result cache, then runs the *same* batch again to show
//! every job served from the cache with zero additional states explored.
//!
//! Run: `cargo run --release --example batch_tune`

use mcautotune::coordinator::{run_batch, BatchOptions, ResultCache, TuningJob};
use mcautotune::swarm::SwarmConfig;
use std::time::Duration;

const SPEC: &str = "\
# the paper's Minimum model at three sizes, an abstract-model job, and the
# paper's actual artifact: the Promela model itself, batch-tuned through
# the full-interleaving front end (shards left unset = adaptive count)
job minimum size=64 np=4 gmt=3 shards=4
job minimum size=128 np=4 gmt=3 shards=4
job minimum size=64 np=64 gmt=3 name=min64-np64
job abstract size=32 gmt=10 shards=2
job minimum size=16 np=4 gmt=3 engine=promela name=min16-promela
";

fn main() -> mcautotune::util::error::Result<()> {
    let cache_path = std::env::temp_dir()
        .join(format!("mcat_batch_tune_example_{}.json", std::process::id()));
    std::fs::remove_file(&cache_path).ok();

    let jobs = TuningJob::parse_spec(SPEC)?;
    let mut opts = BatchOptions { workers: 4, ..BatchOptions::default() };
    opts.swarm = SwarmConfig { workers: 2, time_budget: Duration::from_secs(5), ..SwarmConfig::default() };

    println!("[batch] {} jobs -> sharded work-stealing queue ({} workers)", jobs.len(), opts.workers);
    let mut cache = ResultCache::open(&cache_path)?;
    let cold = run_batch(&jobs, &opts, &mut cache)?;
    print!("{}", cold.render());

    // every optimum must equal the model's closed-form ground truth — the
    // Promela job included (its template is pinned to the native model)
    for o in &cold.outcomes {
        assert_eq!(o.result.t_min, o.job.optimum_time()? as i64, "job {}", o.job.name);
    }

    println!("\n[batch] second invocation against the persisted cache ({}):", cache_path.display());
    let mut cache = ResultCache::open(&cache_path)?;
    let warm = run_batch(&jobs, &opts, &mut cache)?;
    print!("{}", warm.render());
    mcautotune::ensure!(warm.cache_hits == jobs.len() as u64, "warm run must hit on every job");
    mcautotune::ensure!(warm.total_states() == 0, "warm run must explore zero states");

    std::fs::remove_file(&cache_path).ok();
    println!("\nBATCH OK: {} jobs tuned once, replayed from the cache for free.", jobs.len());
    Ok(())
}
