//! BATCH TUNING DRIVER — the coordinator layer end to end.
//!
//! Parses a multi-job spec (several input sizes and both search methods),
//! runs it through the sharded work-stealing batch runner with a
//! persistent result cache, then runs the *same* batch again to show
//! every job served from the cache with zero additional states explored.
//! Finally replays the batch through **worker mode**: the plan serialized
//! as durable task manifests, drained by two concurrent workers (stand-ins
//! for two terminals — or two machines sharing the directory), and merged
//! into the identical report.
//!
//! Run: `cargo run --release --example batch_tune`

use mcautotune::coordinator::{run_batch, BatchOptions, ResultCache, TaskDir, TuningJob};
use mcautotune::swarm::SwarmConfig;
use std::time::Duration;

const SPEC: &str = "\
# the paper's Minimum model at three sizes, an abstract-model job, and the
# paper's actual artifact: the Promela model itself, batch-tuned through
# the full-interleaving front end (shards left unset = adaptive count)
job minimum size=64 np=4 gmt=3 shards=4
job minimum size=128 np=4 gmt=3 shards=4
job minimum size=64 np=64 gmt=3 name=min64-np64
job abstract size=32 gmt=10 shards=2
job minimum size=16 np=4 gmt=3 engine=promela name=min16-promela
";

fn main() -> mcautotune::util::error::Result<()> {
    let cache_path = std::env::temp_dir()
        .join(format!("mcat_batch_tune_example_{}.json", std::process::id()));
    std::fs::remove_file(&cache_path).ok();

    let jobs = TuningJob::parse_spec(SPEC)?;
    let mut opts = BatchOptions { workers: 4, ..BatchOptions::default() };
    opts.swarm = SwarmConfig { workers: 2, time_budget: Duration::from_secs(5), ..SwarmConfig::default() };

    println!("[batch] {} jobs -> sharded work-stealing queue ({} workers)", jobs.len(), opts.workers);
    let mut cache = ResultCache::open(&cache_path)?;
    let cold = run_batch(&jobs, &opts, &mut cache)?;
    print!("{}", cold.render());

    // every optimum must equal the model's closed-form ground truth — the
    // Promela job included (its template is pinned to the native model)
    for o in &cold.outcomes {
        assert_eq!(o.result.t_min, o.job.optimum_time()? as i64, "job {}", o.job.name);
    }

    println!("\n[batch] second invocation against the persisted cache ({}):", cache_path.display());
    let mut cache = ResultCache::open(&cache_path)?;
    let warm = run_batch(&jobs, &opts, &mut cache)?;
    print!("{}", warm.render());
    mcautotune::ensure!(warm.cache_hits == jobs.len() as u64, "warm run must hit on every job");
    mcautotune::ensure!(warm.total_states() == 0, "warm run must explore zero states");

    // ---- surrogate warm-start: observations make new sizes cheap -----
    //
    // `search=surrogate` jobs harvest per-family observations into the
    // cache as they complete. A cold batch over three sizes of one family
    // falls back to exhaustive search (no observations yet) but seeds the
    // cache; a later job at a *new* size of the same family then rides
    // the surrogate path — k-NN proposals over the harvested points, an
    // exact point oracle, and one certificate sweep — and still lands on
    // the identical optimum. In production:
    //
    //   mcautotune batch jobs.spec --search surrogate --cache results.json
    let surr_cache_path = std::env::temp_dir()
        .join(format!("mcat_batch_tune_surr_{}.json", std::process::id()));
    std::fs::remove_file(&surr_cache_path).ok();
    let warmup = TuningJob::parse_spec(
        "job minimum size=16 np=4 gmt=3 search=surrogate shards=1\n\
         job minimum size=32 np=4 gmt=3 search=surrogate shards=1\n\
         job minimum size=64 np=4 gmt=3 search=surrogate shards=1\n",
    )?;
    let mut surr_cache = ResultCache::open(&surr_cache_path)?;
    let seeded = run_batch(&warmup, &opts, &mut surr_cache)?;
    for o in &seeded.outcomes {
        assert_eq!(o.result.t_min, o.job.optimum_time()? as i64, "job {}", o.job.name);
    }
    println!(
        "\n[surrogate] cold batch over sizes 16/32/64 harvested {} observation row(s)",
        surr_cache.observation_count()
    );
    mcautotune::ensure!(
        surr_cache.observation_count() >= 3,
        "three completed jobs must harvest enough observations to warm-start"
    );

    let target =
        TuningJob::parse_spec("job minimum size=128 np=4 gmt=3 search=surrogate shards=1\n")?;
    let surr = run_batch(&target, &opts, &mut surr_cache)?;
    let out = &surr.outcomes[0];
    assert_eq!(out.result.t_min, out.job.optimum_time()? as i64, "surrogate optimum is exact");
    mcautotune::ensure!(
        out.result.log.iter().any(|l| l.contains("certificate:")),
        "the warm job must take the surrogate path, not the fallback"
    );
    for line in out.result.log.iter().filter(|l| l.starts_with("surrogate:")) {
        println!("[surrogate] size=128: {}", line);
    }
    println!(
        "[surrogate] size=128 optimum WG={} TS={} t_min={} — identical to exhaustive, \
         in a handful of point evaluations",
        out.result.optimal.wg, out.result.optimal.ts, out.result.t_min
    );
    std::fs::remove_file(&surr_cache_path).ok();

    // ---- worker mode: the same batch drained across processes --------
    //
    // In production this is three commands on any machines that share the
    // directory (the planner participates too unless --plan-only):
    //
    //   terminal 0:  mcautotune batch jobs.spec --task-dir tasks/ --plan-only
    //   terminal 1:  mcautotune worker tasks/
    //   terminal 2:  mcautotune worker tasks/
    //   any:         mcautotune merge tasks/
    //
    // Here the two "terminals" are two threads, each draining through the
    // same public API the CLI uses.
    let task_dir = std::env::temp_dir()
        .join(format!("mcat_batch_tune_tasks_{}", std::process::id()));
    std::fs::remove_dir_all(&task_dir).ok();
    let fresh_cache = std::env::temp_dir()
        .join(format!("mcat_batch_tune_dist_{}.json", std::process::id()));
    std::fs::remove_file(&fresh_cache).ok();

    let td = TaskDir::new(&task_dir);
    let mut dist_cache = ResultCache::open(&fresh_cache)?;
    let summary = td.plan(&jobs, &opts, &mut dist_cache)?;
    println!(
        "\n[worker mode] planned {} durable task(s) into {}",
        summary.tasks,
        task_dir.display()
    );
    std::thread::scope(|s| {
        let w1 = s.spawn(|| TaskDir::new(&task_dir).drain(1, false));
        let w2 = s.spawn(|| TaskDir::new(&task_dir).drain(1, false));
        let s1 = w1.join().expect("worker 1 panicked").expect("worker 1 failed");
        let s2 = w2.join().expect("worker 2 panicked").expect("worker 2 failed");
        println!(
            "[worker mode] worker 1 drained {} task(s), worker 2 drained {} task(s)",
            s1.executed, s2.executed
        );
        assert_eq!(s1.executed + s2.executed, summary.tasks as u64);
    });
    let dist = td.merge(&mut dist_cache)?;
    for (a, b) in cold.outcomes.iter().zip(&dist.outcomes) {
        assert_eq!(a.result.t_min, b.result.t_min, "job {}", a.job.name);
        assert_eq!(
            (a.result.optimal.wg, a.result.optimal.ts),
            (b.result.optimal.wg, b.result.optimal.ts),
            "job {}",
            a.job.name
        );
    }
    println!("[worker mode] merged report matches the single-process run.");

    // ---- chaos: a poison task, dead-lettered, folded with --partial --
    //
    // A failpoint (the same facility `MCAT_FAILPOINTS` drives from the
    // environment) makes the first job's only shard panic on every
    // attempt. The drain retries it through the attempt budget, moves it
    // to dead/<id>.json, and finishes the rest of the batch. A strict
    // merge refuses; `merge --partial` (merge_partial here) folds the
    // healthy job and reports the casualty. In production:
    //
    //   mcautotune batch jobs.spec --task-dir tasks/ --plan-only --max-attempts 3
    //   mcautotune worker tasks/            # retries, then dead-letters
    //   mcautotune merge tasks/ --partial   # folds what completed
    let chaos_dir = std::env::temp_dir()
        .join(format!("mcat_batch_tune_chaos_{}", std::process::id()));
    std::fs::remove_dir_all(&chaos_dir).ok();
    let chaos_cache_path = std::env::temp_dir()
        .join(format!("mcat_batch_tune_chaos_{}.json", std::process::id()));
    std::fs::remove_file(&chaos_cache_path).ok();

    let chaos_jobs = TuningJob::parse_spec(
        "job minimum size=16 np=4 gmt=3 shards=1\njob minimum size=32 np=4 gmt=3 shards=1\n",
    )?;
    let ctd = TaskDir::new(&chaos_dir).with_max_attempts(2);
    let mut chaos_cache = ResultCache::open(&chaos_cache_path)?;
    ctd.plan(&chaos_jobs, &opts, &mut chaos_cache)?;
    // exactly two injected panics; a single-threaded drain leases tasks
    // in id order, so both land on job 0's only task — one per attempt —
    // and the attempt budget (2) runs out. Job 1 never sees a fault.
    mcautotune::util::failpoint::activate("shard.exec=panic:2")?;
    let stats = ctd.drain(1, false)?;
    mcautotune::util::failpoint::deactivate();
    mcautotune::ensure!(stats.complete, "dead-lettering must unblock the drain");
    let dead = ctd.status()?.dead;
    println!("\n[chaos] dead-lettered: {:?}", dead);
    mcautotune::ensure!(dead.len() == 1, "exactly the poisoned task is dead-lettered");
    mcautotune::ensure!(
        ctd.merge(&mut chaos_cache).is_err(),
        "a strict merge must refuse a batch with dead-lettered tasks"
    );
    let partial = ctd.merge_partial(&mut chaos_cache)?;
    print!("{}", partial.render());
    mcautotune::ensure!(partial.partial, "merge_partial must flag the report");
    mcautotune::ensure!(partial.dead_tasks.len() == 1, "the report must list the dead task");
    mcautotune::ensure!(
        partial.outcomes.iter().any(|o| o.job.size == 32 && !o.lower_bound),
        "the healthy job must be folded whole"
    );

    std::fs::remove_dir_all(&chaos_dir).ok();
    std::fs::remove_file(&chaos_cache_path).ok();
    std::fs::remove_dir_all(&task_dir).ok();
    std::fs::remove_file(&fresh_cache).ok();
    std::fs::remove_file(&cache_path).ok();
    println!("\nBATCH OK: {} jobs tuned once, replayed from the cache for free.", jobs.len());
    Ok(())
}
