//! The paper's §7 use case in one program: tune the Minimum problem with
//! model checking (Table 3 workflow) on both engines — the native model
//! and the generated Promela model — and compare.
//!
//! Run: `cargo run --release --example tune_minimum`

use mcautotune::checker::{check, CheckOptions};
use mcautotune::model::SafetyLtl;
use mcautotune::platform::{DataInit, Granularity, MinModel};
use mcautotune::promela::{templates, PromelaSystem};
use mcautotune::swarm::SwarmConfig;
use mcautotune::tuner::{extract_sorted, tune, Method};

fn main() -> mcautotune::util::error::Result<()> {
    let (size, np, gmt) = (64u32, 4u32, 3u32);

    // Engine 1: the native transition system (checker hot path)
    let native = MinModel::new(size, np, gmt, DataInit::Descending, Granularity::Phase)?;
    let r = tune(&native, Method::Exhaustive, &CheckOptions::default(), &SwarmConfig::default(), None)?;
    println!(
        "native engine:  optimal WG={} TS={} time={} ({} states, {:?})",
        r.optimal.wg, r.optimal.ts, r.t_min, r.states_explored, r.elapsed
    );

    // Engine 2: the generated Promela model, full process interleaving
    let pml = templates::minimum_pml(size, np, gmt);
    let sys = PromelaSystem::from_source(&pml)?;
    let rp = tune(&sys, Method::Exhaustive, &CheckOptions::default(), &SwarmConfig::default(), Some(10_000))?;
    println!(
        "promela engine: optimal WG={} TS={} time={} ({} states, {:?})",
        rp.optimal.wg, rp.optimal.ts, rp.t_min, rp.states_explored, rp.elapsed
    );
    assert_eq!(r.t_min, rp.t_min, "engines must agree");

    // Table-3-style listing: all configurations sorted by model time
    let mut opts = CheckOptions::default();
    opts.collect_all = true;
    let rep = check(&native, &SafetyLtl::non_termination(), &opts)?;
    let ws = extract_sorted(&native, rep.violations.iter())?;
    println!("\nall configurations (best first), size={} NP={} GMT={}:", size, np, gmt);
    println!("{:>6} {:>6} {:>12} {:>8}", "WG", "TS", "model time", "steps");
    for w in &ws {
        println!("{:>6} {:>6} {:>12} {:>8}", w.wg, w.ts, w.time, w.steps);
    }
    println!(
        "\nverified: min value {} computed correctly on every explored schedule",
        native.true_min()
    );
    Ok(())
}
