//! Verify a Promela model file with the explicit-state checker — the
//! SPIN-style workflow: load `models/minimum_16.pml`, check the over-time
//! property, and replay the counterexample trail.
//!
//! Run: `cargo run --release --example promela_check [model.pml]`
//! (generate the models first: `cargo run -- gen-models`)

use mcautotune::checker::{check, CheckOptions};
use mcautotune::model::{SafetyLtl, TransitionSystem};
use mcautotune::promela::{templates, PromelaSystem};

fn main() -> mcautotune::util::error::Result<()> {
    let path = std::env::args().nth(1);
    let src = match &path {
        Some(p) => std::fs::read_to_string(p)?,
        None => templates::minimum_pml(16, 4, 3), // same as models/minimum_16.pml
    };
    let sys = PromelaSystem::from_source(&src)?;

    // Φo with a deliberately loose bound: counterexample guaranteed
    let prop = SafetyLtl::parse("G(FIN -> time > 30)")?;
    let rep = check(&sys, &prop, &CheckOptions::default())?;
    println!(
        "property {}: {}",
        prop,
        if rep.found() { "violated — program can finish within 30 time units" } else { "holds" }
    );
    println!(
        "search: {} states stored, {} matched, {} transitions, depth {}",
        rep.stats.states_stored,
        rep.stats.states_matched,
        rep.stats.transitions,
        rep.stats.max_depth_reached
    );

    if let Some(v) = rep.violations.first() {
        let last = v.trail.last();
        println!(
            "\ncounterexample: WG={} TS={} time={} result={} ({} steps)",
            sys.eval_var(last, "WG").unwrap(),
            sys.eval_var(last, "TS").unwrap(),
            sys.eval_var(last, "time").unwrap(),
            sys.eval_var(last, "result").unwrap(),
            v.trail.steps(),
        );
        println!("\ntrail (elided):");
        print!("{}", v.trail.render(&sys, 16));
    }
    Ok(())
}
