"""L2 correctness: model graphs compose the kernel correctly and the AOT
lowering produces parseable HLO text."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.aot import SWEEP, lower_abstract, lower_min, to_hlo_text
from compile.kernels.ref import global_min_ref, min_reduce_ref


def _x(size, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(-2**31, 2**31 - 1, size=size,
                                    dtype=np.int32))


def test_min_device_matches_ref():
    u, w, t = 4, 4, 8
    x = _x(u * w * t)
    (mins,) = model.min_device(x, units=u, wg=w, ts=t)
    np.testing.assert_array_equal(mins, min_reduce_ref(x, u, w, t))


def test_min_fused_host_reduce_agrees():
    u, w, t = 4, 8, 4
    x = _x(u * w * t, seed=3)
    mins, gmin = model.min_fused(x, units=u, wg=w, ts=t)
    # The Rust host-side reduce over `mins` must equal the fused output.
    assert int(jnp.min(mins)) == int(gmin) == int(global_min_ref(x))


def test_min_device_jit_roundtrip():
    u, w, t = 2, 4, 4
    x = _x(u * w * t, seed=5)
    import functools
    f = jax.jit(functools.partial(model.min_device, units=u, wg=w, ts=t))
    (mins,) = f(x)
    np.testing.assert_array_equal(mins, min_reduce_ref(x, u, w, t))


def test_lower_min_emits_entry():
    text = lower_min("min_device", 2, 2, 2)
    assert "ENTRY" in text and "HloModule" in text
    # return_tuple=True: root must be a tuple for the rust to_tupleN unwrap.
    assert "tuple(" in text.replace(" ", "")


def test_lower_fused_two_outputs():
    text = lower_min("min_fused", 2, 2, 2)
    assert "ENTRY" in text
    assert text.count("s32") > 0


def test_lower_abstract_emits_entry():
    text = lower_abstract(4, 4, 2)
    assert "ENTRY" in text and "f32" in text


def test_sweep_configs_consistent():
    data = 1 << 22
    globals_seen = set()
    for units, wg in SWEEP:
        ts = data // (units * wg)
        assert units * wg * ts == data, (units, wg)
        assert ts >= 64
        globals_seen.add(units * wg)
    # the sweep must vary global size (Table 2 column 2)
    assert len(globals_seen) >= 4
