"""L1 correctness: Pallas kernels vs pure-jnp oracles.

hypothesis sweeps configurations (units, WG, TS), dtypes and adversarial
data; every case asserts exact agreement with ref.py (min/sum/max over
integers and floats are reduction-order-robust at these sizes).
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.abstract import make_abstract
from compile.kernels.minreduce import make_min_reduce, vmem_bytes
from compile.kernels.ref import (abstract_ref, global_min_ref,
                                 min_reduce_ref)

POW2 = st.sampled_from([1, 2, 4, 8, 16])


def _data(size, dtype, seed):
    rng = np.random.default_rng(seed)
    if np.issubdtype(dtype, np.integer):
        info = np.iinfo(dtype)
        return jnp.asarray(
            rng.integers(info.min, info.max, size=size, dtype=dtype))
    return jnp.asarray(rng.standard_normal(size).astype(dtype) * 100)


@hypothesis.given(units=POW2, wg=POW2, ts=POW2, seed=st.integers(0, 2**31))
@hypothesis.settings(max_examples=40, deadline=None)
def test_min_reduce_matches_ref_i32(units, wg, ts, seed):
    x = _data(units * wg * ts, np.int32, seed)
    got = make_min_reduce(units, wg, ts)(x)
    want = min_reduce_ref(x, units, wg, ts)
    np.testing.assert_array_equal(got, want)


@hypothesis.given(units=POW2, wg=POW2, ts=POW2, seed=st.integers(0, 2**31))
@hypothesis.settings(max_examples=20, deadline=None)
def test_min_reduce_matches_ref_f32(units, wg, ts, seed):
    x = _data(units * wg * ts, np.float32, seed)
    got = make_min_reduce(units, wg, ts, dtype=jnp.float32)(x)
    want = min_reduce_ref(x, units, wg, ts)
    np.testing.assert_allclose(got, want)


@pytest.mark.parametrize("units,wg,ts", [(1, 1, 1), (1, 8, 1), (8, 1, 1),
                                         (1, 1, 8), (2, 4, 8)])
def test_min_reduce_degenerate_shapes(units, wg, ts):
    x = _data(units * wg * ts, np.int32, 7)
    got = make_min_reduce(units, wg, ts)(x)
    np.testing.assert_array_equal(got, min_reduce_ref(x, units, wg, ts))


def test_min_reduce_extreme_values():
    # INT32_MIN must survive the staging + reduce path.
    x = jnp.full((4 * 4 * 4,), np.int32(np.iinfo(np.int32).max))
    x = x.at[37].set(np.iinfo(np.int32).min)
    got = make_min_reduce(4, 4, 4)(x)
    assert int(jnp.min(got)) == np.iinfo(np.int32).min
    assert int(global_min_ref(x)) == np.iinfo(np.int32).min


def test_min_reduce_all_equal():
    x = jnp.full((2 * 2 * 4,), np.int32(42))
    np.testing.assert_array_equal(make_min_reduce(2, 2, 4)(x),
                                  jnp.full((2,), 42, jnp.int32))


def test_min_reduce_rejects_bad_shape():
    with pytest.raises(ValueError, match="expected flat input"):
        make_min_reduce(2, 2, 2)(jnp.zeros((9,), jnp.int32))
    with pytest.raises(ValueError, match="positive"):
        make_min_reduce(0, 2, 2)


def test_min_reduce_workgroup_isolation():
    # A tiny value in group 0 must not leak into group 1's partial.
    x = jnp.arange(2 * 2 * 2, dtype=jnp.int32) + 100
    x = x.at[0].set(-5)
    got = make_min_reduce(2, 2, 2)(x)
    assert int(got[0]) == -5
    assert int(got[1]) == 104


@hypothesis.given(wg=st.sampled_from([2, 4, 8]), ts=st.sampled_from([2, 4, 8]),
                  n_tiles=st.sampled_from([1, 2, 4]),
                  seed=st.integers(0, 2**31))
@hypothesis.settings(max_examples=20, deadline=None)
def test_abstract_matches_ref(wg, ts, n_tiles, seed):
    x = _data(wg * ts * n_tiles, np.float32, seed)
    got = make_abstract(wg, ts, n_tiles)(x)
    want = abstract_ref(x, wg, ts, n_tiles)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-3)


def test_abstract_branch_divergence():
    # Even items use g1 (sum), odd items use g2 (2*max) — verify both arms.
    wg, ts, n_tiles = 4, 4, 2
    x = jnp.ones((wg * ts * n_tiles,), jnp.float32)
    got = np.asarray(make_abstract(wg, ts, n_tiles)(x))
    np.testing.assert_allclose(got[0::2], 8.0)  # sum of 8 ones
    np.testing.assert_allclose(got[1::2], 4.0)  # 2 tiles * 2*max(1)


def test_vmem_estimate_monotone():
    assert vmem_bytes(64, 64) < vmem_bytes(128, 64) < vmem_bytes(128, 128)
    assert vmem_bytes(4, 4) == 4 * 4 * 4 + 4 * 4 + 4
