"""L1 Pallas kernel for the paper's Minimum problem (paper §7.1, Listing 10).

The OpenCL kernel tiles a large array over (units x WG) work items, each
scanning TS elements (MAP), then work item 0 of each group reduces the
group's partial minima from local memory (REDUCE local). On TPU the same
insight maps to: stage HBM->VMEM in (WG, TS) blocks via BlockSpec (the
analogue of the __local staging array), reduce on the VPU, and emit one
partial minimum per workgroup; the final REDUCE-global stays on the host
(the Rust coordinator), exactly like Listing 11.

interpret=True throughout: CPU PJRT cannot execute Mosaic custom-calls, and
interpret mode lowers to plain HLO that the Rust runtime can load.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _min_kernel(x_ref, o_ref):
    """One grid step == one workgroup.

    x_ref block: (WG, TS) — row r is work item r's tile.
    o_ref block: (1,)     — this workgroup's partial minimum.
    """
    tile = x_ref[...]
    # MAP: every work item reduces its TS-element tile (kernel lines 7-9).
    per_item = jnp.min(tile, axis=1)
    # REDUCE local: work item 0 folds the group's partials (lines 12-16).
    o_ref[0] = jnp.min(per_item)


def make_min_reduce(units: int, wg: int, ts: int, dtype=jnp.int32,
                    interpret: bool = True):
    """Build the tuned min-reduction for a (units, WG, TS) configuration.

    Returns a function mapping a flat array of ``units*wg*ts`` elements to
    the ``(units,)`` vector of per-workgroup minima.
    """
    if units <= 0 or wg <= 0 or ts <= 0:
        raise ValueError(f"config must be positive, got {(units, wg, ts)}")
    size = units * wg * ts

    def run(x):
        if x.shape != (size,):
            raise ValueError(
                f"expected flat input of {size} elements for config "
                f"(units={units}, wg={wg}, ts={ts}), got {x.shape}")
        x2 = x.reshape(units * wg, ts)
        return pl.pallas_call(
            _min_kernel,
            grid=(units,),
            in_specs=[pl.BlockSpec((wg, ts), lambda u: (u, 0))],
            out_specs=pl.BlockSpec((1,), lambda u: (u,)),
            out_shape=jax.ShapeDtypeStruct((units,), dtype),
            interpret=interpret,
        )(x2)

    return run


def vmem_bytes(wg: int, ts: int, dtype=jnp.int32) -> int:
    """Estimated VMEM footprint of one grid step: the staged (WG, TS) input
    block plus the (WG,) partials and the (1,) output."""
    isz = jnp.dtype(dtype).itemsize
    return wg * ts * isz + wg * isz + isz
