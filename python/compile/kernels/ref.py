"""Pure-jnp oracles for the Pallas kernels (build-time correctness only)."""

from __future__ import annotations

import jax.numpy as jnp


def min_reduce_ref(x, units: int, wg: int, ts: int):
    """Per-workgroup minima of a flat (units*wg*ts,) array."""
    return jnp.min(x.reshape(units, wg * ts), axis=1)


def global_min_ref(x):
    return jnp.min(x)


def abstract_ref(x, wg: int, ts: int, n_tiles: int):
    """Oracle for kernels.abstract: even items sum their row, odd items
    accumulate 2*max per tile."""
    x2 = x.reshape(wg, n_tiles, ts)
    g1 = jnp.sum(x2, axis=(1, 2))
    g2 = jnp.sum(jnp.max(x2, axis=2) * 2.0, axis=1)
    idx = jnp.arange(wg)
    return jnp.where(idx % 2 == 0, g1, g2)
