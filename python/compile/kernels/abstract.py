"""L1 Pallas kernel mirroring the paper's *abstract* OpenCL kernel
(paper §3.2, Listing 2).

Each work item processes size/TS tiles; per tile it stages data to local
memory (here: the BlockSpec HBM->VMEM copy), then accumulates with one of
two branch functions selected by b(idx_l) (here: parity — even items fold
with g1 = sum, odd items with g2 = max), synchronizing on the tile boundary
(here: the sequential grid dimension is the barrier).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _abstract_kernel(x_ref, o_ref):
    t = pl.program_id(0)
    tile = x_ref[...]  # (WG, TS) block for this tile step
    wg = tile.shape[0]
    idx_l = jax.lax.broadcasted_iota(jnp.int32, (wg,), 0)
    g1 = jnp.sum(tile, axis=1)          # branch for b(idx_l) == true
    g2 = jnp.max(tile, axis=1) * 2.0    # branch for b(idx_l) == false
    contrib = jnp.where(idx_l % 2 == 0, g1, g2)

    @pl.when(t == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += contrib


def make_abstract(wg: int, ts: int, n_tiles: int, dtype=jnp.float32,
                  interpret: bool = True):
    """Abstract kernel for one workgroup of ``wg`` items over ``n_tiles``
    tiles of ``ts`` elements each (size = wg * n_tiles * ts)."""
    if wg <= 0 or ts <= 0 or n_tiles <= 0:
        raise ValueError(f"config must be positive, got {(wg, ts, n_tiles)}")

    def run(x):
        size = wg * n_tiles * ts
        if x.shape != (size,):
            raise ValueError(f"expected {size} elements, got {x.shape}")
        x2 = x.reshape(wg, n_tiles * ts)
        return pl.pallas_call(
            _abstract_kernel,
            grid=(n_tiles,),
            in_specs=[pl.BlockSpec((wg, ts), lambda t: (0, t))],
            out_specs=pl.BlockSpec((wg,), lambda t: (0,)),
            out_shape=jax.ShapeDtypeStruct((wg,), dtype),
            interpret=interpret,
        )(x2)

    return run
