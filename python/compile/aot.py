"""AOT bridge: lower the L2 graphs to HLO *text* for the Rust runtime.

HLO text (not serialized HloModuleProto) is the interchange format: jax >=
0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the `xla` 0.1.6 crate) rejects; the text parser reassigns ids
and round-trips cleanly. Lowered with return_tuple=True — the Rust side
unwraps with to_tupleN().

Emits one artifact per tuning configuration (the Table-2 sweep, scaled per
DESIGN.md §4) plus small self-test artifacts, and a manifest.json the Rust
runtime uses for discovery.

Usage: python -m compile.aot [--out-dir ../artifacts] [--data-pow 22] [--quick]
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels.minreduce import vmem_bytes

# Table-2 sweep (scaled): data size = units * wg * ts = 2**data_pow.
# (units, wg) pairs chosen so that, like the paper's Table 2, TS varies at
# fixed WG (rows 1-3, 4-5, 6-8 ...) and WG varies at fixed global size.
SWEEP = [
    (64, 64), (32, 128), (16, 256),      # global 4096,  ts = data/4096
    (128, 64), (64, 128),                # global 8192
    (256, 64), (128, 128), (32, 512),    # global 16384
    (256, 128), (64, 512),               # global 32768
    (256, 256), (128, 512),              # global 65536
]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_min(kind: str, units: int, wg: int, ts: int) -> str:
    size = units * wg * ts
    spec = jax.ShapeDtypeStruct((size,), jnp.int32)
    fn = {"min_device": model.min_device, "min_fused": model.min_fused}[kind]
    bound = functools.partial(fn, units=units, wg=wg, ts=ts)
    return to_hlo_text(jax.jit(bound).lower(spec))


def lower_abstract(wg: int, ts: int, n_tiles: int) -> str:
    size = wg * n_tiles * ts
    spec = jax.ShapeDtypeStruct((size,), jnp.float32)
    bound = functools.partial(model.abstract_device, wg=wg, ts=ts,
                              n_tiles=n_tiles)
    return to_hlo_text(jax.jit(bound).lower(spec))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--data-pow", type=int, default=22,
                    help="log2 of the Table-2 data size (paper: 4GB; scaled)")
    ap.add_argument("--quick", action="store_true",
                    help="emit only the small self-test artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    entries = []

    def emit(name: str, text: str, meta: dict) -> None:
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        entries.append({"name": name, "file": fname, **meta})
        print(f"  wrote {fname} ({len(text)} chars)")

    # Small artifacts: runtime smoke tests, examples/quickstart.
    for kind in ("min_device", "min_fused"):
        u, w, t = 4, 4, 4
        emit(f"{kind}_small", lower_min(kind, u, w, t), {
            "kind": kind, "units": u, "wg": w, "ts": t, "size": u * w * t,
            "dtype": "i32", "vmem_bytes": vmem_bytes(w, t),
        })
    emit("abstract_small", lower_abstract(8, 16, 4), {
        "kind": "abstract", "wg": 8, "ts": 16, "n_tiles": 4,
        "size": 8 * 16 * 4, "dtype": "f32",
    })

    if not args.quick:
        data = 1 << args.data_pow
        for units, wg in SWEEP:
            ts = data // (units * wg)
            assert units * wg * ts == data
            name = f"min_u{units}_wg{wg}_ts{ts}"
            emit(name, lower_min("min_device", units, wg, ts), {
                "kind": "min_device", "units": units, "wg": wg, "ts": ts,
                "size": data, "dtype": "i32",
                "vmem_bytes": vmem_bytes(wg, ts),
            })

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump({"data_pow": args.data_pow, "artifacts": entries}, f,
                  indent=2)
    # Flat TSV for the Rust runtime (no JSON parser needed offline).
    cols = ["name", "file", "kind", "units", "wg", "ts", "size", "dtype",
            "vmem_bytes"]
    with open(os.path.join(args.out_dir, "manifest.tsv"), "w") as f:
        f.write("\t".join(cols) + "\n")
        for e in entries:
            row = [str(e.get(c, 0)) for c in cols]
            f.write("\t".join(row) + "\n")
    print(f"manifest: {len(entries)} artifacts -> {args.out_dir}/manifest.{{json,tsv}}")


if __name__ == "__main__":
    main()
