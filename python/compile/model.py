"""L2 JAX model: the Minimum-problem compute graph (paper §7, Listings 10-11)
and the abstract-kernel graph (paper §3.2, Listing 2), both calling the L1
Pallas kernels.

These are lowered ONCE by aot.py to HLO text; the Rust coordinator loads the
artifacts and drives them. The device-side graph mirrors the OpenCL split:
the kernel produces per-workgroup minima, the host (Rust) does REDUCE-global.
We additionally emit the fused variant (partials + global min in one call)
so the runtime can validate its own host-side reduction.
"""

from __future__ import annotations

import jax.numpy as jnp

from compile.kernels.abstract import make_abstract
from compile.kernels.minreduce import make_min_reduce


def min_device(x, *, units: int, wg: int, ts: int, dtype=jnp.int32):
    """Device-side Minimum: per-workgroup minima (Listing 10). The host-side
    final reduction (Listing 11, lines 22-24) is performed by the Rust
    coordinator over this output."""
    kern = make_min_reduce(units, wg, ts, dtype=dtype)
    return (kern(x),)


def min_fused(x, *, units: int, wg: int, ts: int, dtype=jnp.int32):
    """Minimum with the global reduction folded into the graph; used by the
    runtime's self-check (host reduce must agree with this)."""
    (mins,) = min_device(x, units=units, wg=wg, ts=ts, dtype=dtype)
    return (mins, jnp.min(mins))


def abstract_device(x, *, wg: int, ts: int, n_tiles: int):
    """Abstract-kernel graph: one workgroup of `wg` items over
    `n_tiles` x `ts` tiles (Listing 2)."""
    kern = make_abstract(wg, ts, n_tiles)
    return (kern(x),)
