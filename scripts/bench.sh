#!/usr/bin/env bash
# Checker perf trajectory: run the hot-path bench suite and emit
# BENCH_checker.json at the repo root (or at $1).
#
#   scripts/bench.sh                 # full run, writes BENCH_checker.json
#   scripts/bench.sh out.json        # custom output path
#   MCAT_BENCH_FAST=1 scripts/bench.sh   # 10x smaller measurement budget
#   MCAT_BENCH_SIZE=128 scripts/bench.sh # smaller model (CI smoke)
#
# JSON format: {bench, model, states, speedup_par4_vs_seq,
# reduction_por_states_ratio, reduction_deadslots_states_ratio,
# compression_bytes_ratio, spill_slowdown_ratio,
# results: [{name, iters, mean_ns, per_sec}]} — one entry per bench case,
# sequential + parallel exploration throughput first. The two reduction
# ratios are reduced/baseline states_stored on the Promela minimum model
# (1.0 = the reduction degraded to a no-op). compression_bytes_ratio is
# the collapse/full resident store footprint at identical coverage
# (explore/collapse row; < 1.0 = COLLAPSE interning pays), and
# spill_slowdown_ratio is explore/spill vs explore/pml-seq wall time
# under a 512 KiB budget that forces frozen runs to disk.
# surrogate_eval_fraction is the tune/surrogate vs tune/exhaustive
# checker-invocation ratio on a warm observation store (< 1.0 = the
# cache-seeded proposer replaces full-lattice Cex sweeps with point
# evaluations; both rows tune the same model to the identical optimum).
set -euo pipefail
if ! command -v cargo >/dev/null 2>&1; then
  echo "error: cargo not found — measuring BENCH_checker.json needs a Rust toolchain" >&2
  echo "       (the committed file stays a schema placeholder until one is available)" >&2
  exit 1
fi
cd "$(dirname "$0")/../rust"
out="${1:-../BENCH_checker.json}"
MCAT_BENCH_JSON="$out" cargo bench --bench checker_hot_path
echo "bench results written to $out"
