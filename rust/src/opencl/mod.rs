//! "Real execution" harness — the Table 2 counterpart (paper §7.1/§7.3).
//!
//! The paper runs the OpenCL Minimum kernel on an Nvidia P104-100 over a
//! 4 GB array for 12 launch configurations and reports time (ms) and
//! bandwidth (GB/s). Our testbed substitute (DESIGN.md §4) executes the
//! AOT-compiled Pallas min-reduction artifacts on the PJRT CPU client over
//! a scaled array; the *relative* behaviour — bandwidth grows with WG,
//! is flat in TS — is the reproduction target, not absolute numbers.

use crate::runtime::Engine;
use crate::util::rng::Xoshiro256;
use crate::util::error::{Context, Result};
use std::time::Instant;

#[derive(Debug, Clone)]
pub struct SweepRow {
    pub name: String,
    /// total work items = units × WG (Table 2 column "Global size")
    pub global_size: u32,
    pub wg: u32,
    pub ts: u32,
    pub best_ms: f64,
    pub mean_ms: f64,
    pub bandwidth_gbs: f64,
    /// result verified against the host-side reference
    pub correct: bool,
}

#[derive(Debug, Clone)]
pub struct SweepReport {
    pub rows: Vec<SweepRow>,
    pub data_bytes: u64,
    pub platform: String,
}

/// Deterministic input array shared by every sweep configuration (all
/// Table-2 rows process the same data size).
pub fn gen_data(n: usize, seed: u64) -> Vec<i32> {
    let mut rng = Xoshiro256::new(seed);
    (0..n).map(|_| rng.next_u64() as i32).collect()
}

/// Run every `min_device` artifact of the sweep (everything except the
/// `*_small` self-test entries), `repeats` times each.
pub fn run_sweep(engine: &mut Engine, repeats: u32, seed: u64) -> Result<SweepReport> {
    let entries: Vec<_> = engine
        .manifest()
        .of_kind("min_device")
        .filter(|e| !e.name.ends_with("_small"))
        .cloned()
        .collect();
    crate::ensure!(!entries.is_empty(), "no sweep artifacts in manifest (run `make artifacts`)");
    let n = entries[0].size as usize;
    crate::ensure!(
        entries.iter().all(|e| e.size as usize == n),
        "sweep artifacts disagree on data size"
    );
    let data = gen_data(n, seed);
    let expected = *data.iter().min().context("empty data")?;
    let data_bytes = (n * std::mem::size_of::<i32>()) as u64;

    let mut rows = Vec::new();
    for e in &entries {
        // warm-up run compiles the executable and faults in buffers
        let first = engine.run_min(&e.name, &data)?;
        let mut correct = first.global_min == expected;
        let mut times = Vec::with_capacity(repeats as usize);
        for _ in 0..repeats {
            let t = Instant::now();
            let out = engine.run_min(&e.name, &data)?;
            times.push(t.elapsed().as_secs_f64() * 1e3);
            correct &= out.global_min == expected;
        }
        let best = times.iter().copied().fold(f64::INFINITY, f64::min);
        let mean = times.iter().sum::<f64>() / times.len() as f64;
        rows.push(SweepRow {
            name: e.name.clone(),
            global_size: e.units * e.wg,
            wg: e.wg,
            ts: e.ts,
            best_ms: best,
            mean_ms: mean,
            bandwidth_gbs: data_bytes as f64 / (best / 1e3) / 1e9,
            correct,
        });
    }
    // Table 2 is ordered by global size, then WG
    rows.sort_by_key(|r| (r.global_size, r.wg, r.ts));
    Ok(SweepReport { rows, data_bytes, platform: engine.platform() })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gen_data_deterministic() {
        assert_eq!(gen_data(64, 1), gen_data(64, 1));
        assert_ne!(gen_data(64, 1), gen_data(64, 2));
    }

    #[test]
    fn sweep_runs_and_verifies() {
        let dir = Engine::default_dir();
        if !dir.join("manifest.tsv").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let mut eng = Engine::new(&dir).unwrap();
        // single repeat keeps the unit test fast; benches do real timing
        let rep = run_sweep(&mut eng, 1, 42).unwrap();
        assert_eq!(rep.rows.len(), 12, "Table 2 has 12 sweep rows");
        assert!(rep.rows.iter().all(|r| r.correct), "kernel results must match host min");
        assert!(rep.rows.iter().all(|r| r.bandwidth_gbs > 0.0));
        // sorted by global size
        for w in rep.rows.windows(2) {
            assert!(w[0].global_size <= w[1].global_size);
        }
    }
}
