//! Minimal ASCII table renderer for the experiment reports.

pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(headers: Vec<S>) -> Self {
        Self { headers: headers.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep: String = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for (cell, width) in cells.iter().zip(&widths).take(ncol) {
                s.push_str(&format!(" {:>width$} |", cell, width = *width));
            }
            s
        };
        let mut out = String::new();
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(vec!["N", "Size", "time"]);
        t.row(vec!["1", "8", "44"]);
        t.row(vec!["2", "1024", "549912"]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 6); // sep, header, sep, 2 rows, sep
        // all lines equal width
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
        assert!(r.contains("549912"));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        Table::new(vec!["a", "b"]).row(vec!["1"]);
    }
}
