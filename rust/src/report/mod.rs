//! Reporting: ASCII tables and the drivers that regenerate the paper's
//! Tables 1-3 (see DESIGN.md §5 for the experiment index).

pub mod experiments;
pub mod table;

pub use experiments::{paper_table3_groups, table1, table2, table3, Table1Opts};
pub use table::Table;
