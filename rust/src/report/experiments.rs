//! Experiment drivers regenerating the paper's Tables 1–3.
//!
//! Each driver returns both structured rows and a rendered ASCII table;
//! the CLI prints them and EXPERIMENTS.md records paper-vs-measured.

use super::table::Table;
use crate::checker::{check, CheckOptions};
use crate::model::SafetyLtl;
use crate::opencl::{run_sweep, SweepReport};
use crate::platform::{AbstractModel, Granularity, MinModel, PlatformConfig};
use crate::promela::{templates, PromelaSystem};
use crate::runtime::Engine;
use crate::swarm::SwarmConfig;
use crate::tuner::{extract_sorted, tune, Method, TuneResult};
use crate::util::fmt::{human_bytes, human_duration, thousands};
use crate::util::error::Result;
use std::time::Duration;

// ------------------------------------------------------------- Table 1 --

#[derive(Debug)]
pub struct Table1Row {
    pub size: u32,
    pub model_time: i64,
    pub steps: usize,
    pub ts: u32,
    pub wg: u32,
    /// bytes used by exhaustive verification (Promela engine when run,
    /// else the native engine); None when skipped (over the budget)
    pub mem_exhaustive: Option<u64>,
    pub mem_swarm: u64,
    pub verification: Duration,
    pub first_trail: Duration,
    pub optimality: f64,
}

#[derive(Debug, Clone)]
pub struct Table1Opts {
    pub sizes: Vec<u32>,
    pub plat: PlatformConfig,
    /// largest size verified exhaustively on the native engine
    pub max_exhaustive_size: u32,
    /// largest size verified exhaustively on the *Promela* engine
    /// (full interleaving — the SPIN-comparable memory column)
    pub max_promela_size: u32,
    pub swarm: SwarmConfig,
}

impl Default for Table1Opts {
    fn default() -> Self {
        Self {
            sizes: vec![8, 16, 32, 64, 128, 256, 512, 1024],
            plat: PlatformConfig::default(),
            max_exhaustive_size: 256,
            max_promela_size: 16,
            swarm: SwarmConfig { time_budget: Duration::from_secs(5), ..Default::default() },
        }
    }
}

pub fn table1(opts: &Table1Opts) -> Result<(Vec<Table1Row>, String)> {
    let mut rows = Vec::new();
    for &size in &opts.sizes {
        let model = AbstractModel::new(size, opts.plat, Granularity::Phase)?;

        // memory of exhaustive verification: prefer the Promela engine
        // (full interleaving, the honest SPIN analogue) on small sizes;
        // also harvest its best trail's step count (the column SPIN's
        // simulation mode reports in the paper's Table 1)
        let mut pml_steps: Option<usize> = None;
        let mem_exhaustive = if size <= opts.max_promela_size {
            let pml = templates::abstract_pml(size, &opts.plat);
            let sys = PromelaSystem::from_source(&pml)?;
            let co = CheckOptions { collect_all: true, ..CheckOptions::default() };
            let rep = check(&sys, &SafetyLtl::non_termination(), &co)?;
            let ws = crate::tuner::extract_sorted(&sys, rep.violations.iter())?;
            pml_steps = ws.first().map(|w| w.steps);
            Some(rep.stats.bytes_used)
        } else {
            None
        };

        // the tuning itself: exhaustive bisection when affordable, swarm always
        let (result, mem_exh_native): (TuneResult, Option<u64>) =
            if size <= opts.max_exhaustive_size {
                let r = tune(&model, Method::Exhaustive, &CheckOptions::default(), &opts.swarm, None)?;
                let m = r.peak_bytes;
                (r, Some(m))
            } else {
                (tune(&model, Method::Swarm, &CheckOptions::default(), &opts.swarm, None)?, None)
            };
        let swarm_result = tune(&model, Method::Swarm, &CheckOptions::default(), &opts.swarm, None)?;

        rows.push(Table1Row {
            size,
            // steps: Promela-engine trail length when measured (comparable
            // to SPIN's simulation step counts); otherwise the native
            // phase-granularity trail length
            model_time: result.t_min,
            steps: pml_steps.unwrap_or(result.optimal.steps),
            ts: result.optimal.ts,
            wg: result.optimal.wg,
            mem_exhaustive: mem_exhaustive.or(mem_exh_native),
            mem_swarm: swarm_result.peak_bytes,
            verification: result.elapsed,
            first_trail: result.first_trail.map(|(_, d)| d).unwrap_or_default(),
            optimality: result.first_trail_optimality.unwrap_or(1.0),
        });
    }

    let mut t = Table::new(vec![
        "N", "Size", "Model time", "Steps", "TS", "WG", "Mem (exh)", "Mem (swarm)",
        "Verif time", "1st trail", "1st trail opt",
    ]);
    for (i, r) in rows.iter().enumerate() {
        t.row(vec![
            (i + 1).to_string(),
            r.size.to_string(),
            r.model_time.to_string(),
            thousands(r.steps as u64),
            r.ts.to_string(),
            r.wg.to_string(),
            r.mem_exhaustive.map_or("-".into(), human_bytes),
            human_bytes(r.mem_swarm),
            human_duration(r.verification),
            human_duration(r.first_trail),
            format!("{:.0}%", r.optimality * 100.0),
        ]);
    }
    Ok((rows, t.render()))
}

// ------------------------------------------------------------- Table 2 --

pub fn table2(engine: &mut Engine, repeats: u32) -> Result<(SweepReport, String)> {
    let rep = run_sweep(engine, repeats, 42)?;
    let mut t = Table::new(vec![
        "N", "Global size", "WG", "TS", "Time (ms)", "Bandwidth (GB/s)", "Correct",
    ]);
    for (i, r) in rep.rows.iter().enumerate() {
        t.row(vec![
            (i + 1).to_string(),
            r.global_size.to_string(),
            r.wg.to_string(),
            r.ts.to_string(),
            format!("{:.2}", r.best_ms),
            format!("{:.2}", r.bandwidth_gbs),
            if r.correct { "yes".into() } else { "NO".into() },
        ]);
    }
    let header = format!(
        "platform={} data={} ({} runs/config)\n",
        rep.platform,
        human_bytes(rep.data_bytes),
        repeats
    );
    Ok((rep, header + &t.render()))
}

// ------------------------------------------------------------- Table 3 --

#[derive(Debug)]
pub struct Table3Row {
    pub pes: u32,
    pub size: u32,
    pub wg: u32,
    pub ts: u32,
    pub model_time: i64,
    pub steps: usize,
}

/// (NP, size) groups as in the paper's Table 3; `top` best configurations
/// reported per group (the paper lists 3).
pub fn table3(groups: &[(u32, u32)], gmt: u32, top: usize) -> Result<(Vec<Table3Row>, String)> {
    let mut rows = Vec::new();
    for &(np, size) in groups {
        let model = MinModel::new(
            size,
            np,
            gmt,
            crate::platform::DataInit::Descending,
            Granularity::Phase,
        )?;
        let co = CheckOptions { collect_all: true, ..CheckOptions::default() };
        let rep = check(&model, &SafetyLtl::non_termination(), &co)?;
        crate::ensure!(rep.exhausted, "table3 model must be exhaustible");
        let ws = extract_sorted(&model, rep.violations.iter())?;
        for w in ws.iter().take(top) {
            rows.push(Table3Row {
                pes: np,
                size,
                wg: w.wg,
                ts: w.ts,
                model_time: w.time,
                steps: w.steps,
            });
        }
    }
    let mut t = Table::new(vec!["N", "PEs", "Data size", "WG", "TS", "Model time", "Steps"]);
    for (i, r) in rows.iter().enumerate() {
        t.row(vec![
            (i + 1).to_string(),
            r.pes.to_string(),
            r.size.to_string(),
            r.wg.to_string(),
            r.ts.to_string(),
            r.model_time.to_string(),
            thousands(r.steps as u64),
        ]);
    }
    Ok((rows, t.render()))
}

/// The paper's Table 3 groups.
pub fn paper_table3_groups() -> Vec<(u32, u32)> {
    vec![(4, 16), (64, 64), (64, 128), (64, 256)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_small_sizes() {
        let opts = Table1Opts {
            sizes: vec![8, 16],
            max_promela_size: 0, // promela engine covered by templates tests
            max_exhaustive_size: 64,
            swarm: SwarmConfig {
                workers: 2,
                time_budget: Duration::from_millis(500),
                ..Default::default()
            },
            ..Default::default()
        };
        let (rows, rendered) = table1(&opts).unwrap();
        assert_eq!(rows.len(), 2);
        // optimal times must match the native ground truth
        for r in &rows {
            let m = AbstractModel::new(r.size, opts.plat, Granularity::Phase).unwrap();
            assert_eq!(r.model_time, m.optimum().0 as i64);
        }
        assert!(rows[0].mem_exhaustive.is_some(), "native exhaustive memory recorded");
        assert!(rendered.contains("Model time"));
    }

    #[test]
    fn table3_rows_sorted_and_correct() {
        let (rows, rendered) = table3(&[(4, 16), (64, 64)], 3, 3).unwrap();
        assert_eq!(rows.len(), 6);
        // within each group: ascending model time; best equals optimum
        let m = MinModel::paper(16, 4).unwrap();
        assert_eq!(rows[0].model_time, m.optimum().0 as i64);
        assert!(rows[0].model_time <= rows[1].model_time);
        let m2 = MinModel::paper(64, 64).unwrap();
        assert_eq!(rows[3].model_time, m2.optimum().0 as i64);
        assert!(rendered.contains("PEs"));
    }
}
