//! Swarm verification (paper §5; Holzmann, Joshi, Groce 2008/2010).
//!
//! A fleet of independent, diversified, *bounded* searches: each worker
//! runs the DFS engine with its own RNG seed (randomized successor order),
//! a bitstate store (fixed memory), a depth bound and a time budget. The
//! fleet's counterexamples are merged; the paper then picks the minimal
//! termination time among them (tuner::swarm_search).
//!
//! Workers run on std::thread (the paper uses 1–8 cores).

use crate::checker::{check, CheckOptions, CheckReport, Order, SearchStats, StoreKind};
use crate::model::{SafetyLtl, TransitionSystem, Violation};
use crate::util::error::Result;
use std::time::{Duration, Instant};

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SwarmConfig {
    pub workers: u32,
    pub seed: u64,
    /// per-worker bitstate table size (log2 bits); 2^27 bits = 16 MB,
    /// mirroring the paper's ~115-172 MB swarm footprints across workers
    pub log2_bits: u8,
    pub hashes: u8,
    /// SPIN -m: per-worker depth bound
    pub max_depth: usize,
    /// per-worker wall-clock budget
    pub time_budget: Duration,
    /// collect every violation on a path (spin -e)
    pub max_errors_per_worker: usize,
}

impl Default for SwarmConfig {
    fn default() -> Self {
        Self {
            // one diversified worker per core (the paper uses 1-8); capped
            // at 32 so the default per-worker bitstate tables (2^27 bits =
            // 16 MB each) stay bounded on very wide machines
            workers: std::thread::available_parallelism()
                .map(|n| n.get() as u32)
                .unwrap_or(4)
                .clamp(1, 32),
            seed: 0x5AFE,
            log2_bits: 27,
            hashes: 3,
            max_depth: 200_000_000, // the paper's final -m 2x10^8
            time_budget: Duration::from_secs(10),
            max_errors_per_worker: 256,
        }
    }
}

#[derive(Debug)]
pub struct WorkerReport<S> {
    pub worker: u32,
    pub violations: Vec<Violation<S>>,
    pub stats: SearchStats,
}

#[derive(Debug)]
pub struct SwarmReport<S> {
    pub per_worker: Vec<WorkerReport<S>>,
    pub elapsed: Duration,
}

impl<S> SwarmReport<S> {
    pub fn violations(&self) -> impl Iterator<Item = &Violation<S>> {
        self.per_worker.iter().flat_map(|w| w.violations.iter())
    }

    pub fn found(&self) -> bool {
        self.per_worker.iter().any(|w| !w.violations.is_empty())
    }

    pub fn total_states(&self) -> u64 {
        self.per_worker.iter().map(|w| w.stats.states_stored).sum()
    }

    pub fn total_bytes(&self) -> u64 {
        self.per_worker.iter().map(|w| w.stats.bytes_used).sum()
    }

    /// Earliest wall-clock time at which any worker found its first
    /// violation (the paper's "1st trail" column).
    pub fn first_trail_after(&self) -> Option<Duration> {
        self.violations().map(|v| v.found_after).min()
    }
}

fn worker_options(cfg: &SwarmConfig, worker: u32) -> CheckOptions {
    CheckOptions {
        store: StoreKind::Bitstate { log2_bits: cfg.log2_bits, hashes: cfg.hashes },
        max_depth: cfg.max_depth,
        time_budget: Some(cfg.time_budget),
        collect_all: true,
        max_errors: cfg.max_errors_per_worker,
        // diversify: each worker gets an independent exploration order
        order: Order::Random(
            cfg.seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(worker as u64),
        ),
        ..CheckOptions::default()
    }
}

/// Run the swarm against `G(prop)`. The model is shared read-only across
/// worker threads.
pub fn swarm<M>(model: &M, prop: &SafetyLtl, cfg: &SwarmConfig) -> Result<SwarmReport<M::State>>
where
    M: TransitionSystem + Sync,
    M::State: Send,
{
    let start = Instant::now();
    let mut per_worker = Vec::with_capacity(cfg.workers as usize);
    if cfg.workers <= 1 {
        let rep = check(model, prop, &worker_options(cfg, 0))?;
        per_worker.push(to_worker_report(0, rep));
    } else {
        let reports: Vec<Result<CheckReport<M::State>>> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..cfg.workers)
                .map(|w| {
                    let opts = worker_options(cfg, w);
                    let prop = prop.clone();
                    scope.spawn(move || check(model, &prop, &opts))
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
        });
        for (w, rep) in reports.into_iter().enumerate() {
            per_worker.push(to_worker_report(w as u32, rep?));
        }
    }
    Ok(SwarmReport { per_worker, elapsed: start.elapsed() })
}

fn to_worker_report<S>(worker: u32, rep: CheckReport<S>) -> WorkerReport<S> {
    WorkerReport { worker, violations: rep.violations, stats: rep.stats }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::{AbstractModel, Granularity, MinModel, PlatformConfig};

    #[test]
    fn swarm_finds_termination_counterexamples() {
        // Φt = G(!FIN): every terminating run is a counterexample (paper §5)
        let m = AbstractModel::new(32, PlatformConfig::default(), Granularity::Phase).unwrap();
        let cfg = SwarmConfig {
            workers: 2,
            time_budget: Duration::from_secs(5),
            ..Default::default()
        };
        let rep = swarm(&m, &SafetyLtl::non_termination(), &cfg).unwrap();
        assert!(rep.found());
        // every violation is a FIN state with a positive time
        for v in rep.violations() {
            assert_eq!(v.trail.final_var(&m, "FIN"), Some(1));
            assert!(v.trail.final_var(&m, "time").unwrap() > 0);
        }
        assert!(rep.first_trail_after().is_some());
    }

    #[test]
    fn swarm_workers_diversify() {
        let m = MinModel::paper(64, 4).unwrap();
        let cfg = SwarmConfig { workers: 4, ..Default::default() };
        let rep = swarm(&m, &SafetyLtl::non_termination(), &cfg).unwrap();
        // different workers should hit FIN through different tunings
        let mut wgs = std::collections::HashSet::new();
        for v in rep.violations() {
            wgs.insert(v.trail.final_var(&m, "WG").unwrap());
        }
        assert!(wgs.len() > 1, "expected diverse tunings, got {:?}", wgs);
    }

    #[test]
    fn swarm_respects_time_budget() {
        let m = AbstractModel::new(1024, PlatformConfig::default(), Granularity::Tick).unwrap();
        let cfg = SwarmConfig {
            workers: 1,
            time_budget: Duration::from_millis(100),
            ..Default::default()
        };
        let t = Instant::now();
        let _ = swarm(&m, &SafetyLtl::parse("G(true)").unwrap(), &cfg).unwrap();
        assert!(t.elapsed() < Duration::from_secs(5));
    }

    #[test]
    fn swarm_memory_is_bounded_by_bitstate() {
        let m = AbstractModel::new(64, PlatformConfig::default(), Granularity::Phase).unwrap();
        let cfg = SwarmConfig { workers: 2, log2_bits: 20, ..Default::default() };
        let rep = swarm(&m, &SafetyLtl::non_termination(), &cfg).unwrap();
        // 2 workers x 2^20 bits / 8 = 256 KB total
        assert_eq!(rep.total_bytes(), 2 * (1 << 20) / 8);
    }
}
