//! The auto-tuner — the paper's four-step counterexample method (§2, §4)
//! as a facade over the checker and the swarm.
//!
//! Step 1 (the model) is supplied by the caller (`platform::*` or a
//! `promela::PromelaSystem`); Step 2 is `SafetyLtl::over_time`; Step 3 is
//! [`bisection`] (Fig. 1) or [`swarm_search`] (Fig. 5); Step 4 is
//! [`extract`].
//!
//! Orthogonal to the *method* is the *search mode* ([`SearchMode`]):
//! `Exhaustive` runs step 3 directly over the full lattice, while
//! `Surrogate` ([`surrogate`]) wraps it in a proposer/oracle/certificate
//! loop — a cached-observation k-NN regressor **proposes** candidate
//! configs, the checker is invoked as the exact **oracle** only on those
//! proposals (singleton-shard bisections), and one collect-all
//! **certificate** sweep pins the exact global optimum; with too few
//! observations the mode **falls back** to plain exhaustive search.
//! Either mode returns the identical optimum (same `t_min`, canonical
//! tie-break), which is why the mode never joins a cache key.

pub mod bisection;
pub mod extract;
pub mod surrogate;
pub mod swarm_search;

pub use bisection::{bisection, BisectionIter, BisectionResult};
pub use extract::{extract, extract_sorted, harvest_observations, TuningWitness};
pub use surrogate::{surrogate_tune, Observation, SurrogateOptions, SurrogateReport};
pub use swarm_search::{swarm_search, SwarmIter, SwarmSearchResult};

use crate::checker::CheckOptions;
use crate::model::TransitionSystem;
use crate::platform::sim::initial_bound;
use crate::swarm::SwarmConfig;
use crate::util::error::{Context, Result};
use std::time::Duration;

/// Search strategy (paper §4 vs §5).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    /// exhaustive verification + bisection over T (Fig. 1)
    Exhaustive,
    /// swarm verification + decreasing-T loop (Fig. 5)
    Swarm,
}

impl std::str::FromStr for Method {
    type Err = crate::util::error::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "exhaustive" | "bisection" => Ok(Method::Exhaustive),
            "swarm" => Ok(Method::Swarm),
            _ => crate::bail!("unknown method `{}` (exhaustive|swarm)", s),
        }
    }
}

/// How the tuning lattice is searched (orthogonal to [`Method`]; see the
/// module docs). An *execution* knob like the shard count: both modes
/// return the identical optimum, so the mode is excluded from cache
/// descriptions and a surrogate run may serve — and be served by —
/// exhaustive cache entries.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SearchMode {
    /// evaluate the full lattice per `Cex(T)` query (paper Fig. 1)
    #[default]
    Exhaustive,
    /// model-guided proposals + point oracle + exact certificate
    /// ([`surrogate::surrogate_tune`])
    Surrogate,
}

impl std::fmt::Display for SearchMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            SearchMode::Exhaustive => "exhaustive",
            SearchMode::Surrogate => "surrogate",
        })
    }
}

impl std::str::FromStr for SearchMode {
    type Err = crate::util::error::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "exhaustive" => Ok(SearchMode::Exhaustive),
            "surrogate" => Ok(SearchMode::Surrogate),
            _ => crate::bail!("unknown search mode `{}` (exhaustive|surrogate)", s),
        }
    }
}

/// Unified tuning outcome across both methods.
#[derive(Debug)]
pub struct TuneResult {
    pub method: Method,
    pub optimal: TuningWitness,
    pub t_min: i64,
    pub first_trail: Option<(TuningWitness, Duration)>,
    pub first_trail_optimality: Option<f64>,
    pub states_explored: u64,
    pub peak_bytes: u64,
    pub elapsed: Duration,
    /// human-readable per-iteration log (for the CLI and EXPERIMENTS.md)
    pub log: Vec<String>,
}

/// Auto-tune `model`: find the minimal model time and its (WG, TS).
///
/// `T_ini` is obtained by simulation (paper §2 Step 3) unless overridden.
pub fn tune<M>(
    model: &M,
    method: Method,
    check_opts: &CheckOptions,
    swarm_cfg: &SwarmConfig,
    t_ini_override: Option<i64>,
) -> Result<TuneResult>
where
    M: TransitionSystem + Sync,
    M::State: Send,
{
    match method {
        Method::Exhaustive => {
            let t_ini = match t_ini_override {
                Some(t) => t,
                None => initial_bound(model, 8, 0x51_u64, 100_000_000)
                    .context("simulation found no terminating run for T_ini")?,
            };
            let r = bisection(model, check_opts, t_ini)?;
            let log = r
                .iterations
                .iter()
                .map(|i| {
                    format!(
                        "Cex(T={}) -> {} [{} states, {}]",
                        i.t,
                        if i.cex_found { "counterexample" } else { "proved" },
                        i.states_stored,
                        crate::util::fmt::human_duration(i.elapsed)
                    )
                })
                .collect();
            Ok(TuneResult {
                method,
                optimal: r.witness,
                t_min: r.t_min,
                first_trail_optimality: r.first_trail_optimality(),
                first_trail: r.first_trail,
                states_explored: r.total_states,
                peak_bytes: r.peak_bytes,
                elapsed: r.total_elapsed,
                log,
            })
        }
        Method::Swarm => {
            let r = swarm_search(model, swarm_cfg)?;
            let log = r
                .iterations
                .iter()
                .map(|i| {
                    format!(
                        "swarm({}) -> {} cex, best time {:?} [{} states, {}]",
                        i.bound.map_or("Φt".to_string(), |b| format!("Φo T={}", b)),
                        i.cex_count,
                        i.best_time,
                        i.states,
                        crate::util::fmt::human_duration(i.elapsed)
                    )
                })
                .collect();
            Ok(TuneResult {
                method,
                optimal: r.witness,
                t_min: r.t_min,
                first_trail_optimality: r.first_trail_optimality(),
                first_trail: r.first_trail,
                states_explored: r.total_states,
                peak_bytes: r.total_bytes,
                elapsed: r.total_elapsed,
                log,
            })
        }
    }
}

// ----------------------------------------------------------- caching --

/// The cacheable core of a [`TuneResult`] — what a content-addressed
/// result cache stores and what a hit reconstructs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CachedTune {
    pub wg: u32,
    pub ts: u32,
    pub t_min: i64,
    /// transitions on the original witnessing trail
    pub steps: usize,
}

/// Cache interface for [`tune_cached`], implemented by
/// [`crate::coordinator::ResultCache`]. Keys are canonical description
/// strings of (model, platform config, property/method); how they are
/// hashed and persisted is the implementation's concern.
pub trait TuneCache {
    fn lookup(&mut self, desc: &str) -> Option<CachedTune>;
    fn store(&mut self, desc: &str, result: &TuneResult);
}

/// Reconstruct a [`TuneResult`] from a cache hit: the optimum is exact,
/// and no verification ran — zero states explored, zero bytes, ~zero
/// elapsed time.
pub fn cached_result(method: Method, hit: CachedTune, desc: &str) -> TuneResult {
    TuneResult {
        method,
        optimal: TuningWitness { wg: hit.wg, ts: hit.ts, time: hit.t_min, steps: hit.steps },
        t_min: hit.t_min,
        first_trail: None,
        first_trail_optimality: None,
        states_explored: 0,
        peak_bytes: 0,
        elapsed: Duration::ZERO,
        log: vec![format!("cache hit: {}", desc)],
    }
}

/// Cache-aware [`tune`]: a hit short-circuits verification entirely (the
/// returned result reports zero states explored); a miss runs [`tune`]
/// and stores the optimum under `cache_desc`. Returns the result and
/// whether it was served from the cache.
pub fn tune_cached<M, C>(
    model: &M,
    method: Method,
    check_opts: &CheckOptions,
    swarm_cfg: &SwarmConfig,
    t_ini_override: Option<i64>,
    cache_desc: &str,
    cache: &mut C,
) -> Result<(TuneResult, bool)>
where
    M: TransitionSystem + Sync,
    M::State: Send,
    C: TuneCache + ?Sized,
{
    if let Some(hit) = cache.lookup(cache_desc) {
        return Ok((cached_result(method, hit, cache_desc), true));
    }
    let r = tune(model, method, check_opts, swarm_cfg, t_ini_override)?;
    cache.store(cache_desc, &r);
    Ok((r, false))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::{AbstractModel, Granularity, MinModel, PlatformConfig};

    #[test]
    fn both_methods_agree_on_optimum() {
        let m = AbstractModel::new(32, PlatformConfig::default(), Granularity::Phase).unwrap();
        let (opt_time, _) = m.optimum();
        let ex = tune(&m, Method::Exhaustive, &CheckOptions::default(), &SwarmConfig::default(), None).unwrap();
        let sw = tune(&m, Method::Swarm, &CheckOptions::default(), &SwarmConfig::default(), None).unwrap();
        assert_eq!(ex.t_min, opt_time as i64);
        assert_eq!(sw.t_min, opt_time as i64);
        assert_eq!(ex.optimal.time, sw.optimal.time);
        assert!(!ex.log.is_empty() && !sw.log.is_empty());
    }

    #[test]
    fn tune_min_model_witness_is_valid_tuning() {
        let m = MinModel::paper(64, 4).unwrap();
        let r = tune(&m, Method::Exhaustive, &CheckOptions::default(), &SwarmConfig::default(), None).unwrap();
        assert!(m
            .tunings()
            .iter()
            .any(|t| t.wg == r.optimal.wg && t.ts == r.optimal.ts));
    }

    #[test]
    fn method_parsing() {
        assert_eq!("exhaustive".parse::<Method>().unwrap(), Method::Exhaustive);
        assert_eq!("swarm".parse::<Method>().unwrap(), Method::Swarm);
        assert!("annealing".parse::<Method>().is_err());
    }

    #[test]
    fn search_mode_parsing_and_default() {
        assert_eq!(SearchMode::default(), SearchMode::Exhaustive);
        assert_eq!("exhaustive".parse::<SearchMode>().unwrap(), SearchMode::Exhaustive);
        assert_eq!("surrogate".parse::<SearchMode>().unwrap(), SearchMode::Surrogate);
        assert!("bayesian".parse::<SearchMode>().is_err());
        assert_eq!(SearchMode::Surrogate.to_string(), "surrogate");
    }
}
