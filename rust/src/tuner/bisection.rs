//! The bisection method for the minimal termination time (paper Fig. 1).
//!
//! Predicate `Cex(T)` = "the checker produces a counterexample for
//! Φo = G(FIN → time > T)", i.e. some run terminates within T. Starting
//! from a sound upper bound `T_ini` (obtained by simulation, §2 Step 3),
//! bisect down to the smallest T with `Cex(T)`; `Cex(T_min)` holds and
//! `Cex(T_min − 1)` provably fails, so T_min is the minimal model time and
//! its witness trail carries the optimal (WG, TS).

use super::extract::{extract, extract_sorted, TuningWitness};
use crate::checker::{check, CheckOptions};
use crate::model::{SafetyLtl, TransitionSystem};
use crate::util::error::{bail, Context, Result};
use std::time::Duration;

#[derive(Debug, Clone)]
pub struct BisectionIter {
    pub t: i64,
    pub cex_found: bool,
    pub states_stored: u64,
    pub elapsed: Duration,
}

#[derive(Debug)]
pub struct BisectionResult {
    pub t_min: i64,
    pub witness: TuningWitness,
    pub iterations: Vec<BisectionIter>,
    /// first counterexample ever found (the paper's "1st trail" column):
    /// the quickest sub-optimal answer and how long it took
    pub first_trail: Option<(TuningWitness, Duration)>,
    pub total_states: u64,
    pub peak_bytes: u64,
    pub total_elapsed: Duration,
}

impl BisectionResult {
    /// Paper Table 1 last column: optimality of the first trail as the
    /// ratio of the optimal model time to the first-trail model time.
    pub fn first_trail_optimality(&self) -> Option<f64> {
        self.first_trail
            .as_ref()
            .map(|(w, _)| self.t_min as f64 / w.time as f64)
    }
}

/// Run Fig. 1. `opts` configures each inner verification (store kind,
/// budgets, `threads` — each `Cex(T)` query runs on the parallel engine
/// when enabled). `t_ini` must satisfy `Cex(t_ini)`; when it does not
/// (e.g. a too-small simulation bound), it is doubled until it does.
pub fn bisection<M>(model: &M, opts: &CheckOptions, t_ini: i64) -> Result<BisectionResult>
where
    M: TransitionSystem + Sync,
    M::State: Send,
{
    let start = std::time::Instant::now();
    let mut iterations = Vec::new();
    let mut total_states = 0u64;
    let mut peak_bytes = 0u64;
    let mut first_trail: Option<(TuningWitness, Duration)> = None;
    #[allow(unused_assignments)] // initialized for the bail path below
    let mut best_witness: Option<TuningWitness> = None;

    // collect_all on the *first* conclusive run would be wasteful; each
    // Cex(T) query stops at the first counterexample.
    let mut cex = |t: i64| -> Result<Option<TuningWitness>> {
        let prop = SafetyLtl::over_time(t);
        let rep = check(model, &prop, opts)
            .with_context(|| format!("verifying {} failed", prop))?;
        total_states += rep.stats.states_stored;
        peak_bytes = peak_bytes.max(rep.stats.bytes_used);
        let found = rep.found();
        iterations.push(BisectionIter {
            t,
            cex_found: found,
            states_stored: rep.stats.states_stored,
            elapsed: rep.stats.elapsed,
        });
        if found {
            let ws = extract_sorted(model, rep.violations.iter())?;
            let w = ws[0];
            if first_trail.is_none() {
                let v0 = &rep.violations[0];
                first_trail = Some((extract(model, v0)?, start.elapsed()));
            }
            Ok(Some(w))
        } else {
            // "no counterexample" is only meaningful when exhaustive
            rep.verdict().context(
                "Cex(T) inconclusive: raise budgets or use the swarm method",
            )?;
            Ok(None)
        }
    };

    // establish a valid upper bound
    let mut hi = t_ini.max(1);
    let mut grow = 0;
    loop {
        match cex(hi)? {
            Some(w) => {
                best_witness = Some(w);
                // the witness time is itself a (possibly much) tighter hi
                hi = w.time;
                break;
            }
            None => {
                grow += 1;
                if grow > 62 {
                    bail!("no terminating run found below T = 2^62 — model deadlocks?");
                }
                hi = hi.saturating_mul(2);
            }
        }
    }

    // bisect: invariant Cex(hi) ∧ ¬Cex(lo)
    let mut lo = 0i64;
    while hi - lo > 1 {
        let mid = lo + (hi - lo) / 2;
        match cex(mid)? {
            Some(w) => {
                best_witness = Some(w);
                hi = w.time.min(mid); // witness time is ≤ mid and achievable
            }
            None => lo = mid,
        }
    }

    Ok(BisectionResult {
        t_min: hi,
        witness: best_witness.expect("Cex(hi) held at least once"),
        iterations,
        first_trail,
        total_states,
        peak_bytes,
        total_elapsed: start.elapsed(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::{AbstractModel, DataInit, Granularity, MinModel, PlatformConfig};
    use crate::platform::sim::initial_bound;

    #[test]
    fn bisection_finds_exact_optimum_abstract() {
        let m = AbstractModel::new(32, PlatformConfig::default(), Granularity::Phase).unwrap();
        let (opt_time, _) = m.optimum();
        let t_ini = initial_bound(&m, 4, 7, 10_000_000).unwrap();
        let r = bisection(&m, &CheckOptions::default(), t_ini).unwrap();
        assert_eq!(r.t_min, opt_time as i64);
        // witness achieves the optimum (ties possible among tunings)
        use crate::platform::Tuning;
        let w = Tuning { wg: r.witness.wg, ts: r.witness.ts };
        assert_eq!(m.predicted_time(w), opt_time);
        assert!(r.iterations.len() >= 2);
        // last-iteration invariant: Cex(t_min) true was observed
        assert!(r.iterations.iter().any(|i| i.cex_found && i.t >= r.t_min));
    }

    #[test]
    fn bisection_finds_exact_optimum_minimum() {
        let m = MinModel::new(64, 4, 3, DataInit::Descending, Granularity::Phase).unwrap();
        let (opt_time, _) = m.optimum();
        let r = bisection(&m, &CheckOptions::default(), 100_000).unwrap();
        assert_eq!(r.t_min, opt_time as i64);
        use crate::platform::Tuning;
        let w = Tuning { wg: r.witness.wg, ts: r.witness.ts };
        assert_eq!(m.predicted_time(w), opt_time);
    }

    #[test]
    fn bisection_grows_small_t_ini() {
        let m = AbstractModel::new(16, PlatformConfig::default(), Granularity::Phase).unwrap();
        let (opt_time, _) = m.optimum();
        // t_ini = 1 is below every terminal time: must grow, then converge
        let r = bisection(&m, &CheckOptions::default(), 1).unwrap();
        assert_eq!(r.t_min, opt_time as i64);
    }

    #[test]
    fn first_trail_optimality_in_unit_range() {
        let m = AbstractModel::new(32, PlatformConfig::default(), Granularity::Phase).unwrap();
        let r = bisection(&m, &CheckOptions::default(), 1_000_000).unwrap();
        let opt = r.first_trail_optimality().unwrap();
        assert!(opt > 0.0 && opt <= 1.0, "optimality {}", opt);
    }

    #[test]
    fn inconclusive_budget_is_an_error_not_a_wrong_answer() {
        let m = AbstractModel::new(64, PlatformConfig::default(), Granularity::Tick).unwrap();
        let mut o = CheckOptions::default();
        o.max_states = 50; // absurdly small
        assert!(bisection(&m, &o, 10).is_err());
    }
}
