//! Step 4 of the counterexample method (paper §4): read the optimal
//! configuration of tuning parameters off a counterexample trail.
//!
//! SPIN replays the `.trail` in simulation mode and the paper's runner
//! script greps WG/TS/time out of the simulation output; our trails expose
//! the final state directly through the model's `eval_var` interface.

use crate::model::{TransitionSystem, Violation};
use crate::util::error::{Context, Result};

/// A tuning configuration witnessed by a counterexample, with the model
/// time it achieves.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TuningWitness {
    pub wg: u32,
    pub ts: u32,
    pub time: i64,
    /// transitions on the witnessing trail (SPIN's "steps")
    pub steps: usize,
}

impl std::fmt::Display for TuningWitness {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "WG={} TS={} time={} steps={}", self.wg, self.ts, self.time, self.steps)
    }
}

/// Extract (WG, TS, time) from the final state of a violation trail.
pub fn extract<M: TransitionSystem>(model: &M, v: &Violation<M::State>) -> Result<TuningWitness> {
    let last = v.trail.last();
    let get = |name: &str| {
        model
            .eval_var(last, name)
            .with_context(|| format!("counterexample state does not expose `{}`", name))
    };
    Ok(TuningWitness {
        wg: get("WG")? as u32,
        ts: get("TS")? as u32,
        time: get("time")?,
        steps: v.trail.steps(),
    })
}

/// Extract every witness from a batch of violations and return them sorted
/// by (time, steps) — the paper's runner script that sorts all trails.
pub fn extract_sorted<'a, M, I>(model: &M, violations: I) -> Result<Vec<TuningWitness>>
where
    M: TransitionSystem,
    I: IntoIterator<Item = &'a Violation<M::State>>,
    M::State: 'a,
{
    let mut out = Vec::new();
    for v in violations {
        out.push(extract(model, v)?);
    }
    out.sort_by_key(|w| (w.time, w.steps, w.wg, w.ts));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checker::{check, CheckOptions};
    use crate::model::SafetyLtl;
    use crate::platform::{AbstractModel, Granularity, PlatformConfig};

    #[test]
    fn extracts_wg_ts_time_from_trail() {
        let m = AbstractModel::new(16, PlatformConfig::default(), Granularity::Phase).unwrap();
        let (opt_time, opt_t) = m.optimum();
        // Φo with T = optimum: the optimal run is a counterexample
        let p = SafetyLtl::over_time(opt_time as i64);
        let mut o = CheckOptions::default();
        o.collect_all = true;
        let rep = check(&m, &p, &o).unwrap();
        assert!(rep.found());
        let ws = extract_sorted(&m, rep.violations.iter()).unwrap();
        // the best witness is the model optimum
        assert_eq!(ws[0].time, opt_time as i64);
        assert_eq!((ws[0].wg, ws[0].ts), (opt_t.wg, opt_t.ts));
        // sorted ascending
        for w in ws.windows(2) {
            assert!(w[0].time <= w[1].time);
        }
    }

    #[test]
    fn extract_fails_without_tuning_vars() {
        // initial state has no WG yet; craft a violation ending there
        let m = AbstractModel::new(16, PlatformConfig::default(), Granularity::Phase).unwrap();
        let init = m.initial_states()[0].clone();
        let v = Violation {
            trail: crate::model::Trail { states: vec![init] },
            depth: 0,
            found_after: std::time::Duration::ZERO,
        };
        assert!(extract(&m, &v).is_err());
    }
}
