//! Step 4 of the counterexample method (paper §4): read the optimal
//! configuration of tuning parameters off a counterexample trail.
//!
//! SPIN replays the `.trail` in simulation mode and the paper's runner
//! script greps WG/TS/time out of the simulation output; our trails expose
//! the final state directly through the model's `eval_var` interface.

use crate::model::{TransitionSystem, Violation};
use crate::util::error::{Context, Result};

/// A tuning configuration witnessed by a counterexample, with the model
/// time it achieves.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TuningWitness {
    pub wg: u32,
    pub ts: u32,
    pub time: i64,
    /// transitions on the witnessing trail (SPIN's "steps")
    pub steps: usize,
}

impl std::fmt::Display for TuningWitness {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "WG={} TS={} time={} steps={}", self.wg, self.ts, self.time, self.steps)
    }
}

/// Extract (WG, TS, time) from the final state of a violation trail.
pub fn extract<M: TransitionSystem>(model: &M, v: &Violation<M::State>) -> Result<TuningWitness> {
    let last = v.trail.last();
    let get = |name: &str| {
        model
            .eval_var(last, name)
            .with_context(|| format!("counterexample state does not expose `{}`", name))
    };
    Ok(TuningWitness {
        wg: get("WG")? as u32,
        ts: get("TS")? as u32,
        time: get("time")?,
        steps: v.trail.steps(),
    })
}

/// Extract every witness from a batch of violations and return them sorted
/// by (time, steps) — the paper's runner script that sorts all trails.
pub fn extract_sorted<'a, M, I>(model: &M, violations: I) -> Result<Vec<TuningWitness>>
where
    M: TransitionSystem,
    I: IntoIterator<Item = &'a Violation<M::State>>,
    M::State: 'a,
{
    let mut out = Vec::new();
    for v in violations {
        out.push(extract(model, v)?);
    }
    out.sort_by_key(|w| (w.time, w.steps, w.wg, w.ts));
    Ok(out)
}

/// Harvest surrogate-training observations from a finished tune: the
/// exact optimum plus the first-trail witness (an achievable, possibly
/// sub-optimal time — still a sound regression target). These are what
/// cache-aware callers persist as `method="obs"` rows for future
/// [`super::surrogate`] runs; duplicates collapse on the (wg, ts) key.
pub fn harvest_observations(
    result: &super::TuneResult,
    size: u32,
) -> Vec<super::surrogate::Observation> {
    use super::surrogate::Observation;
    let mut out = vec![Observation {
        wg: result.optimal.wg,
        ts: result.optimal.ts,
        size,
        time: result.optimal.time,
    }];
    if let Some((w, _)) = &result.first_trail {
        if (w.wg, w.ts) != (result.optimal.wg, result.optimal.ts) {
            out.push(Observation { wg: w.wg, ts: w.ts, size, time: w.time });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checker::{check, CheckOptions};
    use crate::model::SafetyLtl;
    use crate::platform::{AbstractModel, Granularity, PlatformConfig};

    #[test]
    fn extracts_wg_ts_time_from_trail() {
        let m = AbstractModel::new(16, PlatformConfig::default(), Granularity::Phase).unwrap();
        let (opt_time, opt_t) = m.optimum();
        // Φo with T = optimum: the optimal run is a counterexample
        let p = SafetyLtl::over_time(opt_time as i64);
        let mut o = CheckOptions::default();
        o.collect_all = true;
        let rep = check(&m, &p, &o).unwrap();
        assert!(rep.found());
        let ws = extract_sorted(&m, rep.violations.iter()).unwrap();
        // the best witness is the model optimum
        assert_eq!(ws[0].time, opt_time as i64);
        assert_eq!((ws[0].wg, ws[0].ts), (opt_t.wg, opt_t.ts));
        // sorted ascending
        for w in ws.windows(2) {
            assert!(w[0].time <= w[1].time);
        }
    }

    #[test]
    fn harvest_collects_optimum_and_distinct_first_trail() {
        use crate::tuner::{TuneResult, TuningWitness};
        use std::time::Duration;
        let base = TuneResult {
            method: crate::tuner::Method::Exhaustive,
            optimal: TuningWitness { wg: 8, ts: 2, time: 36, steps: 9 },
            t_min: 36,
            first_trail: Some((TuningWitness { wg: 2, ts: 2, time: 80, steps: 20 }, Duration::ZERO)),
            first_trail_optimality: Some(36.0 / 80.0),
            states_explored: 1,
            peak_bytes: 1,
            elapsed: Duration::ZERO,
            log: Vec::new(),
        };
        let obs = harvest_observations(&base, 64);
        assert_eq!(obs.len(), 2);
        assert_eq!((obs[0].wg, obs[0].ts, obs[0].size, obs[0].time), (8, 2, 64, 36));
        assert_eq!((obs[1].wg, obs[1].ts, obs[1].time), (2, 2, 80));
        // a first trail at the optimal coordinates is not duplicated
        let mut same = base;
        same.first_trail = Some((TuningWitness { wg: 8, ts: 2, time: 36, steps: 9 }, Duration::ZERO));
        assert_eq!(harvest_observations(&same, 64).len(), 1);
    }

    #[test]
    fn extract_fails_without_tuning_vars() {
        // initial state has no WG yet; craft a violation ending there
        let m = AbstractModel::new(16, PlatformConfig::default(), Granularity::Phase).unwrap();
        let init = m.initial_states()[0].clone();
        let v = Violation {
            trail: crate::model::Trail { states: vec![init] },
            depth: 0,
            found_after: std::time::Duration::ZERO,
        };
        assert!(extract(&m, &v).is_err());
    }
}
