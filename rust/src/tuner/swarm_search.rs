//! The swarm search method (paper Fig. 5, §5) — the non-bisection strategy
//! for inputs whose state space exceeds the exhaustive-mode memory budget.
//!
//! 1. Swarm-verify Φt = G(¬FIN): every counterexample is a terminating
//!    run; take the minimal termination time among them.
//! 2. Repeatedly swarm Φo = G(FIN → time > T−1) with T the current best:
//!    a counterexample is a strictly better run. Stop when a swarm round
//!    finds nothing within (roughly) the previous round's execution time —
//!    the paper's stopping criterion ("if the swarm does not find a
//!    counterexample as quickly as at the previous launching, a smaller
//!    time does not exist with very high probability").

use super::extract::{extract_sorted, TuningWitness};
use crate::model::{SafetyLtl, TransitionSystem};
use crate::swarm::{swarm, SwarmConfig};
use crate::util::error::{bail, Result};
use std::time::Duration;

#[derive(Debug, Clone)]
pub struct SwarmIter {
    /// bound used this round (None = the initial Φt round)
    pub bound: Option<i64>,
    pub cex_count: usize,
    pub best_time: Option<i64>,
    pub elapsed: Duration,
    pub states: u64,
}

#[derive(Debug)]
pub struct SwarmSearchResult {
    pub t_min: i64,
    pub witness: TuningWitness,
    pub iterations: Vec<SwarmIter>,
    pub first_trail: Option<(TuningWitness, Duration)>,
    pub total_states: u64,
    pub total_bytes: u64,
    pub total_elapsed: Duration,
}

impl SwarmSearchResult {
    pub fn first_trail_optimality(&self) -> Option<f64> {
        self.first_trail.as_ref().map(|(w, _)| self.t_min as f64 / w.time as f64)
    }
}

/// Run Fig. 5 with `cfg` as the per-round swarm configuration. The per
/// round time budget adapts: each Φo round gets the previous round's
/// execution time (clamped to cfg.time_budget as a maximum).
pub fn swarm_search<M>(model: &M, cfg: &SwarmConfig) -> Result<SwarmSearchResult>
where
    M: TransitionSystem + Sync,
    M::State: Send,
{
    let start = std::time::Instant::now();
    let mut iterations = Vec::new();
    let mut total_states = 0u64;
    let mut total_bytes = 0u64;

    // Round 0: Φt — harvest terminating runs.
    let rep = swarm(model, &SafetyLtl::non_termination(), cfg)?;
    total_states += rep.total_states();
    total_bytes = total_bytes.max(rep.total_bytes());
    let mut witnesses = extract_sorted(model, rep.violations())?;
    iterations.push(SwarmIter {
        bound: None,
        cex_count: witnesses.len(),
        best_time: witnesses.first().map(|w| w.time),
        elapsed: rep.elapsed,
        states: rep.total_states(),
    });
    if witnesses.is_empty() {
        bail!(
            "swarm found no terminating run (Φt has no counterexample within \
             the budget) — increase workers, depth, or time budget"
        );
    }
    let first_trail = {
        // the first violation in wall-clock order across workers
        let mut first: Option<(TuningWitness, Duration)> = None;
        for v in rep.violations() {
            let w = extract_sorted(model, std::iter::once(v))?[0];
            if first.as_ref().map_or(true, |(_, d)| v.found_after < *d) {
                first = Some((w, v.found_after));
            }
        }
        first
    };

    let mut best = witnesses[0];
    let mut prev_exec = rep.elapsed;

    // Φo rounds: tighten the bound until a round comes back empty.
    let mut round_seed_bump = 1u64;
    loop {
        if best.time <= 1 {
            break;
        }
        let bound = best.time - 1;
        let mut round_cfg = cfg.clone();
        // paper's criterion: give the round the previous execution time
        round_cfg.time_budget = prev_exec.max(Duration::from_millis(50)).min(cfg.time_budget);
        // re-seed so each round explores differently
        round_cfg.seed = cfg.seed.wrapping_add(round_seed_bump);
        round_seed_bump += 1;

        let prop = SafetyLtl::over_time(bound);
        let rep = swarm(model, &prop, &round_cfg)?;
        total_states += rep.total_states();
        total_bytes = total_bytes.max(rep.total_bytes());
        witnesses = extract_sorted(model, rep.violations())?;
        iterations.push(SwarmIter {
            bound: Some(bound),
            cex_count: witnesses.len(),
            best_time: witnesses.first().map(|w| w.time),
            elapsed: rep.elapsed,
            states: rep.total_states(),
        });
        match witnesses.first() {
            Some(&w) if w.time < best.time => {
                best = w;
                prev_exec = rep.elapsed;
            }
            _ => break, // no smaller time found as quickly: stop (Fig. 5)
        }
    }

    Ok(SwarmSearchResult {
        t_min: best.time,
        witness: best,
        iterations,
        first_trail,
        total_states,
        total_bytes,
        total_elapsed: start.elapsed(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::{AbstractModel, Granularity, MinModel, PlatformConfig};

    fn test_cfg() -> SwarmConfig {
        SwarmConfig {
            workers: 2,
            time_budget: Duration::from_secs(5),
            log2_bits: 22,
            ..Default::default()
        }
    }

    #[test]
    fn swarm_search_reaches_optimum_on_small_models() {
        // On small models the swarm covers the whole tuning space, so it
        // must land on the true optimum.
        let m = AbstractModel::new(32, PlatformConfig::default(), Granularity::Phase).unwrap();
        let (opt_time, _) = m.optimum();
        let r = swarm_search(&m, &test_cfg()).unwrap();
        assert_eq!(r.t_min, opt_time as i64);
        assert!(r.iterations.len() >= 2, "at least Φt round + one Φo round");
        // iteration log: first round is Φt, later rounds carry bounds
        assert!(r.iterations[0].bound.is_none());
        assert!(r.iterations[1..].iter().all(|i| i.bound.is_some()));
    }

    #[test]
    fn swarm_search_min_model() {
        let m = MinModel::paper(128, 4).unwrap();
        let (opt_time, _) = m.optimum();
        let r = swarm_search(&m, &test_cfg()).unwrap();
        assert_eq!(r.t_min, opt_time as i64);
        // several tunings may tie at the optimum; the witness must achieve it
        use crate::platform::Tuning;
        let w = Tuning { wg: r.witness.wg, ts: r.witness.ts };
        assert_eq!(m.predicted_time(w), opt_time);
        assert!(r.first_trail_optimality().unwrap() <= 1.0);
    }

    #[test]
    fn bounds_strictly_decrease() {
        let m = AbstractModel::new(64, PlatformConfig::default(), Granularity::Phase).unwrap();
        let r = swarm_search(&m, &test_cfg()).unwrap();
        let bounds: Vec<i64> = r.iterations.iter().filter_map(|i| i.bound).collect();
        for w in bounds.windows(2) {
            assert!(w[1] < w[0], "bounds must tighten: {:?}", bounds);
        }
    }
}
