//! Surrogate-guided search: near-optimal configs in a fraction of the
//! checker evaluations.
//!
//! Exhaustive tuning ([`super::bisection`]) pays one full-lattice sweep
//! per `Cex(T)` query — ~log(T_ini) sweeps per job. This module replaces
//! most of those sweeps with a cheap learned ranking plus a handful of
//! *point-oracle* evaluations, while keeping the answer exact:
//!
//! - **Proposer**: a dependency-free distance-weighted k-NN regressor
//!   ([`predict`]) over log-scaled (WG, TS, input-size) features, fitted
//!   to observations harvested from prior runs (the result cache's
//!   `method="obs"` rows — see `coordinator::cache`). Each round proposes
//!   the best-predicted unevaluated configs plus one seeded-random
//!   exploration pick ([`crate::util::rng::Xoshiro256`], fixed seed, so
//!   runs reproduce).
//! - **Oracle**: each proposal is evaluated *exactly* by restricting the
//!   model to that single (WG, TS) — a singleton
//!   [`TuningShard`] behind [`ShardModel`] — and bisecting; the shard
//!   state space is one tuning branch, orders of magnitude below a
//!   full-lattice sweep. The best evaluated time `T*` is achievable by
//!   construction.
//! - **Certificate**: one `collect_all` check of `Φo(T*)` over the full
//!   lattice. `T*` is achievable, so a counterexample always exists, the
//!   global optimum's run is among the collected violations (its time
//!   `t_min <= T*`), and [`extract_sorted`]`[0]` is therefore the exact
//!   optimum under the canonical `(time, steps, WG, TS)` tie-break — the
//!   differential guarantee against `--search exhaustive` holds no
//!   matter how wrong the predictions were. Poisoned or stale
//!   observations can only cost extra point evaluations, never a wrong
//!   answer.
//! - **Fallback**: with fewer than [`SurrogateOptions::min_obs`]
//!   observations or a lattice below [`SurrogateOptions::min_lattice`]
//!   configs, the search falls back to plain exhaustive [`tune`] (the
//!   regressor would be noise); the fallback still reports its checker
//!   invocations through `surrogate.oracle_calls`, so a warm re-run's
//!   strictly lower count is observable in the trace.
//!
//! Point evaluations are capped well below the lattice size
//! ([`eval_cap`]), so a warm-cache run's `surrogate.oracle_calls` —
//! point evaluations plus the one certificate sweep — stays strictly
//! below the lattice size.

use super::bisection::bisection;
use super::extract::{extract_sorted, TuningWitness};
use super::{tune, Method, TuneResult};
use crate::checker::{check, CheckOptions};
use crate::coordinator::shard::{ShardModel, TuningShard};
use crate::model::{SafetyLtl, TransitionSystem};
use crate::platform::Tuning;
use crate::swarm::SwarmConfig;
use crate::util::error::{ensure, Context, Result};
use crate::util::rng::Xoshiro256;

/// One harvested (config, input size) → model-time measurement. `time`
/// is exact for observations recorded by a point oracle or a completed
/// tune, and an achievable upper bound for first-trail harvests — either
/// way a sound regression target.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Observation {
    pub wg: u32,
    pub ts: u32,
    /// input size of the run that produced the measurement (cross-size
    /// neighbor warm-start: same-family observations at other sizes
    /// still rank candidates for a new size)
    pub size: u32,
    pub time: i64,
}

/// Tunables of the surrogate loop. Defaults keep every knob conservative
/// enough that the oracle-call cap stays strictly below the lattice size.
#[derive(Debug, Clone)]
pub struct SurrogateOptions {
    /// fewer prior observations than this → fall back to exhaustive
    pub min_obs: usize,
    /// fewer lattice configs than this → fall back to exhaustive
    pub min_lattice: usize,
    /// k-NN neighborhood size
    pub k: usize,
    /// best-predicted proposals per round
    pub batch: usize,
    /// seeded-random exploration proposals per round
    pub explore: usize,
    /// convergence window: stop proposing after this many consecutive
    /// rounds without an incumbent improvement
    pub window: usize,
    /// hard round cap
    pub max_rounds: usize,
    /// deterministic exploration seed
    pub seed: u64,
}

impl Default for SurrogateOptions {
    fn default() -> Self {
        Self {
            min_obs: 3,
            min_lattice: 4,
            k: 4,
            batch: 2,
            explore: 1,
            window: 2,
            max_rounds: 8,
            seed: 0x5ab0_7a6e,
        }
    }
}

/// A [`tune`]-shaped result plus the surrogate bookkeeping callers
/// persist (exact point evaluations become cache observations) and
/// assert on (oracle-call accounting).
#[derive(Debug)]
pub struct SurrogateReport {
    pub result: TuneResult,
    /// exact per-config measurements made by the point oracle this run —
    /// the caller records them as cache observations for future runs
    pub evals: Vec<Observation>,
    /// true when the search degraded to plain exhaustive [`tune`]
    pub fell_back: bool,
    /// checker invocations: point-oracle bisections + the certificate
    /// sweep (or, on fallback, the exhaustive bisection's `Cex` queries)
    pub oracle_calls: u64,
    /// candidate configs proposed (0 on fallback)
    pub proposals: u64,
}

/// Distance-weighted k-NN prediction of the model time of `t` at `size`
/// from `obs`. Features are `ln(1 + x)` so the power-of-two lattice axes
/// and the input size contribute comparable distances. Deterministic:
/// ties in distance break on (time, wg, ts).
pub fn predict(obs: &[Observation], t: Tuning, size: u32, k: usize) -> f64 {
    debug_assert!(!obs.is_empty(), "predict() needs at least one observation");
    let feat =
        |wg: u32, ts: u32, sz: u32| [f64::from(wg).ln_1p(), f64::from(ts).ln_1p(), f64::from(sz).ln_1p()];
    let q = feat(t.wg, t.ts, size);
    let mut near: Vec<(f64, i64, u32, u32)> = obs
        .iter()
        .map(|o| {
            let f = feat(o.wg, o.ts, o.size);
            let d2: f64 = (0..3).map(|i| (f[i] - q[i]) * (f[i] - q[i])).sum();
            (d2, o.time, o.wg, o.ts)
        })
        .collect();
    near.sort_by(|a, b| a.0.total_cmp(&b.0).then_with(|| (a.1, a.2, a.3).cmp(&(b.1, b.2, b.3))));
    let k = k.max(1).min(near.len());
    let mut num = 0.0;
    let mut den = 0.0;
    for &(d2, time, _, _) in near.iter().take(k) {
        let w = 1.0 / (d2 + 1e-6);
        num += w * time as f64;
        den += w;
    }
    num / den
}

/// Point-evaluation cap: strictly below the lattice size by at least the
/// certificate sweep (so `oracle_calls = evals + 1 < lattice` holds on
/// every surrogate-path run), at least one, and roomy enough for one
/// full proposal round on small lattices.
pub fn eval_cap(cfg: &SurrogateOptions, lattice: usize) -> usize {
    (cfg.batch + cfg.explore).max(lattice / 4).min(lattice.saturating_sub(2)).max(1)
}

/// Exact cost of one config: bisection on the model restricted to the
/// singleton shard `{t}`. The restricted state space is a single tuning
/// branch, so each inner `Cex` query is cheap. `hint` (a prediction) is
/// only a starting bound — bisection doubles its way out of
/// underestimates, so a poisoned hint cannot change the answer.
fn point_eval<M>(
    model: &M,
    opts: &CheckOptions,
    t: Tuning,
    hint: f64,
) -> Result<(TuningWitness, u64, u64)>
where
    M: TransitionSystem + Sync,
    M::State: Send,
{
    let shard = TuningShard { wg_min: t.wg, wg_max: t.wg, ts_min: t.ts, ts_max: t.ts };
    let sm = ShardModel::new(model, shard);
    let t_ini = hint.max(1.0).min((1i64 << 60) as f64) as i64;
    let r = bisection(&sm, opts, t_ini)
        .with_context(|| format!("point oracle for WG={} TS={}", t.wg, t.ts))?;
    Ok((r.witness, r.total_states, r.peak_bytes))
}

fn witness_better(a: &TuningWitness, b: &TuningWitness) -> bool {
    (a.time, a.steps, a.wg, a.ts) < (b.time, b.steps, b.wg, b.ts)
}

fn search_event(fields: Vec<(&str, crate::util::manifest::Json)>) {
    if let Some(rec) = crate::obs::active() {
        rec.det_event("search", fields);
    }
}

/// Surrogate-guided tuning of `model` over `lattice` (the full (WG, TS)
/// space, or one batch shard's sub-lattice). `seeds` are prior
/// observations (cache harvest, cross-size neighbors included); `size`
/// is the current job's input size (a regressor feature). Exactness does
/// not depend on the seeds — see the module docs for the
/// proposer/oracle/certificate/fallback contract.
///
/// The returned [`TuneResult`] carries `Method::Exhaustive`: the result
/// *is* the exhaustive optimum (same value, same canonical tie-break),
/// so cache entries written from it are interchangeable with exhaustive
/// ones.
#[allow(clippy::too_many_arguments)]
pub fn surrogate_tune<M>(
    model: &M,
    check_opts: &CheckOptions,
    swarm_cfg: &SwarmConfig,
    t_ini_override: Option<i64>,
    lattice: &[Tuning],
    size: u32,
    seeds: &[Observation],
    cfg: &SurrogateOptions,
) -> Result<SurrogateReport>
where
    M: TransitionSystem + Sync,
    M::State: Send,
{
    ensure!(!lattice.is_empty(), "surrogate search over an empty tuning lattice");
    let metrics = crate::obs::metrics();
    if seeds.len() < cfg.min_obs || lattice.len() < cfg.min_lattice {
        let mut r = tune(model, Method::Exhaustive, check_opts, swarm_cfg, t_ini_override)?;
        // one exhaustive log line per Cex(T) query = checker invocations
        let oracle_calls = r.log.len() as u64;
        metrics.surrogate_oracle_calls.add(oracle_calls);
        r.log.insert(
            0,
            format!(
                "surrogate: {} observation(s) < {} or lattice {} < {} — exhaustive fallback",
                seeds.len(),
                cfg.min_obs,
                lattice.len(),
                cfg.min_lattice
            ),
        );
        search_event(vec![
            ("kind", crate::util::manifest::Json::Str("fallback".into())),
            ("obs", crate::obs::ju64(seeds.len() as u64)),
            ("lattice", crate::obs::ju64(lattice.len() as u64)),
            ("oracle_calls", crate::obs::ju64(oracle_calls)),
        ]);
        let evals = vec![Observation {
            wg: r.optimal.wg,
            ts: r.optimal.ts,
            size,
            time: r.optimal.time,
        }];
        return Ok(SurrogateReport { result: r, evals, fell_back: true, oracle_calls, proposals: 0 });
    }

    use crate::obs::ju64;
    use crate::util::manifest::Json;
    let start = std::time::Instant::now();
    metrics.surrogate_cache_seeds.add(seeds.len() as u64);
    let cap = eval_cap(cfg, lattice.len());
    let mut log = vec![format!(
        "surrogate: {} observation(s), lattice {} configs, eval cap {}",
        seeds.len(),
        lattice.len(),
        cap
    )];
    // the working observation set: cache seeds + this run's exact evals
    // (exact same-size points quickly dominate the k-NN neighborhoods)
    let mut obs: Vec<Observation> = seeds.to_vec();
    let mut evals: Vec<Observation> = Vec::new();
    let mut incumbent: Option<TuningWitness> = None;
    let mut first_trail: Option<(TuningWitness, std::time::Duration)> = None;
    let mut states = 0u64;
    let mut peak = 0u64;
    let mut oracle_calls = 0u64;
    let mut proposals = 0u64;
    let mut rng = Xoshiro256::new(cfg.seed);
    let mut stale = 0usize;
    let evaluated =
        |evals: &[Observation], t: Tuning| evals.iter().any(|e| e.wg == t.wg && e.ts == t.ts);

    'rounds: for round in 0..cfg.max_rounds {
        if evals.len() >= cap {
            break;
        }
        // rank every unevaluated config by predicted time (deterministic
        // tie-break on the lattice coordinates)
        let mut cands: Vec<(f64, Tuning)> = lattice
            .iter()
            .filter(|&&t| !evaluated(&evals, t))
            .map(|&t| (predict(&obs, t, size, cfg.k), t))
            .collect();
        if cands.is_empty() {
            break;
        }
        cands.sort_by(|a, b| {
            a.0.total_cmp(&b.0).then_with(|| (a.1.wg, a.1.ts).cmp(&(b.1.wg, b.1.ts)))
        });
        let mut picks: Vec<(Tuning, f64)> =
            cands.iter().take(cfg.batch).map(|&(p, t)| (t, p)).collect();
        for _ in 0..cfg.explore {
            let rest: Vec<&(f64, Tuning)> = cands
                .iter()
                .skip(cfg.batch)
                .filter(|(_, t)| !picks.iter().any(|(p, _)| p.wg == t.wg && p.ts == t.ts))
                .collect();
            if rest.is_empty() {
                break;
            }
            let &(p, t) = rest[rng.below(rest.len() as u64) as usize];
            picks.push((t, p));
        }
        let before = incumbent.map(|w| w.time);
        for (t, pred) in picks {
            if evals.len() >= cap {
                break;
            }
            proposals += 1;
            metrics.surrogate_proposals.add(1);
            oracle_calls += 1;
            metrics.surrogate_oracle_calls.add(1);
            match point_eval(model, check_opts, t, pred) {
                Ok((w, st, by)) => {
                    states += st;
                    peak = peak.max(by);
                    evals.push(Observation { wg: t.wg, ts: t.ts, size, time: w.time });
                    obs.push(Observation { wg: t.wg, ts: t.ts, size, time: w.time });
                    if first_trail.is_none() {
                        first_trail = Some((w, start.elapsed()));
                    }
                    if incumbent.map_or(true, |inc| witness_better(&w, &inc)) {
                        incumbent = Some(w);
                    }
                    log.push(format!(
                        "round {}: WG={} TS={} predicted {} -> exact {} [{} states]",
                        round, t.wg, t.ts, pred as i64, w.time, st
                    ));
                    search_event(vec![
                        ("kind", Json::Str("eval".into())),
                        ("round", ju64(round as u64)),
                        ("wg", Json::Int(t.wg as i64)),
                        ("ts", Json::Int(t.ts as i64)),
                        ("predicted", Json::Int(pred as i64)),
                        ("actual", Json::Int(w.time)),
                    ]);
                }
                Err(e) => {
                    // an unachievable config (external sources may never
                    // reach a lattice point) costs its oracle call but
                    // cannot poison the result; mark it evaluated so it
                    // is never re-proposed
                    evals.push(Observation { wg: t.wg, ts: t.ts, size, time: i64::MAX });
                    log.push(format!("round {}: WG={} TS={} unachievable ({:#})", round, t.wg, t.ts, e));
                }
            }
        }
        match (before, incumbent.map(|w| w.time)) {
            (Some(b), Some(now)) if now >= b => {
                stale += 1;
                if stale >= cfg.window {
                    log.push(format!(
                        "converged: no improvement for {} round(s), incumbent T={}",
                        stale, now
                    ));
                    break 'rounds;
                }
            }
            _ => stale = 0,
        }
    }

    let Some(inc) = incumbent else {
        // every proposal was unachievable — the predictions told us
        // nothing; degrade to the exhaustive path rather than guess
        let mut r = tune(model, Method::Exhaustive, check_opts, swarm_cfg, t_ini_override)?;
        let fallback_calls = r.log.len() as u64;
        oracle_calls += fallback_calls;
        metrics.surrogate_oracle_calls.add(fallback_calls);
        r.log.insert(0, "surrogate: no proposal was achievable — exhaustive fallback".into());
        let evals = vec![Observation {
            wg: r.optimal.wg,
            ts: r.optimal.ts,
            size,
            time: r.optimal.time,
        }];
        return Ok(SurrogateReport { result: r, evals, fell_back: true, oracle_calls, proposals });
    };

    // certificate: one collect-all sweep at the achievable incumbent
    // bound T*. The optimal run has time <= T*, so it is among the
    // collected violations and the canonical sort finds it.
    let mut copts = check_opts.clone();
    copts.collect_all = true;
    let prop = SafetyLtl::over_time(inc.time);
    let rep = check(model, &prop, &copts)
        .with_context(|| format!("surrogate certificate: verifying {} failed", prop))?;
    oracle_calls += 1;
    metrics.surrogate_oracle_calls.add(1);
    states += rep.stats.states_stored;
    peak = peak.max(rep.stats.bytes_used);
    ensure!(
        rep.found(),
        "surrogate certificate found no counterexample at achievable T={}",
        inc.time
    );
    let ws = extract_sorted(model, rep.violations.iter())?;
    let best = ws[0];
    log.push(format!(
        "certificate: Cex(T={}) collect-all -> optimum WG={} TS={} time={} [{} states]",
        inc.time, best.wg, best.ts, best.time, rep.stats.states_stored
    ));
    log.push(format!(
        "surrogate: {} oracle call(s) for a {}-config lattice",
        oracle_calls,
        lattice.len()
    ));
    search_event(vec![
        ("kind", Json::Str("certificate".into())),
        ("wg", Json::Int(best.wg as i64)),
        ("ts", Json::Int(best.ts as i64)),
        ("t_min", Json::Int(best.time)),
        ("oracle_calls", ju64(oracle_calls)),
        ("lattice", ju64(lattice.len() as u64)),
    ]);
    // the certificate's optimum is exact — record it as an observation
    if !evals.iter().any(|e| e.wg == best.wg && e.ts == best.ts && e.time == best.time) {
        evals.push(Observation { wg: best.wg, ts: best.ts, size, time: best.time });
    }
    evals.retain(|e| e.time != i64::MAX); // drop unachievable markers
    let result = TuneResult {
        method: Method::Exhaustive,
        optimal: best,
        t_min: best.time,
        first_trail_optimality: first_trail.as_ref().map(|(w, _)| best.time as f64 / w.time as f64),
        first_trail,
        states_explored: states,
        peak_bytes: peak,
        elapsed: start.elapsed(),
        log,
    };
    Ok(SurrogateReport { result, evals, fell_back: false, oracle_calls, proposals })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::{enumerate_tunings, MinModel};

    fn seeds_for(m: &MinModel, size: u32, n: usize) -> Vec<Observation> {
        // honest observations from the model's closed form
        m.tunings()
            .iter()
            .take(n)
            .map(|&t| Observation { wg: t.wg, ts: t.ts, size, time: m.predicted_time(t) as i64 })
            .collect()
    }

    #[test]
    fn predict_interpolates_and_is_deterministic() {
        let obs = vec![
            Observation { wg: 2, ts: 2, size: 64, time: 100 },
            Observation { wg: 8, ts: 2, size: 64, time: 40 },
            Observation { wg: 32, ts: 2, size: 64, time: 90 },
        ];
        let t = Tuning { wg: 8, ts: 2 };
        let p = predict(&obs, t, 64, 2);
        assert!(p > 0.0 && p.is_finite());
        // an exact-coordinate observation dominates its own prediction
        assert!((p - 40.0).abs() < 5.0, "prediction {} far from the exact neighbor", p);
        assert_eq!(p.to_bits(), predict(&obs, t, 64, 2).to_bits(), "must be deterministic");
    }

    #[test]
    fn eval_cap_stays_strictly_below_lattice() {
        let cfg = SurrogateOptions::default();
        for l in 4..200 {
            let cap = eval_cap(&cfg, l);
            assert!(cap >= 1);
            assert!(cap + 1 < l || l < 4, "cap {} + certificate not < lattice {}", cap, l);
        }
    }

    #[test]
    fn sparse_observations_fall_back_to_exhaustive() {
        let m = MinModel::paper(64, 4).unwrap();
        let (opt_time, _) = m.optimum();
        let lattice = enumerate_tunings(64).unwrap();
        let rep = surrogate_tune(
            &m,
            &CheckOptions::default(),
            &SwarmConfig::default(),
            Some(100_000),
            &lattice,
            64,
            &[],
            &SurrogateOptions::default(),
        )
        .unwrap();
        assert!(rep.fell_back);
        assert_eq!(rep.result.t_min, opt_time as i64);
        assert!(rep.oracle_calls > 0);
        assert!(!rep.evals.is_empty(), "fallback still harvests the optimum");
    }

    #[test]
    fn seeded_surrogate_matches_exhaustive_with_fewer_oracle_calls() {
        let m = MinModel::paper(64, 4).unwrap();
        let (opt_time, _) = m.optimum();
        let lattice = enumerate_tunings(64).unwrap();
        let seeds = seeds_for(&m, 64, 5);
        let rep = surrogate_tune(
            &m,
            &CheckOptions::default(),
            &SwarmConfig::default(),
            Some(100_000),
            &lattice,
            64,
            &seeds,
            &SurrogateOptions::default(),
        )
        .unwrap();
        assert!(!rep.fell_back);
        assert_eq!(rep.result.t_min, opt_time as i64);
        let w = Tuning { wg: rep.result.optimal.wg, ts: rep.result.optimal.ts };
        assert_eq!(m.predicted_time(w), opt_time, "witness must achieve the optimum");
        assert!(
            rep.oracle_calls < lattice.len() as u64,
            "{} oracle calls not below lattice {}",
            rep.oracle_calls,
            lattice.len()
        );
        assert!(rep.proposals > 0);
        assert!(rep.evals.iter().all(|e| e.time != i64::MAX));
    }

    #[test]
    fn poisoned_seeds_cannot_change_the_answer() {
        let m = MinModel::paper(64, 4).unwrap();
        let (opt_time, _) = m.optimum();
        let lattice = enumerate_tunings(64).unwrap();
        // adversarial garbage: absurd times, off-lattice coordinates,
        // near-duplicates disagreeing with each other
        let seeds = vec![
            Observation { wg: 2, ts: 2, size: 64, time: 1 },
            Observation { wg: 2, ts: 2, size: 64, time: i64::MAX / 2 },
            Observation { wg: 999, ts: 777, size: 64, time: -5 },
            Observation { wg: 32, ts: 2, size: 16, time: 0 },
        ];
        let rep = surrogate_tune(
            &m,
            &CheckOptions::default(),
            &SwarmConfig::default(),
            Some(100_000),
            &lattice,
            64,
            &seeds,
            &SurrogateOptions::default(),
        )
        .unwrap();
        assert!(!rep.fell_back);
        assert_eq!(rep.result.t_min, opt_time as i64, "certificate must override the poison");
    }
}
