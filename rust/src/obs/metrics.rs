//! The metrics registry: cheap sharded atomic counters for hot paths.
//!
//! Every counter is striped across [`STRIPES`] cache-line-padded
//! `AtomicU64`s; a thread adds to its own stripe (assigned round-robin
//! on first use), so concurrent checker workers never contend on one
//! line. Reads ([`Counter::value`]) sum the stripes — reads are rare
//! (progress ticks, the final `counters` trace event), writes are the
//! hot side.
//!
//! When telemetry is disabled ([`super::enabled`] false) every `add` is
//! one relaxed bool load and an untaken branch. The checker goes
//! further: its per-state loops accumulate into plain locals and flush
//! *deltas* here only at their pre-existing amortized checkpoints, so
//! the disabled cost on the per-state path is zero instructions.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

/// Stripe count per counter (fixed: cheap modulo, bounded memory).
pub const STRIPES: usize = 16;

#[repr(align(64))]
struct Stripe(AtomicU64);

const ZERO_STRIPE: Stripe = Stripe(AtomicU64::new(0));

fn stripe_index() -> usize {
    use std::cell::Cell;
    thread_local! {
        static STRIPE: Cell<usize> = Cell::new(usize::MAX);
    }
    STRIPE.with(|s| {
        let mut v = s.get();
        if v == usize::MAX {
            static NEXT: AtomicUsize = AtomicUsize::new(0);
            v = NEXT.fetch_add(1, Ordering::Relaxed) % STRIPES;
            s.set(v);
        }
        v
    })
}

/// A monotone event counter, striped to avoid write contention.
pub struct Counter {
    stripes: [Stripe; STRIPES],
}

impl Counter {
    const fn new() -> Self {
        Self { stripes: [ZERO_STRIPE; STRIPES] }
    }

    /// Add `n` when telemetry is enabled; a no-op branch otherwise.
    #[inline]
    pub fn add(&self, n: u64) {
        if super::enabled() {
            self.stripes[stripe_index()].0.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Sum of all stripes (approximate under concurrent writers, exact
    /// once they quiesce).
    pub fn value(&self) -> u64 {
        self.stripes.iter().map(|s| s.0.load(Ordering::Relaxed)).sum()
    }

    fn reset(&self) {
        for s in &self.stripes {
            s.0.store(0, Ordering::Relaxed);
        }
    }
}

impl std::fmt::Debug for Counter {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Counter({})", self.value())
    }
}

/// A level gauge (current/peak value rather than a running total).
pub struct Gauge(AtomicU64);

impl Gauge {
    const fn new() -> Self {
        Self(AtomicU64::new(0))
    }

    /// Raise the gauge to `v` if it is higher (peak tracking).
    #[inline]
    pub fn set_max(&self, v: u64) {
        if super::enabled() {
            self.0.fetch_max(v, Ordering::Relaxed);
        }
    }

    /// Overwrite the gauge (level tracking, e.g. current frontier depth).
    #[inline]
    pub fn set(&self, v: u64) {
        if super::enabled() {
            self.0.store(v, Ordering::Relaxed);
        }
    }

    pub fn value(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }

    fn reset(&self) {
        self.0.store(0, Ordering::Relaxed);
    }
}

impl std::fmt::Debug for Gauge {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Gauge({})", self.value())
    }
}

/// Every counter and gauge the subsystem knows, by name. One static
/// instance per process ([`metrics`]); the name column is the schema
/// the final `counters` trace event and the ROADMAP document.
#[derive(Debug)]
pub struct Metrics {
    /// unique states inserted into a visited store
    pub states_stored: Counter,
    /// successor states that were already visited
    pub states_matched: Counter,
    /// transitions (successor generations) executed
    pub transitions: Counter,
    /// counterexample trail replays (backlink reconstruction walks)
    pub trail_replays: Counter,
    /// linear-probe steps across all visited-store inserts
    pub store_probes: Counter,
    /// visited-store table growths
    pub store_resizes: Counter,
    /// tasks executed by the work-stealing queue
    pub queue_executed: Counter,
    /// tasks the queue moved between workers
    pub queue_stolen: Counter,
    /// successor states emitted by the Promela bytecode VM
    pub vm_generated: Counter,
    /// off-shard successors pruned by shard-specialized VM programs
    pub vm_pruned: Counter,
    /// successor states produced by the reference tree interpreter
    pub interp_generated: Counter,
    /// result-cache hits
    pub cache_hits: Counter,
    /// result-cache misses
    pub cache_misses: Counter,
    /// worker-mode lease grants (task claims won)
    pub lease_grants: Counter,
    /// worker-mode lease heartbeats (mtime freshens)
    pub lease_heartbeats: Counter,
    /// worker-mode stale-lease reclaims
    pub lease_reclaims: Counter,
    /// worker-mode task execution attempts (including retries)
    pub task_attempts: Counter,
    /// tasks moved to the dead-letter directory after max attempts
    pub task_dead_lettered: Counter,
    /// states expanded through a proper ample subset (`--por`)
    pub por_reduced: Counter,
    /// dead local slots canonicalized to zero before hashing
    /// (`--reduce dead-slots`, both Promela engines)
    pub slots_canonicalized: Counter,
    /// in-RAM tables frozen to disk runs (`--store spill`)
    pub spill_runs: Counter,
    /// disk-run lookups past the bloom filters (`--store spill`)
    pub spill_probes: Counter,
    /// candidate configs proposed by the surrogate ranker (`--search surrogate`)
    pub surrogate_proposals: Counter,
    /// checker invocations made by surrogate search (point-oracle
    /// bisections + certificate sweeps, or fallback `Cex` queries)
    pub surrogate_oracle_calls: Counter,
    /// cached observations loaded to warm-start surrogate runs
    pub surrogate_cache_seeds: Counter,
    /// deepest frontier depth observed
    pub depth: Gauge,
    /// peak visited-store bytes observed
    pub store_bytes: Gauge,
}

static METRICS: Metrics = Metrics {
    states_stored: Counter::new(),
    states_matched: Counter::new(),
    transitions: Counter::new(),
    trail_replays: Counter::new(),
    store_probes: Counter::new(),
    store_resizes: Counter::new(),
    queue_executed: Counter::new(),
    queue_stolen: Counter::new(),
    vm_generated: Counter::new(),
    vm_pruned: Counter::new(),
    interp_generated: Counter::new(),
    cache_hits: Counter::new(),
    cache_misses: Counter::new(),
    lease_grants: Counter::new(),
    lease_heartbeats: Counter::new(),
    lease_reclaims: Counter::new(),
    task_attempts: Counter::new(),
    task_dead_lettered: Counter::new(),
    por_reduced: Counter::new(),
    slots_canonicalized: Counter::new(),
    spill_runs: Counter::new(),
    spill_probes: Counter::new(),
    surrogate_proposals: Counter::new(),
    surrogate_oracle_calls: Counter::new(),
    surrogate_cache_seeds: Counter::new(),
    depth: Gauge::new(),
    store_bytes: Gauge::new(),
};

/// The process-global registry.
pub fn metrics() -> &'static Metrics {
    &METRICS
}

impl Metrics {
    /// Every (name, value), in fixed schema order.
    pub fn snapshot(&self) -> Vec<(&'static str, u64)> {
        vec![
            ("checker.states_stored", self.states_stored.value()),
            ("checker.states_matched", self.states_matched.value()),
            ("checker.transitions", self.transitions.value()),
            ("checker.trail_replays", self.trail_replays.value()),
            ("checker.depth_max", self.depth.value()),
            ("store.probes", self.store_probes.value()),
            ("store.resizes", self.store_resizes.value()),
            ("store.bytes_peak", self.store_bytes.value()),
            ("queue.executed", self.queue_executed.value()),
            ("queue.stolen", self.queue_stolen.value()),
            ("vm.generated", self.vm_generated.value()),
            ("vm.pruned", self.vm_pruned.value()),
            ("interp.generated", self.interp_generated.value()),
            ("cache.hits", self.cache_hits.value()),
            ("cache.misses", self.cache_misses.value()),
            ("lease.grants", self.lease_grants.value()),
            ("lease.heartbeats", self.lease_heartbeats.value()),
            ("lease.reclaims", self.lease_reclaims.value()),
            ("task.attempts", self.task_attempts.value()),
            ("task.dead_lettered", self.task_dead_lettered.value()),
            ("checker.por_reduced", self.por_reduced.value()),
            ("vm.slots_canonicalized", self.slots_canonicalized.value()),
            ("spill.runs", self.spill_runs.value()),
            ("spill.probes", self.spill_probes.value()),
            ("surrogate.proposals", self.surrogate_proposals.value()),
            ("surrogate.oracle_calls", self.surrogate_oracle_calls.value()),
            ("surrogate.cache_seeds", self.surrogate_cache_seeds.value()),
        ]
    }

    /// Zero everything (bench/test isolation).
    pub fn reset(&self) {
        self.states_stored.reset();
        self.states_matched.reset();
        self.transitions.reset();
        self.trail_replays.reset();
        self.store_probes.reset();
        self.store_resizes.reset();
        self.queue_executed.reset();
        self.queue_stolen.reset();
        self.vm_generated.reset();
        self.vm_pruned.reset();
        self.interp_generated.reset();
        self.cache_hits.reset();
        self.cache_misses.reset();
        self.lease_grants.reset();
        self.lease_heartbeats.reset();
        self.lease_reclaims.reset();
        self.task_attempts.reset();
        self.task_dead_lettered.reset();
        self.por_reduced.reset();
        self.slots_canonicalized.reset();
        self.spill_runs.reset();
        self.spill_probes.reset();
        self.surrogate_proposals.reset();
        self.surrogate_oracle_calls.reset();
        self.surrogate_cache_seeds.reset();
        self.depth.reset();
        self.store_bytes.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_gate_on_the_enabled_flag() {
        let _g = crate::obs::test_lock();
        let c = Counter::new();
        let was = crate::obs::enabled();
        crate::obs::set_enabled(false);
        c.add(5);
        assert_eq!(c.value(), 0, "disabled counters must not record");
        crate::obs::set_enabled(true);
        c.add(5);
        c.add(2);
        assert_eq!(c.value(), 7);
        let g = Gauge::new();
        g.set_max(9);
        g.set_max(4);
        assert_eq!(g.value(), 9);
        g.set(3);
        assert_eq!(g.value(), 3);
        crate::obs::set_enabled(was);
    }

    #[test]
    fn striped_adds_from_many_threads_sum_exactly() {
        let _g = crate::obs::test_lock();
        let was = crate::obs::enabled();
        crate::obs::set_enabled(true);
        static C: Counter = Counter::new();
        C.reset();
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    for _ in 0..1000 {
                        C.add(1);
                    }
                });
            }
        });
        assert_eq!(C.value(), 8000);
        crate::obs::set_enabled(was);
    }

    #[test]
    fn snapshot_names_are_unique_and_stable() {
        let snap = metrics().snapshot();
        let names: std::collections::HashSet<_> = snap.iter().map(|(n, _)| *n).collect();
        assert_eq!(names.len(), snap.len(), "duplicate metric name");
        assert!(names.contains("checker.states_stored"));
        assert!(names.contains("vm.pruned"));
        assert!(names.contains("lease.reclaims"));
    }
}
