//! Trace-file tooling: JSONL schema validation, the deterministic-line
//! filter the conformance tests compare, and the `mcautotune trace`
//! summarizer (top spans by wall time, per-shard imbalance table).

use crate::util::error::{bail, Context, Result};
use crate::util::fmt::{human_duration, thousands};
use crate::util::manifest::Json;
use std::collections::BTreeMap;
use std::time::Duration;

fn u64_field(v: &Json, key: &str) -> Result<u64> {
    match v.get(key) {
        Some(Json::Int(i)) if *i >= 0 => Ok(*i as u64),
        Some(Json::Str(s)) => s
            .parse::<u64>()
            .with_context(|| format!("field `{}`: `{}` is not a u64", key, s)),
        Some(_) => bail!("field `{}` is not a u64", key),
        None => bail!("missing field `{}`", key),
    }
}

fn str_field<'a>(v: &'a Json, key: &str) -> Result<&'a str> {
    v.get(key)
        .and_then(Json::as_str)
        .with_context(|| format!("missing string field `{}`", key))
}

/// Parse and schema-check a JSONL trace: every non-empty line must be a
/// JSON object with a string `k` kind, and the known kinds must carry
/// their required fields. Unknown kinds pass (forward compatibility).
/// Returns the parsed events.
pub fn validate(text: &str) -> Result<Vec<Json>> {
    let mut out = Vec::new();
    for (idx, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let lineno = idx + 1;
        let v = Json::parse(line).with_context(|| format!("trace line {}", lineno))?;
        let Json::Obj(_) = &v else {
            bail!("trace line {}: not a JSON object", lineno);
        };
        let kind = str_field(&v, "k")
            .with_context(|| format!("trace line {}", lineno))?
            .to_string();
        let check = || -> Result<()> {
            match kind.as_str() {
                "span" => {
                    str_field(&v, "path")?;
                    u64_field(&v, "ns")?;
                    u64_field(&v, "t_ns")?;
                }
                "run" => {
                    str_field(&v, "cmd")?;
                    u64_field(&v, "states")?;
                    // deterministic content: no timing allowed
                    if v.get("t_ns").is_some() {
                        bail!("`run` events must not carry wall-clock fields");
                    }
                }
                "shard" => {
                    str_field(&v, "id")?;
                    str_field(&v, "job")?;
                    u64_field(&v, "est")?;
                    u64_field(&v, "states")?;
                    if v.get("t_ns").is_some() {
                        bail!("`shard` events must not carry wall-clock fields");
                    }
                }
                "search" => {
                    // surrogate-search progress: fallback | eval | certificate
                    str_field(&v, "kind")?;
                    if v.get("t_ns").is_some() {
                        bail!("`search` events must not carry wall-clock fields");
                    }
                }
                "lease" => {
                    str_field(&v, "action")?;
                    str_field(&v, "id")?;
                    u64_field(&v, "t_ns")?;
                }
                "fault" => {
                    // a contained failure: panic | deadline | error |
                    // reclaim | cache_save, with free-form detail
                    str_field(&v, "class")?;
                    u64_field(&v, "t_ns")?;
                }
                "meta" | "counters" => {
                    u64_field(&v, "t_ns")?;
                }
                _ => {}
            }
            Ok(())
        };
        check().with_context(|| format!("trace line {} (kind `{}`)", lineno, kind))?;
        out.push(v);
    }
    Ok(out)
}

/// The lines whose content is pinned deterministic (`run` and `shard`
/// events), verbatim. Two `--frontier det` executions of the same work —
/// including a worker-mode duplicate of a single-process run — must
/// produce equal multisets of these lines.
pub fn deterministic_lines(text: &str) -> Vec<String> {
    text.lines()
        .filter(|l| !l.trim().is_empty())
        .filter(|l| {
            Json::parse(l)
                .ok()
                .as_ref()
                .and_then(|v| v.get("k"))
                .and_then(Json::as_str)
                .map(|k| k == "run" || k == "shard")
                .unwrap_or(false)
        })
        .map(str::to_string)
        .collect()
}

/// One shard's actual-vs-estimated telemetry from a `shard` event.
#[derive(Debug, Clone)]
pub struct ShardRow {
    pub id: String,
    pub job: String,
    /// the `ShardPlan` weight (estimated sub-lattice state-space size)
    pub est: u64,
    /// states actually explored
    pub states: u64,
}

/// What `mcautotune trace <file>` prints.
#[derive(Debug)]
pub struct TraceSummary {
    pub events: usize,
    pub by_kind: BTreeMap<String, usize>,
    /// (path, total ns, calls), heaviest first
    pub spans: Vec<(String, u64, usize)>,
    pub shards: Vec<ShardRow>,
    /// contained failures: (class, task id if any, detail), trace order
    pub faults: Vec<(String, String, String)>,
    /// the last `counters` dump, schema order
    pub counters: Vec<(String, u64)>,
}

/// Validate and aggregate a trace document.
pub fn summarize(text: &str) -> Result<TraceSummary> {
    let events = validate(text)?;
    let mut by_kind: BTreeMap<String, usize> = BTreeMap::new();
    let mut span_agg: BTreeMap<String, (u64, usize)> = BTreeMap::new();
    let mut shards: Vec<ShardRow> = Vec::new();
    let mut faults: Vec<(String, String, String)> = Vec::new();
    let mut counters: Vec<(String, u64)> = Vec::new();
    for v in &events {
        let kind = str_field(v, "k")?.to_string();
        *by_kind.entry(kind.clone()).or_insert(0) += 1;
        match kind.as_str() {
            "span" => {
                let path = str_field(v, "path")?.to_string();
                let ns = u64_field(v, "ns")?;
                let e = span_agg.entry(path).or_insert((0, 0));
                e.0 = e.0.saturating_add(ns);
                e.1 += 1;
            }
            "shard" => shards.push(ShardRow {
                id: str_field(v, "id")?.to_string(),
                job: str_field(v, "job")?.to_string(),
                est: u64_field(v, "est")?,
                states: u64_field(v, "states")?,
            }),
            "fault" => faults.push((
                str_field(v, "class")?.to_string(),
                v.get("id").and_then(Json::as_str).unwrap_or("").to_string(),
                v.get("detail").and_then(Json::as_str).unwrap_or("").to_string(),
            )),
            "counters" => {
                let Json::Obj(fields) = v else { unreachable!("validated object") };
                counters = fields
                    .iter()
                    .filter(|(name, _)| name != "k" && name != "t_ns")
                    .map(|(name, _)| Ok((name.clone(), u64_field(v, name)?)))
                    .collect::<Result<Vec<_>>>()?;
            }
            _ => {}
        }
    }
    let mut spans: Vec<(String, u64, usize)> =
        span_agg.into_iter().map(|(p, (ns, n))| (p, ns, n)).collect();
    spans.sort_by(|a, b| b.1.cmp(&a.1).then_with(|| a.0.cmp(&b.0)));
    shards.sort_by(|a, b| a.id.cmp(&b.id));
    Ok(TraceSummary { events: events.len(), by_kind, spans, shards, faults, counters })
}

impl TraceSummary {
    /// Human-readable report: event counts, top spans by wall time, the
    /// per-shard imbalance table (actual states vs. planned weight), and
    /// the final counter dump.
    pub fn render(&self) -> String {
        let mut out = format!("trace: {} event(s)", self.events);
        if !self.by_kind.is_empty() {
            let kinds: Vec<String> =
                self.by_kind.iter().map(|(k, n)| format!("{}={}", k, n)).collect();
            out.push_str(&format!(" ({})", kinds.join(", ")));
        }
        out.push('\n');
        if !self.spans.is_empty() {
            out.push_str("top spans by wall time:\n");
            for (path, ns, calls) in self.spans.iter().take(10) {
                out.push_str(&format!(
                    "  {:>10}  x{:<4} {}\n",
                    human_duration(Duration::from_nanos(*ns)),
                    calls,
                    path
                ));
            }
        }
        if !self.shards.is_empty() {
            let est_total: u64 = self.shards.iter().map(|s| s.est).sum();
            let act_total: u64 = self.shards.iter().map(|s| s.states).sum();
            out.push_str("shard imbalance (actual states vs. planned weight):\n");
            for s in &self.shards {
                let est_share = share(s.est, est_total);
                let act_share = share(s.states, act_total);
                out.push_str(&format!(
                    "  {}  {}  est {} ({:.1}%)  actual {} ({:.1}%)\n",
                    s.id,
                    s.job,
                    thousands(s.est),
                    est_share,
                    thousands(s.states),
                    act_share,
                ));
            }
        }
        if !self.faults.is_empty() {
            out.push_str("faults (contained failures):\n");
            for (class, id, detail) in &self.faults {
                let id = if id.is_empty() { "-" } else { id };
                out.push_str(&format!("  {:<9} {:<12} {}\n", class, id, detail));
            }
        }
        if !self.counters.is_empty() {
            out.push_str("counters:\n");
            for (name, v) in &self.counters {
                out.push_str(&format!("  {:<24} {}\n", name, thousands(*v)));
            }
        }
        out
    }
}

fn share(part: u64, total: u64) -> f64 {
    if total == 0 {
        0.0
    } else {
        part as f64 / total as f64 * 100.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::recorder::{ju64, Recorder};

    fn sample_trace() -> String {
        let r = Recorder::in_memory();
        r.event("meta", vec![("cmd", Json::Str("batch".into()))]);
        r.span("job/shard", || {});
        r.span("job/shard", || {});
        r.span("job", || {});
        r.det_event(
            "shard",
            vec![
                ("id", Json::Str("j000-s000".into())),
                ("job", Json::Str("minimum-16".into())),
                ("est", ju64(100)),
                ("states", ju64(120)),
            ],
        );
        r.det_event(
            "shard",
            vec![
                ("id", Json::Str("j000-s001".into())),
                ("job", Json::Str("minimum-16".into())),
                ("est", ju64(100)),
                ("states", ju64(80)),
            ],
        );
        r.finish().unwrap();
        r.render()
    }

    #[test]
    fn validate_accepts_recorder_output() {
        let text = sample_trace();
        let events = validate(&text).unwrap();
        assert_eq!(events.len(), 7);
    }

    #[test]
    fn validate_rejects_garbage_and_schema_violations() {
        assert!(validate("not json\n").is_err());
        assert!(validate("{\"no_kind\":1}\n").is_err());
        // a span without its ns field
        assert!(validate("{\"k\":\"span\",\"path\":\"x\",\"t_ns\":1}\n").is_err());
        // deterministic kinds must not carry wall-clock fields
        assert!(validate("{\"k\":\"shard\",\"id\":\"a\",\"job\":\"j\",\"est\":1,\"states\":1,\"t_ns\":5}\n").is_err());
        // surrogate search events: kind required, content-only
        assert!(validate("{\"k\":\"search\",\"kind\":\"eval\",\"wg\":4,\"ts\":2}\n").is_ok());
        assert!(validate("{\"k\":\"search\",\"wg\":4}\n").is_err());
        assert!(validate("{\"k\":\"search\",\"kind\":\"eval\",\"t_ns\":5}\n").is_err());
        // unknown kinds pass
        assert!(validate("{\"k\":\"future-kind\",\"x\":1}\n").is_ok());
        // blank lines are skipped
        assert!(validate("\n\n").unwrap().is_empty());
    }

    #[test]
    fn deterministic_filter_keeps_run_and_shard_only() {
        let text = sample_trace();
        let det = deterministic_lines(&text);
        assert_eq!(det.len(), 2);
        for l in &det {
            assert!(l.contains("\"k\":\"shard\""));
            assert!(!l.contains("t_ns"));
        }
    }

    #[test]
    fn fault_events_validate_and_summarize() {
        // class is required
        assert!(validate("{\"k\":\"fault\",\"t_ns\":1}\n").is_err());
        let line = "{\"k\":\"fault\",\"class\":\"panic\",\"id\":\"j000-s001\",\
                    \"detail\":\"task panicked: boom\",\"attempts\":2,\"t_ns\":7}\n";
        assert_eq!(validate(line).unwrap().len(), 1);
        let s = summarize(line).unwrap();
        assert_eq!(s.faults.len(), 1);
        assert_eq!(s.faults[0].0, "panic");
        assert_eq!(s.faults[0].1, "j000-s001");
        let rendered = s.render();
        assert!(rendered.contains("faults (contained failures):"));
        assert!(rendered.contains("task panicked: boom"));
    }

    #[test]
    fn summary_aggregates_spans_and_shards() {
        let text = sample_trace();
        let s = summarize(&text).unwrap();
        assert_eq!(s.events, 7);
        assert_eq!(s.by_kind.get("span"), Some(&3));
        assert_eq!(s.by_kind.get("shard"), Some(&2));
        let (path, _ns, calls) =
            s.spans.iter().find(|(p, _, _)| p == "job/shard").unwrap();
        assert_eq!((path.as_str(), *calls), ("job/shard", 2));
        assert_eq!(s.shards.len(), 2);
        assert_eq!(s.shards[0].id, "j000-s000");
        assert!(!s.counters.is_empty());
        let rendered = s.render();
        assert!(rendered.contains("top spans"));
        assert!(rendered.contains("shard imbalance"));
        assert!(rendered.contains("j000-s001"));
        assert!(rendered.contains("counters:"));
    }
}
