//! The flight recorder: structured events serialized as JSONL.
//!
//! One [`Recorder`] per traced run. Events are compact
//! [`Json`] objects, one per line, buffered in memory and written
//! atomically on [`Recorder::finish`] (traces are small — hot-path
//! volume goes through the [`super::metrics`] counters, not events).
//!
//! Two emission flavors implement the determinism contract
//! (see [`super`]): [`Recorder::event`] appends a `t_ns` wall-clock
//! field (kinds `meta`, `span`, `lease`, `counters`), while
//! [`Recorder::det_event`] emits content-only lines (kinds `run`,
//! `shard`) that are byte-identical across deterministic re-executions.

use crate::util::error::Result;
use crate::util::manifest::{write_atomic, Json};
use std::path::PathBuf;
use std::sync::Mutex;
use std::time::Instant;

/// u64 as JSON: an integer when it fits `i64`, a decimal string above —
/// the same lossless encoding `coordinator::task::TaskSpec` uses.
pub fn ju64(v: u64) -> Json {
    if v <= i64::MAX as u64 {
        Json::Int(v as i64)
    } else {
        Json::Str(v.to_string())
    }
}

/// Where a recorder's lines go on [`Recorder::finish`].
#[derive(Debug, Clone)]
pub enum TraceSink {
    /// keep in memory only ([`Recorder::lines`] reads them back)
    Memory,
    /// write the JSONL file atomically (temp + rename)
    File(PathBuf),
}

/// A buffer of JSONL trace events for one run.
#[derive(Debug)]
pub struct Recorder {
    t0: Instant,
    sink: TraceSink,
    lines: Mutex<Vec<String>>,
}

impl Recorder {
    pub fn new(sink: TraceSink) -> Self {
        Self { t0: Instant::now(), sink, lines: Mutex::new(Vec::new()) }
    }

    /// A recorder that writes `path` on [`finish`](Self::finish).
    pub fn to_file(path: impl Into<PathBuf>) -> Self {
        Self::new(TraceSink::File(path.into()))
    }

    /// A recorder for tests and benches: lines stay in memory.
    pub fn in_memory() -> Self {
        Self::new(TraceSink::Memory)
    }

    fn push(&self, kind: &str, fields: Vec<(&str, Json)>, timed: bool) {
        let mut all: Vec<(String, Json)> = Vec::with_capacity(fields.len() + 2);
        all.push(("k".to_string(), Json::Str(kind.to_string())));
        for (k, v) in fields {
            all.push((k.to_string(), v));
        }
        if timed {
            let ns = self.t0.elapsed().as_nanos().min(u64::MAX as u128) as u64;
            all.push(("t_ns".to_string(), ju64(ns)));
        }
        let line = Json::Obj(all).render();
        self.lines.lock().expect("recorder lines").push(line);
    }

    /// Emit a timed event (`t_ns` = nanoseconds since recorder start).
    /// For run-identity data use [`det_event`](Self::det_event) instead.
    pub fn event(&self, kind: &str, fields: Vec<(&str, Json)>) {
        self.push(kind, fields, true);
    }

    /// Emit a content-only event: no timing, no process identity. Lines
    /// of kinds `run` and `shard` must go through here so deterministic
    /// re-executions (worker-mode duplicate leases, `--frontier det`
    /// re-runs) publish identical bytes.
    pub fn det_event(&self, kind: &str, fields: Vec<(&str, Json)>) {
        self.push(kind, fields, false);
    }

    /// Run `f` under a named span and emit its wall time. `path` is
    /// hierarchical (`batch/job/shard/explore`) — nesting is encoded in
    /// the path, and inner spans complete (and appear) before outer ones.
    pub fn span<T>(&self, path: &str, f: impl FnOnce() -> T) -> T {
        let t = Instant::now();
        let out = f();
        let ns = t.elapsed().as_nanos().min(u64::MAX as u128) as u64;
        self.event(
            "span",
            vec![("path", Json::Str(path.to_string())), ("ns", ju64(ns))],
        );
        out
    }

    /// Snapshot of the buffered lines (tests, summaries).
    pub fn lines(&self) -> Vec<String> {
        self.lines.lock().expect("recorder lines").clone()
    }

    /// All lines as one JSONL document (trailing newline when non-empty).
    pub fn render(&self) -> String {
        let lines = self.lines.lock().expect("recorder lines");
        if lines.is_empty() {
            String::new()
        } else {
            let mut out = lines.join("\n");
            out.push('\n');
            out
        }
    }

    /// Append the final `counters` event (a dump of the global metrics
    /// registry) and, for file sinks, write the JSONL atomically.
    pub fn finish(&self) -> Result<()> {
        let snap = super::metrics::metrics().snapshot();
        let fields: Vec<(&str, Json)> =
            snap.into_iter().map(|(n, v)| (n, ju64(v))).collect();
        self.event("counters", fields);
        if let TraceSink::File(path) = &self.sink {
            write_atomic(path, &self.render())?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_render_one_json_object_per_line() {
        let r = Recorder::in_memory();
        r.event("meta", vec![("cmd", Json::Str("verify".into()))]);
        r.det_event("run", vec![("states", ju64(7))]);
        let lines = r.lines();
        assert_eq!(lines.len(), 2);
        let v = Json::parse(&lines[0]).unwrap();
        assert_eq!(v.get("k").and_then(Json::as_str), Some("meta"));
        assert!(v.get("t_ns").is_some(), "timed events carry t_ns");
        let v = Json::parse(&lines[1]).unwrap();
        assert_eq!(v.get("k").and_then(Json::as_str), Some("run"));
        assert!(v.get("t_ns").is_none(), "det events carry no timing");
        assert_eq!(v.get("states").and_then(Json::as_i64), Some(7));
    }

    #[test]
    fn u64_beyond_i64_encodes_as_decimal_string() {
        let r = Recorder::in_memory();
        r.det_event("run", vec![("max_states", ju64(u64::MAX))]);
        let line = &r.lines()[0];
        let v = Json::parse(line).unwrap();
        let s = v.get("max_states").and_then(Json::as_str).expect("string-encoded");
        assert_eq!(s.parse::<u64>().unwrap(), u64::MAX);
    }

    #[test]
    fn spans_nest_inner_before_outer() {
        let r = Recorder::in_memory();
        let x = r.span("outer", || {
            r.span("outer/inner", || 21) * 2
        });
        assert_eq!(x, 42);
        let lines = r.lines();
        assert_eq!(lines.len(), 2);
        let inner = Json::parse(&lines[0]).unwrap();
        let outer = Json::parse(&lines[1]).unwrap();
        assert_eq!(inner.get("path").and_then(Json::as_str), Some("outer/inner"));
        assert_eq!(outer.get("path").and_then(Json::as_str), Some("outer"));
        // nesting is visible in the path prefix and the ns ordering
        let ns = |v: &Json| match v.get("ns") {
            Some(Json::Int(i)) => *i as u64,
            Some(Json::Str(s)) => s.parse().unwrap(),
            _ => panic!("span without ns"),
        };
        assert!(ns(&outer) >= ns(&inner), "outer span contains inner");
    }

    #[test]
    fn finish_appends_counters_and_renders_jsonl() {
        let r = Recorder::in_memory();
        r.det_event("run", vec![("states", ju64(1))]);
        r.finish().unwrap();
        let text = r.render();
        assert!(text.ends_with('\n'));
        let last = text.lines().last().unwrap();
        let v = Json::parse(last).unwrap();
        assert_eq!(v.get("k").and_then(Json::as_str), Some("counters"));
        assert!(v.get("checker.states_stored").is_some());
    }
}
