//! obs — the flight recorder: structured run events + hot-path counters.
//!
//! Dependency-free observability for every layer of the tuner:
//!
//! - [`metrics`] — a registry of striped (cache-line-padded) atomic
//!   counters and gauges the hot paths feed. Collection is gated on one
//!   process-global flag: when telemetry is off every counter `add` is a
//!   single relaxed bool load and an untaken branch, and the checker's
//!   per-state path flushes *deltas* only at its pre-existing amortized
//!   checkpoints — the `checker_hot_path` bench pins the disabled-mode
//!   overhead (`overhead_trace_vs_off` in `BENCH_checker.json`).
//! - [`recorder`] — span-scoped structured events serialized as JSONL
//!   (one compact `util::manifest::Json` object per line) behind
//!   `--trace <file>` on `verify`/`tune`/`batch`/`worker`.
//! - [`trace`] — schema validation and the `mcautotune trace <file>`
//!   summarizer (top spans by wall time, per-shard imbalance table).
//! - [`progress`] — the `--progress` periodic one-line stderr heartbeat
//!   (states, depth, store bytes, elapsed) for long runs.
//!
//! **Determinism contract.** Event kinds split in two: `run` and `shard`
//! events carry only run-derived data (state counts, verdicts, optima,
//! per-instance VM counters) and no timing, so under `--frontier det`
//! their *content* is identical across repeated runs and across
//! single-process vs. worker-mode execution of the same plan — the
//! property `rust/tests/trace_events.rs` pins. `meta`, `span`, `lease`
//! and `counters` events carry wall-clock timing and process identity
//! and are expected to differ between runs.
//!
//! The recorder is installed process-globally ([`install`]) because the
//! hot paths cannot thread a handle through every call; library tests
//! that need event capture without global state construct an explicit
//! [`Recorder`] and pass it where supported, or serialize installs.

pub mod metrics;
pub mod progress;
pub mod recorder;
pub mod trace;

pub use metrics::{metrics, Counter, Gauge, Metrics};
pub use progress::ProgressMeter;
pub use recorder::{ju64, Recorder, TraceSink};
pub use trace::{deterministic_lines, summarize, validate, TraceSummary};

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Is telemetry collection on? The one branch every counter pays.
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn metric collection on or off (independently of any recorder —
/// `--progress` enables counters without tracing events).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

fn active_slot() -> &'static Mutex<Option<Arc<Recorder>>> {
    static SLOT: OnceLock<Mutex<Option<Arc<Recorder>>>> = OnceLock::new();
    SLOT.get_or_init(|| Mutex::new(None))
}

/// Install `rec` as the process-global recorder and enable collection.
/// Returns the previously installed recorder, if any.
pub fn install(rec: Arc<Recorder>) -> Option<Arc<Recorder>> {
    set_enabled(true);
    active_slot().lock().expect("obs recorder slot").replace(rec)
}

/// Remove the global recorder and disable collection. Returns it so the
/// caller can [`Recorder::finish`] it.
pub fn uninstall() -> Option<Arc<Recorder>> {
    set_enabled(false);
    active_slot().lock().expect("obs recorder slot").take()
}

/// The installed recorder — `None` when telemetry is off, so event
/// emission sites cost one relaxed load on the disabled path.
pub fn active() -> Option<Arc<Recorder>> {
    if !enabled() {
        return None;
    }
    active_slot().lock().expect("obs recorder slot").as_ref().cloned()
}

/// Serializes tests that toggle the process-global flag or recorder —
/// `cargo test` runs tests on threads, and two tests flipping
/// [`set_enabled`] concurrently would see each other's state.
#[cfg(test)]
pub(crate) fn test_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_by_default_and_install_roundtrip() {
        let _g = test_lock();
        // Note: `enabled()` is process-global; this test only asserts the
        // install/uninstall protocol, not the initial value (a sibling
        // test may have toggled it).
        let rec = Arc::new(Recorder::in_memory());
        let prev = install(rec.clone());
        assert!(enabled());
        assert!(active().is_some());
        let got = uninstall().expect("recorder was installed");
        assert!(Arc::ptr_eq(&got, &rec));
        assert!(!enabled());
        assert!(active().is_none());
        // restore whatever was there before (other tests' recorder)
        if let Some(p) = prev {
            install(p);
        }
    }
}
