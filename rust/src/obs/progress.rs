//! `--progress`: a periodic one-line stderr heartbeat for long runs.
//!
//! A background thread samples the global [`super::metrics`] registry
//! and prints `states (rate) | depth | store bytes | elapsed` every
//! interval. Writes are error-silent (a closed stderr must not panic a
//! run), and the meter stops-and-joins on drop so no line is emitted
//! after the owning command finished.

use super::metrics::metrics;
use crate::util::fmt::{human_bytes, human_duration, thousands};
use std::io::Write;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Live progress reporter; ticks until dropped.
#[derive(Debug)]
pub struct ProgressMeter {
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl ProgressMeter {
    /// Start ticking every `interval`. Enables metric collection (the
    /// meter is useless without counters flowing).
    pub fn start(interval: Duration) -> Self {
        super::set_enabled(true);
        let interval = interval.max(Duration::from_millis(20));
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handle = std::thread::spawn(move || {
            let t0 = Instant::now();
            // sleep in short steps so drop() never waits a full interval
            let step = interval.min(Duration::from_millis(25));
            let mut since = Duration::ZERO;
            let mut last_states = 0u64;
            let mut last_tick = Instant::now();
            while !stop2.load(Ordering::Relaxed) {
                std::thread::sleep(step);
                since += step;
                if since < interval {
                    continue;
                }
                since = Duration::ZERO;
                let m = metrics();
                let states = m.states_stored.value();
                let dt = last_tick.elapsed().as_secs_f64();
                let rate = if dt > 0.0 {
                    (states.saturating_sub(last_states) as f64 / dt) as u64
                } else {
                    0
                };
                last_states = states;
                last_tick = Instant::now();
                let mut err = std::io::stderr();
                let _ = writeln!(
                    err,
                    "progress: {} states ({}/s) | depth {} | store {} | elapsed {}",
                    thousands(states),
                    thousands(rate),
                    m.depth.value(),
                    human_bytes(m.store_bytes.value()),
                    human_duration(t0.elapsed()),
                );
            }
        });
        Self { stop, handle: Some(handle) }
    }
}

impl Drop for ProgressMeter {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meter_starts_ticks_and_stops_cleanly() {
        let _g = crate::obs::test_lock();
        let was = crate::obs::enabled();
        {
            let _m = ProgressMeter::start(Duration::from_millis(20));
            metrics().states_stored.add(10);
            std::thread::sleep(Duration::from_millis(60));
        } // drop joins the thread; reaching here is the assertion
        crate::obs::set_enabled(was);
    }
}
