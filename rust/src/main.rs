//! `mcautotune` CLI — the L3 entrypoint.
//!
//! Subcommands map to the paper's workflow:
//!   simulate   SPIN simulation mode (finds T_ini)            §2 step 3
//!   verify     one verification run of a safety-LTL property  §4 step 2-3
//!   tune       full counterexample method (Fig. 1 / Fig. 5)   §4-5
//!   batch      sharded batch of tuning jobs + result cache    coordinator
//!   table1/2/3 regenerate the paper's experiment tables       §6-7
//!   exec       run an AOT-compiled Pallas kernel via PJRT     §7.1
//!   gen-models write the pregenerated Promela models          §4, §7.2

use mcautotune::checker::{check, CheckOptions, Compression, Frontier, StoreKind};
use mcautotune::coordinator::{
    run_batch, BatchOptions, JobEngine, ModelKind, ResultCache, TaskDir, TuningJob,
};
use mcautotune::model::{SafetyLtl, TransitionSystem};
use mcautotune::obs::{self, ju64, ProgressMeter, Recorder};
use mcautotune::platform::{
    enumerate_tunings, simulate, AbstractModel, DataInit, Granularity, MinModel, PlatformConfig,
};
use mcautotune::promela::{analysis, templates, PromelaSystem, PromelaVm};
use mcautotune::report;
use mcautotune::runtime::Engine;
use mcautotune::swarm::SwarmConfig;
use mcautotune::tuner::{
    cached_result, harvest_observations, surrogate_tune, tune, tune_cached, Method, SearchMode,
    SurrogateOptions, TuneCache,
};
use mcautotune::util::cli::{Args, Spec};
use mcautotune::util::error::{bail, Context, Result};
use mcautotune::util::fmt::{human_bytes, human_duration};
use mcautotune::util::manifest::Json;
use mcautotune::{outln, outp};
use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&argv) {
        eprintln!("error: {:#}", e);
        std::process::exit(1);
    }
}

const USAGE: &str = "\
mcautotune — model-checking-driven auto-tuning (Garanina/Staroletov/Gorlatch 2023)

usage: mcautotune <command> [options]

commands:
  tune        find the optimal (WG, TS) via the counterexample method
  batch       run a spec file of tuning jobs: sharded parameter-space search
              across a work-stealing queue, with a persistent result cache
              (--task-dir serializes the plan for cross-process draining)
  worker      lease and execute tasks from a --task-dir batch plan; any
              number of worker processes/machines can drain one batch
  merge       fold a drained task dir's partial results into the batch
              report + result cache (identical to a single-process run)
  cache       inspect a result-cache file: `cache ls <file>` lists entries,
              `cache rm <file> <needle>` drops matching entries
  trace       validate + summarize a JSONL flight-recorder trace written by
              `--trace <file>` on tune/verify/batch/worker
  simulate    random simulation of a model (reports terminal time, T_ini)
  verify      verify a safety-LTL property, print the first counterexample
  lint        static analysis of a .pml source: dead variables and stores,
              statically-false/shadowed guards, unreachable channel capacity,
              degenerate tuning lattices (--deny gates CI, --json for tools)
  table1      regenerate the paper's Table 1 (abstract-model experiments)
  table2      regenerate the paper's Table 2 (kernel sweep via PJRT)
  table3      regenerate the paper's Table 3 (Minimum-model experiments)
  exec        execute an AOT kernel artifact on PJRT, verify + time it
  gen-models  write pregenerated Promela models to models/
  help        show this message

run `mcautotune <command> --help` for per-command options";

fn dispatch(argv: &[String]) -> Result<()> {
    let Some(cmd) = argv.first() else {
        outln!("{}", USAGE);
        return Ok(());
    };
    let rest = &argv[1..];
    match cmd.as_str() {
        "tune" => cmd_tune(rest),
        "batch" => cmd_batch(rest),
        "worker" => cmd_worker(rest),
        "merge" => cmd_merge(rest),
        "cache" => cmd_cache(rest),
        "trace" => cmd_trace(rest),
        "simulate" => cmd_simulate(rest),
        "verify" => cmd_verify(rest),
        "lint" => cmd_lint(rest),
        "table1" => cmd_table1(rest),
        "table2" => cmd_table2(rest),
        "table3" => cmd_table3(rest),
        "exec" => cmd_exec(rest),
        "gen-models" => cmd_gen_models(rest),
        "help" | "--help" | "-h" => {
            outln!("{}", USAGE);
            Ok(())
        }
        other => bail!("unknown command `{}`\n{}", other, USAGE),
    }
}

// ----------------------------------------------------------- model opts --

fn model_spec(spec: Spec) -> Spec {
    spec.opt("model", "abstract | minimum | path to a .pml file")
        .opt("size", "input data size, power of two (default 64)")
        .opt("np", "processing elements per unit (default 4)")
        .opt("nd", "devices (default 1)")
        .opt("nu", "units per device (default 1)")
        .opt("gmt", "global/local memory time ratio (default 10 abstract, 3 minimum)")
        .opt("granularity", "tick | phase (default phase)")
        .opt("engine", "native | promela (default native)")
        .opt(
            "promela-exec",
            "vm | interp — Promela execution engine (default vm: compiled \
             bytecode over flat packed states; interp: the reference \
             tree-walking interpreter the differential suite pins the VM to)",
        )
        .opt(
            "reduce",
            "none | dead-slots — canonicalize statically-dead local slots to \
             zero before states are hashed (Promela engines only; sound for \
             safety verdicts and tuning optima, shrinks the visited store)",
        )
}

/// Parse `--reduce`: `true` means dead-slot canonicalization is on.
fn parse_reduce(a: &Args) -> Result<bool> {
    match a.get_or("reduce", "none").as_str() {
        "none" => Ok(false),
        "dead-slots" => Ok(true),
        other => bail!("unknown reduce `{}` (none | dead-slots)", other),
    }
}

enum AnyModel {
    Abs(AbstractModel),
    Min(MinModel),
    Pml(PromelaSystem),
    Vm(PromelaVm),
}

macro_rules! with_model {
    ($m:expr, $name:ident, $body:expr) => {
        match &$m {
            AnyModel::Abs($name) => $body,
            AnyModel::Min($name) => $body,
            AnyModel::Pml($name) => $body,
            AnyModel::Vm($name) => $body,
        }
    };
}

/// Build the selected Promela execution engine for a source text.
fn promela_model(a: &Args, src: &str) -> Result<AnyModel> {
    let dead_slots = parse_reduce(a)?;
    match a.get_or("promela-exec", "vm").as_str() {
        "vm" => {
            let m = PromelaVm::from_source(src)?;
            Ok(AnyModel::Vm(if dead_slots { m.with_dead_slot_reduction() } else { m }))
        }
        "interp" | "interpreter" => {
            let m = PromelaSystem::from_source(src)?;
            Ok(AnyModel::Pml(if dead_slots { m.with_dead_slot_reduction() } else { m }))
        }
        other => bail!("unknown promela-exec `{}` (vm | interp)", other),
    }
}

fn build_model(a: &Args) -> Result<AnyModel> {
    let kind = a.get_or("model", "minimum");
    let size: u32 = a.get_parsed_or("size", 64)?;
    let np: u32 = a.get_parsed_or("np", 4)?;
    let nd: u32 = a.get_parsed_or("nd", 1)?;
    let nu: u32 = a.get_parsed_or("nu", 1)?;
    let gran = match a.get_or("granularity", "phase").as_str() {
        "tick" => Granularity::Tick,
        "phase" => Granularity::Phase,
        g => bail!("unknown granularity `{}`", g),
    };
    // strict parse: a typo like `--engine promla` must error, not
    // silently tune the native model (and cache it under a native key)
    let engine: JobEngine = a.get_or("engine", "native").parse()?;
    match kind.as_str() {
        "abstract" => {
            let gmt: u32 = a.get_parsed_or("gmt", 10)?;
            let plat = PlatformConfig { nd, nu, np, gmt };
            if engine == JobEngine::Promela {
                promela_model(a, &templates::abstract_pml(size, &plat))
            } else {
                reject_native_reduce(a)?;
                Ok(AnyModel::Abs(AbstractModel::new(size, plat, gran)?))
            }
        }
        "minimum" => {
            let gmt: u32 = a.get_parsed_or("gmt", 3)?;
            if engine == JobEngine::Promela {
                promela_model(a, &templates::minimum_pml(size, np, gmt))
            } else {
                reject_native_reduce(a)?;
                Ok(AnyModel::Min(MinModel::new(size, np, gmt, DataInit::Descending, gran)?))
            }
        }
        path if path.ends_with(".pml") => {
            let src = std::fs::read_to_string(path)
                .with_context(|| format!("reading {}", path))?;
            promela_model(a, &src)
        }
        other => bail!("unknown model `{}` (abstract | minimum | *.pml)", other),
    }
}

/// Dead-slot canonicalization is defined over compiled Promela frames;
/// the native models have no local slots, so asking for it is a typo.
fn reject_native_reduce(a: &Args) -> Result<()> {
    if parse_reduce(a)? {
        bail!("--reduce dead-slots requires the Promela engine (--engine promela or a .pml model)");
    }
    Ok(())
}

fn parse_frontier(a: &Args) -> Result<Frontier> {
    match a.get_or("frontier", "async").as_str() {
        "async" => Ok(Frontier::Async),
        "det" | "deterministic" => Ok(Frontier::Deterministic),
        f => bail!("unknown frontier `{}` (async | det)", f),
    }
}

fn check_opts(a: &Args) -> Result<CheckOptions> {
    let d = CheckOptions::default();
    let store = match a.get_or("store", "full").as_str() {
        "full" => StoreKind::Full,
        "compact" => StoreKind::HashCompact,
        "bitstate" => StoreKind::Bitstate {
            log2_bits: a.get_parsed_or("bits", 27u8)?,
            hashes: 3,
        },
        "spill" => StoreKind::Spill,
        s => bail!("unknown store `{}` (full | compact | bitstate | spill)", s),
    };
    let compress = match a.get_or("compress", "none").as_str() {
        "none" => Compression::None,
        "collapse" => Compression::Collapse,
        c => bail!("unknown compression `{}` (none | collapse)", c),
    };
    let opts = CheckOptions {
        store,
        compress,
        spill_dir: a.get("spill-dir").map(std::path::PathBuf::from),
        max_depth: a.get_parsed_or("max-depth", d.max_depth)?,
        max_states: a.get_parsed_or("max-states", d.max_states)?,
        memory_budget: a.get_parsed_or("memory-budget", d.memory_budget)?,
        threads: a.get_parsed_or("threads", d.threads)?,
        frontier: parse_frontier(a)?,
        por: a.flag("por"),
        ..d
    };
    if opts.compress == Compression::Collapse
        && !matches!(opts.store, StoreKind::Full | StoreKind::HashCompact)
    {
        bail!("--compress collapse requires --store full or --store compact");
    }
    if opts.por && opts.effective_threads() > 1 && opts.frontier != Frontier::Deterministic {
        bail!("--por requires a deterministic engine (threads=1, or --frontier det)");
    }
    if opts.store == StoreKind::Spill
        && (opts.effective_threads() > 1 || opts.frontier == Frontier::Deterministic)
    {
        bail!("--store spill requires the sequential engine (threads=1, async frontier)");
    }
    Ok(opts)
}

fn store_spec(spec: Spec) -> Spec {
    spec.opt(
        "store",
        "full | compact | bitstate | spill (default full; spill: exact store \
         that freezes to sorted disk runs past the memory watermark)",
    )
        .opt("bits", "bitstate table log2 bits (default 27)")
        .opt(
            "compress",
            "none | collapse (collapse: SPIN COLLAPSE-style component interning \
             on the full or compact store — exact, smaller resident state vectors; \
             with --store compact the hash covers the interned component tuple)",
        )
        .opt("spill-dir", "directory for --store spill run files (default: temp dir)")
        .opt("max-depth", "search depth bound (spin -m)")
        .opt("max-states", "stored-state budget")
        .opt("memory-budget", "visited-store byte budget (default 16GiB)")
        .opt("threads", "exhaustive-search worker threads (default 1; 0 = all cores)")
        .opt(
            "frontier",
            "async | det (det: deterministic parallel exploration — reproducible \
             trails and first-trail identity across runs and thread counts)",
        )
        .flag(
            "por",
            "ample-set partial-order reduction (sequential or det-frontier \
             engines): expand one statically-invisible process where sound \
             instead of all — same verdicts and tuning optima, fewer states",
        )
}

fn swarm_cfg(a: &Args) -> Result<SwarmConfig> {
    Ok(SwarmConfig {
        workers: a.get_parsed_or("workers", 4)?,
        seed: a.get_parsed_or("seed", 0x5AFEu64)?,
        log2_bits: a.get_parsed_or("bits", 27u8)?,
        time_budget: Duration::from_millis(a.get_parsed_or("budget-ms", 10_000u64)?),
        ..Default::default()
    })
}

// -------------------------------------------------------- observability --

/// Flight-recorder options shared by the run commands (tune, verify,
/// batch, worker).
fn obs_spec(spec: Spec) -> Spec {
    spec.opt("trace", "write a JSONL flight-recorder trace to <file> (see `mcautotune trace`)")
        .flag("progress", "periodic one-line progress heartbeat on stderr")
}

/// Run `f` under a recorder span when tracing is on.
fn spanned<T>(path: &str, f: impl FnOnce() -> T) -> T {
    match obs::active() {
        Some(rec) => rec.span(path, f),
        None => f(),
    }
}

/// One command's observability session: the globally installed recorder
/// and the progress meter, when the shared flags asked for them. Success
/// paths call [`finish`](Self::finish) to flush the trace file; error
/// paths just exit (a partial trace is never written — the file appears
/// atomically or not at all).
struct ObsSession {
    rec: Option<Arc<Recorder>>,
    meter: Option<ProgressMeter>,
}

impl ObsSession {
    fn start(a: &Args, cmd: &str) -> Self {
        let rec = a.get("trace").map(|path| {
            let rec = Arc::new(Recorder::to_file(path));
            obs::install(Arc::clone(&rec));
            rec.event("meta", vec![("cmd", Json::Str(cmd.to_string()))]);
            rec
        });
        let meter = a.flag("progress").then(|| ProgressMeter::start(Duration::from_secs(2)));
        Self { rec, meter }
    }

    /// Stop the heartbeat, uninstall the recorder, write the trace.
    fn finish(mut self) -> Result<()> {
        let had_meter = self.meter.take().is_some(); // drop joins the ticker
        if let Some(rec) = self.rec.take() {
            obs::uninstall();
            rec.finish()?;
        } else if had_meter {
            obs::set_enabled(false);
        }
        Ok(())
    }
}

// ------------------------------------------------------------- commands --

/// Reconstruct the coordinator job a `tune` invocation corresponds to, so
/// `tune --cache` and `batch` share cache entries. Promela jobs (via
/// `--engine promela` or a `.pml` model path) are keyed on a content hash
/// of their source; for `.pml` paths the model kind is a placeholder that
/// only supplies defaults — the hash carries the identity.
fn job_from_args(a: &Args, method: Method) -> Result<TuningJob> {
    let model_arg = a.get_or("model", "minimum");
    let (kind, source) = if model_arg.ends_with(".pml") {
        let src = std::fs::read_to_string(&model_arg)
            .with_context(|| format!("reading {}", model_arg))?;
        (ModelKind::Minimum, Some(src))
    } else {
        (model_arg.parse::<ModelKind>()?, None)
    };
    let mut job = TuningJob::new(kind, a.get_parsed_or("size", 64)?);
    job.engine = a.get_or("engine", "native").parse()?;
    if source.is_some() {
        job.engine = JobEngine::Promela; // a .pml model implies the engine
    }
    job.source = source;
    job.plat.np = a.get_parsed_or("np", 4)?;
    job.plat.nd = a.get_parsed_or("nd", 1)?;
    job.plat.nu = a.get_parsed_or("nu", 1)?;
    job.plat.gmt = a.get_parsed_or(
        "gmt",
        match kind {
            ModelKind::Abstract => 10,
            ModelKind::Minimum => 3,
        },
    )?;
    job.granularity = match a.get_or("granularity", "phase").as_str() {
        "tick" => Granularity::Tick,
        "phase" => Granularity::Phase,
        g => bail!("unknown granularity `{}`", g),
    };
    job.method = method;
    Ok(job)
}

fn cmd_tune(argv: &[String]) -> Result<()> {
    let spec = obs_spec(store_spec(model_spec(Spec::new())))
        .opt("method", "exhaustive | swarm (default exhaustive)")
        .opt("workers", "swarm workers (default 4)")
        .opt("seed", "swarm seed")
        .opt("budget-ms", "per-swarm-round time budget (default 10000)")
        .opt("t-ini", "initial over-time bound (default: by simulation)")
        .opt(
            "search",
            "exhaustive | surrogate (surrogate: cache-seeded k-NN proposals + \
             exact point oracle + one certificate sweep — the identical optimum \
             in a fraction of the checker evaluations; falls back to exhaustive \
             when the cache holds too few observations)",
        )
        .opt("cache", "result-cache JSON path: reuse/record the optimum")
        .flag("help", "show options");
    let a = spec.parse(argv)?;
    if a.flag("help") {
        outln!("{}", spec.usage("mcautotune tune"));
        return Ok(());
    }
    let method: Method = a.get_or("method", "exhaustive").parse()?;
    let search: SearchMode = a.get_or("search", "exhaustive").parse()?;
    if search == SearchMode::Surrogate && method != Method::Exhaustive {
        bail!("--search surrogate requires --method exhaustive (the swarm is its own sampler)");
    }
    let model = build_model(&a)?;
    // refuse degenerate lattices up front: a source that never assigns
    // WG/TS would "tune" the same model at every configuration
    match &model {
        AnyModel::Pml(m) => analysis::require_tunable(&m.prog)?,
        AnyModel::Vm(m) => analysis::require_tunable(m.program())?,
        AnyModel::Abs(_) | AnyModel::Min(_) => {}
    }
    let opts = check_opts(&a)?;
    let sw = swarm_cfg(&a)?;
    let t_ini = a.get_parsed::<i64>("t-ini")?;
    let session = ObsSession::start(&a, "tune");
    // the lattice surrogate proposals range over; a size outside the
    // power-of-two enumeration has none, and the run degrades to the
    // exhaustive path instead of erroring
    let size: u32 = a.get_parsed_or("size", 64)?;
    let lattice = if search == SearchMode::Surrogate {
        enumerate_tunings(size).unwrap_or_default()
    } else {
        Vec::new()
    };
    let surrogate = search == SearchMode::Surrogate && !lattice.is_empty();
    let r = if let Some(cache_path) = a.get("cache") {
        let job = job_from_args(&a, method)?;
        // swarm results are configuration-dependent, so the swarm config
        // joins the cache key for Method::Swarm (see TuningJob::cache_desc_with)
        let desc = job.cache_desc_with(&sw);
        let family = job.obs_family();
        let mut cache = ResultCache::open(Path::new(cache_path))?;
        warn_quarantined(&cache);
        let (r, hit) = if surrogate {
            if let Some(h) = cache.lookup(&desc) {
                (cached_result(method, h, &desc), true)
            } else {
                let seeds = cache.observations(&family);
                let rep = with_model!(model, m, {
                    spanned("tune/search", || {
                        surrogate_tune(
                            m,
                            &opts,
                            &sw,
                            t_ini,
                            &lattice,
                            size,
                            &seeds,
                            &SurrogateOptions::default(),
                        )
                    })
                })?;
                cache.store(&desc, &rep.result);
                // this run's exact point evaluations warm future runs
                for o in &rep.evals {
                    cache.record_observation(&family, *o);
                }
                (rep.result, false)
            }
        } else {
            let (r, hit) = with_model!(model, m, {
                spanned("tune/search", || {
                    tune_cached(m, method, &opts, &sw, t_ini, &desc, &mut cache)
                })
            })?;
            // exhaustive optima seed the surrogate observation store too,
            // so plain cached tunes warm later `--search surrogate` runs
            if !hit && method == Method::Exhaustive {
                for o in harvest_observations(&r, job.size) {
                    cache.record_observation(&family, o);
                }
            }
            (r, hit)
        };
        cache.save()?;
        outln!("  cache: {} ({})", if hit { "hit" } else { "miss" }, cache_path);
        r
    } else if surrogate {
        with_model!(model, m, {
            spanned("tune/search", || {
                surrogate_tune(m, &opts, &sw, t_ini, &lattice, size, &[], &SurrogateOptions::default())
            })
        })?
        .result
    } else {
        with_model!(model, m, spanned("tune/search", || tune(m, method, &opts, &sw, t_ini)))?
    };
    if let Some(rec) = obs::active() {
        // content-only run identity: deterministic under `--frontier det`
        let mut fields = vec![
            ("cmd", Json::Str("tune".into())),
            ("model", Json::Str(a.get_or("model", "minimum"))),
            ("size", Json::Int(i64::from(a.get_parsed_or("size", 64u32)?))),
            ("wg", Json::Int(i64::from(r.optimal.wg))),
            ("ts", Json::Int(i64::from(r.optimal.ts))),
            ("t_min", Json::Int(r.t_min)),
            ("states", ju64(r.states_explored)),
        ];
        // reduction modes change state counts, so a trace must say which
        // regime produced its numbers; absent fields = the default run
        if opts.por {
            fields.push(("por", Json::Int(1)));
        }
        if parse_reduce(&a)? {
            fields.push(("reduce", Json::Str("dead-slots".into())));
        }
        if opts.compress != Compression::None {
            fields.push(("compress", Json::Str(opts.compress.name().to_string())));
        }
        if opts.store == StoreKind::Spill {
            fields.push(("store", Json::Str("spill".into())));
        }
        if surrogate {
            fields.push(("search", Json::Str("surrogate".into())));
        }
        rec.det_event("run", fields);
    }
    for line in &r.log {
        outln!("  {}", line);
    }
    outln!();
    outln!("optimal configuration: WG={} TS={}", r.optimal.wg, r.optimal.ts);
    outln!("minimal model time:    {}", r.t_min);
    if let Some((w, d)) = &r.first_trail {
        outln!(
            "first trail:           WG={} TS={} time={} (found after {}, optimality {:.0}%)",
            w.wg,
            w.ts,
            w.time,
            human_duration(*d),
            r.first_trail_optimality.unwrap_or(1.0) * 100.0
        );
    }
    outln!(
        "search: {} states, peak memory {}, wall time {}",
        r.states_explored,
        human_bytes(r.peak_bytes),
        human_duration(r.elapsed)
    );
    session.finish()
}

fn cmd_batch(argv: &[String]) -> Result<()> {
    let spec = obs_spec(Spec::new())
        .opt("workers", "queue worker threads (default 4)")
        .opt(
            "shards",
            "parameter-space shards for jobs that do not set shards= \
             (default 0 = adaptive from each job's state-space estimate)",
        )
        .opt(
            "threads",
            "checker threads per shard (default 1; 0 = all cores; multiplies with --workers)",
        )
        .opt("frontier", "async | det checker frontier (see `verify --help`)")
        .opt(
            "search",
            "exhaustive | surrogate — lattice search for exhaustive-method jobs \
             (overrides the spec's search=; surrogate warm-starts from cached \
             observations, see `tune --help`)",
        )
        .opt("cache", "result-cache JSON path (default mcat_cache.json; `none` disables)")
        .opt("budget-ms", "per-swarm-round time budget for swarm jobs (default 10000)")
        .opt(
            "task-dir",
            "serialize every (job, shard) task into <dir> as durable JSON manifests; \
             `mcautotune worker <dir>` processes drain them, `mcautotune merge <dir>` folds",
        )
        .opt(
            "ttl-ms",
            "with --task-dir: lease TTL before a crashed worker's task is re-leased (default 30000)",
        )
        .opt(
            "max-attempts",
            "with --task-dir: failed attempts before a task is dead-lettered to dead/ (default 3)",
        )
        .flag("plan-only", "with --task-dir: write the plan and exit without draining or merging")
        .flag("help", "show options");
    let a = spec.parse(argv)?;
    if a.flag("help") {
        outln!("{}", spec.usage("mcautotune batch <spec-file>"));
        outln!(
            "\nspec file: one `job <model> [k=v...]` per line, e.g.\n\
             \n  # tune four configurations; the last runs the Promela engine\n\
             \x20 job minimum size=64 np=4 gmt=3 shards=4\n\
             \x20 job minimum size=128 np=4 gmt=3 method=swarm\n\
             \x20 job abstract size=32 gmt=10\n\
             \x20 job minimum size=16 engine=promela\n\
             \nkeys: name size np nd nu gmt gran=tick|phase method=exhaustive|swarm\n\
             \x20     shards engine=native|promela src=<file.pml>\n\
             \x20     search=exhaustive|surrogate (surrogate: cache-seeded proposals,\n\
             \x20     exact certificate — identical optimum, fewer checker sweeps)\n\
             \nengine=promela verifies the generated Promela model (full process\n\
             interleaving) instead of the native transition system; src= supplies\n\
             an external .pml source (implies engine=promela). Promela results are\n\
             cached under a content hash of the source, so edited models never\n\
             reuse stale optima. Job budgets (--max-states/memory/time of `tune`)\n\
             are split across shards proportionally to estimated sub-lattice size."
        );
        return Ok(());
    }
    let Some(spec_path) = a.positionals().first() else {
        bail!("usage: mcautotune batch <spec-file> [options] (see `mcautotune batch --help`)");
    };
    let text = std::fs::read_to_string(spec_path)
        .with_context(|| format!("reading spec file {}", spec_path))?;
    let mut jobs = TuningJob::parse_spec(&text)?;
    if jobs.is_empty() {
        bail!("spec file {} contains no jobs", spec_path);
    }
    if let Some(s) = a.get("search") {
        let mode: SearchMode = s.parse()?;
        // swarm jobs keep their own sampler; the flag governs the rest
        for job in jobs.iter_mut().filter(|j| j.method == Method::Exhaustive) {
            job.search = mode;
        }
    }
    let mut opts = BatchOptions {
        workers: a.get_parsed_or("workers", 4)?,
        default_shards: a.get_parsed_or("shards", 0)?,
        ..BatchOptions::default()
    };
    opts.check.threads = a.get_parsed_or("threads", opts.check.threads)?;
    opts.check.frontier = parse_frontier(&a)?;
    opts.swarm.time_budget = Duration::from_millis(a.get_parsed_or("budget-ms", 10_000u64)?);
    // SwarmConfig defaults to one worker per core; shards already run on
    // `--workers` queue threads, so split the swarm fleet among them to
    // avoid ~workers x oversubscription (and workers x 16 MiB bitstate
    // tables) on swarm-method jobs. Floor of 2: swarm coverage comes from
    // seed-diversified workers, so never collapse a job to a single seed.
    opts.swarm.workers = (opts.swarm.workers / opts.workers.max(1)).max(2);
    let cache_arg = a.get_or("cache", "mcat_cache.json");
    let mut cache = if cache_arg == "none" {
        ResultCache::in_memory()
    } else {
        ResultCache::open(Path::new(&cache_arg))?
    };
    warn_quarantined(&cache);
    let session = ObsSession::start(&a, "batch");

    // Worker mode: serialize the plan instead of draining it in-process.
    if let Some(dir) = a.get("task-dir") {
        let start = std::time::Instant::now();
        let ttl = Duration::from_millis(a.get_parsed_or("ttl-ms", 30_000u64)?);
        let mut td = TaskDir::new(dir).with_ttl(ttl);
        if let Some(n) = a.get_parsed::<u32>("max-attempts")? {
            td = td.with_max_attempts(n);
        }
        let summary = spanned("batch/plan", || td.plan(&jobs, &opts, &mut cache))?;
        outln!(
            "planned {} task(s) for {} job(s) into {} ({} job(s) served from cache at plan time)",
            summary.tasks, summary.jobs, dir, summary.cached
        );
        if a.flag("plan-only") {
            outln!("drain:  mcautotune worker {}   (any number of processes/machines)", dir);
            outln!("merge:  mcautotune merge {}", dir);
            return session.finish();
        }
        // participate in the drain, then fold once all tasks complete
        let stats = spanned("batch/drain", || td.drain(opts.workers, false))?;
        outln!(
            "drained {} task(s) in this process ({} reclaimed from expired leases)",
            stats.executed, stats.reclaimed
        );
        let mut report = spanned("batch/merge", || td.merge(&mut cache))?;
        // merge() only times the fold; this invocation also planned and
        // drained, and the summary line should say so
        report.total_elapsed = start.elapsed();
        outln!(
            "batch: {} job(s), {} worker(s), cache {} (task dir {})",
            jobs.len(),
            opts.workers,
            if cache_arg == "none" { "disabled".to_string() } else { cache_arg },
            dir
        );
        outp!("{}", report.render());
        return session.finish();
    }

    let report = spanned("batch/run", || run_batch(&jobs, &opts, &mut cache))?;
    outln!(
        "batch: {} job(s), {} worker(s), cache {}",
        jobs.len(),
        opts.workers,
        if cache_arg == "none" { "disabled".to_string() } else { cache_arg }
    );
    outp!("{}", report.render());
    session.finish()
}

fn warn_quarantined(cache: &ResultCache) {
    if let Some(q) = cache.quarantined() {
        eprintln!(
            "warning: result cache was corrupt; original quarantined at {} and the cache rebuilt",
            q.display()
        );
    }
}

fn cmd_worker(argv: &[String]) -> Result<()> {
    let spec = obs_spec(Spec::new())
        .opt("ttl-ms", "lease TTL before an expired lease is re-leased (default: the plan's)")
        .opt("poll-ms", "sleep between scans while waiting for leasable work (default 100)")
        .opt("workers", "concurrent tasks in this worker process (default 1)")
        .opt(
            "max-attempts",
            "failed attempts before a task is dead-lettered to dead/ (default: the plan's)",
        )
        .flag("oneshot", "exit when nothing is leasable instead of waiting for the batch to finish")
        .flag(
            "status",
            "print a one-shot batch progress view (available/leased/done, per lease owner) and exit",
        )
        .flag("help", "show options");
    let a = spec.parse(argv)?;
    if a.flag("help") {
        outln!("{}", spec.usage("mcautotune worker <task-dir>"));
        outln!(
            "\nLeases tasks planned by `mcautotune batch <spec> --task-dir <dir>` with\n\
             atomic rename-based lock files, runs them, and publishes partial results\n\
             any process can merge. Crash-safe: a lease whose mtime exceeds the TTL is\n\
             re-leased by the next worker. By default the worker waits until every task\n\
             in the batch has a result (so crashed peers' work is picked up), then exits.\n\
             A task that keeps failing (panic, crash, deadline) is retried with backoff\n\
             up to --max-attempts times, then dead-lettered to <dir>/dead/ so the rest\n\
             of the batch drains; `mcautotune merge <dir> --partial` folds around it.\n\
             SIGTERM is graceful: the worker finishes its current task, publishes it,\n\
             and exits 0 holding no leases.\n\
             `--status` instead prints what the fleet is doing — tasks still available,\n\
             leases per worker (pid@host, heartbeat age), published results and any\n\
             dead-lettered tasks."
        );
        return Ok(());
    }
    let Some(dir) = a.positionals().first() else {
        bail!("usage: mcautotune worker <task-dir> [options] (see `mcautotune worker --help`)");
    };
    if a.flag("status") {
        let st = TaskDir::new(dir).status()?;
        outln!(
            "batch {}: {} task(s) — {} available, {} leased, {} done{}",
            dir,
            st.total,
            st.available,
            st.leases.len(),
            st.done,
            if st.dead.is_empty() {
                String::new()
            } else {
                format!(", {} dead-lettered", st.dead.len())
            }
        );
        for (owner, n) in st.per_owner() {
            outln!("  worker {}: {} lease(s)", owner, n);
        }
        for l in &st.leases {
            outln!(
                "    {} held by {} (running {}, heartbeat {} ago)",
                l.id,
                l.owner.as_deref().unwrap_or("?"),
                l.elapsed.map(human_duration).unwrap_or_else(|| "?".into()),
                human_duration(l.age)
            );
        }
        for (id, error) in &st.dead {
            outln!("  dead {}: {}", id, error);
        }
        return Ok(());
    }
    let mut td =
        TaskDir::new(dir).with_poll(Duration::from_millis(a.get_parsed_or("poll-ms", 100u64)?));
    if let Some(ms) = a.get_parsed::<u64>("ttl-ms")? {
        td = td.with_ttl(Duration::from_millis(ms));
    }
    if let Some(n) = a.get_parsed::<u32>("max-attempts")? {
        td = td.with_max_attempts(n);
    }
    let workers: u32 = a.get_parsed_or("workers", 1)?;
    // graceful shutdown: SIGTERM sets a flag the drain loop polls between
    // tasks — the current task finishes and publishes, no lease is left
    // behind, the trace session still writes, and the exit code is 0
    mcautotune::util::signal::install_term_handler();
    let session = ObsSession::start(&a, "worker");
    let stats = spanned("worker/drain", || td.drain(workers, a.flag("oneshot")))?;
    outln!(
        "worker {}: drained {} task(s), {} reclaimed from expired leases{}",
        std::process::id(),
        stats.executed,
        stats.reclaimed,
        if mcautotune::util::signal::term_requested() {
            " — SIGTERM: exiting gracefully, leases released"
        } else if stats.complete {
            " — batch complete"
        } else {
            ""
        }
    );
    session.finish()
}

fn cmd_merge(argv: &[String]) -> Result<()> {
    let spec = Spec::new()
        .opt("cache", "result-cache JSON path (default: the planning process's; `none` disables)")
        .flag(
            "partial",
            "fold what completed instead of refusing: jobs missing shards (dead-lettered \
             or outstanding tasks) report lower-bound optima and are not cached",
        )
        .flag("help", "show options");
    let a = spec.parse(argv)?;
    if a.flag("help") {
        outln!("{}", spec.usage("mcautotune merge <task-dir>"));
        outln!(
            "\nFolds a fully drained task dir's partial results into the same batch\n\
             report and result-cache entries a single-process `mcautotune batch` of\n\
             the spec produces. Errors (listing the count) while tasks are outstanding\n\
             or dead-lettered; `--partial` degrades instead — completed jobs merge and\n\
             cache exactly as usual, incomplete jobs report lower-bound optima (marked\n\
             `*`, never cached) and the report lists every dead-lettered task."
        );
        return Ok(());
    }
    let Some(dir) = a.positionals().first() else {
        bail!("usage: mcautotune merge <task-dir> [options] (see `mcautotune merge --help`)");
    };
    let td = TaskDir::new(dir);
    let cache_arg = match a.get("cache") {
        Some(c) => Some(c.to_string()),
        None => td.planned_cache_path()?,
    };
    let mut cache = match cache_arg.as_deref() {
        None | Some("none") => ResultCache::in_memory(),
        Some(path) => ResultCache::open(Path::new(path))?,
    };
    warn_quarantined(&cache);
    let report = if a.flag("partial") {
        td.merge_partial(&mut cache)?
    } else {
        td.merge(&mut cache)?
    };
    outln!(
        "merge: {} ({} job(s), cache {})",
        dir,
        report.outcomes.len(),
        cache_arg.unwrap_or_else(|| "disabled".into())
    );
    outp!("{}", report.render());
    Ok(())
}

fn cmd_cache(argv: &[String]) -> Result<()> {
    let spec = Spec::new()
        .flag("json", "with ls: machine-readable output (one JSON object)")
        .flag("help", "show options");
    let a = spec.parse(argv)?;
    let pos = a.positionals();
    if a.flag("help") || pos.is_empty() {
        outln!("{}", spec.usage("mcautotune cache <ls|rm> <file> [needle]"));
        outln!(
            "\nInspect or edit a result-cache JSON file (cache lifecycle tooling):\n\
             \x20 ls <file> [--json]  list entries: content key, optimum, method,\n\
             \x20                     cold-run states, canonical description, plus\n\
             \x20                     the surrogate observation count and file age\n\
             \x20 rm <file> <needle>  drop entries whose description contains <needle>\n\
             \x20                     (or whose 16-hex-digit key equals it) and rewrite\n\
             \x20                     the file atomically"
        );
        return Ok(());
    }
    match pos[0].as_str() {
        "ls" => {
            let Some(file) = pos.get(1) else {
                bail!("usage: mcautotune cache ls <file> [--json]");
            };
            let cache = ResultCache::open(Path::new(file))?;
            warn_quarantined(&cache);
            let n = cache.len();
            let obs_n = cache.observation_count();
            let age = cache.age_secs();
            if a.flag("json") {
                let rows: Vec<Json> = cache
                    .entries_sorted()
                    .into_iter()
                    .map(|e| {
                        Json::Obj(vec![
                            (
                                "key".to_string(),
                                Json::Str(format!(
                                    "{:016x}",
                                    mcautotune::util::hash::hash_bytes(e.desc.as_bytes())
                                )),
                            ),
                            ("wg".to_string(), Json::Int(e.wg as i64)),
                            ("ts".to_string(), Json::Int(e.ts as i64)),
                            ("t_min".to_string(), Json::Int(e.t_min)),
                            ("steps".to_string(), ju64(e.steps as u64)),
                            ("method".to_string(), Json::Str(e.method.clone())),
                            ("cold_states".to_string(), ju64(e.cold_states)),
                            ("desc".to_string(), Json::Str(e.desc.clone())),
                        ])
                    })
                    .collect();
                let top = Json::Obj(vec![
                    ("file".to_string(), Json::Str(file.to_string())),
                    ("entries".to_string(), ju64(n as u64)),
                    ("observations".to_string(), ju64(obs_n as u64)),
                    ("age_secs".to_string(), age.map_or(Json::Null, ju64)),
                    ("rows".to_string(), Json::Arr(rows)),
                ]);
                outln!("{}", top.render());
                return Ok(());
            }
            outln!(
                "{}: {} entr{} ({} observation row{}{})",
                file,
                n,
                if n == 1 { "y" } else { "ies" },
                obs_n,
                if obs_n == 1 { "" } else { "s" },
                match age {
                    Some(s) => format!(", {} old", human_duration(Duration::from_secs(s))),
                    None => String::new(),
                }
            );
            for e in cache.entries_sorted() {
                outln!(
                    "  {:016x}  WG={} TS={} t_min={} steps={} method={} cold_states={}\n\
                     \x20           {}",
                    mcautotune::util::hash::hash_bytes(e.desc.as_bytes()),
                    e.wg,
                    e.ts,
                    e.t_min,
                    e.steps,
                    e.method,
                    e.cold_states,
                    e.desc
                );
            }
            Ok(())
        }
        "rm" => {
            let (Some(file), Some(needle)) = (pos.get(1), pos.get(2)) else {
                bail!("usage: mcautotune cache rm <file> <needle>");
            };
            let path = Path::new(file);
            if !path.exists() {
                bail!("result cache {} does not exist", file);
            }
            let mut cache = ResultCache::open(path)?;
            warn_quarantined(&cache);
            let removed = cache.remove_matching(needle);
            cache.save()?;
            outln!(
                "removed {} entr{} matching `{}` from {} ({} left)",
                removed,
                if removed == 1 { "y" } else { "ies" },
                needle,
                file,
                cache.len()
            );
            Ok(())
        }
        other => bail!(
            "unknown cache action `{}` (ls | rm — see `mcautotune cache --help`)",
            other
        ),
    }
}

fn cmd_simulate(argv: &[String]) -> Result<()> {
    let spec = model_spec(Spec::new())
        .opt("runs", "number of random walks (default 8)")
        .opt("seed", "rng seed (default 1)")
        .flag("help", "show options");
    let a = spec.parse(argv)?;
    if a.flag("help") {
        outln!("{}", spec.usage("mcautotune simulate"));
        return Ok(());
    }
    let runs: u64 = a.get_parsed_or("runs", 8)?;
    let seed: u64 = a.get_parsed_or("seed", 1)?;
    let model = build_model(&a)?;
    let mut t_ini: Option<i64> = None;
    for r in 0..runs {
        let (terminated, time) = with_model!(model, m, {
            let rep = simulate(m, seed + r, 100_000_000);
            outln!(
                "run {}: steps={} terminated={} time={:?} WG={:?} TS={:?}",
                r,
                rep.steps,
                rep.terminated,
                rep.time,
                m.eval_var(&rep.final_state, "WG"),
                m.eval_var(&rep.final_state, "TS"),
            );
            (rep.terminated, rep.time)
        });
        if terminated {
            if let Some(t) = time {
                t_ini = Some(t_ini.map_or(t, |b: i64| b.max(t)));
            }
        }
    }
    match t_ini {
        Some(t) => outln!("\nT_ini = {} (max observed terminal time)", t),
        None => outln!("\nno terminating run observed"),
    }
    Ok(())
}

fn cmd_verify(argv: &[String]) -> Result<()> {
    let spec = obs_spec(store_spec(model_spec(Spec::new())))
        .opt("prop", "safety LTL formula, e.g. 'G(FIN -> time > 100)'")
        .opt("trail-limit", "max trail lines to print (default 40)")
        .flag("all-errors", "keep searching after the first violation (spin -e)")
        .flag("help", "show options");
    let a = spec.parse(argv)?;
    if a.flag("help") {
        outln!("{}", spec.usage("mcautotune verify"));
        return Ok(());
    }
    let prop = SafetyLtl::parse(&a.get_or("prop", "G(!FIN)"))?;
    let model = build_model(&a)?;
    let mut opts = check_opts(&a)?;
    opts.collect_all = a.flag("all-errors");
    let limit: usize = a.get_parsed_or("trail-limit", 40)?;
    let session = ObsSession::start(&a, "verify");
    with_model!(model, m, {
        let rep = spanned("verify/explore", || check(m, &prop, &opts))?;
        if let Some(rec) = obs::active() {
            // content-only run identity: deterministic under `--frontier det`
            let mut fields = vec![
                ("cmd", Json::Str("verify".into())),
                ("model", Json::Str(a.get_or("model", "minimum"))),
                ("prop", Json::Str(prop.to_string())),
                (
                    "verdict",
                    Json::Str(
                        if rep.found() {
                            "violated"
                        } else if rep.exhausted {
                            "holds"
                        } else {
                            "inconclusive"
                        }
                        .to_string(),
                    ),
                ),
                ("states", ju64(rep.stats.states_stored)),
                ("matched", ju64(rep.stats.states_matched)),
                ("transitions", ju64(rep.stats.transitions)),
                ("depth", ju64(rep.stats.max_depth_reached as u64)),
                ("violations", ju64(rep.violations.len() as u64)),
            ];
            // reduction modes change state counts, so a trace must say
            // which regime produced its numbers; absent = default run
            if opts.por {
                fields.push(("por", Json::Int(1)));
            }
            if parse_reduce(&a)? {
                fields.push(("reduce", Json::Str("dead-slots".into())));
            }
            if opts.compress != Compression::None {
                fields.push(("compress", Json::Str(opts.compress.name().to_string())));
            }
            if opts.store == StoreKind::Spill {
                fields.push(("store", Json::Str("spill".into())));
            }
            rec.det_event("run", fields);
        }
        outln!(
            "property {}: {}",
            prop,
            if rep.found() {
                "VIOLATED (counterexample found)"
            } else if rep.exhausted {
                "HOLDS (state space exhausted)"
            } else {
                "inconclusive (budget hit)"
            }
        );
        outln!(
            "states stored {}  matched {}  transitions {}  depth {}  memory {}  elapsed {}",
            rep.stats.states_stored,
            rep.stats.states_matched,
            rep.stats.transitions,
            rep.stats.max_depth_reached,
            human_bytes(rep.stats.bytes_used),
            human_duration(rep.stats.elapsed)
        );
        if let Some(v) = rep.violations.first() {
            outln!("\ncounterexample trail ({} steps):", v.trail.steps());
            outp!("{}", v.trail.render(m, limit));
        }
        if rep.violations.len() > 1 {
            outln!("({} violations total)", rep.violations.len());
        }
        Ok(())
    })?;
    session.finish()
}

fn cmd_lint(argv: &[String]) -> Result<()> {
    let spec = Spec::new()
        .flag("deny", "exit nonzero if any warning-severity diagnostic fires (CI gate)")
        .flag("json", "one machine-readable JSON report line per file (schema-checked)")
        .flag("help", "show options");
    let a = spec.parse(argv)?;
    if a.flag("help") || a.positionals().is_empty() {
        outln!("{}", spec.usage("mcautotune lint <file.pml>..."));
        outln!(
            "\nCompiles each source and runs the effect/liveness analysis the\n\
             reduction modes (`--por`, `--reduce dead-slots`) are built on,\n\
             reporting what it proves about the model:\n\
             \x20 warn  unused locals, dead stores, statically-false guards,\n\
             \x20       shadowed options, channels that can never fill or are\n\
             \x20       never sent on, tuning variables (WG/TS) never assigned\n\
             \x20 info  unused/write-only globals (often outputs — benign)\n\
             `--deny` fails on warnings only; infos never gate."
        );
        return if a.flag("help") { Ok(()) } else { bail!("no input files") };
    }
    let mut warns = 0usize;
    for path in a.positionals() {
        let src = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path))?;
        let sys = PromelaSystem::from_source(&src)
            .with_context(|| format!("compiling {}", path))?;
        let diags = analysis::diagnostics(&sys.prog);
        warns += diags.iter().filter(|d| d.severity == analysis::Severity::Warn).count();
        if a.flag("json") {
            let j = analysis::lint_json(path, &sys.prog, &diags);
            // self-check: the emitted report must satisfy its own schema,
            // so downstream tooling never sees a malformed line
            analysis::validate_lint_json(&j)
                .with_context(|| format!("internal: lint JSON schema violation for {}", path))?;
            outln!("{}", j.render());
        } else if diags.is_empty() {
            outln!("{}: clean", path);
        } else {
            outln!("{}:", path);
            for d in &diags {
                outln!("  {}", d);
            }
        }
    }
    if a.flag("deny") && warns > 0 {
        bail!("lint: {} warning(s) (--deny)", warns);
    }
    Ok(())
}

fn cmd_trace(argv: &[String]) -> Result<()> {
    let spec = Spec::new().flag("help", "show options");
    let a = spec.parse(argv)?;
    let pos = a.positionals();
    if a.flag("help") || pos.is_empty() {
        outln!("{}", spec.usage("mcautotune trace <file>"));
        outln!(
            "\nValidate and summarize a JSONL flight-recorder trace written by\n\
             `--trace <file>` on tune/verify/batch/worker: event counts, top\n\
             spans by wall time, the per-shard imbalance table (actual states\n\
             vs. planned weight) and the final counter dump."
        );
        return Ok(());
    }
    let file = &pos[0];
    let text =
        std::fs::read_to_string(file).with_context(|| format!("reading trace {}", file))?;
    let summary =
        mcautotune::obs::summarize(&text).with_context(|| format!("validating trace {}", file))?;
    outp!("{}", summary.render());
    Ok(())
}

fn cmd_table1(argv: &[String]) -> Result<()> {
    let spec = Spec::new()
        .opt("sizes", "comma-separated sizes (default 8,16,32,64,128,256,512,1024)")
        .opt("max-exhaustive", "largest size tuned exhaustively (default 256)")
        .opt("max-promela", "largest size verified on the Promela engine (default 16)")
        .opt("np", "PEs per unit (default 4)")
        .opt("gmt", "memory ratio (default 10)")
        .opt("workers", "swarm workers (default 4)")
        .opt("budget-ms", "swarm round budget (default 5000)")
        .flag("help", "show options");
    let a = spec.parse(argv)?;
    if a.flag("help") {
        outln!("{}", spec.usage("mcautotune table1"));
        return Ok(());
    }
    let mut opts = report::Table1Opts::default();
    if let Some(s) = a.get("sizes") {
        opts.sizes = s
            .split(',')
            .map(|x| x.trim().parse::<u32>().context("bad size"))
            .collect::<Result<_>>()?;
    }
    opts.max_exhaustive_size = a.get_parsed_or("max-exhaustive", opts.max_exhaustive_size)?;
    opts.max_promela_size = a.get_parsed_or("max-promela", opts.max_promela_size)?;
    opts.plat.np = a.get_parsed_or("np", opts.plat.np)?;
    opts.plat.gmt = a.get_parsed_or("gmt", opts.plat.gmt)?;
    opts.swarm.workers = a.get_parsed_or("workers", opts.swarm.workers)?;
    opts.swarm.time_budget = Duration::from_millis(a.get_parsed_or("budget-ms", 5000u64)?);
    let (_, rendered) = report::table1(&opts)?;
    outln!(
        "Table 1 — abstract-model experiments (platform: 1 device, 1 unit, {} PEs, GMT={})",
        opts.plat.np, opts.plat.gmt
    );
    outp!("{}", rendered);
    Ok(())
}

fn cmd_table2(argv: &[String]) -> Result<()> {
    let spec = Spec::new()
        .opt("artifacts", "artifacts directory (default artifacts/ or $MCAT_ARTIFACTS)")
        .opt("repeats", "timed runs per configuration (default 5)")
        .flag("help", "show options");
    let a = spec.parse(argv)?;
    if a.flag("help") {
        outln!("{}", spec.usage("mcautotune table2"));
        return Ok(());
    }
    let dir = a
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(Engine::default_dir);
    let mut engine = Engine::new(&dir)?;
    let repeats: u32 = a.get_parsed_or("repeats", 5)?;
    let (_, rendered) = report::table2(&mut engine, repeats)?;
    outln!("Table 2 — Minimum kernel sweep (PJRT substitute for the paper's P104-100)");
    outp!("{}", rendered);
    Ok(())
}

fn cmd_table3(argv: &[String]) -> Result<()> {
    let spec = Spec::new()
        .opt("gmt", "memory ratio (default 3, the Table-3 calibration)")
        .opt("top", "best configurations listed per group (default 3)")
        .flag("help", "show options");
    let a = spec.parse(argv)?;
    if a.flag("help") {
        outln!("{}", spec.usage("mcautotune table3"));
        return Ok(());
    }
    let gmt: u32 = a.get_parsed_or("gmt", 3)?;
    let top: usize = a.get_parsed_or("top", 3)?;
    let (_, rendered) = report::table3(&report::paper_table3_groups(), gmt, top)?;
    outln!("Table 3 — Minimum-model experiments (GMT={})", gmt);
    outp!("{}", rendered);
    Ok(())
}

fn cmd_exec(argv: &[String]) -> Result<()> {
    let spec = Spec::new()
        .opt("artifact", "artifact name from the manifest (default min_device_small)")
        .opt("artifacts", "artifacts directory")
        .opt("seed", "data seed (default 42)")
        .opt("repeats", "timed repetitions (default 3)")
        .flag("help", "show options");
    let a = spec.parse(argv)?;
    if a.flag("help") {
        outln!("{}", spec.usage("mcautotune exec"));
        return Ok(());
    }
    let dir = a
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(Engine::default_dir);
    let mut engine = Engine::new(&dir)?;
    let name = a.get_or("artifact", "min_device_small");
    let seed: u64 = a.get_parsed_or("seed", 42)?;
    let repeats: u32 = a.get_parsed_or("repeats", 3)?;
    let entry = engine
        .manifest()
        .find(&name)
        .with_context(|| format!("artifact `{}` not found", name))?
        .clone();
    outln!(
        "artifact {}: kind={} units={} WG={} TS={} size={} (vmem est {})",
        entry.name,
        entry.kind,
        entry.units,
        entry.wg,
        entry.ts,
        entry.size,
        human_bytes(entry.vmem_bytes)
    );
    let data = mcautotune::opencl::gen_data(entry.size as usize, seed);
    let expected = *data.iter().min().unwrap();
    let mut best = f64::INFINITY;
    let mut out_min = 0;
    for _ in 0..repeats.max(1) {
        let t = std::time::Instant::now();
        let out = engine.run_min(&name, &data)?;
        best = best.min(t.elapsed().as_secs_f64() * 1e3);
        out_min = out.global_min;
    }
    outln!(
        "result: min={} (expected {}) {} — best {:.3} ms, {:.2} GB/s",
        out_min,
        expected,
        if out_min == expected { "CORRECT" } else { "WRONG" },
        best,
        (entry.size as f64 * 4.0) / (best / 1e3) / 1e9
    );
    Ok(())
}

fn cmd_gen_models(argv: &[String]) -> Result<()> {
    let spec = Spec::new()
        .opt("out", "output directory (default models/)")
        .flag("help", "show options");
    let a = spec.parse(argv)?;
    if a.flag("help") {
        outln!("{}", spec.usage("mcautotune gen-models"));
        return Ok(());
    }
    let dir = std::path::PathBuf::from(a.get_or("out", "models"));
    std::fs::create_dir_all(&dir)?;
    let plat = PlatformConfig::default();
    for (name, src) in [
        ("abstract_8.pml", templates::abstract_pml(8, &plat)),
        ("abstract_16.pml", templates::abstract_pml(16, &plat)),
        ("minimum_16.pml", templates::minimum_pml(16, 4, 3)),
        ("minimum_32.pml", templates::minimum_pml(32, 4, 3)),
        ("minimum_64_np64.pml", templates::minimum_pml(64, 64, 3)),
    ] {
        let path = dir.join(name);
        std::fs::write(&path, src)?;
        outln!("wrote {}", path.display());
    }
    Ok(())
}
