//! Core abstractions the checker operates on.
//!
//! Everything the paper verifies — the Promela abstract-platform model, the
//! Minimum-problem model, and our native re-implementations of both — is
//! exposed to the checker as a [`TransitionSystem`]: a set of initial
//! states, a successor relation, a stable byte encoding (for hashing /
//! bitstate storage), and a named-variable observation interface that LTL
//! properties and the tuner's counterexample extraction read.

pub mod property;
pub mod trail;

pub use property::{CompiledProp, EvalScratch, Expr, SafetyLtl};
pub use trail::{Trail, Violation};

/// A state-transition system explored by the checker.
pub trait TransitionSystem {
    type State: Clone + std::fmt::Debug;

    /// Initial states. Several when the model opens with a nondeterministic
    /// choice (e.g. the tuning-parameter selection in `main`).
    fn initial_states(&self) -> Vec<Self::State>;

    /// Append all successors of `s` to `out` (which is cleared first).
    /// A state with no successors is terminal.
    ///
    /// Buffer contract: implementations fill the *caller's* buffer in
    /// place — both checker engines recycle these buffers (freelists in
    /// the DFS, per-worker buffers in the parallel frontier), so
    /// steady-state exploration performs no per-call allocation beyond
    /// the successor states themselves. Engines with flat packed states
    /// (e.g. `promela::vm`) make each appended successor a single memcpy.
    fn successors(&self, s: &Self::State, out: &mut Vec<Self::State>);

    /// Stable, injective byte encoding of the state, appended to `out`
    /// (cleared first). Used for the visited store and bitstate hashing.
    fn encode(&self, s: &Self::State, out: &mut Vec<u8>);

    /// Region split of [`encode`](Self::encode)'s byte string, for
    /// COLLAPSE-style store compression: fill `out` (cleared first) with
    /// ascending region-end byte offsets; the final region runs to the
    /// encoding's end implicitly. Regions should follow the model's
    /// natural component structure (globals / per-channel / per-process
    /// frame), so that components repeat across states and the interning
    /// store can share them. Must be a pure function of the state — the
    /// store relies on equal states producing equal splits. The default
    /// (no offsets) declares the whole encoding one region: compression
    /// degrades to indirection but stays exact.
    fn encode_regions(&self, s: &Self::State, out: &mut Vec<u32>) {
        let _ = s;
        out.clear();
    }

    /// Observe a named model variable (e.g. "time", "FIN", "WG", "TS").
    /// Booleans are 0/1. Returns None for unknown names.
    fn eval_var(&self, s: &Self::State, name: &str) -> Option<i64>;

    /// Resolve a variable name to a model-private dense slot id, once, at
    /// property-compile time ([`SafetyLtl::compile`]). Models that override
    /// this (together with [`eval_slots`](Self::eval_slots)) give the
    /// checker a string-free observation path: the per-state cost becomes
    /// one integer-dispatched bulk read instead of one name lookup per
    /// variable. The default advertises no slots, which makes the compiled
    /// evaluator fall back to `eval_var` — existing models keep working
    /// unchanged.
    fn resolve_slot(&self, name: &str) -> Option<u32> {
        let _ = name;
        None
    }

    /// Fill `out[i]` with the value of pre-resolved slot `ids[i]` in `s`,
    /// returning a bitmask with bit `i` set when that slot has no value in
    /// this state (e.g. `WG` before the tuning choice). A masked slot only
    /// becomes an error if the property actually reads it — mirroring the
    /// lazy `eval_var` lookups of the interpreted evaluator. Callers
    /// guarantee `ids.len() == out.len() <= 64` and that every id came
    /// from [`resolve_slot`](Self::resolve_slot) on the same model.
    fn eval_slots(&self, s: &Self::State, ids: &[u32], out: &mut [i64]) -> u64 {
        let _ = (s, ids, out);
        u64::MAX
    }

    /// Like [`successors`](Self::successors), but the model may generate
    /// only an *ample* subset of them — a partial-order reduction hook.
    /// Returns true iff a proper ample selection was applied (the checker
    /// counts reduced expansions). Soundness contract for implementers:
    /// the reduced graph must preserve the verdict of every stutter-
    /// insensitive safety property (see `promela::analysis` for the
    /// provisos the Promela engines discharge statically). The default
    /// performs no reduction, so `--por` is a no-op on models that do not
    /// opt in.
    fn reduced_successors(&self, s: &Self::State, out: &mut Vec<Self::State>) -> bool {
        self.successors(s, out);
        false
    }

    /// Human-readable one-line description for trail printing.
    fn describe(&self, s: &Self::State) -> String {
        format!("{:?}", s)
    }

    /// Convenience: terminality probe via `successors`.
    fn is_terminal(&self, s: &Self::State) -> bool {
        let mut buf = Vec::new();
        self.successors(s, &mut buf);
        buf.is_empty()
    }
}

/// Blanket impl so `&M` can be passed wherever a system is expected.
impl<M: TransitionSystem> TransitionSystem for &M {
    type State = M::State;

    fn initial_states(&self) -> Vec<Self::State> {
        (**self).initial_states()
    }

    fn successors(&self, s: &Self::State, out: &mut Vec<Self::State>) {
        (**self).successors(s, out)
    }

    fn encode(&self, s: &Self::State, out: &mut Vec<u8>) {
        (**self).encode(s, out)
    }

    fn encode_regions(&self, s: &Self::State, out: &mut Vec<u32>) {
        (**self).encode_regions(s, out)
    }

    fn eval_var(&self, s: &Self::State, name: &str) -> Option<i64> {
        (**self).eval_var(s, name)
    }

    fn resolve_slot(&self, name: &str) -> Option<u32> {
        (**self).resolve_slot(name)
    }

    fn eval_slots(&self, s: &Self::State, ids: &[u32], out: &mut [i64]) -> u64 {
        (**self).eval_slots(s, ids, out)
    }

    fn reduced_successors(&self, s: &Self::State, out: &mut Vec<Self::State>) -> bool {
        (**self).reduced_successors(s, out)
    }

    fn describe(&self, s: &Self::State) -> String {
        (**self).describe(s)
    }
}
