//! LTL properties — safety fragment.
//!
//! The paper verifies two formulas:
//!   Φo = G(FIN -> time > T)   (over-time property, §4 Step 2)
//!   Φt = G(!FIN)              (non-termination property, §5)
//!
//! Both are *safety* properties: a violation is witnessed by a single
//! reachable state, so a state monitor suffices and no Büchi construction
//! is needed. We parse exactly the `G(<boolean state expression>)` fragment
//! (also written `[](...)`), with integer arithmetic, comparisons, boolean
//! connectives and `->` implication over named model variables; anything
//! outside the fragment (nested temporal operators, U, X, F) is rejected
//! with a clear error. This is the same fragment the paper uses.

use crate::util::error::{anyhow, bail, Result};
use std::fmt;

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Expr {
    Int(i64),
    Var(String),
    Not(Box<Expr>),
    Neg(Box<Expr>),
    Bin(BinOp, Box<Expr>, Box<Expr>),
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BinOp {
    Or,
    And,
    Implies,
    Eq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
    Add,
    Sub,
    Mul,
    Div,
    Mod,
}

impl Expr {
    /// Evaluate with a variable lookup. Booleans are 0/1; any nonzero value
    /// is truthy (C/Promela convention).
    pub fn eval(&self, lookup: &dyn Fn(&str) -> Option<i64>) -> Result<i64> {
        Ok(match self {
            Expr::Int(v) => *v,
            Expr::Var(name) => lookup(name)
                .ok_or_else(|| anyhow!("unknown variable `{}` in property", name))?,
            Expr::Not(e) => (e.eval(lookup)? == 0) as i64,
            Expr::Neg(e) => -(e.eval(lookup)?),
            Expr::Bin(op, a, b) => {
                use BinOp::*;
                match op {
                    And => ((a.eval(lookup)? != 0) && (b.eval(lookup)? != 0)) as i64,
                    Or => ((a.eval(lookup)? != 0) || (b.eval(lookup)? != 0)) as i64,
                    Implies => ((a.eval(lookup)? == 0) || (b.eval(lookup)? != 0)) as i64,
                    _ => {
                        let (x, y) = (a.eval(lookup)?, b.eval(lookup)?);
                        match op {
                            Eq => (x == y) as i64,
                            Ne => (x != y) as i64,
                            Lt => (x < y) as i64,
                            Le => (x <= y) as i64,
                            Gt => (x > y) as i64,
                            Ge => (x >= y) as i64,
                            Add => x.wrapping_add(y),
                            Sub => x.wrapping_sub(y),
                            Mul => x.wrapping_mul(y),
                            Div => {
                                if y == 0 {
                                    bail!("division by zero in property");
                                }
                                x / y
                            }
                            Mod => {
                                if y == 0 {
                                    bail!("mod by zero in property");
                                }
                                x % y
                            }
                            _ => unreachable!(),
                        }
                    }
                }
            }
        })
    }

    /// Free variables referenced by the expression.
    pub fn vars(&self, out: &mut Vec<String>) {
        match self {
            Expr::Int(_) => {}
            Expr::Var(n) => {
                if !out.contains(n) {
                    out.push(n.clone());
                }
            }
            Expr::Not(e) | Expr::Neg(e) => e.vars(out),
            Expr::Bin(_, a, b) => {
                a.vars(out);
                b.vars(out);
            }
        }
    }
}

/// `G(body)` — holds on a run iff `body` holds in every state.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SafetyLtl {
    pub body: Expr,
    pub source: String,
}

impl SafetyLtl {
    /// Parse `G(expr)` / `[](expr)` / bare `expr` (treated as G(expr)).
    pub fn parse(src: &str) -> Result<Self> {
        let mut p = Parser::new(src);
        p.skip_ws();
        let had_g = if p.eat_kw("G") || p.eat_str("[]") {
            p.skip_ws();
            if !p.eat_str("(") {
                bail!("expected '(' after temporal G in `{}`", src);
            }
            true
        } else {
            false
        };
        let body = p.parse_expr(0)?;
        if had_g {
            p.skip_ws();
            if !p.eat_str(")") {
                bail!("expected closing ')' in `{}`", src);
            }
        }
        p.skip_ws();
        if !p.rest().is_empty() {
            bail!("trailing input `{}` in property `{}`", p.rest(), src);
        }
        Ok(Self { body, source: src.to_string() })
    }

    /// The over-time property Φo = G(FIN -> time > T) with a concrete T.
    pub fn over_time(t: i64) -> Self {
        Self::parse(&format!("G(FIN -> time > {})", t)).expect("static formula")
    }

    /// The non-termination property Φt = G(!FIN).
    pub fn non_termination() -> Self {
        Self::parse("G(!FIN)").expect("static formula")
    }

    /// Does the invariant hold in this state? (false = violation here)
    pub fn holds(&self, lookup: &dyn Fn(&str) -> Option<i64>) -> Result<bool> {
        Ok(self.body.eval(lookup)? != 0)
    }
}

impl fmt::Display for SafetyLtl {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.source)
    }
}

// ---------------------------------------------------------------- parser --

struct Parser<'a> {
    src: &'a str,
    pos: usize,
}

impl<'a> Parser<'a> {
    fn new(src: &'a str) -> Self {
        Self { src, pos: 0 }
    }

    fn rest(&self) -> &'a str {
        &self.src[self.pos..]
    }

    fn skip_ws(&mut self) {
        while self.rest().starts_with(|c: char| c.is_whitespace()) {
            self.pos += 1;
        }
    }

    fn eat_str(&mut self, s: &str) -> bool {
        if self.rest().starts_with(s) {
            self.pos += s.len();
            true
        } else {
            false
        }
    }

    /// Eat keyword `s` only when not followed by an identifier char.
    fn eat_kw(&mut self, s: &str) -> bool {
        if self.rest().starts_with(s) {
            let after = &self.rest()[s.len()..];
            if !after.starts_with(|c: char| c.is_alphanumeric() || c == '_') {
                self.pos += s.len();
                return true;
            }
        }
        false
    }

    // precedence-climbing: higher binds tighter
    fn peek_binop(&mut self) -> Option<(BinOp, u8)> {
        self.skip_ws();
        let r = self.rest();
        // order matters: match longest first
        const TABLE: &[(&str, BinOp, u8)] = &[
            ("->", BinOp::Implies, 1),
            ("||", BinOp::Or, 2),
            ("&&", BinOp::And, 3),
            ("==", BinOp::Eq, 4),
            ("!=", BinOp::Ne, 4),
            ("<=", BinOp::Le, 5),
            (">=", BinOp::Ge, 5),
            ("<", BinOp::Lt, 5),
            (">", BinOp::Gt, 5),
            ("+", BinOp::Add, 6),
            ("-", BinOp::Sub, 6),
            ("*", BinOp::Mul, 7),
            ("/", BinOp::Div, 7),
            ("%", BinOp::Mod, 7),
        ];
        for (tok, op, prec) in TABLE {
            if r.starts_with(tok) {
                return Some((*op, *prec));
            }
        }
        None
    }

    fn parse_expr(&mut self, min_prec: u8) -> Result<Expr> {
        let mut lhs = self.parse_unary()?;
        while let Some((op, prec)) = self.peek_binop() {
            if prec < min_prec {
                break;
            }
            // consume the operator token
            let tok_len = match op {
                BinOp::Implies | BinOp::Or | BinOp::And | BinOp::Eq | BinOp::Ne
                | BinOp::Le | BinOp::Ge => 2,
                _ => 1,
            };
            self.pos += tok_len;
            // implication is right-associative; the rest left-associative
            let next_min = if op == BinOp::Implies { prec } else { prec + 1 };
            let rhs = self.parse_expr(next_min)?;
            lhs = Expr::Bin(op, Box::new(lhs), Box::new(rhs));
        }
        Ok(lhs)
    }

    fn parse_unary(&mut self) -> Result<Expr> {
        self.skip_ws();
        if self.eat_str("!") {
            return Ok(Expr::Not(Box::new(self.parse_unary()?)));
        }
        if self.eat_str("-") {
            return Ok(Expr::Neg(Box::new(self.parse_unary()?)));
        }
        if self.eat_str("(") {
            let e = self.parse_expr(0)?;
            self.skip_ws();
            if !self.eat_str(")") {
                bail!("expected ')' at `{}`", self.rest());
            }
            return Ok(e);
        }
        let r = self.rest();
        if r.starts_with(|c: char| c.is_ascii_digit()) {
            let end = r.find(|c: char| !c.is_ascii_digit()).unwrap_or(r.len());
            let v: i64 = r[..end].parse()?;
            self.pos += end;
            return Ok(Expr::Int(v));
        }
        if r.starts_with(|c: char| c.is_alphabetic() || c == '_') {
            let end = r
                .find(|c: char| !(c.is_alphanumeric() || c == '_'))
                .unwrap_or(r.len());
            let name = &r[..end];
            // reject temporal operators outside the supported fragment
            if matches!(name, "U" | "X" | "F" | "W" | "R") {
                bail!("temporal operator `{}` outside the safety fragment (only G(...) supported)", name);
            }
            self.pos += end;
            if name == "true" {
                return Ok(Expr::Int(1));
            }
            if name == "false" {
                return Ok(Expr::Int(0));
            }
            return Ok(Expr::Var(name.to_string()));
        }
        bail!("cannot parse property at `{}`", r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn env<'a>(pairs: &'a [(&'a str, i64)]) -> impl Fn(&str) -> Option<i64> + 'a {
        move |n| pairs.iter().find(|(k, _)| *k == n).map(|(_, v)| *v)
    }

    #[test]
    fn parse_over_time() {
        let p = SafetyLtl::parse("G(FIN -> time > 100)").unwrap();
        let e = env(&[("FIN", 1), ("time", 101)]);
        assert!(p.holds(&e).unwrap());
        let e = env(&[("FIN", 1), ("time", 100)]);
        assert!(!p.holds(&e).unwrap()); // terminated within T: violation
        let e = env(&[("FIN", 0), ("time", 5)]);
        assert!(p.holds(&e).unwrap()); // not terminated: vacuous
    }

    #[test]
    fn parse_box_syntax() {
        let p = SafetyLtl::parse("[](!FIN)").unwrap();
        assert!(p.holds(&env(&[("FIN", 0)])).unwrap());
        assert!(!p.holds(&env(&[("FIN", 1)])).unwrap());
    }

    #[test]
    fn constructors_match_paper() {
        let o = SafetyLtl::over_time(44);
        assert!(!o.holds(&env(&[("FIN", 1), ("time", 44)])).unwrap());
        assert!(o.holds(&env(&[("FIN", 1), ("time", 45)])).unwrap());
        let t = SafetyLtl::non_termination();
        assert!(!t.holds(&env(&[("FIN", 1)])).unwrap());
    }

    #[test]
    fn precedence_and_arith() {
        let p = SafetyLtl::parse("G(a + 2 * 3 == 7 && b % 2 == 0)").unwrap();
        assert!(p.holds(&env(&[("a", 1), ("b", 4)])).unwrap());
        assert!(!p.holds(&env(&[("a", 1), ("b", 3)])).unwrap());
    }

    #[test]
    fn implies_right_assoc() {
        // a -> b -> c parses as a -> (b -> c)
        let p = SafetyLtl::parse("a -> b -> c").unwrap();
        assert!(p.holds(&env(&[("a", 1), ("b", 1), ("c", 1)])).unwrap());
        assert!(p.holds(&env(&[("a", 0), ("b", 1), ("c", 0)])).unwrap());
        assert!(!p.holds(&env(&[("a", 1), ("b", 1), ("c", 0)])).unwrap());
    }

    #[test]
    fn unknown_var_is_error() {
        let p = SafetyLtl::parse("G(nosuch > 0)").unwrap();
        assert!(p.holds(&env(&[])).is_err());
    }

    #[test]
    fn liveness_rejected() {
        assert!(SafetyLtl::parse("F(FIN)").is_err());
        assert!(SafetyLtl::parse("G(a U b)").is_err());
    }

    #[test]
    fn division_by_zero_is_error() {
        let p = SafetyLtl::parse("G(1 / a > 0)").unwrap();
        assert!(p.holds(&env(&[("a", 0)])).is_err());
    }

    #[test]
    fn vars_collected() {
        let p = SafetyLtl::parse("G(FIN -> time > T)").unwrap();
        let mut vs = Vec::new();
        p.body.vars(&mut vs);
        assert_eq!(vs, vec!["FIN".to_string(), "time".into(), "T".into()]);
    }

    #[test]
    fn trailing_garbage_rejected() {
        assert!(SafetyLtl::parse("G(FIN) xyz").is_err());
    }
}
