//! LTL properties — safety fragment.
//!
//! The paper verifies two formulas:
//!   Φo = G(FIN -> time > T)   (over-time property, §4 Step 2)
//!   Φt = G(!FIN)              (non-termination property, §5)
//!
//! Both are *safety* properties: a violation is witnessed by a single
//! reachable state, so a state monitor suffices and no Büchi construction
//! is needed. We parse exactly the `G(<boolean state expression>)` fragment
//! (also written `[](...)`), with integer arithmetic, comparisons, boolean
//! connectives and `->` implication over named model variables; anything
//! outside the fragment (nested temporal operators, U, X, F) is rejected
//! with a clear error. This is the same fragment the paper uses.

use crate::model::TransitionSystem;
use crate::util::error::{anyhow, bail, ensure, Result};
use std::fmt;

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Expr {
    Int(i64),
    Var(String),
    Not(Box<Expr>),
    Neg(Box<Expr>),
    Bin(BinOp, Box<Expr>, Box<Expr>),
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BinOp {
    Or,
    And,
    Implies,
    Eq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
    Add,
    Sub,
    Mul,
    Div,
    Mod,
}

impl Expr {
    /// Evaluate with a variable lookup. Booleans are 0/1; any nonzero value
    /// is truthy (C/Promela convention).
    pub fn eval(&self, lookup: &dyn Fn(&str) -> Option<i64>) -> Result<i64> {
        Ok(match self {
            Expr::Int(v) => *v,
            Expr::Var(name) => lookup(name)
                .ok_or_else(|| anyhow!("unknown variable `{}` in property", name))?,
            Expr::Not(e) => (e.eval(lookup)? == 0) as i64,
            Expr::Neg(e) => -(e.eval(lookup)?),
            Expr::Bin(op, a, b) => {
                use BinOp::*;
                match op {
                    And => ((a.eval(lookup)? != 0) && (b.eval(lookup)? != 0)) as i64,
                    Or => ((a.eval(lookup)? != 0) || (b.eval(lookup)? != 0)) as i64,
                    Implies => ((a.eval(lookup)? == 0) || (b.eval(lookup)? != 0)) as i64,
                    _ => {
                        let (x, y) = (a.eval(lookup)?, b.eval(lookup)?);
                        match op {
                            Eq => (x == y) as i64,
                            Ne => (x != y) as i64,
                            Lt => (x < y) as i64,
                            Le => (x <= y) as i64,
                            Gt => (x > y) as i64,
                            Ge => (x >= y) as i64,
                            Add => x.wrapping_add(y),
                            Sub => x.wrapping_sub(y),
                            Mul => x.wrapping_mul(y),
                            Div => {
                                if y == 0 {
                                    bail!("division by zero in property");
                                }
                                x / y
                            }
                            Mod => {
                                if y == 0 {
                                    bail!("mod by zero in property");
                                }
                                x % y
                            }
                            _ => unreachable!(),
                        }
                    }
                }
            }
        })
    }

    /// Free variables referenced by the expression.
    pub fn vars(&self, out: &mut Vec<String>) {
        match self {
            Expr::Int(_) => {}
            Expr::Var(n) => {
                if !out.contains(n) {
                    out.push(n.clone());
                }
            }
            Expr::Not(e) | Expr::Neg(e) => e.vars(out),
            Expr::Bin(_, a, b) => {
                a.vars(out);
                b.vars(out);
            }
        }
    }
}

/// `G(body)` — holds on a run iff `body` holds in every state.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SafetyLtl {
    pub body: Expr,
    pub source: String,
}

impl SafetyLtl {
    /// Parse `G(expr)` / `[](expr)` / bare `expr` (treated as G(expr)).
    pub fn parse(src: &str) -> Result<Self> {
        let mut p = Parser::new(src);
        p.skip_ws();
        let had_g = if p.eat_kw("G") || p.eat_str("[]") {
            p.skip_ws();
            if !p.eat_str("(") {
                bail!("expected '(' after temporal G in `{}`", src);
            }
            true
        } else {
            false
        };
        let body = p.parse_expr(0)?;
        if had_g {
            p.skip_ws();
            if !p.eat_str(")") {
                bail!("expected closing ')' in `{}`", src);
            }
        }
        p.skip_ws();
        if !p.rest().is_empty() {
            bail!("trailing input `{}` in property `{}`", p.rest(), src);
        }
        Ok(Self { body, source: src.to_string() })
    }

    /// The over-time property Φo = G(FIN -> time > T) with a concrete T.
    pub fn over_time(t: i64) -> Self {
        Self::parse(&format!("G(FIN -> time > {})", t)).expect("static formula")
    }

    /// The non-termination property Φt = G(!FIN).
    pub fn non_termination() -> Self {
        Self::parse("G(!FIN)").expect("static formula")
    }

    /// Does the invariant hold in this state? (false = violation here)
    pub fn holds(&self, lookup: &dyn Fn(&str) -> Option<i64>) -> Result<bool> {
        Ok(self.body.eval(lookup)? != 0)
    }

    /// Compile the body to a flat bytecode program with variable names
    /// resolved against `model` once — the checker's per-state hot path
    /// then runs [`CompiledProp::holds_state`] with no string matching and
    /// no recursive AST dispatch. Equivalent to [`Expr::eval`] on every
    /// input, including short-circuit laziness (see [`CompiledProp`]).
    pub fn compile<M: TransitionSystem + ?Sized>(&self, model: &M) -> Result<CompiledProp> {
        CompiledProp::new(self, model)
    }
}

impl fmt::Display for SafetyLtl {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.source)
    }
}

// ------------------------------------------------------ compiled evaluator --

/// One bytecode instruction of a [`CompiledProp`]. Binary connectives are
/// compiled to conditional jumps so the program short-circuits exactly like
/// [`Expr::eval`]: the right operand of `&&` / `||` / `->` is neither
/// evaluated nor error-checked when the left operand decides the result.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Op {
    Const(i64),
    /// push the value of variable slot `i` (errors if unavailable in state)
    Var(u8),
    Not,
    Neg,
    Add,
    Sub,
    Mul,
    Div,
    Mod,
    Eq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
    /// top = (top != 0) — normalizes connective operands to 0/1
    Norm,
    /// if top == 0 jump to target keeping top, else pop and fall through
    Jz(u16),
    /// if top != 0 jump to target keeping top, else pop and fall through
    Jnz(u16),
}

#[derive(Debug, Clone)]
struct VarBinding {
    name: String,
    slot: Option<u32>,
}

/// A [`SafetyLtl`] body lowered to postfix bytecode with variables resolved
/// to dense slot indices — the checker's allocation-free per-state monitor.
///
/// Variable access: at compile time each distinct name is bound either to a
/// native model slot ([`TransitionSystem::resolve_slot`]) or, as a
/// fallback, to a per-state `eval_var` lookup by name. When *all* names
/// resolve natively, evaluation performs a single
/// [`TransitionSystem::eval_slots`] bulk read per state and never touches a
/// string. Unavailable variables are detected at fill time but error only
/// when the program actually reads them, so short-circuited subexpressions
/// behave exactly as in the interpreted evaluator.
#[derive(Debug, Clone)]
pub struct CompiledProp {
    ops: Vec<Op>,
    vars: Vec<VarBinding>,
    /// ids aligned with `vars`, present iff every variable resolved natively
    slot_ids: Option<Vec<u32>>,
    source: String,
}

/// Reusable per-worker evaluation buffers (slot values + operand stack) so
/// the checker's inner loop performs zero allocation after warmup.
#[derive(Debug, Default)]
pub struct EvalScratch {
    vals: Vec<i64>,
    stack: Vec<i64>,
}

impl CompiledProp {
    fn new<M: TransitionSystem + ?Sized>(prop: &SafetyLtl, model: &M) -> Result<Self> {
        let mut names = Vec::new();
        prop.body.vars(&mut names);
        ensure!(
            names.len() <= 64,
            "property `{}` references {} variables (compiled evaluator supports at most 64)",
            prop.source,
            names.len()
        );
        let vars: Vec<VarBinding> = names
            .into_iter()
            .map(|name| {
                let slot = model.resolve_slot(&name);
                VarBinding { name, slot }
            })
            .collect();
        let slot_ids = vars.iter().map(|v| v.slot).collect::<Option<Vec<u32>>>();
        let mut ops = Vec::new();
        emit(&prop.body, &vars, &mut ops);
        ensure!(
            ops.len() <= u16::MAX as usize,
            "property `{}` compiles to {} ops (max {})",
            prop.source,
            ops.len(),
            u16::MAX
        );
        Ok(Self { ops, vars, slot_ids, source: prop.source.clone() })
    }

    /// The property source this program was compiled from.
    pub fn source(&self) -> &str {
        &self.source
    }

    /// Evaluate the body in state `s`. With native slots: one bulk
    /// `eval_slots` read, then a linear bytecode pass. Without (fallback):
    /// each `Var` op performs one lazy `eval_var` lookup at read time, so
    /// short-circuited variables are never looked up — exactly the
    /// interpreter's cost and error behavior.
    pub fn eval_state<M: TransitionSystem + ?Sized>(
        &self,
        model: &M,
        s: &M::State,
        scratch: &mut EvalScratch,
    ) -> Result<i64> {
        let EvalScratch { vals, stack } = scratch;
        if let Some(ids) = &self.slot_ids {
            vals.clear();
            vals.resize(self.vars.len(), 0);
            let missing = model.eval_slots(s, ids, vals);
            let vars = &self.vars;
            self.run(
                |i| {
                    if missing & (1u64 << i) != 0 {
                        Err(anyhow!(
                            "unknown variable `{}` in property",
                            vars[i as usize].name
                        ))
                    } else {
                        Ok(vals[i as usize])
                    }
                },
                stack,
            )
        } else {
            let vars = &self.vars;
            self.run(
                |i| {
                    let name = &vars[i as usize].name;
                    model
                        .eval_var(s, name)
                        .ok_or_else(|| anyhow!("unknown variable `{}` in property", name))
                },
                stack,
            )
        }
    }

    /// Does the invariant hold in `s`? (false = violation here)
    pub fn holds_state<M: TransitionSystem + ?Sized>(
        &self,
        model: &M,
        s: &M::State,
        scratch: &mut EvalScratch,
    ) -> Result<bool> {
        Ok(self.eval_state(model, s, scratch)? != 0)
    }

    fn run<F: FnMut(u8) -> Result<i64>>(&self, mut var: F, stack: &mut Vec<i64>) -> Result<i64> {
        stack.clear();
        let mut pc = 0usize;
        while pc < self.ops.len() {
            match self.ops[pc] {
                Op::Const(v) => stack.push(v),
                Op::Var(i) => stack.push(var(i)?),
                Op::Not => {
                    let t = stack.last_mut().expect("compiled stack underflow");
                    *t = (*t == 0) as i64;
                }
                Op::Neg => {
                    let t = stack.last_mut().expect("compiled stack underflow");
                    *t = -*t; // same overflow behavior as the interpreter's `-`
                }
                Op::Norm => {
                    let t = stack.last_mut().expect("compiled stack underflow");
                    *t = (*t != 0) as i64;
                }
                Op::Jz(target) => {
                    if *stack.last().expect("compiled stack underflow") == 0 {
                        pc = target as usize;
                        continue;
                    }
                    stack.pop();
                }
                Op::Jnz(target) => {
                    if *stack.last().expect("compiled stack underflow") != 0 {
                        pc = target as usize;
                        continue;
                    }
                    stack.pop();
                }
                op => {
                    let b = stack.pop().expect("compiled stack underflow");
                    let a = stack.last_mut().expect("compiled stack underflow");
                    *a = match op {
                        Op::Add => a.wrapping_add(b),
                        Op::Sub => a.wrapping_sub(b),
                        Op::Mul => a.wrapping_mul(b),
                        Op::Div => {
                            if b == 0 {
                                bail!("division by zero in property");
                            }
                            *a / b
                        }
                        Op::Mod => {
                            if b == 0 {
                                bail!("mod by zero in property");
                            }
                            *a % b
                        }
                        Op::Eq => (*a == b) as i64,
                        Op::Ne => (*a != b) as i64,
                        Op::Lt => (*a < b) as i64,
                        Op::Le => (*a <= b) as i64,
                        Op::Gt => (*a > b) as i64,
                        Op::Ge => (*a >= b) as i64,
                        _ => unreachable!("non-binary op in binary dispatch"),
                    };
                }
            }
            pc += 1;
        }
        Ok(stack.pop().expect("compiled program left an empty stack"))
    }
}

fn emit(e: &Expr, vars: &[VarBinding], ops: &mut Vec<Op>) {
    match e {
        Expr::Int(v) => ops.push(Op::Const(*v)),
        Expr::Var(n) => {
            let i = vars
                .iter()
                .position(|v| v.name == *n)
                .expect("every variable is collected before emission");
            ops.push(Op::Var(i as u8));
        }
        Expr::Not(a) => {
            emit(a, vars, ops);
            ops.push(Op::Not);
        }
        Expr::Neg(a) => {
            emit(a, vars, ops);
            ops.push(Op::Neg);
        }
        Expr::Bin(op, a, b) => match op {
            BinOp::And => {
                emit(a, vars, ops);
                ops.push(Op::Norm);
                let j = ops.len();
                ops.push(Op::Jz(0));
                emit(b, vars, ops);
                ops.push(Op::Norm);
                ops[j] = Op::Jz(ops.len() as u16);
            }
            BinOp::Or => {
                emit(a, vars, ops);
                ops.push(Op::Norm);
                let j = ops.len();
                ops.push(Op::Jnz(0));
                emit(b, vars, ops);
                ops.push(Op::Norm);
                ops[j] = Op::Jnz(ops.len() as u16);
            }
            BinOp::Implies => {
                // (a == 0) || (b != 0): Not normalizes, Jnz short-circuits
                emit(a, vars, ops);
                ops.push(Op::Not);
                let j = ops.len();
                ops.push(Op::Jnz(0));
                emit(b, vars, ops);
                ops.push(Op::Norm);
                ops[j] = Op::Jnz(ops.len() as u16);
            }
            _ => {
                emit(a, vars, ops);
                emit(b, vars, ops);
                ops.push(match op {
                    BinOp::Add => Op::Add,
                    BinOp::Sub => Op::Sub,
                    BinOp::Mul => Op::Mul,
                    BinOp::Div => Op::Div,
                    BinOp::Mod => Op::Mod,
                    BinOp::Eq => Op::Eq,
                    BinOp::Ne => Op::Ne,
                    BinOp::Lt => Op::Lt,
                    BinOp::Le => Op::Le,
                    BinOp::Gt => Op::Gt,
                    BinOp::Ge => Op::Ge,
                    BinOp::And | BinOp::Or | BinOp::Implies => {
                        unreachable!("connectives handled above")
                    }
                });
            }
        },
    }
}

// ---------------------------------------------------------------- parser --

struct Parser<'a> {
    src: &'a str,
    pos: usize,
}

impl<'a> Parser<'a> {
    fn new(src: &'a str) -> Self {
        Self { src, pos: 0 }
    }

    fn rest(&self) -> &'a str {
        &self.src[self.pos..]
    }

    fn skip_ws(&mut self) {
        while self.rest().starts_with(|c: char| c.is_whitespace()) {
            self.pos += 1;
        }
    }

    fn eat_str(&mut self, s: &str) -> bool {
        if self.rest().starts_with(s) {
            self.pos += s.len();
            true
        } else {
            false
        }
    }

    /// Eat keyword `s` only when not followed by an identifier char.
    fn eat_kw(&mut self, s: &str) -> bool {
        if self.rest().starts_with(s) {
            let after = &self.rest()[s.len()..];
            if !after.starts_with(|c: char| c.is_alphanumeric() || c == '_') {
                self.pos += s.len();
                return true;
            }
        }
        false
    }

    // precedence-climbing: higher binds tighter
    fn peek_binop(&mut self) -> Option<(BinOp, u8)> {
        self.skip_ws();
        let r = self.rest();
        // order matters: match longest first
        const TABLE: &[(&str, BinOp, u8)] = &[
            ("->", BinOp::Implies, 1),
            ("||", BinOp::Or, 2),
            ("&&", BinOp::And, 3),
            ("==", BinOp::Eq, 4),
            ("!=", BinOp::Ne, 4),
            ("<=", BinOp::Le, 5),
            (">=", BinOp::Ge, 5),
            ("<", BinOp::Lt, 5),
            (">", BinOp::Gt, 5),
            ("+", BinOp::Add, 6),
            ("-", BinOp::Sub, 6),
            ("*", BinOp::Mul, 7),
            ("/", BinOp::Div, 7),
            ("%", BinOp::Mod, 7),
        ];
        for (tok, op, prec) in TABLE {
            if r.starts_with(tok) {
                return Some((*op, *prec));
            }
        }
        None
    }

    fn parse_expr(&mut self, min_prec: u8) -> Result<Expr> {
        let mut lhs = self.parse_unary()?;
        while let Some((op, prec)) = self.peek_binop() {
            if prec < min_prec {
                break;
            }
            // consume the operator token
            let tok_len = match op {
                BinOp::Implies | BinOp::Or | BinOp::And | BinOp::Eq | BinOp::Ne
                | BinOp::Le | BinOp::Ge => 2,
                _ => 1,
            };
            self.pos += tok_len;
            // implication is right-associative; the rest left-associative
            let next_min = if op == BinOp::Implies { prec } else { prec + 1 };
            let rhs = self.parse_expr(next_min)?;
            lhs = Expr::Bin(op, Box::new(lhs), Box::new(rhs));
        }
        Ok(lhs)
    }

    fn parse_unary(&mut self) -> Result<Expr> {
        self.skip_ws();
        if self.eat_str("!") {
            return Ok(Expr::Not(Box::new(self.parse_unary()?)));
        }
        if self.eat_str("-") {
            return Ok(Expr::Neg(Box::new(self.parse_unary()?)));
        }
        if self.eat_str("(") {
            let e = self.parse_expr(0)?;
            self.skip_ws();
            if !self.eat_str(")") {
                bail!("expected ')' at `{}`", self.rest());
            }
            return Ok(e);
        }
        let r = self.rest();
        if r.starts_with(|c: char| c.is_ascii_digit()) {
            let end = r.find(|c: char| !c.is_ascii_digit()).unwrap_or(r.len());
            let v: i64 = r[..end].parse()?;
            self.pos += end;
            return Ok(Expr::Int(v));
        }
        if r.starts_with(|c: char| c.is_alphabetic() || c == '_') {
            let end = r
                .find(|c: char| !(c.is_alphanumeric() || c == '_'))
                .unwrap_or(r.len());
            let name = &r[..end];
            // reject temporal operators outside the supported fragment
            if matches!(name, "U" | "X" | "F" | "W" | "R") {
                bail!("temporal operator `{}` outside the safety fragment (only G(...) supported)", name);
            }
            self.pos += end;
            if name == "true" {
                return Ok(Expr::Int(1));
            }
            if name == "false" {
                return Ok(Expr::Int(0));
            }
            return Ok(Expr::Var(name.to_string()));
        }
        bail!("cannot parse property at `{}`", r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn env<'a>(pairs: &'a [(&'a str, i64)]) -> impl Fn(&str) -> Option<i64> + 'a {
        move |n| pairs.iter().find(|(k, _)| *k == n).map(|(_, v)| *v)
    }

    #[test]
    fn parse_over_time() {
        let p = SafetyLtl::parse("G(FIN -> time > 100)").unwrap();
        let e = env(&[("FIN", 1), ("time", 101)]);
        assert!(p.holds(&e).unwrap());
        let e = env(&[("FIN", 1), ("time", 100)]);
        assert!(!p.holds(&e).unwrap()); // terminated within T: violation
        let e = env(&[("FIN", 0), ("time", 5)]);
        assert!(p.holds(&e).unwrap()); // not terminated: vacuous
    }

    #[test]
    fn parse_box_syntax() {
        let p = SafetyLtl::parse("[](!FIN)").unwrap();
        assert!(p.holds(&env(&[("FIN", 0)])).unwrap());
        assert!(!p.holds(&env(&[("FIN", 1)])).unwrap());
    }

    #[test]
    fn constructors_match_paper() {
        let o = SafetyLtl::over_time(44);
        assert!(!o.holds(&env(&[("FIN", 1), ("time", 44)])).unwrap());
        assert!(o.holds(&env(&[("FIN", 1), ("time", 45)])).unwrap());
        let t = SafetyLtl::non_termination();
        assert!(!t.holds(&env(&[("FIN", 1)])).unwrap());
    }

    #[test]
    fn precedence_and_arith() {
        let p = SafetyLtl::parse("G(a + 2 * 3 == 7 && b % 2 == 0)").unwrap();
        assert!(p.holds(&env(&[("a", 1), ("b", 4)])).unwrap());
        assert!(!p.holds(&env(&[("a", 1), ("b", 3)])).unwrap());
    }

    #[test]
    fn implies_right_assoc() {
        // a -> b -> c parses as a -> (b -> c)
        let p = SafetyLtl::parse("a -> b -> c").unwrap();
        assert!(p.holds(&env(&[("a", 1), ("b", 1), ("c", 1)])).unwrap());
        assert!(p.holds(&env(&[("a", 0), ("b", 1), ("c", 0)])).unwrap());
        assert!(!p.holds(&env(&[("a", 1), ("b", 1), ("c", 0)])).unwrap());
    }

    #[test]
    fn unknown_var_is_error() {
        let p = SafetyLtl::parse("G(nosuch > 0)").unwrap();
        assert!(p.holds(&env(&[])).is_err());
    }

    #[test]
    fn liveness_rejected() {
        assert!(SafetyLtl::parse("F(FIN)").is_err());
        assert!(SafetyLtl::parse("G(a U b)").is_err());
    }

    #[test]
    fn division_by_zero_is_error() {
        let p = SafetyLtl::parse("G(1 / a > 0)").unwrap();
        assert!(p.holds(&env(&[("a", 0)])).is_err());
    }

    #[test]
    fn vars_collected() {
        let p = SafetyLtl::parse("G(FIN -> time > T)").unwrap();
        let mut vs = Vec::new();
        p.body.vars(&mut vs);
        assert_eq!(vs, vec!["FIN".to_string(), "time".into(), "T".into()]);
    }

    #[test]
    fn trailing_garbage_rejected() {
        assert!(SafetyLtl::parse("G(FIN) xyz").is_err());
    }

    // ------------------------------------------- compiled evaluator --

    /// Single-state model exposing `pairs` by name only (fallback path).
    struct EnvModel {
        pairs: Vec<(String, i64)>,
    }

    impl EnvModel {
        fn new(pairs: &[(&str, i64)]) -> Self {
            Self { pairs: pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect() }
        }
    }

    impl TransitionSystem for EnvModel {
        type State = u8;

        fn initial_states(&self) -> Vec<u8> {
            vec![0]
        }

        fn successors(&self, _s: &u8, out: &mut Vec<u8>) {
            out.clear();
        }

        fn encode(&self, s: &u8, out: &mut Vec<u8>) {
            out.clear();
            out.push(*s);
        }

        fn eval_var(&self, _s: &u8, name: &str) -> Option<i64> {
            self.pairs.iter().find(|(k, _)| k == name).map(|(_, v)| *v)
        }
    }

    /// Same environment, exposed through the native slot interface; `None`
    /// values resolve but are unavailable in the state (like WG pre-choice).
    struct SlotEnvModel {
        pairs: Vec<(String, Option<i64>)>,
    }

    impl TransitionSystem for SlotEnvModel {
        type State = u8;

        fn initial_states(&self) -> Vec<u8> {
            vec![0]
        }

        fn successors(&self, _s: &u8, out: &mut Vec<u8>) {
            out.clear();
        }

        fn encode(&self, s: &u8, out: &mut Vec<u8>) {
            out.clear();
            out.push(*s);
        }

        fn eval_var(&self, _s: &u8, name: &str) -> Option<i64> {
            self.pairs.iter().find(|(k, _)| k == name).and_then(|(_, v)| *v)
        }

        fn resolve_slot(&self, name: &str) -> Option<u32> {
            self.pairs.iter().position(|(k, _)| k == name).map(|i| i as u32)
        }

        fn eval_slots(&self, _s: &u8, ids: &[u32], out: &mut [i64]) -> u64 {
            let mut missing = 0u64;
            for (i, &id) in ids.iter().enumerate() {
                match self.pairs[id as usize].1 {
                    Some(v) => out[i] = v,
                    None => missing |= 1u64 << i,
                }
            }
            missing
        }
    }

    fn both_ways(src: &str, pairs: &[(&str, i64)]) -> (Result<i64>, Result<i64>) {
        let p = SafetyLtl::parse(src).unwrap();
        let lookup = |n: &str| pairs.iter().find(|(k, _)| *k == n).map(|(_, v)| *v);
        let interp = p.body.eval(&lookup);
        let m = EnvModel::new(pairs);
        let c = p.compile(&m).unwrap();
        let compiled = c.eval_state(&m, &0, &mut EvalScratch::default());
        (interp, compiled)
    }

    #[test]
    fn compiled_matches_interpreter() {
        for (src, pairs) in [
            ("G(FIN -> time > 100)", &[("FIN", 1i64), ("time", 101)][..]),
            ("G(FIN -> time > 100)", &[("FIN", 1), ("time", 100)][..]),
            ("G(FIN -> time > 100)", &[("FIN", 0), ("time", 5)][..]),
            ("G(a + 2 * 3 == 7 && b % 2 == 0)", &[("a", 1), ("b", 4)][..]),
            ("G(a + 2 * 3 == 7 && b % 2 == 0)", &[("a", 1), ("b", 3)][..]),
            ("a -> b -> c", &[("a", 1), ("b", 1), ("c", 0)][..]),
            ("G(-a == 0 - a)", &[("a", 17)][..]),
            ("G(!(a < b) || a / b >= 1)", &[("a", 9), ("b", 3)][..]),
        ] {
            let (i, c) = both_ways(src, pairs);
            assert_eq!(i.unwrap(), c.unwrap(), "{} on {:?}", src, pairs);
        }
    }

    #[test]
    fn compiled_short_circuits_like_interpreter() {
        // unknown variable behind a short circuit: neither path errors
        let (i, c) = both_ways("G(FIN -> nosuch > 0)", &[("FIN", 0)]);
        assert_eq!(i.unwrap(), 1);
        assert_eq!(c.unwrap(), 1);
        // ... and both error once the guard is hot
        let (i, c) = both_ways("G(FIN -> nosuch > 0)", &[("FIN", 1)]);
        assert!(i.is_err() && c.is_err());
        // division by zero guarded by && never evaluates
        let (i, c) = both_ways("G(x != 0 && 10 / x > 1)", &[("x", 0)]);
        assert_eq!(i.unwrap(), 0);
        assert_eq!(c.unwrap(), 0);
        // unguarded division by zero errors in both
        let (i, c) = both_ways("G(10 / x > 1)", &[("x", 0)]);
        assert!(i.is_err() && c.is_err());
    }

    #[test]
    fn compiled_slot_path_matches_fallback() {
        let p = SafetyLtl::parse("G(FIN -> time > 40)").unwrap();
        let m = SlotEnvModel {
            pairs: vec![("FIN".into(), Some(1)), ("time".into(), Some(44))],
        };
        let c = p.compile(&m).unwrap();
        let mut scratch = EvalScratch::default();
        assert_eq!(c.eval_state(&m, &0, &mut scratch).unwrap(), 1);
        // unavailable slot behind a false guard is not an error
        let m = SlotEnvModel { pairs: vec![("FIN".into(), Some(0)), ("time".into(), None)] };
        let c = p.compile(&m).unwrap();
        assert_eq!(c.eval_state(&m, &0, &mut scratch).unwrap(), 1);
        // ... but errors when read
        let m = SlotEnvModel { pairs: vec![("FIN".into(), Some(1)), ("time".into(), None)] };
        let c = p.compile(&m).unwrap();
        assert!(c.eval_state(&m, &0, &mut scratch).is_err());
    }

    #[test]
    fn compiled_reports_source() {
        let p = SafetyLtl::parse("G(!FIN)").unwrap();
        let m = EnvModel::new(&[("FIN", 0)]);
        assert_eq!(p.compile(&m).unwrap().source(), "G(!FIN)");
    }
}
