//! Counterexample trails — the core artifact of the paper's method.
//!
//! SPIN writes `.trail` files and replays them in simulation mode to expose
//! variable values (paper §4 Step 4). Our checker keeps the violating path
//! in memory; [`Trail`] carries the states, and the tuner reads the tuning
//! parameters (WG, TS) and the model time off the final state through the
//! model's `eval_var` interface.

use super::TransitionSystem;

/// A path from an initial state to a (violating) state.
#[derive(Debug, Clone)]
pub struct Trail<S> {
    pub states: Vec<S>,
}

impl<S> Trail<S> {
    /// Number of transitions (SPIN's "steps" analogue).
    pub fn steps(&self) -> usize {
        self.states.len().saturating_sub(1)
    }

    pub fn last(&self) -> &S {
        self.states.last().expect("trail is never empty")
    }

    /// Read a model variable off the final (violating) state.
    pub fn final_var<M>(&self, model: &M, name: &str) -> Option<i64>
    where
        M: TransitionSystem<State = S>,
    {
        model.eval_var(self.last(), name)
    }

    /// Render the trail like `spin -t` simulation output (one line/state).
    pub fn render<M>(&self, model: &M, limit: usize) -> String
    where
        M: TransitionSystem<State = S>,
    {
        let mut out = String::new();
        let n = self.states.len();
        for (i, s) in self.states.iter().enumerate() {
            if n > limit && i >= limit / 2 && i < n - limit / 2 {
                if i == limit / 2 {
                    out.push_str(&format!("  ... ({} states elided) ...\n", n - limit));
                }
                continue;
            }
            out.push_str(&format!("{:>6}: {}\n", i, model.describe(s)));
        }
        out
    }
}

/// A property violation found by the checker: the trail plus bookkeeping.
#[derive(Debug, Clone)]
pub struct Violation<S> {
    pub trail: Trail<S>,
    /// Search depth at which the violation was found.
    pub depth: usize,
    /// Seconds since search start when this violation was found.
    pub found_after: std::time::Duration,
}

impl<S> Violation<S> {
    pub fn steps(&self) -> usize {
        self.trail.steps()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::TransitionSystem;

    /// Toy counter system: 0..=3, terminal at 3.
    struct Counter;

    impl TransitionSystem for Counter {
        type State = u8;

        fn initial_states(&self) -> Vec<u8> {
            vec![0]
        }

        fn successors(&self, s: &u8, out: &mut Vec<u8>) {
            out.clear();
            if *s < 3 {
                out.push(s + 1);
            }
        }

        fn encode(&self, s: &u8, out: &mut Vec<u8>) {
            out.clear();
            out.push(*s);
        }

        fn eval_var(&self, s: &u8, name: &str) -> Option<i64> {
            (name == "c").then_some(*s as i64)
        }
    }

    #[test]
    fn steps_and_final_var() {
        let t = Trail { states: vec![0u8, 1, 2, 3] };
        assert_eq!(t.steps(), 3);
        assert_eq!(t.final_var(&Counter, "c"), Some(3));
        assert_eq!(t.final_var(&Counter, "bogus"), None);
    }

    #[test]
    fn render_elides_long_trails() {
        let t = Trail { states: (0u8..100).collect() };
        let r = t.render(&Counter, 10);
        assert!(r.contains("elided"));
        assert!(r.lines().count() < 20);
    }
}
