//! # mcautotune
//!
//! Model-checking-driven auto-tuning of data-parallel (OpenCL-style)
//! kernels — a Rust + JAX + Pallas reproduction of *"Auto-Tuning
//! High-Performance Programs Using Model Checking in Promela"*
//! (Garanina, Staroletov, Gorlatch, 2023).
//!
//! The paper's four-step counterexample method:
//!
//! 1. **Model** the parallel program + target platform ([`platform`] native
//!    engines, or [`promela`] — a Promela-subset front end executing the
//!    shipped `models/*.pml` with full process interleaving, compiled to a
//!    bytecode VM over flat packed states with a tree-walking reference
//!    interpreter behind it);
//! 2. **State** the over-time property Φo = `G(FIN -> time > T)`
//!    ([`model::SafetyLtl`]);
//! 3. **Search** for the minimal termination time with the explicit-state
//!    [`checker`] + bisection (paper Fig. 1) or [`swarm`] verification +
//!    the decreasing-T loop (Fig. 5) — both in [`tuner`];
//! 4. **Extract** the optimal (WG, TS) from the minimal-time
//!    counterexample trail ([`tuner::extract`]).
//!
//! The tuned kernel itself is a Pallas min-reduction, AOT-lowered by
//! `python/compile/aot.py` to HLO text and executed python-free through
//! the PJRT [`runtime`]; [`opencl`] is the Table-2 measurement harness and
//! [`report`] regenerates the paper's Tables 1–3.
//!
//! Above the single-shot method sits the [`coordinator`]: batch
//! tuning-job orchestration (`mcautotune batch`) that shards each job's
//! (WG, TS) lattice across a work-stealing queue and reuses results
//! through a content-addressed persistent cache — the layer that turns
//! the reproduction into a multi-tenant tuning service.
//!
//! ```no_run
//! use mcautotune::checker::CheckOptions;
//! use mcautotune::platform::MinModel;
//! use mcautotune::swarm::SwarmConfig;
//! use mcautotune::tuner::{tune, Method};
//!
//! let model = MinModel::paper(256, 64).unwrap();
//! let r = tune(&model, Method::Exhaustive, &CheckOptions::default(),
//!              &SwarmConfig::default(), None).unwrap();
//! println!("optimal WG={} TS={} time={}", r.optimal.wg, r.optimal.ts, r.t_min);
//! ```

pub mod checker;
pub mod coordinator;
pub mod model;
pub mod obs;
pub mod opencl;
pub mod platform;
pub mod promela;
pub mod report;
pub mod runtime;
pub mod swarm;
pub mod tuner;
pub mod util;
