//! Stub engine for builds without the `pjrt` feature (the external `xla`
//! crate is unavailable offline). Mirrors `exec.rs`'s API surface:
//! manifest loading and inspection work, kernel execution errors out with
//! a pointer at the feature flag. Keeps every caller — the CLI `exec` /
//! `table2` commands, `opencl::run_sweep`, examples and tests — compiling
//! unchanged; the artifact-gated tests skip at runtime exactly as they do
//! when `make artifacts` has not been run.

use crate::util::error::{bail, Result};
use crate::util::manifest::{ArtifactEntry, Manifest};
use std::path::{Path, PathBuf};

/// A compiled artifact plus its tuning metadata (stub: metadata only).
pub struct LoadedKernel {
    pub entry: ArtifactEntry,
}

/// Output of one Minimum-kernel execution.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MinOutput {
    /// per-workgroup partial minima (device side, Listing 10)
    pub partials: Vec<i32>,
    /// host-side REDUCE-global over the partials (Listing 11 lines 22-24)
    pub global_min: i32,
}

/// Stub PJRT engine: manifest only, no client.
pub struct Engine {
    manifest: Manifest,
}

const UNAVAILABLE: &str =
    "PJRT execution unavailable: built without the `pjrt` feature (requires the external `xla` crate)";

impl Engine {
    /// Create an engine over an artifacts directory (default: `artifacts/`
    /// next to the workspace root, or `$MCAT_ARTIFACTS`).
    pub fn new(artifacts_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        Ok(Self { manifest })
    }

    pub fn default_dir() -> PathBuf {
        std::env::var_os("MCAT_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    pub fn platform(&self) -> String {
        "stub (no pjrt feature)".to_string()
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (once) and return the named artifact. Stub: always errors.
    pub fn load(&mut self, name: &str) -> Result<&LoadedKernel> {
        bail!("cannot load artifact `{}`: {}", name, UNAVAILABLE)
    }

    /// Execute a `min_device` artifact. Stub: always errors.
    pub fn run_min(&mut self, name: &str, _data: &[i32]) -> Result<MinOutput> {
        bail!("cannot run artifact `{}`: {}", name, UNAVAILABLE)
    }

    /// Execute an `abstract` artifact. Stub: always errors.
    pub fn run_abstract(&mut self, name: &str, _data: &[f32]) -> Result<Vec<f32>> {
        bail!("cannot run artifact `{}`: {}", name, UNAVAILABLE)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_artifacts_dir_errors() {
        assert!(Engine::new(Path::new("/nonexistent/mcat/artifacts")).is_err());
    }

    #[test]
    fn stub_reads_manifest_but_cannot_execute() {
        let dir = std::env::temp_dir().join(format!("mcat_stub_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.tsv"),
            "name\tfile\tkind\tunits\twg\tts\tsize\tdtype\tvmem_bytes\n\
             m\tm.hlo.txt\tmin_device\t4\t4\t4\t64\ti32\t84\n",
        )
        .unwrap();
        let mut e = Engine::new(&dir).unwrap();
        assert!(e.manifest().find("m").is_some());
        assert!(e.platform().contains("stub"));
        let err = e.run_min("m", &[0; 64]).unwrap_err();
        assert!(format!("{:#}", err).contains("pjrt"));
        assert!(e.load("m").is_err());
        assert!(e.run_abstract("m", &[0.0; 64]).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
