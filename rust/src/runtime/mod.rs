//! PJRT runtime — loads the AOT-compiled Pallas/JAX artifacts
//! (`artifacts/*.hlo.txt`) and executes them from the Rust hot path.
//!
//! Python is build-time only: `make artifacts` lowers the L2 graphs once;
//! this module parses the HLO *text* (the interchange format that survives
//! the jax>=0.5 / xla_extension 0.5.1 proto-id mismatch), compiles each
//! module on the PJRT CPU client, and caches the loaded executables.
//!
//! The PJRT client comes from the external `xla` crate, which is not
//! vendored in the offline build. The real engine is therefore gated
//! behind the `pjrt` cargo feature (enabling it requires providing the
//! `xla` crate, e.g. as a path dependency); the default build compiles a
//! stub with the same API whose manifest inspection works but whose
//! kernel execution returns an actionable error.

#[cfg(feature = "pjrt")]
pub mod exec;

#[cfg(not(feature = "pjrt"))]
#[path = "exec_stub.rs"]
pub mod exec;

pub use exec::{Engine, LoadedKernel, MinOutput};
