//! PJRT runtime — loads the AOT-compiled Pallas/JAX artifacts
//! (`artifacts/*.hlo.txt`) and executes them from the Rust hot path.
//!
//! Python is build-time only: `make artifacts` lowers the L2 graphs once;
//! this module parses the HLO *text* (the interchange format that survives
//! the jax>=0.5 / xla_extension 0.5.1 proto-id mismatch), compiles each
//! module on the PJRT CPU client, and caches the loaded executables.

pub mod exec;

pub use exec::{Engine, LoadedKernel, MinOutput};
