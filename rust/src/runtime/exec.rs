//! Executable loading and typed execution of the Minimum-problem kernels.

use crate::util::manifest::{ArtifactEntry, Manifest};
use crate::util::error::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A compiled artifact plus its tuning metadata.
pub struct LoadedKernel {
    pub entry: ArtifactEntry,
    exe: xla::PjRtLoadedExecutable,
}

/// Output of one Minimum-kernel execution.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MinOutput {
    /// per-workgroup partial minima (device side, Listing 10)
    pub partials: Vec<i32>,
    /// host-side REDUCE-global over the partials (Listing 11 lines 22-24)
    pub global_min: i32,
}

/// PJRT engine: one CPU client, lazily compiled executables by name.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, LoadedKernel>,
}

impl Engine {
    /// Create an engine over an artifacts directory (default: `artifacts/`
    /// next to the workspace root, or `$MCAT_ARTIFACTS`).
    pub fn new(artifacts_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().map_err(to_anyhow)?;
        Ok(Self { client, manifest, cache: HashMap::new() })
    }

    pub fn default_dir() -> PathBuf {
        std::env::var_os("MCAT_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (once) and return the named artifact.
    pub fn load(&mut self, name: &str) -> Result<&LoadedKernel> {
        if !self.cache.contains_key(name) {
            let entry = self
                .manifest
                .find(name)
                .with_context(|| format!("artifact `{}` not in manifest", name))?
                .clone();
            let path = entry.path(&self.manifest.dir);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 artifact path")?,
            )
            .map_err(to_anyhow)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).map_err(to_anyhow)?;
            self.cache.insert(name.to_string(), LoadedKernel { entry, exe });
        }
        Ok(&self.cache[name])
    }

    /// Execute a `min_device` artifact on `data` (flat i32 array of the
    /// artifact's size) and perform the host-side global reduction.
    pub fn run_min(&mut self, name: &str, data: &[i32]) -> Result<MinOutput> {
        let kernel = self.load(name)?;
        let entry = kernel.entry.clone();
        if entry.kind != "min_device" && entry.kind != "min_fused" {
            bail!("artifact `{}` has kind {}, not a minimum kernel", name, entry.kind);
        }
        if data.len() as u64 != entry.size {
            bail!(
                "artifact `{}` expects {} elements, got {}",
                name,
                entry.size,
                data.len()
            );
        }
        let input = xla::Literal::vec1(data);
        let result = kernel.exe.execute::<xla::Literal>(&[input]).map_err(to_anyhow)?;
        let out = result[0][0].to_literal_sync().map_err(to_anyhow)?;
        match entry.kind.as_str() {
            "min_device" => {
                let partials_lit = out.to_tuple1().map_err(to_anyhow)?;
                let partials: Vec<i32> = partials_lit.to_vec().map_err(to_anyhow)?;
                crate::ensure!(
                    partials.len() == entry.units as usize,
                    "expected {} partials, got {}",
                    entry.units,
                    partials.len()
                );
                let global_min = partials.iter().copied().min().context("empty partials")?;
                Ok(MinOutput { partials, global_min })
            }
            _ => {
                // min_fused: (partials, global_min) — used for self-check
                let (p, g) = out.to_tuple2().map_err(to_anyhow)?;
                let partials: Vec<i32> = p.to_vec().map_err(to_anyhow)?;
                let gv: Vec<i32> = g.to_vec().map_err(to_anyhow)?;
                let global_min = *gv.first().context("empty fused output")?;
                Ok(MinOutput { partials, global_min })
            }
        }
    }

    /// Execute an `abstract` artifact on f32 data; returns the per-item
    /// result vector.
    pub fn run_abstract(&mut self, name: &str, data: &[f32]) -> Result<Vec<f32>> {
        let kernel = self.load(name)?;
        let entry = kernel.entry.clone();
        if entry.kind != "abstract" {
            bail!("artifact `{}` has kind {}, not abstract", name, entry.kind);
        }
        if data.len() as u64 != entry.size {
            bail!("artifact `{}` expects {} elements, got {}", name, entry.size, data.len());
        }
        let input = xla::Literal::vec1(data);
        let result = kernel.exe.execute::<xla::Literal>(&[input]).map_err(to_anyhow)?;
        let out = result[0][0].to_literal_sync().map_err(to_anyhow)?;
        let v = out.to_tuple1().map_err(to_anyhow)?;
        v.to_vec().map_err(to_anyhow)
    }
}

fn to_anyhow(e: xla::Error) -> crate::util::error::Error {
    anyhow!("{e}")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_available() -> Option<PathBuf> {
        let dir = Engine::default_dir();
        dir.join("manifest.tsv").exists().then_some(dir)
    }

    /// Reference min on the host.
    fn ref_min(data: &[i32]) -> i32 {
        data.iter().copied().min().unwrap()
    }

    #[test]
    fn run_min_small_matches_host_reference() {
        let Some(dir) = artifacts_available() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let mut eng = Engine::new(&dir).unwrap();
        let n = eng.manifest().find("min_device_small").unwrap().size as usize;
        let data: Vec<i32> = (0..n as i32).map(|i| 1000 - 13 * i).collect();
        let out = eng.run_min("min_device_small", &data).unwrap();
        assert_eq!(out.global_min, ref_min(&data));
        assert_eq!(out.partials.len(), 4);
    }

    #[test]
    fn fused_and_device_agree() {
        let Some(dir) = artifacts_available() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let mut eng = Engine::new(&dir).unwrap();
        let n = eng.manifest().find("min_device_small").unwrap().size as usize;
        let data: Vec<i32> = (0..n as i32).map(|i| (i * 7919) % 101 - 50).collect();
        let a = eng.run_min("min_device_small", &data).unwrap();
        let b = eng.run_min("min_fused_small", &data).unwrap();
        assert_eq!(a.global_min, b.global_min);
        assert_eq!(a.partials, b.partials);
    }

    #[test]
    fn wrong_size_rejected() {
        let Some(dir) = artifacts_available() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let mut eng = Engine::new(&dir).unwrap();
        assert!(eng.run_min("min_device_small", &[1, 2, 3]).is_err());
    }

    #[test]
    fn unknown_artifact_rejected() {
        let Some(dir) = artifacts_available() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let mut eng = Engine::new(&dir).unwrap();
        assert!(eng.run_min("nope", &[0i32; 4]).is_err());
    }
}
