//! Deterministic failure injection for chaos testing.
//!
//! Named sites are compiled into the hot seams of the coordinator
//! (lease rename, result publish, header write, cache save, shard
//! execution) and stay inert — one relaxed atomic load and a branch,
//! the same contract as [`crate::obs::enabled`] — until activated.
//!
//! Activation is usually via the environment:
//!
//! ```text
//! MCAT_FAILPOINTS=site=action[:count][,site=action[:count]...]
//! ```
//!
//! Actions:
//!
//! - `panic`    — panic at the site (workers convert this into a
//!   structured task failure via `catch_unwind`);
//! - `io-error` — the site returns an injected I/O error;
//! - `delay`    — sleep 100ms at the site, then continue;
//! - `exit`     — terminate the process immediately with exit code 86
//!   (simulates a hard crash, e.g. crash-after-lease).
//!
//! An optional `:count` arms the site for exactly that many firings;
//! without it the site fires every time. Counts are decremented
//! process-globally under a lock, so `site=panic:1` injects exactly one
//! panic no matter how many threads race through the site.
//!
//! Programmatic activation ([`activate`]/[`deactivate`]) exists for
//! in-process demos and tests; an invalid `MCAT_FAILPOINTS` spec
//! terminates the process with exit code 2 and a message on stderr
//! (silently ignoring a typo'd chaos schedule would be worse).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::util::error::{anyhow, Error, Result};

/// Environment variable holding the failpoint spec.
pub const ENV: &str = "MCAT_FAILPOINTS";

const UNINIT: u8 = 0;
const OFF: u8 = 1;
const ON: u8 = 2;

static STATE: AtomicU8 = AtomicU8::new(UNINIT);
static SITES: Mutex<Option<HashMap<String, Site>>> = Mutex::new(None);

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Action {
    Panic,
    IoError,
    Delay,
    Exit,
}

#[derive(Clone, Debug)]
struct Site {
    action: Action,
    /// `None` = fire every time; `Some(n)` = fire `n` more times.
    remaining: Option<u32>,
}

/// One relaxed load + branch when failpoints are off (the common case).
/// The first call per process inspects `MCAT_FAILPOINTS` and latches the
/// result, so every later call is a single atomic load.
#[inline(always)]
pub fn armed() -> bool {
    match STATE.load(Ordering::Relaxed) {
        OFF => false,
        ON => true,
        _ => init_from_env(),
    }
}

#[cold]
fn init_from_env() -> bool {
    let spec = std::env::var(ENV).unwrap_or_default();
    if spec.trim().is_empty() {
        STATE.store(OFF, Ordering::Relaxed);
        return false;
    }
    match parse(&spec) {
        Ok(sites) => {
            *SITES.lock().unwrap() = Some(sites);
            STATE.store(ON, Ordering::Relaxed);
            true
        }
        Err(e) => {
            eprintln!("mcautotune: invalid {ENV} spec `{spec}`: {e:#}");
            std::process::exit(2);
        }
    }
}

/// Evaluate the failpoint `site`. Inert unless [`armed`] — one branch.
///
/// Returns the injected error for `io-error`, panics for `panic`, exits
/// the process for `exit`, sleeps briefly for `delay`, and is a no-op
/// for sites that are not configured or whose count is exhausted.
#[inline]
pub fn hit(site: &str) -> Result<()> {
    if !armed() {
        return Ok(());
    }
    fire(site)
}

#[cold]
fn fire(site: &str) -> Result<()> {
    let action = {
        let mut guard = SITES.lock().unwrap();
        let sites = match guard.as_mut() {
            Some(s) => s,
            None => return Ok(()),
        };
        match sites.get_mut(site) {
            Some(s) => match &mut s.remaining {
                Some(0) => None,
                Some(n) => {
                    *n -= 1;
                    Some(s.action)
                }
                None => Some(s.action),
            },
            None => None,
        }
    };
    match action {
        None => Ok(()),
        Some(Action::Panic) => panic!("failpoint `{site}`: injected panic"),
        Some(Action::IoError) => Err(anyhow!("failpoint `{site}`: injected I/O error")),
        Some(Action::Delay) => {
            std::thread::sleep(Duration::from_millis(100));
            Ok(())
        }
        Some(Action::Exit) => {
            eprintln!("mcautotune: failpoint `{site}`: injected process exit");
            std::process::exit(86);
        }
    }
}

/// Programmatically arm the given spec (same grammar as the env var),
/// replacing any previous configuration. Meant for demos and in-process
/// tests; production activation goes through [`ENV`].
pub fn activate(spec: &str) -> Result<()> {
    let sites = parse(spec)?;
    *SITES.lock().unwrap() = Some(sites);
    STATE.store(ON, Ordering::Relaxed);
    Ok(())
}

/// Disarm every failpoint; sites go back to the one-branch inert path.
pub fn deactivate() {
    *SITES.lock().unwrap() = None;
    STATE.store(OFF, Ordering::Relaxed);
}

fn parse(spec: &str) -> std::result::Result<HashMap<String, Site>, Error> {
    let mut sites = HashMap::new();
    for part in spec.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (site, rhs) = part
            .split_once('=')
            .ok_or_else(|| anyhow!("`{part}`: expected site=action[:count]"))?;
        let (action, count) = match rhs.split_once(':') {
            Some((a, c)) => {
                let n: u32 = c
                    .parse()
                    .map_err(|_| anyhow!("`{part}`: count `{c}` is not a number"))?;
                (a, Some(n))
            }
            None => (rhs, None),
        };
        let action = match action {
            "panic" => Action::Panic,
            "io-error" => Action::IoError,
            "delay" => Action::Delay,
            "exit" => Action::Exit,
            other => {
                return Err(anyhow!(
                    "`{part}`: unknown action `{other}` (expected panic|io-error|delay|exit)"
                ))
            }
        };
        if site.is_empty() {
            return Err(anyhow!("`{part}`: empty site name"));
        }
        sites.insert(site.to_string(), Site { action, remaining: count });
    }
    if sites.is_empty() {
        return Err(anyhow!("no failpoints in spec"));
    }
    Ok(sites)
}

#[cfg(test)]
mod tests {
    use super::*;

    // Failpoint state is process-global; serialize the tests that touch it.
    fn test_lock() -> std::sync::MutexGuard<'static, ()> {
        static LOCK: Mutex<()> = Mutex::new(());
        LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn unconfigured_sites_are_inert() {
        let _g = test_lock();
        activate("some.other.site=panic").unwrap();
        assert!(hit("fp_test.unconfigured").is_ok());
        deactivate();
        assert!(hit("fp_test.unconfigured").is_ok());
    }

    #[test]
    fn io_error_fires_exactly_count_times() {
        let _g = test_lock();
        activate("fp_test.count=io-error:2").unwrap();
        assert!(hit("fp_test.count").is_err());
        assert!(hit("fp_test.count").is_err());
        assert!(hit("fp_test.count").is_ok(), "count must exhaust");
        assert!(hit("fp_test.count").is_ok());
        deactivate();
    }

    #[test]
    fn uncounted_site_fires_every_time() {
        let _g = test_lock();
        activate("fp_test.always=io-error").unwrap();
        for _ in 0..4 {
            let e = hit("fp_test.always").expect_err("must keep firing");
            assert!(format!("{e:#}").contains("injected I/O error"));
        }
        deactivate();
    }

    #[test]
    fn panic_action_panics_and_is_catchable() {
        let _g = test_lock();
        activate("fp_test.panic=panic:1").unwrap();
        let r = std::panic::catch_unwind(|| hit("fp_test.panic"));
        assert!(r.is_err(), "panic action must unwind");
        assert!(hit("fp_test.panic").is_ok(), "count 1 is spent");
        deactivate();
    }

    #[test]
    fn delay_action_sleeps_then_succeeds() {
        let _g = test_lock();
        activate("fp_test.delay=delay:1").unwrap();
        let t0 = std::time::Instant::now();
        assert!(hit("fp_test.delay").is_ok());
        assert!(t0.elapsed() >= Duration::from_millis(90));
        deactivate();
    }

    #[test]
    fn bad_specs_are_rejected() {
        for bad in ["nosite", "a=unknown", "a=panic:xyz", "=panic", "", " , "] {
            assert!(parse(bad).is_err(), "spec `{bad}` must be rejected");
        }
        assert!(parse("a=panic,b=io-error:3,c=delay,d=exit").is_ok());
    }
}
