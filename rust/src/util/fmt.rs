//! Human formatting helpers for reports (memory sizes, durations, tables).

use std::time::Duration;

pub fn human_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KB", "MB", "GB", "TB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{}B", b)
    } else {
        format!("{:.3}{}", v, UNITS[u])
    }
}

pub fn human_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s < 1e-3 {
        format!("{:.0}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.1}ms", s * 1e3)
    } else if s < 60.0 {
        format!("{:.2}s", s)
    } else if s < 3600.0 {
        format!("{}m{:02.0}s", (s / 60.0) as u64, s % 60.0)
    } else {
        format!("{}h{:02.0}m", (s / 3600.0) as u64, (s % 3600.0) / 60.0)
    }
}

pub fn thousands(n: u64) -> String {
    let s = n.to_string();
    let mut out = String::with_capacity(s.len() + s.len() / 3);
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes() {
        assert_eq!(human_bytes(512), "512B");
        assert_eq!(human_bytes(2048), "2.000KB");
        assert_eq!(human_bytes(14_767_000_000 / 1000 * 1000), human_bytes(14_767_000_000));
        assert!(human_bytes(15_852_470_272).starts_with("14.7"));
    }

    #[test]
    fn durations() {
        assert_eq!(human_duration(Duration::from_micros(50)), "50us");
        assert_eq!(human_duration(Duration::from_millis(250)), "250.0ms");
        assert_eq!(human_duration(Duration::from_secs(25)), "25.00s");
        assert_eq!(human_duration(Duration::from_secs(90)), "1m30s");
        assert_eq!(human_duration(Duration::from_secs(7200)), "2h00m");
    }

    #[test]
    fn thousands_sep() {
        assert_eq!(thousands(0), "0");
        assert_eq!(thousands(999), "999");
        assert_eq!(thousands(1000), "1,000");
        assert_eq!(thousands(15_973_533), "15,973,533");
    }
}
