//! Fast non-cryptographic hashing for state vectors.
//!
//! The checker hashes millions of encoded states; std's SipHash is too slow
//! and the `ahash`/`fxhash` crates are not available offline, so we ship an
//! FxHash-style 64-bit mixer plus a `BuildHasher` to plug into std maps.

use std::hash::{BuildHasherDefault, Hasher};

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// FxHash-style word-at-a-time hasher (rustc's FxHasher, 64-bit).
#[derive(Default, Clone)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for c in &mut chunks {
            self.add(u64::from_le_bytes(c.try_into().unwrap()));
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut buf = [0u8; 8];
            buf[..rem.len()].copy_from_slice(rem);
            self.add(u64::from_le_bytes(buf) | ((rem.len() as u64) << 56));
        }
    }

    #[inline]
    fn write_u64(&mut self, v: u64) {
        self.add(v);
    }

    #[inline]
    fn write_u32(&mut self, v: u32) {
        self.add(v as u64);
    }

    #[inline]
    fn write_u8(&mut self, v: u8) {
        self.add(v as u64);
    }

    #[inline]
    fn write_usize(&mut self, v: usize) {
        self.add(v as u64);
    }
}

pub type FxBuildHasher = BuildHasherDefault<FxHasher>;
pub type FxHashMap<K, V> = std::collections::HashMap<K, V, FxBuildHasher>;
pub type FxHashSet<K> = std::collections::HashSet<K, FxBuildHasher>;

/// One-shot hash of a byte slice with an explicit seed (used by the bitstate
/// store to derive the k Bloom probes and by swarm workers to diversify).
#[inline]
pub fn hash_bytes_seeded(bytes: &[u8], seed: u64) -> u64 {
    let mut h = FxHasher { hash: seed };
    h.write(bytes);
    // final avalanche (splitmix finalizer) — Fx alone is weak in low bits
    let mut z = h.finish();
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

#[inline]
pub fn hash_bytes(bytes: &[u8]) -> u64 {
    hash_bytes_seeded(bytes, 0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        assert_eq!(hash_bytes(b"abc"), hash_bytes(b"abc"));
        assert_ne!(hash_bytes(b"abc"), hash_bytes(b"abd"));
    }

    #[test]
    fn seed_changes_hash() {
        assert_ne!(hash_bytes_seeded(b"abc", 1), hash_bytes_seeded(b"abc", 2));
    }

    #[test]
    fn length_extension_distinct() {
        // trailing zero bytes must not collide with shorter input
        assert_ne!(hash_bytes(b"a"), hash_bytes(b"a\0"));
        assert_ne!(hash_bytes(b""), hash_bytes(b"\0"));
    }

    #[test]
    fn low_bits_spread() {
        // bitstate store indexes by low bits: check they vary
        let mut seen = FxHashSet::default();
        for i in 0u64..4096 {
            seen.insert(hash_bytes(&i.to_le_bytes()) & 0xFFF);
        }
        assert!(seen.len() > 2500, "low-bit spread too poor: {}", seen.len());
    }
}
