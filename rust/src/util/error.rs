//! Minimal `anyhow`-compatible error handling.
//!
//! The build environment is offline (no crates.io), so the crate vendors
//! the small slice of `anyhow`'s API the codebase uses: an opaque
//! [`Error`] carrying a chain of context messages, a [`Result`] alias
//! with a defaulted error type, the [`Context`] extension trait for
//! `Result` and `Option`, and the [`anyhow!`](crate::anyhow) /
//! [`bail!`](crate::bail) / [`ensure!`](crate::ensure) macros.
//!
//! Formatting mirrors `anyhow`: `{}` prints the outermost message, `{:#}`
//! the full `outer: inner: root` chain, and `{:?}` a multi-line report
//! with a `Caused by:` section.

use std::fmt;

/// An error message plus an optional chain of underlying causes.
///
/// Unlike `std` error types this deliberately does **not** implement
/// `std::error::Error`: that keeps the blanket `From<E: std::error::Error>`
/// conversion below coherent (the same trick `anyhow` uses), so `?`
/// converts any std error into an [`Error`] automatically.
pub struct Error {
    msg: String,
    cause: Option<Box<Error>>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error { msg: m.to_string(), cause: None }
    }

    /// Wrap this error in an outer context message.
    pub fn context<C: fmt::Display>(self, ctx: C) -> Self {
        Error { msg: ctx.to_string(), cause: Some(Box::new(self)) }
    }

    /// The messages of the chain, outermost first.
    pub fn chain(&self) -> Vec<&str> {
        let mut out = vec![self.msg.as_str()];
        let mut cause = self.cause.as_deref();
        while let Some(e) = cause {
            out.push(e.msg.as_str());
            cause = e.cause.as_deref();
        }
        out
    }

    /// The innermost (root) message of the chain.
    pub fn root_cause(&self) -> &str {
        *self.chain().last().expect("chain is never empty")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if f.alternate() {
            let mut cause = self.cause.as_deref();
            while let Some(e) = cause {
                write!(f, ": {}", e.msg)?;
                cause = e.cause.as_deref();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        let mut cause = self.cause.as_deref();
        if cause.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = cause {
            write!(f, "\n    {}", e.msg)?;
            cause = e.cause.as_deref();
        }
        Ok(())
    }
}

/// Any std error converts via `?`, preserving its `source()` chain as
/// context layers.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut msgs: Vec<String> = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut err = Error { msg: msgs.pop().expect("at least one message"), cause: None };
        while let Some(m) = msgs.pop() {
            err = Error { msg: m, cause: Some(Box::new(err)) };
        }
        err
    }
}

/// `Result` with the error type defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context-attachment extension for `Result` and `Option` (the `anyhow`
/// surface the codebase relies on).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into().context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

// Make the crate-root macros importable alongside the types:
// `use crate::util::error::{anyhow, bail, Context, Result};`
pub use crate::{anyhow, bail, ensure};

#[cfg(test)]
mod tests {
    use super::*;

    fn fails(flag: bool) -> Result<u32> {
        ensure!(flag, "flag was {}", flag);
        Ok(7)
    }

    #[test]
    fn macros_and_result() {
        assert_eq!(fails(true).unwrap(), 7);
        let e = fails(false).unwrap_err();
        assert_eq!(e.to_string(), "flag was false");
    }

    #[test]
    fn context_chains_format() {
        let e = fails(false).context("checking the flag").unwrap_err();
        assert_eq!(format!("{}", e), "checking the flag");
        assert_eq!(format!("{:#}", e), "checking the flag: flag was false");
        let dbg = format!("{:?}", e);
        assert!(dbg.contains("Caused by:"));
        assert!(dbg.contains("flag was false"));
        assert_eq!(e.chain(), vec!["checking the flag", "flag was false"]);
        assert_eq!(e.root_cause(), "flag was false");
    }

    #[test]
    fn option_context() {
        let none: Option<u32> = None;
        let e = none.with_context(|| format!("missing {}", "value")).unwrap_err();
        assert_eq!(e.to_string(), "missing value");
        assert_eq!(Some(3).context("unused").unwrap(), 3);
    }

    #[test]
    fn std_errors_convert_with_source_chain() {
        fn io() -> Result<String> {
            Ok(std::fs::read_to_string("/nonexistent/mcat/error/test")?)
        }
        let e = io().unwrap_err();
        assert!(!e.to_string().is_empty());
        let n: std::result::Result<i32, _> = "xyz".parse::<i32>();
        let e: Error = n.unwrap_err().into();
        assert!(e.to_string().contains("invalid digit"));
    }

    #[test]
    fn bail_returns_early() {
        fn f() -> Result<()> {
            bail!("stop at {}", 42);
        }
        assert_eq!(f().unwrap_err().to_string(), "stop at 42");
    }
}
