//! Tiny argv parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positionals, with
//! typed getters and an auto-generated usage string. Unknown options are an
//! error — the CLI surface stays honest.

use std::collections::BTreeMap;

#[derive(Debug)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pos: Vec<String>,
    known_opts: Vec<(String, String)>,
    known_flags: Vec<(String, String)>,
}

#[derive(Debug)]
pub struct CliError(pub String);

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CliError {}

pub struct Spec {
    opts: Vec<(String, String)>,  // (name, help)
    flags: Vec<(String, String)>, // (name, help)
}

impl Default for Spec {
    fn default() -> Self {
        Self::new()
    }
}

impl Spec {
    pub fn new() -> Self {
        Self { opts: Vec::new(), flags: Vec::new() }
    }

    pub fn opt(mut self, name: &str, help: &str) -> Self {
        self.opts.push((name.to_string(), help.to_string()));
        self
    }

    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.flags.push((name.to_string(), help.to_string()));
        self
    }

    pub fn usage(&self, cmd: &str) -> String {
        let mut s = format!("usage: {}", cmd);
        for (n, h) in &self.opts {
            s.push_str(&format!("\n  --{} <v>   {}", n, h));
        }
        for (n, h) in &self.flags {
            s.push_str(&format!("\n  --{}       {}", n, h));
        }
        s
    }

    pub fn parse(&self, argv: &[String]) -> Result<Args, CliError> {
        let mut a = Args {
            opts: BTreeMap::new(),
            flags: Vec::new(),
            pos: Vec::new(),
            known_opts: self.opts.clone(),
            known_flags: self.flags.clone(),
        };
        let mut i = 0;
        while i < argv.len() {
            let arg = &argv[i];
            if let Some(stripped) = arg.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                if self.flags.iter().any(|(n, _)| *n == key) {
                    if inline_val.is_some() {
                        return Err(CliError(format!("flag --{} takes no value", key)));
                    }
                    a.flags.push(key);
                } else if self.opts.iter().any(|(n, _)| *n == key) {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| CliError(format!("--{} needs a value", key)))?
                        }
                    };
                    a.opts.insert(key, val);
                } else {
                    return Err(CliError(format!("unknown option --{}", key)));
                }
            } else {
                a.pos.push(arg.clone());
            }
            i += 1;
        }
        Ok(a)
    }
}

impl Args {
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        debug_assert!(
            self.known_opts.iter().any(|(n, _)| n == name),
            "get() of undeclared option --{name}"
        );
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_parsed<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>, CliError> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|_| CliError(format!("--{}: cannot parse {:?}", name, v))),
        }
    }

    pub fn get_parsed_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, CliError> {
        Ok(self.get_parsed(name)?.unwrap_or(default))
    }

    pub fn positionals(&self) -> &[String] {
        &self.pos
    }

    /// Accessor used by help printing.
    pub fn known(&self) -> (&[(String, String)], &[(String, String)]) {
        (&self.known_opts, &self.known_flags)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parse_opts_flags_positionals() {
        let spec = Spec::new().opt("size", "input size").flag("verbose", "chatty");
        let a = spec
            .parse(&sv(&["--size", "64", "--verbose", "model.pml"]))
            .unwrap();
        assert_eq!(a.get("size"), Some("64"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positionals(), &["model.pml".to_string()]);
    }

    #[test]
    fn parse_equals_form() {
        let spec = Spec::new().opt("size", "");
        let a = spec.parse(&sv(&["--size=128"])).unwrap();
        assert_eq!(a.get_parsed::<u32>("size").unwrap(), Some(128));
    }

    #[test]
    fn unknown_option_rejected() {
        let spec = Spec::new().opt("size", "");
        assert!(spec.parse(&sv(&["--bogus"])).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        let spec = Spec::new().opt("size", "");
        assert!(spec.parse(&sv(&["--size"])).is_err());
    }

    #[test]
    fn typed_default() {
        let spec = Spec::new().opt("gmt", "");
        let a = spec.parse(&sv(&[])).unwrap();
        assert_eq!(a.get_parsed_or("gmt", 10u64).unwrap(), 10);
        let a = spec.parse(&sv(&["--gmt", "3"])).unwrap();
        assert_eq!(a.get_parsed_or("gmt", 10u64).unwrap(), 3);
        let a = spec.parse(&sv(&["--gmt", "x"])).unwrap();
        assert!(a.get_parsed_or("gmt", 10u64).is_err());
    }
}
