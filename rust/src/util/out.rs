//! BrokenPipe-safe stdout for the CLI.
//!
//! Rust's `println!` panics when stdout is closed, so
//! `mcautotune batch ... | head` would die with a `failed printing to
//! stdout` panic once `head` exits. Every CLI output path goes through
//! [`emit`] (via the [`outln!`](crate::outln) / [`outp!`](crate::outp)
//! macros) instead: a write failure means the downstream reader is gone,
//! which for a pipeline is normal termination — exit 0, like the
//! default `SIGPIPE` disposition would.

use std::io::Write;

/// Write to stdout; exit the process cleanly if the pipe is closed.
pub fn emit(args: std::fmt::Arguments<'_>) {
    let mut out = std::io::stdout().lock();
    if out.write_fmt(args).is_err() || out.flush().is_err() {
        std::process::exit(0);
    }
}

/// `println!` that exits cleanly on a closed stdout.
#[macro_export]
macro_rules! outln {
    () => {
        $crate::util::out::emit(format_args!("\n"))
    };
    ($($arg:tt)*) => {{
        $crate::util::out::emit(format_args!($($arg)*));
        $crate::util::out::emit(format_args!("\n"));
    }};
}

/// `print!` that exits cleanly on a closed stdout.
#[macro_export]
macro_rules! outp {
    ($($arg:tt)*) => {
        $crate::util::out::emit(format_args!($($arg)*))
    };
}
