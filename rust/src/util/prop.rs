//! Minimal property-based-testing driver (proptest is unavailable offline).
//!
//! `forall` draws `cases` random inputs from a generator closure and asserts
//! the property; on failure it performs a simple halving shrink over the
//! generator's seed-space is not possible, so instead the *input itself* is
//! shrunk via the user-provided `shrink` steps when given. Failures print
//! the reproducing seed so a regression test can pin it.

use super::rng::Xoshiro256;

pub struct Config {
    pub cases: u32,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        // MCAT_PROP_CASES / MCAT_PROP_SEED env overrides for CI sweeps
        let cases = std::env::var("MCAT_PROP_CASES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(64);
        let seed = std::env::var("MCAT_PROP_SEED")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(0xC0FFEE);
        Self { cases, seed }
    }
}

/// Run `prop` on `cases` inputs drawn by `gen`. Panics (with the seed and
/// case index) on the first falsifying input.
pub fn forall<T: std::fmt::Debug>(
    name: &str,
    cfg: Config,
    mut gen: impl FnMut(&mut Xoshiro256) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let mut rng = Xoshiro256::new(cfg.seed);
    for case in 0..cfg.cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property `{}` falsified at case {}/{} (seed {:#x}):\n  input: {:?}\n  {}",
                name, case, cfg.cases, cfg.seed, input, msg
            );
        }
    }
}

/// Convenience assertion helpers for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !($cond) {
            return Err(format!($($fmt)+));
        }
    };
    ($cond:expr) => {
        if !($cond) {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!(
                "{} != {} ({:?} vs {:?})",
                stringify!($a),
                stringify!($b),
                a,
                b
            ));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall(
            "sum-commutes",
            Config { cases: 32, seed: 1 },
            |r| (r.range_i64(-100, 100), r.range_i64(-100, 100)),
            |&(a, b)| {
                prop_assert_eq!(a + b, b + a);
                Ok(())
            },
        );
    }

    #[test]
    #[should_panic(expected = "falsified")]
    fn forall_reports_failure() {
        forall(
            "always-positive",
            Config { cases: 64, seed: 2 },
            |r| r.range_i64(-5, 5),
            |&x| {
                prop_assert!(x >= -100 && x < 5, "x was {}", x);
                Ok(())
            },
        );
    }
}
