//! Deterministic PRNGs (no external `rand`): SplitMix64 for seeding and
//! xoshiro256** for streams. Swarm workers and property tests rely on
//! reproducible seeds, so the generators are fully specified here.

/// SplitMix64 — used to expand a single u64 seed into stream seeds.
#[derive(Clone, Debug)]
pub struct SplitMix64(pub u64);

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self(seed)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** 1.0 — the main stream generator.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)` (Lemire-style rejection-free enough for our use).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // 128-bit multiply trick; bias < 2^-64, irrelevant here.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi_inclusive: i64) -> i64 {
        debug_assert!(lo <= hi_inclusive);
        lo + self.below((hi_inclusive - lo + 1) as u64) as i64
    }

    #[inline]
    pub fn chance(&mut self, p_num: u64, p_den: u64) -> bool {
        self.below(p_den) < p_num
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        let n = xs.len();
        for i in (1..n).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // Reference sequence for seed 0 (from the published algorithm).
        let mut sm = SplitMix64::new(0);
        assert_eq!(sm.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(sm.next_u64(), 0x6E78_9E6A_A1B9_65F4);
    }

    #[test]
    fn xoshiro_deterministic_and_seed_sensitive() {
        let a: Vec<u64> = {
            let mut r = Xoshiro256::new(42);
            (0..8).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = Xoshiro256::new(42);
            (0..8).map(|_| r.next_u64()).collect()
        };
        let c: Vec<u64> = {
            let mut r = Xoshiro256::new(43);
            (0..8).map(|_| r.next_u64()).collect()
        };
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn below_in_range() {
        let mut r = Xoshiro256::new(7);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
        }
        // all residues reachable
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10) as usize] = true;
        }
        assert!(seen.iter().all(|&x| x));
    }

    #[test]
    fn range_inclusive_endpoints() {
        let mut r = Xoshiro256::new(9);
        let (mut lo_seen, mut hi_seen) = (false, false);
        for _ in 0..2000 {
            let v = r.range_i64(-3, 3);
            assert!((-3..=3).contains(&v));
            lo_seen |= v == -3;
            hi_seen |= v == 3;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::new(1);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
