//! Support utilities: deterministic RNG, fast hashing, CLI/bench/property
//! harnesses (the heavyweight ecosystem crates are unavailable offline),
//! human formatting, and the artifact manifest reader.

pub mod bench;
pub mod cli;
pub mod fmt;
pub mod hash;
pub mod manifest;
pub mod prop;
pub mod rng;
