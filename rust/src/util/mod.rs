//! Support utilities: error handling, deterministic RNG, fast hashing,
//! CLI/bench/property harnesses (the heavyweight ecosystem crates are
//! unavailable offline), human formatting, and the artifact manifest +
//! JSON reader/writer.

pub mod bench;
pub mod cli;
pub mod error;
pub mod failpoint;
pub mod fmt;
pub mod hash;
pub mod manifest;
pub mod out;
pub mod prop;
pub mod rng;
pub mod signal;
