//! Artifact-manifest reader.
//!
//! aot.py writes both `manifest.json` (human) and `manifest.tsv` (machine).
//! We parse the TSV here — a full JSON parser is unnecessary for a flat
//! record table and the TSV is regenerated in the same `make artifacts`.

use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: String,
    pub kind: String,
    pub units: u32,
    pub wg: u32,
    pub ts: u32,
    pub size: u64,
    pub dtype: String,
    pub vmem_bytes: u64,
}

impl ArtifactEntry {
    pub fn path(&self, dir: &Path) -> PathBuf {
        dir.join(&self.file)
    }
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<ArtifactEntry>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let tsv = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&tsv)
            .with_context(|| format!("reading {} (run `make artifacts`)", tsv.display()))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Self> {
        let mut lines = text.lines().filter(|l| !l.trim().is_empty());
        let header = lines.next().context("empty manifest")?;
        let cols: Vec<&str> = header.split('\t').collect();
        let idx = |name: &str| -> Result<usize> {
            cols.iter()
                .position(|c| *c == name)
                .with_context(|| format!("manifest missing column {name}"))
        };
        let (c_name, c_file, c_kind) = (idx("name")?, idx("file")?, idx("kind")?);
        let (c_units, c_wg, c_ts) = (idx("units")?, idx("wg")?, idx("ts")?);
        let (c_size, c_dtype, c_vmem) = (idx("size")?, idx("dtype")?, idx("vmem_bytes")?);
        let mut entries = Vec::new();
        for (lineno, line) in lines.enumerate() {
            let f: Vec<&str> = line.split('\t').collect();
            if f.len() != cols.len() {
                bail!("manifest line {}: {} fields, expected {}", lineno + 2, f.len(), cols.len());
            }
            let p = |i: usize| -> Result<u64> {
                f[i].parse::<u64>()
                    .with_context(|| format!("manifest line {}: bad number {:?}", lineno + 2, f[i]))
            };
            entries.push(ArtifactEntry {
                name: f[c_name].to_string(),
                file: f[c_file].to_string(),
                kind: f[c_kind].to_string(),
                units: p(c_units)? as u32,
                wg: p(c_wg)? as u32,
                ts: p(c_ts)? as u32,
                size: p(c_size)?,
                dtype: f[c_dtype].to_string(),
                vmem_bytes: p(c_vmem)?,
            });
        }
        Ok(Self { dir: dir.to_path_buf(), entries })
    }

    pub fn find(&self, name: &str) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    pub fn of_kind<'a>(&'a self, kind: &'a str) -> impl Iterator<Item = &'a ArtifactEntry> {
        self.entries.iter().filter(move |e| e.kind == kind)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "name\tfile\tkind\tunits\twg\tts\tsize\tdtype\tvmem_bytes\n\
        min_small\tmin_small.hlo.txt\tmin_device\t4\t4\t4\t64\ti32\t84\n\
        min_u64_wg64_ts1024\tmin_u64_wg64_ts1024.hlo.txt\tmin_device\t64\t64\t1024\t4194304\ti32\t262404\n";

    #[test]
    fn parse_roundtrip() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.entries.len(), 2);
        let e = m.find("min_small").unwrap();
        assert_eq!((e.units, e.wg, e.ts, e.size), (4, 4, 4, 64));
        assert_eq!(e.path(&m.dir), PathBuf::from("/tmp/a/min_small.hlo.txt"));
        assert_eq!(m.of_kind("min_device").count(), 2);
    }

    #[test]
    fn rejects_ragged_rows() {
        let bad = "name\tfile\tkind\tunits\twg\tts\tsize\tdtype\tvmem_bytes\nx\ty\n";
        assert!(Manifest::parse(bad, Path::new(".")).is_err());
    }

    #[test]
    fn rejects_missing_column() {
        let bad = "name\tfile\nx\ty\n";
        assert!(Manifest::parse(bad, Path::new(".")).is_err());
    }

    #[test]
    fn rejects_bad_number() {
        let bad = "name\tfile\tkind\tunits\twg\tts\tsize\tdtype\tvmem_bytes\n\
                   a\tb\tc\tNaN\t1\t1\t1\ti32\t1\n";
        assert!(Manifest::parse(bad, Path::new(".")).is_err());
    }
}
