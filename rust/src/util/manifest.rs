//! Artifact-manifest reader and a minimal JSON value.
//!
//! aot.py writes both `manifest.json` (human) and `manifest.tsv` (machine).
//! We parse the TSV here — a full JSON parser is unnecessary for a flat
//! record table and the TSV is regenerated in the same `make artifacts`.
//!
//! [`Json`] is the small JSON reader/writer the coordinator's persistent
//! [`crate::coordinator::ResultCache`] serializes through (the `serde`
//! ecosystem is unavailable offline). Integers only — every number the
//! repo persists is integral.

use crate::util::error::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

/// Atomically publish `text` at `path`: write a unique sibling temp file
/// (pid + per-process sequence, so concurrent writers — even within one
/// process — never share a temp), then rename over the target. Readers
/// can never observe a partial file; concurrent publishes are
/// last-writer-wins. Shared by the coordinator's task protocol and the
/// persistent result cache.
pub fn write_atomic(path: &Path, text: &str) -> Result<()> {
    use std::sync::atomic::{AtomicU64, Ordering};
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let tmp = PathBuf::from(format!(
        "{}.tmp.{}.{}",
        path.display(),
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    std::fs::write(&tmp, text).with_context(|| format!("writing {}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("publishing {}", path.display()))?;
    Ok(())
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: String,
    pub kind: String,
    pub units: u32,
    pub wg: u32,
    pub ts: u32,
    pub size: u64,
    pub dtype: String,
    pub vmem_bytes: u64,
}

impl ArtifactEntry {
    pub fn path(&self, dir: &Path) -> PathBuf {
        dir.join(&self.file)
    }
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<ArtifactEntry>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let tsv = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&tsv)
            .with_context(|| format!("reading {} (run `make artifacts`)", tsv.display()))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Self> {
        let mut lines = text.lines().filter(|l| !l.trim().is_empty());
        let header = lines.next().context("empty manifest")?;
        let cols: Vec<&str> = header.split('\t').collect();
        let idx = |name: &str| -> Result<usize> {
            cols.iter()
                .position(|c| *c == name)
                .with_context(|| format!("manifest missing column {name}"))
        };
        let (c_name, c_file, c_kind) = (idx("name")?, idx("file")?, idx("kind")?);
        let (c_units, c_wg, c_ts) = (idx("units")?, idx("wg")?, idx("ts")?);
        let (c_size, c_dtype, c_vmem) = (idx("size")?, idx("dtype")?, idx("vmem_bytes")?);
        let mut entries = Vec::new();
        for (lineno, line) in lines.enumerate() {
            let f: Vec<&str> = line.split('\t').collect();
            if f.len() != cols.len() {
                bail!("manifest line {}: {} fields, expected {}", lineno + 2, f.len(), cols.len());
            }
            let p = |i: usize| -> Result<u64> {
                f[i].parse::<u64>()
                    .with_context(|| format!("manifest line {}: bad number {:?}", lineno + 2, f[i]))
            };
            entries.push(ArtifactEntry {
                name: f[c_name].to_string(),
                file: f[c_file].to_string(),
                kind: f[c_kind].to_string(),
                units: p(c_units)? as u32,
                wg: p(c_wg)? as u32,
                ts: p(c_ts)? as u32,
                size: p(c_size)?,
                dtype: f[c_dtype].to_string(),
                vmem_bytes: p(c_vmem)?,
            });
        }
        Ok(Self { dir: dir.to_path_buf(), entries })
    }

    pub fn find(&self, name: &str) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    pub fn of_kind<'a>(&'a self, kind: &'a str) -> impl Iterator<Item = &'a ArtifactEntry> {
        self.entries.iter().filter(move |e| e.kind == kind)
    }
}

// ---------------------------------------------------------------- JSON --

/// A JSON value (integers only; floats are not needed by any persisted
/// record). Objects preserve insertion order so rendered files are
/// deterministic and diff-friendly.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Json {
    Null,
    Bool(bool),
    Int(i64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object-field lookup (None for non-objects and missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Int(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s.as_str()),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(xs) => Some(xs.as_slice()),
            _ => None,
        }
    }

    /// Serialize without insignificant whitespace.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(v) => out.push_str(&v.to_string()),
            Json::Str(s) => write_json_string(s, out),
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_json_string(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a complete JSON document (trailing whitespace allowed).
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = JsonParser { bytes: text.as_bytes(), pos: 0 };
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing garbage at byte {} of JSON document", p.pos);
        }
        Ok(v)
    }
}

fn write_json_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct JsonParser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> JsonParser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected `{}` at byte {} of JSON document", b as char, self.pos)
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            bail!("bad JSON literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek().context("unexpected end of JSON document")? {
            b'n' => self.literal("null", Json::Null),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected `{}` at byte {} of JSON document", c as char, self.pos),
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if matches!(self.peek(), Some(b'.') | Some(b'e') | Some(b'E')) {
            bail!("floating-point JSON numbers are not supported (byte {})", self.pos);
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).expect("digits are ASCII");
        s.parse::<i64>().map(Json::Int).map_err(|_| anyhow!("bad JSON number `{}`", s))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut buf: Vec<u8> = Vec::new();
        loop {
            match self.peek().context("unterminated JSON string")? {
                b'"' => {
                    self.pos += 1;
                    return String::from_utf8(buf)
                        .map_err(|_| anyhow!("invalid UTF-8 in JSON string"));
                }
                b'\\' => {
                    self.pos += 1;
                    let esc = self.peek().context("unterminated JSON escape")?;
                    self.pos += 1;
                    match esc {
                        b'"' => buf.push(b'"'),
                        b'\\' => buf.push(b'\\'),
                        b'/' => buf.push(b'/'),
                        b'b' => buf.push(0x08),
                        b'f' => buf.push(0x0C),
                        b'n' => buf.push(b'\n'),
                        b'r' => buf.push(b'\r'),
                        b't' => buf.push(b'\t'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                .map_err(|_| anyhow!("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| anyhow!("bad \\u escape `{}`", hex))?;
                            self.pos += 4;
                            let c = char::from_u32(cp)
                                .context("surrogate \\u escapes are not supported")?;
                            let mut tmp = [0u8; 4];
                            buf.extend_from_slice(c.encode_utf8(&mut tmp).as_bytes());
                        }
                        c => bail!("unknown JSON escape `\\{}`", c as char),
                    }
                }
                _ => {
                    // copy raw UTF-8 bytes through unchanged
                    buf.push(self.bytes[self.pos]);
                    self.pos += 1;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut xs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(xs));
        }
        loop {
            xs.push(self.value()?);
            self.skip_ws();
            match self.peek().context("unterminated JSON array")? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(xs));
                }
                c => bail!("expected `,` or `]`, got `{}` at byte {}", c as char, self.pos),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let value = self.value()?;
            fields.push((key, value));
            self.skip_ws();
            match self.peek().context("unterminated JSON object")? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(fields));
                }
                c => bail!("expected `,` or `}}`, got `{}` at byte {}", c as char, self.pos),
            }
        }
    }
}

#[cfg(test)]
mod json_tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let v = Json::Obj(vec![
            ("version".into(), Json::Int(1)),
            (
                "entries".into(),
                Json::Arr(vec![
                    Json::Obj(vec![
                        ("desc".into(), Json::Str("model=minimum size=64".into())),
                        ("t_min".into(), Json::Int(-3)),
                        ("ok".into(), Json::Bool(true)),
                        ("none".into(), Json::Null),
                    ]),
                    Json::Arr(vec![]),
                ]),
            ),
        ]);
        let text = v.render();
        assert_eq!(Json::parse(&text).unwrap(), v);
    }

    #[test]
    fn string_escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\te\u{1}π".into());
        let parsed = Json::parse(&v.render()).unwrap();
        assert_eq!(parsed, v);
    }

    #[test]
    fn parses_whitespace_and_unicode_escapes() {
        let v = Json::parse(" { \"a\" : [ 1 , 2 ] , \"b\" : \"\\u00e9\" } ").unwrap();
        assert_eq!(v.get("a").and_then(Json::as_arr).unwrap().len(), 2);
        assert_eq!(v.get("b").and_then(Json::as_str), Some("é"));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[0].as_i64(), Some(1));
    }

    #[test]
    fn rejects_malformed() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1.5").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{} garbage").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn accessors() {
        let v = Json::parse("{\"k\":7}").unwrap();
        assert_eq!(v.get("k").and_then(Json::as_i64), Some(7));
        assert!(v.get("missing").is_none());
        assert!(Json::Int(1).get("k").is_none());
        assert!(Json::Int(1).as_str().is_none());
        assert_eq!(Json::Bool(true).as_bool(), Some(true));
        assert!(Json::Int(1).as_bool().is_none());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_atomic_publishes_and_replaces() {
        let path = std::env::temp_dir()
            .join(format!("mcat_atomic_{}.txt", std::process::id()));
        write_atomic(&path, "hello").unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "hello");
        write_atomic(&path, "world").unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "world");
        std::fs::remove_file(&path).ok();
    }

    const SAMPLE: &str = "name\tfile\tkind\tunits\twg\tts\tsize\tdtype\tvmem_bytes\n\
        min_small\tmin_small.hlo.txt\tmin_device\t4\t4\t4\t64\ti32\t84\n\
        min_u64_wg64_ts1024\tmin_u64_wg64_ts1024.hlo.txt\tmin_device\t64\t64\t1024\t4194304\ti32\t262404\n";

    #[test]
    fn parse_roundtrip() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.entries.len(), 2);
        let e = m.find("min_small").unwrap();
        assert_eq!((e.units, e.wg, e.ts, e.size), (4, 4, 4, 64));
        assert_eq!(e.path(&m.dir), PathBuf::from("/tmp/a/min_small.hlo.txt"));
        assert_eq!(m.of_kind("min_device").count(), 2);
    }

    #[test]
    fn rejects_ragged_rows() {
        let bad = "name\tfile\tkind\tunits\twg\tts\tsize\tdtype\tvmem_bytes\nx\ty\n";
        assert!(Manifest::parse(bad, Path::new(".")).is_err());
    }

    #[test]
    fn rejects_missing_column() {
        let bad = "name\tfile\nx\ty\n";
        assert!(Manifest::parse(bad, Path::new(".")).is_err());
    }

    #[test]
    fn rejects_bad_number() {
        let bad = "name\tfile\tkind\tunits\twg\tts\tsize\tdtype\tvmem_bytes\n\
                   a\tb\tc\tNaN\t1\t1\t1\ti32\t1\n";
        assert!(Manifest::parse(bad, Path::new(".")).is_err());
    }
}
