//! Minimal, dependency-free SIGTERM handling for graceful workers.
//!
//! `mcautotune worker` installs a handler that only sets a process-wide
//! atomic flag (the one async-signal-safe thing a handler may do); the
//! drain loop polls [`term_requested`] between tasks, finishes the task
//! it is on, releases its lease by completing normally, writes the final
//! trace, and exits 0. No `libc` crate: the one `signal(2)` symbol we
//! need is declared directly against the C library std already links.
//! On non-Unix targets installation is a no-op and the flag stays false.

use std::sync::atomic::{AtomicBool, Ordering};

static TERM: AtomicBool = AtomicBool::new(false);

/// True once SIGTERM has been delivered to this process.
#[inline]
pub fn term_requested() -> bool {
    TERM.load(Ordering::Relaxed)
}

/// Pretend a SIGTERM arrived (for tests and demos).
pub fn request_term() {
    TERM.store(true, Ordering::Relaxed);
}

#[cfg(test)]
pub fn reset_for_test() {
    TERM.store(false, Ordering::Relaxed);
}

#[cfg(unix)]
mod imp {
    use super::TERM;
    use std::sync::atomic::Ordering;

    // POSIX reserves 15 for SIGTERM on every Unix this crate targets.
    const SIGTERM: i32 = 15;

    type Handler = extern "C" fn(i32);

    extern "C" {
        // glibc/musl `signal` has BSD semantics (handler stays installed,
        // interrupted syscalls restart) — all we need for a latch flag.
        fn signal(signum: i32, handler: Handler) -> usize;
    }

    extern "C" fn on_term(_signum: i32) {
        // A relaxed store to a static atomic is async-signal-safe.
        TERM.store(true, Ordering::Relaxed);
    }

    pub fn install() {
        unsafe {
            signal(SIGTERM, on_term);
        }
    }
}

#[cfg(not(unix))]
mod imp {
    pub fn install() {}
}

/// Install the SIGTERM → flag handler. Idempotent; safe to call from
/// any thread before the drain loop starts.
pub fn install_term_handler() {
    imp::install();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flag_latches_and_resets() {
        reset_for_test();
        assert!(!term_requested());
        request_term();
        assert!(term_requested());
        reset_for_test();
        assert!(!term_requested());
    }

    #[cfg(unix)]
    #[test]
    fn handler_installs_without_error() {
        install_term_handler();
    }
}
