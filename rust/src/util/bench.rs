//! Minimal criterion-style micro-benchmark harness.
//!
//! The sandbox has no `criterion` crate offline, so `cargo bench` targets
//! (declared with `harness = false`) drive this module instead: warmup,
//! timed iterations, mean / stddev / min, and a text report compatible with
//! `tee bench_output.txt`. Deterministic iteration counts keep runs
//! comparable across the perf-pass iterations recorded in EXPERIMENTS.md.

use std::time::{Duration, Instant};

pub struct BenchOpts {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_iters: u32,
    pub max_iters: u32,
}

impl Default for BenchOpts {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(300),
            measure: Duration::from_secs(2),
            min_iters: 5,
            max_iters: 10_000,
        }
    }
}

pub struct BenchResult {
    pub name: String,
    pub iters: u32,
    pub mean: Duration,
    pub stddev: Duration,
    pub min: Duration,
    pub max: Duration,
    /// optional user-supplied throughput unit count per iteration
    pub elements: Option<u64>,
}

impl BenchResult {
    pub fn report(&self) -> String {
        let mut s = format!(
            "{:<44} iters={:<6} mean={:>10} min={:>10} max={:>10} stddev={:>10}",
            self.name,
            self.iters,
            super::fmt::human_duration(self.mean),
            super::fmt::human_duration(self.min),
            super::fmt::human_duration(self.max),
            super::fmt::human_duration(self.stddev),
        );
        if let Some(n) = self.elements {
            let per_s = n as f64 / self.mean.as_secs_f64();
            s.push_str(&format!(" thrpt={:.3}M/s", per_s / 1e6));
        }
        s
    }
}

pub struct Bencher {
    opts: BenchOpts,
    results: Vec<BenchResult>,
    group: String,
}

impl Bencher {
    pub fn new(group: &str) -> Self {
        let mut opts = BenchOpts::default();
        // honor quick runs: MCAT_BENCH_FAST=1 shrinks the budget 10x
        if std::env::var("MCAT_BENCH_FAST").is_ok() {
            opts.warmup = Duration::from_millis(30);
            opts.measure = Duration::from_millis(200);
        }
        println!("== bench group: {} ==", group);
        Self { opts, results: Vec::new(), group: group.to_string() }
    }

    pub fn with_opts(group: &str, opts: BenchOpts) -> Self {
        println!("== bench group: {} ==", group);
        Self { opts, results: Vec::new(), group: group.to_string() }
    }

    /// Benchmark `f`, which must perform one full iteration per call and
    /// return a value that is black-boxed to keep the optimizer honest.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, f: F) {
        self.bench_n(name, None, f)
    }

    pub fn bench_elems<T, F: FnMut() -> T>(&mut self, name: &str, elements: u64, f: F) {
        self.bench_n(name, Some(elements), f)
    }

    fn bench_n<T, F: FnMut() -> T>(&mut self, name: &str, elements: Option<u64>, mut f: F) {
        // warmup
        let wstart = Instant::now();
        while wstart.elapsed() < self.opts.warmup {
            black_box(f());
        }
        // measure
        let mut samples: Vec<Duration> = Vec::new();
        let mstart = Instant::now();
        while (mstart.elapsed() < self.opts.measure
            || (samples.len() as u32) < self.opts.min_iters)
            && (samples.len() as u32) < self.opts.max_iters
        {
            let t = Instant::now();
            black_box(f());
            samples.push(t.elapsed());
        }
        let n = samples.len() as u32;
        let sum: Duration = samples.iter().sum();
        let mean = sum / n;
        let var = samples
            .iter()
            .map(|s| {
                let d = s.as_secs_f64() - mean.as_secs_f64();
                d * d
            })
            .sum::<f64>()
            / n as f64;
        let res = BenchResult {
            name: format!("{}/{}", self.group, name),
            iters: n,
            mean,
            stddev: Duration::from_secs_f64(var.sqrt()),
            min: *samples.iter().min().unwrap(),
            max: *samples.iter().max().unwrap(),
            elements,
        };
        println!("{}", res.report());
        self.results.push(res);
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

/// `std::hint::black_box` wrapper (stable since 1.66).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let mut b = Bencher::with_opts(
            "t",
            BenchOpts {
                warmup: Duration::from_millis(1),
                measure: Duration::from_millis(10),
                min_iters: 3,
                max_iters: 1000,
            },
        );
        let mut acc = 0u64;
        b.bench("noop", || {
            acc = acc.wrapping_add(1);
            acc
        });
        assert_eq!(b.results().len(), 1);
        let r = &b.results()[0];
        assert!(r.iters >= 3);
        assert!(r.min <= r.mean && r.mean <= r.max);
        assert!(r.report().contains("t/noop"));
    }
}
