//! Recursive-descent parser for the Promela subset.
//!
//! Grammar notes (matching the paper's models):
//! - statement separators are `;` and `->` interchangeably;
//! - proctype parameter lists separate with `;` or `,`;
//! - `if`/`do` options open with `::`; an `else` option is supported;
//! - receive arguments are binds (plain variables) or matches (numbers and
//!   mtype constants), resolved against the declared mtype set;
//! - conditional expressions use Promela's `(c -> a : b)`.

use super::ast::*;
use super::lexer::{lex, Lexed, Tok};
use crate::util::error::{bail, Result};

pub fn parse(src: &str) -> Result<Model> {
    let lexed = lex(src)?;
    Parser { toks: lexed, pos: 0, model: Model::default() }.parse_model()
}

struct Parser {
    toks: Lexed,
    pos: usize,
    model: Model,
}

impl Parser {
    fn peek(&self) -> &Tok {
        &self.toks.toks[self.pos].0
    }

    fn peek2(&self) -> &Tok {
        let i = (self.pos + 1).min(self.toks.toks.len() - 1);
        &self.toks.toks[i].0
    }

    fn line(&self) -> u32 {
        self.toks.toks[self.pos].1
    }

    fn bump(&mut self) -> Tok {
        let t = self.toks.toks[self.pos].0.clone();
        if self.pos < self.toks.toks.len() - 1 {
            self.pos += 1;
        }
        t
    }

    fn expect(&mut self, t: &Tok) -> Result<()> {
        if self.peek() == t {
            self.bump();
            Ok(())
        } else {
            bail!("line {}: expected {:?}, found {:?}", self.line(), t, self.peek())
        }
    }

    fn eat(&mut self, t: &Tok) -> bool {
        if self.peek() == t {
            self.bump();
            true
        } else {
            false
        }
    }

    fn ident(&mut self) -> Result<String> {
        match self.bump() {
            Tok::Ident(s) => Ok(s),
            other => bail!("line {}: expected identifier, found {:?}", self.line(), other),
        }
    }

    fn is_mtype_const(&self, name: &str) -> bool {
        self.model.mtypes.iter().any(|m| m == name)
    }

    // ------------------------------------------------------------- model --

    fn parse_model(mut self) -> Result<Model> {
        loop {
            match self.peek().clone() {
                Tok::Eof => break,
                Tok::Mtype if *self.peek2() == Tok::Assign => {
                    self.bump();
                    self.expect(&Tok::Assign)?;
                    self.expect(&Tok::LBrace)?;
                    loop {
                        let n = self.ident()?;
                        self.model.mtypes.push(n);
                        if !self.eat(&Tok::Comma) {
                            break;
                        }
                    }
                    self.expect(&Tok::RBrace)?;
                    self.eat(&Tok::Semi);
                }
                Tok::TypeName(_) | Tok::Mtype => {
                    let ds = self.parse_var_decls()?;
                    self.model.globals.extend(ds);
                    self.eat(&Tok::Semi);
                }
                Tok::Chan => {
                    let c = self.parse_chan_decl()?;
                    self.model.global_chans.push(c);
                    self.eat(&Tok::Semi);
                }
                Tok::Inline => {
                    self.bump();
                    let name = self.ident()?;
                    self.expect(&Tok::LParen)?;
                    let mut params = Vec::new();
                    if !self.eat(&Tok::RParen) {
                        loop {
                            params.push(self.ident()?);
                            if !self.eat(&Tok::Comma) {
                                break;
                            }
                        }
                        self.expect(&Tok::RParen)?;
                    }
                    self.expect(&Tok::LBrace)?;
                    let body = self.parse_stmts(&[Tok::RBrace])?;
                    self.expect(&Tok::RBrace)?;
                    self.model.inlines.push(InlineDef { name, params, body });
                }
                Tok::Active | Tok::Proctype => {
                    let active = self.eat(&Tok::Active);
                    self.expect(&Tok::Proctype)?;
                    let name = self.ident()?;
                    self.expect(&Tok::LParen)?;
                    let mut params = Vec::new();
                    if !self.eat(&Tok::RParen) {
                        loop {
                            let ty = match self.bump() {
                                Tok::Chan => "chan".to_string(),
                                Tok::TypeName(t) => t.to_string(),
                                Tok::Mtype => "mtype".to_string(),
                                other => bail!(
                                    "line {}: expected parameter type, found {:?}",
                                    self.line(),
                                    other
                                ),
                            };
                            let pname = self.ident()?;
                            params.push((ty, pname));
                            if !(self.eat(&Tok::Semi) || self.eat(&Tok::Comma)) {
                                break;
                            }
                        }
                        self.expect(&Tok::RParen)?;
                    }
                    self.expect(&Tok::LBrace)?;
                    let body = self.parse_stmts(&[Tok::RBrace])?;
                    self.expect(&Tok::RBrace)?;
                    self.model.procs.push(Proctype { name, active, params, body });
                }
                other => bail!("line {}: unexpected top-level token {:?}", self.line(), other),
            }
        }
        Ok(self.model)
    }

    fn parse_var_decls(&mut self) -> Result<Vec<VarDecl>> {
        let ty = match self.bump() {
            Tok::TypeName(t) => t.to_string(),
            Tok::Mtype => "mtype".to_string(),
            other => bail!("line {}: expected type, found {:?}", self.line(), other),
        };
        let mut out = Vec::new();
        loop {
            let name = self.ident()?;
            let len = if self.eat(&Tok::LBrack) {
                let e = self.parse_expr(0)?;
                self.expect(&Tok::RBrack)?;
                match const_eval(&e) {
                    Some(n) if n > 0 => Some(n as u32),
                    _ => bail!("line {}: array length must be a positive constant", self.line()),
                }
            } else {
                None
            };
            let init = if self.eat(&Tok::Assign) { Some(self.parse_expr(0)?) } else { None };
            out.push(VarDecl { ty: ty.clone(), name, len, init });
            if !self.eat(&Tok::Comma) {
                break;
            }
        }
        Ok(out)
    }

    fn parse_chan_decl(&mut self) -> Result<ChanDecl> {
        self.expect(&Tok::Chan)?;
        let name = self.ident()?;
        self.expect(&Tok::Assign)?;
        self.expect(&Tok::LBrack)?;
        let cap = match self.bump() {
            Tok::Num(n) if n >= 0 => n as u32,
            other => bail!("line {}: channel capacity must be a number, got {:?}", self.line(), other),
        };
        self.expect(&Tok::RBrack)?;
        self.expect(&Tok::Of)?;
        self.expect(&Tok::LBrace)?;
        // field list: types, possibly annotated `mtype : action`
        let mut arity = 0u32;
        loop {
            match self.bump() {
                Tok::TypeName(_) | Tok::Mtype | Tok::Chan => arity += 1,
                other => bail!("line {}: expected field type, found {:?}", self.line(), other),
            }
            // optional `: name` annotation (paper writes `mtype : action`)
            if self.eat(&Tok::Colon) {
                self.ident()?;
            }
            if !self.eat(&Tok::Comma) {
                break;
            }
        }
        self.expect(&Tok::RBrace)?;
        Ok(ChanDecl { name, capacity: cap, arity })
    }

    // --------------------------------------------------------- statements --

    /// Parse statements until one of `stop` tokens (not consumed).
    fn parse_stmts(&mut self, stop: &[Tok]) -> Result<Vec<Stmt>> {
        let mut out = Vec::new();
        loop {
            // skip separators
            while self.eat(&Tok::Semi) || self.eat(&Tok::Arrow) {}
            if stop.contains(self.peek()) || *self.peek() == Tok::Eof {
                break;
            }
            out.push(self.parse_stmt()?);
        }
        Ok(out)
    }

    fn parse_options(&mut self, end: &Tok) -> Result<(Vec<Vec<Stmt>>, Option<Vec<Stmt>>)> {
        let mut opts = Vec::new();
        let mut els = None;
        if *self.peek() != Tok::ColonColon {
            bail!("line {}: expected `::` to open an option", self.line());
        }
        while self.eat(&Tok::ColonColon) {
            if self.eat(&Tok::Else) {
                // optional ->
                self.eat(&Tok::Arrow);
                let body = self.parse_stmts(&[Tok::ColonColon, end.clone()])?;
                if els.is_some() {
                    bail!("line {}: duplicate else option", self.line());
                }
                els = Some(body);
            } else {
                let body = self.parse_stmts(&[Tok::ColonColon, end.clone()])?;
                if body.is_empty() {
                    bail!("line {}: empty option", self.line());
                }
                opts.push(body);
            }
        }
        self.expect(end)?;
        Ok((opts, els))
    }

    fn parse_stmt(&mut self) -> Result<Stmt> {
        match self.peek().clone() {
            Tok::TypeName(_) => {
                let mut ds = self.parse_var_decls()?;
                if ds.len() == 1 {
                    Ok(Stmt::VarDecl(ds.pop().unwrap()))
                } else {
                    // represent multi-declarator lines as atomic-free group:
                    // wrap into Atomic for a single Stmt (no blocking inside)
                    Ok(Stmt::Atomic(ds.into_iter().map(Stmt::VarDecl).collect()))
                }
            }
            Tok::Chan => Ok(Stmt::ChanDecl(self.parse_chan_decl()?)),
            Tok::Atomic => {
                self.bump();
                self.expect(&Tok::LBrace)?;
                let body = self.parse_stmts(&[Tok::RBrace])?;
                self.expect(&Tok::RBrace)?;
                Ok(Stmt::Atomic(body))
            }
            Tok::If => {
                self.bump();
                let (opts, els) = self.parse_options(&Tok::Fi)?;
                Ok(Stmt::If(opts, els))
            }
            Tok::Do => {
                self.bump();
                let (opts, els) = self.parse_options(&Tok::Od)?;
                Ok(Stmt::Do(opts, els))
            }
            Tok::For => {
                self.bump();
                self.expect(&Tok::LParen)?;
                let v = self.ident()?;
                self.expect(&Tok::Colon)?;
                let lo = self.parse_expr(0)?;
                self.expect(&Tok::DotDot)?;
                let hi = self.parse_expr(0)?;
                self.expect(&Tok::RParen)?;
                self.expect(&Tok::LBrace)?;
                let body = self.parse_stmts(&[Tok::RBrace])?;
                self.expect(&Tok::RBrace)?;
                Ok(Stmt::For(v, lo, hi, body))
            }
            Tok::Select => {
                self.bump();
                self.expect(&Tok::LParen)?;
                let v = self.ident()?;
                self.expect(&Tok::Colon)?;
                let lo = self.parse_expr(0)?;
                self.expect(&Tok::DotDot)?;
                let hi = self.parse_expr(0)?;
                self.expect(&Tok::RParen)?;
                Ok(Stmt::Select(v, lo, hi))
            }
            Tok::Run => {
                self.bump();
                let name = self.ident()?;
                self.expect(&Tok::LParen)?;
                let mut args = Vec::new();
                if !self.eat(&Tok::RParen) {
                    loop {
                        args.push(self.parse_expr(0)?);
                        if !self.eat(&Tok::Comma) {
                            break;
                        }
                    }
                    self.expect(&Tok::RParen)?;
                }
                Ok(Stmt::Run(name, args))
            }
            Tok::Break => {
                self.bump();
                Ok(Stmt::Break)
            }
            Tok::Skip => {
                self.bump();
                Ok(Stmt::Skip)
            }
            Tok::Ident(name) => {
                // lookahead decides: send/recv/assign/inc/dec/inline/index
                match self.peek2().clone() {
                    Tok::Bang => {
                        self.bump();
                        self.bump();
                        let mut args = Vec::new();
                        loop {
                            args.push(self.parse_expr(0)?);
                            if !self.eat(&Tok::Comma) {
                                break;
                            }
                        }
                        Ok(Stmt::Send(name, args))
                    }
                    Tok::Quest => {
                        self.bump();
                        self.bump();
                        let mut args = Vec::new();
                        loop {
                            args.push(self.parse_recv_arg()?);
                            if !self.eat(&Tok::Comma) {
                                break;
                            }
                        }
                        Ok(Stmt::Recv(name, args))
                    }
                    Tok::Assign => {
                        self.bump();
                        self.bump();
                        let e = self.parse_expr(0)?;
                        Ok(Stmt::Assign(LValue::Var(name), e))
                    }
                    Tok::PlusPlus => {
                        self.bump();
                        self.bump();
                        Ok(Stmt::Inc(LValue::Var(name)))
                    }
                    Tok::MinusMinus => {
                        self.bump();
                        self.bump();
                        Ok(Stmt::Dec(LValue::Var(name)))
                    }
                    Tok::LBrack => {
                        // array element assign/inc/dec — or an expr stmt
                        let save = self.pos;
                        self.bump();
                        self.bump();
                        let idx = self.parse_expr(0)?;
                        self.expect(&Tok::RBrack)?;
                        match self.peek().clone() {
                            Tok::Assign => {
                                self.bump();
                                let e = self.parse_expr(0)?;
                                Ok(Stmt::Assign(LValue::Index(name, Box::new(idx)), e))
                            }
                            Tok::PlusPlus => {
                                self.bump();
                                Ok(Stmt::Inc(LValue::Index(name, Box::new(idx))))
                            }
                            Tok::MinusMinus => {
                                self.bump();
                                Ok(Stmt::Dec(LValue::Index(name, Box::new(idx))))
                            }
                            _ => {
                                self.pos = save;
                                let e = self.parse_expr(0)?;
                                Ok(Stmt::ExprStmt(e))
                            }
                        }
                    }
                    Tok::LParen => {
                        // inline call
                        self.bump();
                        self.bump();
                        let mut args = Vec::new();
                        if !self.eat(&Tok::RParen) {
                            loop {
                                args.push(self.parse_expr(0)?);
                                if !self.eat(&Tok::Comma) {
                                    break;
                                }
                            }
                            self.expect(&Tok::RParen)?;
                        }
                        Ok(Stmt::InlineCall(name, args))
                    }
                    _ => {
                        let e = self.parse_expr(0)?;
                        Ok(Stmt::ExprStmt(e))
                    }
                }
            }
            _ => {
                let e = self.parse_expr(0)?;
                Ok(Stmt::ExprStmt(e))
            }
        }
    }

    fn parse_recv_arg(&mut self) -> Result<RecvArg> {
        match self.peek().clone() {
            Tok::Num(n) => {
                self.bump();
                Ok(RecvArg::Match(PExpr::Num(n)))
            }
            Tok::Ident(name) => {
                self.bump();
                if self.is_mtype_const(&name) {
                    Ok(RecvArg::Match(PExpr::Var(name)))
                } else if self.eat(&Tok::LBrack) {
                    let idx = self.parse_expr(0)?;
                    self.expect(&Tok::RBrack)?;
                    Ok(RecvArg::Bind(LValue::Index(name, Box::new(idx))))
                } else {
                    Ok(RecvArg::Bind(LValue::Var(name)))
                }
            }
            other => bail!("line {}: bad receive argument {:?}", self.line(), other),
        }
    }

    // -------------------------------------------------------- expressions --

    fn parse_expr(&mut self, min_prec: u8) -> Result<PExpr> {
        let mut lhs = self.parse_unary()?;
        loop {
            let (op, prec) = match self.peek() {
                Tok::OrOr => (PBinOp::Or, 1),
                Tok::AndAnd => (PBinOp::And, 2),
                Tok::Eq => (PBinOp::Eq, 3),
                Tok::Ne => (PBinOp::Ne, 3),
                Tok::Lt => (PBinOp::Lt, 4),
                Tok::Le => (PBinOp::Le, 4),
                Tok::Gt => (PBinOp::Gt, 4),
                Tok::Ge => (PBinOp::Ge, 4),
                Tok::Shl => (PBinOp::Shl, 5),
                Tok::Shr => (PBinOp::Shr, 5),
                Tok::Plus => (PBinOp::Add, 6),
                Tok::Minus => (PBinOp::Sub, 6),
                Tok::Star => (PBinOp::Mul, 7),
                Tok::Slash => (PBinOp::Div, 7),
                Tok::Percent => (PBinOp::Mod, 7),
                _ => break,
            };
            if prec < min_prec {
                break;
            }
            self.bump();
            let rhs = self.parse_expr(prec + 1)?;
            lhs = PExpr::Bin(op, Box::new(lhs), Box::new(rhs));
        }
        Ok(lhs)
    }

    fn parse_unary(&mut self) -> Result<PExpr> {
        match self.peek().clone() {
            Tok::Bang => {
                self.bump();
                Ok(PExpr::Unary(UnOp::Not, Box::new(self.parse_unary()?)))
            }
            Tok::Minus => {
                self.bump();
                Ok(PExpr::Unary(UnOp::Neg, Box::new(self.parse_unary()?)))
            }
            Tok::LParen => {
                self.bump();
                let e = self.parse_expr(0)?;
                if self.eat(&Tok::Arrow) {
                    // Promela conditional: (c -> a : b)
                    let a = self.parse_expr(0)?;
                    self.expect(&Tok::Colon)?;
                    let b = self.parse_expr(0)?;
                    self.expect(&Tok::RParen)?;
                    Ok(PExpr::Cond(Box::new(e), Box::new(a), Box::new(b)))
                } else {
                    self.expect(&Tok::RParen)?;
                    Ok(e)
                }
            }
            Tok::Num(n) => {
                self.bump();
                Ok(PExpr::Num(n))
            }
            Tok::True => {
                self.bump();
                Ok(PExpr::Num(1))
            }
            Tok::False => {
                self.bump();
                Ok(PExpr::Num(0))
            }
            Tok::Ident(name) => {
                self.bump();
                if self.eat(&Tok::LBrack) {
                    let idx = self.parse_expr(0)?;
                    self.expect(&Tok::RBrack)?;
                    Ok(PExpr::Index(name, Box::new(idx)))
                } else {
                    Ok(PExpr::Var(name))
                }
            }
            other => bail!("line {}: cannot parse expression at {:?}", self.line(), other),
        }
    }
}

/// Constant folding for array lengths and similar compile-time contexts.
pub fn const_eval(e: &PExpr) -> Option<i64> {
    match e {
        PExpr::Num(n) => Some(*n),
        PExpr::Unary(UnOp::Neg, a) => Some(-const_eval(a)?),
        PExpr::Unary(UnOp::Not, a) => Some((const_eval(a)? == 0) as i64),
        PExpr::Bin(op, a, b) => {
            let (x, y) = (const_eval(a)?, const_eval(b)?);
            Some(match op {
                PBinOp::Add => x + y,
                PBinOp::Sub => x - y,
                PBinOp::Mul => x * y,
                PBinOp::Div => {
                    if y == 0 {
                        return None;
                    }
                    x / y
                }
                PBinOp::Mod => {
                    if y == 0 {
                        return None;
                    }
                    x % y
                }
                PBinOp::Shl => x << (y & 63),
                PBinOp::Shr => x >> (y & 63),
                PBinOp::Eq => (x == y) as i64,
                PBinOp::Ne => (x != y) as i64,
                PBinOp::Lt => (x < y) as i64,
                PBinOp::Le => (x <= y) as i64,
                PBinOp::Gt => (x > y) as i64,
                PBinOp::Ge => (x >= y) as i64,
                PBinOp::And => ((x != 0) && (y != 0)) as i64,
                PBinOp::Or => ((x != 0) || (y != 0)) as i64,
            })
        }
        PExpr::Cond(c, a, b) => {
            if const_eval(c)? != 0 {
                const_eval(a)
            } else {
                const_eval(b)
            }
        }
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_globals_and_mtype() {
        let m = parse("mtype = {go, stop, done};\nint time = 0;\nbool FIN = false;\nbyte arr[4];").unwrap();
        assert_eq!(m.mtypes, vec!["go", "stop", "done"]);
        assert_eq!(m.globals.len(), 3);
        assert_eq!(m.globals[2].len, Some(4));
    }

    #[test]
    fn parse_proctype_with_params() {
        let m = parse(
            "mtype = {go};\nproctype pex (byte me; chan c) { c ! go; c ? go }",
        )
        .unwrap();
        assert_eq!(m.procs.len(), 1);
        let p = &m.procs[0];
        assert_eq!(p.params, vec![("byte".into(), "me".into()), ("chan".into(), "c".into())]);
        assert!(matches!(p.body[0], Stmt::Send(..)));
        assert!(matches!(p.body[1], Stmt::Recv(..)));
    }

    #[test]
    fn recv_args_bind_vs_match() {
        let m = parse("mtype = {go};\nproctype u (chan c) { byte x; c ? x, go; c ? 0, go }").unwrap();
        let body = &m.procs[0].body;
        match &body[1] {
            Stmt::Recv(_, args) => {
                assert!(matches!(args[0], RecvArg::Bind(LValue::Var(ref v)) if v == "x"));
                assert!(matches!(args[1], RecvArg::Match(PExpr::Var(ref v)) if v == "go"));
            }
            other => panic!("expected recv, got {:?}", other),
        }
        match &body[2] {
            Stmt::Recv(_, args) => {
                assert!(matches!(args[0], RecvArg::Match(PExpr::Num(0))));
            }
            other => panic!("expected recv, got {:?}", other),
        }
    }

    #[test]
    fn parse_do_with_else_and_break() {
        let m = parse(
            "active proctype main() { int i; do :: i < 3 -> i++ :: else -> break od }",
        )
        .unwrap();
        match &m.procs[0].body[1] {
            Stmt::Do(opts, els) => {
                assert_eq!(opts.len(), 1);
                assert!(els.is_some());
                assert_eq!(els.as_ref().unwrap()[0], Stmt::Break);
            }
            other => panic!("expected do, got {:?}", other),
        }
    }

    #[test]
    fn parse_listing3_fragment() {
        // straight from the paper's Listing 3 (abridged)
        let src = r#"
            int size, WG, TS, WGs, NWD; byte i;
            active proctype main() {
              byte n = 4;
              size = 1 << n;
              select (i : 1 .. n-1);
              WG = size >> (n - i);
              select (i : 1 .. n-1);
              TS = size >> (n - i);
              WGs = size / (WG * TS);
              NWD = (WGs <= 2 -> WGs : 1);
              atomic { run host(); }
            }
            proctype host() { skip }
        "#;
        let m = parse(src).unwrap();
        assert_eq!(m.procs.len(), 2);
        assert!(m.procs[0].active);
        let body = &m.procs[0].body;
        assert!(body.iter().any(|s| matches!(s, Stmt::Select(..))));
        assert!(body
            .iter()
            .any(|s| matches!(s, Stmt::Assign(LValue::Var(v), PExpr::Cond(..)) if v == "NWD")));
        assert!(body.iter().any(|s| matches!(s, Stmt::Atomic(..))));
    }

    #[test]
    fn parse_inline_def_and_call() {
        let src = r#"
            int time;
            inline long_work(gt, tz) {
              do :: time > gt * tz -> break :: else -> skip od
            }
            proctype pex() { long_work(10, 4) }
        "#;
        let m = parse(src).unwrap();
        assert_eq!(m.inlines.len(), 1);
        assert_eq!(m.inlines[0].params, vec!["gt", "tz"]);
        assert!(matches!(m.procs[0].body[0], Stmt::InlineCall(ref n, _) if n == "long_work"));
    }

    #[test]
    fn parse_chan_decl_with_annotation() {
        let m = parse("proctype h() { chan d = [0] of {mtype : action}; chan e = [2] of {byte, mtype} }").unwrap();
        match (&m.procs[0].body[0], &m.procs[0].body[1]) {
            (Stmt::ChanDecl(c), Stmt::ChanDecl(e)) => {
                assert_eq!((c.capacity, c.arity), (0, 1));
                assert_eq!((e.capacity, e.arity), (2, 2));
            }
            other => panic!("expected chan decls, got {:?}", other),
        }
    }

    #[test]
    fn parse_for_loop() {
        let m = parse("proctype h() { byte i; for (i : 0 .. 3) { skip } }").unwrap();
        assert!(matches!(m.procs[0].body[1], Stmt::For(ref v, _, _, _) if v == "i"));
    }

    #[test]
    fn error_on_garbage() {
        assert!(parse("proctype x() { ??? }").is_err());
        assert!(parse("if :: fi").is_err());
    }
}
