//! Lexer for the Promela subset (paper Listings 3–9, 12–15).
//!
//! Handles `//` and `/* */` comments and a one-pass `#define NAME value`
//! preprocessor (object-like macros only — what the paper's models use).

use crate::util::error::{bail, Result};
use std::collections::HashMap;

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Tok {
    Ident(String),
    Num(i64),
    // keywords
    Proctype,
    Active,
    Run,
    Chan,
    Of,
    Mtype,
    If,
    Fi,
    Do,
    Od,
    Atomic,
    Else,
    Skip,
    Break,
    For,
    Select,
    Inline,
    True,
    False,
    TypeName(&'static str), // bit bool byte short int
    // punctuation / operators
    LBrace,
    RBrace,
    LParen,
    RParen,
    LBrack,
    RBrack,
    Semi,
    Comma,
    Colon,
    ColonColon,
    DotDot,
    Arrow,  // ->
    Bang,   // !
    Quest,  // ?
    Assign, // =
    Eq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
    Plus,
    Minus,
    Star,
    Slash,
    Percent,
    Shl,
    Shr,
    AndAnd,
    OrOr,
    PlusPlus,
    MinusMinus,
    Eof,
}

#[derive(Debug, Clone)]
pub struct Lexed {
    pub toks: Vec<(Tok, u32)>, // token + line number
}

pub fn lex(src: &str) -> Result<Lexed> {
    // pass 1: collect #define macros, strip directives & comments
    let mut defines: HashMap<String, String> = HashMap::new();
    let mut clean = String::with_capacity(src.len());
    let mut chars = src.chars().peekable();
    let in_line_comment = false;
    let mut in_block_comment = false;
    let line_start = true;
    let mut line_buf = String::new();

    // simpler: process line by line for directives, then strip comments
    for line in src.lines() {
        let trimmed = line.trim_start();
        if !in_block_comment && trimmed.starts_with("#define") {
            let rest = trimmed["#define".len()..].trim();
            let mut parts = rest.splitn(2, char::is_whitespace);
            let name = parts.next().unwrap_or("").to_string();
            let val = parts.next().unwrap_or("").trim().to_string();
            if name.is_empty() {
                bail!("malformed #define: `{}`", line);
            }
            defines.insert(name, val);
            clean.push('\n');
            continue;
        }
        if !in_block_comment && trimmed.starts_with('#') {
            bail!("unsupported preprocessor directive: `{}`", trimmed);
        }
        clean.push_str(line);
        clean.push('\n');
        // track block comments crossing lines (coarse but adequate)
        let mut i = 0;
        let b = line.as_bytes();
        while i + 1 < b.len() + 1 {
            if in_block_comment {
                if i + 1 < b.len() && b[i] == b'*' && b[i + 1] == b'/' {
                    in_block_comment = false;
                    i += 2;
                    continue;
                }
                i += 1;
            } else {
                if i + 1 < b.len() && b[i] == b'/' && b[i + 1] == b'*' {
                    in_block_comment = true;
                    i += 2;
                    continue;
                }
                if i + 1 < b.len() && b[i] == b'/' && b[i + 1] == b'/' {
                    break;
                }
                i += 1;
            }
        }
    }
    let _ = (&mut chars, in_line_comment, line_start, &mut line_buf); // silence

    // expand macros repeatedly (supports macros referencing macros)
    let expand = |s: &str, defines: &HashMap<String, String>| -> String {
        let mut out = String::with_capacity(s.len());
        let mut it = s.char_indices().peekable();
        let bytes = s;
        let mut idx = 0;
        while idx < bytes.len() {
            let c = bytes[idx..].chars().next().unwrap();
            if c.is_alphabetic() || c == '_' {
                let start = idx;
                while idx < bytes.len() {
                    let ch = bytes[idx..].chars().next().unwrap();
                    if ch.is_alphanumeric() || ch == '_' {
                        idx += ch.len_utf8();
                    } else {
                        break;
                    }
                }
                let word = &bytes[start..idx];
                if let Some(v) = defines.get(word) {
                    out.push('(');
                    out.push_str(v);
                    out.push(')');
                } else {
                    out.push_str(word);
                }
            } else {
                out.push(c);
                idx += c.len_utf8();
            }
        }
        let _ = &mut it;
        out
    };
    let mut text = clean;
    for _ in 0..8 {
        let next = expand(&text, &defines);
        if next == text {
            break;
        }
        text = next;
    }

    // pass 2: tokenize
    let mut toks = Vec::new();
    let b: Vec<char> = text.chars().collect();
    let mut i = 0usize;
    let mut line: u32 = 1;
    while i < b.len() {
        let c = b[i];
        match c {
            '\n' => {
                line += 1;
                i += 1;
            }
            c if c.is_whitespace() => i += 1,
            '/' if i + 1 < b.len() && b[i + 1] == '/' => {
                while i < b.len() && b[i] != '\n' {
                    i += 1;
                }
            }
            '/' if i + 1 < b.len() && b[i + 1] == '*' => {
                i += 2;
                while i + 1 < b.len() && !(b[i] == '*' && b[i + 1] == '/') {
                    if b[i] == '\n' {
                        line += 1;
                    }
                    i += 1;
                }
                if i + 1 >= b.len() {
                    bail!("unterminated /* comment (line {})", line);
                }
                i += 2;
            }
            c if c.is_ascii_digit() => {
                let start = i;
                while i < b.len() && b[i].is_ascii_digit() {
                    i += 1;
                }
                let s: String = b[start..i].iter().collect();
                toks.push((Tok::Num(s.parse()?), line));
            }
            c if c.is_alphabetic() || c == '_' => {
                let start = i;
                while i < b.len() && (b[i].is_alphanumeric() || b[i] == '_') {
                    i += 1;
                }
                let s: String = b[start..i].iter().collect();
                let t = match s.as_str() {
                    "proctype" => Tok::Proctype,
                    "active" => Tok::Active,
                    "run" => Tok::Run,
                    "chan" => Tok::Chan,
                    "of" => Tok::Of,
                    "mtype" => Tok::Mtype,
                    "if" => Tok::If,
                    "fi" => Tok::Fi,
                    "do" => Tok::Do,
                    "od" => Tok::Od,
                    "atomic" => Tok::Atomic,
                    "else" => Tok::Else,
                    "skip" => Tok::Skip,
                    "break" => Tok::Break,
                    "for" => Tok::For,
                    "select" => Tok::Select,
                    "inline" => Tok::Inline,
                    "true" => Tok::True,
                    "false" => Tok::False,
                    "bit" => Tok::TypeName("bit"),
                    "bool" => Tok::TypeName("bool"),
                    "byte" => Tok::TypeName("byte"),
                    "short" => Tok::TypeName("short"),
                    "int" => Tok::TypeName("int"),
                    _ => Tok::Ident(s),
                };
                toks.push((t, line));
            }
            _ => {
                let two: String = b[i..(i + 2).min(b.len())].iter().collect();
                let (t, len) = match two.as_str() {
                    "::" => (Tok::ColonColon, 2),
                    ".." => (Tok::DotDot, 2),
                    "->" => (Tok::Arrow, 2),
                    "==" => (Tok::Eq, 2),
                    "!=" => (Tok::Ne, 2),
                    "<=" => (Tok::Le, 2),
                    ">=" => (Tok::Ge, 2),
                    "<<" => (Tok::Shl, 2),
                    ">>" => (Tok::Shr, 2),
                    "&&" => (Tok::AndAnd, 2),
                    "||" => (Tok::OrOr, 2),
                    "++" => (Tok::PlusPlus, 2),
                    "--" => (Tok::MinusMinus, 2),
                    _ => match c {
                        '{' => (Tok::LBrace, 1),
                        '}' => (Tok::RBrace, 1),
                        '(' => (Tok::LParen, 1),
                        ')' => (Tok::RParen, 1),
                        '[' => (Tok::LBrack, 1),
                        ']' => (Tok::RBrack, 1),
                        ';' => (Tok::Semi, 1),
                        ',' => (Tok::Comma, 1),
                        ':' => (Tok::Colon, 1),
                        '!' => (Tok::Bang, 1),
                        '?' => (Tok::Quest, 1),
                        '=' => (Tok::Assign, 1),
                        '<' => (Tok::Lt, 1),
                        '>' => (Tok::Gt, 1),
                        '+' => (Tok::Plus, 1),
                        '-' => (Tok::Minus, 1),
                        '*' => (Tok::Star, 1),
                        '/' => (Tok::Slash, 1),
                        '%' => (Tok::Percent, 1),
                        _ => bail!("unexpected character `{}` at line {}", c, line),
                    },
                };
                toks.push((t, line));
                i += len;
            }
        }
    }
    toks.push((Tok::Eof, line));
    Ok(Lexed { toks })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lex_basic_tokens() {
        let l = lex("byte x = 10; x++;").unwrap();
        let kinds: Vec<&Tok> = l.toks.iter().map(|(t, _)| t).collect();
        assert!(matches!(kinds[0], Tok::TypeName("byte")));
        assert!(matches!(kinds[1], Tok::Ident(s) if s == "x"));
        assert_eq!(*kinds[2], Tok::Assign);
        assert_eq!(*kinds[3], Tok::Num(10));
        assert_eq!(*kinds[5], Tok::Ident("x".into()));
        assert_eq!(*kinds[6], Tok::PlusPlus);
    }

    #[test]
    fn lex_comments_stripped() {
        let l = lex("int a; // trailing\n/* block\nspanning */ int b;").unwrap();
        let idents: Vec<String> = l
            .toks
            .iter()
            .filter_map(|(t, _)| match t {
                Tok::Ident(s) => Some(s.clone()),
                _ => None,
            })
            .collect();
        assert_eq!(idents, vec!["a", "b"]);
    }

    #[test]
    fn define_expansion() {
        let l = lex("#define N 4\n#define M (N+1)\nint x = M;").unwrap();
        // M -> ((4)+1): the numbers 4 and 1 must appear
        let nums: Vec<i64> = l
            .toks
            .iter()
            .filter_map(|(t, _)| match t {
                Tok::Num(n) => Some(*n),
                _ => None,
            })
            .collect();
        assert_eq!(nums, vec![4, 1]);
    }

    #[test]
    fn two_char_operators() {
        let l = lex(":: x <= 1 -> y = x << 2 .. 3").unwrap();
        let kinds: Vec<&Tok> = l.toks.iter().map(|(t, _)| t).collect();
        assert_eq!(*kinds[0], Tok::ColonColon);
        assert_eq!(*kinds[2], Tok::Le);
        assert_eq!(*kinds[4], Tok::Arrow);
        assert!(kinds.contains(&&Tok::Shl));
        assert!(kinds.contains(&&Tok::DotDot));
    }

    #[test]
    fn keywords_recognized() {
        let l = lex("active proctype main() { do :: skip od }").unwrap();
        let kinds: Vec<&Tok> = l.toks.iter().map(|(t, _)| t).collect();
        assert_eq!(*kinds[0], Tok::Active);
        assert_eq!(*kinds[1], Tok::Proctype);
        assert!(kinds.contains(&&Tok::Do));
        assert!(kinds.contains(&&Tok::Skip));
        assert!(kinds.contains(&&Tok::Od));
    }

    #[test]
    fn rejects_unknown_directive() {
        assert!(lex("#include \"x\"").is_err());
    }

    #[test]
    fn line_numbers_tracked() {
        let l = lex("int a;\nint b;").unwrap();
        let b_line = l
            .toks
            .iter()
            .find(|(t, _)| matches!(t, Tok::Ident(s) if s == "b"))
            .unwrap()
            .1;
        assert_eq!(b_line, 2);
    }
}
