//! Promela-subset front end — our stand-in for SPIN's modeling language.
//!
//! Pipeline: [`lexer`] -> [`parser`] (AST) -> [`compile`] (flat process
//! automata) -> [`interp`] (a full-interleaving [`crate::model::TransitionSystem`]).
//! The subset covers everything the paper's models use: proctypes (active
//! or run-spawned, with parameters), rendezvous and buffered channels,
//! atomic, if/do with else, for, select, inline macros, #define, mtype,
//! arrays, and Promela's conditional expressions.
//!
//! `templates` generates the paper's two models (abstract platform &
//! minimum problem) for arbitrary sizes; pregenerated instances ship in
//! `models/*.pml`.

pub mod ast;
pub mod compile;
pub mod interp;
pub mod lexer;
pub mod parser;
pub mod templates;

pub use interp::{source_hash, PromelaSystem, PState};
