//! Promela-subset front end and execution engines — our stand-in for
//! SPIN's modeling language.
//!
//! The pipeline is a **two-stage compile** feeding two engines:
//!
//! ```text
//! [lexer] -> [parser] (AST) -> [compile]  (stage 1: flat process automata,
//!        |                                 tree-shaped CExpr operands)
//!        |                         ├── [interp]  reference tree-walking
//!        |                         │             interpreter (nested state)
//!        |                         └── [vm]      stage 2: constant folding +
//!        |                                       expression bytecode over
//!        |                                       flat packed states
//! ```
//!
//! Stage 1 ([`compile`]) resolves names to dense slots and threads every
//! proctype into a SPIN-style instruction automaton. Stage 2
//! ([`vm::PromelaVm`]) lowers the operand trees to linear bytecode with
//! short-circuit jumps, packs the whole state into one flat `i32` vector
//! (clone = memcpy, hashing = one pass) and can **specialize** the
//! program to a coordinator shard's (WG, TS) sub-lattice so off-shard
//! successors are never generated. The interpreter
//! ([`interp::PromelaSystem`]) executes stage 1 directly and serves as
//! the reference implementation the differential suite
//! (`rust/tests/promela_vm.rs`) pins the VM against — state counts,
//! verdicts and trails must match one-to-one.
//!
//! The subset covers everything the paper's models use: proctypes
//! (active or run-spawned, with parameters), rendezvous and buffered
//! channels, atomic, if/do with else, for, select, inline macros,
//! #define, mtype, arrays, Promela's conditional expressions, and
//! SPIN's per-declared-width store truncation (bit/byte/short/int).
//!
//! `templates` generates the paper's two models (abstract platform &
//! minimum problem) for arbitrary sizes; pregenerated instances ship in
//! `models/*.pml`.

pub mod analysis;
pub mod ast;
pub mod compile;
pub mod interp;
pub mod lexer;
pub mod parser;
pub mod templates;
pub mod vm;

pub use interp::{source_hash, PromelaSystem, PState};
pub use vm::{PromelaVm, TuningBounds, VState};
