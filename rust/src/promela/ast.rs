//! AST for the Promela subset.

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PExpr {
    Num(i64),
    Var(String),
    Index(String, Box<PExpr>),
    Unary(UnOp, Box<PExpr>),
    Bin(PBinOp, Box<PExpr>, Box<PExpr>),
    /// Promela conditional expression `(c -> a : b)`
    Cond(Box<PExpr>, Box<PExpr>, Box<PExpr>),
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UnOp {
    Not,
    Neg,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PBinOp {
    Add,
    Sub,
    Mul,
    Div,
    Mod,
    Shl,
    Shr,
    Eq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
    And,
    Or,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum LValue {
    Var(String),
    Index(String, Box<PExpr>),
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RecvArg {
    /// bind the received field into a variable
    Bind(LValue),
    /// match a constant (mtype name or literal) — message filtered on it
    Match(PExpr),
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct VarDecl {
    pub ty: String,
    pub name: String,
    /// array length (None = scalar)
    pub len: Option<u32>,
    pub init: Option<PExpr>,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ChanDecl {
    pub name: String,
    pub capacity: u32,
    pub arity: u32,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Stmt {
    VarDecl(VarDecl),
    ChanDecl(ChanDecl),
    Assign(LValue, PExpr),
    Inc(LValue),
    Dec(LValue),
    /// blocking expression statement
    ExprStmt(PExpr),
    Send(String, Vec<PExpr>),
    Recv(String, Vec<RecvArg>),
    If(Vec<Vec<Stmt>>, Option<Vec<Stmt>>),
    Do(Vec<Vec<Stmt>>, Option<Vec<Stmt>>),
    Atomic(Vec<Stmt>),
    For(String, PExpr, PExpr, Vec<Stmt>),
    Select(String, PExpr, PExpr),
    Run(String, Vec<PExpr>),
    InlineCall(String, Vec<PExpr>),
    Break,
    Skip,
}

#[derive(Debug, Clone)]
pub struct Proctype {
    pub name: String,
    pub active: bool,
    pub params: Vec<(String, String)>, // (type-ish: "chan"/"byte"/..., name)
    pub body: Vec<Stmt>,
}

#[derive(Debug, Clone)]
pub struct InlineDef {
    pub name: String,
    pub params: Vec<String>,
    pub body: Vec<Stmt>,
}

#[derive(Debug, Clone, Default)]
pub struct Model {
    pub mtypes: Vec<String>,
    pub globals: Vec<VarDecl>,
    pub global_chans: Vec<ChanDecl>,
    pub inlines: Vec<InlineDef>,
    pub procs: Vec<Proctype>,
}

/// Substitute identifiers by expressions (inline-macro expansion).
/// Replaces whole-variable references and, where the substitute is itself a
/// plain variable, lvalues/channel names too.
pub fn subst_stmts(stmts: &[Stmt], map: &std::collections::HashMap<String, PExpr>) -> Vec<Stmt> {
    stmts.iter().map(|s| subst_stmt(s, map)).collect()
}

fn subst_name(name: &str, map: &std::collections::HashMap<String, PExpr>) -> String {
    match map.get(name) {
        Some(PExpr::Var(v)) => v.clone(),
        _ => name.to_string(),
    }
}

fn subst_lval(lv: &LValue, map: &std::collections::HashMap<String, PExpr>) -> LValue {
    match lv {
        LValue::Var(n) => LValue::Var(subst_name(n, map)),
        LValue::Index(n, e) => LValue::Index(subst_name(n, map), Box::new(subst_expr(e, map))),
    }
}

pub fn subst_expr(e: &PExpr, map: &std::collections::HashMap<String, PExpr>) -> PExpr {
    match e {
        PExpr::Num(n) => PExpr::Num(*n),
        PExpr::Var(n) => map.get(n).cloned().unwrap_or_else(|| PExpr::Var(n.clone())),
        PExpr::Index(n, i) => PExpr::Index(subst_name(n, map), Box::new(subst_expr(i, map))),
        PExpr::Unary(op, a) => PExpr::Unary(*op, Box::new(subst_expr(a, map))),
        PExpr::Bin(op, a, b) => {
            PExpr::Bin(*op, Box::new(subst_expr(a, map)), Box::new(subst_expr(b, map)))
        }
        PExpr::Cond(c, a, b) => PExpr::Cond(
            Box::new(subst_expr(c, map)),
            Box::new(subst_expr(a, map)),
            Box::new(subst_expr(b, map)),
        ),
    }
}

fn subst_stmt(s: &Stmt, map: &std::collections::HashMap<String, PExpr>) -> Stmt {
    match s {
        Stmt::VarDecl(d) => Stmt::VarDecl(VarDecl {
            ty: d.ty.clone(),
            name: subst_name(&d.name, map),
            len: d.len,
            init: d.init.as_ref().map(|e| subst_expr(e, map)),
        }),
        Stmt::ChanDecl(c) => Stmt::ChanDecl(ChanDecl {
            name: subst_name(&c.name, map),
            ..c.clone()
        }),
        Stmt::Assign(lv, e) => Stmt::Assign(subst_lval(lv, map), subst_expr(e, map)),
        Stmt::Inc(lv) => Stmt::Inc(subst_lval(lv, map)),
        Stmt::Dec(lv) => Stmt::Dec(subst_lval(lv, map)),
        Stmt::ExprStmt(e) => Stmt::ExprStmt(subst_expr(e, map)),
        Stmt::Send(c, es) => Stmt::Send(
            subst_name(c, map),
            es.iter().map(|e| subst_expr(e, map)).collect(),
        ),
        Stmt::Recv(c, args) => Stmt::Recv(
            subst_name(c, map),
            args.iter()
                .map(|a| match a {
                    RecvArg::Bind(lv) => RecvArg::Bind(subst_lval(lv, map)),
                    RecvArg::Match(e) => RecvArg::Match(subst_expr(e, map)),
                })
                .collect(),
        ),
        Stmt::If(opts, els) => Stmt::If(
            opts.iter().map(|o| subst_stmts(o, map)).collect(),
            els.as_ref().map(|o| subst_stmts(o, map)),
        ),
        Stmt::Do(opts, els) => Stmt::Do(
            opts.iter().map(|o| subst_stmts(o, map)).collect(),
            els.as_ref().map(|o| subst_stmts(o, map)),
        ),
        Stmt::Atomic(body) => Stmt::Atomic(subst_stmts(body, map)),
        Stmt::For(v, lo, hi, body) => Stmt::For(
            subst_name(v, map),
            subst_expr(lo, map),
            subst_expr(hi, map),
            subst_stmts(body, map),
        ),
        Stmt::Select(v, lo, hi) => {
            Stmt::Select(subst_name(v, map), subst_expr(lo, map), subst_expr(hi, map))
        }
        Stmt::Run(p, es) => {
            Stmt::Run(p.clone(), es.iter().map(|e| subst_expr(e, map)).collect())
        }
        Stmt::InlineCall(n, es) => {
            Stmt::InlineCall(n.clone(), es.iter().map(|e| subst_expr(e, map)).collect())
        }
        Stmt::Break => Stmt::Break,
        Stmt::Skip => Stmt::Skip,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    #[test]
    fn subst_replaces_vars_and_channels() {
        let mut map = HashMap::new();
        map.insert("gt".to_string(), PExpr::Num(3));
        map.insert("c".to_string(), PExpr::Var("pex_b".into()));
        let body = vec![
            Stmt::Assign(LValue::Var("x".into()), PExpr::Var("gt".into())),
            Stmt::Send("c".into(), vec![PExpr::Var("gt".into())]),
        ];
        let out = subst_stmts(&body, &map);
        assert_eq!(out[0], Stmt::Assign(LValue::Var("x".into()), PExpr::Num(3)));
        assert_eq!(out[1], Stmt::Send("pex_b".into(), vec![PExpr::Num(3)]));
    }
}
