//! AST → flat instruction program (stage one of the two-stage compile).
//!
//! Each proctype compiles to a vector of [`Instr`]s threaded by `next`
//! indices — the classical SPIN-style process automaton. `if`/`do`/`for`
//! compile to [`Op::Branch`] whose option executability follows Promela's
//! first-statement rule; `atomic` marks instructions with `atomic_next` so
//! the execution engines keep exclusivity while inside the block; inline
//! macros are expanded at compile time with parameter substitution.
//!
//! The [`Program`] this stage produces still carries tree-shaped
//! [`CExpr`]s; it is executed directly by the reference tree-walking
//! interpreter ([`super::interp`]) and lowered further — constant folding,
//! linear expression bytecode, flat packed state layout — by the
//! production engine ([`super::vm`]). Both engines share this automaton
//! (same pcs, same `next` threading), which is what lets the differential
//! suite compare their state spaces one-to-one.

use super::ast::*;
use super::parser::const_eval;
use crate::util::error::{anyhow, bail, Context, Result};
use std::collections::HashMap;

pub const NO_PC: u32 = u32::MAX;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Slot {
    Global(u32),
    Local(u32),
}

/// Declared scalar width. SPIN truncates every assignment to the declared
/// width (C bitfield semantics: `bit`/`bool` keep 1 bit, `byte` is an
/// unsigned 8-bit wrap, `short` a signed 16-bit wrap); both execution
/// engines apply the same truncation at store time so models that rely on
/// wrapping agree with SPIN. Channel *message fields* are not typed in
/// this subset and stay untruncated until received into a typed variable.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VarType {
    Bit,
    Byte,
    Short,
    Int,
}

impl VarType {
    /// Width of a declared type name (`chan` variables hold channel ids,
    /// `mtype` constants are byte-sized in SPIN).
    pub fn of(ty: &str) -> VarType {
        match ty {
            "bit" | "bool" => VarType::Bit,
            "byte" | "mtype" => VarType::Byte,
            "short" => VarType::Short,
            _ => VarType::Int, // int, chan ids
        }
    }

    /// Truncate an assigned value to the declared width.
    #[inline]
    pub fn truncate(self, v: i32) -> i32 {
        match self {
            VarType::Bit => v & 1,
            VarType::Byte => v & 0xFF,
            VarType::Short => v as i16 as i32,
            VarType::Int => v,
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub enum CExpr {
    Num(i32),
    Load(Slot),
    LoadElem(Slot, u32, Box<CExpr>),
    Un(UnOp, Box<CExpr>),
    Bin(PBinOp, Box<CExpr>, Box<CExpr>),
    Cond(Box<CExpr>, Box<CExpr>, Box<CExpr>),
}

#[derive(Debug, Clone, PartialEq)]
pub enum CLVal {
    Scalar(Slot, VarType),
    Elem(Slot, u32, CExpr, VarType),
}

#[derive(Debug, Clone, PartialEq)]
pub enum CRecvArg {
    Bind(CLVal),
    Match(CExpr),
}

#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    /// blocking expression (also `skip` = Guard(1))
    Guard(CExpr),
    Assign(CLVal, CExpr),
    Send(CExpr, Vec<CExpr>),
    Recv(CExpr, Vec<CRecvArg>),
    /// nondeterministic assignment lo..=hi
    Select(CLVal, CExpr, CExpr),
    /// option entries + optional else entry
    Branch(Vec<u32>, Option<u32>),
    Run(u32, Vec<CExpr>),
    /// allocate a channel, store its id
    NewChan(CLVal, u16, u16),
    Halt,
}

#[derive(Debug, Clone)]
pub struct Instr {
    pub op: Op,
    pub next: u32,
    /// keep process exclusivity after firing (inside `atomic`)
    pub atomic_next: bool,
}

#[derive(Debug, Clone)]
pub struct ProcDef {
    pub name: String,
    pub nparams: u32,
    /// declared width of each parameter (run-arguments truncate on bind)
    pub param_types: Vec<VarType>,
    pub nlocals: u32,
    pub code: Vec<Instr>,
    pub entry: u32,
    /// declared locals (params first) sorted by slot offset — source names
    /// for diagnostics; execution never consults this
    pub locals: Vec<(String, VarInfo)>,
}

impl ProcDef {
    /// Source name of a local slot (`name` or `name[i]` for array cells).
    pub fn local_name(&self, slot: u32) -> Option<String> {
        let (name, info) =
            self.locals.iter().find(|(_, i)| i.offset <= slot && slot < i.offset + i.len)?;
        Some(if info.len == 1 {
            name.clone()
        } else {
            format!("{}[{}]", name, slot - info.offset)
        })
    }
}

#[derive(Debug, Clone, Copy)]
pub struct VarInfo {
    pub offset: u32,
    pub len: u32, // 1 = scalar
    pub ty: VarType,
}

#[derive(Debug, Clone)]
pub struct Program {
    pub mtypes: Vec<String>,
    pub global_syms: HashMap<String, VarInfo>,
    pub globals_init: Vec<i32>,
    /// (capacity, arity) of channels declared at global scope (ids 0..n)
    pub global_chans: Vec<(u16, u16)>,
    pub procs: Vec<ProcDef>,
    pub active: Vec<u32>,
}

impl Program {
    /// Source name of a global slot (`name` or `name[i]` for array cells).
    pub fn global_name(&self, slot: u32) -> Option<String> {
        let (name, info) =
            self.global_syms.iter().find(|(_, i)| i.offset <= slot && slot < i.offset + i.len)?;
        Some(if info.len == 1 {
            name.clone()
        } else {
            format!("{}[{}]", name, slot - info.offset)
        })
    }
}

pub fn compile(model: &Model) -> Result<Program> {
    // mtype values: index+1 (0 stays "no message")
    let mtypes = model.mtypes.clone();

    // global symbol table + init image
    let mut global_syms = HashMap::new();
    let mut globals_init = Vec::new();
    for d in &model.globals {
        let len = d.len.unwrap_or(1);
        if global_syms.contains_key(&d.name) {
            bail!("duplicate global `{}`", d.name);
        }
        let ty = VarType::of(&d.ty);
        global_syms
            .insert(d.name.clone(), VarInfo { offset: globals_init.len() as u32, len, ty });
        let init = match &d.init {
            None => 0,
            Some(e) => ty.truncate(
                const_eval(e)
                    .with_context(|| format!("global `{}` initializer must be constant", d.name))?
                    as i32,
            ),
        };
        for _ in 0..len {
            globals_init.push(init);
        }
    }

    let mut global_chan_ids = HashMap::new();
    let mut global_chans = Vec::new();
    for (i, c) in model.global_chans.iter().enumerate() {
        global_chan_ids.insert(c.name.clone(), i as i32);
        global_chans.push((c.capacity as u16, c.arity as u16));
    }

    let proc_ids: HashMap<String, u32> = model
        .procs
        .iter()
        .enumerate()
        .map(|(i, p)| (p.name.clone(), i as u32))
        .collect();

    let inlines: HashMap<String, &InlineDef> =
        model.inlines.iter().map(|d| (d.name.clone(), d)).collect();

    let mut procs = Vec::new();
    let mut active = Vec::new();
    for (i, p) in model.procs.iter().enumerate() {
        let def = ProcCompiler {
            mtypes: &mtypes,
            global_syms: &global_syms,
            global_chan_ids: &global_chan_ids,
            proc_ids: &proc_ids,
            inlines: &inlines,
            local_syms: HashMap::new(),
            nlocals: 0,
            code: Vec::new(),
            break_stack: Vec::new(),
            inline_depth: 0,
        }
        .compile_proc(p)?;
        if p.active {
            if !p.params.is_empty() {
                bail!("active proctype `{}` cannot take parameters", p.name);
            }
            active.push(i as u32);
        }
        procs.push(def);
    }
    if active.is_empty() {
        bail!("no active proctype — nothing to run");
    }

    Ok(Program { mtypes, global_syms, globals_init, global_chans, procs, active })
}

struct ProcCompiler<'a> {
    mtypes: &'a [String],
    global_syms: &'a HashMap<String, VarInfo>,
    global_chan_ids: &'a HashMap<String, i32>,
    proc_ids: &'a HashMap<String, u32>,
    inlines: &'a HashMap<String, &'a InlineDef>,
    local_syms: HashMap<String, VarInfo>,
    nlocals: u32,
    code: Vec<Instr>,
    /// per-loop lists of Guard(true) "break" instrs awaiting exit patch
    break_stack: Vec<Vec<u32>>,
    inline_depth: u32,
}

impl<'a> ProcCompiler<'a> {
    fn compile_proc(mut self, p: &Proctype) -> Result<ProcDef> {
        // params occupy the first local slots (all scalar)
        let mut param_types = Vec::with_capacity(p.params.len());
        for (ty, name) in &p.params {
            self.alloc_local(name, 1, VarType::of(ty))?;
            param_types.push(VarType::of(ty));
        }
        let nparams = p.params.len() as u32;

        // pre-scan: allocate every local declared anywhere in the body
        self.prealloc(&p.body)?;

        let (entry, exits) = self.emit_seq(&p.body)?;
        let halt_pc = self.emit(Op::Halt);
        self.patch(&exits, halt_pc);
        let entry = entry.unwrap_or(halt_pc);
        let mut locals: Vec<(String, VarInfo)> =
            self.local_syms.iter().map(|(n, i)| (n.clone(), *i)).collect();
        locals.sort_by_key(|(_, i)| i.offset);
        Ok(ProcDef {
            name: p.name.clone(),
            nparams,
            param_types,
            nlocals: self.nlocals,
            code: self.code,
            entry,
            locals,
        })
    }

    fn alloc_local(&mut self, name: &str, len: u32, ty: VarType) -> Result<()> {
        if self.local_syms.contains_key(name) {
            // Promela proctype scope: a second decl of the same name would
            // shadow confusingly — reject.
            bail!("duplicate local `{}`", name);
        }
        self.local_syms.insert(name.to_string(), VarInfo { offset: self.nlocals, len, ty });
        self.nlocals += len;
        Ok(())
    }

    fn prealloc(&mut self, stmts: &[Stmt]) -> Result<()> {
        for s in stmts {
            match s {
                Stmt::VarDecl(d) => {
                    if !self.local_syms.contains_key(&d.name) {
                        self.alloc_local(&d.name, d.len.unwrap_or(1), VarType::of(&d.ty))?;
                    }
                }
                Stmt::ChanDecl(c) => {
                    if !self.local_syms.contains_key(&c.name) {
                        // holds a channel id
                        self.alloc_local(&c.name, 1, VarType::Int)?;
                    }
                }
                Stmt::If(opts, els) | Stmt::Do(opts, els) => {
                    for o in opts {
                        self.prealloc(o)?;
                    }
                    if let Some(e) = els {
                        self.prealloc(e)?;
                    }
                }
                Stmt::Atomic(b) | Stmt::For(_, _, _, b) => self.prealloc(b)?,
                Stmt::InlineCall(name, args) => {
                    // expand to know its decls too
                    let body = self.expand_inline(name, args)?;
                    self.inline_depth += 1;
                    self.prealloc(&body)?;
                    self.inline_depth -= 1;
                }
                _ => {}
            }
            // For loop variables may be undeclared in some dialects; the
            // paper declares them, so we require a declaration.
        }
        Ok(())
    }

    fn expand_inline(&self, name: &str, args: &[PExpr]) -> Result<Vec<Stmt>> {
        let def = self
            .inlines
            .get(name)
            .ok_or_else(|| anyhow!("unknown statement or inline `{}`", name))?;
        if def.params.len() != args.len() {
            bail!("inline `{}` expects {} args, got {}", name, def.params.len(), args.len());
        }
        if self.inline_depth > 16 {
            bail!("inline expansion too deep (recursive inline `{}`?)", name);
        }
        let map: HashMap<String, PExpr> = def
            .params
            .iter()
            .cloned()
            .zip(args.iter().cloned())
            .collect();
        Ok(subst_stmts(&def.body, &map))
    }

    fn emit(&mut self, op: Op) -> u32 {
        self.code.push(Instr { op, next: NO_PC, atomic_next: false });
        (self.code.len() - 1) as u32
    }

    fn patch(&mut self, locs: &[u32], target: u32) {
        for &l in locs {
            debug_assert_eq!(self.code[l as usize].next, NO_PC);
            self.code[l as usize].next = target;
        }
    }

    /// Emit a statement sequence; returns (entry pc, dangling exits).
    fn emit_seq(&mut self, stmts: &[Stmt]) -> Result<(Option<u32>, Vec<u32>)> {
        let mut entry: Option<u32> = None;
        let mut exits: Vec<u32> = Vec::new();
        for s in stmts {
            let (e, x) = self.emit_stmt(s)?;
            if let Some(e) = e {
                self.patch(&exits, e);
                exits = x;
                entry.get_or_insert(e);
            } else {
                debug_assert!(x.is_empty());
            }
        }
        Ok((entry, exits))
    }

    /// Like emit_seq but guarantees an entry (inserts `skip` when the
    /// sequence emits nothing) — needed for branch option targets.
    fn emit_seq_entry(&mut self, stmts: &[Stmt]) -> Result<(u32, Vec<u32>)> {
        let (e, x) = self.emit_seq(stmts)?;
        match e {
            Some(e) => Ok((e, x)),
            None => {
                let pc = self.emit(Op::Guard(CExpr::Num(1)));
                Ok((pc, vec![pc]))
            }
        }
    }

    fn emit_stmt(&mut self, s: &Stmt) -> Result<(Option<u32>, Vec<u32>)> {
        match s {
            Stmt::VarDecl(d) => {
                // slot already allocated by prealloc; init emits an assign
                match &d.init {
                    None => Ok((None, Vec::new())),
                    Some(e) => {
                        let lv = self.lval(&LValue::Var(d.name.clone()))?;
                        let ce = self.expr(e)?;
                        let pc = self.emit(Op::Assign(lv, ce));
                        Ok((Some(pc), vec![pc]))
                    }
                }
            }
            Stmt::ChanDecl(c) => {
                let lv = self.lval(&LValue::Var(c.name.clone()))?;
                let pc = self.emit(Op::NewChan(lv, c.capacity as u16, c.arity as u16));
                Ok((Some(pc), vec![pc]))
            }
            Stmt::Assign(lv, e) => {
                let lv = self.lval(lv)?;
                let ce = self.expr(e)?;
                let pc = self.emit(Op::Assign(lv, ce));
                Ok((Some(pc), vec![pc]))
            }
            Stmt::Inc(lv) | Stmt::Dec(lv) => {
                let clv = self.lval(lv)?;
                let load = match &clv {
                    CLVal::Scalar(s, _) => CExpr::Load(*s),
                    CLVal::Elem(s, n, i, _) => CExpr::LoadElem(*s, *n, Box::new(i.clone())),
                };
                let op = if matches!(s, Stmt::Inc(_)) { PBinOp::Add } else { PBinOp::Sub };
                let pc = self.emit(Op::Assign(
                    clv,
                    CExpr::Bin(op, Box::new(load), Box::new(CExpr::Num(1))),
                ));
                Ok((Some(pc), vec![pc]))
            }
            Stmt::ExprStmt(e) => {
                let ce = self.expr(e)?;
                let pc = self.emit(Op::Guard(ce));
                Ok((Some(pc), vec![pc]))
            }
            Stmt::Skip => {
                let pc = self.emit(Op::Guard(CExpr::Num(1)));
                Ok((Some(pc), vec![pc]))
            }
            Stmt::Send(chan, args) => {
                let c = self.chan_expr(chan)?;
                let mut es = Vec::new();
                for a in args {
                    es.push(self.expr(a)?);
                }
                let pc = self.emit(Op::Send(c, es));
                Ok((Some(pc), vec![pc]))
            }
            Stmt::Recv(chan, args) => {
                let c = self.chan_expr(chan)?;
                let mut rs = Vec::new();
                for a in args {
                    rs.push(match a {
                        RecvArg::Bind(lv) => CRecvArg::Bind(self.lval(lv)?),
                        RecvArg::Match(e) => CRecvArg::Match(self.expr(e)?),
                    });
                }
                let pc = self.emit(Op::Recv(c, rs));
                Ok((Some(pc), vec![pc]))
            }
            Stmt::Run(name, args) => {
                let pid = *self
                    .proc_ids
                    .get(name)
                    .ok_or_else(|| anyhow!("run of unknown proctype `{}`", name))?;
                let mut es = Vec::new();
                for a in args {
                    es.push(self.expr(a)?);
                }
                let pc = self.emit(Op::Run(pid, es));
                Ok((Some(pc), vec![pc]))
            }
            Stmt::InlineCall(name, args) => {
                let body = self.expand_inline(name, args)?;
                self.inline_depth += 1;
                let r = self.emit_seq(&body);
                self.inline_depth -= 1;
                r
            }
            Stmt::Atomic(body) => {
                let lo = self.code.len();
                let (e, x) = self.emit_seq(body)?;
                let hi = self.code.len();
                // everything inside keeps exclusivity...
                for pc in lo..hi {
                    self.code[pc].atomic_next = true;
                }
                // ...except the dangling exits (they leave the block)
                for &pc in &x {
                    self.code[pc as usize].atomic_next = false;
                }
                Ok((e, x))
            }
            Stmt::Select(v, lo, hi) => {
                let lv = self.lval(&LValue::Var(v.clone()))?;
                let lo = self.expr(lo)?;
                let hi = self.expr(hi)?;
                let pc = self.emit(Op::Select(lv, lo, hi));
                Ok((Some(pc), vec![pc]))
            }
            Stmt::If(opts, els) => {
                let bpc = self.emit(Op::Branch(Vec::new(), None));
                let mut targets = Vec::new();
                let mut exits = Vec::new();
                for o in opts {
                    let (e, x) = self.emit_seq_entry(o)?;
                    targets.push(e);
                    exits.extend(x);
                }
                let else_t = match els {
                    None => None,
                    Some(o) => {
                        let (e, x) = self.emit_seq_entry(o)?;
                        exits.extend(x);
                        Some(e)
                    }
                };
                self.code[bpc as usize].op = Op::Branch(targets, else_t);
                Ok((Some(bpc), exits))
            }
            Stmt::Do(opts, els) => {
                let bpc = self.emit(Op::Branch(Vec::new(), None));
                self.break_stack.push(Vec::new());
                let mut targets = Vec::new();
                for o in opts {
                    let (e, x) = self.emit_seq_entry(o)?;
                    targets.push(e);
                    self.patch(&x, bpc); // loop back
                }
                let else_t = match els {
                    None => None,
                    Some(o) => {
                        let (e, x) = self.emit_seq_entry(o)?;
                        self.patch(&x, bpc);
                        Some(e)
                    }
                };
                self.code[bpc as usize].op = Op::Branch(targets, else_t);
                let breaks = self.break_stack.pop().unwrap();
                Ok((Some(bpc), breaks))
            }
            Stmt::For(v, lo, hi, body) => {
                // i = lo; L: Branch([i<=hi -> body; i++ -> L], else -> exit)
                let lv = self.lval(&LValue::Var(v.clone()))?;
                let clo = self.expr(lo)?;
                let init_pc = self.emit(Op::Assign(lv.clone(), clo));
                let bpc = self.emit(Op::Branch(Vec::new(), None));
                self.code[init_pc as usize].next = bpc;
                self.break_stack.push(Vec::new());
                let chi = self.expr(hi)?;
                let load = match &lv {
                    CLVal::Scalar(s, _) => CExpr::Load(*s),
                    CLVal::Elem(..) => bail!("for-loop variable must be scalar"),
                };
                let guard_pc =
                    self.emit(Op::Guard(CExpr::Bin(PBinOp::Le, Box::new(load.clone()), Box::new(chi))));
                let (body_e, body_x) = self.emit_seq(body)?;
                let inc_pc = self.emit(Op::Assign(
                    lv,
                    CExpr::Bin(PBinOp::Add, Box::new(load), Box::new(CExpr::Num(1))),
                ));
                self.code[inc_pc as usize].next = bpc;
                match body_e {
                    Some(e) => {
                        self.code[guard_pc as usize].next = e;
                        self.patch(&body_x, inc_pc);
                    }
                    None => self.code[guard_pc as usize].next = inc_pc,
                }
                // else exit of the loop dangles
                let exit_guard = self.emit(Op::Guard(CExpr::Num(1)));
                self.code[bpc as usize].op = Op::Branch(vec![guard_pc], Some(exit_guard));
                let mut exits = vec![exit_guard];
                exits.extend(self.break_stack.pop().unwrap());
                Ok((Some(init_pc), exits))
            }
            Stmt::Break => {
                let frame = self
                    .break_stack
                    .last_mut()
                    .ok_or_else(|| anyhow!("break outside of do/for"))?;
                let pc = self.code.len() as u32;
                frame.push(pc);
                self.emit(Op::Guard(CExpr::Num(1)));
                Ok((Some(pc), Vec::new())) // exit patched via break frame
            }
        }
    }

    // ------------------------------------------------------------- names --

    fn lookup(&self, name: &str) -> Result<(Slot, u32, VarType)> {
        if let Some(v) = self.local_syms.get(name) {
            return Ok((Slot::Local(v.offset), v.len, v.ty));
        }
        if let Some(v) = self.global_syms.get(name) {
            return Ok((Slot::Global(v.offset), v.len, v.ty));
        }
        bail!("unknown identifier `{}`", name)
    }

    fn lval(&mut self, lv: &LValue) -> Result<CLVal> {
        match lv {
            LValue::Var(n) => {
                let (slot, len, ty) = self.lookup(n)?;
                if len != 1 {
                    bail!("array `{}` used without index", n);
                }
                Ok(CLVal::Scalar(slot, ty))
            }
            LValue::Index(n, e) => {
                let (slot, len, ty) = self.lookup(n)?;
                if len == 1 {
                    bail!("`{}` is not an array", n);
                }
                Ok(CLVal::Elem(slot, len, self.expr(e)?, ty))
            }
        }
    }

    fn chan_expr(&mut self, name: &str) -> Result<CExpr> {
        if let Some(id) = self.global_chan_ids.get(name) {
            return Ok(CExpr::Num(*id));
        }
        let (slot, len, _) = self.lookup(name)?;
        if len != 1 {
            bail!("channel `{}` cannot be an array", name);
        }
        Ok(CExpr::Load(slot))
    }

    fn expr(&mut self, e: &PExpr) -> Result<CExpr> {
        Ok(match e {
            PExpr::Num(n) => CExpr::Num(*n as i32),
            PExpr::Var(n) => {
                // mtype constant?
                if let Some(i) = self.mtypes.iter().position(|m| m == n) {
                    return Ok(CExpr::Num(i as i32 + 1));
                }
                if let Some(id) = self.global_chan_ids.get(n) {
                    return Ok(CExpr::Num(*id));
                }
                let (slot, len, _) = self.lookup(n)?;
                if len != 1 {
                    bail!("array `{}` used as scalar", n);
                }
                CExpr::Load(slot)
            }
            PExpr::Index(n, i) => {
                let (slot, len, _) = self.lookup(n)?;
                if len == 1 {
                    bail!("`{}` is not an array", n);
                }
                CExpr::LoadElem(slot, len, Box::new(self.expr(i)?))
            }
            PExpr::Unary(op, a) => CExpr::Un(*op, Box::new(self.expr(a)?)),
            PExpr::Bin(op, a, b) => {
                CExpr::Bin(*op, Box::new(self.expr(a)?), Box::new(self.expr(b)?))
            }
            PExpr::Cond(c, a, b) => CExpr::Cond(
                Box::new(self.expr(c)?),
                Box::new(self.expr(a)?),
                Box::new(self.expr(b)?),
            ),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::promela::parser::parse;

    fn compile_src(src: &str) -> Result<Program> {
        compile(&parse(src)?)
    }

    #[test]
    fn compiles_globals_with_const_inits() {
        let p = compile_src("int time = 5; byte a[3]; active proctype main() { skip }").unwrap();
        assert_eq!(p.globals_init, vec![5, 0, 0, 0]);
        assert_eq!(p.global_syms["a"].len, 3);
        assert_eq!(p.active, vec![0]);
    }

    #[test]
    fn rejects_nonconst_global_init() {
        assert!(compile_src("int a = 1; int b = a; active proctype main() { skip }").is_err());
    }

    #[test]
    fn global_inits_truncate_to_declared_width() {
        // SPIN semantics: byte wraps at 256, short at 2^15, bool keeps a bit
        let p = compile_src(
            "byte b = 300; short s = 40000; bool f = 2; int i = 70000;\n\
             active proctype main() { skip }",
        )
        .unwrap();
        assert_eq!(p.globals_init, vec![300 & 0xFF, 40000u16 as i16 as i32, 0, 70000]);
        assert_eq!(p.global_syms["b"].ty, VarType::Byte);
        assert_eq!(p.global_syms["s"].ty, VarType::Short);
        assert_eq!(p.global_syms["f"].ty, VarType::Bit);
        assert_eq!(p.global_syms["i"].ty, VarType::Int);
    }

    #[test]
    fn param_types_recorded_for_run_truncation() {
        let p = compile_src(
            "proctype w(byte v; short u) { skip }\nactive proctype main() { run w(300, 1) }",
        )
        .unwrap();
        assert_eq!(p.procs[0].param_types, vec![VarType::Byte, VarType::Short]);
    }

    #[test]
    fn do_loop_wires_back_edges() {
        let p = compile_src(
            "int i; active proctype main() { do :: i < 3 -> i++ :: else -> break od }",
        )
        .unwrap();
        let code = &p.procs[0].code;
        // find the Branch
        let bpos = code.iter().position(|i| matches!(i.op, Op::Branch(..))).unwrap();
        match &code[bpos].op {
            Op::Branch(opts, els) => {
                assert_eq!(opts.len(), 1);
                assert!(els.is_some());
            }
            _ => unreachable!(),
        }
        // the i++ instr loops back to the branch
        let inc = code
            .iter()
            .find(|i| matches!(&i.op, Op::Assign(_, CExpr::Bin(PBinOp::Add, _, _))))
            .unwrap();
        assert_eq!(inc.next, bpos as u32);
        // everything threads somewhere (no dangling NO_PC except Halt)
        for (i, ins) in code.iter().enumerate() {
            if !matches!(ins.op, Op::Halt | Op::Branch(..)) {
                assert_ne!(ins.next, NO_PC, "instr {} dangles: {:?}", i, ins.op);
            }
        }
    }

    #[test]
    fn atomic_marks_inner_instrs() {
        let p = compile_src("int a, b; active proctype main() { atomic { a = 1; b = 2 }; a = 3 }")
            .unwrap();
        let code = &p.procs[0].code;
        let assigns: Vec<&Instr> = code
            .iter()
            .filter(|i| matches!(i.op, Op::Assign(..)))
            .collect();
        assert_eq!(assigns.len(), 3);
        assert!(assigns[0].atomic_next, "first atomic instr keeps exclusivity");
        assert!(!assigns[1].atomic_next, "last atomic instr releases");
        assert!(!assigns[2].atomic_next);
    }

    #[test]
    fn inline_expansion_inlines_body() {
        let p = compile_src(
            "int time; inline work(gt) { time = time + gt }\n\
             active proctype main() { work(5); work(7) }",
        )
        .unwrap();
        let code = &p.procs[0].code;
        let adds: Vec<i32> = code
            .iter()
            .filter_map(|i| match &i.op {
                Op::Assign(_, CExpr::Bin(PBinOp::Add, _, b)) => match **b {
                    CExpr::Num(n) => Some(n),
                    _ => None,
                },
                _ => None,
            })
            .collect();
        assert_eq!(adds, vec![5, 7]);
    }

    #[test]
    fn mtype_constants_resolve() {
        let p = compile_src(
            "mtype = {go, stop};\nint x;\nactive proctype main() { x = stop }",
        )
        .unwrap();
        let code = &p.procs[0].code;
        assert!(code
            .iter()
            .any(|i| matches!(&i.op, Op::Assign(_, CExpr::Num(2)))));
    }

    #[test]
    fn unknown_identifier_rejected() {
        assert!(compile_src("active proctype main() { x = 1 }").is_err());
        assert!(compile_src("active proctype main() { nosuch(3) }").is_err());
    }

    #[test]
    fn break_outside_loop_rejected() {
        assert!(compile_src("active proctype main() { break }").is_err());
    }

    #[test]
    fn run_resolves_proctype() {
        let p = compile_src(
            "proctype w(byte i) { skip }\nactive proctype main() { run w(3) }",
        )
        .unwrap();
        assert!(p.procs[1]
            .code
            .iter()
            .any(|i| matches!(&i.op, Op::Run(0, args) if args.len() == 1)));
    }
}
