//! Static effect analysis over compiled process automata — the one pass
//! behind `mcautotune lint`, `--reduce dead-slots` and `--por`.
//!
//! SPIN ships two classic static reductions our engines historically
//! lacked: dead-variable elimination and partial-order reduction. Both
//! need the same raw material — per-instruction **effect sets** (which
//! slots an [`Op`] reads/writes, which channels it touches, whether it
//! spawns or allocates) — which the flat slot layout of stage-one
//! [`Program`]s makes cheap to compute. From those sets this module
//! derives three artifacts:
//!
//! 1. **Slot liveness** per (proctype, pc): a backward worklist fixpoint
//!    over each automaton. A local slot is *dead* at a pc when every path
//!    from that pc overwrites it before reading it. Both engines use the
//!    table (opt-in, `--reduce dead-slots`) to canonicalize dead slots to
//!    zero in `encode`, so states differing only in dead local garbage
//!    hash identically: `states_stored` can only shrink, and verdicts,
//!    optima, trails and per-state semantics are untouched (raw states are
//!    never rewritten — only their hashed image is).
//!
//! 2. **POR eligibility + independence**: [`independent`] is the static
//!    conflict relation between transitions of *different* processes
//!    (disjoint global read/write footprints, disjoint static channel
//!    sets, no spawns/allocs/dynamic channel handles). A pc is
//!    *ample-eligible* ([`Analysis::por_safe`]) when every op reachable
//!    within one observable transition from it is invisible (touches only
//!    the process's own locals — or, channel-aware: is a send/receive on
//!    a buffered channel whose send/receive role is *exclusive* to this
//!    single-instance proctype, see [`exclusive_channel_roles`]), never
//!    enters an `atomic` block (a blocked
//!    chain would leave exclusivity set and restrict other processes),
//!    and only moves the pc strictly forward. Forward-only edges give the
//!    cycle proviso (C3) for free: any cycle in the reduced graph must
//!    take some process's back edge, and back-edge sources are always
//!    fully expanded. Invisibility gives C2 for the whole supported
//!    property fragment — `SafetyLtl` is `G(expr)` over globals, so
//!    local-only transitions are stutter steps. C0/C1 are checked at
//!    selection time (non-empty ample set, first eligible alive process).
//!    Safety-only: we make no liveness/acceptance-cycle claims.
//!
//! 3. **Diagnostics** ([`diagnostics`]): unused/dead locals, dead stores,
//!    statically-false or duplicate option guards, unreachable channel
//!    capacity, write-only globals, and declared-but-never-assigned WG/TS
//!    tuning slots. `warn`-severity findings gate CI via
//!    `mcautotune lint --deny`; `info` findings (e.g. write-only globals,
//!    which are usually observables read by properties or reports) never
//!    fail the gate. [`lint_json`] renders diagnostics plus a static
//!    feature summary (op-site counts, POR-eligible pc density, …) as a
//!    `util::manifest` JSON document; [`validate_lint_json`] is the
//!    schema check downstream tools — and the future surrogate-guided
//!    search, which wants exactly these features — can rely on.

use super::compile::{CExpr, CLVal, CRecvArg, Instr, Op, Program, Slot, NO_PC};
use crate::util::error::{bail, Result};
use crate::util::manifest::Json;

// ---------------------------------------------------------------- sets --

/// Dense bitset over slot (or pc) indices; grows on demand.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SlotSet {
    words: Vec<u64>,
}

impl SlotSet {
    pub fn new() -> SlotSet {
        SlotSet::default()
    }

    /// Insert `i`; true when it was not already present.
    pub fn insert(&mut self, i: u32) -> bool {
        let (w, b) = (i as usize / 64, i as usize % 64);
        if w >= self.words.len() {
            self.words.resize(w + 1, 0);
        }
        let fresh = self.words[w] & (1 << b) == 0;
        self.words[w] |= 1 << b;
        fresh
    }

    pub fn insert_range(&mut self, start: u32, len: u32) {
        for i in start..start + len {
            self.insert(i);
        }
    }

    pub fn contains(&self, i: u32) -> bool {
        let (w, b) = (i as usize / 64, i as usize % 64);
        self.words.get(w).is_some_and(|x| x & (1 << b) != 0)
    }

    /// Union `other` in; true when any bit was added.
    pub fn union_with(&mut self, other: &SlotSet) -> bool {
        if other.words.len() > self.words.len() {
            self.words.resize(other.words.len(), 0);
        }
        let mut changed = false;
        for (w, o) in self.words.iter_mut().zip(&other.words) {
            let n = *w | o;
            changed |= n != *w;
            *w = n;
        }
        changed
    }

    /// Remove every bit present in `other`.
    pub fn subtract(&mut self, other: &SlotSet) {
        for (w, o) in self.words.iter_mut().zip(&other.words) {
            *w &= !o;
        }
    }

    pub fn intersects(&self, other: &SlotSet) -> bool {
        self.words.iter().zip(&other.words).any(|(a, b)| a & b != 0)
    }

    pub fn is_empty(&self) -> bool {
        self.words.iter().all(|w| *w == 0)
    }

    pub fn count(&self) -> u32 {
        self.words.iter().map(|w| w.count_ones()).sum()
    }

    pub fn iter(&self) -> impl Iterator<Item = u32> + '_ {
        self.words.iter().enumerate().flat_map(|(wi, &w)| {
            (0..64u32).filter(move |&b| (w >> b) & 1 != 0).map(move |b| wi as u32 * 64 + b)
        })
    }
}

// ------------------------------------------------------------- effects --

/// Static read/write footprint of one [`Op`]. Local slots are private to
/// the owning process (rendezvous receive binds are modeled as the
/// *receiver's* effect), so only the global/channel components matter for
/// cross-process independence.
#[derive(Debug, Clone, Default)]
pub struct Effects {
    pub global_reads: SlotSet,
    pub global_writes: SlotSet,
    pub local_reads: SlotSet,
    pub local_writes: SlotSet,
    /// local slots definitely overwritten (strong kill for liveness)
    pub local_kills: SlotSet,
    /// statically-known channel ids touched (compile folds global channel
    /// names to `CExpr::Num(id)`); always the union of `chan_sends` and
    /// `chan_recvs`
    pub chans: SlotSet,
    /// statically-known channel ids this op sends on
    pub chan_sends: SlotSet,
    /// statically-known channel ids this op receives from
    pub chan_recvs: SlotSet,
    /// channel op through a non-constant handle (local `chan` variables)
    pub chan_dynamic: bool,
    pub spawns: bool,
    /// allocates a channel — id depends on allocation order
    pub allocs: bool,
    pub halts: bool,
}

fn read_expr(e: &CExpr, eff: &mut Effects) {
    match e {
        CExpr::Num(_) => {}
        CExpr::Load(s) => read_slot(*s, 1, eff),
        CExpr::LoadElem(s, len, idx) => {
            read_expr(idx, eff);
            // constant in-range index reads exactly one cell
            if let CExpr::Num(k) = **idx {
                if k >= 0 && (k as u32) < *len {
                    read_slot(offset_slot(*s, k as u32), 1, eff);
                    return;
                }
            }
            read_slot(*s, *len, eff);
        }
        CExpr::Un(_, a) => read_expr(a, eff),
        CExpr::Bin(_, a, b) => {
            read_expr(a, eff);
            read_expr(b, eff);
        }
        CExpr::Cond(c, t, f) => {
            read_expr(c, eff);
            read_expr(t, eff);
            read_expr(f, eff);
        }
    }
}

fn offset_slot(s: Slot, k: u32) -> Slot {
    match s {
        Slot::Global(b) => Slot::Global(b + k),
        Slot::Local(b) => Slot::Local(b + k),
    }
}

fn read_slot(s: Slot, len: u32, eff: &mut Effects) {
    match s {
        Slot::Global(b) => eff.global_reads.insert_range(b, len),
        Slot::Local(b) => eff.local_reads.insert_range(b, len),
    }
}

/// Record a write through `lv`: index expressions are reads; constant
/// in-range element indices (and scalars) are strong kills.
fn write_lval(lv: &CLVal, eff: &mut Effects) {
    match lv {
        CLVal::Scalar(s, _) => match *s {
            Slot::Global(b) => {
                eff.global_writes.insert(b);
            }
            Slot::Local(b) => {
                eff.local_writes.insert(b);
                eff.local_kills.insert(b);
            }
        },
        CLVal::Elem(s, len, idx, _) => {
            read_expr(idx, eff);
            if let CExpr::Num(k) = idx {
                if *k >= 0 && (*k as u32) < *len {
                    match offset_slot(*s, *k as u32) {
                        Slot::Global(b) => {
                            eff.global_writes.insert(b);
                        }
                        Slot::Local(b) => {
                            eff.local_writes.insert(b);
                            eff.local_kills.insert(b);
                        }
                    }
                    return;
                }
            }
            // dynamic index: may write any cell, kills none
            match *s {
                Slot::Global(b) => eff.global_writes.insert_range(b, *len),
                Slot::Local(b) => eff.local_writes.insert_range(b, *len),
            }
        }
    }
}

fn chan_effect(c: &CExpr, eff: &mut Effects) {
    read_expr(c, eff);
    match c {
        CExpr::Num(id) if *id >= 0 => {
            eff.chans.insert(*id as u32);
        }
        _ => eff.chan_dynamic = true,
    }
}

/// Effect set of a single op (pure syntax-directed; no context needed).
pub fn op_effects(op: &Op) -> Effects {
    let mut eff = Effects::default();
    match op {
        Op::Guard(e) => read_expr(e, &mut eff),
        Op::Assign(lv, e) => {
            read_expr(e, &mut eff);
            write_lval(lv, &mut eff);
        }
        Op::Send(c, args) => {
            chan_effect(c, &mut eff);
            if let CExpr::Num(id) = c {
                if *id >= 0 {
                    eff.chan_sends.insert(*id as u32);
                }
            }
            for a in args {
                read_expr(a, &mut eff);
            }
        }
        Op::Recv(c, args) => {
            chan_effect(c, &mut eff);
            if let CExpr::Num(id) = c {
                if *id >= 0 {
                    eff.chan_recvs.insert(*id as u32);
                }
            }
            for a in args {
                match a {
                    CRecvArg::Bind(lv) => write_lval(lv, &mut eff),
                    CRecvArg::Match(e) => read_expr(e, &mut eff),
                }
            }
        }
        Op::Select(lv, lo, hi) => {
            read_expr(lo, &mut eff);
            read_expr(hi, &mut eff);
            write_lval(lv, &mut eff);
        }
        Op::Branch(_, _) => {} // guards live at the option entry pcs
        Op::Run(_, args) => {
            eff.spawns = true;
            for a in args {
                read_expr(a, &mut eff);
            }
        }
        Op::NewChan(lv, _, _) => {
            eff.allocs = true;
            write_lval(lv, &mut eff);
        }
        Op::Halt => eff.halts = true,
    }
    eff
}

/// Static independence of two transitions owned by *different*
/// processes: they commute and neither enables/disables the other.
/// Locals are per-process private, so only globals, channels and
/// structural effects (spawn/alloc/halt) can conflict. Conservative:
/// any shared channel (even send vs. send) counts as a conflict — this
/// context-free relation cannot see channel capacities or per-channel
/// sender/receiver exclusivity. The channel-aware refinement (an
/// exclusive send and an exclusive receive on a *buffered* channel
/// commute) lives in [`Analysis`], which has the whole-program context
/// to discharge it; see [`ample_eligible`].
pub fn independent(a: &Effects, b: &Effects) -> bool {
    if a.spawns || b.spawns || a.allocs || b.allocs || a.halts || b.halts {
        return false;
    }
    if a.chan_dynamic || b.chan_dynamic || a.chans.intersects(&b.chans) {
        return false;
    }
    !a.global_writes.intersects(&b.global_writes)
        && !a.global_writes.intersects(&b.global_reads)
        && !a.global_reads.intersects(&b.global_writes)
}

// ------------------------------------------------------------ analysis --

/// Precomputed static tables for one [`Program`]: per-op effects, slot
/// liveness per (proctype, pc) and POR ample-eligibility per
/// (proctype, pc). Built once (the engines cache it lazily) — lookups on
/// the exploration hot path are a bitset probe.
#[derive(Debug)]
pub struct Analysis {
    /// per (proctype, pc): effect set of the op at that pc
    pub effects: Vec<Vec<Effects>>,
    /// per (proctype, pc): local slots live *entering* that pc
    live: Vec<Vec<SlotSet>>,
    /// per (proctype, pc): pc is ample-eligible for POR
    safe: Vec<Vec<bool>>,
    /// per global channel id: the only proctype that can ever send on it
    /// (None when senders are plural/dynamic, or that proctype can have
    /// more than one instance) — see [`exclusive_channel_roles`]
    excl_sender: Vec<Option<u32>>,
    /// per global channel id: the only proctype that can ever receive
    excl_recver: Vec<Option<u32>>,
}

impl Analysis {
    pub fn of(prog: &Program) -> Analysis {
        let effects: Vec<Vec<Effects>> =
            prog.procs.iter().map(|p| p.code.iter().map(|i| op_effects(&i.op)).collect()).collect();
        let live = prog
            .procs
            .iter()
            .zip(&effects)
            .map(|(p, eff)| liveness(&p.code, eff))
            .collect();
        let (excl_sender, excl_recver) = exclusive_channel_roles(prog, &effects);
        let safe = prog
            .procs
            .iter()
            .zip(&effects)
            .enumerate()
            .map(|(pi, (p, eff))| {
                let ctx = ChanCtx {
                    caps: &prog.global_chans,
                    excl_sender: &excl_sender,
                    excl_recver: &excl_recver,
                    ptype: pi as u32,
                };
                (0..p.code.len() as u32)
                    .map(|pc| ample_eligible(&p.code, eff, pc, &ctx))
                    .collect()
            })
            .collect();
        Analysis { effects, live, safe, excl_sender, excl_recver }
    }

    /// The single proctype allowed to send on global channel `cid`, when
    /// sender-exclusivity holds (exposed for lint features and tests).
    pub fn exclusive_sender(&self, cid: u32) -> Option<u32> {
        self.excl_sender.get(cid as usize).copied().flatten()
    }

    /// The single proctype allowed to receive on global channel `cid`.
    pub fn exclusive_recver(&self, cid: u32) -> Option<u32> {
        self.excl_recver.get(cid as usize).copied().flatten()
    }

    /// Local slots live when `ptype` is at `pc` (dead slots may be
    /// canonicalized away before hashing).
    pub fn live_at(&self, ptype: usize, pc: u32) -> &SlotSet {
        &self.live[ptype][pc as usize]
    }

    pub fn slot_dead_at(&self, ptype: usize, pc: u32, slot: u32) -> bool {
        !self.live_at(ptype, pc).contains(slot)
    }

    /// All transitions from `pc` are invisible, strictly forward, and
    /// either local-only or exclusive buffered channel ops — a process
    /// resting here may serve as a singleton ample set.
    pub fn por_safe(&self, ptype: usize, pc: u32) -> bool {
        self.safe.get(ptype).and_then(|s| s.get(pc as usize)).copied().unwrap_or(false)
    }
}

/// Per-channel sender/receiver exclusivity: channel `c`'s send (receive)
/// role is *exclusive* when every static send (receive) site on `c` lives
/// in one proctype, that proctype has exactly one instance for the whole
/// run (exactly one `active` entry and no `run` site anywhere — spawns
/// would multiply it), and no dynamic-handle send (receive) exists in the
/// program (a dynamic handle could alias any channel id). Exclusivity is
/// what lets a buffered send commute with every transition of every other
/// process: no other process can alter the channel's tail (resp. head) or
/// disable the op — see [`ample_eligible`].
fn exclusive_channel_roles(
    prog: &Program,
    effects: &[Vec<Effects>],
) -> (Vec<Option<u32>>, Vec<Option<u32>>) {
    #[derive(Clone, Copy, PartialEq)]
    enum Role {
        NoSite,
        One(u32),
        Many,
    }
    fn claim(roles: &mut [Role], cid: u32, ptype: u32) {
        let r = &mut roles[cid as usize];
        *r = match *r {
            Role::NoSite => Role::One(ptype),
            Role::One(p) if p == ptype => Role::One(p),
            _ => Role::Many,
        };
    }

    let nchans = prog.global_chans.len();
    // instance count per proctype: initial actives, poisoned by any
    // `run` site (each execution spawns another instance)
    let mut instances = vec![0usize; prog.procs.len()];
    for &pt in &prog.active {
        if let Some(c) = instances.get_mut(pt as usize) {
            *c += 1;
        }
    }
    for p in &prog.procs {
        for ins in &p.code {
            if let Op::Run(pt, _) = &ins.op {
                if let Some(c) = instances.get_mut(*pt as usize) {
                    *c = usize::MAX;
                }
            }
        }
    }
    let single: Vec<bool> = instances.iter().map(|&c| c == 1).collect();

    let mut senders = vec![Role::NoSite; nchans];
    let mut recvers = vec![Role::NoSite; nchans];
    let (mut dyn_send, mut dyn_recv) = (false, false);
    for (pi, p) in prog.procs.iter().enumerate() {
        for (pc, ins) in p.code.iter().enumerate() {
            let eff = &effects[pi][pc];
            match ins.op {
                Op::Send(_, _) => {
                    dyn_send |= eff.chan_dynamic;
                    for cid in eff.chan_sends.iter() {
                        if (cid as usize) < nchans {
                            claim(&mut senders, cid, pi as u32);
                        }
                    }
                }
                Op::Recv(_, _) => {
                    dyn_recv |= eff.chan_dynamic;
                    for cid in eff.chan_recvs.iter() {
                        if (cid as usize) < nchans {
                            claim(&mut recvers, cid, pi as u32);
                        }
                    }
                }
                _ => {}
            }
        }
    }
    let resolve = |roles: &[Role], poisoned: bool| -> Vec<Option<u32>> {
        roles
            .iter()
            .map(|r| match *r {
                Role::One(pt) if !poisoned && single[pt as usize] => Some(pt),
                _ => None,
            })
            .collect()
    };
    (resolve(&senders, dyn_send), resolve(&recvers, dyn_recv))
}

/// Whole-program channel context threaded into [`ample_eligible`].
struct ChanCtx<'a> {
    /// (capacity, arity) per global channel id
    caps: &'a [(u16, u16)],
    excl_sender: &'a [Option<u32>],
    excl_recver: &'a [Option<u32>],
    /// proctype whose automaton is being analyzed
    ptype: u32,
}

/// Execution successors of the instruction at `pc` (pc-level control
/// flow; `Branch` targets are option/else entries).
fn succs(code: &[Instr], pc: u32, out: &mut Vec<u32>) {
    out.clear();
    let ins = &code[pc as usize];
    match &ins.op {
        Op::Branch(opts, els) => {
            out.extend(opts.iter().chain(els.iter()).copied().filter(|&t| t != NO_PC));
        }
        Op::Halt => {}
        _ => {
            if ins.next != NO_PC {
                out.push(ins.next);
            }
        }
    }
}

/// Backward may-liveness fixpoint over one automaton:
/// `live_in(pc) = use(pc) ∪ (∪ live_in(succ) \ kill(pc))`.
fn liveness(code: &[Instr], eff: &[Effects]) -> Vec<SlotSet> {
    let n = code.len();
    let mut live: Vec<SlotSet> = vec![SlotSet::new(); n];
    let mut sbuf = Vec::new();
    loop {
        let mut changed = false;
        for pc in (0..n as u32).rev() {
            succs(code, pc, &mut sbuf);
            let mut out = SlotSet::new();
            for &s in &sbuf {
                out.union_with(&live[s as usize]);
            }
            out.subtract(&eff[pc as usize].local_kills);
            out.union_with(&eff[pc as usize].local_reads);
            changed |= live[pc as usize].union_with(&out);
        }
        if !changed {
            return live;
        }
    }
}

/// Ample-eligibility of the transitions leaving `pc`: walk every op a
/// single observable transition from `pc` can execute (Branch recurses
/// into its option guards; other ops end the transition at `next`) and
/// require each to be invisible, non-atomic, strictly forward-branching,
/// and either local-only or an *exclusive buffered channel op*. See the
/// module docs for why each clause is load-bearing for the C1–C3
/// provisos.
///
/// The channel arm: a send (receive) on a single statically-known
/// *buffered* channel qualifies when this proctype is the channel's
/// exclusive sender (receiver) per [`exclusive_channel_roles`] and the op
/// touches no globals. Soundness: whenever the op and any transition `t`
/// of another process are co-enabled, they commute — `t` can only be a
/// receive (resp. send) on the same channel by exclusivity, co-enabledness
/// forces `1 <= qlen < cap`, and appending at the tail commutes with
/// removing the unchanged head — and neither ever disables the other (a
/// receive only frees send capacity; a send only provides receive data).
/// Channel state is invisible to `SafetyLtl` (properties read globals
/// only), so C2 holds; `next > pc` keeps C3; rendezvous (cap 0) is
/// excluded because it couples two processes in a single step.
fn ample_eligible(code: &[Instr], eff: &[Effects], pc: u32, ctx: &ChanCtx<'_>) -> bool {
    let mut stack = vec![pc];
    let mut seen = SlotSet::new();
    while let Some(v) = stack.pop() {
        if !seen.insert(v) {
            continue;
        }
        let ins = &code[v as usize];
        match &ins.op {
            Op::Branch(opts, els) => {
                for &t in opts.iter().chain(els.iter()) {
                    if t == NO_PC || t <= v {
                        return false;
                    }
                    stack.push(t);
                }
            }
            Op::Guard(_) | Op::Assign(_, _) | Op::Select(_, _, _) => {
                let e = &eff[v as usize];
                if !e.global_reads.is_empty()
                    || !e.global_writes.is_empty()
                    || !e.chans.is_empty()
                    || e.chan_dynamic
                    || ins.atomic_next
                    || ins.next == NO_PC
                    || ins.next <= v
                {
                    return false;
                }
                // landing on Halt inside the transition only flips this
                // process's own alive bit — local and invisible
            }
            Op::Send(_, _) | Op::Recv(_, _) => {
                let e = &eff[v as usize];
                let (ids, excl) = if matches!(ins.op, Op::Send(_, _)) {
                    (&e.chan_sends, ctx.excl_sender)
                } else {
                    (&e.chan_recvs, ctx.excl_recver)
                };
                if e.chan_dynamic
                    || !e.global_reads.is_empty()
                    || !e.global_writes.is_empty()
                    || ins.atomic_next
                    || ins.next == NO_PC
                    || ins.next <= v
                    || ids.count() != 1
                {
                    return false;
                }
                let cid = ids.iter().next().expect("count checked") as usize;
                if ctx.caps.get(cid).is_none_or(|&(cap, _)| cap == 0) {
                    return false; // rendezvous or out-of-range handle
                }
                if excl.get(cid).copied().flatten() != Some(ctx.ptype) {
                    return false;
                }
            }
            // Run/NewChan mutate shared structure (process table, channel
            // ids); Halt as the *resting* op would shrink the process set
            // mid-reduction
            _ => return false,
        }
    }
    true
}

// --------------------------------------------------------- diagnostics --

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    /// informational — never fails `lint --deny`
    Info,
    /// likely modeling mistake — fails `lint --deny`
    Warn,
}

impl Severity {
    pub fn label(self) -> &'static str {
        match self {
            Severity::Info => "info",
            Severity::Warn => "warn",
        }
    }
}

#[derive(Debug, Clone)]
pub struct Diag {
    pub severity: Severity,
    /// stable kebab-case finding id (schema-checked by `validate_lint_json`)
    pub category: &'static str,
    pub proc_name: Option<String>,
    pub pc: Option<u32>,
    pub message: String,
}

impl std::fmt::Display for Diag {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}[{}]", self.severity.label(), self.category)?;
        match (&self.proc_name, self.pc) {
            (Some(p), Some(pc)) => write!(f, " {}@{}", p, pc)?,
            (Some(p), None) => write!(f, " {}", p)?,
            _ => {}
        }
        write!(f, ": {}", self.message)
    }
}

/// Literal constant value of a stage-one expression. Stage one does not
/// fold, so this intentionally covers only bare literals — enough for the
/// classic `:: 0 -> ...` dead-option mistake without duplicating the
/// engines' evaluation semantics.
fn const_value(e: &CExpr) -> Option<i32> {
    match e {
        CExpr::Num(n) => Some(*n),
        _ => None,
    }
}

/// Can the instruction at `pc` re-execute (is it on a cycle of its own
/// automaton)? Used to tell one-shot send sites from repeatable ones.
fn on_cycle(code: &[Instr], pc: u32) -> bool {
    let mut stack = Vec::new();
    let mut seen = SlotSet::new();
    let mut sbuf = Vec::new();
    succs(code, pc, &mut sbuf);
    stack.extend_from_slice(&sbuf);
    while let Some(v) = stack.pop() {
        if v == pc {
            return true;
        }
        if !seen.insert(v) {
            continue;
        }
        succs(code, v, &mut sbuf);
        stack.extend_from_slice(&sbuf);
    }
    false
}

/// Tuning slots that `tune` explores. Assignability of these decides
/// whether a source spans a real (WG, TS) lattice.
const TUNING_SLOTS: [&str; 2] = ["WG", "TS"];

/// `Err` when the source declares neither assignment nor positive
/// initializer for a tuning variable, i.e. `tune` would explore a
/// degenerate lattice where every configuration verifies the same model.
pub fn require_tunable(prog: &Program) -> Result<()> {
    for name in TUNING_SLOTS {
        let Some(info) = prog.global_syms.get(name) else {
            bail!(
                "tuning variable `{}` is not declared — this source has no (WG, TS) \
                 lattice to tune (run `mcautotune verify` for plain model checking)",
                name
            );
        };
        if prog.globals_init[info.offset as usize] > 0 {
            continue;
        }
        let assigned = prog.procs.iter().any(|p| {
            p.code.iter().any(|i| {
                let eff = op_effects(&i.op);
                eff.global_writes.contains(info.offset)
            })
        });
        if !assigned {
            bail!(
                "tuning variable `{}` is never assigned — every (WG, TS) configuration \
                 would verify the same model (degenerate lattice); run `mcautotune lint` \
                 on the source for details",
                name
            );
        }
    }
    Ok(())
}

/// Static findings over a compiled program. Deterministic order:
/// program-level first, then per-proc in (proc, pc) order.
pub fn diagnostics(prog: &Program) -> Vec<Diag> {
    let analysis = Analysis::of(prog);
    let mut out = Vec::new();

    // global usage across all processes
    let mut greads = SlotSet::new();
    let mut gwrites = SlotSet::new();
    let mut send_sites: Vec<(usize, u32)> = Vec::new(); // (proc, pc) of Send ops
    for (pi, proc_eff) in analysis.effects.iter().enumerate() {
        for (pc, eff) in proc_eff.iter().enumerate() {
            greads.union_with(&eff.global_reads);
            gwrites.union_with(&eff.global_writes);
            if matches!(prog.procs[pi].code[pc].op, Op::Send(_, _)) {
                send_sites.push((pi, pc as u32));
            }
        }
    }

    // declared-but-never-assigned tuning slots (missing decls are not a
    // lint finding: arbitrary .pml sources need not be tuning models)
    for name in TUNING_SLOTS {
        if let Some(info) = prog.global_syms.get(name) {
            if prog.globals_init[info.offset as usize] <= 0 && !gwrites.contains(info.offset) {
                out.push(Diag {
                    severity: Severity::Warn,
                    category: "tuning-unassigned",
                    proc_name: None,
                    pc: None,
                    message: format!(
                        "tuning variable `{}` is declared but never assigned — \
                         `tune` would explore a degenerate lattice",
                        name
                    ),
                });
            }
        }
    }

    // write-only / unreferenced globals (info: write-only globals are
    // usually observables read by properties or reports)
    let mut gsyms: Vec<(&String, &super::compile::VarInfo)> = prog.global_syms.iter().collect();
    gsyms.sort_by_key(|(_, i)| i.offset);
    for (name, info) in gsyms {
        let read = (info.offset..info.offset + info.len).any(|s| greads.contains(s));
        let written = (info.offset..info.offset + info.len).any(|s| gwrites.contains(s));
        if !read {
            out.push(Diag {
                severity: Severity::Info,
                category: if written { "global-write-only" } else { "global-unused" },
                proc_name: None,
                pc: None,
                message: if written {
                    format!(
                        "global `{}` is written but never read by any process \
                         (observable only through properties/reports)",
                        name
                    )
                } else {
                    format!("global `{}` is never referenced", name)
                },
            });
        }
    }

    // buffered channels whose capacity is unreachable
    for (id, (cap, _arity)) in prog.global_chans.iter().enumerate() {
        if *cap == 0 {
            continue; // rendezvous: no buffer to fill
        }
        let sites: Vec<&(usize, u32)> = send_sites
            .iter()
            .filter(|(pi, pc)| {
                let eff = &analysis.effects[*pi][*pc as usize];
                eff.chans.contains(id as u32) || eff.chan_dynamic
            })
            .collect();
        if sites.is_empty() {
            out.push(Diag {
                severity: Severity::Warn,
                category: "chan-never-sent",
                proc_name: None,
                pc: None,
                message: format!("channel #{} (capacity {}) is never sent to", id, cap),
            });
        } else {
            // a send site on a cycle can fire arbitrarily often
            let repeatable =
                sites.iter().any(|(pi, pc)| on_cycle(&prog.procs[*pi].code, *pc));
            if !repeatable && (sites.len() as u16) < *cap {
                out.push(Diag {
                    severity: Severity::Warn,
                    category: "chan-cap-unreachable",
                    proc_name: None,
                    pc: None,
                    message: format!(
                        "channel #{}: capacity {} can never be reached (at most {} \
                         one-shot send site(s))",
                        id, cap, sites.len()
                    ),
                });
            }
        }
    }

    // per-proc findings
    for (pi, proc) in prog.procs.iter().enumerate() {
        let eff = &analysis.effects[pi];

        // locals never read anywhere in the proctype
        let mut lreads = SlotSet::new();
        for e in eff {
            lreads.union_with(&e.local_reads);
        }
        for (name, info) in &proc.locals {
            if !(info.offset..info.offset + info.len).any(|s| lreads.contains(s)) {
                out.push(Diag {
                    severity: Severity::Warn,
                    category: "local-unused",
                    proc_name: Some(proc.name.clone()),
                    pc: None,
                    message: format!("local `{}` is never read", name),
                });
            }
        }

        for (pc, ins) in proc.code.iter().enumerate() {
            let pc = pc as u32;
            match &ins.op {
                // dead store: scalar local whose value is dead at the
                // landing pc (suppress when the local is never read at
                // all — local-unused already covers it)
                Op::Assign(CLVal::Scalar(Slot::Local(s), _), _)
                    if ins.next != NO_PC
                        && lreads.contains(*s)
                        && analysis.slot_dead_at(pi, ins.next, *s) =>
                {
                    out.push(Diag {
                        severity: Severity::Warn,
                        category: "dead-store",
                        proc_name: Some(proc.name.clone()),
                        pc: Some(pc),
                        message: format!(
                            "value written to `{}` is overwritten before any read",
                            proc.local_name(*s).unwrap_or_else(|| format!("local#{}", s))
                        ),
                    });
                }
                Op::Guard(e) if const_value(e) == Some(0) => {
                    out.push(Diag {
                        severity: Severity::Warn,
                        category: "guard-false",
                        proc_name: Some(proc.name.clone()),
                        pc: Some(pc),
                        message: "guard is statically false — this statement can never \
                                  execute"
                            .into(),
                    });
                }
                Op::Branch(opts, _) => {
                    // duplicate option edges: same entry op and same
                    // continuation — truly redundant nondeterminism
                    for (i, &a) in opts.iter().enumerate() {
                        for &b in &opts[i + 1..] {
                            if a == b
                                || (proc.code[a as usize].op == proc.code[b as usize].op
                                    && proc.code[a as usize].next == proc.code[b as usize].next)
                            {
                                out.push(Diag {
                                    severity: Severity::Warn,
                                    category: "option-shadowed",
                                    proc_name: Some(proc.name.clone()),
                                    pc: Some(pc),
                                    message: format!(
                                        "options at pc {} and {} are identical — one \
                                         shadows the other",
                                        a, b
                                    ),
                                });
                            }
                        }
                    }
                }
                _ => {}
            }
        }
    }
    out
}

// ------------------------------------------------------------- lint IO --

/// Machine-readable lint document for one source file: diagnostics plus
/// the static feature summary future cost models consume.
pub fn lint_json(file: &str, prog: &Program, diags: &[Diag]) -> Json {
    let analysis = Analysis::of(prog);
    let mut sends = 0i64;
    let mut recvs = 0i64;
    let mut branches = 0i64;
    let mut runs = 0i64;
    let mut atomic_edges = 0i64;
    let mut instrs = 0i64;
    let mut por_safe_pcs = 0i64;
    for (pi, p) in prog.procs.iter().enumerate() {
        instrs += p.code.len() as i64;
        for (pc, ins) in p.code.iter().enumerate() {
            match ins.op {
                Op::Send(_, _) => sends += 1,
                Op::Recv(_, _) => recvs += 1,
                Op::Branch(_, _) => branches += 1,
                Op::Run(_, _) => runs += 1,
                _ => {}
            }
            if ins.atomic_next {
                atomic_edges += 1;
            }
            if analysis.por_safe(pi, pc as u32) {
                por_safe_pcs += 1;
            }
        }
    }
    let warns = diags.iter().filter(|d| d.severity == Severity::Warn).count() as i64;
    let infos = diags.len() as i64 - warns;
    let jdiags = diags
        .iter()
        .map(|d| {
            let mut f = vec![
                ("severity".to_string(), Json::Str(d.severity.label().into())),
                ("category".to_string(), Json::Str(d.category.into())),
            ];
            if let Some(p) = &d.proc_name {
                f.push(("proc".to_string(), Json::Str(p.clone())));
            }
            if let Some(pc) = d.pc {
                f.push(("pc".to_string(), Json::Int(i64::from(pc))));
            }
            f.push(("message".to_string(), Json::Str(d.message.clone())));
            Json::Obj(f)
        })
        .collect();
    Json::Obj(vec![
        ("tool".to_string(), Json::Str("mcautotune-lint".into())),
        ("version".to_string(), Json::Int(1)),
        ("file".to_string(), Json::Str(file.to_string())),
        ("diags".to_string(), Json::Arr(jdiags)),
        (
            "summary".to_string(),
            Json::Obj(vec![
                ("warns".to_string(), Json::Int(warns)),
                ("infos".to_string(), Json::Int(infos)),
            ]),
        ),
        (
            "features".to_string(),
            Json::Obj(vec![
                ("procs".to_string(), Json::Int(prog.procs.len() as i64)),
                ("active".to_string(), Json::Int(prog.active.len() as i64)),
                ("instrs".to_string(), Json::Int(instrs)),
                ("globals".to_string(), Json::Int(prog.globals_init.len() as i64)),
                ("global_chans".to_string(), Json::Int(prog.global_chans.len() as i64)),
                ("send_sites".to_string(), Json::Int(sends)),
                ("recv_sites".to_string(), Json::Int(recvs)),
                ("branch_sites".to_string(), Json::Int(branches)),
                ("run_sites".to_string(), Json::Int(runs)),
                ("atomic_edges".to_string(), Json::Int(atomic_edges)),
                ("por_safe_pcs".to_string(), Json::Int(por_safe_pcs)),
                (
                    "max_locals".to_string(),
                    Json::Int(prog.procs.iter().map(|p| i64::from(p.nlocals)).max().unwrap_or(0)),
                ),
            ]),
        ),
    ])
}

fn expect_int(j: &Json, key: &str) -> Result<i64> {
    match j.get(key).and_then(Json::as_i64) {
        Some(v) if v >= 0 => Ok(v),
        _ => bail!("lint JSON: `{}` must be a non-negative integer", key),
    }
}

/// Schema check for [`lint_json`] output (the `obs::trace::validate`
/// counterpart for lint documents): field presence, types, severity
/// vocabulary and summary-count consistency.
pub fn validate_lint_json(j: &Json) -> Result<()> {
    if j.get("tool").and_then(Json::as_str) != Some("mcautotune-lint") {
        bail!("lint JSON: `tool` must be \"mcautotune-lint\"");
    }
    if expect_int(j, "version")? < 1 {
        bail!("lint JSON: `version` must be >= 1");
    }
    if j.get("file").and_then(Json::as_str).is_none_or(str::is_empty) {
        bail!("lint JSON: `file` must be a non-empty string");
    }
    let Some(diags) = j.get("diags").and_then(Json::as_arr) else {
        bail!("lint JSON: `diags` must be an array");
    };
    let (mut warns, mut infos) = (0i64, 0i64);
    for (i, d) in diags.iter().enumerate() {
        match d.get("severity").and_then(Json::as_str) {
            Some("warn") => warns += 1,
            Some("info") => infos += 1,
            s => bail!("lint JSON: diag {}: bad severity {:?}", i, s),
        }
        if d.get("category").and_then(Json::as_str).is_none_or(str::is_empty) {
            bail!("lint JSON: diag {}: `category` must be a non-empty string", i);
        }
        if d.get("message").and_then(Json::as_str).is_none_or(str::is_empty) {
            bail!("lint JSON: diag {}: `message` must be a non-empty string", i);
        }
        if let Some(p) = d.get("proc") {
            if p.as_str().is_none() {
                bail!("lint JSON: diag {}: `proc` must be a string", i);
            }
        }
        if let Some(pc) = d.get("pc") {
            if pc.as_i64().is_none_or(|v| v < 0) {
                bail!("lint JSON: diag {}: `pc` must be a non-negative integer", i);
            }
        }
    }
    let Some(summary) = j.get("summary") else {
        bail!("lint JSON: missing `summary`");
    };
    if expect_int(summary, "warns")? != warns || expect_int(summary, "infos")? != infos {
        bail!("lint JSON: summary counts disagree with `diags`");
    }
    let Some(features) = j.get("features") else {
        bail!("lint JSON: missing `features`");
    };
    for key in [
        "procs",
        "active",
        "instrs",
        "globals",
        "global_chans",
        "send_sites",
        "recv_sites",
        "branch_sites",
        "run_sites",
        "atomic_edges",
        "por_safe_pcs",
        "max_locals",
    ] {
        expect_int(features, key)?;
    }
    Ok(())
}
