//! Stage two of the two-stage compile: the bytecode VM over flat packed
//! states — the production Promela execution engine.
//!
//! The front end ([`super::parser`] → [`super::compile`]) produces a
//! [`Program`] of per-proctype instruction automata whose operands are
//! tree-shaped [`CExpr`]s. The reference interpreter ([`super::interp`])
//! walks those trees and clones a nested `Vec<Vec<i32>>` state per
//! successor. This module lowers the same automaton one stage further:
//!
//! - **expression bytecode**: every `CExpr` is constant-folded and
//!   compiled to a linear stack program ([`VOp`]) with short-circuit
//!   jumps — the same discipline as `SafetyLtl::compile` — evaluated over
//!   a fixed-size stack with zero allocation;
//! - **flat packed states**: a [`VState`] is a single `Vec<i32>` laid out
//!   by a compile-time table (header, globals, uniform channel regions,
//!   uniform process frames), so cloning a state is one memcpy and
//!   encoding/hashing is a single linear pass;
//! - **shard specialization**: the compiler optionally bakes a (WG, TS)
//!   sub-lattice ([`TuningBounds`]) into the program. Stores into the
//!   tuning slots check the bounds *at the choice point, before the
//!   successor is materialized*, replacing the coordinator's per-successor
//!   `ShardModel` re-filtering for Promela jobs. The check fires only
//!   once both tuning variables are positive (a non-positive value means
//!   "not chosen yet"), which keeps the explored state space — including
//!   the intermediate states between the WG and TS choices — *identical*
//!   to the generic re-filtering wrapper, so shard results, state counts
//!   and cache entries are byte-for-byte unchanged. Contract: the tuning
//!   slots must start non-positive and be committed monotonically (the
//!   paper's models choose them exactly once); a model whose initial
//!   image already commits a tuning must use the `ShardModel` wrapper
//!   (see [`tuning_committed_at_init`]).
//!
//! The VM executes the *same* automaton as the interpreter — identical
//! pcs, `next` threading, option order and atomic coalescing — so the two
//! engines' state spaces correspond one-to-one. The differential suite
//! (`rust/tests/promela_vm.rs`) pins verdicts, state counts and trails of
//! both engines against each other on the whole example corpus.
//!
//! Known (documented) divergence: channel *message* layouts are
//! fixed-width here, so a send whose argument count exceeds the declared
//! channel arity truncates the message to the arity (the interpreter
//! appends the extra words). SPIN rejects such models at compile time;
//! none of the corpus contains one.

use super::ast::{PBinOp, UnOp};
use super::compile::{CExpr, CLVal, CRecvArg, Instr, Op, Program, Slot, VarType};
use super::interp::{MAX_PROCS, MAX_SELECT_FANOUT};
use crate::model::TransitionSystem;
use crate::util::error::{ensure, Result};
use std::sync::atomic::{AtomicU64, Ordering};

/// Header word indices of a packed state.
const EXCL: usize = 0;
const NCHANS: usize = 1;
const NPROCS: usize = 2;
const HDR: usize = 3;

/// Process-frame field offsets (frame-relative).
const PC: usize = 1; // frame[0] = ptype
const ALIVE: usize = 2;
const LOCALS: usize = 3;

/// Channel-region field offsets (region-relative; capacity is word 0 of
/// the region, indexed directly).
const CHAN_ARITY: usize = 1;
const CHAN_QLEN: usize = 2;
const CHAN_BUF: usize = 3;

/// Operand-stack slots of the expression evaluator. The lowering pass
/// computes each expression's exact peak depth and rejects programs that
/// exceed this (the paper's models peak below 10).
const MAX_EVAL_DEPTH: usize = 64;

/// Bound on channel arity, send/recv argument lists and proctype
/// parameter lists — sizes fixed-width message buffers on the stack.
const MAX_ARGS: usize = 16;

/// Bound on coalesced atomic chains (see `interp::MAX_ATOMIC_CHAIN`).
const MAX_ATOMIC_CHAIN: u32 = 4096;

/// A packed Promela state: one flat `i32` vector.
///
/// Layout: `[exclusive, nchans, nprocs | globals… | chan regions… |
/// proc frames…]`. Channel regions are a uniform `chan_stride` words
/// (`[cap, arity, qlen, buf…]`, unused buffer words held at zero so the
/// encoding stays canonical); process frames are a uniform `frame_stride`
/// words (`[ptype, pc, alive, locals…]`, unused local words zero). All
/// strides come from the compiled program, so cloning is a single memcpy
/// and the visited-store encoding is one linear pass over the words.
///
/// Clone and Drop route through a per-thread buffer pool: dropping a
/// state retires its `Vec<i32>` to a thread-local freelist and cloning
/// one draws from it, so steady-state exploration (clone a successor,
/// drop it once deduped) recycles allocations instead of hitting the
/// allocator once per emitted state. The pool is capacity-bounded and
/// survives TLS teardown gracefully (`try_with`), and pooled clones are
/// observably identical to fresh ones — same data, same equality/hash.
#[derive(Debug, PartialEq, Eq, Hash)]
pub struct VState {
    pub data: Vec<i32>,
}

/// Retired state buffers kept per worker thread (see [`VState`] docs).
/// Bounded so a burst (e.g. a huge frontier dropped at once) cannot pin
/// unbounded memory in idle freelists.
const STATE_POOL_CAP: usize = 1024;

thread_local! {
    static STATE_POOL: std::cell::RefCell<Vec<Vec<i32>>> =
        const { std::cell::RefCell::new(Vec::new()) };
}

impl Clone for VState {
    fn clone(&self) -> Self {
        let mut data = STATE_POOL
            .try_with(|p| p.borrow_mut().pop())
            .ok()
            .flatten()
            .unwrap_or_default();
        data.clear();
        data.extend_from_slice(&self.data);
        VState { data }
    }

    fn clone_from(&mut self, source: &Self) {
        self.data.clear();
        self.data.extend_from_slice(&source.data);
    }
}

impl Drop for VState {
    fn drop(&mut self) {
        if self.data.capacity() == 0 {
            return;
        }
        let buf = std::mem::take(&mut self.data);
        // ignore AccessError during thread teardown — the buffer just frees
        let _ = STATE_POOL.try_with(|p| {
            let mut p = p.borrow_mut();
            if p.len() < STATE_POOL_CAP {
                p.push(buf);
            }
        });
    }
}

/// An axis-aligned (WG, TS) sub-lattice baked into a specialized program
/// (inclusive bounds; the coordinator converts its `TuningShard`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TuningBounds {
    pub wg_min: u32,
    pub wg_max: u32,
    pub ts_min: u32,
    pub ts_max: u32,
}

/// Does the program's initial image already commit a (WG, TS) tuning?
/// Shard specialization prunes at *stores* into the tuning slots, so a
/// model violating the start-unset contract must fall back to the generic
/// `ShardModel` re-filtering wrapper.
pub fn tuning_committed_at_init(prog: &Program) -> bool {
    let read = |name: &str| {
        prog.global_syms
            .get(name)
            .map(|v| prog.globals_init[v.offset as usize])
            .unwrap_or(0)
    };
    read("WG") > 0 && read("TS") > 0
}

// ------------------------------------------------------------- bytecode --

/// One expression-bytecode instruction. Connectives compile to
/// conditional jumps with the same keep-top/pop-fallthrough convention as
/// `model::property`'s compiled evaluator, so short-circuit laziness —
/// including division-by-zero reachability — matches the tree-walking
/// interpreter exactly.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum VOp {
    Const(i32),
    LoadG(u32),
    LoadL(u32),
    /// (base, len): pops the index, pushes the element (bounds-checked)
    ElemG(u32, u32),
    ElemL(u32, u32),
    Not,
    Neg,
    Add,
    Sub,
    Mul,
    Div,
    Mod,
    Shl,
    Shr,
    Eq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
    /// top = (top != 0)
    Norm,
    /// if top == 0 jump keeping top, else pop and fall through
    Jz(u32),
    /// if top != 0 jump keeping top, else pop and fall through
    Jnz(u32),
    /// pop; jump when the popped value was 0 (conditional expression)
    JzPop(u32),
    Jmp(u32),
}

/// A lowered expression: either fully constant-folded, or a region of the
/// shared bytecode pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ExprRef {
    Const(i32),
    Code(u32, u32),
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum VmLVal {
    G(u32, VarType),
    L(u32, VarType),
    GElem(u32, u32, ExprRef, VarType),
    LElem(u32, u32, ExprRef, VarType),
}

#[derive(Debug, Clone, PartialEq, Eq)]
enum VmRecvArg {
    Bind(VmLVal),
    Match(ExprRef),
}

#[derive(Debug, Clone, PartialEq, Eq)]
enum VmOp {
    Guard(ExprRef),
    Assign(VmLVal, ExprRef),
    Send(ExprRef, Vec<ExprRef>),
    /// third field: true when some bind targets a watched tuning slot
    /// (then the post-bind state takes the shard check)
    Recv(ExprRef, Vec<VmRecvArg>, bool),
    Select(VmLVal, ExprRef, ExprRef),
    Branch(Vec<u32>, Option<u32>),
    Run(u32, Vec<ExprRef>),
    NewChan(VmLVal, u16, u16),
    Halt,
}

#[derive(Debug, Clone)]
struct VmInstr {
    op: VmOp,
    next: u32,
    atomic_next: bool,
}

#[derive(Debug, Clone)]
struct VmProc {
    entry: u32,
    nparams: u32,
    param_types: Vec<VarType>,
    code: Vec<VmInstr>,
}

/// Shard-specialization constants compiled into the program: the dense
/// slots of WG/TS and the inclusive bounds.
#[derive(Debug, Clone, Copy)]
struct Spec {
    wg: u32,
    ts: u32,
    wg_min: i64,
    wg_max: i64,
    ts_min: i64,
    ts_max: i64,
}

// ---------------------------------------------------------------- fold --

/// Constant-fold a front-end expression. Division/modulo by a constant
/// zero is left unfolded so the runtime error fires exactly where the
/// interpreter's does; `&&`/`||` with a constant *left* operand fold to
/// the normalized right operand (or a constant), preserving the
/// interpreter's evaluation-order and laziness semantics.
fn fold(e: &CExpr) -> CExpr {
    match e {
        CExpr::Num(_) | CExpr::Load(_) => e.clone(),
        CExpr::LoadElem(s, len, idx) => CExpr::LoadElem(*s, *len, Box::new(fold(idx))),
        CExpr::Un(op, a) => {
            let a = fold(a);
            if let CExpr::Num(n) = a {
                return CExpr::Num(match op {
                    UnOp::Not => (n == 0) as i32,
                    UnOp::Neg => n.wrapping_neg(),
                });
            }
            CExpr::Un(*op, Box::new(a))
        }
        CExpr::Bin(op, a, b) => {
            let a = fold(a);
            let b = fold(b);
            match (*op, &a) {
                (PBinOp::And, CExpr::Num(0)) => return CExpr::Num(0),
                (PBinOp::And, CExpr::Num(_)) => return normalized(b),
                (PBinOp::Or, CExpr::Num(0)) => return normalized(b),
                (PBinOp::Or, CExpr::Num(_)) => return CExpr::Num(1),
                _ => {}
            }
            if let (CExpr::Num(x), CExpr::Num(y)) = (&a, &b) {
                if let Some(v) = fold_bin(*op, *x, *y) {
                    return CExpr::Num(v);
                }
            }
            CExpr::Bin(*op, Box::new(a), Box::new(b))
        }
        CExpr::Cond(c, a, b) => {
            let c = fold(c);
            if let CExpr::Num(n) = c {
                return if n != 0 { fold(a) } else { fold(b) };
            }
            CExpr::Cond(Box::new(c), Box::new(fold(a)), Box::new(fold(b)))
        }
    }
}

/// `(e != 0)` — the value `&&`/`||` folding substitutes for a live
/// operand (same value, same evaluation effects).
fn normalized(e: CExpr) -> CExpr {
    match e {
        CExpr::Num(n) => CExpr::Num((n != 0) as i32),
        e => CExpr::Bin(PBinOp::Ne, Box::new(e), Box::new(CExpr::Num(0))),
    }
}

/// Wrapping semantics identical to `interp::PromelaSystem::eval`.
fn fold_bin(op: PBinOp, x: i32, y: i32) -> Option<i32> {
    Some(match op {
        PBinOp::Add => x.wrapping_add(y),
        PBinOp::Sub => x.wrapping_sub(y),
        PBinOp::Mul => x.wrapping_mul(y),
        PBinOp::Div => {
            if y == 0 {
                return None;
            }
            x.wrapping_div(y)
        }
        PBinOp::Mod => {
            if y == 0 {
                return None;
            }
            x.wrapping_rem(y)
        }
        PBinOp::Shl => x.wrapping_shl(y as u32 & 31),
        PBinOp::Shr => x.wrapping_shr(y as u32 & 31),
        PBinOp::Eq => (x == y) as i32,
        PBinOp::Ne => (x != y) as i32,
        PBinOp::Lt => (x < y) as i32,
        PBinOp::Le => (x <= y) as i32,
        PBinOp::Gt => (x > y) as i32,
        PBinOp::Ge => (x >= y) as i32,
        PBinOp::And => ((x != 0) && (y != 0)) as i32,
        PBinOp::Or => ((x != 0) || (y != 0)) as i32,
    })
}

// ------------------------------------------------------------- lowering --

struct Lowerer {
    pool: Vec<VOp>,
}

impl Lowerer {
    fn lower_expr(&mut self, e: &CExpr) -> Result<ExprRef> {
        let f = fold(e);
        if let CExpr::Num(n) = f {
            return Ok(ExprRef::Const(n));
        }
        let start = self.pool.len() as u32;
        let mut max = 0u32;
        self.emit_expr(&f, 0, &mut max);
        ensure!(
            max as usize <= MAX_EVAL_DEPTH,
            "expression needs {} evaluation-stack slots (VM limit {})",
            max,
            MAX_EVAL_DEPTH
        );
        Ok(ExprRef::Code(start, self.pool.len() as u32))
    }

    /// Emit bytecode that pushes exactly one value; `depth` is the number
    /// of operands already on the stack, `max` tracks the peak.
    fn emit_expr(&mut self, e: &CExpr, depth: u32, max: &mut u32) {
        *max = (*max).max(depth + 1);
        match e {
            CExpr::Num(n) => self.pool.push(VOp::Const(*n)),
            CExpr::Load(Slot::Global(o)) => self.pool.push(VOp::LoadG(*o)),
            CExpr::Load(Slot::Local(o)) => self.pool.push(VOp::LoadL(*o)),
            CExpr::LoadElem(s, len, idx) => {
                self.emit_expr(idx, depth, max);
                self.pool.push(match s {
                    Slot::Global(o) => VOp::ElemG(*o, *len),
                    Slot::Local(o) => VOp::ElemL(*o, *len),
                });
            }
            CExpr::Un(UnOp::Not, a) => {
                self.emit_expr(a, depth, max);
                self.pool.push(VOp::Not);
            }
            CExpr::Un(UnOp::Neg, a) => {
                self.emit_expr(a, depth, max);
                self.pool.push(VOp::Neg);
            }
            CExpr::Bin(PBinOp::And, a, b) => {
                self.emit_expr(a, depth, max);
                self.pool.push(VOp::Norm);
                let j = self.pool.len();
                self.pool.push(VOp::Jz(0));
                self.emit_expr(b, depth, max);
                self.pool.push(VOp::Norm);
                self.pool[j] = VOp::Jz(self.pool.len() as u32);
            }
            CExpr::Bin(PBinOp::Or, a, b) => {
                self.emit_expr(a, depth, max);
                self.pool.push(VOp::Norm);
                let j = self.pool.len();
                self.pool.push(VOp::Jnz(0));
                self.emit_expr(b, depth, max);
                self.pool.push(VOp::Norm);
                self.pool[j] = VOp::Jnz(self.pool.len() as u32);
            }
            CExpr::Bin(op, a, b) => {
                self.emit_expr(a, depth, max);
                self.emit_expr(b, depth + 1, max);
                self.pool.push(match op {
                    PBinOp::Add => VOp::Add,
                    PBinOp::Sub => VOp::Sub,
                    PBinOp::Mul => VOp::Mul,
                    PBinOp::Div => VOp::Div,
                    PBinOp::Mod => VOp::Mod,
                    PBinOp::Shl => VOp::Shl,
                    PBinOp::Shr => VOp::Shr,
                    PBinOp::Eq => VOp::Eq,
                    PBinOp::Ne => VOp::Ne,
                    PBinOp::Lt => VOp::Lt,
                    PBinOp::Le => VOp::Le,
                    PBinOp::Gt => VOp::Gt,
                    PBinOp::Ge => VOp::Ge,
                    PBinOp::And | PBinOp::Or => unreachable!("connectives handled above"),
                });
            }
            CExpr::Cond(c, a, b) => {
                self.emit_expr(c, depth, max);
                let j_else = self.pool.len();
                self.pool.push(VOp::JzPop(0));
                self.emit_expr(a, depth, max);
                let j_end = self.pool.len();
                self.pool.push(VOp::Jmp(0));
                self.pool[j_else] = VOp::JzPop(self.pool.len() as u32);
                self.emit_expr(b, depth, max);
                self.pool[j_end] = VOp::Jmp(self.pool.len() as u32);
            }
        }
    }

    fn lower_lval(&mut self, lv: &CLVal) -> Result<VmLVal> {
        Ok(match lv {
            CLVal::Scalar(Slot::Global(o), ty) => VmLVal::G(*o, *ty),
            CLVal::Scalar(Slot::Local(o), ty) => VmLVal::L(*o, *ty),
            CLVal::Elem(Slot::Global(o), len, idx, ty) => {
                VmLVal::GElem(*o, *len, self.lower_expr(idx)?, *ty)
            }
            CLVal::Elem(Slot::Local(o), len, idx, ty) => {
                VmLVal::LElem(*o, *len, self.lower_expr(idx)?, *ty)
            }
        })
    }
}

fn lval_watches(spec: &Spec, lv: &VmLVal) -> bool {
    match *lv {
        VmLVal::G(o, _) => o == spec.wg || o == spec.ts,
        VmLVal::GElem(base, len, _, _) => {
            (spec.wg >= base && spec.wg < base + len) || (spec.ts >= base && spec.ts < base + len)
        }
        _ => false,
    }
}

// ------------------------------------------------------------------ VM --

/// A compiled Promela model: the front-end [`Program`] lowered to
/// expression bytecode over flat packed [`VState`]s, optionally
/// shard-specialized (see the module docs).
pub struct PromelaVm {
    src: Program,
    nglobals: usize,
    chan_stride: usize,
    frame_stride: usize,
    procs: Vec<VmProc>,
    pool: Vec<VOp>,
    spec: Option<Spec>,
    /// SPIN-style atomic merging (see `interp::PromelaSystem`).
    pub coalesce_atomic: bool,
    /// successor states materialized and emitted (pre any downstream
    /// filtering) — lets tests assert that specialization generates
    /// strictly fewer raw successors than generate-then-filter
    generated: AtomicU64,
    /// off-shard choices dropped by compile-time specialization before
    /// materialization — the telemetry complement of `generated`
    pruned: AtomicU64,
    /// opt-in dead-slot reduction (see `PromelaSystem::with_dead_slot_reduction`)
    dead_slots: bool,
    /// lazily-built static tables (liveness + POR eligibility); default
    /// runs never touch this, so construction stays free
    analysis: std::sync::OnceLock<super::analysis::Analysis>,
}

impl PromelaVm {
    /// Compile without shard specialization (explores the full lattice).
    pub fn new(prog: Program) -> Result<Self> {
        Self::specialized(prog, None)
    }

    pub fn from_source(src: &str) -> Result<Self> {
        let model = super::parser::parse(src)?;
        Self::new(super::compile::compile(&model)?)
    }

    /// Compile with an optional (WG, TS) sub-lattice baked in. Bounds
    /// covering the whole lattice — or a model without WG/TS globals —
    /// compile unspecialized (nothing would ever be pruned).
    pub fn specialized(prog: Program, bounds: Option<TuningBounds>) -> Result<Self> {
        let spec = bounds.and_then(|b| {
            let wg = prog.global_syms.get("WG")?.offset;
            let ts = prog.global_syms.get("TS")?.offset;
            if b.wg_min <= 1 && b.wg_max == u32::MAX && b.ts_min <= 1 && b.ts_max == u32::MAX {
                return None;
            }
            Some(Spec {
                wg,
                ts,
                wg_min: b.wg_min as i64,
                wg_max: b.wg_max as i64,
                ts_min: b.ts_min as i64,
                ts_max: b.ts_max as i64,
            })
        });

        let nglobals = prog.globals_init.len();
        let mut max_buf = 0usize;
        for &(cap, arity) in &prog.global_chans {
            ensure!(
                (arity as usize) <= MAX_ARGS,
                "channel arity {} exceeds the VM limit {}",
                arity,
                MAX_ARGS
            );
            max_buf = max_buf.max(cap as usize * arity as usize);
        }
        let mut max_locals = 0u32;
        let mut lw = Lowerer { pool: Vec::new() };
        let mut procs = Vec::with_capacity(prog.procs.len());
        for pd in &prog.procs {
            max_locals = max_locals.max(pd.nlocals);
            ensure!(
                (pd.nparams as usize) <= MAX_ARGS,
                "proctype `{}` has {} parameters (VM limit {})",
                pd.name,
                pd.nparams,
                MAX_ARGS
            );
            let mut code = Vec::with_capacity(pd.code.len());
            for ins in &pd.code {
                let op = lower_op(&mut lw, ins, spec.as_ref(), &mut max_buf)?;
                code.push(VmInstr { op, next: ins.next, atomic_next: ins.atomic_next });
            }
            procs.push(VmProc {
                entry: pd.entry,
                nparams: pd.nparams,
                param_types: pd.param_types.clone(),
                code,
            });
        }

        Ok(Self {
            nglobals,
            chan_stride: CHAN_BUF + max_buf,
            frame_stride: LOCALS + max_locals as usize,
            procs,
            pool: lw.pool,
            spec,
            coalesce_atomic: true,
            generated: AtomicU64::new(0),
            pruned: AtomicU64::new(0),
            dead_slots: false,
            analysis: std::sync::OnceLock::new(),
            src: prog,
        })
    }

    /// Instruction-level variant (every atomic step is a visible state).
    pub fn without_atomic_coalescing(mut self) -> Self {
        self.coalesce_atomic = false;
        self
    }

    /// Opt-in `--reduce dead-slots`: `encode` zeroes provably dead local
    /// slots (and every local of a terminated process) before hashing.
    /// Same contract as `PromelaSystem::with_dead_slot_reduction`.
    pub fn with_dead_slot_reduction(mut self) -> Self {
        self.dead_slots = true;
        self
    }

    /// Static analysis tables, built on first use.
    fn analysis(&self) -> &super::analysis::Analysis {
        self.analysis.get_or_init(|| super::analysis::Analysis::of(&self.src))
    }

    /// The stage-one program this VM was compiled from.
    pub fn program(&self) -> &Program {
        &self.src
    }

    /// Whether this program was compiled with shard bounds baked in.
    pub fn is_specialized(&self) -> bool {
        self.spec.is_some()
    }

    /// Raw successor states materialized so far (see field docs).
    pub fn generated(&self) -> u64 {
        self.generated.load(Ordering::Relaxed)
    }

    pub fn reset_generated(&self) {
        self.generated.store(0, Ordering::Relaxed);
        self.pruned.store(0, Ordering::Relaxed);
    }

    /// Off-shard choices pruned before materialization (see field docs).
    pub fn pruned(&self) -> u64 {
        self.pruned.load(Ordering::Relaxed)
    }

    /// Count one pruned (never-materialized) off-shard choice.
    #[inline]
    fn note_prune(&self) {
        self.pruned.fetch_add(1, Ordering::Relaxed);
    }

    // ------------------------------------------------------- state access --

    #[inline]
    fn nchans(&self, d: &[i32]) -> usize {
        d[NCHANS] as usize
    }

    #[inline]
    fn nprocs(&self, d: &[i32]) -> usize {
        d[NPROCS] as usize
    }

    #[inline]
    fn chan_off(&self, c: usize) -> usize {
        HDR + self.nglobals + c * self.chan_stride
    }

    #[inline]
    fn procs_base(&self, d: &[i32]) -> usize {
        HDR + self.nglobals + self.nchans(d) * self.chan_stride
    }

    #[inline]
    fn proc_off(&self, d: &[i32], p: usize) -> usize {
        self.procs_base(d) + p * self.frame_stride
    }

    #[inline]
    fn frame_of(&self, d: &[i32], p: usize) -> usize {
        self.proc_off(d, p) + LOCALS
    }

    #[inline]
    fn alive(&self, d: &[i32], p: usize) -> bool {
        d[self.proc_off(d, p) + ALIVE] != 0
    }

    #[inline]
    fn pc_of(&self, d: &[i32], p: usize) -> u32 {
        d[self.proc_off(d, p) + PC] as u32
    }

    #[inline]
    fn instr_of(&self, d: &[i32], p: usize, pc: u32) -> &VmInstr {
        let off = self.proc_off(d, p);
        &self.procs[d[off] as usize].code[pc as usize]
    }

    // ---------------------------------------------------------- expr eval --

    #[inline]
    fn eval(&self, d: &[i32], frame: usize, e: ExprRef) -> i32 {
        match e {
            ExprRef::Const(n) => n,
            ExprRef::Code(s, t) => self.run_code(d, frame, s as usize, t as usize),
        }
    }

    fn run_code(&self, d: &[i32], frame: usize, start: usize, end: usize) -> i32 {
        let mut stack = [0i32; MAX_EVAL_DEPTH];
        let mut sp = 0usize;
        let mut pc = start;
        while pc < end {
            match self.pool[pc] {
                VOp::Const(n) => {
                    stack[sp] = n;
                    sp += 1;
                }
                VOp::LoadG(o) => {
                    stack[sp] = d[HDR + o as usize];
                    sp += 1;
                }
                VOp::LoadL(o) => {
                    stack[sp] = d[frame + o as usize];
                    sp += 1;
                }
                VOp::ElemG(base, len) => {
                    let i = stack[sp - 1];
                    assert!(
                        i >= 0 && (i as u32) < len,
                        "array index {} out of bounds 0..{}",
                        i,
                        len
                    );
                    stack[sp - 1] = d[HDR + base as usize + i as usize];
                }
                VOp::ElemL(base, len) => {
                    let i = stack[sp - 1];
                    assert!(
                        i >= 0 && (i as u32) < len,
                        "array index {} out of bounds 0..{}",
                        i,
                        len
                    );
                    stack[sp - 1] = d[frame + base as usize + i as usize];
                }
                VOp::Not => stack[sp - 1] = (stack[sp - 1] == 0) as i32,
                VOp::Neg => stack[sp - 1] = stack[sp - 1].wrapping_neg(),
                VOp::Norm => stack[sp - 1] = (stack[sp - 1] != 0) as i32,
                VOp::Jz(t) => {
                    if stack[sp - 1] == 0 {
                        pc = t as usize;
                        continue;
                    }
                    sp -= 1;
                }
                VOp::Jnz(t) => {
                    if stack[sp - 1] != 0 {
                        pc = t as usize;
                        continue;
                    }
                    sp -= 1;
                }
                VOp::JzPop(t) => {
                    sp -= 1;
                    if stack[sp] == 0 {
                        pc = t as usize;
                        continue;
                    }
                }
                VOp::Jmp(t) => {
                    pc = t as usize;
                    continue;
                }
                op => {
                    sp -= 1;
                    let b = stack[sp];
                    let a = stack[sp - 1];
                    stack[sp - 1] = match op {
                        VOp::Add => a.wrapping_add(b),
                        VOp::Sub => a.wrapping_sub(b),
                        VOp::Mul => a.wrapping_mul(b),
                        VOp::Div => {
                            assert!(b != 0, "division by zero in model");
                            a.wrapping_div(b)
                        }
                        VOp::Mod => {
                            assert!(b != 0, "mod by zero in model");
                            a.wrapping_rem(b)
                        }
                        VOp::Shl => a.wrapping_shl(b as u32 & 31),
                        VOp::Shr => a.wrapping_shr(b as u32 & 31),
                        VOp::Eq => (a == b) as i32,
                        VOp::Ne => (a != b) as i32,
                        VOp::Lt => (a < b) as i32,
                        VOp::Le => (a <= b) as i32,
                        VOp::Gt => (a > b) as i32,
                        VOp::Ge => (a >= b) as i32,
                        _ => unreachable!("non-binary op in binary dispatch"),
                    };
                }
            }
            pc += 1;
        }
        debug_assert_eq!(sp, 1, "expression block must leave exactly one value");
        stack[0]
    }

    fn store(&self, d: &mut [i32], frame: usize, lv: VmLVal, v: i32) {
        match lv {
            VmLVal::G(o, ty) => d[HDR + o as usize] = ty.truncate(v),
            VmLVal::L(o, ty) => d[frame + o as usize] = ty.truncate(v),
            VmLVal::GElem(base, len, idx, ty) => {
                let i = self.eval(&*d, frame, idx);
                assert!(i >= 0 && (i as u32) < len, "array store out of bounds");
                d[HDR + base as usize + i as usize] = ty.truncate(v);
            }
            VmLVal::LElem(base, len, idx, ty) => {
                let i = self.eval(&*d, frame, idx);
                assert!(i >= 0 && (i as u32) < len, "array store out of bounds");
                d[frame + base as usize + i as usize] = ty.truncate(v);
            }
        }
    }

    // ----------------------------------------------------- specialization --

    /// Would committing `v` into watched *scalar* global slot `o` (other
    /// tuning slot read from the pre-state) land outside the shard?
    #[inline]
    fn store_prunes(&self, d: &[i32], o: u32, v: i32) -> bool {
        let Some(sp) = &self.spec else { return false };
        let (wg, ts) = if o == sp.wg {
            (v as i64, d[HDR + sp.ts as usize] as i64)
        } else if o == sp.ts {
            (d[HDR + sp.wg as usize] as i64, v as i64)
        } else {
            return false;
        };
        wg > 0
            && ts > 0
            && !(wg >= sp.wg_min && wg <= sp.wg_max && ts >= sp.ts_min && ts <= sp.ts_max)
    }

    /// Post-store check for the rare lvalue shapes whose target cannot be
    /// predicted pre-clone (array stores overlapping a tuning slot).
    fn elem_store_prunes(&self, lv: &VmLVal, d_new: &[i32]) -> bool {
        let Some(sp) = &self.spec else { return false };
        if lval_watches(sp, lv) && matches!(lv, VmLVal::GElem(..)) {
            return self.off_shard(d_new);
        }
        false
    }

    /// Is the state's committed tuning outside the compiled bounds?
    /// (False while either tuning variable is still non-positive.)
    fn off_shard(&self, d: &[i32]) -> bool {
        let Some(sp) = &self.spec else { return false };
        let wg = d[HDR + sp.wg as usize] as i64;
        let ts = d[HDR + sp.ts as usize] as i64;
        wg > 0
            && ts > 0
            && !(wg >= sp.wg_min && wg <= sp.wg_max && ts >= sp.ts_min && ts <= sp.ts_max)
    }

    // ------------------------------------------------------- executability --

    /// Mirrors `interp::PromelaSystem::enabled` — deliberately
    /// specialization-blind: `else` semantics and option selection follow
    /// the *unsharded* executability, exactly as the generic re-filtering
    /// wrapper observes them.
    fn enabled(&self, d: &[i32], p: usize, pc: u32) -> bool {
        let frame = self.frame_of(d, p);
        match &self.instr_of(d, p, pc).op {
            VmOp::Guard(e) => self.eval(d, frame, *e) != 0,
            VmOp::Assign(..) | VmOp::NewChan(..) => true,
            VmOp::Select(_, lo, hi) => self.eval(d, frame, *lo) <= self.eval(d, frame, *hi),
            VmOp::Run(..) => self.nprocs(d) < MAX_PROCS,
            VmOp::Send(c, args) => {
                let cid = self.eval(d, frame, *c) as usize;
                let coff = self.chan_off(cid);
                if d[coff] > 0 {
                    d[coff + CHAN_QLEN] < d[coff]
                } else {
                    let mut msg = [0i32; MAX_ARGS];
                    for (slot, a) in msg.iter_mut().zip(args.iter()) {
                        *slot = self.eval(d, frame, *a);
                    }
                    self.any_ready_recv(d, p, cid, &msg[..args.len()])
                }
            }
            VmOp::Recv(c, pats, _) => {
                let cid = self.eval(d, frame, *c) as usize;
                let coff = self.chan_off(cid);
                if d[coff] > 0 {
                    if d[coff + CHAN_QLEN] == 0 {
                        return false;
                    }
                    let arity = d[coff + CHAN_ARITY] as usize;
                    self.msg_matches(d, frame, pats, &d[coff + CHAN_BUF..coff + CHAN_BUF + arity])
                } else {
                    self.any_ready_send(d, p, cid, pats)
                }
            }
            VmOp::Branch(opts, els) => {
                opts.iter().any(|&o| self.enabled(d, p, o))
                    || els.map_or(false, |e| self.enabled(d, p, e))
            }
            VmOp::Halt => false,
        }
    }

    fn msg_matches(&self, d: &[i32], frame: usize, pats: &[VmRecvArg], msg: &[i32]) -> bool {
        pats.iter().zip(msg).all(|(p, &v)| match p {
            VmRecvArg::Bind(_) => true,
            VmRecvArg::Match(e) => self.eval(d, frame, *e) == v,
        })
    }

    /// Walk process `q`'s current instruction tree for rendezvous receives
    /// matching (`cid`, `msg`), calling `f` per match. `found` is shared
    /// across the whole scan (all processes) so `else` options are honored
    /// only while no match exists anywhere — the interpreter's exact rule.
    #[allow(clippy::too_many_arguments)]
    fn walk_recvs<F: FnMut(usize, u32)>(
        &self,
        d: &[i32],
        q: usize,
        pc: u32,
        cid: usize,
        msg: &[i32],
        found: &mut bool,
        f: &mut F,
    ) {
        let frame_q = self.frame_of(d, q);
        match &self.instr_of(d, q, pc).op {
            VmOp::Recv(c, pats, _) => {
                if self.eval(d, frame_q, *c) as usize == cid
                    && d[self.chan_off(cid)] == 0
                    && pats.len() == msg.len()
                    && self.msg_matches(d, frame_q, pats, msg)
                {
                    *found = true;
                    f(q, pc);
                }
            }
            VmOp::Branch(opts, els) => {
                for &o in opts {
                    self.walk_recvs(d, q, o, cid, msg, found, f);
                }
                if let Some(e) = els {
                    if !*found {
                        self.walk_recvs(d, q, *e, cid, msg, found, f);
                    }
                }
            }
            _ => {}
        }
    }

    fn any_ready_recv(&self, d: &[i32], sender: usize, cid: usize, msg: &[i32]) -> bool {
        let mut found = false;
        for q in 0..self.nprocs(d) {
            if q == sender || !self.alive(d, q) {
                continue;
            }
            let pc = self.pc_of(d, q);
            self.walk_recvs(d, q, pc, cid, msg, &mut found, &mut |_, _| {});
        }
        found
    }

    /// Rendezvous-receive executability: walk other processes for a
    /// matching ready *send* on `cid` (generation stays sender-side).
    fn any_ready_send(&self, d: &[i32], recver: usize, cid: usize, pats: &[VmRecvArg]) -> bool {
        let recver_frame = self.frame_of(d, recver);
        let mut found = false;
        for q in 0..self.nprocs(d) {
            if q == recver || !self.alive(d, q) {
                continue;
            }
            let pc = self.pc_of(d, q);
            self.walk_sends(d, recver_frame, q, pc, cid, pats, &mut found);
        }
        found
    }

    #[allow(clippy::too_many_arguments)]
    fn walk_sends(
        &self,
        d: &[i32],
        recver_frame: usize,
        q: usize,
        pc: u32,
        cid: usize,
        pats: &[VmRecvArg],
        found: &mut bool,
    ) {
        let frame_q = self.frame_of(d, q);
        match &self.instr_of(d, q, pc).op {
            VmOp::Send(c, args) => {
                if self.eval(d, frame_q, *c) as usize == cid
                    && d[self.chan_off(cid)] == 0
                    && args.len() == pats.len()
                {
                    let mut msg = [0i32; MAX_ARGS];
                    for (slot, a) in msg.iter_mut().zip(args.iter()) {
                        *slot = self.eval(d, frame_q, *a);
                    }
                    if self.msg_matches(d, recver_frame, pats, &msg[..args.len()]) {
                        *found = true;
                    }
                }
            }
            VmOp::Branch(opts, els) => {
                for &o in opts {
                    self.walk_sends(d, recver_frame, q, o, cid, pats, found);
                }
                if let Some(e) = els {
                    if !*found {
                        self.walk_sends(d, recver_frame, q, *e, cid, pats, found);
                    }
                }
            }
            _ => {}
        }
    }

    // --------------------------------------------------------- transitions --

    /// Kill the process if its pc reached Halt (mirrors interp).
    fn maybe_halt(&self, d: &mut [i32], p: usize) {
        let off = self.proc_off(d, p);
        let ptype = d[off] as usize;
        let pc = d[off + PC] as usize;
        if matches!(self.procs[ptype].code[pc].op, VmOp::Halt) {
            d[off + ALIVE] = 0;
            if d[EXCL] == p as i32 {
                d[EXCL] = -1;
            }
        }
    }

    /// Advance proc `p` past the fired instruction: set pc, handle body
    /// end, update exclusivity — the interpreter's `after` sequence.
    fn finish_step(&self, ns: &mut VState, p: usize, next: u32, atomic_next: bool) {
        let off = self.proc_off(&ns.data, p);
        ns.data[off + PC] = next as i32;
        self.maybe_halt(&mut ns.data, p);
        ns.data[EXCL] = if atomic_next { p as i32 } else { -1 };
    }

    /// Emit `ns`, or continue its atomic chain (mirrors
    /// `interp::push_or_continue`). Returns true when shard
    /// specialization pruned any continuation — the caller must then not
    /// fall back to emitting the intermediate state, exactly as the
    /// re-filtering wrapper drops the chain's off-shard leaves.
    fn emit(&self, ns: VState, out: &mut Vec<VState>, depth: u32) -> bool {
        if self.coalesce_atomic && depth < MAX_ATOMIC_CHAIN && ns.data[EXCL] >= 0 {
            let p = ns.data[EXCL] as usize;
            let off = self.proc_off(&ns.data, p);
            if ns.data[off + ALIVE] != 0 {
                let pc = ns.data[off + PC] as u32;
                if self.enabled(&ns.data, p, pc) {
                    let before = out.len();
                    let pruned = self.gen_from_d(&ns, p, pc, out, depth + 1);
                    if out.len() > before || pruned {
                        return pruned;
                    }
                }
            }
        }
        self.generated.fetch_add(1, Ordering::Relaxed);
        out.push(ns);
        false
    }

    fn gen_from(&self, s: &VState, p: usize, pc: u32, out: &mut Vec<VState>) -> bool {
        self.gen_from_d(s, p, pc, out, 0)
    }

    /// Generate all transitions of process `p` from instruction `pc`.
    /// Returns true when shard specialization suppressed any successor.
    fn gen_from_d(&self, s: &VState, p: usize, pc: u32, out: &mut Vec<VState>, depth: u32) -> bool {
        let d = &s.data[..];
        let frame = self.frame_of(d, p);
        let instr = self.instr_of(d, p, pc);
        let mut pruned = false;
        match &instr.op {
            VmOp::Branch(opts, els) => {
                let mut any = false;
                for &o in opts {
                    if self.enabled(d, p, o) {
                        any = true;
                        pruned |= self.gen_from_d(s, p, o, out, depth);
                    }
                }
                if !any {
                    if let Some(e) = els {
                        if self.enabled(d, p, *e) {
                            pruned |= self.gen_from_d(s, p, *e, out, depth);
                        }
                    }
                }
            }
            VmOp::Guard(e) => {
                if self.eval(d, frame, *e) != 0 {
                    let mut ns = s.clone();
                    self.finish_step(&mut ns, p, instr.next, instr.atomic_next);
                    pruned |= self.emit(ns, out, depth);
                }
            }
            VmOp::Assign(lv, e) => {
                let v = self.eval(d, frame, *e);
                if let VmLVal::G(o, ty) = *lv {
                    if self.store_prunes(d, o, ty.truncate(v)) {
                        self.note_prune();
                        return true; // off-shard choice: never materialized
                    }
                }
                let mut ns = s.clone();
                self.store(&mut ns.data, frame, *lv, v);
                if self.elem_store_prunes(lv, &ns.data) {
                    self.note_prune();
                    return true;
                }
                self.finish_step(&mut ns, p, instr.next, instr.atomic_next);
                pruned |= self.emit(ns, out, depth);
            }
            VmOp::NewChan(lv, cap, arity) => {
                let id = self.nchans(d) as i32;
                if let VmLVal::G(o, ty) = *lv {
                    if self.store_prunes(d, o, ty.truncate(id)) {
                        self.note_prune();
                        return true;
                    }
                }
                let mut ns = s.clone();
                let pb = self.procs_base(&ns.data);
                let stride = self.chan_stride;
                let old_len = ns.data.len();
                // append a zeroed region, rotate it in front of the frames
                ns.data.resize(old_len + stride, 0);
                ns.data[pb..].rotate_right(stride);
                ns.data[pb] = *cap as i32;
                ns.data[pb + CHAN_ARITY] = *arity as i32;
                ns.data[NCHANS] += 1;
                let frame_ns = self.frame_of(&ns.data, p);
                self.store(&mut ns.data, frame_ns, *lv, id);
                if self.elem_store_prunes(lv, &ns.data) {
                    self.note_prune();
                    return true;
                }
                self.finish_step(&mut ns, p, instr.next, instr.atomic_next);
                pruned |= self.emit(ns, out, depth);
            }
            VmOp::Select(lv, lo, hi) => {
                let l = self.eval(d, frame, *lo);
                let h = self.eval(d, frame, *hi).min(l.saturating_add(MAX_SELECT_FANOUT));
                for v in l..=h {
                    if let VmLVal::G(o, ty) = *lv {
                        if self.store_prunes(d, o, ty.truncate(v)) {
                            self.note_prune();
                            pruned = true; // off-shard value: skip unmaterialized
                            continue;
                        }
                    }
                    let mut ns = s.clone();
                    self.store(&mut ns.data, frame, *lv, v);
                    self.finish_step(&mut ns, p, instr.next, instr.atomic_next);
                    pruned |= self.emit(ns, out, depth);
                }
            }
            VmOp::Run(pt, args) => {
                if self.nprocs(d) >= MAX_PROCS {
                    return false;
                }
                let def = &self.procs[*pt as usize];
                let n = args.len().min(def.nparams as usize);
                let mut argv = [0i32; MAX_ARGS];
                for (i, (slot, a)) in argv.iter_mut().zip(args.iter()).enumerate().take(n) {
                    *slot = def.param_types[i].truncate(self.eval(d, frame, *a));
                }
                let mut ns = s.clone();
                let base = ns.data.len(); // frames are the trailing region
                ns.data.resize(base + self.frame_stride, 0);
                ns.data[base] = *pt as i32;
                ns.data[base + PC] = def.entry as i32;
                ns.data[base + ALIVE] = 1;
                ns.data[base + LOCALS..base + LOCALS + n].copy_from_slice(&argv[..n]);
                ns.data[NPROCS] += 1;
                // entry could itself be a Halt (empty body)
                let np = ns.data[NPROCS] as usize - 1;
                self.maybe_halt(&mut ns.data, np);
                let off = self.proc_off(&ns.data, p);
                ns.data[off + PC] = instr.next as i32;
                self.maybe_halt(&mut ns.data, p);
                ns.data[EXCL] = if instr.atomic_next { p as i32 } else { -1 };
                pruned |= self.emit(ns, out, depth);
            }
            VmOp::Send(c, args) => {
                let cid = self.eval(d, frame, *c) as usize;
                let coff = self.chan_off(cid);
                let mut msg_buf = [0i32; MAX_ARGS];
                for (slot, a) in msg_buf.iter_mut().zip(args.iter()) {
                    *slot = self.eval(d, frame, *a);
                }
                let msg = &msg_buf[..args.len()];
                if d[coff] > 0 {
                    let qlen = d[coff + CHAN_QLEN];
                    if qlen < d[coff] {
                        let arity = d[coff + CHAN_ARITY] as usize;
                        let mut ns = s.clone();
                        let w = coff + CHAN_BUF + qlen as usize * arity;
                        let n = msg.len().min(arity);
                        ns.data[w..w + n].copy_from_slice(&msg[..n]);
                        ns.data[coff + CHAN_QLEN] += 1;
                        self.finish_step(&mut ns, p, instr.next, instr.atomic_next);
                        pruned |= self.emit(ns, out, depth);
                    }
                } else {
                    // rendezvous: one combined transition per ready receiver
                    let mut found = false;
                    let mut chain_pruned = false;
                    for q in 0..self.nprocs(d) {
                        if q == p || !self.alive(d, q) {
                            continue;
                        }
                        let pcq = self.pc_of(d, q);
                        self.walk_recvs(d, q, pcq, cid, msg, &mut found, &mut |qm, rpc| {
                            chain_pruned |=
                                self.fire_rendezvous(s, p, instr, qm, rpc, msg, out, depth);
                        });
                    }
                    pruned |= chain_pruned;
                }
            }
            VmOp::Recv(c, pats, binds_watch) => {
                let cid = self.eval(d, frame, *c) as usize;
                let coff = self.chan_off(cid);
                if d[coff] > 0 && d[coff + CHAN_QLEN] > 0 {
                    let arity = d[coff + CHAN_ARITY] as usize;
                    let mut head_buf = [0i32; MAX_ARGS];
                    head_buf[..arity]
                        .copy_from_slice(&d[coff + CHAN_BUF..coff + CHAN_BUF + arity]);
                    let head = &head_buf[..arity];
                    if self.msg_matches(d, frame, pats, head) {
                        let mut ns = s.clone();
                        // dequeue: shift the remaining messages, zero the tail
                        let qlen = ns.data[coff + CHAN_QLEN] as usize;
                        let b = coff + CHAN_BUF;
                        ns.data.copy_within(b + arity..b + qlen * arity, b);
                        ns.data[b + (qlen - 1) * arity..b + qlen * arity].fill(0);
                        ns.data[coff + CHAN_QLEN] -= 1;
                        for (pat, &v) in pats.iter().zip(head) {
                            if let VmRecvArg::Bind(lv) = pat {
                                self.store(&mut ns.data, frame, *lv, v);
                            }
                        }
                        if *binds_watch && self.off_shard(&ns.data) {
                            self.note_prune();
                            return true;
                        }
                        self.finish_step(&mut ns, p, instr.next, instr.atomic_next);
                        pruned |= self.emit(ns, out, depth);
                    }
                }
                // rendezvous receives fire from the sender's side only
            }
            VmOp::Halt => {}
        }
        pruned
    }

    /// One combined rendezvous transition: sender `p` hands `msg` to
    /// receiver `q` at its receive instruction `rpc`.
    #[allow(clippy::too_many_arguments)]
    fn fire_rendezvous(
        &self,
        s: &VState,
        p: usize,
        sinstr: &VmInstr,
        q: usize,
        rpc: u32,
        msg: &[i32],
        out: &mut Vec<VState>,
        depth: u32,
    ) -> bool {
        let d = &s.data[..];
        let rinstr = self.instr_of(d, q, rpc);
        let VmOp::Recv(_, pats, binds_watch) = &rinstr.op else {
            unreachable!("walk_recvs only matches receive instructions")
        };
        let frame_q = self.frame_of(d, q);
        let mut ns = s.clone();
        for (pat, &v) in pats.iter().zip(msg) {
            if let VmRecvArg::Bind(lv) = pat {
                self.store(&mut ns.data, frame_q, *lv, v);
            }
        }
        if *binds_watch && self.off_shard(&ns.data) {
            self.note_prune();
            return true;
        }
        let poff = self.proc_off(&ns.data, p);
        ns.data[poff + PC] = sinstr.next as i32;
        let qoff = self.proc_off(&ns.data, q);
        ns.data[qoff + PC] = rinstr.next as i32;
        self.maybe_halt(&mut ns.data, p);
        self.maybe_halt(&mut ns.data, q);
        // SPIN passes control to the receiver inside atomic
        ns.data[EXCL] = if rinstr.atomic_next {
            q as i32
        } else if sinstr.atomic_next {
            p as i32
        } else {
            -1
        };
        self.emit(ns, out, depth)
    }

    fn initial_state(&self) -> VState {
        let src = &self.src;
        let mut data = Vec::with_capacity(
            HDR + self.nglobals
                + src.global_chans.len() * self.chan_stride
                + src.active.len() * self.frame_stride,
        );
        data.push(-1); // exclusive
        data.push(src.global_chans.len() as i32);
        data.push(src.active.len() as i32);
        data.extend_from_slice(&src.globals_init);
        for &(cap, arity) in &src.global_chans {
            let at = data.len();
            data.resize(at + self.chan_stride, 0);
            data[at] = cap as i32;
            data[at + CHAN_ARITY] = arity as i32;
        }
        for &a in &src.active {
            let at = data.len();
            data.resize(at + self.frame_stride, 0);
            data[at] = a as i32;
            data[at + PC] = self.procs[a as usize].entry as i32;
            data[at + ALIVE] = 1;
        }
        VState { data }
    }
}

fn lower_op(
    lw: &mut Lowerer,
    ins: &Instr,
    spec: Option<&Spec>,
    max_buf: &mut usize,
) -> Result<VmOp> {
    Ok(match &ins.op {
        Op::Guard(e) => VmOp::Guard(lw.lower_expr(e)?),
        Op::Assign(lv, e) => VmOp::Assign(lw.lower_lval(lv)?, lw.lower_expr(e)?),
        Op::Send(c, args) => {
            ensure!(
                args.len() <= MAX_ARGS,
                "send carries {} fields (VM limit {})",
                args.len(),
                MAX_ARGS
            );
            let c = lw.lower_expr(c)?;
            let args = args.iter().map(|a| lw.lower_expr(a)).collect::<Result<Vec<_>>>()?;
            VmOp::Send(c, args)
        }
        Op::Recv(c, pats) => {
            ensure!(
                pats.len() <= MAX_ARGS,
                "receive carries {} fields (VM limit {})",
                pats.len(),
                MAX_ARGS
            );
            let c = lw.lower_expr(c)?;
            let pats = pats
                .iter()
                .map(|a| {
                    Ok(match a {
                        CRecvArg::Bind(lv) => VmRecvArg::Bind(lw.lower_lval(lv)?),
                        CRecvArg::Match(e) => VmRecvArg::Match(lw.lower_expr(e)?),
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let binds_watch = spec.map_or(false, |sp| {
                pats.iter().any(|a| matches!(a, VmRecvArg::Bind(lv) if lval_watches(sp, lv)))
            });
            VmOp::Recv(c, pats, binds_watch)
        }
        Op::Select(lv, lo, hi) => {
            VmOp::Select(lw.lower_lval(lv)?, lw.lower_expr(lo)?, lw.lower_expr(hi)?)
        }
        Op::Branch(opts, els) => VmOp::Branch(opts.clone(), *els),
        Op::Run(pt, args) => {
            let args = args.iter().map(|a| lw.lower_expr(a)).collect::<Result<Vec<_>>>()?;
            VmOp::Run(*pt, args)
        }
        Op::NewChan(lv, cap, arity) => {
            ensure!(
                (*arity as usize) <= MAX_ARGS,
                "channel arity {} exceeds the VM limit {}",
                arity,
                MAX_ARGS
            );
            *max_buf = (*max_buf).max(*cap as usize * *arity as usize);
            VmOp::NewChan(lw.lower_lval(lv)?, *cap, *arity)
        }
        Op::Halt => VmOp::Halt,
    })
}

impl TransitionSystem for PromelaVm {
    type State = VState;

    fn initial_states(&self) -> Vec<VState> {
        vec![self.initial_state()]
    }

    fn successors(&self, s: &VState, out: &mut Vec<VState>) {
        out.clear();
        let d = &s.data[..];
        // exclusivity: if the exclusive process can move, only it moves
        if d[EXCL] >= 0 {
            let p = d[EXCL] as usize;
            if self.alive(d, p) {
                let pc = self.pc_of(d, p);
                let pruned = self.gen_from(s, p, pc, out);
                // `pruned` counts as "the process could move": the generic
                // wrapper would see its (filtered-away) successors and
                // keep exclusivity too, ending with the same empty set
                if !out.is_empty() || pruned {
                    return;
                }
            }
            // blocked inside atomic: exclusivity is lost (SPIN semantics)
        }
        for p in 0..self.nprocs(d) {
            if self.alive(d, p) {
                let pc = self.pc_of(d, p);
                self.gen_from(s, p, pc, out);
            }
        }
    }

    fn reduced_successors(&self, s: &VState, out: &mut Vec<VState>) -> bool {
        out.clear();
        let d = &s.data[..];
        // held exclusivity breaks independence — no ample selection
        if d[EXCL] >= 0 {
            self.successors(s, out);
            return false;
        }
        let a = self.analysis();
        for p in 0..self.nprocs(d) {
            if self.alive(d, p) {
                let off = self.proc_off(d, p);
                let pc = self.pc_of(d, p);
                if a.por_safe(d[off] as usize, pc) {
                    // ample-eligible ops never touch (WG, TS), so
                    // specialization cannot prune here — ignore the flag
                    let _ = self.gen_from(s, p, pc, out);
                    if !out.is_empty() {
                        return true;
                    }
                }
            }
        }
        self.successors(s, out);
        false
    }

    fn encode(&self, s: &VState, out: &mut Vec<u8>) {
        out.clear();
        out.reserve(s.data.len() * 4);
        for w in &s.data {
            out.extend_from_slice(&w.to_le_bytes());
        }
        if !self.dead_slots {
            return;
        }
        let d = &s.data[..];
        let a = self.analysis();
        let mut zeroed = 0u64;
        for p in 0..self.nprocs(d) {
            let off = self.proc_off(d, p);
            let frame = self.frame_of(d, p);
            let def = &self.src.procs[d[off] as usize];
            let live = (d[off + ALIVE] != 0)
                .then(|| a.live_at(d[off] as usize, d[off + PC] as u32));
            for i in 0..def.nlocals {
                if live.is_some_and(|lv| lv.contains(i)) {
                    continue;
                }
                // dead (or post-halt) slot: store the canonical image
                let b = (frame + i as usize) * 4;
                if out[b..b + 4] != [0u8; 4] {
                    zeroed += 1;
                    out[b..b + 4].copy_from_slice(&[0u8; 4]);
                }
            }
        }
        if zeroed > 0 {
            crate::obs::metrics().slots_canonicalized.add(zeroed);
        }
    }

    /// COLLAPSE region split: header+globals, one region per channel,
    /// one per process frame. The packed layout makes every region end a
    /// word boundary (`encode` writes one LE word per `data` slot), and
    /// the strides are compile-time constants, so the split is a pure
    /// function of the state header — exactly what the interning store
    /// requires. Frames repeat heavily across states (a process that did
    /// not move keeps its frame bytes), which is where the sharing comes
    /// from.
    fn encode_regions(&self, s: &VState, out: &mut Vec<u32>) {
        out.clear();
        let d = &s.data[..];
        let nchans = self.nchans(d);
        let nprocs = self.nprocs(d);
        out.reserve(1 + nchans + nprocs);
        out.push(((HDR + self.nglobals) * 4) as u32);
        for c in 0..nchans {
            out.push(((self.chan_off(c) + self.chan_stride) * 4) as u32);
        }
        let base = self.procs_base(d);
        for p in 0..nprocs {
            out.push(((base + (p + 1) * self.frame_stride) * 4) as u32);
        }
    }

    fn eval_var(&self, s: &VState, name: &str) -> Option<i64> {
        let v = self.src.global_syms.get(name)?;
        Some(s.data[HDR + v.offset as usize] as i64)
    }

    fn resolve_slot(&self, name: &str) -> Option<u32> {
        // slot id = offset into the packed globals, resolved once
        self.src.global_syms.get(name).map(|v| v.offset)
    }

    fn eval_slots(&self, s: &VState, ids: &[u32], out: &mut [i64]) -> u64 {
        for (i, &id) in ids.iter().enumerate() {
            out[i] = s.data[HDR + id as usize] as i64;
        }
        0
    }

    fn describe(&self, s: &VState) -> String {
        let d = &s.data[..];
        let pcs: Vec<String> = (0..self.nprocs(d))
            .map(|p| {
                let off = self.proc_off(d, p);
                let def = &self.src.procs[d[off] as usize];
                if d[off + ALIVE] != 0 {
                    format!("{}@{}", def.name, d[off + PC])
                } else {
                    format!("{}†", def.name)
                }
            })
            .collect();
        let mut globs: Vec<(&String, i64)> = self
            .src
            .global_syms
            .iter()
            .filter(|(_, v)| v.len == 1)
            .map(|(n, v)| (n, d[HDR + v.offset as usize] as i64))
            .collect();
        globs.sort();
        let gs: Vec<String> = globs.iter().map(|(n, v)| format!("{}={}", n, v)).collect();
        format!("[{}] {}", pcs.join(" "), gs.join(" "))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checker::{check, CheckOptions};
    use crate::model::SafetyLtl;

    fn vm(src: &str) -> PromelaVm {
        PromelaVm::from_source(src).expect("model compiles")
    }

    fn terminals(m: &PromelaVm) -> Vec<VState> {
        let p = SafetyLtl::parse("G(true)").unwrap();
        let rep = check(m, &p, &CheckOptions::default()).unwrap();
        assert!(rep.exhausted);
        let mut terminals = Vec::new();
        let mut seen = std::collections::HashSet::new();
        let mut stack = m.initial_states();
        let mut buf = Vec::new();
        while let Some(s) = stack.pop() {
            if !seen.insert(s.clone()) {
                continue;
            }
            m.successors(&s, &mut buf);
            if buf.is_empty() {
                terminals.push(s.clone());
            }
            stack.extend(buf.drain(..));
        }
        terminals
    }

    #[test]
    fn sequential_assignments_execute() {
        let m = vm("int a; int b; active proctype main() { a = 2; b = a + 3 }");
        let ts = terminals(&m);
        assert_eq!(ts.len(), 1);
        assert_eq!(m.eval_var(&ts[0], "a"), Some(2));
        assert_eq!(m.eval_var(&ts[0], "b"), Some(5));
    }

    #[test]
    fn select_branches_and_arrays() {
        let m = vm(
            "int x; byte i; int a[3]; active proctype main() {\
               select (i : 0 .. 2); a[i] = 7; x = a[i] * 10 }",
        );
        let ts = terminals(&m);
        let mut xs: Vec<i64> = ts.iter().map(|t| m.eval_var(t, "x").unwrap()).collect();
        xs.sort();
        assert_eq!(xs, vec![70, 70, 70]);
        assert_eq!(ts.len(), 3, "three distinct array states");
    }

    #[test]
    fn rendezvous_handshake() {
        let m = vm(
            "mtype = {go, done};\nchan c = [0] of {mtype};\nint got;\n\
             active proctype main() { run w(); c ! go; c ? done }\n\
             proctype w() { c ? go; got = 1; c ! done }",
        );
        let ts = terminals(&m);
        assert_eq!(ts.len(), 1);
        assert_eq!(m.eval_var(&ts[0], "got"), Some(1));
    }

    #[test]
    fn buffered_channel_fifo_and_truncation() {
        let m = vm(
            "chan c = [2] of {int};\nint a; int got;\n\
             active proctype main() { byte x; c ! 300; c ! 2; c ? x; got = x; c ? x; a = x }",
        );
        let ts = terminals(&m);
        assert_eq!(m.eval_var(&ts[0], "got"), Some((300 & 0xFF) as i64));
        assert_eq!(m.eval_var(&ts[0], "a"), Some(2));
    }

    #[test]
    fn local_chan_declaration_works() {
        let m = vm(
            "int got;\n\
             active proctype main() { chan c = [1] of {byte}; c ! 9; byte x; c ? x; got = x }",
        );
        let ts = terminals(&m);
        assert_eq!(ts.len(), 1);
        assert_eq!(m.eval_var(&ts[0], "got"), Some(9));
    }

    #[test]
    fn constant_folding_collapses_constant_expressions() {
        // `2 * 3 + 1` and a constant-true guard must lower to Const refs
        let m = vm("int x; active proctype main() { skip; x = 2 * 3 + 1 }");
        let code = &m.procs[0].code;
        assert!(code.iter().any(|i| matches!(i.op, VmOp::Guard(ExprRef::Const(1)))));
        assert!(code
            .iter()
            .any(|i| matches!(i.op, VmOp::Assign(_, ExprRef::Const(7)))));
        let ts = terminals(&m);
        assert_eq!(m.eval_var(&ts[0], "x"), Some(7));
    }

    #[test]
    fn folding_preserves_division_by_zero() {
        // 1/0 must stay a runtime error, not a compile-time panic
        let m = vm("int x; active proctype main() { x = 1 / 0 }");
        let init = m.initial_states().pop().unwrap();
        let mut out = Vec::new();
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            m.successors(&init, &mut out)
        }));
        assert!(r.is_err(), "division by zero must panic at evaluation time");
    }

    #[test]
    fn short_circuit_skips_division_by_zero() {
        let m = vm("int x; int z; active proctype main() { x = (z != 0 && 10 / z > 1) }");
        let ts = terminals(&m);
        assert_eq!(m.eval_var(&ts[0], "x"), Some(0));
    }

    #[test]
    fn atomic_chain_coalesces() {
        let m = vm(
            "int x;\nactive proctype main() { run a(); run b() }\n\
             proctype a() { int t; atomic { t = x; x = t + 1 } }\n\
             proctype b() { int t; atomic { t = x; x = t + 1 } }",
        );
        let ts = terminals(&m);
        let xs: std::collections::HashSet<i64> =
            ts.iter().map(|t| m.eval_var(t, "x").unwrap()).collect();
        assert_eq!(xs, [2i64].into_iter().collect());
    }

    #[test]
    fn specialization_prunes_at_the_choice_point() {
        // WG/TS chosen by selects through a shift — prune fires at the
        // assignment that commits the pair
        let src = "int WG; int TS; int done;\n\
             active proctype main() {\n\
               byte i; byte j;\n\
               select (i : 1 .. 2); WG = 1 << i;\n\
               select (j : 1 .. 2); TS = 1 << j;\n\
               done = 1\n\
             }";
        let full = PromelaVm::from_source(src).unwrap();
        let prog = super::super::parser::parse(src)
            .and_then(|m| super::super::compile::compile(&m))
            .unwrap();
        let narrow = PromelaVm::specialized(
            prog,
            Some(TuningBounds { wg_min: 4, wg_max: 4, ts_min: 0, ts_max: u32::MAX }),
        )
        .unwrap();
        assert!(narrow.is_specialized());

        let p = SafetyLtl::parse("G(true)").unwrap();
        let all = check(&full, &p, &CheckOptions::default()).unwrap();
        let shard = check(&narrow, &p, &CheckOptions::default()).unwrap();
        assert!(shard.stats.states_stored < all.stats.states_stored);
        // raw generation strictly dropped (compare before any further walk)
        assert!(narrow.generated() < full.generated());

        // every completed terminal in the shard carries WG == 4
        for t in terminals(&narrow) {
            if narrow.eval_var(&t, "done") == Some(1) {
                assert_eq!(narrow.eval_var(&t, "WG"), Some(4));
            }
        }
    }

    #[test]
    fn tuning_committed_at_init_detects_preset_models() {
        let committed = super::super::parser::parse(
            "int WG = 2; int TS = 2; active proctype main() { skip }",
        )
        .and_then(|m| super::super::compile::compile(&m))
        .unwrap();
        assert!(tuning_committed_at_init(&committed));
        let unset = super::super::parser::parse(
            "int WG; int TS; active proctype main() { skip }",
        )
        .and_then(|m| super::super::compile::compile(&m))
        .unwrap();
        assert!(!tuning_committed_at_init(&unset));
    }

    #[test]
    fn packed_layout_roundtrips_header() {
        let m = vm("int a = 5; active proctype main() { run w() }\nproctype w() { skip }");
        let init = m.initial_state();
        assert_eq!(init.data[EXCL], -1);
        assert_eq!(init.data[NCHANS], 0);
        assert_eq!(init.data[NPROCS], 1);
        assert_eq!(m.eval_var(&init, "a"), Some(5));
        let mut out = Vec::new();
        m.successors(&init, &mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].data[NPROCS], 2, "run appends a frame");
        let mut enc = Vec::new();
        m.encode(&out[0], &mut enc);
        assert_eq!(enc.len(), out[0].data.len() * 4);
    }

    #[test]
    fn encode_regions_covers_the_packed_layout() {
        let m = vm(
            "int a;\nchan c = [1] of {byte};\n\
             active proctype main() { c ! 1; run w() }\nproctype w() { skip }",
        );
        let init = m.initial_state();
        let mut enc = Vec::new();
        m.encode(&init, &mut enc);
        let mut bounds = Vec::new();
        m.encode_regions(&init, &mut bounds);
        // header+globals, one channel region, one frame region
        assert_eq!(bounds.len(), 3);
        assert!(bounds.windows(2).all(|w| w[0] < w[1]), "ascending: {:?}", bounds);
        assert_eq!(*bounds.last().unwrap() as usize, enc.len());

        // the split tracks the state, not the program: run() adds a frame
        let mut succ = Vec::new();
        m.successors(&init, &mut succ);
        let grown = succ.iter().find(|s| s.data[NPROCS] == 2).unwrap();
        m.encode_regions(grown, &mut bounds);
        assert_eq!(bounds.len(), 4);
    }

    #[test]
    fn pooled_clone_is_observably_identical() {
        let m = vm("int a; active proctype main() { a = 1 }");
        let init = m.initial_state();
        let c = init.clone();
        assert_eq!(init, c);
        drop(c); // retires the buffer to the thread-local pool
        let c2 = init.clone(); // reuses it
        assert_eq!(init, c2);
        let mut e1 = Vec::new();
        let mut e2 = Vec::new();
        m.encode(&init, &mut e1);
        m.encode(&c2, &mut e2);
        assert_eq!(e1, e2);
    }
}
