//! Operational semantics: the compiled Promela program as a
//! [`TransitionSystem`] with full process interleaving.
//!
//! Semantics notes (standard Promela, with documented simplifications):
//! - a statement is *executable* or blocked; `if`/`do` options follow the
//!   first-statement rule, `else` fires iff no sibling option is
//!   executable;
//! - rendezvous (capacity-0) channels hand over in a single combined
//!   transition, generated from the sender's side; a receive is
//!   "executable" for option-selection purposes iff a matching sender is
//!   ready (and vice versa);
//! - `atomic` keeps exclusivity while the marked instruction chain stays
//!   executable; blocking inside an atomic releases exclusivity (as in
//!   SPIN); after a rendezvous, exclusivity follows the receiver if it is
//!   inside an atomic, else the sender's flag (SPIN passes control to the
//!   receiver);
//! - processes die immediately at the end of their body (we do not model
//!   SPIN's creation-order death rule — the paper's models never rely on
//!   it);
//! - arithmetic is i32 with wrapping semantics; every *store* (assignment,
//!   increment, `select`, receive bind, run-argument bind) truncates to
//!   the declared width (`bit`/`byte`/`short`/`int`, see
//!   [`super::compile::VarType`]) exactly as SPIN does, so models that
//!   wrap agree with SPIN. Channel message fields are untyped and stay
//!   untruncated until received into a typed variable.
//!
//! This tree-walking interpreter is the **reference implementation**: the
//! production engine is the bytecode VM over flat packed states
//! ([`super::vm::PromelaVm`]), whose semantics the differential suite
//! (`rust/tests/promela_vm.rs`) pins to this file state-for-state.

use super::compile::{CExpr, CLVal, CRecvArg, Instr, Op, Program, Slot};
use crate::model::TransitionSystem;
use crate::util::error::Result;
use crate::util::hash::hash_bytes;

/// Content hash of a Promela source text — the identity under which the
/// coordinator caches `engine: promela` tuning results (see
/// `coordinator::job::TuningJob::cache_desc`): any edit to a model yields
/// a new hash, so stale cache entries are unreachable by construction.
pub fn source_hash(src: &str) -> u64 {
    hash_bytes(src.as_bytes())
}

pub const MAX_PROCS: usize = 64;
/// Fan-out clamp on `select` ranges, shared with the VM so both engines
/// enumerate identical choice sets.
pub(crate) const MAX_SELECT_FANOUT: i32 = 4096;

#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ChanState {
    pub cap: u16,
    pub arity: u16,
    /// flattened message queue (len = arity * nmsgs)
    pub buf: Vec<i32>,
}

#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ProcState {
    pub ptype: u16,
    pub pc: u32,
    pub alive: bool,
    pub locals: Vec<i32>,
}

#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct PState {
    pub globals: Vec<i32>,
    pub chans: Vec<ChanState>,
    pub procs: Vec<ProcState>,
    /// process holding atomic exclusivity (-1 = none)
    pub exclusive: i16,
}

/// A compiled Promela model, ready for the checker.
pub struct PromelaSystem {
    pub prog: Program,
    /// SPIN-style atomic merging: an `atomic { ... }` chain executes as a
    /// single transition (intermediate states are not emitted) as long as
    /// it stays executable. This is both closer to SPIN's semantics and
    /// the interpreter's main optimization (§Perf: ~5x fewer states on the
    /// paper's models). Disable for instruction-level debugging.
    pub coalesce_atomic: bool,
    /// opt-in dead-slot reduction: canonicalize provably dead local slots
    /// to zero in `encode` so garbage-only state differences hash alike
    dead_slots: bool,
    /// lazily-built static tables (liveness + POR eligibility); default
    /// runs never touch this, so construction stays free
    analysis: std::sync::OnceLock<super::analysis::Analysis>,
}

/// Bound on coalesced atomic chains — a guard against `do`-loops inside
/// `atomic` that never block (would otherwise hang successor generation).
const MAX_ATOMIC_CHAIN: u32 = 4096;

impl PromelaSystem {
    pub fn new(prog: Program) -> Self {
        Self {
            prog,
            coalesce_atomic: true,
            dead_slots: false,
            analysis: std::sync::OnceLock::new(),
        }
    }

    pub fn from_source(src: &str) -> Result<Self> {
        let model = super::parser::parse(src)?;
        Ok(Self::new(super::compile::compile(&model)?))
    }

    /// Instruction-level variant (every atomic step is a visible state).
    pub fn without_atomic_coalescing(mut self) -> Self {
        self.coalesce_atomic = false;
        self
    }

    /// Opt-in `--reduce dead-slots`: `encode` zeroes local slots that are
    /// provably dead at the process's pc (and every local of a terminated
    /// process) before hashing. Verdict-, optimum- and trail-preserving —
    /// raw states are untouched, only their stored image is canonical —
    /// with `states_stored` ≤ the unreduced run.
    pub fn with_dead_slot_reduction(mut self) -> Self {
        self.dead_slots = true;
        self
    }

    /// Static analysis tables, built on first use.
    fn analysis(&self) -> &super::analysis::Analysis {
        self.analysis.get_or_init(|| super::analysis::Analysis::of(&self.prog))
    }

    /// Emit `ns`, or — when it is mid-atomic and its owner can move —
    /// continue executing the owner so the whole atomic chain becomes one
    /// transition (SPIN semantics).
    fn push_or_continue(&self, ns: PState, out: &mut Vec<PState>, depth: u32) {
        if self.coalesce_atomic && depth < MAX_ATOMIC_CHAIN && ns.exclusive >= 0 {
            let p = ns.exclusive as usize;
            if ns.procs[p].alive && self.enabled(&ns, p, ns.procs[p].pc) {
                let before = out.len();
                let pc = ns.procs[p].pc;
                self.gen_from_d(&ns, p, pc, out, depth + 1);
                if out.len() > before {
                    return;
                }
            }
        }
        out.push(ns);
    }

    fn code(&self, p: &ProcState) -> &[Instr] {
        &self.prog.procs[p.ptype as usize].code
    }

    // ---------------------------------------------------------- expr eval --

    fn load(&self, st: &PState, proc: usize, slot: Slot) -> i32 {
        match slot {
            Slot::Global(o) => st.globals[o as usize],
            Slot::Local(o) => st.procs[proc].locals[o as usize],
        }
    }

    fn eval(&self, st: &PState, proc: usize, e: &CExpr) -> i32 {
        use super::ast::{PBinOp as B, UnOp};
        match e {
            CExpr::Num(n) => *n,
            CExpr::Load(s) => self.load(st, proc, *s),
            CExpr::LoadElem(s, len, idx) => {
                let i = self.eval(st, proc, idx);
                assert!(
                    i >= 0 && (i as u32) < *len,
                    "array index {} out of bounds 0..{}",
                    i,
                    len
                );
                match s {
                    Slot::Global(o) => st.globals[*o as usize + i as usize],
                    Slot::Local(o) => st.procs[proc].locals[*o as usize + i as usize],
                }
            }
            CExpr::Un(UnOp::Not, a) => (self.eval(st, proc, a) == 0) as i32,
            CExpr::Un(UnOp::Neg, a) => self.eval(st, proc, a).wrapping_neg(),
            CExpr::Bin(op, a, b) => {
                let x = self.eval(st, proc, a);
                match op {
                    B::And => return ((x != 0) && (self.eval(st, proc, b) != 0)) as i32,
                    B::Or => return ((x != 0) || (self.eval(st, proc, b) != 0)) as i32,
                    _ => {}
                }
                let y = self.eval(st, proc, b);
                match op {
                    B::Add => x.wrapping_add(y),
                    B::Sub => x.wrapping_sub(y),
                    B::Mul => x.wrapping_mul(y),
                    B::Div => {
                        assert!(y != 0, "division by zero in model");
                        x.wrapping_div(y)
                    }
                    B::Mod => {
                        assert!(y != 0, "mod by zero in model");
                        x.wrapping_rem(y)
                    }
                    B::Shl => x.wrapping_shl(y as u32 & 31),
                    B::Shr => x.wrapping_shr(y as u32 & 31),
                    B::Eq => (x == y) as i32,
                    B::Ne => (x != y) as i32,
                    B::Lt => (x < y) as i32,
                    B::Le => (x <= y) as i32,
                    B::Gt => (x > y) as i32,
                    B::Ge => (x >= y) as i32,
                    B::And | B::Or => unreachable!(),
                }
            }
            CExpr::Cond(c, a, b) => {
                if self.eval(st, proc, c) != 0 {
                    self.eval(st, proc, a)
                } else {
                    self.eval(st, proc, b)
                }
            }
        }
    }

    fn store(&self, st: &mut PState, proc: usize, lv: &CLVal, v: i32) {
        match lv {
            CLVal::Scalar(Slot::Global(o), ty) => st.globals[*o as usize] = ty.truncate(v),
            CLVal::Scalar(Slot::Local(o), ty) => {
                st.procs[proc].locals[*o as usize] = ty.truncate(v)
            }
            CLVal::Elem(s, len, idx, ty) => {
                let i = self.eval(st, proc, idx);
                assert!(i >= 0 && (i as u32) < *len, "array store out of bounds");
                let v = ty.truncate(v);
                match s {
                    Slot::Global(o) => st.globals[*o as usize + i as usize] = v,
                    Slot::Local(o) => st.procs[proc].locals[*o as usize + i as usize] = v,
                }
            }
        }
    }

    // ------------------------------------------------------- executability --

    /// Is the instruction at (proc, pc) executable in `st`?
    fn enabled(&self, st: &PState, proc: usize, pc: u32) -> bool {
        let instr = &self.code(&st.procs[proc])[pc as usize];
        match &instr.op {
            Op::Guard(e) => self.eval(st, proc, e) != 0,
            Op::Assign(..) | Op::NewChan(..) => true,
            Op::Select(_, lo, hi) => self.eval(st, proc, lo) <= self.eval(st, proc, hi),
            Op::Run(..) => st.procs.len() < MAX_PROCS,
            Op::Send(c, args) => {
                let cid = self.eval(st, proc, c) as usize;
                let ch = &st.chans[cid];
                if ch.cap > 0 {
                    (ch.buf.len() / ch.arity.max(1) as usize) < ch.cap as usize
                } else {
                    let msg: Vec<i32> = args.iter().map(|a| self.eval(st, proc, a)).collect();
                    self.find_ready_recvs(st, proc, cid, &msg).next_some()
                }
            }
            Op::Recv(c, pats) => {
                let cid = self.eval(st, proc, c) as usize;
                let ch = &st.chans[cid];
                if ch.cap > 0 {
                    if ch.buf.len() < ch.arity as usize {
                        return false;
                    }
                    self.msg_matches(st, proc, pats, &ch.buf[..ch.arity as usize])
                } else {
                    self.find_ready_sends(st, proc, cid, pats).next_some()
                }
            }
            Op::Branch(opts, els) => {
                opts.iter().any(|&o| self.enabled(st, proc, o))
                    || els.map_or(false, |e| self.enabled(st, proc, e))
            }
            Op::Halt => false,
        }
    }

    fn msg_matches(&self, st: &PState, proc: usize, pats: &[CRecvArg], msg: &[i32]) -> bool {
        pats.iter().zip(msg).all(|(p, &v)| match p {
            CRecvArg::Bind(_) => true,
            CRecvArg::Match(e) => self.eval(st, proc, e) == v,
        })
    }

    /// All (other) processes whose current instruction tree contains a
    /// matching rendezvous receive on `cid` for message `msg`.
    fn find_ready_recvs(
        &self,
        st: &PState,
        sender: usize,
        cid: usize,
        msg: &[i32],
    ) -> Matches {
        let mut out = Vec::new();
        for q in 0..st.procs.len() {
            if q == sender || !st.procs[q].alive {
                continue;
            }
            self.collect_recv_pcs(st, q, st.procs[q].pc, cid, msg, &mut out);
        }
        Matches(out)
    }

    fn collect_recv_pcs(
        &self,
        st: &PState,
        q: usize,
        pc: u32,
        cid: usize,
        msg: &[i32],
        out: &mut Vec<(usize, u32)>,
    ) {
        match &self.code(&st.procs[q])[pc as usize].op {
            Op::Recv(c, pats) => {
                let ch = self.eval(st, q, c) as usize;
                if ch == cid
                    && st.chans[cid].cap == 0
                    && pats.len() == msg.len()
                    && self.msg_matches(st, q, pats, msg)
                {
                    out.push((q, pc));
                }
            }
            Op::Branch(opts, els) => {
                for &o in opts {
                    self.collect_recv_pcs(st, q, o, cid, msg, out);
                }
                // an `else` option never opens with a receive in practice;
                // honour it anyway only if no option matched (Promela rule)
                if let Some(e) = els {
                    if out.is_empty() {
                        self.collect_recv_pcs(st, q, *e, cid, msg, out);
                    }
                }
            }
            _ => {}
        }
    }

    /// All (other) processes ready to *send* a matching message on `cid`
    /// (used only for the executability of a receive heading an option).
    fn find_ready_sends(&self, st: &PState, recver: usize, cid: usize, pats: &[CRecvArg]) -> Matches {
        let mut out = Vec::new();
        for q in 0..st.procs.len() {
            if q == recver || !st.procs[q].alive {
                continue;
            }
            self.collect_send_pcs(st, recver, q, st.procs[q].pc, cid, pats, &mut out);
        }
        Matches(out)
    }

    #[allow(clippy::too_many_arguments)]
    fn collect_send_pcs(
        &self,
        st: &PState,
        recver: usize,
        q: usize,
        pc: u32,
        cid: usize,
        pats: &[CRecvArg],
        out: &mut Vec<(usize, u32)>,
    ) {
        match &self.code(&st.procs[q])[pc as usize].op {
            Op::Send(c, args) => {
                let ch = self.eval(st, q, c) as usize;
                if ch == cid && st.chans[cid].cap == 0 && args.len() == pats.len() {
                    let msg: Vec<i32> = args.iter().map(|a| self.eval(st, q, a)).collect();
                    if self.msg_matches(st, recver, pats, &msg) {
                        out.push((q, pc));
                    }
                }
            }
            Op::Branch(opts, els) => {
                for &o in opts {
                    self.collect_send_pcs(st, recver, q, o, cid, pats, out);
                }
                if let Some(e) = els {
                    if out.is_empty() {
                        self.collect_send_pcs(st, recver, q, *e, cid, pats, out);
                    }
                }
            }
            _ => {}
        }
    }

    // --------------------------------------------------------- transitions --

    /// Generate all transitions of process `p` from instruction `pc`
    /// (flattening Branch per the first-statement rule).
    fn gen_from(&self, st: &PState, p: usize, pc: u32, out: &mut Vec<PState>) {
        self.gen_from_d(st, p, pc, out, 0)
    }

    fn gen_from_d(&self, st: &PState, p: usize, pc: u32, out: &mut Vec<PState>, depth: u32) {
        let instr = &self.code(&st.procs[p])[pc as usize];
        let after = |ns: &mut PState, atomic_next: bool| {
            ns.exclusive = if atomic_next { p as i16 } else { -1 };
        };
        match &instr.op {
            Op::Branch(opts, els) => {
                let mut any = false;
                for &o in opts {
                    if self.enabled(st, p, o) {
                        any = true;
                        self.gen_from_d(st, p, o, out, depth);
                    }
                }
                if !any {
                    if let Some(e) = els {
                        if self.enabled(st, p, *e) {
                            self.gen_from_d(st, p, *e, out, depth);
                        }
                    }
                }
            }
            Op::Guard(e) => {
                if self.eval(st, p, e) != 0 {
                    let mut ns = st.clone();
                    ns.procs[p].pc = instr.next;
                    self.maybe_halt(&mut ns, p);
                    after(&mut ns, instr.atomic_next);
                    self.push_or_continue(ns, out, depth);
                }
            }
            Op::Assign(lv, e) => {
                let v = self.eval(st, p, e);
                let mut ns = st.clone();
                self.store(&mut ns, p, lv, v);
                ns.procs[p].pc = instr.next;
                self.maybe_halt(&mut ns, p);
                after(&mut ns, instr.atomic_next);
                self.push_or_continue(ns, out, depth);
            }
            Op::NewChan(lv, cap, arity) => {
                let mut ns = st.clone();
                let id = ns.chans.len() as i32;
                ns.chans.push(ChanState { cap: *cap, arity: *arity, buf: Vec::new() });
                self.store(&mut ns, p, lv, id);
                ns.procs[p].pc = instr.next;
                self.maybe_halt(&mut ns, p);
                after(&mut ns, instr.atomic_next);
                self.push_or_continue(ns, out, depth);
            }
            Op::Select(lv, lo, hi) => {
                let (l, h) = (self.eval(st, p, lo), self.eval(st, p, hi));
                let h = h.min(l.saturating_add(MAX_SELECT_FANOUT));
                for v in l..=h {
                    let mut ns = st.clone();
                    self.store(&mut ns, p, lv, v);
                    ns.procs[p].pc = instr.next;
                    self.maybe_halt(&mut ns, p);
                    after(&mut ns, instr.atomic_next);
                    self.push_or_continue(ns, out, depth);
                }
            }
            Op::Run(pt, args) => {
                if st.procs.len() >= MAX_PROCS {
                    return;
                }
                let def = &self.prog.procs[*pt as usize];
                let mut locals = vec![0i32; def.nlocals as usize];
                for (i, a) in args.iter().enumerate().take(def.nparams as usize) {
                    locals[i] = def.param_types[i].truncate(self.eval(st, p, a));
                }
                let mut ns = st.clone();
                ns.procs.push(ProcState {
                    ptype: *pt as u16,
                    pc: def.entry,
                    alive: true,
                    locals,
                });
                // entry could itself be a Halt (empty body)
                let np = ns.procs.len() - 1;
                self.maybe_halt(&mut ns, np);
                ns.procs[p].pc = instr.next;
                self.maybe_halt(&mut ns, p);
                after(&mut ns, instr.atomic_next);
                self.push_or_continue(ns, out, depth);
            }
            Op::Send(c, args) => {
                let cid = self.eval(st, p, c) as usize;
                let msg: Vec<i32> = args.iter().map(|a| self.eval(st, p, a)).collect();
                let ch = &st.chans[cid];
                if ch.cap > 0 {
                    if (ch.buf.len() / ch.arity.max(1) as usize) < ch.cap as usize {
                        let mut ns = st.clone();
                        ns.chans[cid].buf.extend_from_slice(&msg);
                        ns.procs[p].pc = instr.next;
                        self.maybe_halt(&mut ns, p);
                        after(&mut ns, instr.atomic_next);
                        self.push_or_continue(ns, out, depth);
                    }
                } else {
                    // rendezvous: one combined transition per ready receiver
                    for (q, rpc) in self.find_ready_recvs(st, p, cid, &msg).0 {
                        let rinstr = &self.code(&st.procs[q])[rpc as usize];
                        let pats = match &rinstr.op {
                            Op::Recv(_, pats) => pats.clone(),
                            _ => unreachable!(),
                        };
                        let mut ns = st.clone();
                        for (pat, &v) in pats.iter().zip(&msg) {
                            if let CRecvArg::Bind(lv) = pat {
                                self.store(&mut ns, q, lv, v);
                            }
                        }
                        ns.procs[p].pc = instr.next;
                        ns.procs[q].pc = rinstr.next;
                        self.maybe_halt(&mut ns, p);
                        self.maybe_halt(&mut ns, q);
                        // SPIN passes control to the receiver inside atomic
                        ns.exclusive = if rinstr.atomic_next {
                            q as i16
                        } else if instr.atomic_next {
                            p as i16
                        } else {
                            -1
                        };
                        self.push_or_continue(ns, out, depth);
                    }
                }
            }
            Op::Recv(c, pats) => {
                let cid = self.eval(st, p, c) as usize;
                let ch = &st.chans[cid];
                if ch.cap > 0 && ch.buf.len() >= ch.arity as usize {
                    let head: Vec<i32> = ch.buf[..ch.arity as usize].to_vec();
                    if self.msg_matches(st, p, pats, &head) {
                        let mut ns = st.clone();
                        ns.chans[cid].buf.drain(..ch.arity as usize);
                        for (pat, &v) in pats.iter().zip(&head) {
                            if let CRecvArg::Bind(lv) = pat {
                                self.store(&mut ns, p, lv, v);
                            }
                        }
                        ns.procs[p].pc = instr.next;
                        self.maybe_halt(&mut ns, p);
                        after(&mut ns, instr.atomic_next);
                        self.push_or_continue(ns, out, depth);
                    }
                }
                // rendezvous receives fire from the sender's side only
            }
            Op::Halt => {}
        }
    }

    /// Kill the process if its pc reached Halt.
    fn maybe_halt(&self, st: &mut PState, p: usize) {
        let pc = st.procs[p].pc;
        if matches!(self.code(&st.procs[p])[pc as usize].op, Op::Halt) {
            st.procs[p].alive = false;
            if st.exclusive == p as i16 {
                st.exclusive = -1;
            }
        }
    }
}

/// tiny helper so `enabled` can ask "any match?" without allocating twice
struct Matches(Vec<(usize, u32)>);

impl Matches {
    fn next_some(&self) -> bool {
        !self.0.is_empty()
    }
}

impl TransitionSystem for PromelaSystem {
    type State = PState;

    fn initial_states(&self) -> Vec<PState> {
        let chans = self
            .prog
            .global_chans
            .iter()
            .map(|&(cap, arity)| ChanState { cap, arity, buf: Vec::new() })
            .collect();
        let mut procs = Vec::new();
        for &a in &self.prog.active {
            let def = &self.prog.procs[a as usize];
            procs.push(ProcState {
                ptype: a as u16,
                pc: def.entry,
                alive: true,
                locals: vec![0i32; def.nlocals as usize],
            });
        }
        vec![PState { globals: self.prog.globals_init.clone(), chans, procs, exclusive: -1 }]
    }

    fn successors(&self, s: &PState, out: &mut Vec<PState>) {
        out.clear();
        // exclusivity: if the exclusive process can move, only it moves
        if s.exclusive >= 0 {
            let p = s.exclusive as usize;
            if s.procs[p].alive {
                self.gen_from(s, p, s.procs[p].pc, out);
                if !out.is_empty() {
                    crate::obs::metrics().interp_generated.add(out.len() as u64);
                    return;
                }
            }
            // blocked inside atomic: exclusivity is lost (SPIN semantics)
        }
        for p in 0..s.procs.len() {
            if s.procs[p].alive {
                self.gen_from(s, p, s.procs[p].pc, out);
            }
        }
        crate::obs::metrics().interp_generated.add(out.len() as u64);
    }

    fn reduced_successors(&self, s: &PState, out: &mut Vec<PState>) -> bool {
        out.clear();
        // inside an atomic chain only the owner moves anyway — and its
        // held exclusivity is exactly what breaks independence, so no
        // ample selection applies
        if s.exclusive >= 0 {
            self.successors(s, out);
            return false;
        }
        let a = self.analysis();
        for p in 0..s.procs.len() {
            let pr = &s.procs[p];
            if pr.alive && a.por_safe(pr.ptype as usize, pr.pc) {
                self.gen_from(s, p, pr.pc, out);
                if !out.is_empty() {
                    crate::obs::metrics().interp_generated.add(out.len() as u64);
                    return true;
                }
            }
        }
        self.successors(s, out);
        false
    }

    fn encode(&self, s: &PState, out: &mut Vec<u8>) {
        out.clear();
        out.push(s.exclusive as u8);
        out.push(s.procs.len() as u8);
        for g in &s.globals {
            out.extend_from_slice(&g.to_le_bytes());
        }
        for c in &s.chans {
            out.push(c.buf.len() as u8);
            for v in &c.buf {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        let mut zeroed = 0u64;
        for p in &s.procs {
            out.push(p.ptype as u8);
            out.push(p.alive as u8);
            out.extend_from_slice(&p.pc.to_le_bytes());
            if !self.dead_slots {
                for l in &p.locals {
                    out.extend_from_slice(&l.to_le_bytes());
                }
                continue;
            }
            let live =
                p.alive.then(|| self.analysis().live_at(p.ptype as usize, p.pc));
            for (i, l) in p.locals.iter().enumerate() {
                if live.is_some_and(|lv| lv.contains(i as u32)) {
                    out.extend_from_slice(&l.to_le_bytes());
                } else {
                    // dead (or post-halt) slot: store the canonical image
                    zeroed += u64::from(*l != 0);
                    out.extend_from_slice(&0i32.to_le_bytes());
                }
            }
        }
        if zeroed > 0 {
            crate::obs::metrics().slots_canonicalized.add(zeroed);
        }
    }

    fn eval_var(&self, s: &PState, name: &str) -> Option<i64> {
        let v = self.prog.global_syms.get(name)?;
        Some(s.globals[v.offset as usize] as i64)
    }

    fn resolve_slot(&self, name: &str) -> Option<u32> {
        // slot id = offset into the flat globals array, resolved once
        self.prog.global_syms.get(name).map(|v| v.offset)
    }

    fn eval_slots(&self, s: &PState, ids: &[u32], out: &mut [i64]) -> u64 {
        for (i, &id) in ids.iter().enumerate() {
            out[i] = s.globals[id as usize] as i64;
        }
        0
    }

    fn describe(&self, s: &PState) -> String {
        let pcs: Vec<String> = s
            .procs
            .iter()
            .map(|p| {
                let def = &self.prog.procs[p.ptype as usize];
                if p.alive {
                    format!("{}@{}", def.name, p.pc)
                } else {
                    format!("{}†", def.name)
                }
            })
            .collect();
        let mut globs: Vec<(&String, i64)> = self
            .prog
            .global_syms
            .iter()
            .filter(|(_, v)| v.len == 1)
            .map(|(n, v)| (n, s.globals[v.offset as usize] as i64))
            .collect();
        globs.sort();
        let gs: Vec<String> = globs.iter().map(|(n, v)| format!("{}={}", n, v)).collect();
        format!("[{}] {}", pcs.join(" "), gs.join(" "))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checker::{check, CheckOptions};
    use crate::model::SafetyLtl;

    fn sys(src: &str) -> PromelaSystem {
        PromelaSystem::from_source(src).expect("model compiles")
    }

    /// Run to all terminal states, return their `describe` set sizes etc.
    fn reachable_terminals(m: &PromelaSystem) -> Vec<PState> {
        let p = SafetyLtl::parse("G(true)").unwrap();
        let rep = check(m, &p, &CheckOptions::default()).unwrap();
        assert!(rep.exhausted);
        // re-walk to collect terminals
        let mut terminals = Vec::new();
        let mut seen = std::collections::HashSet::new();
        let mut stack = m.initial_states();
        let mut buf = Vec::new();
        let mut enc = Vec::new();
        while let Some(s) = stack.pop() {
            m.encode(&s, &mut enc);
            if !seen.insert(enc.clone()) {
                continue;
            }
            m.successors(&s, &mut buf);
            if buf.is_empty() {
                terminals.push(s.clone());
            }
            stack.extend(buf.drain(..));
        }
        terminals
    }

    #[test]
    fn sequential_assignments_execute() {
        let m = sys("int a; int b; active proctype main() { a = 2; b = a + 3 }");
        let ts = reachable_terminals(&m);
        assert_eq!(ts.len(), 1);
        assert_eq!(m.eval_var(&ts[0], "a"), Some(2));
        assert_eq!(m.eval_var(&ts[0], "b"), Some(5));
    }

    #[test]
    fn select_branches() {
        let m = sys("int x; byte i; active proctype main() { select (i : 1 .. 3); x = i * 10 }");
        let ts = reachable_terminals(&m);
        let mut xs: Vec<i64> = ts.iter().map(|t| m.eval_var(t, "x").unwrap()).collect();
        xs.sort();
        assert_eq!(xs, vec![10, 20, 30]);
    }

    #[test]
    fn do_loop_with_break() {
        let m = sys("int i; active proctype main() { do :: i < 5 -> i++ :: else -> break od }");
        let ts = reachable_terminals(&m);
        assert_eq!(ts.len(), 1);
        assert_eq!(m.eval_var(&ts[0], "i"), Some(5));
    }

    #[test]
    fn for_loop_sums() {
        let m = sys(
            "int s; byte k; active proctype main() { for (k : 1 .. 4) { s = s + k } }",
        );
        let ts = reachable_terminals(&m);
        assert_eq!(m.eval_var(&ts[0], "s"), Some(10));
    }

    #[test]
    fn arrays_work() {
        let m = sys(
            "int a[4]; int s; byte i; active proctype main() {\
               for (i : 0 .. 3) { a[i] = i * i }\
               for (i : 0 .. 3) { s = s + a[i] } }",
        );
        let ts = reachable_terminals(&m);
        assert_eq!(m.eval_var(&ts[0], "s"), Some(0 + 1 + 4 + 9));
    }

    #[test]
    fn rendezvous_handshake() {
        let m = sys(
            "mtype = {go, done};\nchan c = [0] of {mtype};\nint got;\n\
             active proctype main() { run w(); c ! go; c ? done }\n\
             proctype w() { c ? go; got = 1; c ! done }",
        );
        let ts = reachable_terminals(&m);
        assert_eq!(ts.len(), 1);
        assert_eq!(m.eval_var(&ts[0], "got"), Some(1));
        // all processes ended
        assert!(ts[0].procs.iter().all(|p| !p.alive));
    }

    #[test]
    fn rendezvous_value_passing() {
        let m = sys(
            "chan c = [0] of {byte, byte};\nint sum;\n\
             active proctype main() { run w(); c ! 3, 4 }\n\
             proctype w() { byte a; byte b; c ? a, b; sum = a + b }",
        );
        let ts = reachable_terminals(&m);
        assert_eq!(m.eval_var(&ts[0], "sum"), Some(7));
    }

    #[test]
    fn rendezvous_match_filters() {
        // receiver matching `stop` must not accept `go`
        let m = sys(
            "mtype = {go, stop};\nchan c = [0] of {mtype};\nint path;\n\
             active proctype main() { run w(); c ! go }\n\
             proctype w() { if :: c ? go -> path = 1 :: c ? stop -> path = 2 fi }",
        );
        let ts = reachable_terminals(&m);
        assert_eq!(ts.len(), 1);
        assert_eq!(m.eval_var(&ts[0], "path"), Some(1));
    }

    #[test]
    fn buffered_channel_fifo() {
        let m = sys(
            "chan c = [2] of {byte};\nint a; int b;\n\
             active proctype main() { c ! 1; c ! 2; run w() }\n\
             proctype w() { byte x; c ? x; a = x; c ? x; b = x }",
        );
        let ts = reachable_terminals(&m);
        assert_eq!(m.eval_var(&ts[0], "a"), Some(1));
        assert_eq!(m.eval_var(&ts[0], "b"), Some(2));
    }

    #[test]
    fn else_fires_only_when_blocked() {
        let m = sys(
            "int x = 1; int r;\n\
             active proctype main() { if :: x == 1 -> r = 10 :: else -> r = 20 fi }",
        );
        let ts = reachable_terminals(&m);
        assert_eq!(ts.len(), 1);
        assert_eq!(m.eval_var(&ts[0], "r"), Some(10));
    }

    #[test]
    fn interleaving_explores_both_orders() {
        // two writers race; both final values must be reachable
        let m = sys(
            "int x;\n\
             active proctype main() { run a(); run b() }\n\
             proctype a() { x = 1 }\n\
             proctype b() { x = 2 }",
        );
        let ts = reachable_terminals(&m);
        let mut xs: Vec<i64> = ts.iter().map(|t| m.eval_var(t, "x").unwrap()).collect();
        xs.sort();
        xs.dedup();
        assert_eq!(xs, vec![1, 2]);
    }

    #[test]
    fn atomic_suppresses_interleaving() {
        // with the increment pair atomic, the lost-update outcome vanishes
        let src_atomic = "int x;\n\
             active proctype main() { run a(); run b() }\n\
             proctype a() { int t; atomic { t = x; x = t + 1 } }\n\
             proctype b() { int t; atomic { t = x; x = t + 1 } }";
        let m = sys(src_atomic);
        let ts = reachable_terminals(&m);
        let xs: std::collections::HashSet<i64> =
            ts.iter().map(|t| m.eval_var(t, "x").unwrap()).collect();
        assert_eq!(xs, [2i64].into_iter().collect(), "atomic increments cannot lose updates");

        // without atomic, x == 1 (lost update) is also reachable
        let src_racy = src_atomic.replace("atomic { t = x; x = t + 1 }", "t = x; x = t + 1");
        let m2 = sys(&src_racy);
        let ts2 = reachable_terminals(&m2);
        let xs2: std::collections::HashSet<i64> =
            ts2.iter().map(|t| m2.eval_var(t, "x").unwrap()).collect();
        assert!(xs2.contains(&1), "racy version must expose the lost update");
        assert!(xs2.contains(&2));
    }

    #[test]
    fn blocking_guard_waits_for_other_process() {
        let m = sys(
            "int flag; int r;\n\
             active proctype main() { run setter(); flag == 1; r = 99 }\n\
             proctype setter() { flag = 1 }",
        );
        let ts = reachable_terminals(&m);
        assert_eq!(ts.len(), 1);
        assert_eq!(m.eval_var(&ts[0], "r"), Some(99));
    }

    #[test]
    fn deadlock_is_terminal_without_fin() {
        // receiver with no sender: terminal state with r still 0
        let m = sys("chan c = [0] of {byte};\nint r;\nactive proctype main() { byte x; c ? x; r = 1 }");
        let ts = reachable_terminals(&m);
        assert_eq!(ts.len(), 1);
        assert_eq!(m.eval_var(&ts[0], "r"), Some(0));
        assert!(ts[0].procs[0].alive, "deadlocked, not finished");
    }

    #[test]
    fn byte_short_and_bool_assignments_truncate_like_spin() {
        // regression: scalars used to stay untruncated i32, silently
        // diverging from SPIN for models that wrap
        let m = sys(
            "byte b; short s; bool f; int i; byte a[2];\n\
             active proctype main() {\n\
               b = 255; b = b + 1;\n\
               s = 32767; s = s + 1;\n\
               f = 2;\n\
               i = 2147483647; i = i + 1;\n\
               a[1] = 300\n\
             }",
        );
        let ts = reachable_terminals(&m);
        assert_eq!(ts.len(), 1);
        assert_eq!(m.eval_var(&ts[0], "b"), Some(0), "byte wraps at 256");
        assert_eq!(m.eval_var(&ts[0], "s"), Some(-32768), "short wraps at 2^15");
        assert_eq!(m.eval_var(&ts[0], "f"), Some(0), "bool keeps one bit (2 & 1)");
        assert_eq!(m.eval_var(&ts[0], "i"), Some(i32::MIN as i64), "int wraps at 2^31");
        assert_eq!(ts[0].globals[m.prog.global_syms["a"].offset as usize + 1], 300 & 0xFF);
    }

    #[test]
    fn run_arguments_truncate_to_param_width() {
        let m = sys(
            "int got;\n\
             active proctype main() { run w(300) }\n\
             proctype w(byte v) { got = v }",
        );
        let ts = reachable_terminals(&m);
        assert_eq!(m.eval_var(&ts[0], "got"), Some((300 & 0xFF) as i64));
    }

    #[test]
    fn recv_binds_truncate_to_declared_width() {
        // the message carries 300 untruncated; the byte-typed bind wraps it
        let m = sys(
            "chan c = [1] of {int};\nint got;\n\
             active proctype main() { byte x; c ! 300; c ? x; got = x }",
        );
        let ts = reachable_terminals(&m);
        assert_eq!(m.eval_var(&ts[0], "got"), Some((300 & 0xFF) as i64));
    }

    #[test]
    fn wrapping_loop_terminates_via_byte_truncation() {
        // a counter that only terminates because byte wraps — the SPIN
        // behavior untruncated i32 silently got wrong (infinite loop /
        // state-space blowup)
        let m = sys(
            "byte k = 200; int laps;\n\
             active proctype main() { do :: k != 0 -> k++ :: else -> break od; laps = 1 }",
        );
        let ts = reachable_terminals(&m);
        assert_eq!(ts.len(), 1);
        assert_eq!(m.eval_var(&ts[0], "laps"), Some(1));
        assert_eq!(m.eval_var(&ts[0], "k"), Some(0));
    }

    #[test]
    fn paper_clock_pattern_ticks() {
        // miniature of the paper's clock/pex protocol (Listings 8-9)
        let src = r#"
            int time; int nrp; int active_n = 2; bool FIN;
            active proctype main() { atomic { run p(); run p(); run clock() } }
            proctype p() {
              byte k; int cur;
              for (k : 0 .. 2) {
                atomic { cur = time; nrp = nrp + 1 };
                time > cur
              };
              atomic { active_n = active_n - 1; FIN = (active_n == 0 -> 1 : 0) }
            }
            proctype clock() {
              do
              :: FIN -> break
              :: !FIN && nrp >= active_n && active_n > 0 ->
                   atomic { nrp = 0; time = time + 1 }
              od
            }
        "#;
        let m = sys(src);
        let p = SafetyLtl::parse("G(FIN -> time == 3)").unwrap();
        let rep = check(&m, &p, &CheckOptions::default()).unwrap();
        assert!(rep.exhausted);
        assert!(!rep.found(), "every schedule must tick exactly 3 times");
    }
}
