//! Explicit-state model checker — our from-scratch SPIN counterpart.
//!
//! [`check`] runs an exhaustive (or budget-bounded) search verifying a
//! safety-LTL property, with SPIN-analogous knobs: visited-store regime
//! (full / hash-compact / bitstate), depth bound (`-m`), multi-error
//! collection (`-e`), and memory/time budgets. Violations carry replayable
//! trails, from which the tuner extracts parameter configurations.
//!
//! Two engines share the report types: the sequential DFS ([`dfs`],
//! exported as [`check_sequential`]) and the lock-sharded parallel
//! frontier search ([`parallel`], exported as [`check_parallel`]).
//! [`check`] dispatches on [`CheckOptions::threads`]: exact stores
//! (full/compact) with `threads > 1` (or `0` = all cores) run parallel;
//! everything else — including bitstate, whose parallel form is the
//! one-filter-per-worker [`crate::swarm`] — runs the sequential engine.

pub mod dfs;
pub mod parallel;
pub mod store;

pub use dfs::{
    check as check_sequential, Abort, CheckOptions, CheckReport, Frontier, Order, SearchStats,
};
pub use parallel::check_parallel;
pub use store::{Compression, StoreKind, VisitedStore};

use crate::model::{SafetyLtl, TransitionSystem};
use crate::util::error::Result;

/// Verify `G(prop)` on `model`, dispatching on `opts.threads` and
/// `opts.frontier` (see module docs). On full explorations both engines
/// return identical `states_stored`, verdict and `exhausted`;
/// budget-limited runs abort at the same thresholds, though the
/// asynchronous parallel engine may store a few extra states before the
/// stop flag propagates (and its per-state backlink bookkeeping charges
/// the memory budget slightly earlier). `Frontier::Deterministic` always
/// routes to the parallel module (even at one thread) so the exploration
/// order is reproducible across thread counts; bitstate stays sequential
/// regardless.
pub fn check<M>(
    model: &M,
    prop: &SafetyLtl,
    opts: &CheckOptions,
) -> Result<CheckReport<M::State>>
where
    M: TransitionSystem + Sync,
    M::State: Send,
{
    let parallel_engine =
        opts.effective_threads() > 1 || opts.frontier == Frontier::Deterministic;
    if parallel_engine && !matches!(opts.store, StoreKind::Bitstate { .. }) {
        if opts.por && opts.frontier != Frontier::Deterministic {
            // ample-set reduction is validated on the two engines whose
            // exploration (and thus ample selection) is deterministic:
            // the sequential DFS and the depth-synchronous frontier. The
            // async work-stealing frontier stays SPIN-faithful — its
            // schedule-dependent order would make the reduced state count
            // (and any reduction bug) irreproducible
            crate::bail!(
                "--por requires a deterministic engine (threads=1, or --frontier det)"
            );
        }
        if opts.store == StoreKind::Spill {
            // the spill store is a single-owner sequential structure
            crate::bail!(
                "--store spill requires the sequential engine (threads=1, async frontier)"
            );
        }
        parallel::check_parallel(model, prop, opts)
    } else {
        dfs::check(model, prop, opts)
    }
}
