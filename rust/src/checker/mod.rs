//! Explicit-state model checker — our from-scratch SPIN counterpart.
//!
//! [`check`] runs an exhaustive (or budget-bounded) DFS verifying a
//! safety-LTL property, with SPIN-analogous knobs: visited-store regime
//! (full / hash-compact / bitstate), depth bound (`-m`), multi-error
//! collection (`-e`), and memory/time budgets. Violations carry replayable
//! trails, from which the tuner extracts parameter configurations.

pub mod dfs;
pub mod store;

pub use dfs::{check, Abort, CheckOptions, CheckReport, Order, SearchStats};
pub use store::{StoreKind, VisitedStore};
