//! Parallel exhaustive search — one verification run scaled across cores.
//!
//! The sequential engine ([`super::dfs`]) explores depth-first with a
//! single visited store. This engine keeps the *same semantics and report*
//! (`states_stored`, violations-found verdict, `exhausted` flag) but
//! splits the work two ways:
//!
//! - **Lock-sharded visited store** ([`ShardedStore`]): N independently
//!   mutexed shards (N = threads × 8, rounded to a power of two), routed
//!   by the top bits of the state hash — the store index probes use the
//!   low bits, so shard routing costs no extra hashing and inserts on
//!   different shards never contend. Supports the exact regimes: `Full`
//!   (arena store + backlink map) and `HashCompact` (the backlink map's
//!   key set doubles as the visited set); under `--compress collapse` the
//!   `Full` regime swaps in per-shard [`CollapseStore`]s (still exact —
//!   see [`Shard`]). `Bitstate` is deliberately *not*
//!   sharded: a shared Bloom filter would make every worker's false
//!   positives prune every other worker's frontier, destroying the
//!   independence that gives swarm verification its coverage guarantees —
//!   bitstate search stays one-filter-per-worker in [`crate::swarm`].
//! - **Work-stealing frontier**: each worker expands states off a private
//!   stack and steals batches from a shared pool when it runs dry; workers
//!   with surplus push half their stack to the pool whenever a peer is
//!   idle. Termination is detected when every worker is idle and the pool
//!   is empty ([`Queue::fetch`]).
//!
//! Counterexample trails cannot be read off a DFS stack here, so every
//! stored state records a parent-hash backlink in its shard; violation
//! trails are reconstructed after the search by walking backlinks to an
//! initial state and replaying `successors` forward along the hash
//! chains, with replayed states memoized across violations
//! ([`reconstruct_all`]).
//!
//! Determinism: on a full (un-aborted, un-stopped) exploration the set of
//! stored states — and therefore `states_stored`, `states_matched`,
//! `transitions` and the verdict — is identical to the sequential
//! engine's, regardless of scheduling. Exploration *order* is not
//! deterministic, so with `collect_all` the violations arrive unordered
//! (they are sorted by discovery time) and early-stop runs may store a few
//! more states than the sequential engine before the stop flag propagates.
//! When run-to-run reproducibility matters more than peak throughput —
//! e.g. the paper's Table 1 "1st trail" timing — `Frontier::Deterministic`
//! switches to the depth-synchronous engine ([`check_deterministic`]),
//! whose exploration order is independent of scheduling *and* thread
//! count.

use super::dfs::{self, Abort, CheckOptions, CheckReport, Frontier, Order, SearchStats};
use super::store::{CollapseStore, Compression, FullStore, StoreKind, VisitedStore};
use crate::model::{CompiledProp, EvalScratch, SafetyLtl, Trail, TransitionSystem, Violation};
use crate::util::error::{Error, Result};
use crate::util::hash::{hash_bytes, FxHashMap};
use crate::util::rng::Xoshiro256;
use std::collections::hash_map::Entry;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Parent-hash sentinel for initial states.
const ROOT: u64 = u64::MAX;

/// Steal granularity and local-stack overflow threshold.
const BATCH: usize = 64;
const LOCAL_MAX: usize = 2 * BATCH;

/// One shard: a state-hash → parent-hash backlink map (for trail
/// reconstruction), plus — in the `Full` regime — the exact arena store.
/// In the `HashCompact` regime the backlink map's key set *is* the visited
/// set, so the 64-bit state hashes are not stored twice.
struct Shard {
    /// exact byte-level dedup (None = HashCompact: dedup by map key)
    full: Option<FullStore>,
    /// COLLAPSE-compressed dedup (`--compress collapse`): takes the place
    /// of `full`. Component tables are per-shard, so cross-shard region
    /// sharing is lost — the compression ratio degrades by at most the
    /// shard count in the worst case, but dedup stays exact (tuple
    /// equality ⟺ raw-encoding equality within a shard, and distinct
    /// shards only ever see distinct hashes).
    collapse: Option<CollapseStore>,
    parents: FxHashMap<u64, u64>,
}

/// The lock-sharded concurrent visited store (see module docs).
pub struct ShardedStore {
    shards: Vec<Mutex<Shard>>,
    shift: u32,
    /// running per-insert footprint estimate, so the workers' amortized
    /// memory-budget check is one relaxed load instead of sweeping every
    /// shard lock (exact accounting via `bytes_used` runs once, at the end)
    approx_bytes: AtomicU64,
}

impl ShardedStore {
    /// `expected_states` (0 = unknown) pre-sizes every shard: hash routing
    /// spreads states uniformly, so each shard expects `total / n` states
    /// (plus 25% slack for imbalance) and its arena table starts at the
    /// matching power of two — the first inserts never rehash under the
    /// shard lock.
    fn new(
        kind: StoreKind,
        compress: Compression,
        want_shards: usize,
        expected_states: u64,
    ) -> Self {
        let n = want_shards.max(2).next_power_of_two();
        let per_shard =
            ((expected_states / n as u64).saturating_mul(5) / 4).min(1 << 24) as usize;
        let collapsed = compress == Compression::Collapse;
        let full = matches!(kind, StoreKind::Full) && !collapsed;
        let shards = (0..n)
            .map(|_| {
                Mutex::new(Shard {
                    full: full.then(|| {
                        if per_shard > 0 {
                            FullStore::with_capacity(per_shard)
                        } else {
                            FullStore::new()
                        }
                    }),
                    collapse: collapsed.then(|| {
                        if per_shard > 0 {
                            CollapseStore::with_capacity(per_shard)
                        } else {
                            CollapseStore::new()
                        }
                    }),
                    parents: FxHashMap::with_capacity_and_hasher(per_shard, Default::default()),
                })
            })
            .collect();
        Self { shards, shift: 64 - n.trailing_zeros(), approx_bytes: AtomicU64::new(0) }
    }

    #[inline]
    fn shard_of(&self, h: u64) -> usize {
        (h >> self.shift) as usize
    }

    /// Insert an encoded state (hash precomputed); records the parent
    /// backlink when new. `bounds` is the region split for the collapse
    /// regime (empty otherwise — an empty split is the exact fallback).
    /// Returns true when the state was not seen before.
    fn insert(&self, enc: &[u8], h: u64, bounds: &[u32], parent: u64) -> bool {
        let mut guard = self.shards[self.shard_of(h)].lock().expect("shard poisoned");
        let sh = &mut *guard; // reborrow so the fields split cleanly
        let new = if let Some(cs) = &mut sh.collapse {
            if cs.insert_hashed(enc, h, bounds) {
                sh.parents.entry(h).or_insert(parent);
                true
            } else {
                false
            }
        } else if let Some(fs) = &mut sh.full {
            if fs.insert_hashed(enc, h) {
                // on a (astronomically rare) 64-bit collision keep the
                // first backlink so existing chains stay intact
                sh.parents.entry(h).or_insert(parent);
                true
            } else {
                false
            }
        } else {
            match sh.parents.entry(h) {
                Entry::Occupied(_) => false,
                Entry::Vacant(v) => {
                    v.insert(parent);
                    true
                }
            }
        };
        if new {
            // arena bytes + entry + table slot (Full), index tuple + entry
            // (Collapse: component growth is amortized into the exact
            // sweep), or just the backlink entry (HashCompact)
            let delta = if sh.collapse.is_some() {
                (bounds.len() as u64 + 1) * 4 + 28 + 24
            } else if sh.full.is_some() {
                enc.len() as u64 + 28 + 24
            } else {
                24
            };
            self.approx_bytes.fetch_add(delta, Ordering::Relaxed);
        }
        new
    }

    fn approx_bytes(&self) -> u64 {
        self.approx_bytes.load(Ordering::Relaxed)
    }

    fn parent_of(&self, h: u64) -> Option<u64> {
        self.shards[self.shard_of(h)]
            .lock()
            .expect("shard poisoned")
            .parents
            .get(&h)
            .copied()
    }

    fn bytes_used(&self) -> u64 {
        self.shards
            .iter()
            .map(|s| {
                let sh = s.lock().expect("shard poisoned");
                // ~24 B/entry for the backlink map (key + value + bucket)
                sh.full.as_ref().map_or(0, |fs| fs.bytes_used())
                    + sh.collapse.as_ref().map_or(0, |cs| cs.bytes_used())
                    + sh.parents.len() as u64 * 24
            })
            .sum()
    }
}

struct Task<S> {
    state: S,
    hash: u64,
    depth: u32,
}

struct QueueInner<S> {
    tasks: Vec<Task<S>>,
    idle: usize,
    done: bool,
}

struct Queue<S> {
    inner: Mutex<QueueInner<S>>,
    cv: Condvar,
}

impl<S> Queue<S> {
    /// Refill `local` from the shared pool, or block until work appears.
    /// Returns None when the search is over (stop flag, or every worker
    /// idle on an empty pool).
    fn fetch(&self, ctl: &Control, n_workers: usize, local: &mut Vec<Task<S>>) -> Option<Task<S>> {
        let mut g = self.inner.lock().expect("queue poisoned");
        loop {
            if g.done || ctl.stop.load(Ordering::Relaxed) {
                g.done = true;
                self.cv.notify_all();
                return None;
            }
            if !g.tasks.is_empty() {
                let take = (g.tasks.len() / 2).clamp(1, BATCH);
                let at = g.tasks.len() - take;
                local.extend(g.tasks.drain(at..));
                return local.pop();
            }
            g.idle += 1;
            ctl.idle.fetch_add(1, Ordering::Relaxed);
            if g.idle == n_workers {
                g.done = true;
                self.cv.notify_all();
                ctl.idle.fetch_sub(1, Ordering::Relaxed);
                return None;
            }
            g = self.cv.wait(g).expect("queue poisoned");
            g.idle -= 1;
            ctl.idle.fetch_sub(1, Ordering::Relaxed);
        }
    }

    /// Donate the older (shallower) half of `local` to the shared pool —
    /// shallow states root the larger unexplored subtrees, so peers get
    /// the most work per steal.
    fn share(&self, local: &mut Vec<Task<S>>) {
        let donate = local.len() / 2;
        let mut g = self.inner.lock().expect("queue poisoned");
        g.tasks.extend(local.drain(..donate));
        self.cv.notify_all();
    }

    /// Wake everyone and mark the search finished (stop flag already set).
    fn close(&self) {
        let mut g = self.inner.lock().expect("queue poisoned");
        g.done = true;
        self.cv.notify_all();
    }
}

/// Dropped when a worker exits for any reason — normal completion, error,
/// or panic unwind. Stops and wakes every peer so one dying worker can
/// never leave the rest blocked in [`Queue::fetch`] (the panic itself
/// still propagates through the scope join). On a normal exit the search
/// is already done, so the extra stop/close is a no-op.
struct ReleasePeersOnExit<'a, S> {
    queue: &'a Queue<S>,
    ctl: &'a Control,
}

impl<S> Drop for ReleasePeersOnExit<'_, S> {
    fn drop(&mut self) {
        self.ctl.stop.store(true, Ordering::Relaxed);
        self.queue.close();
    }
}

struct Control {
    stop: AtomicBool,
    /// workers currently blocked waiting for work (sharing heuristic)
    idle: AtomicUsize,
    /// global stored-state count (budget enforcement; exact)
    states_stored: AtomicU64,
    /// first hard limit that fired
    abort: Mutex<Option<Abort>>,
    /// some state hit the depth bound (soft: only reported when no hard
    /// limit fired, mirroring the sequential engine)
    truncated: AtomicBool,
}

impl Control {
    fn hard_abort(&self, a: Abort) {
        self.abort.lock().expect("abort poisoned").get_or_insert(a);
        self.stop.store(true, Ordering::Relaxed);
    }
}

struct Pending<S> {
    state: S,
    hash: u64,
    depth: u32,
    found_after: Duration,
}

#[derive(Default)]
struct LocalStats {
    stored: u64,
    matched: u64,
    transitions: u64,
    max_depth: usize,
}

/// Verify `G(prop)` on `model` with `opts.threads` workers. Same report
/// semantics as the sequential [`dfs::check`]; requires an exact store
/// (`Full` or `HashCompact` — see module docs for why bitstate refuses).
pub fn check_parallel<M>(
    model: &M,
    prop: &SafetyLtl,
    opts: &CheckOptions,
) -> Result<CheckReport<M::State>>
where
    M: TransitionSystem + Sync,
    M::State: Send,
{
    if matches!(opts.store, StoreKind::Bitstate { .. }) {
        crate::bail!(
            "parallel exhaustive search requires an exact store (full | compact); \
             bitstate parallelism is one independent filter per worker — use swarm::swarm"
        );
    }
    if opts.frontier == Frontier::Deterministic {
        return check_deterministic(model, prop, opts);
    }
    let threads = opts.effective_threads().max(1);
    if threads == 1 {
        return dfs::check(model, prop, opts);
    }
    opts.validate_store()?;
    if opts.store == StoreKind::Spill {
        crate::bail!("--store spill requires the sequential engine (threads=1, async frontier)");
    }
    if opts.por {
        crate::bail!("--por requires a deterministic engine (threads=1, or --frontier det)");
    }

    let start = Instant::now();
    let compiled = prop.compile(model)?;
    let collapse = opts.compress == Compression::Collapse;
    let store =
        ShardedStore::new(opts.store, opts.compress, threads as usize * 8, opts.presize_hint());
    let ctl = Control {
        stop: AtomicBool::new(false),
        idle: AtomicUsize::new(0),
        states_stored: AtomicU64::new(0),
        abort: Mutex::new(None),
        truncated: AtomicBool::new(false),
    };
    let pending: Mutex<Vec<Pending<M::State>>> = Mutex::new(Vec::new());
    let mut seed_stats = LocalStats::default();

    // seed: insert + monitor the initial states on this thread, exactly
    // like the sequential engine's outer loop preamble
    let mut seed_tasks: Vec<Task<M::State>> = Vec::new();
    {
        let mut enc = Vec::with_capacity(64);
        let mut bounds: Vec<u32> = Vec::new();
        let mut scratch = EvalScratch::default();
        for init in model.initial_states() {
            model.encode(&init, &mut enc);
            if collapse {
                model.encode_regions(&init, &mut bounds);
            }
            let h = hash_bytes(&enc);
            if !store.insert(&enc, h, &bounds, ROOT) {
                seed_stats.matched += 1;
                continue;
            }
            seed_stats.stored += 1;
            ctl.states_stored.fetch_add(1, Ordering::Relaxed);
            if !compiled.holds_state(model, &init, &mut scratch)? {
                let n = {
                    let mut p = pending.lock().expect("pending poisoned");
                    p.push(Pending {
                        state: init.clone(),
                        hash: h,
                        depth: 0,
                        found_after: start.elapsed(),
                    });
                    p.len()
                };
                if n >= opts.max_errors {
                    ctl.hard_abort(Abort::ErrorLimit);
                    break;
                }
                if !opts.collect_all {
                    ctl.stop.store(true, Ordering::Relaxed);
                    break;
                }
            }
            seed_tasks.push(Task { state: init, hash: h, depth: 0 });
        }
    }

    let queue = Queue {
        inner: Mutex::new(QueueInner { tasks: seed_tasks, idle: 0, done: false }),
        cv: Condvar::new(),
    };

    let n_workers = threads as usize;
    let worker_results: Vec<Result<LocalStats>> = std::thread::scope(|scope| {
        let compiled = &compiled;
        let store = &store;
        let ctl = &ctl;
        let pending = &pending;
        let queue = &queue;
        let handles: Vec<_> = (0..threads)
            .map(|w| {
                scope.spawn(move || {
                    let _release = ReleasePeersOnExit { queue, ctl };
                    worker_loop(
                        model, compiled, opts, store, queue, ctl, pending, start, n_workers, w,
                    )
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("checker worker panicked"))
            .collect()
    });

    let mut stats = SearchStats {
        states_stored: seed_stats.stored,
        states_matched: seed_stats.matched,
        transitions: seed_stats.transitions,
        max_depth_reached: seed_stats.max_depth,
        ..SearchStats::default()
    };
    let mut first_err: Option<Error> = None;
    for r in worker_results {
        match r {
            Ok(ls) => {
                stats.states_stored += ls.stored;
                stats.states_matched += ls.matched;
                stats.transitions += ls.transitions;
                stats.max_depth_reached = stats.max_depth_reached.max(ls.max_depth);
            }
            Err(e) => {
                if first_err.is_none() {
                    first_err = Some(e);
                }
            }
        }
    }
    if let Some(e) = first_err {
        return Err(e);
    }

    // resolve violations: order by discovery time, honor the error caps
    let mut pend = pending.into_inner().expect("pending poisoned");
    pend.sort_by_key(|p| p.found_after);
    if !opts.collect_all {
        pend.truncate(1);
    }
    pend.truncate(opts.max_errors);
    let violations = reconstruct_all(model, |h| store.parent_of(h), &pend);

    let hard_abort = *ctl.abort.lock().expect("abort poisoned");
    let truncated = ctl.truncated.load(Ordering::Relaxed);
    stats.abort = hard_abort.or(if truncated { Some(Abort::DepthTruncated) } else { None });
    let mut exhausted = hard_abort.is_none() && !truncated;
    if !opts.collect_all && !violations.is_empty() {
        exhausted = false; // stopped early by design
    }
    stats.bytes_used = store.bytes_used();
    stats.elapsed = start.elapsed();
    // workers flushed their own deltas; account the seed pass and the
    // exact end-of-run footprint here
    if crate::obs::enabled() {
        let m = crate::obs::metrics();
        m.states_stored.add(seed_stats.stored);
        m.states_matched.add(seed_stats.matched);
        m.transitions.add(seed_stats.transitions);
        m.depth.set_max(stats.max_depth_reached as u64);
        m.store_bytes.set_max(stats.bytes_used);
    }
    Ok(CheckReport { violations, stats, exhausted })
}

#[allow(clippy::too_many_arguments)]
fn worker_loop<M>(
    model: &M,
    compiled: &CompiledProp,
    opts: &CheckOptions,
    store: &ShardedStore,
    queue: &Queue<M::State>,
    ctl: &Control,
    pending: &Mutex<Vec<Pending<M::State>>>,
    start: Instant,
    n_workers: usize,
    worker: u32,
) -> Result<LocalStats>
where
    M: TransitionSystem + Sync,
    M::State: Send,
{
    let mut stats = LocalStats::default();
    let mut local: Vec<Task<M::State>> = Vec::new();
    let mut succs: Vec<M::State> = Vec::new();
    let mut enc: Vec<u8> = Vec::with_capacity(64);
    let collapse = opts.compress == Compression::Collapse;
    let mut bounds: Vec<u32> = Vec::new();
    let mut scratch = EvalScratch::default();
    let mut rng = match opts.order {
        Order::Random(seed) => Some(Xoshiro256::new(
            seed ^ (worker as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
        )),
        Order::InOrder => None,
    };
    let mut processed: u32 = 0;
    // last (stored, matched, transitions) pushed to the global telemetry
    // registry; deltas flush from the amortized block below, so the
    // per-state path carries no telemetry instructions
    let mut flushed = (0u64, 0u64, 0u64);

    loop {
        let task = match local.pop() {
            Some(t) => t,
            None => match queue.fetch(ctl, n_workers, &mut local) {
                Some(t) => t,
                None => break,
            },
        };
        if ctl.stop.load(Ordering::Relaxed) {
            break;
        }

        model.successors(&task.state, &mut succs);
        stats.transitions += succs.len() as u64;
        if let Some(r) = rng.as_mut() {
            r.shuffle(&mut succs);
        }
        let child_depth = task.depth + 1;
        for s in succs.drain(..) {
            model.encode(&s, &mut enc);
            if collapse {
                model.encode_regions(&s, &mut bounds);
            }
            let h = hash_bytes(&enc);
            if !store.insert(&enc, h, &bounds, task.hash) {
                stats.matched += 1;
                continue;
            }
            stats.stored += 1;
            stats.max_depth = stats.max_depth.max(child_depth as usize);
            let total = ctl.states_stored.fetch_add(1, Ordering::Relaxed) + 1;

            if !compiled.holds_state(model, &s, &mut scratch)? {
                let n = {
                    let mut p = pending.lock().expect("pending poisoned");
                    p.push(Pending {
                        state: s.clone(),
                        hash: h,
                        depth: child_depth,
                        found_after: start.elapsed(),
                    });
                    p.len()
                };
                if n >= opts.max_errors {
                    ctl.hard_abort(Abort::ErrorLimit);
                    queue.close();
                } else if !opts.collect_all {
                    ctl.stop.store(true, Ordering::Relaxed);
                    queue.close();
                }
            }

            if total >= opts.max_states {
                ctl.hard_abort(Abort::StateLimit);
                queue.close();
            }

            if (child_depth as usize) < opts.max_depth {
                local.push(Task { state: s, hash: h, depth: child_depth });
            } else {
                // stored but not expanded (SPIN -m semantics)
                ctl.truncated.store(true, Ordering::Relaxed);
            }
        }

        // donate work whenever a peer is starving (or we are hoarding)
        if local.len() >= 2
            && (local.len() > LOCAL_MAX || ctl.idle.load(Ordering::Relaxed) > 0)
        {
            queue.share(&mut local);
        }

        // amortized checks: a clock read and one relaxed atomic load every
        // 256 tasks; a capacity-exact sweep (locks every shard, so it also
        // catches Vec/hash-table slack the estimate misses) every 64k
        processed = processed.wrapping_add(1);
        if processed % 256 == 0 {
            if crate::obs::enabled() {
                let m = crate::obs::metrics();
                m.states_stored.add(stats.stored - flushed.0);
                m.states_matched.add(stats.matched - flushed.1);
                m.transitions.add(stats.transitions - flushed.2);
                flushed = (stats.stored, stats.matched, stats.transitions);
                m.depth.set_max(stats.max_depth as u64);
                m.store_bytes.set_max(store.approx_bytes());
            }
            if let Some(tb) = opts.time_budget {
                if start.elapsed() >= tb {
                    ctl.hard_abort(Abort::TimeLimit);
                    queue.close();
                }
            }
            let over = if processed % 65_536 == 0 {
                store.bytes_used() >= opts.memory_budget
            } else {
                store.approx_bytes() >= opts.memory_budget
            };
            if over {
                ctl.hard_abort(Abort::MemoryLimit);
                queue.close();
            }
        }
    }
    // final flush: whatever accumulated since the last amortized checkpoint
    if crate::obs::enabled() {
        let m = crate::obs::metrics();
        m.states_stored.add(stats.stored - flushed.0);
        m.states_matched.add(stats.matched - flushed.1);
        m.transitions.add(stats.transitions - flushed.2);
        m.depth.set_max(stats.max_depth as u64);
    }
    Ok(stats)
}

/// Visited-store shard count for the deterministic engine. Fixed (not a
/// function of the thread count) so store capacities — and therefore the
/// deterministic `MemoryLimit` abort point — are identical across thread
/// counts; the dedup pass runs `min(threads, DET_SHARDS)` workers over
/// contiguous shard ranges.
const DET_SHARDS: usize = 16;

/// Deterministic-frontier engine ([`Frontier::Deterministic`]): a
/// depth-synchronous parallel BFS.
///
/// Each level runs three phases, all scheduling-independent:
///
/// 1. **Expansion** (parallel, contiguous chunks, one per worker —
///    `successors` is the dominant cost on the Promela engines; each
///    worker reuses one successor buffer the model fills in place, per
///    the `TransitionSystem::successors` buffer contract). Workers also
///    encode and hash every child into a per-chunk arena, and apply
///    `--por` ample selection (`reduced_successors`) — legal here because
///    the ample subset is a pure function of the state, so the reduced
///    graph is the same whichever worker expands it.
/// 2. **Dedup** (parallel, hash-prefix-sharded): the visited store and
///    backlink map are split into [`DET_SHARDS`] shards routed by the top
///    hash bits; each dedup worker owns a contiguous shard range and
///    walks the *full* child sequence in its global order (chunk order ×
///    task order × successor order), claiming the children whose hash
///    routes to it. Same-hash duplicates land in the same shard and are
///    processed in global order, so every new/duplicate decision and
///    every surviving backlink is exactly what a single sequential pass
///    would produce; distinct shards only ever see distinct hashes, so no
///    decision crosses shards. (This replaces a fully sequential merge
///    that capped `--frontier det` scaling at Amdahl's bound.)
/// 3. **Effects** (sequential, global order): counters, property
///    monitoring, violation recording, frontier building, and the
///    early-stop cuts (`!collect_all`, `max_states`, `max_errors`).
///
/// Consequences:
///
/// - the violation sequence, the *first* violation, and the states-stored
///   count at every early stop are identical run-to-run and across thread
///   counts (an early stop leaves post-cutoff states in the store, but
///   nothing reported reads them);
/// - `Order::Random(seed)` still diversifies, but the shuffle is keyed by
///   `seed ^ parent_hash` instead of per-worker, so it too is
///   reproducible;
/// - parent backlinks are first-come in the global order, so
///   reconstructed trails are stable as well;
/// - budget aborts (time/memory) are still checked — between levels, so a
///   run that aborts does so at a level boundary (wall-clock aborts remain
///   inherently timing-dependent).
///
/// On a full exploration the report (`states_stored`, `states_matched`,
/// `transitions`, verdict, `exhausted`) matches the sequential engine's.
fn check_deterministic<M>(
    model: &M,
    prop: &SafetyLtl,
    opts: &CheckOptions,
) -> Result<CheckReport<M::State>>
where
    M: TransitionSystem + Sync,
    M::State: Send,
{
    /// One hash-prefix shard of the visited state space.
    struct DetShard {
        store: VisitedStore,
        parents: FxHashMap<u64, u64>,
    }

    /// One chunk's expansion: children with encodings and hashes
    /// precomputed in the parallel phase, so the dedup workers never
    /// touch the model. Child `i` of a chunk is `children[i]` with
    /// encoding `enc[offs[i-1]..offs[i]]` (`offs[-1]` = 0) and — under
    /// collapse — region bounds `bounds[boffs[i-1]..boffs[i]]`.
    struct Chunk<S> {
        /// (parent hash, child hash, child state)
        children: Vec<(u64, u64, S)>,
        enc: Vec<u8>,
        offs: Vec<u32>,
        bounds: Vec<u32>,
        boffs: Vec<u32>,
        trans: u64,
        /// tasks expanded through a proper ample subset (`--por`)
        reduced: u64,
    }

    /// Dedup + backlink in one step. In the `HashCompact` regime the
    /// backlink map's key set *is* the visited set (as in [`Shard`]), so
    /// the store is bypassed — no duplicate 8-byte key, no second probe.
    fn insert_det(
        compact: bool,
        store: &mut VisitedStore,
        parents: &mut FxHashMap<u64, u64>,
        enc: &[u8],
        h: u64,
        bounds: &[u32],
        parent: u64,
    ) -> bool {
        if compact {
            match parents.entry(h) {
                Entry::Occupied(_) => false,
                Entry::Vacant(v) => {
                    v.insert(parent);
                    true
                }
            }
        } else if store.insert_regions(enc, h, bounds) {
            parents.insert(h, parent);
            true
        } else {
            false
        }
    }

    if opts.store == StoreKind::Spill {
        crate::bail!("--store spill requires the sequential engine (threads=1, async frontier)");
    }
    opts.validate_store()?;
    let start = Instant::now();
    let threads = opts.effective_threads().max(1) as usize;
    let compiled = prop.compile(model)?;
    let collapse = opts.compress == Compression::Collapse;
    // compact+collapse routes through the store (the region-aware tuple
    // hash differs from the raw-encoding hash the backlink map is keyed
    // on), so the map-as-visited-set shortcut only applies uncompressed
    let compact = matches!(opts.store, StoreKind::HashCompact) && !collapse;
    let shift = 64 - (DET_SHARDS as u64).trailing_zeros();
    let shard_hint = (opts.presize_hint() / DET_SHARDS as u64).saturating_mul(5) / 4;
    let mut shards: Vec<DetShard> = (0..DET_SHARDS)
        .map(|_| DetShard {
            store: if compact {
                VisitedStore::new(StoreKind::HashCompact) // unused; stays empty
            } else if collapse && matches!(opts.store, StoreKind::HashCompact) {
                VisitedStore::compact_collapsed(shard_hint)
            } else if collapse {
                VisitedStore::collapsed(shard_hint)
            } else {
                VisitedStore::with_capacity(opts.store, shard_hint)
            },
            parents: FxHashMap::with_capacity_and_hasher(
                shard_hint.min(1 << 22) as usize,
                Default::default(),
            ),
        })
        .collect();
    let mut stats = SearchStats::default();
    let mut pend: Vec<Pending<M::State>> = Vec::new();
    let mut truncated = false;
    let mut stop = false;
    let mut por_reduced = 0u64;
    let mut scratch = EvalScratch::default();
    let mut enc = Vec::with_capacity(64);
    let mut seed_bounds: Vec<u32> = Vec::new();
    let mut frontier: Vec<Task<M::State>> = Vec::new();
    // telemetry deltas flush at level boundaries only (see dfs)
    let mut tele_flushed = (0u64, 0u64, 0u64);

    // seed level: monitor the initial states in declaration order
    for init in model.initial_states() {
        model.encode(&init, &mut enc);
        if collapse {
            model.encode_regions(&init, &mut seed_bounds);
        }
        let h = hash_bytes(&enc);
        let sh = &mut shards[(h >> shift) as usize];
        if !insert_det(compact, &mut sh.store, &mut sh.parents, &enc, h, &seed_bounds, ROOT) {
            stats.states_matched += 1;
            continue;
        }
        stats.states_stored += 1;
        if !compiled.holds_state(model, &init, &mut scratch)? {
            pend.push(Pending {
                state: init.clone(),
                hash: h,
                depth: 0,
                found_after: start.elapsed(),
            });
            if pend.len() >= opts.max_errors {
                stats.abort = Some(Abort::ErrorLimit);
                stop = true;
                break;
            }
            if !opts.collect_all {
                stop = true;
                break;
            }
        }
        frontier.push(Task { state: init, hash: h, depth: 0 });
    }

    while !stop && !frontier.is_empty() {
        // phase 1: parallel expansion + encode/hash, chunk order preserved
        let chunk = frontier.len().div_ceil(threads);
        let expanded: Vec<Chunk<M::State>> = std::thread::scope(|scope| {
            let handles: Vec<_> = frontier
                .chunks(chunk)
                .map(|tasks| {
                    scope.spawn(move || -> Chunk<M::State> {
                        let mut ch = Chunk {
                            children: Vec::new(),
                            enc: Vec::new(),
                            offs: Vec::new(),
                            bounds: Vec::new(),
                            boffs: Vec::new(),
                            trans: 0,
                            reduced: 0,
                        };
                        let mut succs: Vec<M::State> = Vec::new();
                        let mut e: Vec<u8> = Vec::with_capacity(64);
                        let mut b: Vec<u32> = Vec::new();
                        for t in tasks {
                            if opts.por {
                                ch.reduced +=
                                    u64::from(model.reduced_successors(&t.state, &mut succs));
                            } else {
                                model.successors(&t.state, &mut succs);
                            }
                            ch.trans += succs.len() as u64;
                            if let Order::Random(seed) = opts.order {
                                // per-state seeding keeps the shuffle
                                // independent of which worker expands it
                                Xoshiro256::new(seed ^ t.hash).shuffle(&mut succs);
                            }
                            for s in succs.drain(..) {
                                model.encode(&s, &mut e);
                                let h = hash_bytes(&e);
                                ch.enc.extend_from_slice(&e);
                                debug_assert!(ch.enc.len() <= u32::MAX as usize);
                                ch.offs.push(ch.enc.len() as u32);
                                if collapse {
                                    model.encode_regions(&s, &mut b);
                                    ch.bounds.extend_from_slice(&b);
                                    ch.boffs.push(ch.bounds.len() as u32);
                                }
                                ch.children.push((t.hash, h, s));
                            }
                        }
                        ch
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("deterministic-frontier worker panicked"))
                .collect()
        });

        // phase 2: sharded dedup — see the module-level determinism
        // argument. `fresh[g]` records whether global child `g` was new.
        let total: usize = expanded.iter().map(|c| c.children.len()).sum();
        let fresh: Vec<AtomicBool> = (0..total).map(|_| AtomicBool::new(false)).collect();
        {
            let n_workers = threads.min(DET_SHARDS).max(1);
            let per = DET_SHARDS.div_ceil(n_workers);
            let fresh = &fresh;
            let expanded = &expanded;
            std::thread::scope(|scope| {
                for (wi, shard_range) in shards.chunks_mut(per).enumerate() {
                    let base = wi * per;
                    scope.spawn(move || {
                        let lo_shard = base;
                        let hi_shard = base + shard_range.len();
                        let mut g = 0usize;
                        for c in expanded {
                            for (i, child) in c.children.iter().enumerate() {
                                let &(parent, h, _) = child;
                                let sid = (h >> shift) as usize;
                                if sid >= lo_shard && sid < hi_shard {
                                    let e_lo =
                                        if i == 0 { 0 } else { c.offs[i - 1] as usize };
                                    let e_hi = c.offs[i] as usize;
                                    let bs = if collapse {
                                        let b_lo =
                                            if i == 0 { 0 } else { c.boffs[i - 1] as usize };
                                        &c.bounds[b_lo..c.boffs[i] as usize]
                                    } else {
                                        &[][..]
                                    };
                                    let sh = &mut shard_range[sid - lo_shard];
                                    if insert_det(
                                        compact,
                                        &mut sh.store,
                                        &mut sh.parents,
                                        &c.enc[e_lo..e_hi],
                                        h,
                                        bs,
                                        parent,
                                    ) {
                                        fresh[g + i].store(true, Ordering::Relaxed);
                                    }
                                }
                            }
                            g += c.children.len();
                        }
                    });
                }
            });
        }

        // phase 3: sequential effects — counters, monitoring, violations,
        // frontier and early stops, all in the global child order
        let depth = frontier[0].depth + 1;
        frontier.clear();
        let mut level_children = 0u64;
        let mut g = 0usize;
        'merge: for c in expanded {
            level_children += c.trans;
            stats.transitions += c.trans;
            por_reduced += c.reduced;
            let n_children = c.children.len();
            for (i, (_, h, s)) in c.children.into_iter().enumerate() {
                if !fresh[g + i].load(Ordering::Relaxed) {
                    stats.states_matched += 1;
                    continue;
                }
                stats.states_stored += 1;
                stats.max_depth_reached = stats.max_depth_reached.max(depth as usize);
                if !compiled.holds_state(model, &s, &mut scratch)? {
                    pend.push(Pending {
                        state: s.clone(),
                        hash: h,
                        depth,
                        found_after: start.elapsed(),
                    });
                    if pend.len() >= opts.max_errors {
                        stats.abort = Some(Abort::ErrorLimit);
                        stop = true;
                        break 'merge;
                    }
                    if !opts.collect_all {
                        stop = true;
                        break 'merge;
                    }
                }
                if stats.states_stored >= opts.max_states {
                    stats.abort = Some(Abort::StateLimit);
                    stop = true;
                    break 'merge;
                }
                if (depth as usize) < opts.max_depth {
                    frontier.push(Task { state: s, hash: h, depth });
                } else {
                    // stored but not expanded (SPIN -m semantics)
                    truncated = true;
                }
            }
            g += n_children;
        }
        if stop {
            break;
        }
        let store_bytes: u64 = shards
            .iter()
            .map(|sh| sh.store.bytes_used() + sh.parents.len() as u64 * 24)
            .sum();
        dfs::flush_search_metrics(&stats, &mut tele_flushed, store_bytes);
        // budgets, at level granularity (~24 B/backlink entry, as in the
        // sharded store's accounting). The frontier and the next level's
        // expansion buffers are resident alongside the stores, so charge
        // them shallowly too — as dfs charges its stack — using this
        // level's child count as the estimate for the next expansion.
        // All inputs are deterministic (shard count and capacities do not
        // depend on the thread count), so MemoryLimit aborts stay
        // reproducible across runs and thread counts.
        if let Some(tb) = opts.time_budget {
            if start.elapsed() >= tb {
                stats.abort = Some(Abort::TimeLimit);
                break;
            }
        }
        let frontier_bytes =
            frontier.capacity() as u64 * std::mem::size_of::<Task<M::State>>() as u64;
        let expansion_bytes =
            level_children * std::mem::size_of::<(u64, M::State)>() as u64;
        if store_bytes + frontier_bytes + expansion_bytes >= opts.memory_budget {
            stats.abort = Some(Abort::MemoryLimit);
            break;
        }
    }

    if stats.abort.is_none() && truncated {
        stats.abort = Some(Abort::DepthTruncated);
    }
    let mut exhausted = stats.abort.is_none();
    if !opts.collect_all && !pend.is_empty() {
        exhausted = false; // stopped early by design
        pend.truncate(1);
    }
    pend.truncate(opts.max_errors);
    let violations = reconstruct_all(
        model,
        |h| shards[(h >> shift) as usize].parents.get(&h).copied(),
        &pend,
    );
    stats.bytes_used = shards
        .iter()
        .map(|sh| sh.store.bytes_used() + sh.parents.len() as u64 * 24)
        .sum();
    stats.elapsed = start.elapsed();
    dfs::flush_search_metrics(&stats, &mut tele_flushed, stats.bytes_used);
    if por_reduced > 0 {
        crate::obs::metrics().por_reduced.add(por_reduced);
    }
    Ok(CheckReport { violations, stats, exhausted })
}

/// Rebuild violation trails from parent-hash backlinks, batched. Replayed
/// states are memoized by hash, so `successors` runs at most once per
/// distinct trail state across *all* violations — `collect_all` runs
/// whose violations share trail prefixes (the common case: every tuning
/// branch forks off one initial segment) replay each shared edge once
/// instead of once per violation, which was quadratic. Backlinks are read
/// through `parent_of` so both parallel engines (sharded store / plain
/// map) share the replay.
fn reconstruct_all<M, F>(
    model: &M,
    parent_of: F,
    pend: &[Pending<M::State>],
) -> Vec<Violation<M::State>>
where
    M: TransitionSystem,
    F: Fn(u64) -> Option<u64>,
{
    // hash -> already-replayed state, seeded with the initial states
    let mut known: FxHashMap<u64, M::State> = FxHashMap::default();
    let mut enc = Vec::with_capacity(64);
    for init in model.initial_states() {
        model.encode(&init, &mut enc);
        known.insert(hash_bytes(&enc), init);
    }
    let mut succs: Vec<M::State> = Vec::new();
    pend.iter()
        .map(|p| reconstruct_one(model, &parent_of, p, &mut known, &mut succs, &mut enc))
        .collect()
}

/// One trail: walk backlinks root-ward (cheap map lookups), then replay
/// forward, serving memoized states and replaying `successors` only for
/// hashes not seen on an earlier trail. Falls back to a single-state
/// trail if the chain cannot be replayed (possible only under 64-bit hash
/// collisions).
fn reconstruct_one<M, F>(
    model: &M,
    parent_of: &F,
    p: &Pending<M::State>,
    known: &mut FxHashMap<u64, M::State>,
    succs: &mut Vec<M::State>,
    enc: &mut Vec<u8>,
) -> Violation<M::State>
where
    M: TransitionSystem,
    F: Fn(u64) -> Option<u64>,
{
    let fallback = |state: &M::State| Violation {
        trail: Trail { states: vec![state.clone()] },
        depth: p.depth as usize,
        found_after: p.found_after,
    };
    crate::obs::metrics().trail_replays.add(1); // cold path; add() self-gates

    let mut chain = vec![p.hash];
    let mut cur = p.hash;
    loop {
        match parent_of(cur) {
            Some(ROOT) => break,
            Some(parent) => {
                chain.push(parent);
                cur = parent;
            }
            None => return fallback(&p.state), // broken link: give up
        }
    }
    chain.reverse();

    let mut states: Vec<M::State> = Vec::with_capacity(chain.len());
    match known.get(&chain[0]) {
        Some(s) => states.push(s.clone()),
        None => return fallback(&p.state), // root hash not an initial state
    }
    for &want in &chain[1..] {
        if let Some(s) = known.get(&want) {
            states.push(s.clone());
            continue;
        }
        let prev = states.last().expect("chain starts with a state");
        model.successors(prev, succs);
        let mut found = None;
        for s in succs.drain(..) {
            model.encode(&s, enc);
            if hash_bytes(enc) == want {
                found = Some(s);
                break;
            }
        }
        match found {
            Some(s) => {
                known.insert(want, s.clone());
                states.push(s);
            }
            None => return fallback(&p.state),
        }
    }
    Violation {
        trail: Trail { states },
        depth: p.depth as usize,
        found_after: p.found_after,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::{AbstractModel, Granularity, PlatformConfig};

    fn popts(threads: u32) -> CheckOptions {
        CheckOptions { threads, ..CheckOptions::default() }
    }

    #[test]
    fn parallel_explores_same_space_as_sequential() {
        let m = AbstractModel::new(64, PlatformConfig::default(), Granularity::Phase).unwrap();
        let p = SafetyLtl::parse("G(true)").unwrap();
        let seq = dfs::check(&m, &p, &CheckOptions::default()).unwrap();
        let par = check_parallel(&m, &p, &popts(4)).unwrap();
        assert_eq!(par.stats.states_stored, seq.stats.states_stored);
        assert_eq!(par.stats.states_matched, seq.stats.states_matched);
        assert_eq!(par.stats.transitions, seq.stats.transitions);
        assert!(par.exhausted);
        assert!(par.verdict().unwrap());
    }

    #[test]
    fn parallel_rejects_bitstate() {
        let m = AbstractModel::new(16, PlatformConfig::default(), Granularity::Phase).unwrap();
        let p = SafetyLtl::parse("G(true)").unwrap();
        let mut o = popts(4);
        o.store = StoreKind::Bitstate { log2_bits: 20, hashes: 3 };
        assert!(check_parallel(&m, &p, &o).is_err());
    }

    #[test]
    fn parallel_single_thread_falls_back_to_dfs() {
        let m = AbstractModel::new(16, PlatformConfig::default(), Granularity::Phase).unwrap();
        let p = SafetyLtl::parse("G(true)").unwrap();
        let r = check_parallel(&m, &p, &popts(1)).unwrap();
        assert!(r.exhausted);
    }

    #[test]
    fn parallel_state_limit_aborts() {
        let m = AbstractModel::new(256, PlatformConfig::default(), Granularity::Tick).unwrap();
        let p = SafetyLtl::parse("G(true)").unwrap();
        let mut o = popts(4);
        o.max_states = 1000;
        let r = check_parallel(&m, &p, &o).unwrap();
        assert_eq!(r.stats.abort, Some(Abort::StateLimit));
        assert!(!r.exhausted);
        assert!(r.verdict().is_err());
    }

    #[test]
    fn parallel_unknown_var_is_error() {
        let m = AbstractModel::new(16, PlatformConfig::default(), Granularity::Phase).unwrap();
        let p = SafetyLtl::parse("G(nosuchvar > 0)").unwrap();
        assert!(check_parallel(&m, &p, &popts(4)).is_err());
    }

    #[test]
    fn parallel_collapse_matches_full() {
        let m = AbstractModel::new(64, PlatformConfig::default(), Granularity::Phase).unwrap();
        let p = SafetyLtl::parse("G(true)").unwrap();
        let base = check_parallel(&m, &p, &popts(4)).unwrap();
        let mut o = popts(4);
        o.compress = Compression::Collapse;
        let col = check_parallel(&m, &p, &o).unwrap();
        assert_eq!(col.stats.states_stored, base.stats.states_stored);
        assert_eq!(col.stats.states_matched, base.stats.states_matched);
        assert_eq!(col.stats.transitions, base.stats.transitions);
        assert!(col.exhausted);
    }

    #[test]
    fn deterministic_collapse_matches_sequential() {
        let m = AbstractModel::new(64, PlatformConfig::default(), Granularity::Phase).unwrap();
        let p = SafetyLtl::parse("G(true)").unwrap();
        let seq = dfs::check(&m, &p, &CheckOptions::default()).unwrap();
        let mut o = popts(4);
        o.frontier = Frontier::Deterministic;
        o.compress = Compression::Collapse;
        let det = check_parallel(&m, &p, &o).unwrap();
        assert_eq!(det.stats.states_stored, seq.stats.states_stored);
        assert_eq!(det.stats.states_matched, seq.stats.states_matched);
        assert_eq!(det.stats.transitions, seq.stats.transitions);
        assert!(det.exhausted);
    }

    #[test]
    fn parallel_async_rejects_por_and_spill() {
        let m = AbstractModel::new(16, PlatformConfig::default(), Granularity::Phase).unwrap();
        let p = SafetyLtl::parse("G(true)").unwrap();
        let mut o = popts(4);
        o.por = true;
        assert!(check_parallel(&m, &p, &o).is_err(), "async + por must refuse");
        let mut o = popts(4);
        o.store = StoreKind::Spill;
        assert!(check_parallel(&m, &p, &o).is_err(), "async + spill must refuse");
        let mut o = popts(4);
        o.frontier = Frontier::Deterministic;
        o.store = StoreKind::Spill;
        assert!(check_parallel(&m, &p, &o).is_err(), "det + spill must refuse");
    }
}
