//! The explicit-state search engine — our SPIN.
//!
//! Iterative DFS over a [`TransitionSystem`] with a pluggable visited
//! store, safety-property monitoring at every new state, trail
//! reconstruction from the DFS stack, multi-error collection (SPIN `-e`),
//! depth bound (SPIN `-m`), state/memory/time budgets, and optionally
//! randomized successor order (the diversification knob swarm workers
//! use).

use super::store::{StoreKind, VisitedStore};
use crate::model::{SafetyLtl, Trail, TransitionSystem, Violation};
use crate::util::rng::Xoshiro256;
use crate::util::error::Result;
use std::time::{Duration, Instant};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Order {
    InOrder,
    /// Fisher-Yates-shuffled successors, seeded (swarm diversification).
    Random(u64),
}

#[derive(Debug, Clone)]
pub struct CheckOptions {
    pub store: StoreKind,
    /// SPIN -m: maximum search depth
    pub max_depth: usize,
    pub max_states: u64,
    /// reproduces the paper's physical-RAM ceiling (Table 1: 16 GB M1)
    pub memory_budget: u64,
    pub time_budget: Option<Duration>,
    /// SPIN -e: keep searching after the first violation
    pub collect_all: bool,
    pub max_errors: usize,
    pub order: Order,
}

impl Default for CheckOptions {
    fn default() -> Self {
        Self {
            store: StoreKind::Full,
            max_depth: 10_000_000,
            max_states: u64::MAX,
            memory_budget: 16 << 30,
            time_budget: None,
            collect_all: false,
            max_errors: 1_000_000,
            order: Order::InOrder,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Abort {
    DepthTruncated,
    StateLimit,
    MemoryLimit,
    TimeLimit,
    ErrorLimit,
}

#[derive(Debug, Clone, Default)]
pub struct SearchStats {
    pub states_stored: u64,
    pub states_matched: u64,
    pub transitions: u64,
    pub max_depth_reached: usize,
    pub bytes_used: u64,
    pub elapsed: Duration,
    /// first limit that fired, if any
    pub abort: Option<Abort>,
}

#[derive(Debug)]
pub struct CheckReport<S> {
    pub violations: Vec<Violation<S>>,
    pub stats: SearchStats,
    /// true iff the full reachable space (within no limits) was explored —
    /// only then is "no counterexample" a proof that the property holds.
    pub exhausted: bool,
}

impl<S> CheckReport<S> {
    pub fn found(&self) -> bool {
        !self.violations.is_empty()
    }

    /// Property verdict, SPIN-style: Ok(true) = property holds (proved),
    /// Ok(false) = violated, Err = search was inconclusive (limits hit,
    /// nothing found).
    pub fn verdict(&self) -> Result<bool> {
        if self.found() {
            Ok(false)
        } else if self.exhausted {
            Ok(true)
        } else {
            crate::bail!("search inconclusive: no violation found but state space not exhausted ({:?})", self.stats.abort)
        }
    }
}

struct Frame<S> {
    state: S,
    succs: Vec<S>,
    next: usize,
}

/// Verify `G(prop)` on `model`. Violations carry full trails.
pub fn check<M: TransitionSystem>(
    model: &M,
    prop: &SafetyLtl,
    opts: &CheckOptions,
) -> Result<CheckReport<M::State>> {
    let start = Instant::now();
    let mut store = VisitedStore::new(opts.store);
    let mut stats = SearchStats::default();
    let mut violations = Vec::new();
    let mut exhausted = true;
    let mut rng = match opts.order {
        Order::Random(seed) => Some(Xoshiro256::new(seed)),
        Order::InOrder => None,
    };
    let mut enc = Vec::with_capacity(64);

    // retained across iterations to avoid re-allocating successor vectors
    let mut stack: Vec<Frame<M::State>> = Vec::new();

    let check_state = |s: &M::State,
                           depth: usize,
                           stack: &[Frame<M::State>],
                           violations: &mut Vec<Violation<M::State>>|
     -> Result<()> {
        let lookup = |name: &str| model.eval_var(s, name);
        if !prop.holds(&lookup)? {
            let mut states: Vec<M::State> =
                stack.iter().map(|f| f.state.clone()).collect();
            states.push(s.clone());
            violations.push(Violation {
                trail: Trail { states },
                depth,
                found_after: start.elapsed(),
            });
        }
        Ok(())
    };

    'outer: for init in model.initial_states() {
        model.encode(&init, &mut enc);
        if !store.insert(&enc) {
            stats.states_matched += 1;
            continue;
        }
        stats.states_stored += 1;
        check_state(&init, 0, &stack, &mut violations)?;
        if violations.len() >= opts.max_errors || (!opts.collect_all && !violations.is_empty()) {
            if violations.len() >= opts.max_errors {
                stats.abort = Some(Abort::ErrorLimit);
                exhausted = false;
            }
            break 'outer;
        }

        let mut succs = Vec::new();
        model.successors(&init, &mut succs);
        stats.transitions += succs.len() as u64;
        if let Some(r) = rng.as_mut() {
            r.shuffle(&mut succs);
        }
        stack.push(Frame { state: init, succs, next: 0 });

        while let Some(top) = stack.last_mut() {
            // take successors back-to-front: avoids a clone per transition
            // (`next` counts consumed successors for stats only)
            let Some(s) = top.succs.pop() else {
                stack.pop();
                continue;
            };
            top.next += 1;

            model.encode(&s, &mut enc);
            if !store.insert(&enc) {
                stats.states_matched += 1;
                continue;
            }
            stats.states_stored += 1;
            let depth = stack.len();
            stats.max_depth_reached = stats.max_depth_reached.max(depth);

            check_state(&s, depth, &stack, &mut violations)?;
            let err_limit = violations.len() >= opts.max_errors;
            if err_limit || (!opts.collect_all && !violations.is_empty()) {
                if err_limit {
                    stats.abort = Some(Abort::ErrorLimit);
                    exhausted = false;
                }
                break 'outer;
            }

            // budget checks (amortized: every 4096 stored states)
            if stats.states_stored % 4096 == 0 {
                if stats.states_stored >= opts.max_states {
                    stats.abort = Some(Abort::StateLimit);
                    exhausted = false;
                    break 'outer;
                }
                if store.bytes_used() >= opts.memory_budget {
                    stats.abort = Some(Abort::MemoryLimit);
                    exhausted = false;
                    break 'outer;
                }
                if let Some(tb) = opts.time_budget {
                    if start.elapsed() >= tb {
                        stats.abort = Some(Abort::TimeLimit);
                        exhausted = false;
                        break 'outer;
                    }
                }
            }

            if depth >= opts.max_depth {
                // do not expand below the depth bound (SPIN -m semantics)
                stats.abort.get_or_insert(Abort::DepthTruncated);
                exhausted = false;
                continue;
            }

            let mut succs = Vec::new();
            model.successors(&s, &mut succs);
            stats.transitions += succs.len() as u64;
            if let Some(r) = rng.as_mut() {
                r.shuffle(&mut succs);
            }
            stack.push(Frame { state: s, succs, next: 0 });
        }
    }

    // Bitstate storage is inherently partial: a Bloom false positive may
    // have pruned genuinely new states, so exhaustion cannot be claimed.
    if matches!(opts.store, StoreKind::Bitstate { .. }) {
        exhausted = false;
    }
    if !opts.collect_all && !violations.is_empty() {
        exhausted = false; // stopped early by design
    }

    stats.bytes_used = store.bytes_used();
    stats.elapsed = start.elapsed();
    Ok(CheckReport { violations, stats, exhausted })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::TransitionSystem;

    /// Binary tree of depth `d`; leaves are terminal; value = path bits.
    struct Tree {
        depth: u32,
    }

    #[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
    struct TState {
        level: u32,
        path: u32,
    }

    impl TransitionSystem for Tree {
        type State = TState;

        fn initial_states(&self) -> Vec<TState> {
            vec![TState { level: 0, path: 0 }]
        }

        fn successors(&self, s: &TState, out: &mut Vec<TState>) {
            out.clear();
            if s.level < self.depth {
                out.push(TState { level: s.level + 1, path: s.path << 1 });
                out.push(TState { level: s.level + 1, path: (s.path << 1) | 1 });
            }
        }

        fn encode(&self, s: &TState, out: &mut Vec<u8>) {
            out.clear();
            out.extend_from_slice(&s.level.to_le_bytes());
            out.extend_from_slice(&s.path.to_le_bytes());
        }

        fn eval_var(&self, s: &TState, name: &str) -> Option<i64> {
            match name {
                "level" => Some(s.level as i64),
                "path" => Some(s.path as i64),
                "leaf" => Some((s.level == self.depth) as i64),
                _ => None,
            }
        }
    }

    #[test]
    fn explores_full_tree() {
        let m = Tree { depth: 10 };
        let p = SafetyLtl::parse("G(level >= 0)").unwrap();
        let r = check(&m, &p, &CheckOptions::default()).unwrap();
        assert!(r.exhausted);
        assert!(!r.found());
        assert_eq!(r.verdict().unwrap(), true);
        // 2^11 - 1 nodes
        assert_eq!(r.stats.states_stored, 2047);
        assert_eq!(r.stats.max_depth_reached, 10);
    }

    #[test]
    fn finds_violation_with_trail() {
        let m = Tree { depth: 8 };
        // "no leaf has path 37" is false: path 37 = 0b00100101 exists
        let p = SafetyLtl::parse("G(leaf -> path != 37)").unwrap();
        let r = check(&m, &p, &CheckOptions::default()).unwrap();
        assert!(r.found());
        assert_eq!(r.verdict().unwrap(), false);
        let v = &r.violations[0];
        assert_eq!(v.trail.steps(), 8);
        assert_eq!(v.trail.final_var(&m, "path"), Some(37));
        // trail states form a parent-child chain
        for w in v.trail.states.windows(2) {
            assert_eq!(w[1].level, w[0].level + 1);
            assert!(w[1].path >> 1 == w[0].path);
        }
    }

    #[test]
    fn collect_all_errors() {
        let m = Tree { depth: 6 };
        // every leaf violates: 64 errors
        let p = SafetyLtl::parse("G(!leaf)").unwrap();
        let mut o = CheckOptions::default();
        o.collect_all = true;
        let r = check(&m, &p, &o).unwrap();
        assert_eq!(r.violations.len(), 64);
        assert!(r.exhausted);
        o.max_errors = 10;
        let r = check(&m, &p, &o).unwrap();
        assert_eq!(r.violations.len(), 10);
        assert_eq!(r.stats.abort, Some(Abort::ErrorLimit));
        assert!(!r.exhausted);
    }

    #[test]
    fn depth_bound_truncates() {
        let m = Tree { depth: 12 };
        let p = SafetyLtl::parse("G(true)").unwrap();
        let mut o = CheckOptions::default();
        o.max_depth = 5;
        let r = check(&m, &p, &o).unwrap();
        assert!(!r.exhausted);
        assert_eq!(r.stats.abort, Some(Abort::DepthTruncated));
        assert!(r.stats.states_stored < 2u64.pow(13));
        assert!(r.verdict().is_err()); // inconclusive
    }

    #[test]
    fn state_limit_aborts() {
        let m = Tree { depth: 20 };
        let p = SafetyLtl::parse("G(true)").unwrap();
        let mut o = CheckOptions::default();
        o.max_states = 10_000;
        let r = check(&m, &p, &o).unwrap();
        assert_eq!(r.stats.abort, Some(Abort::StateLimit));
        assert!(!r.exhausted);
    }

    #[test]
    fn memory_limit_aborts() {
        let m = Tree { depth: 20 };
        let p = SafetyLtl::parse("G(true)").unwrap();
        let mut o = CheckOptions::default();
        o.memory_budget = 64 << 10; // 64 KB
        let r = check(&m, &p, &o).unwrap();
        assert_eq!(r.stats.abort, Some(Abort::MemoryLimit));
    }

    #[test]
    fn randomized_order_same_statespace() {
        let m = Tree { depth: 10 };
        let p = SafetyLtl::parse("G(true)").unwrap();
        let mut o = CheckOptions::default();
        o.order = Order::Random(7);
        let r = check(&m, &p, &o).unwrap();
        assert_eq!(r.stats.states_stored, 2047);
        assert!(r.exhausted);
    }

    #[test]
    fn randomized_order_changes_first_hit() {
        let m = Tree { depth: 10 };
        let p = SafetyLtl::parse("G(!leaf)").unwrap();
        let mut first = std::collections::HashSet::new();
        for seed in 0..8 {
            let mut o = CheckOptions::default();
            o.order = Order::Random(seed);
            let r = check(&m, &p, &o).unwrap();
            first.insert(r.violations[0].trail.final_var(&m, "path").unwrap());
        }
        assert!(first.len() > 1, "seeds should reach different leaves first");
    }

    #[test]
    fn bitstate_never_exhaustive() {
        let m = Tree { depth: 8 };
        let p = SafetyLtl::parse("G(true)").unwrap();
        let mut o = CheckOptions::default();
        o.store = StoreKind::Bitstate { log2_bits: 20, hashes: 3 };
        let r = check(&m, &p, &o).unwrap();
        assert!(!r.exhausted);
        assert!(r.verdict().is_err());
    }

    #[test]
    fn unknown_property_var_errors() {
        let m = Tree { depth: 3 };
        let p = SafetyLtl::parse("G(nosuchvar > 0)").unwrap();
        assert!(check(&m, &p, &CheckOptions::default()).is_err());
    }
}
