//! The sequential explicit-state search engine — our SPIN.
//!
//! Iterative DFS over a [`TransitionSystem`] with a pluggable visited
//! store, safety-property monitoring at every new state, trail
//! reconstruction from the DFS stack, multi-error collection (SPIN `-e`),
//! depth bound (SPIN `-m`), state/memory/time budgets, and optionally
//! randomized successor order (the diversification knob swarm workers
//! use).
//!
//! Hot-path discipline: the property is compiled once
//! ([`SafetyLtl::compile`]) so per-state monitoring is a bulk slot read
//! plus a linear bytecode pass (no string lookups, no AST recursion), and
//! successor buffers are recycled through a freelist so the steady-state
//! loop performs no allocation — models fill them in place per the
//! [`TransitionSystem::successors`] buffer contract (the Promela VM's
//! packed states make each appended successor one memcpy). The `Full`
//! store bump-allocates encodings into an arena (see [`super::store`]).
//! The multi-threaded engine built on the same report types lives in
//! [`super::parallel`].

use super::store::{Compression, StoreKind, VisitedStore};
use crate::model::{EvalScratch, SafetyLtl, Trail, TransitionSystem, Violation};
use crate::util::error::Result;
use crate::util::hash::hash_bytes;
use crate::util::rng::Xoshiro256;
use std::path::PathBuf;
use std::time::{Duration, Instant};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Order {
    InOrder,
    /// Fisher-Yates-shuffled successors, seeded (swarm diversification).
    Random(u64),
}

/// How the *parallel* engine schedules its exploration frontier (the
/// sequential DFS ignores this — its order is already deterministic).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Frontier {
    /// Asynchronous work stealing: fastest, but the global exploration
    /// order — and therefore which violation is found *first* — depends
    /// on OS scheduling.
    Async,
    /// Depth-synchronous deterministic BFS: the exploration order, the
    /// violation sequence, and every early-stop state count are identical
    /// run-to-run and across thread counts (`Order::Random` still
    /// diversifies, keyed per-state instead of per-worker). Trades some
    /// scalability for reproducible first-trail identity (the paper's
    /// Table 1 "1st trail" column).
    Deterministic,
}

#[derive(Debug, Clone, PartialEq)]
pub struct CheckOptions {
    pub store: StoreKind,
    /// SPIN -m: maximum search depth
    pub max_depth: usize,
    pub max_states: u64,
    /// reproduces the paper's physical-RAM ceiling (Table 1: 16 GB M1)
    pub memory_budget: u64,
    pub time_budget: Option<Duration>,
    /// SPIN -e: keep searching after the first violation
    pub collect_all: bool,
    pub max_errors: usize,
    pub order: Order,
    /// worker threads for exhaustive search (1 = sequential DFS; 0 = one
    /// per available core). `checker::check` dispatches to the parallel
    /// engine when this exceeds 1 and the store is exact (full/compact);
    /// bitstate searches always run per-worker (see `swarm`).
    pub threads: u32,
    /// estimated stored-state count (0 = unknown). Both engines pre-size
    /// their visited stores from it so the hot loop never rehashes — in
    /// the parallel engine a rehash runs *under a shard lock* and stalls
    /// every worker probing that shard. Purely a performance hint: a bad
    /// estimate only changes allocation, never results.
    pub expected_states: u64,
    /// parallel frontier scheduling (see [`Frontier`])
    pub frontier: Frontier,
    /// opt-in partial-order reduction (ample sets) — sequential DFS or
    /// the deterministic frontier (`--frontier det`), where ample
    /// selection is itself deterministic. Expansion goes through
    /// [`TransitionSystem::reduced_successors`]; models that do not
    /// implement it explore the full space unchanged. Safety-preserving
    /// for the supported stutter-insensitive property fragment (see
    /// `promela::analysis`); state counts differ from the SPIN-faithful
    /// default, which is why this is off unless asked for.
    pub por: bool,
    /// opt-in state-vector compression on exact stores (`--compress`).
    /// `Collapse` requires `StoreKind::Full` and models that provide a
    /// region split (`encode_regions`); verdicts, violation order, and
    /// trails are unchanged — only `bytes_used` shrinks.
    pub compress: Compression,
    /// directory for `StoreKind::Spill` run files (None = system temp
    /// dir). The store freezes its RAM table there past
    /// `memory_budget / 2` and keeps searching.
    pub spill_dir: Option<PathBuf>,
}

impl Default for CheckOptions {
    fn default() -> Self {
        Self {
            store: StoreKind::Full,
            max_depth: 10_000_000,
            max_states: u64::MAX,
            memory_budget: 16 << 30,
            time_budget: None,
            collect_all: false,
            max_errors: 1_000_000,
            order: Order::InOrder,
            threads: 1,
            expected_states: 0,
            frontier: Frontier::Async,
            por: false,
            compress: Compression::None,
            spill_dir: None,
        }
    }
}

impl CheckOptions {
    /// Resolve `threads`: 0 means one worker per available core.
    pub fn effective_threads(&self) -> u32 {
        if self.threads == 0 {
            std::thread::available_parallelism().map(|n| n.get() as u32).unwrap_or(1)
        } else {
            self.threads
        }
    }

    /// `expected_states` clamped so the up-front reservation (~36 B per
    /// expected state, and reserved capacity counts toward `bytes_used`)
    /// stays a sliver of `memory_budget` — this is what keeps the hint
    /// *purely* a performance hint: an over-estimate must never trip
    /// `Abort::MemoryLimit` on a run that would otherwise fit.
    pub fn presize_hint(&self) -> u64 {
        let hint = self.expected_states.min(self.memory_budget / 256);
        // ample-set runs store a subset of the full space; estimates come
        // from unreduced models, so take them with a grain of salt rather
        // than reserving for states the reduction will never visit
        if self.por {
            hint / 2
        } else {
            hint
        }
    }

    /// Reject store/compression combinations that have no implementation.
    pub(super) fn validate_store(&self) -> Result<()> {
        if self.compress == Compression::Collapse
            && !matches!(self.store, StoreKind::Full | StoreKind::HashCompact)
        {
            crate::bail!("--compress collapse requires --store full or --store compact");
        }
        Ok(())
    }

    /// Build the visited store this run asked for — the exact tiers honor
    /// `compress` and `spill_dir`. Callers validate the combination first
    /// ([`validate_store`](Self::validate_store)).
    pub(super) fn build_store(&self) -> VisitedStore {
        match (self.store, self.compress) {
            (StoreKind::Full, Compression::Collapse) => {
                VisitedStore::collapsed(self.presize_hint())
            }
            (StoreKind::HashCompact, Compression::Collapse) => {
                VisitedStore::compact_collapsed(self.presize_hint())
            }
            (StoreKind::Spill, _) => {
                let dir = self.spill_dir.clone().unwrap_or_else(std::env::temp_dir);
                // half the budget for the RAM table, the rest for the
                // search stack / frontier and the per-run RAM residue
                VisitedStore::spill(&dir, self.memory_budget / 2)
            }
            _ => VisitedStore::with_capacity(self.store, self.presize_hint()),
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Abort {
    DepthTruncated,
    StateLimit,
    MemoryLimit,
    TimeLimit,
    ErrorLimit,
}

#[derive(Debug, Clone, Default)]
pub struct SearchStats {
    pub states_stored: u64,
    pub states_matched: u64,
    pub transitions: u64,
    pub max_depth_reached: usize,
    /// visited-store footprint (the DFS stack is budgeted separately
    /// against `memory_budget` but not reported here, so store regimes
    /// stay comparable across engines)
    pub bytes_used: u64,
    pub elapsed: Duration,
    /// first limit that fired, if any
    pub abort: Option<Abort>,
}

#[derive(Debug)]
pub struct CheckReport<S> {
    pub violations: Vec<Violation<S>>,
    pub stats: SearchStats,
    /// true iff the full reachable space (within no limits) was explored —
    /// only then is "no counterexample" a proof that the property holds.
    pub exhausted: bool,
}

impl<S> CheckReport<S> {
    pub fn found(&self) -> bool {
        !self.violations.is_empty()
    }

    /// Property verdict, SPIN-style: Ok(true) = property holds (proved),
    /// Ok(false) = violated, Err = search was inconclusive (limits hit,
    /// nothing found).
    pub fn verdict(&self) -> Result<bool> {
        if self.found() {
            Ok(false)
        } else if self.exhausted {
            Ok(true)
        } else {
            crate::bail!("search inconclusive: no violation found but state space not exhausted ({:?})", self.stats.abort)
        }
    }
}

struct Frame<S> {
    state: S,
    succs: Vec<S>,
}

/// Telemetry flush: push the *delta* since the last flush into the
/// global [`crate::obs::metrics`] registry. Called only from amortized
/// checkpoints (every 4096 stored states, and once at search end), so
/// the per-state path carries zero telemetry instructions; when tracing
/// is off the whole call is one relaxed bool load.
pub(super) fn flush_search_metrics(
    stats: &SearchStats,
    flushed: &mut (u64, u64, u64),
    bytes: u64,
) {
    if !crate::obs::enabled() {
        return;
    }
    let m = crate::obs::metrics();
    m.states_stored.add(stats.states_stored - flushed.0);
    m.states_matched.add(stats.states_matched - flushed.1);
    m.transitions.add(stats.transitions - flushed.2);
    *flushed = (stats.states_stored, stats.states_matched, stats.transitions);
    m.depth.set_max(stats.max_depth_reached as u64);
    m.store_bytes.set_max(bytes);
}

/// Verify `G(prop)` on `model`, single-threaded. Violations carry full
/// trails. (`checker::check` dispatches here for `threads <= 1`.)
pub fn check<M: TransitionSystem>(
    model: &M,
    prop: &SafetyLtl,
    opts: &CheckOptions,
) -> Result<CheckReport<M::State>> {
    let start = Instant::now();
    opts.validate_store()?;
    let compiled = prop.compile(model)?;
    let mut scratch = EvalScratch::default();
    let mut store = opts.build_store();
    let mut stats = SearchStats::default();
    let mut violations = Vec::new();
    let mut exhausted = true;
    let mut rng = match opts.order {
        Order::Random(seed) => Some(Xoshiro256::new(seed)),
        Order::InOrder => None,
    };
    let mut enc = Vec::with_capacity(64);
    // region bounds for collapse compression (unused, and uncomputed,
    // on every other store)
    let collapse = opts.compress == Compression::Collapse;
    let mut bounds: Vec<u32> = Vec::new();
    // telemetry high-water marks; see flush_search_metrics
    let mut flushed = (0u64, 0u64, 0u64);
    // states expanded through a proper ample subset (--por)
    let mut por_reduced = 0u64;

    let mut stack: Vec<Frame<M::State>> = Vec::new();
    // retired successor buffers, reused by later expansions (zero
    // steady-state allocation: `successors` clears its out-param)
    let mut freelist: Vec<Vec<M::State>> = Vec::new();
    // heap bytes held by successor buffers (stack + freelist), maintained
    // incrementally so the budget check below stays O(1)
    let mut succ_heap: usize = 0;
    let state_size = std::mem::size_of::<M::State>();

    let record = |s: &M::State,
                  depth: usize,
                  stack: &[Frame<M::State>],
                  violations: &mut Vec<Violation<M::State>>,
                  scratch: &mut EvalScratch|
     -> Result<()> {
        if !compiled.holds_state(model, s, scratch)? {
            let mut states: Vec<M::State> =
                stack.iter().map(|f| f.state.clone()).collect();
            states.push(s.clone());
            violations.push(Violation {
                trail: Trail { states },
                depth,
                found_after: start.elapsed(),
            });
        }
        Ok(())
    };

    'outer: for init in model.initial_states() {
        model.encode(&init, &mut enc);
        if collapse {
            model.encode_regions(&init, &mut bounds);
        }
        if !store.insert_regions(&enc, hash_bytes(&enc), &bounds) {
            stats.states_matched += 1;
            continue;
        }
        stats.states_stored += 1;
        record(&init, 0, &stack, &mut violations, &mut scratch)?;
        if violations.len() >= opts.max_errors || (!opts.collect_all && !violations.is_empty()) {
            if violations.len() >= opts.max_errors {
                stats.abort = Some(Abort::ErrorLimit);
                exhausted = false;
            }
            break 'outer;
        }

        let mut succs = freelist.pop().unwrap_or_default();
        let cap_before = succs.capacity();
        if opts.por {
            por_reduced += u64::from(model.reduced_successors(&init, &mut succs));
        } else {
            model.successors(&init, &mut succs);
        }
        succ_heap += (succs.capacity() - cap_before) * state_size;
        stats.transitions += succs.len() as u64;
        if let Some(r) = rng.as_mut() {
            r.shuffle(&mut succs);
        }
        stack.push(Frame { state: init, succs });

        while let Some(top) = stack.last_mut() {
            // take successors back-to-front: avoids a clone per transition
            let Some(s) = top.succs.pop() else {
                let f = stack.pop().expect("stack nonempty inside loop");
                freelist.push(f.succs);
                continue;
            };

            model.encode(&s, &mut enc);
            if collapse {
                model.encode_regions(&s, &mut bounds);
            }
            if !store.insert_regions(&enc, hash_bytes(&enc), &bounds) {
                stats.states_matched += 1;
                continue;
            }
            stats.states_stored += 1;
            let depth = stack.len();
            stats.max_depth_reached = stats.max_depth_reached.max(depth);

            record(&s, depth, &stack, &mut violations, &mut scratch)?;
            let err_limit = violations.len() >= opts.max_errors;
            if err_limit || (!opts.collect_all && !violations.is_empty()) {
                if err_limit {
                    stats.abort = Some(Abort::ErrorLimit);
                    exhausted = false;
                }
                break 'outer;
            }

            // state budget: checked on every insert (one compare), so both
            // engines abort at the same threshold regardless of cadence
            if stats.states_stored >= opts.max_states {
                stats.abort = Some(Abort::StateLimit);
                exhausted = false;
                break 'outer;
            }

            // expensive budget checks (amortized: every 4096 stored states)
            if stats.states_stored % 4096 == 0 {
                flush_search_metrics(&stats, &mut flushed, store.bytes_used());
                // the DFS stack counts against the budget too: frames plus
                // the successor buffers they (and the freelist) retain
                let stack_bytes = (succ_heap
                    + stack.capacity() * std::mem::size_of::<Frame<M::State>>())
                    as u64;
                if store.bytes_used() + stack_bytes >= opts.memory_budget {
                    stats.abort = Some(Abort::MemoryLimit);
                    exhausted = false;
                    break 'outer;
                }
                if let Some(tb) = opts.time_budget {
                    if start.elapsed() >= tb {
                        stats.abort = Some(Abort::TimeLimit);
                        exhausted = false;
                        break 'outer;
                    }
                }
            }

            if depth >= opts.max_depth {
                // do not expand below the depth bound (SPIN -m semantics)
                stats.abort.get_or_insert(Abort::DepthTruncated);
                exhausted = false;
                continue;
            }

            let mut succs = freelist.pop().unwrap_or_default();
            let cap_before = succs.capacity();
            if opts.por {
                por_reduced += u64::from(model.reduced_successors(&s, &mut succs));
            } else {
                model.successors(&s, &mut succs);
            }
            succ_heap += (succs.capacity() - cap_before) * state_size;
            stats.transitions += succs.len() as u64;
            if let Some(r) = rng.as_mut() {
                r.shuffle(&mut succs);
            }
            stack.push(Frame { state: s, succs });
        }
    }

    // Bitstate storage is inherently partial: a Bloom false positive may
    // have pruned genuinely new states, so exhaustion cannot be claimed.
    if matches!(opts.store, StoreKind::Bitstate { .. }) {
        exhausted = false;
    }
    if !opts.collect_all && !violations.is_empty() {
        exhausted = false; // stopped early by design
    }

    stats.bytes_used = store.bytes_used();
    stats.elapsed = start.elapsed();
    flush_search_metrics(&stats, &mut flushed, stats.bytes_used);
    if por_reduced > 0 {
        crate::obs::metrics().por_reduced.add(por_reduced);
    }
    Ok(CheckReport { violations, stats, exhausted })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::TransitionSystem;

    /// Binary tree of depth `d`; leaves are terminal; value = path bits.
    struct Tree {
        depth: u32,
    }

    #[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
    struct TState {
        level: u32,
        path: u32,
    }

    impl TransitionSystem for Tree {
        type State = TState;

        fn initial_states(&self) -> Vec<TState> {
            vec![TState { level: 0, path: 0 }]
        }

        fn successors(&self, s: &TState, out: &mut Vec<TState>) {
            out.clear();
            if s.level < self.depth {
                out.push(TState { level: s.level + 1, path: s.path << 1 });
                out.push(TState { level: s.level + 1, path: (s.path << 1) | 1 });
            }
        }

        fn encode(&self, s: &TState, out: &mut Vec<u8>) {
            out.clear();
            out.extend_from_slice(&s.level.to_le_bytes());
            out.extend_from_slice(&s.path.to_le_bytes());
        }

        fn eval_var(&self, s: &TState, name: &str) -> Option<i64> {
            match name {
                "level" => Some(s.level as i64),
                "path" => Some(s.path as i64),
                "leaf" => Some((s.level == self.depth) as i64),
                _ => None,
            }
        }
    }

    #[test]
    fn explores_full_tree() {
        let m = Tree { depth: 10 };
        let p = SafetyLtl::parse("G(level >= 0)").unwrap();
        let r = check(&m, &p, &CheckOptions::default()).unwrap();
        assert!(r.exhausted);
        assert!(!r.found());
        assert!(r.verdict().unwrap());
        // 2^11 - 1 nodes
        assert_eq!(r.stats.states_stored, 2047);
        assert_eq!(r.stats.max_depth_reached, 10);
    }

    #[test]
    fn finds_violation_with_trail() {
        let m = Tree { depth: 8 };
        // "no leaf has path 37" is false: path 37 = 0b00100101 exists
        let p = SafetyLtl::parse("G(leaf -> path != 37)").unwrap();
        let r = check(&m, &p, &CheckOptions::default()).unwrap();
        assert!(r.found());
        assert!(!r.verdict().unwrap());
        let v = &r.violations[0];
        assert_eq!(v.trail.steps(), 8);
        assert_eq!(v.trail.final_var(&m, "path"), Some(37));
        // trail states form a parent-child chain
        for w in v.trail.states.windows(2) {
            assert_eq!(w[1].level, w[0].level + 1);
            assert!(w[1].path >> 1 == w[0].path);
        }
    }

    #[test]
    fn collect_all_errors() {
        let m = Tree { depth: 6 };
        // every leaf violates: 64 errors
        let p = SafetyLtl::parse("G(!leaf)").unwrap();
        let mut o = CheckOptions::default();
        o.collect_all = true;
        let r = check(&m, &p, &o).unwrap();
        assert_eq!(r.violations.len(), 64);
        assert!(r.exhausted);
        o.max_errors = 10;
        let r = check(&m, &p, &o).unwrap();
        assert_eq!(r.violations.len(), 10);
        assert_eq!(r.stats.abort, Some(Abort::ErrorLimit));
        assert!(!r.exhausted);
    }

    #[test]
    fn depth_bound_truncates() {
        let m = Tree { depth: 12 };
        let p = SafetyLtl::parse("G(true)").unwrap();
        let mut o = CheckOptions::default();
        o.max_depth = 5;
        let r = check(&m, &p, &o).unwrap();
        assert!(!r.exhausted);
        assert_eq!(r.stats.abort, Some(Abort::DepthTruncated));
        assert!(r.stats.states_stored < 2u64.pow(13));
        assert!(r.verdict().is_err()); // inconclusive
    }

    #[test]
    fn state_limit_aborts() {
        let m = Tree { depth: 20 };
        let p = SafetyLtl::parse("G(true)").unwrap();
        let mut o = CheckOptions::default();
        o.max_states = 10_000;
        let r = check(&m, &p, &o).unwrap();
        assert_eq!(r.stats.abort, Some(Abort::StateLimit));
        assert!(!r.exhausted);
    }

    #[test]
    fn memory_limit_aborts() {
        let m = Tree { depth: 20 };
        let p = SafetyLtl::parse("G(true)").unwrap();
        let mut o = CheckOptions::default();
        o.memory_budget = 64 << 10; // 64 KB
        let r = check(&m, &p, &o).unwrap();
        assert_eq!(r.stats.abort, Some(Abort::MemoryLimit));
    }

    #[test]
    fn randomized_order_same_statespace() {
        let m = Tree { depth: 10 };
        let p = SafetyLtl::parse("G(true)").unwrap();
        let mut o = CheckOptions::default();
        o.order = Order::Random(7);
        let r = check(&m, &p, &o).unwrap();
        assert_eq!(r.stats.states_stored, 2047);
        assert!(r.exhausted);
    }

    #[test]
    fn randomized_order_changes_first_hit() {
        let m = Tree { depth: 10 };
        let p = SafetyLtl::parse("G(!leaf)").unwrap();
        let mut first = std::collections::HashSet::new();
        for seed in 0..8 {
            let mut o = CheckOptions::default();
            o.order = Order::Random(seed);
            let r = check(&m, &p, &o).unwrap();
            first.insert(r.violations[0].trail.final_var(&m, "path").unwrap());
        }
        assert!(first.len() > 1, "seeds should reach different leaves first");
    }

    #[test]
    fn bitstate_never_exhaustive() {
        let m = Tree { depth: 8 };
        let p = SafetyLtl::parse("G(true)").unwrap();
        let mut o = CheckOptions::default();
        o.store = StoreKind::Bitstate { log2_bits: 20, hashes: 3 };
        let r = check(&m, &p, &o).unwrap();
        assert!(!r.exhausted);
        assert!(r.verdict().is_err());
    }

    #[test]
    fn unknown_property_var_errors() {
        let m = Tree { depth: 3 };
        let p = SafetyLtl::parse("G(nosuchvar > 0)").unwrap();
        assert!(check(&m, &p, &CheckOptions::default()).is_err());
    }

    #[test]
    fn collapse_is_exact_even_without_a_region_split() {
        // Tree keeps the default encode_regions (one region): compression
        // degrades to indirection but every decision must match Full
        let m = Tree { depth: 10 };
        let p = SafetyLtl::parse("G(!leaf)").unwrap();
        let mut o = CheckOptions::default();
        o.collect_all = true;
        let base = check(&m, &p, &o).unwrap();
        o.compress = Compression::Collapse;
        let col = check(&m, &p, &o).unwrap();
        assert_eq!(base.stats.states_stored, col.stats.states_stored);
        assert_eq!(base.stats.states_matched, col.stats.states_matched);
        assert_eq!(base.violations.len(), col.violations.len());
        assert_eq!(base.exhausted, col.exhausted);
        for (a, b) in base.violations.iter().zip(&col.violations) {
            assert_eq!(a.trail.states, b.trail.states);
        }
    }

    #[test]
    fn collapse_requires_full_store() {
        let m = Tree { depth: 3 };
        let p = SafetyLtl::parse("G(true)").unwrap();
        let mut o = CheckOptions::default();
        o.compress = Compression::Collapse;
        o.store = StoreKind::Bitstate { log2_bits: 20, hashes: 3 };
        assert!(check(&m, &p, &o).is_err());
        // hash-compact gained a region-aware collapse tier — same counts
        // as the exact run on a collision-free space
        o.store = StoreKind::HashCompact;
        let cc = check(&m, &p, &o).unwrap();
        o.store = StoreKind::Full;
        let full = check(&m, &p, &o).unwrap();
        assert_eq!(cc.stats.states_stored, full.stats.states_stored);
    }

    #[test]
    fn spill_survives_a_memory_budget_that_kills_full() {
        let m = Tree { depth: 14 };
        let p = SafetyLtl::parse("G(true)").unwrap();
        let mut o = CheckOptions::default();
        o.memory_budget = 1 << 20; // 1 MiB: too small for 32k stored states
        let full = check(&m, &p, &o).unwrap();
        assert_eq!(full.stats.abort, Some(Abort::MemoryLimit));
        assert!(!full.exhausted);
        o.store = StoreKind::Spill;
        let sp = check(&m, &p, &o).unwrap();
        assert!(sp.exhausted, "spill store must absorb the overflow: {:?}", sp.stats.abort);
        assert_eq!(sp.stats.states_stored, 2u64.pow(15) - 1);
    }

    #[test]
    fn effective_threads_resolves_auto() {
        let mut o = CheckOptions::default();
        assert_eq!(o.effective_threads(), 1);
        o.threads = 3;
        assert_eq!(o.effective_threads(), 3);
        o.threads = 0;
        assert!(o.effective_threads() >= 1);
    }
}
