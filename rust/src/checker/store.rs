//! Visited-state stores — the checker's memory subsystem.
//!
//! Three regimes mirror SPIN's:
//! - `Full`: exact (stores the encoded state vector) — SPIN's default.
//!   Backed by [`FullStore`]: encoded states are bump-appended to one
//!   contiguous byte arena and deduplicated through a hand-rolled
//!   open-addressing table, so an insert costs one hash and one probe
//!   sequence with **no per-state allocation** (the seed version boxed
//!   every state and hashed it twice via `contains` + `insert`);
//! - `HashCompact`: 64-bit hash compaction (SPIN `-DHC`) — exact up to
//!   hash collisions, 8 bytes/state;
//! - `Bitstate`: Bloom-filter bitstate hashing (SPIN `-DBITSTATE`, the
//!   basis of swarm verification) — k probes into a 2^log2_bits bit table.
//!
//! `insert` returns whether the state was new; `insert_hashed` is the same
//! with a caller-supplied hash (the parallel engine hashes once for shard
//! selection and reuses it). `bytes_used` feeds the memory budget that
//! reproduces the paper's 16 GB exhaustive-mode ceiling (Table 1).

use crate::util::hash::{hash_bytes, hash_bytes_seeded, FxHashSet};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StoreKind {
    Full,
    HashCompact,
    Bitstate { log2_bits: u8, hashes: u8 },
}

impl StoreKind {
    pub fn name(&self) -> &'static str {
        match self {
            StoreKind::Full => "full",
            StoreKind::HashCompact => "hash-compact",
            StoreKind::Bitstate { .. } => "bitstate",
        }
    }
}

#[derive(Clone, Copy)]
struct FullEntry {
    hash: u64,
    pos: usize,
    len: u32,
}

/// Arena-backed exact store: one byte arena, one entry record per state,
/// one open-addressing index (slot = entry index + 1, 0 = empty).
pub struct FullStore {
    data: Vec<u8>,
    entries: Vec<FullEntry>,
    table: Vec<u32>,
    mask: usize,
}

const FULL_INIT_SLOTS: usize = 1 << 10;

impl FullStore {
    pub(crate) fn new() -> Self {
        Self {
            data: Vec::new(),
            entries: Vec::new(),
            table: vec![0u32; FULL_INIT_SLOTS],
            mask: FULL_INIT_SLOTS - 1,
        }
    }

    /// A store pre-sized for `expected` states: the index table starts at
    /// the power of two that keeps `expected` entries under the 7/8 load
    /// cap, so a well-estimated search never pays a `grow()` rehash (the
    /// parallel engine would otherwise rehash under a shard lock, stalling
    /// every worker probing that shard). A low estimate only means later
    /// growth — correctness is unaffected.
    pub(crate) fn with_capacity(expected: usize) -> Self {
        let slots = (expected.saturating_mul(8) / 7 + 1)
            .next_power_of_two()
            .max(FULL_INIT_SLOTS);
        Self {
            // ~8 B/state arena headroom; encodings beyond that grow normally
            data: Vec::with_capacity(expected.saturating_mul(8)),
            entries: Vec::with_capacity(expected),
            table: vec![0u32; slots],
            mask: slots - 1,
        }
    }

    #[inline]
    fn entry_bytes(&self, e: &FullEntry) -> &[u8] {
        &self.data[e.pos..e.pos + e.len as usize]
    }

    /// Single-probe insert: hash once (caller-supplied), walk one linear
    /// probe sequence, and either match an existing entry or append to the
    /// arena in place. Telemetry (probe-length counter) is derived from
    /// the start/end indices at the exit points, so the probe loop itself
    /// carries no counting instructions.
    pub(crate) fn insert_hashed(&mut self, enc: &[u8], h: u64) -> bool {
        let start = (h as usize) & self.mask;
        let mut i = start;
        loop {
            let slot = self.table[i];
            if slot == 0 {
                let e = FullEntry { hash: h, pos: self.data.len(), len: enc.len() as u32 };
                self.data.extend_from_slice(enc);
                self.entries.push(e);
                self.table[i] = self.entries.len() as u32;
                if crate::obs::enabled() {
                    let probes = (i.wrapping_sub(start) & self.mask) as u64 + 1;
                    crate::obs::metrics().store_probes.add(probes);
                }
                // grow at 7/8 load so probe sequences stay short
                if self.entries.len() * 8 >= self.table.len() * 7 {
                    self.grow();
                }
                return true;
            }
            let e = self.entries[slot as usize - 1];
            if e.hash == h && e.len as usize == enc.len() && self.entry_bytes(&e) == enc {
                if crate::obs::enabled() {
                    let probes = (i.wrapping_sub(start) & self.mask) as u64 + 1;
                    crate::obs::metrics().store_probes.add(probes);
                }
                return false;
            }
            i = (i + 1) & self.mask;
        }
    }

    fn grow(&mut self) {
        crate::obs::metrics().store_resizes.add(1);
        let new_len = self.table.len() * 2;
        self.mask = new_len - 1;
        self.table.clear();
        self.table.resize(new_len, 0);
        for (idx, e) in self.entries.iter().enumerate() {
            let mut i = (e.hash as usize) & self.mask;
            while self.table[i] != 0 {
                i = (i + 1) & self.mask;
            }
            self.table[i] = (idx + 1) as u32;
        }
    }

    pub(crate) fn len(&self) -> u64 {
        self.entries.len() as u64
    }

    pub(crate) fn bytes_used(&self) -> u64 {
        (self.data.capacity()
            + self.entries.capacity() * std::mem::size_of::<FullEntry>()
            + self.table.len() * std::mem::size_of::<u32>()) as u64
    }
}

pub enum VisitedStore {
    Full(FullStore),
    HashCompact { set: FxHashSet<u64> },
    Bitstate { table: Vec<u64>, mask: u64, hashes: u8, set_bits: u64 },
}

/// Cap on pre-sized entry counts: a wild over-estimate must not allocate
/// unbounded memory up front (1 << 26 entries ≈ 64 M states).
const PRESIZE_CAP: u64 = 1 << 26;

impl VisitedStore {
    /// [`new`](Self::new) pre-sized for an `expected` state count
    /// (0 = unknown: identical to `new`). Bitstate tables are fixed-size
    /// by construction and ignore the hint.
    pub fn with_capacity(kind: StoreKind, expected: u64) -> Self {
        let expected = expected.min(PRESIZE_CAP) as usize;
        if expected == 0 {
            return Self::new(kind);
        }
        match kind {
            StoreKind::Full => Self::Full(FullStore::with_capacity(expected)),
            StoreKind::HashCompact => Self::HashCompact {
                set: FxHashSet::with_capacity_and_hasher(expected, Default::default()),
            },
            StoreKind::Bitstate { .. } => Self::new(kind),
        }
    }

    pub fn new(kind: StoreKind) -> Self {
        match kind {
            StoreKind::Full => Self::Full(FullStore::new()),
            StoreKind::HashCompact => Self::HashCompact { set: FxHashSet::default() },
            StoreKind::Bitstate { log2_bits, hashes } => {
                let log2 = log2_bits.clamp(10, 40);
                let words = (1usize << log2) / 64;
                Self::Bitstate {
                    table: vec![0u64; words],
                    mask: (1u64 << log2) - 1,
                    hashes: hashes.max(1),
                    set_bits: 0,
                }
            }
        }
    }

    /// Insert an encoded state; returns true when it was not seen before.
    /// (Bitstate may return false for genuinely new states — the expected
    /// Bloom false-positive, which makes the search partial, as in SPIN.)
    pub fn insert(&mut self, enc: &[u8]) -> bool {
        match self {
            Self::Full(f) => f.insert_hashed(enc, hash_bytes(enc)),
            Self::HashCompact { set } => set.insert(hash_bytes(enc)),
            Self::Bitstate { .. } => self.insert_bitstate(enc),
        }
    }

    /// [`insert`](Self::insert) with a caller-precomputed `hash_bytes(enc)`
    /// — the parallel engine hashes once for shard routing and passes the
    /// value through. Bitstate ignores the hint (its k Bloom probes use
    /// independent seeds).
    pub fn insert_hashed(&mut self, enc: &[u8], h: u64) -> bool {
        match self {
            Self::Full(f) => f.insert_hashed(enc, h),
            Self::HashCompact { set } => set.insert(h),
            Self::Bitstate { .. } => self.insert_bitstate(enc),
        }
    }

    fn insert_bitstate(&mut self, enc: &[u8]) -> bool {
        let Self::Bitstate { table, mask, hashes, set_bits } = self else {
            unreachable!("insert_bitstate on non-bitstate store");
        };
        let mut new = false;
        for k in 0..*hashes {
            let bit = hash_bytes_seeded(enc, 0x9E37 + k as u64) & *mask;
            let (w, b) = ((bit / 64) as usize, bit % 64);
            if table[w] & (1 << b) == 0 {
                table[w] |= 1 << b;
                *set_bits += 1;
                new = true;
            }
        }
        new
    }

    /// Number of distinct states recorded (bitstate: lower-bound estimate
    /// from bit occupancy).
    pub fn len(&self) -> u64 {
        match self {
            Self::Full(f) => f.len(),
            Self::HashCompact { set } => set.len() as u64,
            Self::Bitstate { set_bits, hashes, .. } => set_bits / (*hashes).max(1) as u64,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn bytes_used(&self) -> u64 {
        match self {
            Self::Full(f) => f.bytes_used(),
            Self::HashCompact { set } => set.len() as u64 * 16,
            Self::Bitstate { table, .. } => table.len() as u64 * 8,
        }
    }

    /// Bloom saturation in [0,1] — swarm workers report this; near 1.0 the
    /// search degenerates (everything looks visited).
    pub fn saturation(&self) -> f64 {
        match self {
            Self::Bitstate { table, set_bits, .. } => {
                *set_bits as f64 / (table.len() as f64 * 64.0)
            }
            _ => 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn states(n: u64) -> Vec<Vec<u8>> {
        (0..n).map(|i| i.to_le_bytes().to_vec()).collect()
    }

    #[test]
    fn full_store_exact() {
        let mut s = VisitedStore::new(StoreKind::Full);
        for st in states(1000) {
            assert!(s.insert(&st));
        }
        for st in states(1000) {
            assert!(!s.insert(&st));
        }
        assert_eq!(s.len(), 1000);
        assert!(s.bytes_used() > 1000 * 8);
    }

    #[test]
    fn full_store_survives_table_growth() {
        // cross several grow() boundaries, with variable-length encodings
        let mut s = VisitedStore::new(StoreKind::Full);
        let mut items: Vec<Vec<u8>> = Vec::new();
        for i in 0u64..20_000 {
            let mut v = i.to_le_bytes().to_vec();
            v.truncate(1 + (i % 8) as usize);
            v.push((i / 251) as u8); // disambiguate truncated prefixes
            items.push(v);
        }
        items.sort();
        items.dedup();
        for it in &items {
            assert!(s.insert(it), "fresh item reported as seen");
        }
        for it in &items {
            assert!(!s.insert(it), "seen item reported as fresh after growth");
        }
        assert_eq!(s.len(), items.len() as u64);
    }

    #[test]
    fn full_store_insert_hashed_consistent_with_insert() {
        let mut a = VisitedStore::new(StoreKind::Full);
        let mut b = VisitedStore::new(StoreKind::Full);
        for st in states(500) {
            let h = hash_bytes(&st);
            assert_eq!(a.insert(&st), b.insert_hashed(&st, h));
        }
        assert_eq!(a.len(), b.len());
    }

    #[test]
    fn hash_compact_mostly_exact() {
        let mut s = VisitedStore::new(StoreKind::HashCompact);
        let mut new = 0;
        for st in states(100_000) {
            if s.insert(&st) {
                new += 1;
            }
        }
        // collisions possible but vanishingly rare at 1e5/2^64
        assert_eq!(new, 100_000);
        assert!(!s.insert(&states(1)[0]));
        assert_eq!(s.bytes_used(), 100_000 * 16);
    }

    #[test]
    fn bitstate_no_false_negatives() {
        // Bloom filters never report "seen" as "new" once inserted.
        let mut s = VisitedStore::new(StoreKind::Bitstate { log2_bits: 20, hashes: 3 });
        for st in states(10_000) {
            s.insert(&st);
        }
        for st in states(10_000) {
            assert!(!s.insert(&st), "false negative in bitstate store");
        }
        assert!(s.saturation() > 0.0 && s.saturation() < 0.1);
    }

    #[test]
    fn bitstate_fixed_memory() {
        let s = VisitedStore::new(StoreKind::Bitstate { log2_bits: 24, hashes: 3 });
        assert_eq!(s.bytes_used(), (1 << 24) / 8);
    }

    #[test]
    fn bitstate_saturates_small_table() {
        let mut s = VisitedStore::new(StoreKind::Bitstate { log2_bits: 10, hashes: 3 });
        let mut missed = 0u64;
        for st in states(5000) {
            if !s.insert(&st) {
                missed += 1; // false positive: state wrongly "seen"
            }
        }
        assert!(missed > 0, "tiny table must produce false positives");
        assert!(s.saturation() > 0.5);
    }

    #[test]
    fn presized_store_agrees_with_default() {
        for kind in [StoreKind::Full, StoreKind::HashCompact] {
            let mut a = VisitedStore::new(kind);
            let mut b = VisitedStore::with_capacity(kind, 2000);
            for st in states(2000) {
                assert_eq!(a.insert(&st), b.insert(&st));
            }
            for st in states(2000) {
                assert!(!b.insert(&st));
            }
            assert_eq!(a.len(), b.len());
        }
        // 0 = unknown, and bitstate ignores the hint
        assert_eq!(VisitedStore::with_capacity(StoreKind::Full, 0).len(), 0);
        let s = VisitedStore::with_capacity(StoreKind::Bitstate { log2_bits: 20, hashes: 3 }, 999);
        assert_eq!(s.bytes_used(), (1 << 20) / 8);
    }

    #[test]
    fn kind_names() {
        assert_eq!(StoreKind::Full.name(), "full");
        assert_eq!(StoreKind::Bitstate { log2_bits: 20, hashes: 3 }.name(), "bitstate");
    }
}
