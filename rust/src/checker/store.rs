//! Visited-state stores — the checker's memory subsystem.
//!
//! Three regimes mirror SPIN's:
//! - `Full`: exact (stores the encoded state vector) — SPIN's default.
//!   Backed by [`FullStore`]: encoded states are bump-appended to one
//!   contiguous byte arena and deduplicated through a hand-rolled
//!   open-addressing table, so an insert costs one hash and one probe
//!   sequence with **no per-state allocation** (the seed version boxed
//!   every state and hashed it twice via `contains` + `insert`);
//! - `HashCompact`: 64-bit hash compaction (SPIN `-DHC`) — exact up to
//!   hash collisions, 8 bytes/state;
//! - `Bitstate`: Bloom-filter bitstate hashing (SPIN `-DBITSTATE`, the
//!   basis of swarm verification) — k probes into a 2^log2_bits bit table.
//!
//! Two scale tiers extend the exact regime:
//! - [`CollapseStore`] (SPIN `-DCOLLAPSE`): the encoded state is split
//!   into regions (globals / per-channel / per-process frame, provided by
//!   the model as byte offsets), each region is interned once in a shared
//!   component table, and only the short tuple of component indices is
//!   stored per state. Exact: tuple equality holds iff the concatenation
//!   of the components — the raw encoding — is equal;
//! - [`SpillStore`] (`--store spill`): a [`FullStore`] that, past a
//!   memory watermark, freezes its contents to hash-sorted runs on disk
//!   and answers membership via bloom-filter-guarded run lookups, so a
//!   model bigger than the `--memory-budget` degrades to sequential I/O
//!   instead of aborting with `MemoryLimit`.
//!
//! `insert` returns whether the state was new; `insert_hashed` is the same
//! with a caller-supplied hash (the parallel engine hashes once for shard
//! selection and reuses it). `bytes_used` feeds the memory budget that
//! reproduces the paper's 16 GB exhaustive-mode ceiling (Table 1).

use std::fs::File;
use std::io::{BufWriter, Read as _, Seek as _, SeekFrom, Write as _};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::hash::{hash_bytes, hash_bytes_seeded, FxHashSet};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StoreKind {
    Full,
    HashCompact,
    Bitstate { log2_bits: u8, hashes: u8 },
    /// Exact store that overflows to sorted runs on disk past a memory
    /// watermark (sequential engine only).
    Spill,
}

impl StoreKind {
    pub fn name(&self) -> &'static str {
        match self {
            StoreKind::Full => "full",
            StoreKind::HashCompact => "hash-compact",
            StoreKind::Bitstate { .. } => "bitstate",
            StoreKind::Spill => "spill",
        }
    }
}

/// State-vector compression applied on top of an exact store.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Compression {
    #[default]
    None,
    /// SPIN `-DCOLLAPSE`: intern state regions, store index tuples.
    Collapse,
}

impl Compression {
    pub fn name(&self) -> &'static str {
        match self {
            Compression::None => "none",
            Compression::Collapse => "collapse",
        }
    }
}

#[derive(Clone, Copy)]
struct FullEntry {
    hash: u64,
    pos: usize,
    len: u32,
}

/// Arena-backed exact store: one byte arena, one entry record per state,
/// one open-addressing index (slot = entry index + 1, 0 = empty).
pub struct FullStore {
    data: Vec<u8>,
    entries: Vec<FullEntry>,
    table: Vec<u32>,
    mask: usize,
}

const FULL_INIT_SLOTS: usize = 1 << 10;

impl FullStore {
    pub(crate) fn new() -> Self {
        Self {
            data: Vec::new(),
            entries: Vec::new(),
            table: vec![0u32; FULL_INIT_SLOTS],
            mask: FULL_INIT_SLOTS - 1,
        }
    }

    /// A store pre-sized for `expected` states: the index table starts at
    /// the power of two that keeps `expected` entries under the 7/8 load
    /// cap, so a well-estimated search never pays a `grow()` rehash (the
    /// parallel engine would otherwise rehash under a shard lock, stalling
    /// every worker probing that shard). A low estimate only means later
    /// growth — correctness is unaffected.
    pub(crate) fn with_capacity(expected: usize) -> Self {
        let slots = (expected.saturating_mul(8) / 7 + 1)
            .next_power_of_two()
            .max(FULL_INIT_SLOTS);
        Self {
            // ~8 B/state arena headroom; encodings beyond that grow normally
            data: Vec::with_capacity(expected.saturating_mul(8)),
            entries: Vec::with_capacity(expected),
            table: vec![0u32; slots],
            mask: slots - 1,
        }
    }

    #[inline]
    fn entry_bytes(&self, e: &FullEntry) -> &[u8] {
        &self.data[e.pos..e.pos + e.len as usize]
    }

    /// Single-probe insert: hash once (caller-supplied), walk one linear
    /// probe sequence, and either match an existing entry or append to the
    /// arena in place. Telemetry (probe-length counter) is derived from
    /// the start/end indices at the exit points, so the probe loop itself
    /// carries no counting instructions.
    pub(crate) fn insert_hashed(&mut self, enc: &[u8], h: u64) -> bool {
        self.intern_hashed(enc, h).1
    }

    /// [`insert_hashed`](Self::insert_hashed) that also returns the entry
    /// index — [`CollapseStore`] stores these indices as its compressed
    /// state representation, so the index of a given byte string must be
    /// stable for the lifetime of the store (it is: entries are append-only
    /// and `grow()` only rebuilds the probe table).
    pub(crate) fn intern_hashed(&mut self, enc: &[u8], h: u64) -> (u32, bool) {
        let start = (h as usize) & self.mask;
        let mut i = start;
        loop {
            let slot = self.table[i];
            if slot == 0 {
                let idx = self.entries.len() as u32;
                let e = FullEntry { hash: h, pos: self.data.len(), len: enc.len() as u32 };
                self.data.extend_from_slice(enc);
                self.entries.push(e);
                self.table[i] = idx + 1;
                if crate::obs::enabled() {
                    let probes = (i.wrapping_sub(start) & self.mask) as u64 + 1;
                    crate::obs::metrics().store_probes.add(probes);
                }
                // grow at 7/8 load so probe sequences stay short
                if self.entries.len() * 8 >= self.table.len() * 7 {
                    self.grow();
                }
                return (idx, true);
            }
            let e = self.entries[slot as usize - 1];
            if e.hash == h && e.len as usize == enc.len() && self.entry_bytes(&e) == enc {
                if crate::obs::enabled() {
                    let probes = (i.wrapping_sub(start) & self.mask) as u64 + 1;
                    crate::obs::metrics().store_probes.add(probes);
                }
                return (slot - 1, false);
            }
            i = (i + 1) & self.mask;
        }
    }

    /// Probe without inserting — the spill store checks RAM residency
    /// before paying a disk lookup.
    pub(crate) fn contains_hashed(&self, enc: &[u8], h: u64) -> bool {
        let mut i = (h as usize) & self.mask;
        loop {
            let slot = self.table[i];
            if slot == 0 {
                return false;
            }
            let e = self.entries[slot as usize - 1];
            if e.hash == h && e.len as usize == enc.len() && self.entry_bytes(&e) == enc {
                return true;
            }
            i = (i + 1) & self.mask;
        }
    }

    /// Entry view for freezing to disk: (hash, bytes) sorted by hash
    /// (stable, so equal hashes keep insertion order and freezes are
    /// deterministic).
    fn sorted_entries(&self) -> Vec<(u64, &[u8])> {
        let mut v: Vec<(u64, &[u8])> =
            self.entries.iter().map(|e| (e.hash, self.entry_bytes(e))).collect();
        v.sort_by_key(|&(h, _)| h);
        v
    }

    fn grow(&mut self) {
        crate::obs::metrics().store_resizes.add(1);
        let new_len = self.table.len() * 2;
        self.mask = new_len - 1;
        self.table.clear();
        self.table.resize(new_len, 0);
        for (idx, e) in self.entries.iter().enumerate() {
            let mut i = (e.hash as usize) & self.mask;
            while self.table[i] != 0 {
                i = (i + 1) & self.mask;
            }
            self.table[i] = (idx + 1) as u32;
        }
    }

    pub(crate) fn len(&self) -> u64 {
        self.entries.len() as u64
    }

    pub(crate) fn bytes_used(&self) -> u64 {
        (self.data.capacity()
            + self.entries.capacity() * std::mem::size_of::<FullEntry>()
            + self.table.len() * std::mem::size_of::<u32>()) as u64
    }
}

/// SPIN's `-DCOLLAPSE`, recast for flat encodings: the caller supplies
/// region boundaries (byte offsets: globals / per-channel / per-process
/// frame), each region is interned once in `components`, and only the
/// tuple of little-endian component indices is stored per state in
/// `tuples`.
///
/// Exactness: component indices are bijective with region byte strings
/// (the component table is an exact [`FullStore`]), so two tuples are
/// equal iff the concatenations of their regions — the raw encodings —
/// are equal. Dedup decisions therefore match `FullStore` byte-for-byte,
/// and the raw hash `h` keyed on the uncompressed encoding stays valid
/// for parent links and shard routing.
///
/// Invariant: a given store must see every insert through the same
/// region-split function (the model's `encode_regions`); mixing splits
/// for the same state would produce distinct tuples.
pub struct CollapseStore {
    components: FullStore,
    tuples: FullStore,
    tuple_buf: Vec<u8>,
}

impl CollapseStore {
    pub(crate) fn new() -> Self {
        Self { components: FullStore::new(), tuples: FullStore::new(), tuple_buf: Vec::new() }
    }

    /// Pre-sized for `expected` states. Tuples dominate (one per state);
    /// the component table saturates early and grows on demand.
    pub(crate) fn with_capacity(expected: usize) -> Self {
        Self {
            components: FullStore::new(),
            tuples: FullStore::with_capacity(expected),
            tuple_buf: Vec::new(),
        }
    }

    /// Insert under a region split: `bounds` are ascending region-end byte
    /// offsets into `enc`; the final region runs to `enc.len()` implicitly
    /// (an empty list means one region — the uncompressed fallback for
    /// models without a native split). `h` is the raw encoding's hash.
    pub(crate) fn insert_hashed(&mut self, enc: &[u8], h: u64, bounds: &[u32]) -> bool {
        let mut tuple = std::mem::take(&mut self.tuple_buf);
        tuple.clear();
        let mut start = 0usize;
        for &b in bounds {
            let end = (b as usize).min(enc.len());
            let region = &enc[start..end];
            let (idx, _) = self.components.intern_hashed(region, hash_bytes(region));
            tuple.extend_from_slice(&idx.to_le_bytes());
            start = end;
        }
        if start < enc.len() || bounds.is_empty() {
            let region = &enc[start..];
            let (idx, _) = self.components.intern_hashed(region, hash_bytes(region));
            tuple.extend_from_slice(&idx.to_le_bytes());
        }
        let new = self.tuples.insert_hashed(&tuple, h);
        self.tuple_buf = tuple;
        new
    }

    pub(crate) fn len(&self) -> u64 {
        self.tuples.len()
    }

    /// Component tables are part of the footprint — `store.bytes_peak`
    /// must not under-report the compression machinery itself.
    pub(crate) fn bytes_used(&self) -> u64 {
        self.components.bytes_used()
            + self.tuples.bytes_used()
            + self.tuple_buf.capacity() as u64
    }
}

/// Entries per sparse-index block in a frozen run: one (hash, offset)
/// pair stays in RAM per block, so a disk probe scans at most ~one block.
const SPILL_BLOCK: usize = 64;

/// Process-wide run-file sequence — two spill stores sharing a directory
/// (parallel tests, batch workers) must not collide on file names.
static SPILL_SEQ: AtomicU64 = AtomicU64::new(0);

/// One frozen run: states sorted by hash in `[u64 hash][u32 len][bytes]`
/// records, guarded by a per-run bloom filter and a sparse block index.
struct SpillRun {
    path: PathBuf,
    file: File,
    bloom: Vec<u64>,
    bloom_mask: u64,
    /// (first hash of block, byte offset of block) every `SPILL_BLOCK`
    /// records.
    index: Vec<(u64, u64)>,
}

impl Drop for SpillRun {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

fn bloom_slots(h: u64, mask: u64) -> [u64; 3] {
    let a = h;
    let b = h.rotate_right(21).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    let c = h.rotate_right(42).wrapping_mul(0xC2B2_AE3D_27D4_EB4F);
    [a & mask, b & mask, c & mask]
}

/// Exact store that degrades to disk instead of dying: new states go to
/// an in-RAM [`FullStore`]; when that exceeds `watermark` bytes it is
/// frozen to a hash-sorted run file and replaced with an empty table.
/// Membership checks probe RAM first, then each run whose bloom filter
/// admits the hash (binary search on the sparse index, then a short
/// sequential scan comparing hashes *and* bytes — lookups stay exact).
///
/// `bytes_used` reports only the RAM-resident footprint (live table +
/// blooms + indexes), so the checker's memory-budget abort does not fire
/// for state that already lives on disk — that is the point.
pub struct SpillStore {
    ram: FullStore,
    runs: Vec<SpillRun>,
    dir: PathBuf,
    watermark: u64,
    spilled: u64,
}

impl SpillStore {
    pub(crate) fn new(dir: &Path, watermark: u64) -> Self {
        Self {
            ram: FullStore::new(),
            runs: Vec::new(),
            dir: dir.to_path_buf(),
            // a zero watermark would freeze one state per run; clamp to
            // something that amortizes the freeze cost
            watermark: watermark.max(1 << 16),
            spilled: 0,
        }
    }

    pub(crate) fn insert_hashed(&mut self, enc: &[u8], h: u64) -> bool {
        if self.ram.contains_hashed(enc, h) {
            return false;
        }
        if !self.runs.is_empty() && self.on_disk(enc, h) {
            return false;
        }
        self.ram.insert_hashed(enc, h);
        if self.ram.bytes_used() >= self.watermark {
            self.freeze();
        }
        true
    }

    /// Exact membership check across all frozen runs.
    fn on_disk(&self, enc: &[u8], h: u64) -> bool {
        let mut probes = 0u64;
        let mut found = false;
        for r in &self.runs {
            if bloom_slots(h, r.bloom_mask)
                .iter()
                .any(|&bit| r.bloom[(bit / 64) as usize] & (1 << (bit % 64)) == 0)
            {
                continue;
            }
            probes += 1;
            if Self::scan_run(r, enc, h) {
                found = true;
                break;
            }
        }
        if probes > 0 && crate::obs::enabled() {
            crate::obs::metrics().spill_probes.add(probes);
        }
        found
    }

    /// Scan one run for (h, enc), starting at the last index block whose
    /// first hash precedes `h` (equal first-hashes may straddle a block
    /// boundary, hence the step back).
    fn scan_run(r: &SpillRun, enc: &[u8], h: u64) -> bool {
        let i = r.index.partition_point(|&(fh, _)| fh < h);
        let start = i.saturating_sub(1);
        if i == 0 && r.index.first().is_some_and(|&(fh, _)| fh > h) {
            return false; // h precedes every record
        }
        let mut f = &r.file;
        f.seek(SeekFrom::Start(r.index[start].1))
            .unwrap_or_else(|e| panic!("spill store: seek in {:?} failed: {e}", r.path));
        let mut hdr = [0u8; 12];
        let mut buf = Vec::new();
        loop {
            match f.read_exact(&mut hdr) {
                Ok(()) => {}
                Err(_) => return false, // end of run
            }
            let rh = u64::from_le_bytes(hdr[..8].try_into().unwrap());
            let rlen = u32::from_le_bytes(hdr[8..].try_into().unwrap()) as usize;
            if rh > h {
                return false; // sorted: past every candidate
            }
            if rh == h && rlen == enc.len() {
                buf.resize(rlen, 0);
                f.read_exact(&mut buf)
                    .unwrap_or_else(|e| panic!("spill store: read in {:?} failed: {e}", r.path));
                if buf == enc {
                    return true;
                }
            } else {
                f.seek(SeekFrom::Current(rlen as i64))
                    .unwrap_or_else(|e| panic!("spill store: seek in {:?} failed: {e}", r.path));
            }
        }
    }

    /// Freeze the in-RAM table to a new sorted run and start fresh.
    fn freeze(&mut self) {
        let entries = self.ram.sorted_entries();
        let n = entries.len();
        if n == 0 {
            return;
        }
        crate::obs::metrics().spill_runs.add(1);
        // ~8 bits/state, 3 probes: a few percent false-positive rate —
        // false positives only cost a disk scan, never correctness
        let bits = (n as u64 * 8).next_power_of_two().max(64);
        let mut bloom = vec![0u64; (bits / 64) as usize];
        let bloom_mask = bits - 1;
        let path = self.dir.join(format!(
            "mcat-spill-{}-{}.run",
            std::process::id(),
            SPILL_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        let file = File::create(&path)
            .unwrap_or_else(|e| panic!("spill store: cannot create {:?}: {e}", path));
        let mut w = BufWriter::new(file);
        let mut index = Vec::with_capacity(n / SPILL_BLOCK + 1);
        let mut off = 0u64;
        for (i, &(h, bytes)) in entries.iter().enumerate() {
            if i % SPILL_BLOCK == 0 {
                index.push((h, off));
            }
            for bit in bloom_slots(h, bloom_mask) {
                bloom[(bit / 64) as usize] |= 1 << (bit % 64);
            }
            w.write_all(&h.to_le_bytes())
                .and_then(|_| w.write_all(&(bytes.len() as u32).to_le_bytes()))
                .and_then(|_| w.write_all(bytes))
                .unwrap_or_else(|e| panic!("spill store: write to {:?} failed: {e}", path));
            off += 12 + bytes.len() as u64;
        }
        let mut file = w
            .into_inner()
            .unwrap_or_else(|e| panic!("spill store: flush of {:?} failed: {e}", path));
        file.flush()
            .unwrap_or_else(|e| panic!("spill store: flush of {:?} failed: {e}", path));
        drop(entries);
        self.runs.push(SpillRun { path, file, bloom, bloom_mask, index });
        self.spilled += n as u64;
        self.ram = FullStore::new();
    }

    pub(crate) fn len(&self) -> u64 {
        self.ram.len() + self.spilled
    }

    pub(crate) fn runs(&self) -> usize {
        self.runs.len()
    }

    /// RAM-resident bytes only: live table + per-run blooms and indexes.
    pub(crate) fn bytes_used(&self) -> u64 {
        self.ram.bytes_used()
            + self
                .runs
                .iter()
                .map(|r| (r.bloom.len() * 8 + r.index.len() * 16) as u64)
                .sum::<u64>()
    }
}

pub enum VisitedStore {
    Full(FullStore),
    Collapse(CollapseStore),
    /// `--store compact --compress collapse`: region-aware hash
    /// compaction. Regions are interned exactly (like [`CollapseStore`]),
    /// but only the hash of the interned index *tuple* is kept per state
    /// — the per-state footprint of hash-compact with the collision
    /// behavior keyed on the component split rather than the raw bytes.
    /// Shared components are stored exactly once, so two states that
    /// differ in one region can never collide through the shared part.
    CompactCollapse { components: FullStore, set: FxHashSet<u64>, tuple_buf: Vec<u8> },
    Spill(SpillStore),
    HashCompact { set: FxHashSet<u64> },
    Bitstate { table: Vec<u64>, mask: u64, hashes: u8, set_bits: u64 },
}

/// Cap on pre-sized entry counts: a wild over-estimate must not allocate
/// unbounded memory up front (1 << 26 entries ≈ 64 M states).
const PRESIZE_CAP: u64 = 1 << 26;

impl VisitedStore {
    /// [`new`](Self::new) pre-sized for an `expected` state count
    /// (0 = unknown: identical to `new`). Bitstate tables are fixed-size
    /// by construction and ignore the hint.
    pub fn with_capacity(kind: StoreKind, expected: u64) -> Self {
        let expected = expected.min(PRESIZE_CAP) as usize;
        if expected == 0 {
            return Self::new(kind);
        }
        match kind {
            StoreKind::Full => Self::Full(FullStore::with_capacity(expected)),
            StoreKind::HashCompact => Self::HashCompact {
                set: FxHashSet::with_capacity_and_hasher(expected, Default::default()),
            },
            StoreKind::Bitstate { .. } | StoreKind::Spill => Self::new(kind),
        }
    }

    /// A compressing exact store — see [`CollapseStore`]. Callers must
    /// feed it through [`insert_regions`](Self::insert_regions) with the
    /// model's region split.
    pub fn collapsed(expected: u64) -> Self {
        let expected = expected.min(PRESIZE_CAP) as usize;
        Self::Collapse(if expected == 0 {
            CollapseStore::new()
        } else {
            CollapseStore::with_capacity(expected)
        })
    }

    /// A region-aware hash-compact store — see
    /// [`CompactCollapse`](Self::CompactCollapse). Fed through
    /// [`insert_regions`](Self::insert_regions) like the collapse store.
    pub fn compact_collapsed(expected: u64) -> Self {
        let expected = expected.min(PRESIZE_CAP) as usize;
        Self::CompactCollapse {
            components: FullStore::new(),
            set: if expected == 0 {
                FxHashSet::default()
            } else {
                FxHashSet::with_capacity_and_hasher(expected, Default::default())
            },
            tuple_buf: Vec::new(),
        }
    }

    /// A disk-spillable exact store — see [`SpillStore`]. `watermark` is
    /// the RAM ceiling that triggers a freeze (typically half the run's
    /// memory budget, leaving room for the search stack).
    pub fn spill(dir: &Path, watermark: u64) -> Self {
        Self::Spill(SpillStore::new(dir, watermark))
    }

    pub fn new(kind: StoreKind) -> Self {
        match kind {
            StoreKind::Full => Self::Full(FullStore::new()),
            StoreKind::Spill => {
                // bare construction (no CheckOptions in sight): spill to
                // the system temp dir past half the default 16 GB budget
                Self::Spill(SpillStore::new(&std::env::temp_dir(), 8 << 30))
            }
            StoreKind::HashCompact => Self::HashCompact { set: FxHashSet::default() },
            StoreKind::Bitstate { log2_bits, hashes } => {
                let log2 = log2_bits.clamp(10, 40);
                let words = (1usize << log2) / 64;
                Self::Bitstate {
                    table: vec![0u64; words],
                    mask: (1u64 << log2) - 1,
                    hashes: hashes.max(1),
                    set_bits: 0,
                }
            }
        }
    }

    /// Insert an encoded state; returns true when it was not seen before.
    /// (Bitstate may return false for genuinely new states — the expected
    /// Bloom false-positive, which makes the search partial, as in SPIN.)
    pub fn insert(&mut self, enc: &[u8]) -> bool {
        match self {
            Self::Full(f) => f.insert_hashed(enc, hash_bytes(enc)),
            Self::Collapse(c) => c.insert_hashed(enc, hash_bytes(enc), &[]),
            Self::CompactCollapse { .. } => self.insert_compact_collapsed(enc, &[]),
            Self::Spill(s) => s.insert_hashed(enc, hash_bytes(enc)),
            Self::HashCompact { set } => set.insert(hash_bytes(enc)),
            Self::Bitstate { .. } => self.insert_bitstate(enc),
        }
    }

    /// [`insert`](Self::insert) with a caller-precomputed `hash_bytes(enc)`
    /// — the parallel engine hashes once for shard routing and passes the
    /// value through. Bitstate ignores the hint (its k Bloom probes use
    /// independent seeds).
    pub fn insert_hashed(&mut self, enc: &[u8], h: u64) -> bool {
        match self {
            Self::Full(f) => f.insert_hashed(enc, h),
            Self::Collapse(c) => c.insert_hashed(enc, h, &[]),
            Self::CompactCollapse { .. } => self.insert_compact_collapsed(enc, &[]),
            Self::Spill(s) => s.insert_hashed(enc, h),
            Self::HashCompact { set } => set.insert(h),
            Self::Bitstate { .. } => self.insert_bitstate(enc),
        }
    }

    /// [`insert_hashed`](Self::insert_hashed) with a region split for the
    /// collapse store (every other store ignores `bounds`). A collapse
    /// store must see *all* of its inserts through one split function —
    /// the engines compute `bounds` via the model's `encode_regions` for
    /// every insert, including initial states.
    pub fn insert_regions(&mut self, enc: &[u8], h: u64, bounds: &[u32]) -> bool {
        match self {
            Self::Collapse(c) => c.insert_hashed(enc, h, bounds),
            Self::CompactCollapse { .. } => self.insert_compact_collapsed(enc, bounds),
            _ => self.insert_hashed(enc, h),
        }
    }

    /// Region-aware hash-compact insert: intern each region exactly, then
    /// record only the hash of the LE index tuple. Same split contract as
    /// [`CollapseStore::insert_hashed`]; the raw encoding's hash is not
    /// used — collisions are keyed on the component tuple.
    fn insert_compact_collapsed(&mut self, enc: &[u8], bounds: &[u32]) -> bool {
        let Self::CompactCollapse { components, set, tuple_buf } = self else {
            unreachable!("insert_compact_collapsed on non-compact-collapse store");
        };
        let mut tuple = std::mem::take(tuple_buf);
        tuple.clear();
        let mut start = 0usize;
        for &b in bounds {
            let end = (b as usize).min(enc.len());
            let region = &enc[start..end];
            let (idx, _) = components.intern_hashed(region, hash_bytes(region));
            tuple.extend_from_slice(&idx.to_le_bytes());
            start = end;
        }
        if start < enc.len() || bounds.is_empty() {
            let region = &enc[start..];
            let (idx, _) = components.intern_hashed(region, hash_bytes(region));
            tuple.extend_from_slice(&idx.to_le_bytes());
        }
        let new = set.insert(hash_bytes(&tuple));
        *tuple_buf = tuple;
        new
    }

    fn insert_bitstate(&mut self, enc: &[u8]) -> bool {
        let Self::Bitstate { table, mask, hashes, set_bits } = self else {
            unreachable!("insert_bitstate on non-bitstate store");
        };
        let mut new = false;
        for k in 0..*hashes {
            let bit = hash_bytes_seeded(enc, 0x9E37 + k as u64) & *mask;
            let (w, b) = ((bit / 64) as usize, bit % 64);
            if table[w] & (1 << b) == 0 {
                table[w] |= 1 << b;
                *set_bits += 1;
                new = true;
            }
        }
        new
    }

    /// Number of distinct states recorded (bitstate: lower-bound estimate
    /// from bit occupancy).
    pub fn len(&self) -> u64 {
        match self {
            Self::Full(f) => f.len(),
            Self::Collapse(c) => c.len(),
            Self::CompactCollapse { set, .. } => set.len() as u64,
            Self::Spill(s) => s.len(),
            Self::HashCompact { set } => set.len() as u64,
            Self::Bitstate { set_bits, hashes, .. } => set_bits / (*hashes).max(1) as u64,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn bytes_used(&self) -> u64 {
        match self {
            Self::Full(f) => f.bytes_used(),
            Self::Collapse(c) => c.bytes_used(),
            Self::CompactCollapse { components, set, tuple_buf } => {
                components.bytes_used() + set.len() as u64 * 16 + tuple_buf.capacity() as u64
            }
            Self::Spill(s) => s.bytes_used(),
            Self::HashCompact { set } => set.len() as u64 * 16,
            Self::Bitstate { table, .. } => table.len() as u64 * 8,
        }
    }

    /// Bloom saturation in [0,1] — swarm workers report this; near 1.0 the
    /// search degenerates (everything looks visited).
    pub fn saturation(&self) -> f64 {
        match self {
            Self::Bitstate { table, set_bits, .. } => {
                *set_bits as f64 / (table.len() as f64 * 64.0)
            }
            _ => 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn states(n: u64) -> Vec<Vec<u8>> {
        (0..n).map(|i| i.to_le_bytes().to_vec()).collect()
    }

    #[test]
    fn full_store_exact() {
        let mut s = VisitedStore::new(StoreKind::Full);
        for st in states(1000) {
            assert!(s.insert(&st));
        }
        for st in states(1000) {
            assert!(!s.insert(&st));
        }
        assert_eq!(s.len(), 1000);
        assert!(s.bytes_used() > 1000 * 8);
    }

    #[test]
    fn full_store_survives_table_growth() {
        // cross several grow() boundaries, with variable-length encodings
        let mut s = VisitedStore::new(StoreKind::Full);
        let mut items: Vec<Vec<u8>> = Vec::new();
        for i in 0u64..20_000 {
            let mut v = i.to_le_bytes().to_vec();
            v.truncate(1 + (i % 8) as usize);
            v.push((i / 251) as u8); // disambiguate truncated prefixes
            items.push(v);
        }
        items.sort();
        items.dedup();
        for it in &items {
            assert!(s.insert(it), "fresh item reported as seen");
        }
        for it in &items {
            assert!(!s.insert(it), "seen item reported as fresh after growth");
        }
        assert_eq!(s.len(), items.len() as u64);
    }

    #[test]
    fn full_store_insert_hashed_consistent_with_insert() {
        let mut a = VisitedStore::new(StoreKind::Full);
        let mut b = VisitedStore::new(StoreKind::Full);
        for st in states(500) {
            let h = hash_bytes(&st);
            assert_eq!(a.insert(&st), b.insert_hashed(&st, h));
        }
        assert_eq!(a.len(), b.len());
    }

    #[test]
    fn hash_compact_mostly_exact() {
        let mut s = VisitedStore::new(StoreKind::HashCompact);
        let mut new = 0;
        for st in states(100_000) {
            if s.insert(&st) {
                new += 1;
            }
        }
        // collisions possible but vanishingly rare at 1e5/2^64
        assert_eq!(new, 100_000);
        assert!(!s.insert(&states(1)[0]));
        assert_eq!(s.bytes_used(), 100_000 * 16);
    }

    #[test]
    fn bitstate_no_false_negatives() {
        // Bloom filters never report "seen" as "new" once inserted.
        let mut s = VisitedStore::new(StoreKind::Bitstate { log2_bits: 20, hashes: 3 });
        for st in states(10_000) {
            s.insert(&st);
        }
        for st in states(10_000) {
            assert!(!s.insert(&st), "false negative in bitstate store");
        }
        assert!(s.saturation() > 0.0 && s.saturation() < 0.1);
    }

    #[test]
    fn bitstate_fixed_memory() {
        let s = VisitedStore::new(StoreKind::Bitstate { log2_bits: 24, hashes: 3 });
        assert_eq!(s.bytes_used(), (1 << 24) / 8);
    }

    #[test]
    fn bitstate_saturates_small_table() {
        let mut s = VisitedStore::new(StoreKind::Bitstate { log2_bits: 10, hashes: 3 });
        let mut missed = 0u64;
        for st in states(5000) {
            if !s.insert(&st) {
                missed += 1; // false positive: state wrongly "seen"
            }
        }
        assert!(missed > 0, "tiny table must produce false positives");
        assert!(s.saturation() > 0.5);
    }

    #[test]
    fn presized_store_agrees_with_default() {
        for kind in [StoreKind::Full, StoreKind::HashCompact] {
            let mut a = VisitedStore::new(kind);
            let mut b = VisitedStore::with_capacity(kind, 2000);
            for st in states(2000) {
                assert_eq!(a.insert(&st), b.insert(&st));
            }
            for st in states(2000) {
                assert!(!b.insert(&st));
            }
            assert_eq!(a.len(), b.len());
        }
        // 0 = unknown, and bitstate ignores the hint
        assert_eq!(VisitedStore::with_capacity(StoreKind::Full, 0).len(), 0);
        let s = VisitedStore::with_capacity(StoreKind::Bitstate { log2_bits: 20, hashes: 3 }, 999);
        assert_eq!(s.bytes_used(), (1 << 20) / 8);
    }

    #[test]
    fn kind_names() {
        assert_eq!(StoreKind::Full.name(), "full");
        assert_eq!(StoreKind::Bitstate { log2_bits: 20, hashes: 3 }.name(), "bitstate");
        assert_eq!(StoreKind::Spill.name(), "spill");
        assert_eq!(Compression::None.name(), "none");
        assert_eq!(Compression::Collapse.name(), "collapse");
    }

    /// Synthetic "state": three 32-byte regions, each drawn from a small
    /// component pool — the shape COLLAPSE exploits.
    fn region_states(n: u64) -> Vec<(Vec<u8>, Vec<u32>)> {
        (0..n)
            .map(|i| {
                let mut enc = Vec::with_capacity(96);
                for (r, modulo) in [(0u64, 7u64), (1, 11), (2, 13)] {
                    let tag = (i * (r + 3)) % modulo;
                    enc.extend_from_slice(&[tag as u8; 24]);
                    enc.extend_from_slice(&tag.to_le_bytes());
                }
                (enc, vec![32, 64])
            })
            .collect()
    }

    #[test]
    fn collapse_agrees_with_full() {
        let mut full = VisitedStore::new(StoreKind::Full);
        let mut col = VisitedStore::collapsed(0);
        for (enc, bounds) in region_states(4000) {
            let h = hash_bytes(&enc);
            assert_eq!(full.insert_hashed(&enc, h), col.insert_regions(&enc, h, &bounds));
        }
        for (enc, bounds) in region_states(4000) {
            assert!(!col.insert_regions(&enc, hash_bytes(&enc), &bounds));
        }
        assert_eq!(full.len(), col.len());
    }

    #[test]
    fn compact_collapse_agrees_with_full_and_shrinks() {
        // region-aware hash-compact: same dedup decisions as the exact
        // stores on a collision-free corpus, smaller footprint than full
        let mut full = VisitedStore::new(StoreKind::Full);
        let mut cc = VisitedStore::compact_collapsed(0);
        for (enc, bounds) in region_states(4000) {
            let h = hash_bytes(&enc);
            assert_eq!(full.insert_hashed(&enc, h), cc.insert_regions(&enc, h, &bounds));
        }
        for (enc, bounds) in region_states(4000) {
            assert!(!cc.insert_regions(&enc, hash_bytes(&enc), &bounds));
        }
        assert_eq!(full.len(), cc.len());
        assert!(
            cc.bytes_used() < full.bytes_used(),
            "compact+collapse must shrink the store: {} vs {}",
            cc.bytes_used(),
            full.bytes_used()
        );
        // boundary shapes follow the collapse contract
        let mut cc = VisitedStore::compact_collapsed(16);
        assert!(cc.insert_regions(b"abcdef", 1, &[2, 6]));
        assert!(!cc.insert_regions(b"abcdef", 1, &[2, 6]));
        assert!(cc.insert(b""));
        assert!(!cc.insert(b""));
        assert_eq!(cc.len(), 2);
    }

    #[test]
    fn collapse_handles_boundary_shapes() {
        // trailing bound == len, empty bounds, and out-of-range bounds all
        // stay exact
        let mut col = VisitedStore::collapsed(16);
        assert!(col.insert_regions(b"abcdef", 1, &[2, 6]));
        assert!(!col.insert_regions(b"abcdef", 1, &[2, 6]));
        assert!(col.insert_regions(b"", 2, &[]));
        assert!(!col.insert_regions(b"", 2, &[]));
        assert!(col.insert_regions(b"xy", 3, &[9]));
        assert!(!col.insert_regions(b"xy", 3, &[9]));
        assert_eq!(col.len(), 3);
    }

    #[test]
    fn collapse_shrinks_shared_region_states() {
        // same dedup decisions, strictly smaller footprint once regions
        // repeat across states
        let mut full = VisitedStore::new(StoreKind::Full);
        let mut col = VisitedStore::collapsed(0);
        for (enc, bounds) in region_states(20_000) {
            let h = hash_bytes(&enc);
            full.insert_hashed(&enc, h);
            col.insert_regions(&enc, h, &bounds);
        }
        assert_eq!(full.len(), col.len());
        assert!(
            col.bytes_used() < full.bytes_used(),
            "collapse must shrink the store: {} vs {}",
            col.bytes_used(),
            full.bytes_used()
        );
    }

    #[test]
    fn spill_store_exact_across_freezes() {
        let dir = std::env::temp_dir().join(format!("mcat-spill-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        {
            // tiny watermark (clamped to 64 KiB) forces several freezes
            let mut s = VisitedStore::spill(&dir, 1);
            let items = states(40_000);
            for st in &items {
                assert!(s.insert(st), "fresh state reported as seen");
            }
            let runs = match &s {
                VisitedStore::Spill(sp) => sp.runs(),
                _ => unreachable!(),
            };
            assert!(runs >= 2, "watermark never tripped: {runs} runs");
            for st in &items {
                assert!(!s.insert(st), "spilled state reported as fresh");
            }
            assert_eq!(s.len(), items.len() as u64);
            // RAM footprint stays near the watermark, not the corpus size
            assert!(s.bytes_used() < 4 * (1 << 16) + (1 << 20));
            // fresh states are still accepted after spilling
            assert!(s.insert(&u64::MAX.to_le_bytes()));
        }
        // runs delete themselves with the store
        let left = std::fs::read_dir(&dir).unwrap().count();
        assert_eq!(left, 0, "spill run files leaked");
        let _ = std::fs::remove_dir(&dir);
    }

    #[test]
    fn spill_store_equivalent_to_full() {
        let dir = std::env::temp_dir().join(format!("mcat-spill-eq-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        {
            let mut full = VisitedStore::new(StoreKind::Full);
            let mut sp = VisitedStore::spill(&dir, 1);
            // interleave fresh and repeated states; decisions must match
            for round in 0..3u64 {
                for i in 0..30_000u64 {
                    let st = (i % (10_000 * (round + 1))).to_le_bytes();
                    assert_eq!(full.insert(&st), sp.insert(&st), "round {round} state {i}");
                }
            }
            assert_eq!(full.len(), sp.len());
        }
        let _ = std::fs::remove_dir(&dir);
    }
}
