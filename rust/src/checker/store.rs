//! Visited-state stores — the checker's memory subsystem.
//!
//! Three regimes mirror SPIN's:
//! - `Full`: exact (stores the encoded state vector) — SPIN's default;
//! - `HashCompact`: 64-bit hash compaction (SPIN `-DHC`) — exact up to
//!   hash collisions, 8 bytes/state;
//! - `Bitstate`: Bloom-filter bitstate hashing (SPIN `-DBITSTATE`, the
//!   basis of swarm verification) — k probes into a 2^log2_bits bit table.
//!
//! `insert` returns whether the state was new. `bytes_used` feeds the
//! memory budget that reproduces the paper's 16 GB exhaustive-mode ceiling
//! (Table 1).

use crate::util::hash::{hash_bytes_seeded, FxHashSet};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StoreKind {
    Full,
    HashCompact,
    Bitstate { log2_bits: u8, hashes: u8 },
}

impl StoreKind {
    pub fn name(&self) -> &'static str {
        match self {
            StoreKind::Full => "full",
            StoreKind::HashCompact => "hash-compact",
            StoreKind::Bitstate { .. } => "bitstate",
        }
    }
}

pub enum VisitedStore {
    Full { set: FxHashSet<Box<[u8]>>, bytes: u64 },
    HashCompact { set: FxHashSet<u64> },
    Bitstate { table: Vec<u64>, mask: u64, hashes: u8, set_bits: u64 },
}

impl VisitedStore {
    pub fn new(kind: StoreKind) -> Self {
        match kind {
            StoreKind::Full => Self::Full { set: FxHashSet::default(), bytes: 0 },
            StoreKind::HashCompact => Self::HashCompact { set: FxHashSet::default() },
            StoreKind::Bitstate { log2_bits, hashes } => {
                let log2 = log2_bits.clamp(10, 40);
                let words = (1usize << log2) / 64;
                Self::Bitstate {
                    table: vec![0u64; words],
                    mask: (1u64 << log2) - 1,
                    hashes: hashes.max(1),
                    set_bits: 0,
                }
            }
        }
    }

    /// Insert an encoded state; returns true when it was not seen before.
    /// (Bitstate may return false for genuinely new states — the expected
    /// Bloom false-positive, which makes the search partial, as in SPIN.)
    pub fn insert(&mut self, enc: &[u8]) -> bool {
        match self {
            Self::Full { set, bytes } => {
                if set.contains(enc) {
                    false
                } else {
                    *bytes += enc.len() as u64 + 48; // box + set overhead est.
                    set.insert(enc.to_vec().into_boxed_slice());
                    true
                }
            }
            Self::HashCompact { set } => set.insert(hash_bytes_seeded(enc, 0)),
            Self::Bitstate { table, mask, hashes, set_bits } => {
                let mut new = false;
                for k in 0..*hashes {
                    let bit = hash_bytes_seeded(enc, 0x9E37 + k as u64) & *mask;
                    let (w, b) = ((bit / 64) as usize, bit % 64);
                    if table[w] & (1 << b) == 0 {
                        table[w] |= 1 << b;
                        *set_bits += 1;
                        new = true;
                    }
                }
                new
            }
        }
    }

    /// Number of distinct states recorded (bitstate: lower-bound estimate
    /// from bit occupancy).
    pub fn len(&self) -> u64 {
        match self {
            Self::Full { set, .. } => set.len() as u64,
            Self::HashCompact { set } => set.len() as u64,
            Self::Bitstate { set_bits, hashes, .. } => set_bits / (*hashes).max(1) as u64,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn bytes_used(&self) -> u64 {
        match self {
            Self::Full { bytes, .. } => *bytes,
            Self::HashCompact { set } => set.len() as u64 * 16,
            Self::Bitstate { table, .. } => table.len() as u64 * 8,
        }
    }

    /// Bloom saturation in [0,1] — swarm workers report this; near 1.0 the
    /// search degenerates (everything looks visited).
    pub fn saturation(&self) -> f64 {
        match self {
            Self::Bitstate { table, set_bits, .. } => {
                *set_bits as f64 / (table.len() as f64 * 64.0)
            }
            _ => 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn states(n: u64) -> Vec<Vec<u8>> {
        (0..n).map(|i| i.to_le_bytes().to_vec()).collect()
    }

    #[test]
    fn full_store_exact() {
        let mut s = VisitedStore::new(StoreKind::Full);
        for st in states(1000) {
            assert!(s.insert(&st));
        }
        for st in states(1000) {
            assert!(!s.insert(&st));
        }
        assert_eq!(s.len(), 1000);
        assert!(s.bytes_used() > 1000 * 8);
    }

    #[test]
    fn hash_compact_mostly_exact() {
        let mut s = VisitedStore::new(StoreKind::HashCompact);
        let mut new = 0;
        for st in states(100_000) {
            if s.insert(&st) {
                new += 1;
            }
        }
        // collisions possible but vanishingly rare at 1e5/2^64
        assert_eq!(new, 100_000);
        assert!(!s.insert(&states(1)[0]));
        assert_eq!(s.bytes_used(), 100_000 * 16);
    }

    #[test]
    fn bitstate_no_false_negatives() {
        // Bloom filters never report "seen" as "new" once inserted.
        let mut s = VisitedStore::new(StoreKind::Bitstate { log2_bits: 20, hashes: 3 });
        for st in states(10_000) {
            s.insert(&st);
        }
        for st in states(10_000) {
            assert!(!s.insert(&st), "false negative in bitstate store");
        }
        assert!(s.saturation() > 0.0 && s.saturation() < 0.1);
    }

    #[test]
    fn bitstate_fixed_memory() {
        let s = VisitedStore::new(StoreKind::Bitstate { log2_bits: 24, hashes: 3 });
        assert_eq!(s.bytes_used(), (1 << 24) / 8);
    }

    #[test]
    fn bitstate_saturates_small_table() {
        let mut s = VisitedStore::new(StoreKind::Bitstate { log2_bits: 10, hashes: 3 });
        let mut missed = 0u64;
        for st in states(5000) {
            if !s.insert(&st) {
                missed += 1; // false positive: state wrongly "seen"
            }
        }
        assert!(missed > 0, "tiny table must produce false positives");
        assert!(s.saturation() > 0.5);
    }

    #[test]
    fn kind_names() {
        assert_eq!(StoreKind::Full.name(), "full");
        assert_eq!(StoreKind::Bitstate { log2_bits: 20, hashes: 3 }.name(), "bitstate");
    }
}
