//! Native transition system for the paper's *abstract* OpenCL platform
//! model (paper §4, Listings 3–9).
//!
//! Semantics. `main` nondeterministically picks (WG, TS); the process
//! network then executes work items in lockstep rounds (the Promela model's
//! clock only advances when *all* active pexes have reported, so equal-cost
//! phases keep every pex synchronous — see DESIGN.md §3.1). Per work item
//! (Listing 8): `size/TS` iterations of [global load `GMT*TS` ticks →
//! barrier → local compute `TS` ticks → barrier], then a `GMT`-tick global
//! write. Rounds = ceil(total work items / simultaneously active pexes),
//! reproducing host/device/unit re-activation (Listings 4–6).
//!
//! The only nondeterminism is the tuning choice: given (WG, TS) the model
//! time is schedule-independent (all interleavings commute on `time`), so
//! the native model explores a canonical schedule; the Promela front end
//! retains full interleaving and is cross-checked against this model in
//! `rust/tests/promela_vs_native.rs`.

use super::config::{enumerate_tunings, geometry, Geometry, PlatformConfig, Tuning};
use crate::model::TransitionSystem;
use crate::util::error::Result;

/// Transition granularity. `Tick` is clock-cycle faithful (one transition
/// per model-time unit, like the Promela model); `Phase` jumps a whole
/// long_work phase per transition — identical reachable terminal states,
/// ~GMT·TS× fewer intermediate states (the checker's optimized hot path).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Granularity {
    Tick,
    Phase,
}

const CFG_NONE: u8 = u8::MAX;

/// Execution phases of one work item (Listing 8).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    GlobalLoad = 0,
    LocalCompute = 1,
    WriteBack = 2,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct AbsState {
    /// index into the tuning table; CFG_NONE before `main` chooses
    cfg: u8,
    round: u16,
    /// tile index within the current work item ("i" in Listing 8 line 15)
    tile: u16,
    phase: u8,
    /// ticks remaining in the current phase
    ticks_left: u32,
    pub time: u64,
    pub fin: bool,
}

pub struct AbstractModel {
    pub size: u32,
    pub plat: PlatformConfig,
    pub granularity: Granularity,
    tunings: Vec<Tuning>,
    geoms: Vec<Geometry>,
}

impl AbstractModel {
    pub fn new(size: u32, plat: PlatformConfig, granularity: Granularity) -> Result<Self> {
        plat.validate()?;
        let tunings = enumerate_tunings(size)?;
        crate::ensure!(
            tunings.len() < CFG_NONE as usize,
            "tuning space too large for u8 index"
        );
        let geoms = tunings.iter().map(|&t| geometry(size, t, &plat)).collect();
        Ok(Self { size, plat, granularity, tunings, geoms })
    }

    pub fn tunings(&self) -> &[Tuning] {
        &self.tunings
    }

    fn tuning(&self, s: &AbsState) -> Option<Tuning> {
        (s.cfg != CFG_NONE).then(|| self.tunings[s.cfg as usize])
    }

    fn n_tiles(&self, t: Tuning) -> u32 {
        self.size / t.ts
    }

    fn phase_ticks(&self, t: Tuning, phase: Phase) -> u32 {
        match phase {
            Phase::GlobalLoad => self.plat.gmt * t.ts,
            Phase::LocalCompute => t.ts,
            Phase::WriteBack => self.plat.gmt,
        }
    }

    /// Closed-form terminal model time for a tuning choice; the transition
    /// system must land exactly here (asserted by tests).
    pub fn predicted_time(&self, t: Tuning) -> u64 {
        let g = geometry(self.size, t, &self.plat);
        let per_item = self.n_tiles(t) as u64
            * (self.phase_ticks(t, Phase::GlobalLoad) as u64
                + self.phase_ticks(t, Phase::LocalCompute) as u64)
            + self.plat.gmt as u64;
        g.rounds as u64 * per_item
    }

    /// Minimal terminal time over the whole tuning space with an argmin
    /// witness — the ground truth the checker/tuner must find.
    pub fn optimum(&self) -> (u64, Tuning) {
        self.tunings
            .iter()
            .map(|&t| (self.predicted_time(t), t))
            .min_by_key(|&(time, t)| (time, t.wg, t.ts))
            .expect("non-empty tuning space")
    }

    /// Advance to the state after the current phase completes; returns the
    /// follow-on state (with `ticks_left` loaded for the next phase).
    fn next_phase(&self, s: &AbsState) -> AbsState {
        let t = self.tunings[s.cfg as usize];
        let g = self.geoms[s.cfg as usize];
        let mut n = *s;
        match s.phase {
            p if p == Phase::GlobalLoad as u8 => {
                n.phase = Phase::LocalCompute as u8;
                n.ticks_left = self.phase_ticks(t, Phase::LocalCompute);
            }
            p if p == Phase::LocalCompute as u8 => {
                if (s.tile as u32) + 1 < self.n_tiles(t) {
                    n.tile += 1;
                    n.phase = Phase::GlobalLoad as u8;
                    n.ticks_left = self.phase_ticks(t, Phase::GlobalLoad);
                } else {
                    n.phase = Phase::WriteBack as u8;
                    n.ticks_left = self.phase_ticks(t, Phase::WriteBack);
                }
            }
            _ => {
                // WriteBack done: next round or finish
                if (s.round as u32) + 1 < g.rounds {
                    n.round += 1;
                    n.tile = 0;
                    n.phase = Phase::GlobalLoad as u8;
                    n.ticks_left = self.phase_ticks(t, Phase::GlobalLoad);
                } else {
                    n.fin = true;
                    n.ticks_left = 0;
                }
            }
        }
        n
    }
}

impl TransitionSystem for AbstractModel {
    type State = AbsState;

    fn initial_states(&self) -> Vec<AbsState> {
        vec![AbsState {
            cfg: CFG_NONE,
            round: 0,
            tile: 0,
            phase: Phase::GlobalLoad as u8,
            ticks_left: 0,
            time: 0,
            fin: false,
        }]
    }

    fn successors(&self, s: &AbsState, out: &mut Vec<AbsState>) {
        out.clear();
        if s.fin {
            return; // terminal
        }
        if s.cfg == CFG_NONE {
            // main's nondeterministic select of WG and TS (Listing 3)
            for (i, t) in self.tunings.iter().enumerate() {
                let mut n = *s;
                n.cfg = i as u8;
                n.ticks_left = self.phase_ticks(*t, Phase::GlobalLoad);
                out.push(n);
            }
            return;
        }
        match self.granularity {
            Granularity::Tick => {
                let mut n = *s;
                if s.ticks_left > 1 {
                    n.ticks_left -= 1;
                    n.time += 1;
                    out.push(n);
                } else {
                    // final tick of the phase: consume it and roll over
                    let mut nn = self.next_phase(s);
                    nn.time = s.time + 1;
                    out.push(nn);
                }
            }
            Granularity::Phase => {
                let mut nn = self.next_phase(s);
                nn.time = s.time + s.ticks_left as u64;
                out.push(nn);
            }
        }
    }

    fn encode(&self, s: &AbsState, out: &mut Vec<u8>) {
        out.clear();
        out.push(s.cfg);
        out.extend_from_slice(&s.round.to_le_bytes());
        out.extend_from_slice(&s.tile.to_le_bytes());
        out.push(s.phase);
        out.extend_from_slice(&s.ticks_left.to_le_bytes());
        out.extend_from_slice(&s.time.to_le_bytes());
        out.push(s.fin as u8);
    }

    fn eval_var(&self, s: &AbsState, name: &str) -> Option<i64> {
        match name {
            "time" => Some(s.time as i64),
            "FIN" => Some(s.fin as i64),
            "size" => Some(self.size as i64),
            "WG" => self.tuning(s).map(|t| t.wg as i64),
            "TS" => self.tuning(s).map(|t| t.ts as i64),
            "WGs" => self.tuning(s).map(|t| geometry(self.size, t, &self.plat).wgs as i64),
            "NWD" => self.tuning(s).map(|t| geometry(self.size, t, &self.plat).nwd as i64),
            "NWU" => self.tuning(s).map(|t| geometry(self.size, t, &self.plat).nwu as i64),
            "NWE" => self.tuning(s).map(|t| geometry(self.size, t, &self.plat).nwe as i64),
            "rounds" => self.tuning(s).map(|t| geometry(self.size, t, &self.plat).rounds as i64),
            _ => None,
        }
    }

    fn resolve_slot(&self, name: &str) -> Option<u32> {
        // ids match the eval_var arm order; eval_slots dispatches on the
        // integer so the checker's hot loop never touches the names
        ["time", "FIN", "size", "WG", "TS", "WGs", "NWD", "NWU", "NWE", "rounds"]
            .iter()
            .position(|n| *n == name)
            .map(|i| i as u32)
    }

    fn eval_slots(&self, s: &AbsState, ids: &[u32], out: &mut [i64]) -> u64 {
        let mut missing = 0u64;
        // tuning + precomputed geometry (no per-state geometry math)
        let chosen = (s.cfg != CFG_NONE)
            .then(|| (self.tunings[s.cfg as usize], self.geoms[s.cfg as usize]));
        for (i, &id) in ids.iter().enumerate() {
            let v = match id {
                0 => Some(s.time as i64),
                1 => Some(s.fin as i64),
                2 => Some(self.size as i64),
                3 => chosen.map(|(t, _)| t.wg as i64),
                4 => chosen.map(|(t, _)| t.ts as i64),
                5 => chosen.map(|(_, g)| g.wgs as i64),
                6 => chosen.map(|(_, g)| g.nwd as i64),
                7 => chosen.map(|(_, g)| g.nwu as i64),
                8 => chosen.map(|(_, g)| g.nwe as i64),
                9 => chosen.map(|(_, g)| g.rounds as i64),
                _ => None,
            };
            match v {
                Some(v) => out[i] = v,
                None => missing |= 1u64 << i,
            }
        }
        missing
    }

    fn describe(&self, s: &AbsState) -> String {
        match self.tuning(s) {
            None => "main: selecting WG, TS".to_string(),
            Some(t) => format!(
                "WG={} TS={} round={} tile={} phase={} time={}{}",
                t.wg,
                t.ts,
                s.round,
                s.tile,
                ["global", "local", "write"][(s.phase as usize).min(2)],
                s.time,
                if s.fin { " FIN" } else { "" }
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_to_fin(m: &AbstractModel, cfg_idx: usize) -> (u64, usize) {
        let init = &m.initial_states()[0];
        let mut buf = Vec::new();
        m.successors(init, &mut buf);
        let mut s = buf[cfg_idx];
        let mut steps = 1usize;
        loop {
            let mut next = Vec::new();
            m.successors(&s, &mut next);
            if next.is_empty() {
                return (s.time, steps);
            }
            assert_eq!(next.len(), 1, "post-choice evolution is deterministic");
            s = next[0];
            steps += 1;
        }
    }

    #[test]
    fn initial_branches_once_per_tuning() {
        let m = AbstractModel::new(16, PlatformConfig::default(), Granularity::Phase).unwrap();
        let mut buf = Vec::new();
        m.successors(&m.initial_states()[0], &mut buf);
        assert_eq!(buf.len(), m.tunings().len());
    }

    #[test]
    fn terminal_time_matches_formula_phase() {
        let m = AbstractModel::new(32, PlatformConfig::default(), Granularity::Phase).unwrap();
        for (i, &t) in m.tunings().iter().enumerate() {
            let (time, _) = run_to_fin(&m, i);
            assert_eq!(time, m.predicted_time(t), "tuning {:?}", t);
        }
    }

    #[test]
    fn terminal_time_matches_formula_tick() {
        let m = AbstractModel::new(16, PlatformConfig::default(), Granularity::Tick).unwrap();
        for (i, &t) in m.tunings().iter().enumerate() {
            let (time, steps) = run_to_fin(&m, i);
            assert_eq!(time, m.predicted_time(t), "tuning {:?}", t);
            // tick granularity: one transition per time unit (+1 for choice)
            assert_eq!(steps as u64, time + 1);
        }
    }

    #[test]
    fn granularities_agree_on_terminal_time() {
        let mp = AbstractModel::new(16, PlatformConfig::default(), Granularity::Phase).unwrap();
        let mt = AbstractModel::new(16, PlatformConfig::default(), Granularity::Tick).unwrap();
        for i in 0..mp.tunings().len() {
            assert_eq!(run_to_fin(&mp, i).0, run_to_fin(&mt, i).0);
        }
    }

    #[test]
    fn optimum_is_min_over_space() {
        let m = AbstractModel::new(64, PlatformConfig::default(), Granularity::Phase).unwrap();
        let (best, t) = m.optimum();
        for &u in m.tunings() {
            assert!(m.predicted_time(u) >= best);
        }
        assert!(m.tunings().contains(&t));
    }

    #[test]
    fn eval_vars_exposed() {
        let m = AbstractModel::new(16, PlatformConfig::default(), Granularity::Phase).unwrap();
        let init = m.initial_states()[0];
        assert_eq!(m.eval_var(&init, "FIN"), Some(0));
        assert_eq!(m.eval_var(&init, "time"), Some(0));
        assert_eq!(m.eval_var(&init, "WG"), None); // not chosen yet
        let mut buf = Vec::new();
        m.successors(&init, &mut buf);
        assert!(m.eval_var(&buf[0], "WG").is_some());
        assert!(m.eval_var(&buf[0], "NWE").is_some());
        assert_eq!(m.eval_var(&buf[0], "nope"), None);
    }

    #[test]
    fn encode_is_injective_on_a_run() {
        let m = AbstractModel::new(16, PlatformConfig::default(), Granularity::Tick).unwrap();
        let mut seen = std::collections::HashSet::new();
        let mut buf = Vec::new();
        m.successors(&m.initial_states()[0], &mut buf);
        let mut s = buf[0];
        let mut enc = Vec::new();
        loop {
            m.encode(&s, &mut enc);
            assert!(seen.insert(enc.clone()), "state encoding collision");
            let mut next = Vec::new();
            m.successors(&s, &mut next);
            if next.is_empty() {
                break;
            }
            s = next[0];
        }
    }

    #[test]
    fn larger_tile_never_slower_on_default_platform() {
        // On the abstract model the compute term is TS-independent and
        // rounds shrink with TS, so time is monotone non-increasing in TS.
        let m = AbstractModel::new(64, PlatformConfig::default(), Granularity::Phase).unwrap();
        for &wg in &[2u32, 4, 8] {
            let mut prev = u64::MAX;
            for &ts in &[2u32, 4, 8] {
                if wg * ts > 64 {
                    continue;
                }
                let time = m.predicted_time(Tuning { wg, ts });
                assert!(time <= prev, "wg={} ts={} time={} prev={}", wg, ts, time, prev);
                prev = time;
            }
        }
    }
}
