//! Platform and tuning-parameter configuration (paper §3.1, §4).

use crate::util::error::{bail, Result};

/// The abstract OpenCL platform: `ND` devices × `NU` units × `NP`
/// processing elements, with `GMT` = global/local memory access-time ratio
/// (paper: "usually between one and two orders of magnitude").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PlatformConfig {
    pub nd: u32,
    pub nu: u32,
    pub np: u32,
    pub gmt: u32,
}

impl Default for PlatformConfig {
    /// The paper's Table-1 platform: one device, one unit, four PEs.
    fn default() -> Self {
        Self { nd: 1, nu: 1, np: 4, gmt: 10 }
    }
}

impl PlatformConfig {
    pub fn validate(&self) -> Result<()> {
        if self.nd == 0 || self.nu == 0 || self.np == 0 {
            bail!("platform dimensions must be positive: {:?}", self);
        }
        if self.gmt == 0 {
            bail!("GMT must be >= 1 (global memory cannot be faster than local)");
        }
        Ok(())
    }
}

/// One tuning-parameter configuration: workgroup size and tile size
/// (both powers of two, paper Listing 3 lines 6-10).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Tuning {
    pub wg: u32,
    pub ts: u32,
}

/// Derived launch geometry (Listing 3 lines 12-22).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Geometry {
    pub wgs: u32,
    pub nwd: u32,
    pub nwu: u32,
    pub nwe: u32,
    /// sequential pex-activation rounds needed to serve all work items
    pub rounds: u32,
}

impl Geometry {
    /// Work items executing simultaneously (Listing 3 line 22).
    pub fn all_nwe(&self) -> u32 {
        self.nwd * self.nwu * self.nwe
    }
}

pub fn is_pow2(x: u32) -> bool {
    x != 0 && x & (x - 1) == 0
}

pub fn ceil_div(a: u64, b: u64) -> u64 {
    debug_assert!(b > 0);
    a.div_ceil(b)
}

/// Enumerate the paper's tuning search space for input `size = 2^n`:
/// `WG = 2^i`, `TS = 2^j`, i,j ∈ 1..=n-1 (Listing 3), restricted to
/// configurations that launch at least one workgroup (`WGs >= 1`).
pub fn enumerate_tunings(size: u32) -> Result<Vec<Tuning>> {
    if !is_pow2(size) || size < 4 {
        bail!("size must be a power of two >= 4, got {}", size);
    }
    let n = size.trailing_zeros();
    let mut out = Vec::new();
    for i in 1..n {
        for j in 1..n {
            let (wg, ts) = (1u32 << i, 1u32 << j);
            if (wg as u64) * (ts as u64) <= size as u64 {
                out.push(Tuning { wg, ts });
            }
        }
    }
    Ok(out)
}

/// Launch geometry for a tuning choice on a platform (Listing 3 semantics,
/// including the two-step NWD clamp in lines 14-16).
pub fn geometry(size: u32, t: Tuning, p: &PlatformConfig) -> Geometry {
    let wgs = size / (t.wg * t.ts);
    debug_assert!(wgs >= 1, "invalid tuning {:?} for size {}", t, size);
    // NWD = (WGs <= NU*ND -> WGs/NU : ND); NWD = (WGs/NU -> NWD : 1)
    let mut nwd = if wgs <= p.nu * p.nd { wgs / p.nu } else { p.nd };
    if wgs / p.nu == 0 {
        nwd = 1;
    }
    let nwu = wgs.min(p.nu);
    let nwe = t.wg.min(p.np);
    let total_items = wgs as u64 * t.wg as u64;
    let rounds = ceil_div(total_items, (nwd * nwu * nwe) as u64) as u32;
    Geometry { wgs, nwd, nwu, nwe, rounds }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pow2_and_ceil_div() {
        assert!(is_pow2(1) && is_pow2(1024));
        assert!(!is_pow2(0) && !is_pow2(12));
        assert_eq!(ceil_div(7, 2), 4);
        assert_eq!(ceil_div(8, 2), 4);
    }

    #[test]
    fn enumerate_respects_bounds() {
        let ts = enumerate_tunings(16).unwrap();
        // i,j in 1..=3, wg*ts <= 16
        assert!(ts.iter().all(|t| is_pow2(t.wg) && is_pow2(t.ts)));
        assert!(ts.iter().all(|t| t.wg >= 2 && t.wg <= 8 && t.ts >= 2 && t.ts <= 8));
        assert!(ts.iter().all(|t| t.wg * t.ts <= 16));
        assert!(ts.contains(&Tuning { wg: 8, ts: 2 }));
        assert!(ts.contains(&Tuning { wg: 2, ts: 8 }));
        assert!(!ts.contains(&Tuning { wg: 8, ts: 8 })); // WGs would be 0
    }

    #[test]
    fn enumerate_rejects_non_pow2() {
        assert!(enumerate_tunings(12).is_err());
        assert!(enumerate_tunings(2).is_err());
    }

    #[test]
    fn geometry_paper_defaults() {
        // size 16, WG 4, TS 2 on the Table-1 platform (1 dev, 1 unit, 4 PE)
        let g = geometry(16, Tuning { wg: 4, ts: 2 }, &PlatformConfig::default());
        assert_eq!(g.wgs, 2);
        assert_eq!(g.nwd, 1);
        assert_eq!(g.nwu, 1);
        assert_eq!(g.nwe, 4);
        // 2 workgroups x 4 items / 4 simultaneous = 2 rounds
        assert_eq!(g.rounds, 2);
        assert_eq!(g.all_nwe(), 4);
    }

    #[test]
    fn geometry_wg_exceeds_np() {
        let g = geometry(64, Tuning { wg: 16, ts: 2 }, &PlatformConfig::default());
        assert_eq!(g.wgs, 2);
        assert_eq!(g.nwe, 4); // capped at NP
        assert_eq!(g.rounds, 8); // 32 items / 4 simultaneous
    }

    #[test]
    fn geometry_multi_device_clamp() {
        let p = PlatformConfig { nd: 2, nu: 3, np: 4, gmt: 10 };
        // WGs = 1 <= NU*ND: NWD = WGs/NU = 0 -> clamped to 1
        let g = geometry(16, Tuning { wg: 4, ts: 4 }, &p);
        assert_eq!(g.nwd, 1);
        assert_eq!(g.nwu, 1);
        // WGs = 8 > NU*ND=6: NWD = ND = 2
        let g = geometry(64, Tuning { wg: 4, ts: 2 }, &p);
        assert_eq!(g.wgs, 8);
        assert_eq!(g.nwd, 2);
        assert_eq!(g.nwu, 3);
    }

    #[test]
    fn platform_validation() {
        assert!(PlatformConfig::default().validate().is_ok());
        assert!(PlatformConfig { nd: 0, ..Default::default() }.validate().is_err());
        assert!(PlatformConfig { gmt: 0, ..Default::default() }.validate().is_err());
    }
}
