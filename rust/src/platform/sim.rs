//! Random simulation — SPIN's simulation mode (paper §2 Step 3: the
//! initial bound `T_ini` "can be specified by simulating the program
//! model"). A uniformly random walk from an initial state to a terminal
//! state (or a step bound) reports the terminal observation.

use crate::model::TransitionSystem;
use crate::util::rng::Xoshiro256;

#[derive(Debug, Clone)]
pub struct SimReport<S> {
    pub final_state: S,
    pub steps: usize,
    /// value of `time` in the final state, when the model exposes it
    pub time: Option<i64>,
    /// true if the walk reached a terminal state (vs hitting max_steps)
    pub terminated: bool,
}

/// One random walk. `max_steps` guards against non-terminating models.
pub fn simulate<M: TransitionSystem>(m: &M, seed: u64, max_steps: usize) -> SimReport<M::State> {
    let mut rng = Xoshiro256::new(seed);
    let inits = m.initial_states();
    let mut state = inits[rng.below(inits.len() as u64) as usize].clone();
    let mut buf = Vec::new();
    let mut steps = 0usize;
    loop {
        m.successors(&state, &mut buf);
        if buf.is_empty() || steps >= max_steps {
            let terminated = buf.is_empty();
            let time = m.eval_var(&state, "time");
            return SimReport { final_state: state, steps, time, terminated };
        }
        state = buf[rng.below(buf.len() as u64) as usize].clone();
        steps += 1;
    }
}

/// `T_ini` via a handful of simulations: the paper seeds the bisection
/// with a simulated termination time; we take the max over `runs` walks so
/// bisection starts from a sound upper region (any observed terminal time
/// is achievable, hence Cex(T_ini) holds).
pub fn initial_bound<M: TransitionSystem>(m: &M, runs: u32, seed: u64, max_steps: usize) -> Option<i64> {
    let mut best: Option<i64> = None;
    for r in 0..runs {
        let rep = simulate(m, seed.wrapping_add(r as u64), max_steps);
        if rep.terminated {
            if let Some(t) = rep.time {
                best = Some(best.map_or(t, |b: i64| b.max(t)));
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::abstract_model::{AbstractModel, Granularity};
    use crate::platform::config::PlatformConfig;
    use crate::platform::min_model::{DataInit, MinModel};

    #[test]
    fn simulation_terminates_on_abstract_model() {
        let m = AbstractModel::new(16, PlatformConfig::default(), Granularity::Phase).unwrap();
        let rep = simulate(&m, 1, 1_000_000);
        assert!(rep.terminated);
        assert_eq!(m.eval_var(&rep.final_state, "FIN"), Some(1));
        assert!(rep.time.unwrap() > 0);
    }

    #[test]
    fn initial_bound_is_achievable_time() {
        let m = AbstractModel::new(16, PlatformConfig::default(), Granularity::Phase).unwrap();
        let t = initial_bound(&m, 8, 42, 1_000_000).unwrap();
        // the bound must be one of the model's terminal times
        let times: Vec<u64> = m.tunings().iter().map(|&u| m.predicted_time(u)).collect();
        assert!(times.contains(&(t as u64)));
    }

    #[test]
    fn different_seeds_explore_different_configs() {
        let m = MinModel::new(64, 4, 3, DataInit::Descending, Granularity::Phase).unwrap();
        let times: std::collections::HashSet<i64> =
            (0..32).map(|s| simulate(&m, s, 1_000_000).time.unwrap()).collect();
        assert!(times.len() > 1, "walks should sample multiple tunings");
    }

    #[test]
    fn max_steps_guard() {
        let m = AbstractModel::new(1024, PlatformConfig::default(), Granularity::Tick).unwrap();
        let rep = simulate(&m, 3, 10);
        assert!(!rep.terminated);
        assert_eq!(rep.steps, 10);
    }
}
