//! Native transition system for the paper's Minimum-problem model
//! (paper §7.2, Listings 12–15).
//!
//! One device / one unit (the paper's §5 symmetry abstraction). `main`
//! loads `glob[i]` and nondeterministically picks (WG, TS); `NWE =
//! min(WG, NP)` pexes then process `size/TS` work items in rounds; each
//! element costs `GMT+1` ticks (the `min` + `long_work(GMT)` pair of
//! Listing 15 lines 15-16); after the last round, pex 0 folds the NWE
//! local slots ((NWE−1) local ticks, lines 27-30) and writes the result to
//! global memory (`GMT` ticks, lines 32-33); one setup and one finish
//! handshake tick bracket the run. Calibration against the paper's
//! Table 3 (GMT=3): rows 4, 5 and 7 reproduce exactly; see
//! EXPERIMENTS.md for the full per-row comparison.
//!
//! Unlike the abstract model, this model carries *data*: `cur_min` folds
//! the actual array values as work items complete, and at FIN it must
//! equal the true minimum — an invariant the checker verifies over every
//! schedule (tests + `rust/tests/proptests.rs`).

use super::abstract_model::Granularity;
use super::config::{ceil_div, is_pow2, Tuning};
use crate::model::TransitionSystem;
use crate::util::rng::SplitMix64;
use crate::util::error::{bail, ensure, Result};

/// How `main` initializes global memory (Listing 12 line 4).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DataInit {
    /// `glob[i] = size - i` — the paper's initialization; min = 1.
    Descending,
    /// pseudorandom i32 values derived from the seed (for property tests)
    Seeded(u64),
}

const CFG_NONE: u8 = u8::MAX;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    Setup = 0,
    Map = 1,
    Reduce = 2,
    Write = 3,
    Finish = 4,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct MinState {
    cfg: u8,
    phase: u8,
    round: u16,
    ticks_left: u32,
    pub time: u64,
    /// running fold of all elements consumed so far (i32 domain)
    pub cur_min: i32,
    pub items_done: u32,
    pub fin: bool,
}

pub struct MinModel {
    pub size: u32,
    pub np: u32,
    pub gmt: u32,
    pub data: DataInit,
    pub granularity: Granularity,
    tunings: Vec<Tuning>,
}

impl MinModel {
    pub fn new(
        size: u32,
        np: u32,
        gmt: u32,
        data: DataInit,
        granularity: Granularity,
    ) -> Result<Self> {
        if !is_pow2(size) || size < 4 {
            bail!("size must be a power of two >= 4, got {}", size);
        }
        if np == 0 || gmt == 0 {
            bail!("np and gmt must be positive");
        }
        let tunings = super::config::enumerate_tunings(size)?;
        ensure!(tunings.len() < CFG_NONE as usize, "tuning space too large");
        Ok(Self { size, np, gmt, data, granularity, tunings })
    }

    /// The paper's Table-3 setup: GMT = 3 (calibrated; see module docs).
    pub fn paper(size: u32, np: u32) -> Result<Self> {
        Self::new(size, np, 3, DataInit::Descending, Granularity::Phase)
    }

    pub fn tunings(&self) -> &[Tuning] {
        &self.tunings
    }

    /// Element value at index i (computed on the fly; the array itself is
    /// never stored in the state).
    pub fn elem(&self, i: u32) -> i32 {
        match self.data {
            DataInit::Descending => (self.size - i) as i32,
            DataInit::Seeded(seed) => {
                let mut sm = SplitMix64::new(seed ^ (i as u64).wrapping_mul(0x9E37_79B9));
                sm.next_u64() as i32
            }
        }
    }

    /// True minimum of the initialized array — the oracle for FIN states.
    pub fn true_min(&self) -> i32 {
        (0..self.size).map(|i| self.elem(i)).min().unwrap()
    }

    fn nwe(&self, t: Tuning) -> u32 {
        t.wg.min(self.np)
    }

    fn items(&self, t: Tuning) -> u32 {
        self.size / t.ts
    }

    fn rounds(&self, t: Tuning) -> u32 {
        ceil_div(self.items(t) as u64, self.nwe(t) as u64) as u32
    }

    /// Ticks of one map round: each pex scans TS elements at GMT+1 each.
    fn map_round_ticks(&self, t: Tuning) -> u32 {
        t.ts * (self.gmt + 1)
    }

    /// Closed-form terminal time (asserted against the transition system).
    pub fn predicted_time(&self, t: Tuning) -> u64 {
        let map = self.rounds(t) as u64 * self.map_round_ticks(t) as u64;
        map + (self.nwe(t) as u64 - 1) + self.gmt as u64 + 2
    }

    pub fn optimum(&self) -> (u64, Tuning) {
        self.tunings
            .iter()
            .map(|&t| (self.predicted_time(t), t))
            .min_by_key(|&(time, t)| (time, t.wg, t.ts))
            .expect("non-empty tuning space")
    }

    fn tuning(&self, s: &MinState) -> Option<Tuning> {
        (s.cfg != CFG_NONE).then(|| self.tunings[s.cfg as usize])
    }

    /// Fold the elements of work items [first, last) into cur_min.
    fn fold_items(&self, t: Tuning, first: u32, last: u32, cur: i32) -> i32 {
        let mut m = cur;
        for item in first..last {
            let base = item * t.ts;
            for k in 0..t.ts {
                m = m.min(self.elem(base + k));
            }
        }
        m
    }

    /// Phase rollover once the current phase's ticks are exhausted.
    fn next_phase(&self, s: &MinState) -> MinState {
        let t = self.tunings[s.cfg as usize];
        let mut n = *s;
        match s.phase {
            p if p == Phase::Setup as u8 => {
                n.phase = Phase::Map as u8;
                n.round = 0;
                n.ticks_left = self.map_round_ticks(t);
            }
            p if p == Phase::Map as u8 => {
                // round completes: NWE work items finished, fold their data
                let first = s.round as u32 * self.nwe(t);
                let last = (first + self.nwe(t)).min(self.items(t));
                n.cur_min = self.fold_items(t, first, last, s.cur_min);
                n.items_done = last;
                if (s.round as u32) + 1 < self.rounds(t) {
                    n.round += 1;
                    n.ticks_left = self.map_round_ticks(t);
                } else {
                    n.phase = Phase::Reduce as u8;
                    n.ticks_left = self.nwe(t) - 1;
                    if n.ticks_left == 0 {
                        // NWE == 1: nothing to fold locally, go straight on
                        n.phase = Phase::Write as u8;
                        n.ticks_left = self.gmt;
                    }
                }
            }
            p if p == Phase::Reduce as u8 => {
                n.phase = Phase::Write as u8;
                n.ticks_left = self.gmt;
            }
            p if p == Phase::Write as u8 => {
                n.phase = Phase::Finish as u8;
                n.ticks_left = 1;
            }
            _ => {
                n.fin = true;
                n.ticks_left = 0;
            }
        }
        n
    }
}

impl TransitionSystem for MinModel {
    type State = MinState;

    fn initial_states(&self) -> Vec<MinState> {
        vec![MinState {
            cfg: CFG_NONE,
            phase: Phase::Setup as u8,
            round: 0,
            ticks_left: 0,
            time: 0,
            cur_min: i32::MAX, // loc[] preset to MAX (Listing 12 line 6)
            items_done: 0,
            fin: false,
        }]
    }

    fn successors(&self, s: &MinState, out: &mut Vec<MinState>) {
        out.clear();
        if s.fin {
            return;
        }
        if s.cfg == CFG_NONE {
            for i in 0..self.tunings.len() {
                let mut n = *s;
                n.cfg = i as u8;
                n.phase = Phase::Setup as u8;
                n.ticks_left = 1; // setup handshake tick
                out.push(n);
            }
            return;
        }
        match self.granularity {
            Granularity::Tick => {
                if s.ticks_left > 1 {
                    let mut n = *s;
                    n.ticks_left -= 1;
                    n.time += 1;
                    out.push(n);
                } else {
                    let mut nn = self.next_phase(s);
                    nn.time = s.time + 1;
                    out.push(nn);
                }
            }
            Granularity::Phase => {
                let mut nn = self.next_phase(s);
                nn.time = s.time + s.ticks_left as u64;
                out.push(nn);
            }
        }
    }

    fn encode(&self, s: &MinState, out: &mut Vec<u8>) {
        out.clear();
        out.push(s.cfg);
        out.push(s.phase);
        out.extend_from_slice(&s.round.to_le_bytes());
        out.extend_from_slice(&s.ticks_left.to_le_bytes());
        out.extend_from_slice(&s.time.to_le_bytes());
        out.extend_from_slice(&s.cur_min.to_le_bytes());
        out.extend_from_slice(&s.items_done.to_le_bytes());
        out.push(s.fin as u8);
    }

    fn eval_var(&self, s: &MinState, name: &str) -> Option<i64> {
        match name {
            "time" => Some(s.time as i64),
            "FIN" => Some(s.fin as i64),
            "size" => Some(self.size as i64),
            "result" => Some(s.cur_min as i64),
            "items_done" => Some(s.items_done as i64),
            "WG" => self.tuning(s).map(|t| t.wg as i64),
            "TS" => self.tuning(s).map(|t| t.ts as i64),
            "NWE" => self.tuning(s).map(|t| self.nwe(t) as i64),
            "rounds" => self.tuning(s).map(|t| self.rounds(t) as i64),
            _ => None,
        }
    }

    fn resolve_slot(&self, name: &str) -> Option<u32> {
        // ids match the eval_var arm order (see eval_slots)
        ["time", "FIN", "size", "result", "items_done", "WG", "TS", "NWE", "rounds"]
            .iter()
            .position(|n| *n == name)
            .map(|i| i as u32)
    }

    fn eval_slots(&self, s: &MinState, ids: &[u32], out: &mut [i64]) -> u64 {
        let mut missing = 0u64;
        let tuning = self.tuning(s);
        for (i, &id) in ids.iter().enumerate() {
            let v = match id {
                0 => Some(s.time as i64),
                1 => Some(s.fin as i64),
                2 => Some(self.size as i64),
                3 => Some(s.cur_min as i64),
                4 => Some(s.items_done as i64),
                5 => tuning.map(|t| t.wg as i64),
                6 => tuning.map(|t| t.ts as i64),
                7 => tuning.map(|t| self.nwe(t) as i64),
                8 => tuning.map(|t| self.rounds(t) as i64),
                _ => None,
            };
            match v {
                Some(v) => out[i] = v,
                None => missing |= 1u64 << i,
            }
        }
        missing
    }

    fn describe(&self, s: &MinState) -> String {
        match self.tuning(s) {
            None => "main: loading glob[], selecting WG, TS".to_string(),
            Some(t) => format!(
                "WG={} TS={} phase={} round={} time={} min={}{}",
                t.wg,
                t.ts,
                ["setup", "map", "reduce", "write", "finish"][(s.phase as usize).min(4)],
                s.round,
                s.time,
                s.cur_min,
                if s.fin { " FIN" } else { "" }
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_to_fin(m: &MinModel, cfg_idx: usize) -> MinState {
        let mut buf = Vec::new();
        m.successors(&m.initial_states()[0], &mut buf);
        let mut s = buf[cfg_idx];
        loop {
            let mut next = Vec::new();
            m.successors(&s, &mut next);
            if next.is_empty() {
                return s;
            }
            assert_eq!(next.len(), 1);
            s = next[0];
        }
    }

    #[test]
    fn descending_data_min_is_one() {
        let m = MinModel::paper(64, 4).unwrap();
        assert_eq!(m.elem(0), 64);
        assert_eq!(m.elem(63), 1);
        assert_eq!(m.true_min(), 1);
    }

    #[test]
    fn fin_state_computes_true_min_all_configs() {
        for data in [DataInit::Descending, DataInit::Seeded(0xDEAD)] {
            let m = MinModel::new(64, 4, 3, data, Granularity::Phase).unwrap();
            for i in 0..m.tunings().len() {
                let fin = run_to_fin(&m, i);
                assert!(fin.fin);
                assert_eq!(fin.cur_min, m.true_min(), "cfg {:?}", m.tunings()[i]);
                assert_eq!(fin.items_done, m.items(m.tunings()[i]));
            }
        }
    }

    #[test]
    fn terminal_time_matches_formula_both_granularities() {
        for g in [Granularity::Phase, Granularity::Tick] {
            let m = MinModel::new(32, 4, 3, DataInit::Descending, g).unwrap();
            for (i, &t) in m.tunings().iter().enumerate() {
                let fin = run_to_fin(&m, i);
                assert_eq!(fin.time, m.predicted_time(t), "tuning {:?} ({:?})", t, g);
            }
        }
    }

    #[test]
    fn paper_table3_calibrated_rows() {
        // Table 3 rows 4, 5, 7 (NP=64) reproduce exactly with GMT=3.
        let m64 = MinModel::paper(64, 64).unwrap();
        assert_eq!(m64.predicted_time(Tuning { wg: 16, ts: 4 }), 36); // row 4
        assert_eq!(m64.predicted_time(Tuning { wg: 8, ts: 8 }), 44); // row 5
        let m128 = MinModel::paper(128, 64).unwrap();
        assert_eq!(m128.predicted_time(Tuning { wg: 8, ts: 16 }), 76); // row 7
    }

    #[test]
    fn wg_dominates_ts_like_paper() {
        // Paper §7.3: "the WG parameter affects the run time more
        // dramatically than TS". At fixed TS, growing WG (up to NP) must
        // shrink time; at fixed WG, growing TS changes time only mildly.
        let m = MinModel::paper(256, 64).unwrap();
        let t_wg2 = m.predicted_time(Tuning { wg: 2, ts: 4 });
        let t_wg16 = m.predicted_time(Tuning { wg: 16, ts: 4 });
        assert!(t_wg16 * 4 < t_wg2, "{} vs {}", t_wg16, t_wg2);
        let a = m.predicted_time(Tuning { wg: 16, ts: 2 });
        let b = m.predicted_time(Tuning { wg: 16, ts: 8 });
        let rel = (a as f64 - b as f64).abs() / a as f64;
        assert!(rel < 0.25, "TS effect too large: {} vs {}", a, b);
    }

    #[test]
    fn nwe_one_skips_reduce() {
        // WG=2, NP=1 -> NWE=1: no local reduce phase, but still terminates
        let m = MinModel::new(8, 1, 3, DataInit::Descending, Granularity::Phase).unwrap();
        let idx = m.tunings().iter().position(|t| *t == Tuning { wg: 2, ts: 2 }).unwrap();
        let fin = run_to_fin(&m, idx);
        assert!(fin.fin);
        assert_eq!(fin.cur_min, 1);
        assert_eq!(fin.time, m.predicted_time(Tuning { wg: 2, ts: 2 }));
    }

    #[test]
    fn seeded_data_differs_by_seed() {
        let a = MinModel::new(16, 4, 3, DataInit::Seeded(1), Granularity::Phase).unwrap();
        let b = MinModel::new(16, 4, 3, DataInit::Seeded(2), Granularity::Phase).unwrap();
        let va: Vec<i32> = (0..16).map(|i| a.elem(i)).collect();
        let vb: Vec<i32> = (0..16).map(|i| b.elem(i)).collect();
        assert_ne!(va, vb);
    }
}
