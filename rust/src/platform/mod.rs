//! Native (Rust) implementations of the paper's two Promela models — the
//! abstract OpenCL platform (§4) and the Minimum problem (§7.2) — as
//! [`crate::model::TransitionSystem`]s, plus SPIN-style random simulation.
//!
//! These are the checker's optimized hot path. The Promela front end
//! (`crate::promela`) executes the shipped `models/*.pml` with full
//! interleaving as the reference semantics; `rust/tests/promela_vs_native.rs`
//! pins both to the same reachable terminal (time, WG, TS) sets.

pub mod abstract_model;
pub mod config;
pub mod min_model;
pub mod sim;

pub use abstract_model::{AbstractModel, Granularity};
pub use config::{enumerate_tunings, geometry, PlatformConfig, Tuning};
pub use min_model::{DataInit, MinModel};
pub use sim::{initial_bound, simulate, SimReport};
