//! Work-stealing job queue on `std::thread` (rayon is unavailable
//! offline, and the checker's swarm already shows scoped std threads are
//! all the paper's workloads need).
//!
//! Tasks are dealt round-robin across per-worker deques. A worker pops
//! from the *back* of its own deque (LIFO — the task it was just dealt,
//! cache-warm) and, when starved, steals from the *front* of another
//! worker's deque (FIFO — the task that has waited longest). Tasks never
//! spawn tasks, so "every deque empty" is a sound termination test: no
//! new work can appear after it holds.

use std::collections::VecDeque;
use std::sync::Mutex;

/// Execution statistics of one [`JobQueue::run_stats`] call.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct QueueStats {
    /// tasks executed per worker
    pub executed: Vec<u64>,
    /// tasks taken from another worker's deque
    pub stolen: u64,
}

/// A fixed-width work-stealing task runner.
#[derive(Debug, Clone, Copy)]
pub struct JobQueue {
    workers: usize,
}

impl JobQueue {
    pub fn new(workers: u32) -> Self {
        Self { workers: workers.max(1) as usize }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run every task, returning results in task order.
    pub fn run<T, R, F>(&self, tasks: Vec<T>, f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(T) -> R + Sync,
    {
        self.run_stats(tasks, f).0
    }

    /// [`run`](Self::run) plus per-worker execution counts and steal
    /// totals. The worker count is clamped to the task count; a worker
    /// that panics propagates the panic.
    pub fn run_stats<T, R, F>(&self, tasks: Vec<T>, f: F) -> (Vec<R>, QueueStats)
    where
        T: Send,
        R: Send,
        F: Fn(T) -> R + Sync,
    {
        let n = tasks.len();
        if n == 0 {
            return (Vec::new(), QueueStats::default());
        }
        let workers = self.workers.min(n);
        let mut deques: Vec<Mutex<VecDeque<(usize, T)>>> =
            (0..workers).map(|_| Mutex::new(VecDeque::new())).collect();
        for (i, t) in tasks.into_iter().enumerate() {
            deques[i % workers].get_mut().expect("fresh mutex").push_back((i, t));
        }
        let deques = &deques;
        let results: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
        let results = &results;
        let f = &f;

        let mut stats = QueueStats { executed: vec![0; workers], stolen: 0 };
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..workers)
                .map(|w| {
                    scope.spawn(move || {
                        let mut executed = 0u64;
                        let mut stolen = 0u64;
                        loop {
                            // own deque first (LIFO), then steal (FIFO)
                            let mut task = deques[w].lock().expect("queue lock").pop_back();
                            if task.is_none() {
                                for v in 0..workers {
                                    if v == w {
                                        continue;
                                    }
                                    task = deques[v].lock().expect("queue lock").pop_front();
                                    if task.is_some() {
                                        stolen += 1;
                                        break;
                                    }
                                }
                            }
                            match task {
                                Some((i, t)) => {
                                    let r = f(t);
                                    *results[i].lock().expect("result lock") = Some(r);
                                    executed += 1;
                                }
                                None => break, // every deque empty: done
                            }
                        }
                        (executed, stolen)
                    })
                })
                .collect();
            for (w, h) in handles.into_iter().enumerate() {
                let (executed, stolen) = h.join().expect("queue worker panicked");
                stats.executed[w] = executed;
                stats.stolen += stolen;
            }
        });

        let out = results
            .iter()
            .map(|m| m.lock().expect("result lock").take().expect("task result missing"))
            .collect();
        (out, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_keep_task_order() {
        let q = JobQueue::new(4);
        let (out, stats) = q.run_stats((0..100u32).collect(), |x| x * x);
        assert_eq!(out, (0..100u32).map(|x| x * x).collect::<Vec<_>>());
        assert_eq!(stats.executed.iter().sum::<u64>(), 100);
    }

    #[test]
    fn single_worker_drains_everything() {
        let q = JobQueue::new(1);
        let (out, stats) = q.run_stats((0..32i32).collect(), |x| x + 1);
        assert_eq!(out.len(), 32);
        assert_eq!(stats.executed, vec![32]);
        assert_eq!(stats.stolen, 0);
    }

    #[test]
    fn imbalanced_tasks_get_stolen() {
        // worker 0's deque holds all the slow tasks (round-robin over 2
        // workers with slowness on even indices): stealing must kick in
        let q = JobQueue::new(2);
        let (out, stats) = q.run_stats(
            (0..16usize).collect(),
            |i| {
                if i % 2 == 0 {
                    std::thread::sleep(std::time::Duration::from_millis(20));
                }
                i
            },
        );
        assert_eq!(out, (0..16).collect::<Vec<_>>());
        assert!(stats.stolen > 0, "expected steals, got {:?}", stats);
    }

    #[test]
    fn empty_and_oversized_worker_counts() {
        let q = JobQueue::new(8);
        let (out, stats) = q.run_stats(Vec::<u32>::new(), |x| x);
        assert!(out.is_empty());
        assert!(stats.executed.is_empty());
        // more workers than tasks: clamped, still correct
        let (out, stats) = q.run_stats(vec![1u32, 2], |x| x * 10);
        assert_eq!(out, vec![10, 20]);
        assert_eq!(stats.executed.len(), 2);
    }

    #[test]
    fn zero_requested_workers_clamps_to_one() {
        let q = JobQueue::new(0);
        assert_eq!(q.workers(), 1);
        assert_eq!(q.run(vec![5u8], |x| x), vec![5]);
    }
}
