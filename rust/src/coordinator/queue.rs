//! Work-stealing job queue on `std::thread` (rayon is unavailable
//! offline, and the checker's swarm already shows scoped std threads are
//! all the paper's workloads need).
//!
//! Tasks are dealt round-robin across per-worker deques. A worker pops
//! from the *back* of its own deque (LIFO — the task it was just dealt,
//! cache-warm) and, when starved, steals from the *front* of another
//! worker's deque (FIFO — the task that has waited longest). Tasks never
//! spawn tasks, so "every deque empty" is a sound termination test: no
//! new work can appear after it holds.

use crate::util::error::Result;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Execution statistics of one [`JobQueue::run_stats`] call.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct QueueStats {
    /// tasks executed per worker
    pub executed: Vec<u64>,
    /// tasks taken from another worker's deque
    pub stolen: u64,
}

/// A fixed-width work-stealing task runner.
#[derive(Debug, Clone, Copy)]
pub struct JobQueue {
    workers: usize,
}

impl JobQueue {
    pub fn new(workers: u32) -> Self {
        Self { workers: workers.max(1) as usize }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run every task, returning results in task order.
    pub fn run<T, R, F>(&self, tasks: Vec<T>, f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(T) -> R + Sync,
    {
        self.run_stats(tasks, f).0
    }

    /// [`run`](Self::run) plus per-worker execution counts and steal
    /// totals. The worker count is clamped to the task count; a worker
    /// that panics propagates the panic.
    pub fn run_stats<T, R, F>(&self, tasks: Vec<T>, f: F) -> (Vec<R>, QueueStats)
    where
        T: Send,
        R: Send,
        F: Fn(T) -> R + Sync,
    {
        let n = tasks.len();
        if n == 0 {
            return (Vec::new(), QueueStats::default());
        }
        let workers = self.workers.min(n);
        let mut deques: Vec<Mutex<VecDeque<(usize, T)>>> =
            (0..workers).map(|_| Mutex::new(VecDeque::new())).collect();
        for (i, t) in tasks.into_iter().enumerate() {
            deques[i % workers].get_mut().expect("fresh mutex").push_back((i, t));
        }
        let deques = &deques;
        let results: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
        let results = &results;
        let f = &f;

        let mut stats = QueueStats { executed: vec![0; workers], stolen: 0 };
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..workers)
                .map(|w| {
                    scope.spawn(move || {
                        let mut executed = 0u64;
                        let mut stolen = 0u64;
                        loop {
                            // own deque first (LIFO), then steal (FIFO)
                            let mut task = deques[w].lock().expect("queue lock").pop_back();
                            if task.is_none() {
                                for v in 0..workers {
                                    if v == w {
                                        continue;
                                    }
                                    task = deques[v].lock().expect("queue lock").pop_front();
                                    if task.is_some() {
                                        stolen += 1;
                                        break;
                                    }
                                }
                            }
                            match task {
                                Some((i, t)) => {
                                    let r = f(t);
                                    *results[i].lock().expect("result lock") = Some(r);
                                    executed += 1;
                                }
                                None => break, // every deque empty: done
                            }
                        }
                        (executed, stolen)
                    })
                })
                .collect();
            for (w, h) in handles.into_iter().enumerate() {
                let (executed, stolen) = h.join().expect("queue worker panicked");
                stats.executed[w] = executed;
                stats.stolen += stolen;
            }
        });
        // telemetry: one pair of adds per run, after the joins (cold path)
        let m = crate::obs::metrics();
        m.queue_executed.add(stats.executed.iter().sum());
        m.queue_stolen.add(stats.stolen);

        let out = results
            .iter()
            .map(|m| m.lock().expect("result lock").take().expect("task result missing"))
            .collect();
        (out, stats)
    }

    /// Run tasks pulled on demand from `source` until every worker sees
    /// `None`. Unlike [`run_stats`](Self::run_stats), the task set need
    /// not be known up front — cross-process draining
    /// ([`super::task::TaskDir::drain`]) leases tasks from a shared
    /// directory as it goes, and `source` itself is the arbiter (it may
    /// block/poll internally and return `None` only when no work will
    /// ever appear again). There is nothing to steal: the source hands
    /// each task to exactly one worker. Returns the total executed task
    /// count; the first error from `source` or `f` propagates after every
    /// worker has stopped (workers that already pulled a task finish it).
    pub fn run_source<T, S, F>(&self, source: S, f: F) -> Result<u64>
    where
        T: Send,
        S: Fn() -> Result<Option<T>> + Sync,
        F: Fn(T) -> Result<()> + Sync,
    {
        let executed = AtomicU64::new(0);
        let (source, f, executed_ref) = (&source, &f, &executed);
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..self.workers)
                .map(|_| {
                    scope.spawn(move || -> Result<()> {
                        while let Some(task) = source()? {
                            f(task)?;
                            executed_ref.fetch_add(1, Ordering::Relaxed);
                        }
                        Ok(())
                    })
                })
                .collect();
            let mut first_err = None;
            for h in handles {
                if let Err(e) = h.join().expect("queue worker panicked") {
                    first_err.get_or_insert(e);
                }
            }
            match first_err {
                Some(e) => Err(e),
                None => {
                    let n = executed.load(Ordering::Relaxed);
                    crate::obs::metrics().queue_executed.add(n);
                    Ok(n)
                }
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_keep_task_order() {
        let q = JobQueue::new(4);
        let (out, stats) = q.run_stats((0..100u32).collect(), |x| x * x);
        assert_eq!(out, (0..100u32).map(|x| x * x).collect::<Vec<_>>());
        assert_eq!(stats.executed.iter().sum::<u64>(), 100);
    }

    #[test]
    fn single_worker_drains_everything() {
        let q = JobQueue::new(1);
        let (out, stats) = q.run_stats((0..32i32).collect(), |x| x + 1);
        assert_eq!(out.len(), 32);
        assert_eq!(stats.executed, vec![32]);
        assert_eq!(stats.stolen, 0);
    }

    #[test]
    fn imbalanced_tasks_get_stolen() {
        // worker 0's deque holds all the slow tasks (round-robin over 2
        // workers with slowness on even indices): stealing must kick in
        let q = JobQueue::new(2);
        let (out, stats) = q.run_stats(
            (0..16usize).collect(),
            |i| {
                if i % 2 == 0 {
                    std::thread::sleep(std::time::Duration::from_millis(20));
                }
                i
            },
        );
        assert_eq!(out, (0..16).collect::<Vec<_>>());
        assert!(stats.stolen > 0, "expected steals, got {:?}", stats);
    }

    #[test]
    fn empty_and_oversized_worker_counts() {
        let q = JobQueue::new(8);
        let (out, stats) = q.run_stats(Vec::<u32>::new(), |x| x);
        assert!(out.is_empty());
        assert!(stats.executed.is_empty());
        // more workers than tasks: clamped, still correct
        let (out, stats) = q.run_stats(vec![1u32, 2], |x| x * 10);
        assert_eq!(out, vec![10, 20]);
        assert_eq!(stats.executed.len(), 2);
    }

    #[test]
    fn run_source_drains_a_shared_counter() {
        use std::sync::atomic::{AtomicU64, Ordering};
        let q = JobQueue::new(4);
        let next = AtomicU64::new(0);
        let sum = AtomicU64::new(0);
        let executed = q
            .run_source(
                || {
                    let n = next.fetch_add(1, Ordering::Relaxed);
                    Ok(if n < 100 { Some(n) } else { None })
                },
                |n| {
                    sum.fetch_add(n, Ordering::Relaxed);
                    Ok(())
                },
            )
            .unwrap();
        assert_eq!(executed, 100);
        assert_eq!(sum.load(Ordering::Relaxed), (0..100u64).sum());
    }

    #[test]
    fn run_source_propagates_errors() {
        use std::sync::atomic::{AtomicU64, Ordering};
        let q = JobQueue::new(2);
        let next = AtomicU64::new(0);
        let err = q.run_source(
            || {
                let n = next.fetch_add(1, Ordering::Relaxed);
                Ok(if n < 8 { Some(n) } else { None })
            },
            |n| {
                if n == 3 {
                    crate::bail!("task {} exploded", n)
                } else {
                    Ok(())
                }
            },
        );
        assert!(err.is_err());
        assert!(format!("{:#}", err.unwrap_err()).contains("exploded"));
    }

    #[test]
    fn zero_requested_workers_clamps_to_one() {
        let q = JobQueue::new(0);
        assert_eq!(q.workers(), 1);
        assert_eq!(q.run(vec![5u8], |x| x), vec![5]);
    }
}
