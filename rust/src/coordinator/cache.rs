//! Content-addressed, persistent tuning-result cache.
//!
//! Entries are keyed by [`crate::util::hash::hash_bytes`] of a job's
//! canonical description string — (model, platform config,
//! property/method), see [`super::job::TuningJob::cache_desc`] — and
//! store the tuned optimum. The cache persists as JSON through
//! [`crate::util::manifest::Json`], so repeated or overlapping batch jobs
//! (and repeated `mcautotune batch` / `tune --cache` invocations) skip
//! verification entirely: a hit reports zero states explored.
//!
//! Hash collisions cannot poison results: a stored entry only counts as a
//! hit when its full description string matches the lookup's.
//!
//! `engine: promela` jobs embed a content hash of their Promela source in
//! the description (`pml=<16 hex>`, see `TuningJob::cache_desc`), so an
//! edited model never hits the entry its previous revision stored — the
//! stale entry simply becomes unreachable and ages out of use.
//!
//! A corrupt or truncated cache file (disk trouble, an interrupted
//! legacy writer) never aborts the batch that opens it:
//! [`ResultCache::open`] quarantines the unreadable file as
//! `<file>.corrupt` and rebuilds from empty. A cleanly parsed file with
//! an unsupported `version` stays a hard error — it belongs to a newer
//! binary, not to the garbage pile.

use crate::tuner::{CachedTune, Method, Observation, TuneCache, TuneResult};
use crate::util::error::{bail, Context, Result};
use crate::util::hash::{hash_bytes, FxHashMap};
use crate::util::manifest::{write_atomic, Json};
use std::path::{Path, PathBuf};

/// One persisted tuning result.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CacheEntry {
    /// canonical job description — the preimage of the content address
    pub desc: String,
    pub wg: u32,
    pub ts: u32,
    pub t_min: i64,
    /// transitions on the original witnessing trail
    pub steps: usize,
    /// search method of the original run ("exhaustive" | "swarm")
    pub method: String,
    /// states explored by the original cold run (reporting only: the
    /// verification work one hit saves)
    pub cold_states: u64,
    /// peak store footprint of the original cold run, in bytes
    /// (telemetry; 0 on entries written by pre-telemetry binaries)
    pub cold_peak_bytes: u64,
    /// wall time of the original cold run, in milliseconds (telemetry;
    /// 0 on entries written by pre-telemetry binaries)
    pub cold_wall_ms: u64,
}

/// The cache: an in-memory map with optional JSON file backing.
#[derive(Debug, Default)]
pub struct ResultCache {
    path: Option<PathBuf>,
    entries: FxHashMap<u64, CacheEntry>,
    /// lookup hits since this cache was opened
    pub hits: u64,
    /// lookup misses since this cache was opened
    pub misses: u64,
    /// where a corrupt backing file was moved, if [`open`](Self::open)
    /// had to quarantine one
    quarantined: Option<PathBuf>,
}

impl ResultCache {
    /// A cache with no file backing ([`save`](Self::save) is a no-op).
    pub fn in_memory() -> Self {
        Self::default()
    }

    /// Open a persistent cache; a missing file is an empty cache.
    ///
    /// A corrupt or truncated backing file must not abort the batch that
    /// opens it: the unreadable file is **quarantined** — renamed to
    /// `<file>.corrupt`, preserving the bytes for inspection — and the
    /// cache starts empty and rebuilds on the next [`save`](Self::save).
    /// [`quarantined`](Self::quarantined) reports the quarantine path so
    /// callers can warn. Two failure classes deliberately stay hard
    /// errors: I/O problems (permissions, unreadable directory — the
    /// cache would be unusable for write-back too), and a cleanly parsed
    /// file with an **unsupported version** — worker mode shares cache
    /// files across machines, and an old binary must not destroy a newer
    /// binary's perfectly valid cache by "quarantining" it.
    pub fn open(path: &Path) -> Result<Self> {
        let mut cache = Self { path: Some(path.to_path_buf()), ..Self::default() };
        if path.exists() {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading result cache {}", path.display()))?;
            if let Err(parse_err) = cache.load_json(&text) {
                cache.entries.clear(); // drop any partially loaded entries
                let future_version = Json::parse(&text).ok().is_some_and(|doc| {
                    doc.get("version").and_then(Json::as_i64).is_some_and(|v| v != 1)
                });
                if future_version {
                    return Err(parse_err)
                        .with_context(|| format!("result cache {}", path.display()));
                }
                let quarantine = PathBuf::from(format!("{}.corrupt", path.display()));
                match std::fs::rename(path, &quarantine) {
                    Ok(()) => {}
                    // a concurrent opener of the same shared cache won the
                    // quarantine race; the file is already moved aside
                    Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
                    Err(e) => {
                        return Err(e).with_context(|| {
                            format!(
                                "quarantining corrupt result cache {} (unreadable: {:#})",
                                path.display(),
                                parse_err
                            )
                        })
                    }
                }
                cache.quarantined = Some(quarantine);
            }
        }
        Ok(cache)
    }

    pub fn path(&self) -> Option<&Path> {
        self.path.as_deref()
    }

    /// Where [`open`](Self::open) moved a corrupt backing file, if it had
    /// to quarantine one.
    pub fn quarantined(&self) -> Option<&Path> {
        self.quarantined.as_deref()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Iterate entries in unspecified order.
    pub fn entries(&self) -> impl Iterator<Item = &CacheEntry> {
        self.entries.values()
    }

    /// Entries sorted by description — the order [`to_json`](Self::to_json)
    /// persists and `mcautotune cache ls` lists.
    pub fn entries_sorted(&self) -> Vec<&CacheEntry> {
        let mut entries: Vec<&CacheEntry> = self.entries.values().collect();
        entries.sort_by(|a, b| a.desc.cmp(&b.desc));
        entries
    }

    /// Record one surrogate-training observation as an ordinary cache
    /// entry (`method="obs"`, desc `obs size=.. wg=.. ts=.. family=..`).
    /// Observations never collide with job results — `lookup` requires
    /// a full-description match — and re-recording the same coordinates
    /// keeps the *best* (lowest) observed time, so a poisoned high value
    /// is displaced by any real measurement. `family` is the job's
    /// size-independent identity ([`super::job::TuningJob::obs_family`]):
    /// all sizes of one (model, platform) share a family, which is what
    /// makes cross-size neighbor warm-starts possible.
    pub fn record_observation(&mut self, family: &str, o: Observation) {
        let desc = format!("obs size={} wg={} ts={} family={}", o.size, o.wg, o.ts, family);
        let key = hash_bytes(desc.as_bytes());
        match self.entries.get_mut(&key) {
            Some(e) if e.desc == desc => e.t_min = e.t_min.min(o.time),
            Some(_) => {} // hash collision with a foreign entry: keep it
            None => {
                self.entries.insert(
                    key,
                    CacheEntry {
                        desc,
                        wg: o.wg,
                        ts: o.ts,
                        t_min: o.time,
                        steps: 0,
                        method: "obs".into(),
                        cold_states: 0,
                        cold_peak_bytes: 0,
                        cold_wall_ms: 0,
                    },
                );
            }
        }
    }

    /// Scan the observations of one family — **every** input size, so a
    /// job at a new size (or on a new platform sharing the family) warm-
    /// starts from its cached near-neighbors. Sorted by (size, wg, ts)
    /// for deterministic downstream predictions.
    pub fn observations(&self, family: &str) -> Vec<Observation> {
        let suffix = format!(" family={}", family);
        let mut out: Vec<Observation> = self
            .entries
            .values()
            .filter(|e| e.method == "obs" && e.desc.starts_with("obs ") && e.desc.ends_with(&suffix))
            .filter_map(|e| {
                let size = e
                    .desc
                    .split_whitespace()
                    .find_map(|tok| tok.strip_prefix("size=")?.parse::<u32>().ok())?;
                Some(Observation { wg: e.wg, ts: e.ts, size, time: e.t_min })
            })
            .collect();
        out.sort_by_key(|o| (o.size, o.wg, o.ts, o.time));
        out
    }

    /// Number of observation rows (vs. [`len`](Self::len) total entries)
    /// — the `cache ls` column that tells a user whether a surrogate run
    /// will warm-start.
    pub fn observation_count(&self) -> usize {
        self.entries.values().filter(|e| e.method == "obs").count()
    }

    /// Age of the backing file in whole seconds (mtime-based — entries
    /// deliberately carry no wall-clock timestamps, so cache files stay
    /// byte-identical across equivalent runs). `None` for in-memory
    /// caches or files that do not exist yet.
    pub fn age_secs(&self) -> Option<u64> {
        let meta = std::fs::metadata(self.path.as_deref()?).ok()?;
        let mtime = meta.modified().ok()?;
        Some(mtime.elapsed().map_or(0, |d| d.as_secs()))
    }

    /// Drop every entry whose description contains `needle`, or whose
    /// 16-hex-digit content key equals it (`mcautotune cache rm`). Returns
    /// the number removed; the caller persists with [`save`](Self::save).
    pub fn remove_matching(&mut self, needle: &str) -> usize {
        let before = self.entries.len();
        self.entries
            .retain(|key, e| !(e.desc.contains(needle) || format!("{:016x}", key) == needle));
        before - self.entries.len()
    }

    fn load_json(&mut self, text: &str) -> Result<()> {
        let doc = Json::parse(text)?;
        let version = doc.get("version").and_then(Json::as_i64).context("missing version")?;
        if version != 1 {
            bail!("unsupported result-cache version {}", version);
        }
        let entries = doc.get("entries").and_then(Json::as_arr).context("missing entries")?;
        for e in entries {
            let string = |key: &str| -> Result<String> {
                e.get(key)
                    .and_then(Json::as_str)
                    .map(str::to_string)
                    .with_context(|| format!("entry missing string field `{}`", key))
            };
            let int = |key: &str| -> Result<i64> {
                e.get(key)
                    .and_then(Json::as_i64)
                    .with_context(|| format!("entry missing integer field `{}`", key))
            };
            // telemetry fields are *optional*: entries written by
            // pre-telemetry binaries (same version 1) simply lack them
            let opt_u64 =
                |key: &str| e.get(key).and_then(Json::as_i64).map_or(0, |v| v.max(0) as u64);
            let entry = CacheEntry {
                desc: string("desc")?,
                wg: int("wg")? as u32,
                ts: int("ts")? as u32,
                t_min: int("t_min")?,
                steps: int("steps")? as usize,
                method: string("method")?,
                cold_states: int("cold_states")? as u64,
                cold_peak_bytes: opt_u64("cold_peak_bytes"),
                cold_wall_ms: opt_u64("cold_wall_ms"),
            };
            self.entries.insert(hash_bytes(entry.desc.as_bytes()), entry);
        }
        Ok(())
    }

    /// Serialize to the persisted JSON form (entries in
    /// [`entries_sorted`](Self::entries_sorted) order, so files are
    /// deterministic and diff-friendly).
    pub fn to_json(&self) -> String {
        let entries = self
            .entries_sorted()
            .into_iter()
            .map(|e| {
                Json::Obj(vec![
                    ("key".into(), Json::Str(format!("{:016x}", hash_bytes(e.desc.as_bytes())))),
                    ("desc".into(), Json::Str(e.desc.clone())),
                    ("wg".into(), Json::Int(e.wg as i64)),
                    ("ts".into(), Json::Int(e.ts as i64)),
                    ("t_min".into(), Json::Int(e.t_min)),
                    ("steps".into(), Json::Int(e.steps as i64)),
                    ("method".into(), Json::Str(e.method.clone())),
                    ("cold_states".into(), Json::Int(e.cold_states as i64)),
                    ("cold_peak_bytes".into(), Json::Int(e.cold_peak_bytes.min(i64::MAX as u64) as i64)),
                    ("cold_wall_ms".into(), Json::Int(e.cold_wall_ms.min(i64::MAX as u64) as i64)),
                ])
            })
            .collect();
        Json::Obj(vec![
            ("version".into(), Json::Int(1)),
            ("entries".into(), Json::Arr(entries)),
        ])
        .render()
    }

    /// Write back to the backing file (no-op for in-memory caches).
    ///
    /// The write is atomic (temp file + rename): worker mode makes cache
    /// files *shared* — the merge step and concurrent `tune --cache`
    /// runs may open one mid-save — and a reader must never observe a
    /// half-written file (it would quarantine a perfectly healthy cache).
    pub fn save(&self) -> Result<()> {
        if let Some(path) = &self.path {
            // chaos site: persistence failing after a whole batch ran —
            // callers must degrade (report warning), not abort
            crate::util::failpoint::hit("cache.save")?;
            write_atomic(path, &self.to_json())
                .with_context(|| format!("saving result cache {}", path.display()))?;
        }
        Ok(())
    }
}

impl TuneCache for ResultCache {
    fn lookup(&mut self, desc: &str) -> Option<CachedTune> {
        let key = hash_bytes(desc.as_bytes());
        match self.entries.get(&key) {
            Some(e) if e.desc == desc => {
                self.hits += 1;
                crate::obs::metrics().cache_hits.add(1);
                Some(CachedTune { wg: e.wg, ts: e.ts, t_min: e.t_min, steps: e.steps })
            }
            _ => {
                self.misses += 1;
                crate::obs::metrics().cache_misses.add(1);
                None
            }
        }
    }

    fn store(&mut self, desc: &str, result: &TuneResult) {
        let entry = CacheEntry {
            desc: desc.to_string(),
            wg: result.optimal.wg,
            ts: result.optimal.ts,
            t_min: result.t_min,
            steps: result.optimal.steps,
            method: match result.method {
                Method::Exhaustive => "exhaustive",
                Method::Swarm => "swarm",
            }
            .to_string(),
            cold_states: result.states_explored,
            cold_peak_bytes: result.peak_bytes,
            cold_wall_ms: result.elapsed.as_millis().min(u64::MAX as u128) as u64,
        };
        self.entries.insert(hash_bytes(desc.as_bytes()), entry);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tuner::{cached_result, Method};

    fn fake_result(wg: u32, ts: u32, t_min: i64) -> TuneResult {
        cached_result(
            Method::Exhaustive,
            CachedTune { wg, ts, t_min, steps: 9 },
            "synthetic",
        )
    }

    fn temp_file(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("mcat_cache_{}_{}.json", tag, std::process::id()))
    }

    #[test]
    fn store_then_lookup_hits() {
        let mut c = ResultCache::in_memory();
        assert!(c.is_empty());
        assert!(c.lookup("job-a").is_none());
        c.store("job-a", &fake_result(4, 2, 44));
        let hit = c.lookup("job-a").unwrap();
        assert_eq!((hit.wg, hit.ts, hit.t_min, hit.steps), (4, 2, 44, 9));
        assert_eq!((c.hits, c.misses), (1, 1));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn json_roundtrip_via_file() {
        let path = temp_file("roundtrip");
        std::fs::remove_file(&path).ok();
        {
            let mut c = ResultCache::open(&path).unwrap();
            c.store("model=minimum size=64", &fake_result(8, 2, 36));
            c.store("model=abstract size=32", &fake_result(4, 4, 528));
            c.save().unwrap();
        }
        let mut c = ResultCache::open(&path).unwrap();
        assert_eq!(c.len(), 2);
        let hit = c.lookup("model=minimum size=64").unwrap();
        assert_eq!((hit.wg, hit.ts, hit.t_min), (8, 2, 36));
        assert!(c.lookup("model=minimum size=128").is_none());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn deterministic_serialization() {
        let mut a = ResultCache::in_memory();
        let mut b = ResultCache::in_memory();
        a.store("x", &fake_result(2, 2, 10));
        a.store("y", &fake_result(4, 4, 20));
        b.store("y", &fake_result(4, 4, 20));
        b.store("x", &fake_result(2, 2, 10));
        assert_eq!(a.to_json(), b.to_json());
    }

    #[test]
    fn corrupt_file_is_quarantined_not_fatal() {
        // regression: a corrupt/truncated cache used to abort the whole
        // batch; it must quarantine and rebuild instead
        let path = temp_file("corrupt");
        let quarantine = PathBuf::from(format!("{}.corrupt", path.display()));
        for bad in [
            "{\"version\":1,\"entries\":[{\"desc\":42}]}", // wrong field type
            "not json",                                    // garbage
            "{\"version\":1,\"entries\":[{\"desc\":\"x\"", // truncated mid-write
        ] {
            std::fs::remove_file(&quarantine).ok();
            std::fs::write(&path, bad).unwrap();
            let c = ResultCache::open(&path).unwrap();
            assert!(c.is_empty(), "no entry may survive a corrupt load: {}", bad);
            assert_eq!(c.quarantined(), Some(quarantine.as_path()));
            assert!(!path.exists(), "the corrupt file must be moved aside");
            let preserved = std::fs::read_to_string(&quarantine).unwrap();
            assert_eq!(preserved, bad, "quarantine preserves the original bytes");
        }
        std::fs::remove_file(&quarantine).ok();
        // a *future-versioned* file is not corruption: it belongs to a
        // newer binary sharing the cache, and must never be destroyed
        std::fs::write(&path, "{\"version\":2,\"entries\":[]}").unwrap();
        assert!(ResultCache::open(&path).is_err());
        assert!(path.exists(), "a future-versioned cache must stay in place");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn quarantined_cache_rebuilds_on_save() {
        let path = temp_file("rebuild");
        std::fs::write(&path, "truncated{").unwrap();
        {
            let mut c = ResultCache::open(&path).unwrap();
            assert!(c.quarantined().is_some());
            assert!(c.lookup("model=minimum size=64").is_none());
            c.store("model=minimum size=64", &fake_result(8, 2, 36));
            c.save().unwrap();
        }
        // the rebuilt file parses cleanly and serves the entry
        let mut c = ResultCache::open(&path).unwrap();
        assert!(c.quarantined().is_none());
        assert_eq!(c.len(), 1);
        assert!(c.lookup("model=minimum size=64").is_some());
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(format!("{}.corrupt", path.display())).ok();
    }

    #[test]
    fn remove_matching_by_desc_substring_and_key() {
        let mut c = ResultCache::in_memory();
        c.store("model=minimum size=64", &fake_result(8, 2, 36));
        c.store("model=minimum size=128", &fake_result(8, 4, 40));
        c.store("model=abstract size=32", &fake_result(4, 4, 528));
        assert_eq!(c.remove_matching("nosuch"), 0);
        assert_eq!(c.remove_matching("model=minimum"), 2);
        assert_eq!(c.len(), 1);
        // removal by exact content key
        let key = format!("{:016x}", hash_bytes(b"model=abstract size=32"));
        assert_eq!(c.remove_matching(&key), 1);
        assert!(c.is_empty());
    }

    #[test]
    fn entries_sorted_matches_persisted_order() {
        let mut c = ResultCache::in_memory();
        c.store("b", &fake_result(2, 2, 1));
        c.store("a", &fake_result(2, 2, 1));
        let descs: Vec<&str> = c.entries_sorted().iter().map(|e| e.desc.as_str()).collect();
        assert_eq!(descs, vec!["a", "b"]);
    }

    #[test]
    fn observations_roundtrip_and_keep_the_best_time() {
        use crate::tuner::Observation;
        let mut c = ResultCache::in_memory();
        let fam = "model=minimum nd=16 nu=4 np=4 gmt=3 gran=phase";
        c.record_observation(fam, Observation { wg: 8, ts: 2, size: 64, time: 40 });
        c.record_observation(fam, Observation { wg: 2, ts: 2, size: 64, time: 80 });
        c.record_observation(fam, Observation { wg: 8, ts: 2, size: 32, time: 22 });
        // re-recording keeps the minimum, ignores a worse measurement
        c.record_observation(fam, Observation { wg: 8, ts: 2, size: 64, time: 36 });
        c.record_observation(fam, Observation { wg: 8, ts: 2, size: 64, time: 99 });
        let obs = c.observations(fam);
        assert_eq!(obs.len(), 3);
        assert_eq!(obs[0], Observation { wg: 8, ts: 2, size: 32, time: 22 }, "sorted by size");
        assert!(obs.contains(&Observation { wg: 8, ts: 2, size: 64, time: 36 }));
        assert_eq!(c.observation_count(), 3);
        // a different family sees nothing
        assert!(c.observations("model=abstract nd=16").is_empty());
        // observation rows never satisfy a job-result lookup...
        assert!(c.lookup("model=minimum size=64").is_none());
        // ...and job results never leak into observation scans
        c.store("model=minimum size=64", &fake_result(8, 2, 36));
        assert_eq!(c.observations(fam).len(), 3);
        assert_eq!(c.len(), 4);
    }

    #[test]
    fn observations_persist_through_the_json_file() {
        use crate::tuner::Observation;
        let path = temp_file("obs");
        std::fs::remove_file(&path).ok();
        let fam = "pml=00000000deadbeef";
        {
            let mut c = ResultCache::open(&path).unwrap();
            c.record_observation(fam, Observation { wg: 4, ts: 4, size: 128, time: 500 });
            c.save().unwrap();
        }
        let c = ResultCache::open(&path).unwrap();
        assert_eq!(c.observations(fam), vec![Observation { wg: 4, ts: 4, size: 128, time: 500 }]);
        assert_eq!(c.observation_count(), 1);
        assert!(c.age_secs().is_some(), "file-backed caches report an mtime age");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn in_memory_cache_has_no_age() {
        assert!(ResultCache::in_memory().age_secs().is_none());
    }

    #[test]
    fn in_memory_save_is_noop() {
        let mut c = ResultCache::in_memory();
        c.store("k", &fake_result(2, 2, 5));
        c.save().unwrap();
        assert!(c.path().is_none());
        assert_eq!(c.entries().count(), 1);
    }
}
