//! Batch-level aggregation: per-job outcomes and the rendered summary
//! table the `mcautotune batch` subcommand prints.

use super::job::TuningJob;
use super::shard::ShardPlan;
use crate::report::Table;
use crate::tuner::{Method, TuneResult};
use crate::util::fmt::{human_bytes, human_duration, thousands};
use std::time::Duration;

/// The outcome of one job in a batch.
#[derive(Debug)]
pub struct JobOutcome {
    pub job: TuningJob,
    pub result: TuneResult,
    /// true when the result was served from the cache (including
    /// within-batch deduplication of overlapping jobs)
    pub cached: bool,
    /// shards the job was split into (0 for cached jobs: nothing ran)
    pub shards: u32,
    /// job wall-clock inside the queue (max over its shards; ~0 cached)
    pub wall: Duration,
    /// the per-shard budget plan the job ran under, in lattice order
    /// (empty for cached jobs: nothing ran) — budgets scale with each
    /// sub-lattice's estimated state-space weight, see
    /// [`super::shard::plan_shards`]
    pub plan: Vec<ShardPlan>,
    /// states each shard actually explored, parallel to [`plan`](Self::plan)
    /// — the telemetry that grades the planner's weight estimates
    pub shard_states: Vec<u64>,
    /// true when not every planned shard contributed (partial merge):
    /// the optimum is a *lower bound* on tuning quality — a missing
    /// sub-lattice may hold a better tuning — and the result was not
    /// written to the cache
    pub lower_bound: bool,
}

/// One dead-lettered task as reported by a partial merge: a task that
/// exhausted its attempt budget and was moved to `dead/<id>.json` so
/// the rest of the batch could finish without it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DeadTaskInfo {
    /// task id (`j###-s###`)
    pub id: String,
    /// name of the job the task belonged to
    pub job: String,
    pub job_index: usize,
    /// failed attempts charged when it was dead-lettered
    pub attempts: u32,
    /// the captured failure from the final attempt
    pub error: String,
}

/// Aggregate of one [`super::run_batch`] call.
#[derive(Debug)]
pub struct BatchReport {
    /// one outcome per submitted job, in submission order (a partial
    /// merge drops jobs with no completed shard at all)
    pub outcomes: Vec<JobOutcome>,
    /// cache hits among this batch's lookups
    pub cache_hits: u64,
    /// cache misses among this batch's lookups
    pub cache_misses: u64,
    /// tasks the queue moved between workers
    pub stolen_tasks: u64,
    /// whole-batch wall clock
    pub total_elapsed: Duration,
    /// true when produced by `merge --partial`: outcomes may be missing
    /// or lower bounds, and `dead_tasks`/`pending_tasks` say why
    pub partial: bool,
    /// tasks with neither a result nor a dead-letter record (still
    /// running, or waiting for a worker)
    pub pending_tasks: usize,
    /// tasks dead-lettered after exhausting their attempt budget
    pub dead_tasks: Vec<DeadTaskInfo>,
    /// the result cache could not be persisted (results above are still
    /// valid; the warning is surfaced instead of aborting the batch)
    pub cache_save_error: Option<String>,
}

/// Integer percentage of `part` in `total` (0 when `total` is 0).
fn share_pct(part: u64, total: u64) -> u64 {
    if total == 0 {
        0
    } else {
        part.saturating_mul(100) / total
    }
}

impl BatchReport {
    /// States explored across the whole batch (cached jobs contribute 0).
    pub fn total_states(&self) -> u64 {
        self.outcomes.iter().map(|o| o.result.states_explored).sum()
    }

    /// ASCII table of per-job optima plus a cache/queue summary line.
    pub fn render(&self) -> String {
        let mut table = Table::new(vec![
            "N", "Job", "Model", "Engine", "Size", "Method", "Shards", "WG", "TS",
            "Model time", "States", "Cache", "Time",
        ]);
        for (i, o) in self.outcomes.iter().enumerate() {
            table.row(vec![
                (i + 1).to_string(),
                o.job.name.clone(),
                o.job.model.to_string(),
                o.job.engine.to_string(),
                o.job.size.to_string(),
                match o.result.method {
                    Method::Exhaustive => "exhaustive".to_string(),
                    Method::Swarm => "swarm".to_string(),
                },
                o.shards.to_string(),
                o.result.optimal.wg.to_string(),
                o.result.optimal.ts.to_string(),
                if o.lower_bound {
                    // not every shard contributed: the optimum is only a
                    // bound, flagged in the table and footnoted below
                    format!("{}*", o.result.t_min)
                } else {
                    o.result.t_min.to_string()
                },
                thousands(o.result.states_explored),
                if o.cached { "hit".to_string() } else { "miss".to_string() },
                human_duration(o.wall),
            ]);
        }
        let mut out = table.render();
        // shard-aware budget plans: weight = estimated sub-lattice
        // state-space size; budgets are the job budget scaled by weight
        for o in &self.outcomes {
            if o.plan.len() < 2 {
                continue; // single-shard and cached jobs have no split to show
            }
            out.push_str(&format!(
                "shard budgets `{}` (~ estimated sub-lattice size):\n",
                o.job.name
            ));
            let est_total: u64 = o.plan.iter().map(|p| p.weight).sum();
            let act_total: u64 = o.shard_states.iter().sum();
            for (si, p) in o.plan.iter().enumerate() {
                out.push_str(&format!(
                    "  {}: weight {}, max_states {}, memory {}, time {}",
                    p.shard,
                    thousands(p.weight),
                    if p.check.max_states == u64::MAX {
                        "unlimited".to_string()
                    } else {
                        thousands(p.check.max_states)
                    },
                    human_bytes(p.check.memory_budget),
                    p.check.time_budget.map_or("unlimited".to_string(), human_duration),
                ));
                // telemetry column: planned vs. actual share of the job's
                // states — how far the weight estimate missed this shard
                if let Some(&states) = o.shard_states.get(si) {
                    out.push_str(&format!(
                        ", states {} ({}% est {}%)",
                        thousands(states),
                        share_pct(states, act_total),
                        share_pct(p.weight, est_total),
                    ));
                }
                out.push('\n');
            }
        }
        if self.outcomes.iter().any(|o| o.lower_bound) {
            out.push_str(
                "* model time is a lower bound: not every parameter-space shard \
                 completed, and the result was not cached\n",
            );
        }
        if !self.dead_tasks.is_empty() {
            out.push_str("dead-lettered task(s):\n");
            for d in &self.dead_tasks {
                out.push_str(&format!(
                    "  {} (job `{}`): gave up after {} attempt(s) — {}\n",
                    d.id, d.job, d.attempts, d.error
                ));
            }
        }
        if let Some(e) = &self.cache_save_error {
            out.push_str(&format!("warning: result cache not saved: {}\n", e));
        }
        out.push_str(&format!(
            "cache: {} hit(s), {} miss(es) | {} states explored | {} task(s) stolen | wall {}{}\n",
            self.cache_hits,
            self.cache_misses,
            thousands(self.total_states()),
            self.stolen_tasks,
            human_duration(self.total_elapsed),
            if self.partial {
                format!(
                    " | PARTIAL ({} dead, {} pending)",
                    self.dead_tasks.len(),
                    self.pending_tasks
                )
            } else {
                String::new()
            },
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::ModelKind;
    use crate::tuner::{cached_result, CachedTune};

    #[test]
    fn render_lists_jobs_and_summary() {
        let job = TuningJob::new(ModelKind::Minimum, 64);
        let result =
            cached_result(Method::Exhaustive, CachedTune { wg: 4, ts: 2, t_min: 44, steps: 7 }, "d");
        let rep = BatchReport {
            outcomes: vec![JobOutcome {
                job,
                result,
                cached: true,
                shards: 0,
                wall: Duration::ZERO,
                plan: Vec::new(),
                shard_states: Vec::new(),
                lower_bound: false,
            }],
            cache_hits: 1,
            cache_misses: 0,
            stolen_tasks: 0,
            total_elapsed: Duration::from_millis(5),
            partial: false,
            pending_tasks: 0,
            dead_tasks: Vec::new(),
            cache_save_error: None,
        };
        let text = rep.render();
        assert!(text.contains("minimum-64"));
        assert!(text.contains("hit"));
        assert!(text.contains("1 hit(s), 0 miss(es)"));
        assert!(!text.contains("PARTIAL"));
        assert!(!text.contains("dead-lettered"));
        assert_eq!(rep.total_states(), 0);
    }

    #[test]
    fn render_flags_partial_dead_and_cache_warning() {
        let job = TuningJob::new(ModelKind::Minimum, 64);
        let result =
            cached_result(Method::Exhaustive, CachedTune { wg: 4, ts: 2, t_min: 44, steps: 7 }, "d");
        let rep = BatchReport {
            outcomes: vec![JobOutcome {
                job,
                result,
                cached: false,
                shards: 3,
                wall: Duration::from_millis(2),
                plan: Vec::new(),
                shard_states: Vec::new(),
                lower_bound: true,
            }],
            cache_hits: 0,
            cache_misses: 1,
            stolen_tasks: 0,
            total_elapsed: Duration::from_millis(5),
            partial: true,
            pending_tasks: 1,
            dead_tasks: vec![DeadTaskInfo {
                id: "j001-s002".into(),
                job: "minimum-128".into(),
                job_index: 1,
                attempts: 3,
                error: "task panicked: boom".into(),
            }],
            cache_save_error: Some("disk full".into()),
        };
        let text = rep.render();
        assert!(text.contains("44*"), "lower-bound optimum is starred: {}", text);
        assert!(text.contains("lower bound"));
        assert!(text.contains("dead-lettered task(s):"));
        assert!(text.contains("j001-s002"));
        assert!(text.contains("gave up after 3 attempt(s)"));
        assert!(text.contains("task panicked: boom"));
        assert!(text.contains("warning: result cache not saved: disk full"));
        assert!(text.contains("PARTIAL (1 dead, 1 pending)"));
    }
}
