//! Batch-level aggregation: per-job outcomes and the rendered summary
//! table the `mcautotune batch` subcommand prints.

use super::job::TuningJob;
use super::shard::ShardPlan;
use crate::report::Table;
use crate::tuner::{Method, TuneResult};
use crate::util::fmt::{human_bytes, human_duration, thousands};
use std::time::Duration;

/// The outcome of one job in a batch.
#[derive(Debug)]
pub struct JobOutcome {
    pub job: TuningJob,
    pub result: TuneResult,
    /// true when the result was served from the cache (including
    /// within-batch deduplication of overlapping jobs)
    pub cached: bool,
    /// shards the job was split into (0 for cached jobs: nothing ran)
    pub shards: u32,
    /// job wall-clock inside the queue (max over its shards; ~0 cached)
    pub wall: Duration,
    /// the per-shard budget plan the job ran under, in lattice order
    /// (empty for cached jobs: nothing ran) — budgets scale with each
    /// sub-lattice's estimated state-space weight, see
    /// [`super::shard::plan_shards`]
    pub plan: Vec<ShardPlan>,
    /// states each shard actually explored, parallel to [`plan`](Self::plan)
    /// — the telemetry that grades the planner's weight estimates
    pub shard_states: Vec<u64>,
}

/// Aggregate of one [`super::run_batch`] call.
#[derive(Debug)]
pub struct BatchReport {
    /// one outcome per submitted job, in submission order
    pub outcomes: Vec<JobOutcome>,
    /// cache hits among this batch's lookups
    pub cache_hits: u64,
    /// cache misses among this batch's lookups
    pub cache_misses: u64,
    /// tasks the queue moved between workers
    pub stolen_tasks: u64,
    /// whole-batch wall clock
    pub total_elapsed: Duration,
}

/// Integer percentage of `part` in `total` (0 when `total` is 0).
fn share_pct(part: u64, total: u64) -> u64 {
    if total == 0 {
        0
    } else {
        part.saturating_mul(100) / total
    }
}

impl BatchReport {
    /// States explored across the whole batch (cached jobs contribute 0).
    pub fn total_states(&self) -> u64 {
        self.outcomes.iter().map(|o| o.result.states_explored).sum()
    }

    /// ASCII table of per-job optima plus a cache/queue summary line.
    pub fn render(&self) -> String {
        let mut table = Table::new(vec![
            "N", "Job", "Model", "Engine", "Size", "Method", "Shards", "WG", "TS",
            "Model time", "States", "Cache", "Time",
        ]);
        for (i, o) in self.outcomes.iter().enumerate() {
            table.row(vec![
                (i + 1).to_string(),
                o.job.name.clone(),
                o.job.model.to_string(),
                o.job.engine.to_string(),
                o.job.size.to_string(),
                match o.result.method {
                    Method::Exhaustive => "exhaustive".to_string(),
                    Method::Swarm => "swarm".to_string(),
                },
                o.shards.to_string(),
                o.result.optimal.wg.to_string(),
                o.result.optimal.ts.to_string(),
                o.result.t_min.to_string(),
                thousands(o.result.states_explored),
                if o.cached { "hit".to_string() } else { "miss".to_string() },
                human_duration(o.wall),
            ]);
        }
        let mut out = table.render();
        // shard-aware budget plans: weight = estimated sub-lattice
        // state-space size; budgets are the job budget scaled by weight
        for o in &self.outcomes {
            if o.plan.len() < 2 {
                continue; // single-shard and cached jobs have no split to show
            }
            out.push_str(&format!(
                "shard budgets `{}` (~ estimated sub-lattice size):\n",
                o.job.name
            ));
            let est_total: u64 = o.plan.iter().map(|p| p.weight).sum();
            let act_total: u64 = o.shard_states.iter().sum();
            for (si, p) in o.plan.iter().enumerate() {
                out.push_str(&format!(
                    "  {}: weight {}, max_states {}, memory {}, time {}",
                    p.shard,
                    thousands(p.weight),
                    if p.check.max_states == u64::MAX {
                        "unlimited".to_string()
                    } else {
                        thousands(p.check.max_states)
                    },
                    human_bytes(p.check.memory_budget),
                    p.check.time_budget.map_or("unlimited".to_string(), human_duration),
                ));
                // telemetry column: planned vs. actual share of the job's
                // states — how far the weight estimate missed this shard
                if let Some(&states) = o.shard_states.get(si) {
                    out.push_str(&format!(
                        ", states {} ({}% est {}%)",
                        thousands(states),
                        share_pct(states, act_total),
                        share_pct(p.weight, est_total),
                    ));
                }
                out.push('\n');
            }
        }
        out.push_str(&format!(
            "cache: {} hit(s), {} miss(es) | {} states explored | {} task(s) stolen | wall {}\n",
            self.cache_hits,
            self.cache_misses,
            thousands(self.total_states()),
            self.stolen_tasks,
            human_duration(self.total_elapsed),
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::ModelKind;
    use crate::tuner::{cached_result, CachedTune};

    #[test]
    fn render_lists_jobs_and_summary() {
        let job = TuningJob::new(ModelKind::Minimum, 64);
        let result =
            cached_result(Method::Exhaustive, CachedTune { wg: 4, ts: 2, t_min: 44, steps: 7 }, "d");
        let rep = BatchReport {
            outcomes: vec![JobOutcome {
                job,
                result,
                cached: true,
                shards: 0,
                wall: Duration::ZERO,
                plan: Vec::new(),
                shard_states: Vec::new(),
            }],
            cache_hits: 1,
            cache_misses: 0,
            stolen_tasks: 0,
            total_elapsed: Duration::from_millis(5),
        };
        let text = rep.render();
        assert!(text.contains("minimum-64"));
        assert!(text.contains("hit"));
        assert!(text.contains("1 hit(s), 0 miss(es)"));
        assert_eq!(rep.total_states(), 0);
    }
}
