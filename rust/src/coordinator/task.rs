//! Worker mode — the durable cross-process task protocol.
//!
//! [`super::run_batch`] drains a batch inside one process. This module
//! turns the same three phases into a protocol over a shared directory so
//! any number of processes (or machines sharing the filesystem) can drain
//! one batch:
//!
//! 1. **Plan** ([`TaskDir::plan`], CLI `mcautotune batch --task-dir`):
//!    phase 1 runs once in the planning process; every remaining
//!    (job, shard) task — engine, inlined source, sub-lattice bounds, and
//!    its [`ShardPlan`] budget slice — is serialized as a JSON
//!    [`TaskSpec`] manifest (`<id>.task.json`), and `batch.json` records
//!    the job list, cache descriptions, plan-time cache hits and the
//!    authoritative task-id list. `batch.json` is written last (via
//!    atomic rename), so its presence guarantees every manifest is in
//!    place.
//! 2. **Lease + execute** ([`TaskDir::lease`] / [`TaskDir::drain`], CLI
//!    `mcautotune worker`): a worker claims a task by atomically renaming
//!    `<id>.task.json` to `<id>.lease.json` — exactly one process wins the
//!    rename — then freshens the lease's mtime (the TTL clock starts at
//!    lease time) and heartbeats it while the task runs. A lease whose
//!    mtime is older than the TTL is presumed crashed and re-leased: any
//!    worker may rename it back to `<id>.task.json` (again one winner) and
//!    claim it. Completed tasks publish `<id>.result.json` via
//!    write-to-temp + rename.
//! 3. **Merge** ([`TaskDir::merge`], CLI `mcautotune merge`): once every
//!    task has a result, any process folds the partials through
//!    [`super::merge_results`] — in plan order, so shard log tags and
//!    first-trail tie-breaks are reproduced — into the same
//!    [`BatchReport`] and [`ResultCache`] entries a single-process
//!    [`super::run_batch`] of the same spec produces. The planning process
//!    runs this implicitly when it observes all tasks complete.
//!
//! Leases are a *liveness* mechanism, not a correctness one: if a slow
//! worker is mistaken for a crashed one (mtime race, heartbeat stall),
//! two workers may execute the same task. That is benign — task execution
//! is deterministic (the plan pins `t_ini`, budgets and the exploration
//! order; multi-threaded plans are upgraded to the deterministic frontier
//! at plan time, see [`TaskDir::plan`]), both compute the same result,
//! and the atomic result rename makes the publication last-writer-wins
//! with identical content. The one exception is `method=swarm` jobs,
//! whose results are wall-clock-budgeted — duplicate executions of a
//! swarm shard may publish different (all individually valid) bests.
//! The planner's TTL is recorded in `batch.json` and adopted by workers
//! that do not override it, so one fleet shares one staleness clock. The
//! differential conformance suite (`rust/tests/batch_distributed.rs`)
//! pins multi-process drains — including crash-and-re-lease schedules —
//! to the single-process engine.

use super::{
    finish_batch, plan_batch, run_shard_task_traced, BatchOptions, BatchReport, DeadTaskInfo,
    JobEngine, JobOutcome, JobQueue, ModelKind, ResultCache, ShardPlan, TuningJob, TuningShard,
};
use crate::checker::{CheckOptions, Compression, Frontier, Order, StoreKind};
use crate::platform::{Granularity, PlatformConfig};
use crate::swarm::SwarmConfig;
use crate::tuner::{Method, Observation, SearchMode, TuneResult, TuningWitness};
use crate::util::error::{anyhow, bail, ensure, Context, Error, Result};
use crate::util::manifest::Json;
use std::collections::HashSet;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::time::{Duration, Instant, SystemTime, UNIX_EPOCH};

const HEADER: &str = "batch.json";
const TASK_SUFFIX: &str = ".task.json";
const LEASE_SUFFIX: &str = ".lease.json";
const RESULT_SUFFIX: &str = ".result.json";
/// Subdirectory holding dead-lettered task manifests (`dead/<id>.json`).
const DEAD_DIR: &str = "dead";
const DEFAULT_TTL: Duration = Duration::from_secs(30);
/// Attempts a task gets before it is dead-lettered as poisoned.
const DEFAULT_MAX_ATTEMPTS: u32 = 3;

/// Exponential re-lease backoff after a failed attempt. The first retry
/// is immediate (one crash or one transient I/O error should not stall
/// recovery), later ones back off exponentially so a task that keeps
/// failing cannot monopolize the fleet while it burns through its
/// attempt budget: 0, 250ms, 500ms, 1s, ... capped at 10s.
fn backoff_ms(attempts: u32) -> u64 {
    if attempts <= 1 {
        0
    } else {
        (250u64 << (attempts - 2).min(16)).min(10_000)
    }
}

// ------------------------------------------------------- serialization --

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// u64 as JSON: an integer when it fits `i64`, a decimal string above
/// (`max_states = u64::MAX` must round-trip losslessly).
fn ju64(v: u64) -> Json {
    if v <= i64::MAX as u64 {
        Json::Int(v as i64)
    } else {
        Json::Str(v.to_string())
    }
}

fn jnanos(d: Duration) -> Json {
    ju64(d.as_nanos().min(u64::MAX as u128) as u64)
}

fn field<'a>(v: &'a Json, key: &str) -> Result<&'a Json> {
    v.get(key).with_context(|| format!("missing field `{}`", key))
}

fn arr_field<'a>(v: &'a Json, key: &str) -> Result<&'a [Json]> {
    field(v, key)?
        .as_arr()
        .with_context(|| format!("field `{}` is not an array", key))
}

fn u64_of(f: &Json, key: &str) -> Result<u64> {
    match f {
        Json::Int(i) if *i >= 0 => Ok(*i as u64),
        Json::Str(s) => s
            .parse::<u64>()
            .with_context(|| format!("field `{}`: `{}` is not a u64", key, s)),
        _ => bail!("field `{}` is not a u64", key),
    }
}

fn gu64(v: &Json, key: &str) -> Result<u64> {
    u64_of(field(v, key)?, key)
}

fn gi64(v: &Json, key: &str) -> Result<i64> {
    field(v, key)?.as_i64().with_context(|| format!("field `{}` is not an integer", key))
}

fn gu32(v: &Json, key: &str) -> Result<u32> {
    let raw = gu64(v, key)?;
    u32::try_from(raw).with_context(|| format!("field `{}`: {} overflows u32", key, raw))
}

fn gu8(v: &Json, key: &str) -> Result<u8> {
    let raw = gu64(v, key)?;
    u8::try_from(raw).with_context(|| format!("field `{}`: {} overflows u8", key, raw))
}

fn gusize(v: &Json, key: &str) -> Result<usize> {
    let raw = gu64(v, key)?;
    usize::try_from(raw).with_context(|| format!("field `{}`: {} overflows usize", key, raw))
}

fn gbool(v: &Json, key: &str) -> Result<bool> {
    field(v, key)?.as_bool().with_context(|| format!("field `{}` is not a bool", key))
}

fn gstr(v: &Json, key: &str) -> Result<String> {
    Ok(field(v, key)?
        .as_str()
        .with_context(|| format!("field `{}` is not a string", key))?
        .to_string())
}

fn method_name(m: Method) -> &'static str {
    match m {
        Method::Exhaustive => "exhaustive",
        Method::Swarm => "swarm",
    }
}

fn check_to_json(c: &CheckOptions) -> Json {
    let mut fields = vec![(
        "store",
        Json::Str(
            match c.store {
                StoreKind::Full => "full",
                StoreKind::HashCompact => "compact",
                StoreKind::Bitstate { .. } => "bitstate",
                StoreKind::Spill => "spill",
            }
            .to_string(),
        ),
    )];
    if let StoreKind::Bitstate { log2_bits, hashes } = c.store {
        fields.push(("store_bits", Json::Int(log2_bits as i64)));
        fields.push(("store_hashes", Json::Int(hashes as i64)));
    }
    fields.push(("max_depth", ju64(c.max_depth as u64)));
    fields.push(("max_states", ju64(c.max_states)));
    fields.push(("memory_budget", ju64(c.memory_budget)));
    fields.push((
        "time_budget_nanos",
        c.time_budget.map_or(Json::Null, jnanos),
    ));
    fields.push(("collect_all", Json::Bool(c.collect_all)));
    fields.push(("max_errors", ju64(c.max_errors as u64)));
    match c.order {
        Order::InOrder => fields.push(("order", Json::Str("in-order".into()))),
        Order::Random(seed) => {
            fields.push(("order", Json::Str("random".into())));
            fields.push(("order_seed", ju64(seed)));
        }
    }
    fields.push(("threads", Json::Int(c.threads as i64)));
    fields.push(("expected_states", ju64(c.expected_states)));
    fields.push((
        "frontier",
        Json::Str(
            match c.frontier {
                Frontier::Async => "async",
                Frontier::Deterministic => "det",
            }
            .to_string(),
        ),
    ));
    fields.push(("por", Json::Bool(c.por)));
    fields.push(("compress", Json::Str(c.compress.name().to_string())));
    fields.push((
        "spill_dir",
        c.spill_dir.as_ref().map_or(Json::Null, |p| Json::Str(p.display().to_string())),
    ));
    obj(fields)
}

fn check_from_json(v: &Json) -> Result<CheckOptions> {
    let store = match gstr(v, "store")?.as_str() {
        "full" => StoreKind::Full,
        "compact" => StoreKind::HashCompact,
        "bitstate" => StoreKind::Bitstate {
            log2_bits: gu8(v, "store_bits")?,
            hashes: gu8(v, "store_hashes")?,
        },
        "spill" => StoreKind::Spill,
        s => bail!("unknown store kind `{}`", s),
    };
    // optional for manifests written before these knobs existed
    let por = match v.get("por") {
        Some(f) => f.as_bool().context("field `por` is not a bool")?,
        None => false,
    };
    let compress = match v.get("compress") {
        Some(f) => match f.as_str().context("field `compress` is not a string")? {
            "none" => Compression::None,
            "collapse" => Compression::Collapse,
            s => bail!("unknown compression `{}`", s),
        },
        None => Compression::None,
    };
    let spill_dir = match v.get("spill_dir") {
        None | Some(Json::Null) => None,
        Some(f) => {
            Some(PathBuf::from(f.as_str().context("field `spill_dir` is not a string")?))
        }
    };
    let order = match gstr(v, "order")?.as_str() {
        "in-order" => Order::InOrder,
        "random" => Order::Random(gu64(v, "order_seed")?),
        s => bail!("unknown successor order `{}`", s),
    };
    let frontier = match gstr(v, "frontier")?.as_str() {
        "async" => Frontier::Async,
        "det" => Frontier::Deterministic,
        s => bail!("unknown frontier `{}`", s),
    };
    let time_budget = match field(v, "time_budget_nanos")? {
        Json::Null => None,
        f => Some(Duration::from_nanos(u64_of(f, "time_budget_nanos")?)),
    };
    Ok(CheckOptions {
        store,
        max_depth: gusize(v, "max_depth")?,
        max_states: gu64(v, "max_states")?,
        memory_budget: gu64(v, "memory_budget")?,
        time_budget,
        collect_all: gbool(v, "collect_all")?,
        max_errors: gusize(v, "max_errors")?,
        order,
        threads: gu32(v, "threads")?,
        expected_states: gu64(v, "expected_states")?,
        frontier,
        por,
        compress,
        spill_dir,
    })
}

fn swarm_to_json(s: &SwarmConfig) -> Json {
    obj(vec![
        ("workers", Json::Int(s.workers as i64)),
        ("seed", ju64(s.seed)),
        ("log2_bits", Json::Int(s.log2_bits as i64)),
        ("hashes", Json::Int(s.hashes as i64)),
        ("max_depth", ju64(s.max_depth as u64)),
        ("time_budget_nanos", jnanos(s.time_budget)),
        ("max_errors_per_worker", ju64(s.max_errors_per_worker as u64)),
    ])
}

fn swarm_from_json(v: &Json) -> Result<SwarmConfig> {
    Ok(SwarmConfig {
        workers: gu32(v, "workers")?,
        seed: gu64(v, "seed")?,
        log2_bits: gu8(v, "log2_bits")?,
        hashes: gu8(v, "hashes")?,
        max_depth: gusize(v, "max_depth")?,
        time_budget: Duration::from_nanos(gu64(v, "time_budget_nanos")?),
        max_errors_per_worker: gusize(v, "max_errors_per_worker")?,
    })
}

fn job_to_json(j: &TuningJob) -> Json {
    obj(vec![
        ("name", Json::Str(j.name.clone())),
        ("model", Json::Str(j.model.to_string())),
        ("engine", Json::Str(j.engine.to_string())),
        // the source text is inlined so a worker machine needs no access
        // to the original .pml path
        ("source", j.source.as_ref().map_or(Json::Null, |s| Json::Str(s.clone()))),
        ("size", Json::Int(j.size as i64)),
        ("nd", Json::Int(j.plat.nd as i64)),
        ("nu", Json::Int(j.plat.nu as i64)),
        ("np", Json::Int(j.plat.np as i64)),
        ("gmt", Json::Int(j.plat.gmt as i64)),
        (
            "granularity",
            Json::Str(
                match j.granularity {
                    Granularity::Tick => "tick",
                    Granularity::Phase => "phase",
                }
                .to_string(),
            ),
        ),
        ("method", Json::Str(method_name(j.method).to_string())),
        ("shards", Json::Int(j.shards as i64)),
        ("search", Json::Str(j.search.to_string())),
    ])
}

fn job_from_json(v: &Json) -> Result<TuningJob> {
    let source = match field(v, "source")? {
        Json::Null => None,
        f => Some(f.as_str().context("field `source` is not a string")?.to_string()),
    };
    let granularity = match gstr(v, "granularity")?.as_str() {
        "tick" => Granularity::Tick,
        "phase" => Granularity::Phase,
        g => bail!("unknown granularity `{}`", g),
    };
    Ok(TuningJob {
        name: gstr(v, "name")?,
        model: gstr(v, "model")?.parse::<ModelKind>()?,
        engine: gstr(v, "engine")?.parse::<JobEngine>()?,
        source,
        size: gu32(v, "size")?,
        plat: PlatformConfig {
            nd: gu32(v, "nd")?,
            nu: gu32(v, "nu")?,
            np: gu32(v, "np")?,
            gmt: gu32(v, "gmt")?,
        },
        granularity,
        method: gstr(v, "method")?.parse::<Method>()?,
        shards: gu32(v, "shards")?,
        // optional for manifests written before surrogate search existed
        search: match v.get("search") {
            Some(f) => f
                .as_str()
                .context("field `search` is not a string")?
                .parse::<SearchMode>()?,
            None => SearchMode::Exhaustive,
        },
    })
}

fn plan_to_json(p: &ShardPlan) -> Json {
    obj(vec![
        ("wg_min", Json::Int(p.shard.wg_min as i64)),
        ("wg_max", Json::Int(p.shard.wg_max as i64)),
        ("ts_min", Json::Int(p.shard.ts_min as i64)),
        ("ts_max", Json::Int(p.shard.ts_max as i64)),
        ("weight", ju64(p.weight)),
        ("t_ini", Json::Int(p.t_ini)),
        ("check", check_to_json(&p.check)),
        // surrogate warm-start observations ride the manifest so worker
        // machines need no access to the planner's cache file
        (
            "seeds",
            Json::Arr(
                p.seeds
                    .iter()
                    .map(|o| {
                        Json::Arr(vec![
                            Json::Int(o.wg as i64),
                            Json::Int(o.ts as i64),
                            Json::Int(o.size as i64),
                            Json::Int(o.time),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

fn plan_from_json(v: &Json) -> Result<ShardPlan> {
    Ok(ShardPlan {
        shard: TuningShard {
            wg_min: gu32(v, "wg_min")?,
            wg_max: gu32(v, "wg_max")?,
            ts_min: gu32(v, "ts_min")?,
            ts_max: gu32(v, "ts_max")?,
        },
        weight: gu64(v, "weight")?,
        t_ini: gi64(v, "t_ini")?,
        check: check_from_json(field(v, "check")?)?,
        seeds: match v.get("seeds") {
            None => Vec::new(), // pre-surrogate manifests
            Some(f) => {
                let rows = f.as_arr().context("field `seeds` is not an array")?;
                let mut out = Vec::with_capacity(rows.len());
                for r in rows {
                    let xs = r.as_arr().context("seed row is not an array")?;
                    ensure!(xs.len() == 4, "seed row needs [wg, ts, size, time]");
                    let n = |i: usize, k: &str| {
                        xs[i].as_i64().with_context(|| format!("seed `{}` is not an integer", k))
                    };
                    out.push(Observation {
                        wg: u32::try_from(n(0, "wg")?).context("seed `wg` overflows u32")?,
                        ts: u32::try_from(n(1, "ts")?).context("seed `ts` overflows u32")?,
                        size: u32::try_from(n(2, "size")?).context("seed `size` overflows u32")?,
                        time: n(3, "time")?,
                    });
                }
                out
            }
        },
    })
}

fn witness_to_json(w: &TuningWitness) -> Json {
    obj(vec![
        ("wg", Json::Int(w.wg as i64)),
        ("ts", Json::Int(w.ts as i64)),
        ("time", Json::Int(w.time)),
        ("steps", ju64(w.steps as u64)),
    ])
}

fn witness_from_json(v: &Json) -> Result<TuningWitness> {
    Ok(TuningWitness {
        wg: gu32(v, "wg")?,
        ts: gu32(v, "ts")?,
        time: gi64(v, "time")?,
        steps: gusize(v, "steps")?,
    })
}

fn result_to_json(r: &TuneResult) -> Json {
    obj(vec![
        ("method", Json::Str(method_name(r.method).to_string())),
        ("optimal", witness_to_json(&r.optimal)),
        ("t_min", Json::Int(r.t_min)),
        (
            "first_trail",
            r.first_trail.as_ref().map_or(Json::Null, |(w, d)| {
                let Json::Obj(mut fields) = witness_to_json(w) else { unreachable!() };
                fields.push(("found_after_nanos".to_string(), jnanos(*d)));
                Json::Obj(fields)
            }),
        ),
        ("states_explored", ju64(r.states_explored)),
        ("peak_bytes", ju64(r.peak_bytes)),
        ("elapsed_nanos", jnanos(r.elapsed)),
        ("log", Json::Arr(r.log.iter().map(|l| Json::Str(l.clone())).collect())),
    ])
}

fn result_from_json(v: &Json) -> Result<TuneResult> {
    let method = gstr(v, "method")?.parse::<Method>()?;
    let t_min = gi64(v, "t_min")?;
    let first_trail = match field(v, "first_trail")? {
        Json::Null => None,
        f => Some((
            witness_from_json(f)?,
            Duration::from_nanos(gu64(f, "found_after_nanos")?),
        )),
    };
    let log = field(v, "log")?
        .as_arr()
        .context("field `log` is not an array")?
        .iter()
        .map(|l| {
            l.as_str().map(str::to_string).context("log line is not a string")
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(TuneResult {
        method,
        optimal: witness_from_json(field(v, "optimal")?)?,
        t_min,
        // derived exactly as bisection/merge_results derive it, so a
        // round-trip reproduces the original value
        first_trail_optimality: first_trail
            .as_ref()
            .map(|(w, _)| t_min as f64 / w.time as f64),
        first_trail,
        states_explored: gu64(v, "states_explored")?,
        peak_bytes: gu64(v, "peak_bytes")?,
        elapsed: Duration::from_nanos(gu64(v, "elapsed_nanos")?),
        log,
    })
}

// ------------------------------------------------------------ TaskSpec --

/// One durable (job, shard) task: everything a worker process on another
/// machine needs to execute the shard — the job (with any Promela source
/// inlined), the sub-lattice bounds, the [`ShardPlan`] budget slice and
/// the swarm configuration — plus the job's cache description so the
/// merge step can write the result back under the right key.
///
/// For Promela jobs the shard bounds double as the **specialized-program
/// recipe**: the executing worker compiles them into a shard-specialized
/// bytecode VM ([`super::run_shard_task`]), so the manifest carries the
/// specialization across processes without serializing compiled code.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskSpec {
    /// filesystem-safe id, `j<job>-s<shard>`
    pub id: String,
    pub job_index: usize,
    /// position among the job's shards, in plan (lattice-partition) order
    pub shard_index: usize,
    /// the job's canonical cache description (swarm-config-aware),
    /// computed once at plan time
    pub desc: String,
    /// failed execution attempts charged so far (0 on a fresh plan).
    /// Carried in the manifest — and therefore in leases, which are the
    /// manifest plus owner fields — so the count survives any worker;
    /// older parsers ignore it (the `owner` precedent).
    pub attempts: u32,
    /// unix-ms timestamp before which the task must not be re-leased
    /// (exponential backoff after a failed attempt); 0 = leasable now
    pub not_before_unix_ms: u64,
    /// the most recent attempt's failure, for `worker --status` and the
    /// dead-letter record
    pub last_error: Option<String>,
    pub job: TuningJob,
    pub plan: ShardPlan,
    pub swarm: SwarmConfig,
}

impl TaskSpec {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("version", Json::Int(1)),
            ("id", Json::Str(self.id.clone())),
            ("job_index", ju64(self.job_index as u64)),
            ("shard_index", ju64(self.shard_index as u64)),
            ("desc", Json::Str(self.desc.clone())),
            ("attempts", ju64(self.attempts as u64)),
        ];
        if self.not_before_unix_ms > 0 {
            fields.push(("not_before_unix_ms", ju64(self.not_before_unix_ms)));
        }
        if let Some(e) = &self.last_error {
            fields.push(("last_error", Json::Str(e.clone())));
        }
        fields.push(("job", job_to_json(&self.job)));
        fields.push(("plan", plan_to_json(&self.plan)));
        fields.push(("swarm", swarm_to_json(&self.swarm)));
        obj(fields)
    }

    pub fn parse(text: &str) -> Result<TaskSpec> {
        let v = Json::parse(text)?;
        let version = gi64(&v, "version")?;
        ensure!(version == 1, "unsupported task-manifest version {}", version);
        // retry bookkeeping is optional: manifests written by older
        // planners simply have no failed attempts yet
        let attempts = match v.get("attempts") {
            Some(f) => u32::try_from(u64_of(f, "attempts")?).unwrap_or(u32::MAX),
            None => 0,
        };
        let not_before_unix_ms = match v.get("not_before_unix_ms") {
            Some(f) => u64_of(f, "not_before_unix_ms")?,
            None => 0,
        };
        let last_error = v.get("last_error").and_then(Json::as_str).map(str::to_string);
        Ok(TaskSpec {
            id: gstr(&v, "id")?,
            job_index: gusize(&v, "job_index")?,
            shard_index: gusize(&v, "shard_index")?,
            desc: gstr(&v, "desc")?,
            attempts,
            not_before_unix_ms,
            last_error,
            job: job_from_json(field(&v, "job")?)?,
            plan: plan_from_json(field(&v, "plan")?)?,
            swarm: swarm_from_json(field(&v, "swarm")?)?,
        })
    }
}

// -------------------------------------------------------------- header --

/// The per-batch record (`batch.json`): what the merge step needs beyond
/// the task results themselves.
#[derive(Debug)]
struct Header {
    jobs: Vec<TuningJob>,
    descs: Vec<String>,
    shard_counts: Vec<u32>,
    duplicates: Vec<usize>,
    /// plan-time cache hits, resolved before any task was written
    cached: Vec<(usize, TuneResult)>,
    plan_hits: u64,
    plan_misses: u64,
    /// authoritative task ids, in plan order
    task_ids: Vec<String>,
    /// the planning process's cache file (merge defaults to it)
    cache_path: Option<String>,
    /// the planner's lease TTL in ms — workers that do not override the
    /// TTL adopt it, so the whole fleet shares one staleness clock
    ttl_ms: u64,
    /// the planner's dead-letter threshold — adopted by workers that do
    /// not override it, for the same one-fleet-one-policy reason
    max_attempts: u32,
}

impl Header {
    fn to_json(&self) -> Json {
        obj(vec![
            ("version", Json::Int(1)),
            ("jobs", Json::Arr(self.jobs.iter().map(job_to_json).collect())),
            (
                "descs",
                Json::Arr(self.descs.iter().map(|d| Json::Str(d.clone())).collect()),
            ),
            (
                "shard_counts",
                Json::Arr(self.shard_counts.iter().map(|&c| Json::Int(c as i64)).collect()),
            ),
            (
                "duplicates",
                Json::Arr(self.duplicates.iter().map(|&d| ju64(d as u64)).collect()),
            ),
            (
                "cached",
                Json::Arr(
                    self.cached
                        .iter()
                        .map(|(ji, r)| {
                            obj(vec![
                                ("job_index", ju64(*ji as u64)),
                                ("result", result_to_json(r)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("plan_hits", ju64(self.plan_hits)),
            ("plan_misses", ju64(self.plan_misses)),
            (
                "task_ids",
                Json::Arr(self.task_ids.iter().map(|t| Json::Str(t.clone())).collect()),
            ),
            (
                "cache_path",
                self.cache_path.as_ref().map_or(Json::Null, |p| Json::Str(p.clone())),
            ),
            ("ttl_ms", ju64(self.ttl_ms)),
            ("max_attempts", ju64(self.max_attempts as u64)),
        ])
    }

    fn parse(text: &str) -> Result<Header> {
        let v = Json::parse(text)?;
        let version = gi64(&v, "version")?;
        ensure!(version == 1, "unsupported batch-header version {}", version);
        let jobs =
            arr_field(&v, "jobs")?.iter().map(job_from_json).collect::<Result<Vec<_>>>()?;
        let descs = arr_field(&v, "descs")?
            .iter()
            .map(|d| d.as_str().map(str::to_string).context("desc is not a string"))
            .collect::<Result<Vec<_>>>()?;
        let shard_counts = arr_field(&v, "shard_counts")?
            .iter()
            .map(|c| Ok(u64_of(c, "shard_counts")? as u32))
            .collect::<Result<Vec<_>>>()?;
        let duplicates = arr_field(&v, "duplicates")?
            .iter()
            .map(|d| Ok(u64_of(d, "duplicates")? as usize))
            .collect::<Result<Vec<_>>>()?;
        let cached = arr_field(&v, "cached")?
            .iter()
            .map(|e| {
                Ok((gusize(e, "job_index")?, result_from_json(field(e, "result")?)?))
            })
            .collect::<Result<Vec<_>>>()?;
        let task_ids = arr_field(&v, "task_ids")?
            .iter()
            .map(|t| t.as_str().map(str::to_string).context("task id is not a string"))
            .collect::<Result<Vec<_>>>()?;
        let cache_path = match field(&v, "cache_path")? {
            Json::Null => None,
            f => Some(f.as_str().context("field `cache_path` is not a string")?.to_string()),
        };
        ensure!(jobs.len() == descs.len(), "jobs/descs length mismatch");
        ensure!(jobs.len() == shard_counts.len(), "jobs/shard_counts length mismatch");
        for &ji in duplicates.iter().chain(cached.iter().map(|(ji, _)| ji)) {
            ensure!(ji < jobs.len(), "job index {} out of range", ji);
        }
        Ok(Header {
            jobs,
            descs,
            shard_counts,
            duplicates,
            cached,
            plan_hits: gu64(&v, "plan_hits")?,
            plan_misses: gu64(&v, "plan_misses")?,
            task_ids,
            cache_path,
            ttl_ms: gu64(&v, "ttl_ms")?,
            // absent in headers planned by older binaries: the default
            max_attempts: match v.get("max_attempts") {
                Some(f) => u32::try_from(u64_of(f, "max_attempts")?)
                    .unwrap_or(DEFAULT_MAX_ATTEMPTS),
                None => DEFAULT_MAX_ATTEMPTS,
            },
        })
    }
}

// ------------------------------------------------------------- TaskDir --

/// A leased task: the parsed [`TaskSpec`] plus the lease file the holder
/// heartbeats and removes on completion. Dropping a `LeasedTask` without
/// [`TaskDir::complete`] simulates a crashed worker — the lease goes
/// stale after the TTL and is re-leased.
#[derive(Debug)]
pub struct LeasedTask {
    pub spec: TaskSpec,
    /// true when this lease was obtained by re-leasing an expired
    /// (crashed or stalled) worker's lease
    pub reclaimed: bool,
    lease_path: PathBuf,
}

/// What one [`TaskDir::drain`] call did.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DrainStats {
    /// tasks this process actually executed (claims skipped because a
    /// duplicate executor already published the result are not counted)
    pub executed: u64,
    /// tasks claimed by re-leasing an expired lease
    pub reclaimed: u64,
    /// true when every task in the batch has a result (not necessarily
    /// all produced by this process)
    pub complete: bool,
}

/// What [`TaskDir::plan`] wrote.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PlanSummary {
    pub jobs: usize,
    /// task manifests written (one per non-cached, non-duplicate shard)
    pub tasks: usize,
    /// jobs resolved from the cache at plan time (no task written)
    pub cached: usize,
}

/// A task directory: the durable home of one planned batch.
#[derive(Debug, Clone)]
pub struct TaskDir {
    dir: PathBuf,
    /// explicit TTL override; `None` = the plan's recorded TTL when
    /// draining (falling back to [`DEFAULT_TTL`] elsewhere)
    ttl: Option<Duration>,
    poll: Duration,
    /// explicit dead-letter threshold override; `None` = the plan's
    /// recorded value when draining ([`DEFAULT_MAX_ATTEMPTS`] elsewhere)
    max_attempts: Option<u32>,
}

impl TaskDir {
    pub fn new(dir: impl Into<PathBuf>) -> Self {
        Self { dir: dir.into(), ttl: None, poll: Duration::from_millis(100), max_attempts: None }
    }

    /// Lease time-to-live: a lease whose mtime is older than this is
    /// presumed crashed and re-leased. Must comfortably exceed the
    /// heartbeat period (ttl/4); sub-second values are for tests. When
    /// not set, [`drain`](Self::drain) adopts the TTL the planner
    /// recorded in `batch.json` — a fleet must share one staleness clock,
    /// or a short-TTL worker would steal live leases from healthy peers
    /// heartbeating at a longer period.
    pub fn with_ttl(mut self, ttl: Duration) -> Self {
        self.ttl = Some(ttl);
        self
    }

    fn effective_ttl(&self) -> Duration {
        self.ttl.unwrap_or(DEFAULT_TTL)
    }

    /// How long [`drain`](Self::drain) sleeps between scans when no task
    /// is leasable but the batch is incomplete.
    pub fn with_poll(mut self, poll: Duration) -> Self {
        self.poll = poll;
        self
    }

    /// How many failed attempts a task gets before it is dead-lettered
    /// to `dead/<id>.json` instead of retried (poison-task containment).
    /// When not set, [`drain`](Self::drain) adopts the value the planner
    /// recorded in `batch.json`.
    pub fn with_max_attempts(mut self, max_attempts: u32) -> Self {
        self.max_attempts = Some(max_attempts.max(1));
        self
    }

    fn effective_max_attempts(&self) -> u32 {
        self.max_attempts.unwrap_or(DEFAULT_MAX_ATTEMPTS)
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn task_path(&self, id: &str) -> PathBuf {
        self.dir.join(format!("{}{}", id, TASK_SUFFIX))
    }

    fn lease_path(&self, id: &str) -> PathBuf {
        self.dir.join(format!("{}{}", id, LEASE_SUFFIX))
    }

    fn result_path(&self, id: &str) -> PathBuf {
        self.dir.join(format!("{}{}", id, RESULT_SUFFIX))
    }

    fn header_path(&self) -> PathBuf {
        self.dir.join(HEADER)
    }

    fn dead_path(&self, id: &str) -> PathBuf {
        self.dir.join(DEAD_DIR).join(format!("{}.json", id))
    }

    fn write_atomic(&self, name: &str, text: &str) -> Result<()> {
        crate::util::manifest::write_atomic(&self.dir.join(name), text)
    }

    /// Phase 1 across processes: plan the batch (cache pass + budget
    /// split) and serialize every remaining (job, shard) task as a
    /// manifest in the directory, the `batch.json` header last.
    ///
    /// Multi-threaded plans (`check.threads != 1`) are upgraded from the
    /// async to the deterministic frontier: duplicate execution under
    /// lease stealing must publish identical bytes, and async
    /// multi-threaded exploration is scheduler-dependent while
    /// `Frontier::Deterministic` is reproducible across runs and thread
    /// counts by construction.
    pub fn plan(
        &self,
        jobs: &[TuningJob],
        opts: &BatchOptions,
        cache: &mut ResultCache,
    ) -> Result<PlanSummary> {
        std::fs::create_dir_all(&self.dir)
            .with_context(|| format!("creating task dir {}", self.dir.display()))?;
        ensure!(
            !self.header_path().exists(),
            "{} already holds a planned batch — merge or remove it before planning another",
            self.dir.display()
        );
        // also refuse headerless leftovers (a planner that died mid-plan):
        // workers lease by directory scan, so orphan manifests from an
        // earlier attempt would be executed alongside the new batch
        let leftovers = self.scan()?;
        ensure!(
            leftovers.available.is_empty()
                && leftovers.leases.is_empty()
                && leftovers.results.is_empty(),
            "{} contains task files from an earlier (unfinished) plan — remove them first",
            self.dir.display()
        );
        let mut opts = opts.clone();
        // raw `threads != 1`, not effective_threads(): `0` (= all cores)
        // must upgrade even when the *planner* machine is single-core —
        // it is the worker machines that resolve the thread count
        if opts.check.threads != 1 && opts.check.frontier == Frontier::Async {
            opts.check.frontier = Frontier::Deterministic;
        }
        let opts = &opts;
        let hits_before = cache.hits;
        let misses_before = cache.misses;
        let plan = plan_batch(jobs, opts, cache)?;
        let mut next_shard = vec![0usize; jobs.len()];
        let mut task_ids = Vec::with_capacity(plan.tasks.len());
        for (ji, shard_plan) in &plan.tasks {
            let si = next_shard[*ji];
            next_shard[*ji] += 1;
            let id = format!("j{:03}-s{:03}", ji, si);
            self.write_task(&TaskSpec {
                id: id.clone(),
                job_index: *ji,
                shard_index: si,
                desc: plan.descs[*ji].clone(),
                attempts: 0,
                not_before_unix_ms: 0,
                last_error: None,
                job: jobs[*ji].clone(),
                plan: shard_plan.clone(),
                swarm: opts.swarm.clone(),
            })?;
            task_ids.push(id);
        }
        let cached: Vec<(usize, TuneResult)> = plan
            .outcomes
            .into_iter()
            .enumerate()
            .filter_map(|(ji, o)| o.map(|o| (ji, o.result)))
            .collect();
        let summary =
            PlanSummary { jobs: jobs.len(), tasks: task_ids.len(), cached: cached.len() };
        let header = Header {
            jobs: jobs.to_vec(),
            descs: plan.descs,
            shard_counts: plan.shard_counts,
            duplicates: plan.duplicates,
            cached,
            plan_hits: cache.hits - hits_before,
            plan_misses: cache.misses - misses_before,
            task_ids,
            cache_path: cache.path().map(|p| p.display().to_string()),
            ttl_ms: self.effective_ttl().as_millis().min(u64::MAX as u128) as u64,
            max_attempts: self.effective_max_attempts(),
        };
        crate::util::failpoint::hit("task.header")?;
        self.write_atomic(HEADER, &header.to_json().render())?;
        Ok(summary)
    }

    /// Write one task manifest (exposed for tests and tools; `plan` is
    /// the normal author).
    pub fn write_task(&self, spec: &TaskSpec) -> Result<()> {
        ensure!(
            !spec.id.is_empty()
                && spec
                    .id
                    .chars()
                    .all(|c| c.is_ascii_alphanumeric() || c == '-' || c == '_'),
            "task id `{}` is not filesystem-safe",
            spec.id
        );
        std::fs::create_dir_all(&self.dir)
            .with_context(|| format!("creating task dir {}", self.dir.display()))?;
        self.write_atomic(
            &format!("{}{}", spec.id, TASK_SUFFIX),
            &spec.to_json().render(),
        )
    }

    fn header(&self) -> Result<Header> {
        let path = self.header_path();
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "reading {} — not a planned task dir? (plan with `mcautotune batch <spec> --task-dir {}`)",
                path.display(),
                self.dir.display()
            )
        })?;
        Header::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }

    /// (tasks without a result yet, total tasks).
    pub fn outstanding(&self) -> Result<(usize, usize)> {
        let h = self.header()?;
        Ok((self.remaining(&h.task_ids)?, h.task_ids.len()))
    }

    /// The cache file the planning process used (the natural default for
    /// `mcautotune merge`).
    pub fn planned_cache_path(&self) -> Result<Option<String>> {
        Ok(self.header()?.cache_path)
    }

    fn remaining(&self, ids: &[String]) -> Result<usize> {
        // dead-lettered tasks count as done for drain purposes: nobody
        // will ever produce their result, so waiting on them would hang
        // every worker forever
        Ok(ids
            .iter()
            .filter(|id| !self.result_path(id).exists() && !self.dead_path(id).exists())
            .count())
    }

    fn scan(&self) -> Result<Scan> {
        let mut s = Scan::default();
        let entries = std::fs::read_dir(&self.dir)
            .with_context(|| format!("scanning task dir {}", self.dir.display()))?;
        for entry in entries {
            // files vanish mid-scan by design (leases move, temps rename)
            let Ok(entry) = entry else { continue };
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            if let Some(id) = name.strip_suffix(TASK_SUFFIX) {
                s.available.push(id.to_string());
            } else if let Some(id) = name.strip_suffix(LEASE_SUFFIX) {
                if let Ok(mtime) = entry.metadata().and_then(|m| m.modified()) {
                    s.leases.push((id.to_string(), mtime));
                }
            } else if let Some(id) = name.strip_suffix(RESULT_SUFFIX) {
                s.results.insert(id.to_string());
            }
        }
        s.available.sort();
        Ok(s)
    }

    /// Try to claim one task: atomically rename an available
    /// `<id>.task.json` to `<id>.lease.json` (exactly one process wins).
    /// When nothing is available, expired leases (mtime older than the
    /// TTL) are renamed back to task files — again one winner per lease —
    /// and the scan retries. `Ok(None)` means nothing is currently
    /// leasable: the batch may be complete, or every remaining task is
    /// held by a live worker.
    pub fn lease(&self) -> Result<Option<LeasedTask>> {
        // ids this call renamed back from expired leases; a win on one of
        // them is flagged `reclaimed`. Attribution is best-effort under
        // concurrency: a racer may win a task someone else renamed back.
        let mut renamed: HashSet<String> = HashSet::new();
        loop {
            let scan = self.scan()?;
            for id in &scan.available {
                if scan.results.contains(id) {
                    // a re-leased task whose original worker had already
                    // published the result before dying: nothing to run
                    let _ = std::fs::remove_file(self.task_path(id));
                    continue;
                }
                if let Some(mut leased) = self.try_lease(id)? {
                    leased.reclaimed = renamed.contains(id.as_str());
                    return Ok(Some(leased));
                }
            }
            let now = SystemTime::now();
            let mut progressed = false;
            for (id, mtime) in &scan.leases {
                if scan.results.contains(id) {
                    // crashed between result publication and lease removal
                    let _ = std::fs::remove_file(self.lease_path(id));
                    continue;
                }
                let age = now.duration_since(*mtime).unwrap_or(Duration::ZERO);
                if age >= self.effective_ttl()
                    && std::fs::rename(self.lease_path(id), self.task_path(id)).is_ok()
                {
                    lease_event("reclaim", id);
                    // a reclaim is evidence of a crashed/stalled attempt:
                    // charge it, so a task that crashes its worker every
                    // time is dead-lettered instead of looping forever
                    self.note_reclaim(id)?;
                    renamed.insert(id.clone());
                    progressed = true;
                }
            }
            if !progressed {
                return Ok(None);
            }
        }
    }

    fn try_lease(&self, id: &str) -> Result<Option<LeasedTask>> {
        let lease = self.lease_path(id);
        if std::fs::rename(self.task_path(id), &lease).is_err() {
            return Ok(None); // another worker won the rename
        }
        // chaos site: a worker that dies right here leaves a fresh lease
        // it will never heartbeat — the canonical crashed-holder schedule
        crate::util::failpoint::hit("task.lease")?;
        // The TTL clock starts at lease time, not plan time (rename keeps
        // the old mtime). A failed touch is tolerated: the lease merely
        // looks older than it is, and duplicate execution is benign.
        let _ = touch(&lease);
        let text = match std::fs::read_to_string(&lease) {
            Ok(t) => t,
            // stolen between our win and the read by an aggressive
            // reclaimer (tiny TTL): treat as a lost race
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => {
                return Err(e).with_context(|| format!("reading lease {}", lease.display()))
            }
        };
        let spec = TaskSpec::parse(&text)
            .with_context(|| format!("parsing leased task {}", lease.display()))?;
        ensure!(
            spec.id == id,
            "task file for `{}` claims id `{}`",
            id,
            spec.id
        );
        if spec.not_before_unix_ms > unix_ms() {
            // still in post-failure backoff: hand the manifest back and
            // report nothing leasable (the drain loop polls; backoff is
            // capped at 10s so this always unblocks)
            let _ = std::fs::rename(&lease, self.task_path(id));
            return Ok(None);
        }
        // Tag the lease with its owner so `worker --status` can attribute
        // it. Atomic (tmp + rename, like every other publish in this
        // protocol): a crash mid-write must never leave a truncated lease
        // that re-leases as an unparseable task and wedges the batch.
        // Best-effort beyond that: a failed write just leaves the owner
        // unknown (TaskSpec::parse ignores the extra field), and the
        // rewrite doubles as a second mtime freshen.
        let Json::Obj(mut fields) = spec.to_json() else {
            unreachable!("TaskSpec::to_json always builds an object")
        };
        fields.push(("owner".to_string(), Json::Str(owner_tag())));
        fields.push(("leased_unix_ms".to_string(), ju64(unix_ms())));
        let _ = self.write_atomic(
            &format!("{}{}", spec.id, LEASE_SUFFIX),
            &Json::Obj(fields).render(),
        );
        lease_event("grant", &spec.id);
        Ok(Some(LeasedTask { spec, reclaimed: false, lease_path: lease }))
    }

    /// Execute one leased task under full fault containment and publish
    /// its result as `<id>.result.json`, heartbeating the lease while it
    /// runs. The task body executes on a dedicated thread behind
    /// `catch_unwind` (checker/VM state is per-task, so unwinding is
    /// local) with a hard deadline derived from its shard budget; a
    /// panic, error, deadline overrun or publish failure is charged as a
    /// failed *attempt* — the task is requeued with backoff, or
    /// dead-lettered once its attempt budget is spent — and the worker
    /// keeps draining. A task whose result already exists (a duplicate
    /// execution lost the race) is skipped; the return value says whether
    /// the task actually ran (`false` = skipped), so drain statistics
    /// stay honest.
    pub fn run(&self, leased: &LeasedTask) -> Result<bool> {
        if self.result_path(&leased.spec.id).exists() {
            let _ = std::fs::remove_file(&leased.lease_path);
            return Ok(false);
        }
        crate::obs::metrics().task_attempts.add(1);
        let t0 = Instant::now();
        let stop = AtomicBool::new(false);
        let outcome = std::thread::scope(|scope| {
            let hb = scope.spawn(|| {
                heartbeat_loop(&leased.lease_path, self.effective_ttl(), &stop, &leased.spec.id)
            });
            let r = execute_task(&leased.spec);
            stop.store(true, Ordering::Relaxed);
            let _ = hb.join();
            r
        });
        match outcome {
            Ok(result) => {
                if let Err(e) = self.complete(leased, t0.elapsed(), Ok(result)) {
                    // publishing failed (disk error, injected fault): the
                    // work is lost but the task is not — charge an attempt
                    self.fail_attempt(leased, "publish", &e)?;
                }
            }
            Err(f) => self.fail_attempt(leased, f.class, &f.error)?,
        }
        Ok(true)
    }

    /// Charge one failed attempt against a leased task: requeue it with
    /// exponential backoff, or move it to `dead/<id>.json` once the
    /// attempt budget ([`TaskDir::with_max_attempts`]) is exhausted.
    /// Either way the lease is released and the worker moves on — one
    /// poisoned task must not take the process (or the batch) with it.
    fn fail_attempt(&self, leased: &LeasedTask, class: &'static str, err: &Error) -> Result<()> {
        let attempts = leased.spec.attempts.saturating_add(1);
        let dead = attempts >= self.effective_max_attempts();
        let detail = format!("{:#}", err);
        fault_event(class, &leased.spec.id, &detail, attempts, dead);
        if dead {
            self.dead_letter(&leased.spec, attempts, class, &detail)?;
            crate::obs::metrics().task_dead_lettered.add(1);
        } else {
            let mut retry = leased.spec.clone();
            retry.attempts = attempts;
            retry.not_before_unix_ms = unix_ms().saturating_add(backoff_ms(attempts));
            retry.last_error = Some(format!("attempt {}: {}: {}", attempts, class, detail));
            self.write_task(&retry)?;
        }
        let _ = std::fs::remove_file(&leased.lease_path);
        Ok(())
    }

    /// Charge a reclaim as a failed attempt on the task file the
    /// reclaiming rename just recreated, so a task that crashes its
    /// worker on every attempt is dead-lettered instead of cycling
    /// through the fleet forever. Best-effort under races: if another
    /// worker leases the file before the rewrite the charge is simply
    /// lost (benign — the task just gets one extra attempt), and a
    /// torn/unparseable file is left for `try_lease` to report.
    fn note_reclaim(&self, id: &str) -> Result<()> {
        let path = self.task_path(id);
        let Ok(text) = std::fs::read_to_string(&path) else {
            return Ok(()); // lost the race to another leaser
        };
        let Ok(mut spec) = TaskSpec::parse(&text) else {
            return Ok(());
        };
        let attempts = spec.attempts.saturating_add(1);
        let dead = attempts >= self.effective_max_attempts();
        let detail = "lease expired without a result (worker crash or stall)";
        fault_event("reclaim", id, detail, attempts, dead);
        if dead {
            self.dead_letter(&spec, attempts, "reclaim", detail)?;
            let _ = std::fs::remove_file(&path);
            crate::obs::metrics().task_dead_lettered.add(1);
        } else {
            spec.attempts = attempts;
            spec.not_before_unix_ms = unix_ms().saturating_add(backoff_ms(attempts));
            spec.last_error = Some(format!("attempt {}: {}", attempts, detail));
            self.write_task(&spec)?;
        }
        Ok(())
    }

    /// Move a poisoned task to `dead/<id>.json`: the full manifest (with
    /// the final attempt count) plus the captured failure class, message,
    /// timestamp and reporting worker, so the task can be inspected,
    /// fixed and re-planned by hand while `merge --partial` degrades
    /// around it.
    fn dead_letter(&self, spec: &TaskSpec, attempts: u32, class: &str, detail: &str) -> Result<()> {
        let dead_dir = self.dir.join(DEAD_DIR);
        std::fs::create_dir_all(&dead_dir)
            .with_context(|| format!("creating dead-letter dir {}", dead_dir.display()))?;
        let mut record = spec.clone();
        record.attempts = attempts;
        let Json::Obj(mut fields) = record.to_json() else {
            unreachable!("TaskSpec::to_json always builds an object")
        };
        fields.push(("dead_class".to_string(), Json::Str(class.to_string())));
        fields.push(("dead_error".to_string(), Json::Str(detail.to_string())));
        fields.push(("dead_unix_ms".to_string(), ju64(unix_ms())));
        fields.push(("dead_owner".to_string(), Json::Str(owner_tag())));
        crate::util::manifest::write_atomic(&self.dead_path(&spec.id), &Json::Obj(fields).render())
    }

    /// Publish a task outcome atomically and release the lease.
    /// [`TaskDir::run`] only publishes successes (failures are requeued
    /// or dead-lettered by `fail_attempt` instead); the `Err` arm is
    /// kept for callers that drive the protocol directly and for result
    /// files written by older binaries, which the merge step still turns
    /// into the same "shard failed" job error a single-process run
    /// reports.
    pub fn complete(
        &self,
        leased: &LeasedTask,
        wall: Duration,
        outcome: Result<TuneResult>,
    ) -> Result<()> {
        // chaos site: a torn/failed result publish after the shard ran
        crate::util::failpoint::hit("task.publish")?;
        let spec = &leased.spec;
        let mut fields = vec![
            ("version", Json::Int(1)),
            ("id", Json::Str(spec.id.clone())),
            ("job_index", ju64(spec.job_index as u64)),
            ("shard_index", ju64(spec.shard_index as u64)),
            ("wall_nanos", jnanos(wall)),
            ("plan", plan_to_json(&spec.plan)),
        ];
        match &outcome {
            Ok(r) => fields.push(("result", result_to_json(r))),
            Err(e) => fields.push(("error", Json::Str(format!("{:#}", e)))),
        }
        self.write_atomic(
            &format!("{}{}", spec.id, RESULT_SUFFIX),
            &obj(fields).render(),
        )?;
        let _ = std::fs::remove_file(&leased.lease_path);
        Ok(())
    }

    /// Lease-and-execute until the batch is fully drained (every task has
    /// a result, whoever produced it), across `workers` threads. With
    /// `oneshot`, stop as soon as nothing is leasable instead of polling
    /// for re-leasable work from crashed peers.
    pub fn drain(&self, workers: u32, oneshot: bool) -> Result<DrainStats> {
        let header = self.header()?;
        // no explicit TTL override: adopt the planner's, so every worker
        // in the fleet applies the same staleness clock
        let me = TaskDir {
            dir: self.dir.clone(),
            ttl: Some(self.ttl.unwrap_or(Duration::from_millis(header.ttl_ms))),
            poll: self.poll,
            // same adoption rule as the TTL: one fleet, one dead-letter
            // policy, unless this worker explicitly overrides it
            max_attempts: self.max_attempts.or(Some(header.max_attempts)),
        };
        let ids = header.task_ids;
        let reclaimed = AtomicU64::new(0);
        let executed = AtomicU64::new(0);
        let queue = JobQueue::new(workers);
        queue.run_source(
            || -> Result<Option<LeasedTask>> {
                loop {
                    // graceful SIGTERM: stop sourcing new tasks; leases
                    // already handed to workers finish and publish
                    // normally, so nothing is left to reclaim
                    if crate::util::signal::term_requested() {
                        return Ok(None);
                    }
                    // lease first: a successful claim already proves the
                    // batch is incomplete, so the O(tasks) remaining()
                    // stat pass only runs when nothing is leasable
                    match me.lease()? {
                        Some(l) => {
                            if l.reclaimed {
                                reclaimed.fetch_add(1, Ordering::Relaxed);
                            }
                            return Ok(Some(l));
                        }
                        None => {
                            if oneshot || me.remaining(&ids)? == 0 {
                                return Ok(None);
                            }
                            std::thread::sleep(me.poll);
                        }
                    }
                }
            },
            |leased| {
                if me.run(&leased)? {
                    executed.fetch_add(1, Ordering::Relaxed);
                }
                Ok(())
            },
        )?;
        Ok(DrainStats {
            executed: executed.load(Ordering::Relaxed),
            reclaimed: reclaimed.load(Ordering::Relaxed),
            complete: me.remaining(&ids)? == 0,
        })
    }

    /// Phase 3 across processes: fold every task result through the same
    /// merge/cache-write path as [`super::run_batch`], producing an
    /// identical [`BatchReport`] and identical cache entries. Errors if
    /// any task still has no result or was dead-lettered — see
    /// [`TaskDir::merge_partial`] for the degraded variant.
    pub fn merge(&self, cache: &mut ResultCache) -> Result<BatchReport> {
        self.merge_inner(cache, false)
    }

    /// Like [`TaskDir::merge`], but degrade gracefully instead of
    /// refusing: jobs whose every shard completed merge (and cache)
    /// exactly as a full merge would, jobs with dead-lettered or still
    /// outstanding shards fold the shards they do have into a
    /// *lower-bound* outcome (marked in the report, never written to the
    /// cache — a later full re-run must not be poisoned by a partial
    /// optimum), and the report lists every dead-lettered task.
    pub fn merge_partial(&self, cache: &mut ResultCache) -> Result<BatchReport> {
        self.merge_inner(cache, true)
    }

    fn merge_inner(&self, cache: &mut ResultCache, partial: bool) -> Result<BatchReport> {
        let start = Instant::now();
        let h = self.header()?;
        let hits_before = cache.hits;
        let misses_before = cache.misses;
        let mut shard_results: Vec<(usize, ShardPlan, Duration, Result<TuneResult>)> =
            Vec::with_capacity(h.task_ids.len());
        let mut outstanding = 0usize;
        let mut dead: Vec<DeadTaskInfo> = Vec::new();
        // iterate in plan order: finish_batch's merge folds (shard log
        // tags, first-trail tie-breaks) must match the in-process runner
        for id in &h.task_ids {
            let path = self.result_path(id);
            let text = match std::fs::read_to_string(&path) {
                Ok(t) => t,
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                    let dead_path = self.dead_path(id);
                    match std::fs::read_to_string(&dead_path) {
                        Ok(d) => {
                            let dv = Json::parse(&d).with_context(|| {
                                format!("parsing {}", dead_path.display())
                            })?;
                            let ji = gusize(&dv, "job_index")?;
                            ensure!(
                                ji < h.jobs.len(),
                                "{}: job index {} out of range",
                                dead_path.display(),
                                ji
                            );
                            dead.push(DeadTaskInfo {
                                id: id.clone(),
                                job: h.jobs[ji].name.clone(),
                                job_index: ji,
                                attempts: match dv.get("attempts") {
                                    Some(f) => u32::try_from(u64_of(f, "attempts")?)
                                        .unwrap_or(u32::MAX),
                                    None => 0,
                                },
                                error: dv
                                    .get("dead_error")
                                    .and_then(Json::as_str)
                                    .unwrap_or("unrecorded failure")
                                    .to_string(),
                            });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                            outstanding += 1;
                        }
                        Err(e) => {
                            return Err(e).with_context(|| {
                                format!("reading {}", dead_path.display())
                            })
                        }
                    }
                    continue;
                }
                Err(e) => {
                    return Err(e).with_context(|| format!("reading {}", path.display()))
                }
            };
            let v = Json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;
            let ji = gusize(&v, "job_index")?;
            ensure!(ji < h.jobs.len(), "{}: job index {} out of range", path.display(), ji);
            let plan = plan_from_json(field(&v, "plan")?)
                .with_context(|| format!("parsing {}", path.display()))?;
            let wall = Duration::from_nanos(gu64(&v, "wall_nanos")?);
            let outcome = match v.get("error") {
                Some(e) => Err(anyhow!(
                    "{}",
                    e.as_str().unwrap_or("unrecorded worker error")
                )),
                None => Ok(result_from_json(field(&v, "result")?)
                    .with_context(|| format!("parsing {}", path.display()))?),
            };
            shard_results.push((ji, plan, wall, outcome));
        }
        if !partial {
            ensure!(
                dead.is_empty(),
                "{} task(s) in {} were dead-lettered after repeated failures (see {}/dead/) — fix and re-plan them, or fold the completed work with `mcautotune merge {} --partial`",
                dead.len(),
                self.dir.display(),
                self.dir.display(),
                self.dir.display()
            );
            ensure!(
                outstanding == 0,
                "{} of {} task(s) in {} still have no result — keep `mcautotune worker {}` running, then merge again",
                outstanding,
                h.task_ids.len(),
                self.dir.display(),
                self.dir.display()
            );
        }
        let mut outcomes: Vec<Option<JobOutcome>> = h.jobs.iter().map(|_| None).collect();
        for (ji, result) in h.cached {
            outcomes[ji] = Some(JobOutcome {
                job: h.jobs[ji].clone(),
                result,
                cached: true,
                shards: 0,
                wall: Duration::ZERO,
                plan: Vec::new(),
                shard_states: Vec::new(),
                lower_bound: false,
            });
        }
        let fin = finish_batch(
            &h.jobs,
            &h.descs,
            outcomes,
            &h.shard_counts,
            &h.duplicates,
            shard_results,
            cache,
            partial,
        )?;
        Ok(BatchReport {
            outcomes: fin.outcomes,
            cache_hits: h.plan_hits + (cache.hits - hits_before),
            cache_misses: h.plan_misses + (cache.misses - misses_before),
            stolen_tasks: 0,
            total_elapsed: start.elapsed(),
            partial,
            pending_tasks: outstanding,
            dead_tasks: dead,
            cache_save_error: fin.cache_save_error,
        })
    }
}

/// One live lease as seen by [`TaskDir::status`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LeaseInfo {
    pub id: String,
    /// the `pid@host` tag the leasing worker wrote into the lease file
    /// (`None`: written by an older binary, or the tag write failed)
    pub owner: Option<String>,
    /// time since the last heartbeat (mtime)
    pub age: Duration,
    /// time since the lease was granted, from the `leased_unix_ms` stamp
    /// in the lease file (`None`: stamp missing — older binary — or the
    /// grantor's clock is ahead of ours)
    pub elapsed: Option<Duration>,
}

/// One-shot progress view of a planned batch (CLI `worker --status`).
#[derive(Debug, Clone)]
pub struct TaskStatus {
    /// authoritative task count from `batch.json` (falls back to
    /// available + leased + done for a header-less synthetic dir)
    pub total: usize,
    /// tasks nobody holds (`*.task.json`)
    pub available: usize,
    /// tasks with a published result (`*.result.json`)
    pub done: usize,
    /// live leases, sorted by task id
    pub leases: Vec<LeaseInfo>,
    /// dead-lettered tasks as `(id, captured error)`, sorted by id
    pub dead: Vec<(String, String)>,
}

impl TaskStatus {
    /// Leases held per owner tag, sorted by owner (`?` = unknown owner).
    pub fn per_owner(&self) -> Vec<(String, usize)> {
        let mut counts: std::collections::BTreeMap<String, usize> = Default::default();
        for l in &self.leases {
            *counts.entry(l.owner.clone().unwrap_or_else(|| "?".into())).or_insert(0) += 1;
        }
        counts.into_iter().collect()
    }
}

impl TaskDir {
    /// Snapshot the batch's progress: tasks available / leased (with the
    /// holder's owner tag and heartbeat age) / done. Read-only — safe to
    /// run next to live workers; counts are a best-effort snapshot since
    /// files move mid-scan by design.
    pub fn status(&self) -> Result<TaskStatus> {
        let scan = self.scan()?;
        let total = match self.header() {
            Ok(h) => h.task_ids.len(),
            Err(_) => scan.available.len() + scan.leases.len() + scan.results.len(),
        };
        let now = SystemTime::now();
        let now_ms = unix_ms();
        let mut leases: Vec<LeaseInfo> = scan
            .leases
            .iter()
            .map(|(id, mtime)| {
                let doc = std::fs::read_to_string(self.lease_path(id))
                    .ok()
                    .and_then(|t| Json::parse(&t).ok());
                let owner = doc
                    .as_ref()
                    .and_then(|v| v.get("owner").and_then(Json::as_str).map(str::to_string));
                // optional telemetry stamp (see `unix_ms`); tolerate the
                // string spelling `ju64` uses for values beyond i64
                let elapsed = doc
                    .as_ref()
                    .and_then(|v| v.get("leased_unix_ms"))
                    .and_then(|f| u64_of(f, "leased_unix_ms").ok())
                    .filter(|&t0| t0 > 0 && t0 <= now_ms)
                    .map(|t0| Duration::from_millis(now_ms - t0));
                LeaseInfo {
                    id: id.clone(),
                    owner,
                    age: now.duration_since(*mtime).unwrap_or(Duration::ZERO),
                    elapsed,
                }
            })
            .collect();
        leases.sort_by(|a, b| a.id.cmp(&b.id));
        let mut dead: Vec<(String, String)> = Vec::new();
        if let Ok(entries) = std::fs::read_dir(self.dir.join(DEAD_DIR)) {
            for entry in entries.flatten() {
                let name = entry.file_name();
                let Some(id) = name.to_str().and_then(|n| n.strip_suffix(".json")) else {
                    continue;
                };
                let error = std::fs::read_to_string(entry.path())
                    .ok()
                    .and_then(|t| Json::parse(&t).ok())
                    .and_then(|v| {
                        v.get("dead_error").and_then(Json::as_str).map(str::to_string)
                    })
                    .unwrap_or_else(|| "unrecorded failure".into());
                dead.push((id.to_string(), error));
            }
        }
        dead.sort();
        Ok(TaskStatus {
            total,
            available: scan.available.len(),
            done: scan.results.len(),
            leases,
            dead,
        })
    }
}

/// `pid@host` identity a worker stamps into the leases it holds. The
/// hostname comes from the kernel (HOSTNAME is a shell-internal variable
/// that services and cron jobs never see) with env-var fallbacks, so
/// multi-machine fleets stay distinguishable in `worker --status`.
fn owner_tag() -> String {
    let host = std::fs::read_to_string("/proc/sys/kernel/hostname")
        .ok()
        .map(|h| h.trim().to_string())
        .filter(|h| !h.is_empty())
        .or_else(|| std::env::var("HOSTNAME").ok())
        .or_else(|| std::env::var("COMPUTERNAME").ok())
        .unwrap_or_else(|| "localhost".into());
    format!("{}@{}", std::process::id(), host)
}

/// Milliseconds since the Unix epoch — the wall-clock stamp `try_lease`
/// writes into the lease so `worker --status` can show per-lease elapsed
/// time across processes (mtime only tracks the *last heartbeat*).
fn unix_ms() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map_or(0, |d| d.as_millis().min(u64::MAX as u128) as u64)
}

/// Telemetry for one lease-protocol action (`grant` | `heartbeat` |
/// `reclaim`): bump the matching counter and, when a flight recorder is
/// installed, publish a timed `lease` event tagged with this process's
/// `pid@host` owner. Lease traffic is timing-dependent by nature, so
/// these are *timed* events — they never appear in the deterministic
/// subset ([`crate::obs::deterministic_lines`]).
fn lease_event(action: &str, id: &str) {
    if !crate::obs::enabled() {
        return;
    }
    let m = crate::obs::metrics();
    match action {
        "grant" => m.lease_grants.add(1),
        "heartbeat" => m.lease_heartbeats.add(1),
        _ => m.lease_reclaims.add(1),
    }
    if let Some(rec) = crate::obs::active() {
        rec.event(
            "lease",
            vec![
                ("action", Json::Str(action.to_string())),
                ("id", Json::Str(id.to_string())),
                ("owner", Json::Str(owner_tag())),
            ],
        );
    }
}

/// One contained task failure: the class that goes into the `fault`
/// trace event and the dead-letter record, plus the captured error.
struct TaskFailure {
    /// `panic` | `deadline` | `error` (plus `publish` / `reclaim` /
    /// `cache_save` charged elsewhere)
    class: &'static str,
    error: Error,
}

/// Execute one task body on a dedicated thread with panic containment
/// and a hard deadline. The checker already honors the shard's
/// *cooperative* time budget (`Abort::TimeLimit`); the deadline here is
/// the backstop for a task that wedges outright — an infinite loop in a
/// VM step, a pathological allocation — and would otherwise hold its
/// lease hostage until the TTL reclaim, crediting the crash to the
/// wrong worker. A timed-out thread is abandoned: its eventual send
/// lands in a dropped receiver, and it never publishes (publication
/// happens in [`TaskDir::run`], not on the task thread).
fn execute_task(spec: &TaskSpec) -> std::result::Result<TuneResult, TaskFailure> {
    use std::panic::{catch_unwind, AssertUnwindSafe};
    use std::sync::mpsc::RecvTimeoutError;
    let job = spec.job.clone();
    let plan = spec.plan.clone();
    let swarm = spec.swarm.clone();
    let id = spec.id.clone();
    let (tx, rx) = std::sync::mpsc::channel();
    let handle = std::thread::Builder::new()
        .name(format!("mcat-task-{}", spec.id))
        .spawn(move || {
            // checker/VM state is constructed per task inside the call,
            // so unwinding cannot leave shared state half-mutated
            let r = catch_unwind(AssertUnwindSafe(|| {
                run_shard_task_traced(&job, &plan, &swarm, &id)
            }));
            let _ = tx.send(r); // receiver gone = deadline already fired
        })
        .map_err(|e| TaskFailure {
            class: "error",
            error: anyhow!("spawning task thread: {}", e),
        })?;
    let received = match spec.plan.check.time_budget.map(hard_deadline) {
        Some(d) => match rx.recv_timeout(d) {
            Ok(r) => Some(r),
            Err(RecvTimeoutError::Timeout) => {
                return Err(TaskFailure {
                    class: "deadline",
                    error: anyhow!(
                        "task exceeded its hard deadline of {:?} (shard budget + 50% grace)",
                        d
                    ),
                });
            }
            Err(RecvTimeoutError::Disconnected) => None,
        },
        None => rx.recv().ok(),
    };
    let _ = handle.join();
    match received {
        Some(Ok(Ok(result))) => Ok(result),
        Some(Ok(Err(e))) => Err(TaskFailure { class: "error", error: e }),
        Some(Err(payload)) => Err(TaskFailure {
            class: "panic",
            error: anyhow!("task panicked: {}", panic_message(payload.as_ref())),
        }),
        // unreachable in practice (catch_unwind catches every unwind),
        // but a dead channel must not wedge the worker
        None => Err(TaskFailure {
            class: "error",
            error: anyhow!("task thread exited without reporting a result"),
        }),
    }
}

/// The hard per-attempt deadline for a shard with cooperative budget
/// `b`: `b + b/2 + 1s`. Generous enough that the in-checker budget
/// always fires first on a healthy task (so fault-free runs never see
/// this path), tight enough that a wedged task frees its worker long
/// before operators notice.
fn hard_deadline(budget: Duration) -> Duration {
    budget
        .checked_add(budget / 2)
        .and_then(|d| d.checked_add(Duration::from_secs(1)))
        .unwrap_or(Duration::from_secs(31_536_000))
}

/// Best-effort text of a panic payload (`&str` and `String` cover
/// `panic!` with and without formatting; anything else is opaque).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".into()
    }
}

/// Keep a lease's mtime fresh so a long-running task is not mistaken
/// for a crashed worker and re-leased mid-run. Sleeps in short steps so
/// the stop flag is honored promptly even under tiny test TTLs; the
/// first beat fires at execution start so short tasks still leave one
/// heartbeat in the trace.
fn heartbeat_loop(lease: &Path, ttl: Duration, stop: &AtomicBool, id: &str) {
    let tick = (ttl / 4).max(Duration::from_millis(10));
    let step = tick.min(Duration::from_millis(25));
    let mut since = Duration::ZERO;
    lease_event("heartbeat", id);
    while !stop.load(Ordering::Relaxed) {
        std::thread::sleep(step);
        since += step;
        if since >= tick {
            let _ = touch(lease);
            lease_event("heartbeat", id);
            since = Duration::ZERO;
        }
    }
}

/// Telemetry for one contained failure: a timed `fault` event carrying
/// the failure class, the task it hit, the human-readable detail and
/// the attempt bookkeeping. Fault traffic is schedule-dependent by
/// nature, so like `lease` events it never appears in the deterministic
/// subset ([`crate::obs::deterministic_lines`]).
pub(crate) fn fault_event(class: &str, id: &str, detail: &str, attempts: u32, dead: bool) {
    if !crate::obs::enabled() {
        return;
    }
    if let Some(rec) = crate::obs::active() {
        rec.event(
            "fault",
            vec![
                ("class", Json::Str(class.to_string())),
                ("id", Json::Str(id.to_string())),
                ("detail", Json::Str(detail.to_string())),
                ("attempts", ju64(attempts as u64)),
                ("dead", Json::Bool(dead)),
                ("owner", Json::Str(owner_tag())),
            ],
        );
    }
}

#[derive(Debug, Default)]
struct Scan {
    available: Vec<String>,
    leases: Vec<(String, SystemTime)>,
    results: HashSet<String>,
}

fn touch(path: &Path) -> std::io::Result<()> {
    std::fs::OpenOptions::new()
        .write(true)
        .open(path)?
        .set_modified(SystemTime::now())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tuner::{cached_result, CachedTune};
    use std::sync::atomic::AtomicU32;

    fn temp_dir(tag: &str) -> PathBuf {
        static SEQ: AtomicU32 = AtomicU32::new(0);
        let dir = std::env::temp_dir().join(format!(
            "mcat_taskdir_{}_{}_{}",
            tag,
            std::process::id(),
            SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn sample_spec(id: &str, job_index: usize) -> TaskSpec {
        let mut job = TuningJob::new(ModelKind::Minimum, 16);
        job.name = "π \"quoted\"\nname".into(); // stress JSON escaping
        job.source = Some("int x;\nactive proctype main() { x = 1 }".into());
        job.engine = JobEngine::Promela;
        job.search = SearchMode::Surrogate;
        let check = CheckOptions {
            store: StoreKind::Bitstate { log2_bits: 21, hashes: 5 },
            max_states: u64::MAX,
            time_budget: Some(Duration::from_millis(1234)),
            order: Order::Random(0xDEAD_BEEF_DEAD_BEEF),
            expected_states: 77,
            frontier: Frontier::Deterministic,
            por: true,
            spill_dir: Some(PathBuf::from("/tmp/mcat-spill")),
            ..CheckOptions::default()
        };
        TaskSpec {
            id: id.to_string(),
            job_index,
            shard_index: 1,
            desc: "engine=promela pml=0123456789abcdef method=exhaustive".into(),
            attempts: 0,
            not_before_unix_ms: 0,
            last_error: None,
            job,
            plan: ShardPlan {
                shard: TuningShard { wg_min: 2, wg_max: u32::MAX, ts_min: 0, ts_max: 8 },
                weight: 42,
                t_ini: 99,
                check,
                seeds: vec![
                    Observation { wg: 4, ts: 2, size: 16, time: 120 },
                    Observation { wg: 8, ts: 8, size: 64, time: 90 },
                ],
            },
            swarm: SwarmConfig { seed: u64::MAX - 3, ..SwarmConfig::default() },
        }
    }

    fn fake_result() -> TuneResult {
        cached_result(Method::Exhaustive, CachedTune { wg: 4, ts: 2, t_min: 44, steps: 9 }, "d")
    }

    #[test]
    fn task_spec_roundtrips_through_json() {
        let spec = sample_spec("j000-s001", 0);
        let text = spec.to_json().render();
        let back = TaskSpec::parse(&text).unwrap();
        assert_eq!(spec, back);
        // u64::MAX budgets survive (encoded as strings beyond i64)
        assert_eq!(back.plan.check.max_states, u64::MAX);
        assert!(TaskSpec::parse("{\"version\":2}").is_err());
        assert!(TaskSpec::parse("not json").is_err());
    }

    #[test]
    fn lease_is_exclusive_and_complete_publishes_result() {
        let dir = temp_dir("lease");
        let td = TaskDir::new(&dir);
        td.write_task(&sample_spec("a", 0)).unwrap();
        td.write_task(&sample_spec("b", 1)).unwrap();

        let first = td.lease().unwrap().expect("a task is available");
        let second = td.lease().unwrap().expect("the other task is available");
        assert_ne!(first.spec.id, second.spec.id);
        assert!(td.lease().unwrap().is_none(), "both tasks are leased (and fresh)");

        td.complete(&first, Duration::from_millis(5), Ok(fake_result())).unwrap();
        assert!(dir.join(format!("{}{}", first.spec.id, RESULT_SUFFIX)).exists());
        assert!(
            !dir.join(format!("{}{}", first.spec.id, LEASE_SUFFIX)).exists(),
            "completion releases the lease"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn stale_leases_are_reclaimed() {
        let dir = temp_dir("reclaim");
        let td = TaskDir::new(&dir);
        td.write_task(&sample_spec("a", 0)).unwrap();
        let abandoned = td.lease().unwrap().expect("leasable");
        assert!(!abandoned.reclaimed);
        // the holder "crashes": no heartbeat, no completion. With ttl = 0
        // the lease is immediately stale for a second worker.
        let thief = TaskDir::new(&dir).with_ttl(Duration::ZERO);
        let stolen = thief.lease().unwrap().expect("stale lease must be re-leasable");
        assert_eq!(stolen.spec.id, "a");
        assert!(stolen.reclaimed, "the claim came from reclaiming an expired lease");
        // with a fresh mtime and a sane ttl it is held again
        assert!(td.lease().unwrap().is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn run_skips_tasks_whose_result_already_exists() {
        let dir = temp_dir("dupexec");
        let td = TaskDir::new(&dir);
        // an invalid job (non-pow2 size): executing it would publish an
        // error result, so an intact success result proves run() skipped
        let mut spec = sample_spec("a", 0);
        spec.job.engine = JobEngine::Native;
        spec.job.source = None;
        spec.job.size = 12;
        td.write_task(&spec).unwrap();
        let leased = td.lease().unwrap().unwrap();
        td.complete(&leased, Duration::ZERO, Ok(fake_result())).unwrap();
        // simulate the duplicate executor racing in after the result
        let dup = LeasedTask {
            spec: leased.spec.clone(),
            reclaimed: true,
            lease_path: td.lease_path("a"),
        };
        assert!(!td.run(&dup).unwrap(), "a skip must not report as executed");
        let text = std::fs::read_to_string(td.result_path("a")).unwrap();
        assert!(text.contains("\"result\""), "published result survived: {}", text);
        assert!(!text.contains("\"error\""));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn status_reports_available_leased_done_per_owner() {
        let dir = temp_dir("status");
        let td = TaskDir::new(&dir);
        td.write_task(&sample_spec("a", 0)).unwrap();
        td.write_task(&sample_spec("b", 1)).unwrap();
        td.write_task(&sample_spec("c", 2)).unwrap();
        let st = td.status().unwrap();
        assert_eq!((st.total, st.available, st.done), (3, 3, 0));
        assert!(st.leases.is_empty());

        let held = td.lease().unwrap().expect("leasable");
        let finished = td.lease().unwrap().expect("leasable");
        td.complete(&finished, Duration::ZERO, Ok(fake_result())).unwrap();

        let st = td.status().unwrap();
        assert_eq!((st.total, st.available, st.done), (3, 1, 1));
        assert_eq!(st.leases.len(), 1);
        assert_eq!(st.leases[0].id, held.spec.id);
        let owner = st.leases[0].owner.clone().expect("lease carries its owner tag");
        assert!(
            owner.starts_with(&std::process::id().to_string()),
            "owner `{}` should start with this pid",
            owner
        );
        assert_eq!(st.per_owner(), vec![(owner, 1)]);
        assert!(
            st.leases[0].elapsed.is_some(),
            "lease carries its leased_unix_ms grant stamp"
        );
        // the owner tag must not break re-parsing (extra fields ignored)
        let text = std::fs::read_to_string(dir.join(format!(
            "{}{}",
            held.spec.id, LEASE_SUFFIX
        )))
        .unwrap();
        assert_eq!(TaskSpec::parse(&text).unwrap(), held.spec);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn heartbeats_outpace_tiny_ttl_reclaim() {
        // Clock-skew/staleness stress: with a TTL far below a second, the
        // ttl/4 heartbeat margin must still keep a live lease from being
        // reclaimed by a worker applying the *same* tiny TTL.
        let dir = temp_dir("tinyttl");
        let ttl = Duration::from_millis(80);
        let td = TaskDir::new(&dir).with_ttl(ttl);
        td.write_task(&sample_spec("a", 0)).unwrap();
        let held = td.lease().unwrap().expect("leasable");
        let stop = AtomicBool::new(false);
        std::thread::scope(|scope| {
            let hb = scope.spawn(|| heartbeat_loop(&held.lease_path, ttl, &stop, "a"));
            let rival = TaskDir::new(&dir).with_ttl(ttl);
            let until = Instant::now() + Duration::from_millis(400);
            while Instant::now() < until {
                assert!(
                    rival.lease().unwrap().is_none(),
                    "a heartbeating lease must never look stale"
                );
                std::thread::sleep(Duration::from_millis(10));
            }
            stop.store(true, Ordering::Relaxed);
            let _ = hb.join();
        });
        // once the heartbeat stops, the rival reclaims — and the reclaim
        // charges the crashed attempt into the recreated task file
        let rival = TaskDir::new(&dir).with_ttl(ttl);
        let deadline = Instant::now() + Duration::from_secs(10);
        let stolen = loop {
            if let Some(l) = rival.lease().unwrap() {
                break l;
            }
            assert!(Instant::now() < deadline, "stale lease never became reclaimable");
            std::thread::sleep(Duration::from_millis(20));
        };
        assert_eq!(stolen.spec.id, "a");
        assert_eq!(stolen.spec.attempts, 1, "the reclaim charges one attempt");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn failed_attempts_requeue_then_dead_letter() {
        let dir = temp_dir("deadletter");
        let td = TaskDir::new(&dir).with_max_attempts(2);
        // an invalid job (native engine, non-pow2 size): every execution
        // fails deterministically with a real error, no failpoint needed
        let mut spec = sample_spec("a", 0);
        spec.job.engine = JobEngine::Native;
        spec.job.source = None;
        spec.job.size = 12;
        td.write_task(&spec).unwrap();

        // attempt 1: fails, requeues with the attempt recorded
        let l1 = td.lease().unwrap().expect("leasable");
        assert!(td.run(&l1).unwrap(), "a failing task still counts as executed");
        assert!(!td.result_path("a").exists(), "failures publish no result");
        assert!(!td.dead_path("a").exists());
        assert!(td.task_path("a").exists(), "first failure requeues the task");
        let requeued =
            TaskSpec::parse(&std::fs::read_to_string(td.task_path("a")).unwrap()).unwrap();
        assert_eq!(requeued.attempts, 1);
        assert!(requeued.last_error.is_some());

        // attempt 2 (= max_attempts): dead-letters instead of requeueing.
        // backoff_ms(1) == 0, so the retry is immediately leasable.
        let l2 = td.lease().unwrap().expect("requeued task is leasable");
        assert_eq!(l2.spec.attempts, 1);
        assert!(td.run(&l2).unwrap());
        assert!(td.dead_path("a").exists(), "max attempts reached: dead-lettered");
        assert!(!td.task_path("a").exists());
        assert!(!td.lease_path("a").exists());
        let dead_text = std::fs::read_to_string(td.dead_path("a")).unwrap();
        let dv = Json::parse(&dead_text).unwrap();
        assert_eq!(gusize(&dv, "attempts").unwrap(), 2);
        assert!(dv.get("dead_error").is_some());
        // nothing leasable, and the task no longer counts as remaining
        assert!(td.lease().unwrap().is_none());
        assert_eq!(td.remaining(&["a".to_string()]).unwrap(), 0);
        // status surfaces it
        let st = td.status().unwrap();
        assert_eq!(st.dead.len(), 1);
        assert_eq!(st.dead[0].0, "a");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn reclaim_dead_letters_and_partial_merge_reports() {
        let dir = temp_dir("partial");
        let td = TaskDir::new(&dir);
        let jobs = vec![
            TuningJob::new(ModelKind::Minimum, 16),
            TuningJob::new(ModelKind::Minimum, 32),
        ];
        let mut cache = ResultCache::in_memory();
        let summary = td.plan(&jobs, &BatchOptions::default(), &mut cache).unwrap();
        assert!(summary.tasks >= 2);
        // run job 0's tasks to completion; abandon job 1's leases
        let mut abandoned = Vec::new();
        while let Some(l) = td.lease().unwrap() {
            if l.spec.job_index == 0 {
                assert!(td.run(&l).unwrap());
            } else {
                abandoned.push(l); // held, never heartbeated, never run
            }
        }
        assert!(!abandoned.is_empty(), "job 1 must have abandoned leases");
        // a zero-TTL single-attempt worker reclaims them straight to the
        // dead-letter directory
        let killer = TaskDir::new(&dir).with_ttl(Duration::ZERO).with_max_attempts(1);
        // one lease() call reclaims every stale lease; at max_attempts=1
        // each reclaim dead-letters, so nothing comes back claimable
        if let Some(l) = killer.lease().unwrap() {
            panic!("{} should have been dead-lettered, not re-leased", l.spec.id);
        }
        for l in &abandoned {
            assert!(
                killer.dead_path(&l.spec.id).exists(),
                "{} should be dead-lettered",
                l.spec.id
            );
        }
        // a strict merge refuses, naming the dead-letter escape hatch
        let err = td.merge(&mut cache).unwrap_err();
        assert!(format!("{:#}", err).contains("dead-lettered"), "{:#}", err);
        // the partial merge degrades: job 0 merges for real, job 1 is
        // reported dead, nothing about job 1 lands in the cache
        let report = td.merge_partial(&mut cache).unwrap();
        assert!(report.partial);
        assert_eq!(report.pending_tasks, 0);
        assert!(!report.dead_tasks.is_empty());
        assert!(report.dead_tasks.iter().all(|d| d.job_index == 1));
        assert!(report.outcomes.iter().any(|o| o.job.size == 16 && !o.lower_bound));
        let rendered = report.render();
        assert!(rendered.contains("dead-lettered"), "{}", rendered);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn merge_refuses_outstanding_tasks() {
        let dir = temp_dir("outstanding");
        let td = TaskDir::new(&dir);
        let jobs = vec![TuningJob::new(ModelKind::Minimum, 16)];
        let mut cache = ResultCache::in_memory();
        let summary = td.plan(&jobs, &BatchOptions::default(), &mut cache).unwrap();
        assert_eq!(summary.jobs, 1);
        assert!(summary.tasks >= 1);
        let (open, total) = td.outstanding().unwrap();
        assert_eq!((open, total), (summary.tasks, summary.tasks));
        let err = td.merge(&mut cache).unwrap_err();
        assert!(format!("{:#}", err).contains("still have no result"));
        // planning twice into the same dir is refused
        assert!(td.plan(&jobs, &BatchOptions::default(), &mut cache).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
