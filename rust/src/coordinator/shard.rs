//! Parameter-space sharding.
//!
//! The swarm (paper §5) diversifies *search order*: every worker explores
//! the same space with a different seed. Sharding instead partitions the
//! *space*: the (WG, TS) tuning lattice is split into axis-aligned
//! sub-lattices ([`TuningShard`]) that are checked completely
//! independently — each shard sees only the runs whose tuning choice
//! falls inside it — and the per-shard counterexample optima are merged
//! ([`merge_results`]). Because the tuning choice is the model's only
//! nondeterminism, the shard state spaces are disjoint below the choice
//! point, so sharding loses no behaviour and the merged optimum equals
//! the unsharded one.

use crate::model::TransitionSystem;
use crate::platform::Tuning;
use crate::tuner::TuneResult;
use crate::util::error::{ensure, Result};

/// An axis-aligned sub-lattice of the tuning space (inclusive bounds).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TuningShard {
    pub wg_min: u32,
    pub wg_max: u32,
    pub ts_min: u32,
    pub ts_max: u32,
}

impl TuningShard {
    /// The shard covering every tuning.
    pub fn full() -> Self {
        Self { wg_min: 0, wg_max: u32::MAX, ts_min: 0, ts_max: u32::MAX }
    }

    pub fn contains(&self, t: Tuning) -> bool {
        t.wg >= self.wg_min && t.wg <= self.wg_max && t.ts >= self.ts_min && t.ts <= self.ts_max
    }
}

impl std::fmt::Display for TuningShard {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "WG[{}..{}] TS[{}..{}]", self.wg_min, self.wg_max, self.ts_min, self.ts_max)
    }
}

/// Split sorted distinct values into `k` balanced contiguous chunks,
/// returned as (first, last) inclusive ranges.
fn chunk_ranges(values: &[u32], k: usize) -> Vec<(u32, u32)> {
    let k = k.min(values.len()).max(1);
    let base = values.len() / k;
    let rem = values.len() % k;
    let mut out = Vec::with_capacity(k);
    let mut start = 0usize;
    for i in 0..k {
        let len = base + usize::from(i < rem);
        out.push((values[start], values[start + len - 1]));
        start += len;
    }
    out
}

/// Partition `tunings` into at most `n` non-empty shards: the distinct WG
/// values are split into up to `n` contiguous ranges, and when the WG
/// axis alone cannot supply `n` shards the TS axis is split as well
/// (a rows × cols grid with rows·cols ≤ n). Cells containing no tuning
/// are dropped; every tuning lands in exactly one shard.
pub fn partition(tunings: &[Tuning], n: u32) -> Vec<TuningShard> {
    if tunings.is_empty() {
        return Vec::new();
    }
    let n = n.max(1) as usize;
    let mut wgs: Vec<u32> = tunings.iter().map(|t| t.wg).collect();
    wgs.sort_unstable();
    wgs.dedup();
    let mut tss: Vec<u32> = tunings.iter().map(|t| t.ts).collect();
    tss.sort_unstable();
    tss.dedup();

    let rows = n.min(wgs.len());
    let cols = (n / rows).clamp(1, tss.len());
    let wg_ranges = chunk_ranges(&wgs, rows);
    let ts_ranges = chunk_ranges(&tss, cols);

    let mut shards = Vec::with_capacity(rows * cols);
    for &(wg_min, wg_max) in &wg_ranges {
        for &(ts_min, ts_max) in &ts_ranges {
            let shard = TuningShard { wg_min, wg_max, ts_min, ts_max };
            if tunings.iter().any(|&t| shard.contains(t)) {
                shards.push(shard);
            }
        }
    }
    shards
}

/// A transition system restricted to one shard: successors that commit to
/// a (WG, TS) outside the shard are pruned at the nondeterministic-choice
/// point. Generic over the model — the only requirement is that states
/// expose `WG`/`TS` through `eval_var` once (and only once) the tuning is
/// chosen, which both native models do.
pub struct ShardModel<'a, M: TransitionSystem> {
    pub inner: &'a M,
    pub shard: TuningShard,
}

impl<'a, M: TransitionSystem> TransitionSystem for ShardModel<'a, M> {
    type State = M::State;

    fn initial_states(&self) -> Vec<M::State> {
        self.inner.initial_states()
    }

    fn successors(&self, s: &M::State, out: &mut Vec<M::State>) {
        self.inner.successors(s, out);
        // keep states that have not chosen a tuning yet (WG/TS unobservable)
        out.retain(|n| {
            match (self.inner.eval_var(n, "WG"), self.inner.eval_var(n, "TS")) {
                (Some(wg), Some(ts)) => {
                    self.shard.contains(Tuning { wg: wg as u32, ts: ts as u32 })
                }
                _ => true,
            }
        });
    }

    fn encode(&self, s: &M::State, out: &mut Vec<u8>) {
        self.inner.encode(s, out)
    }

    fn eval_var(&self, s: &M::State, name: &str) -> Option<i64> {
        self.inner.eval_var(s, name)
    }

    fn resolve_slot(&self, name: &str) -> Option<u32> {
        self.inner.resolve_slot(name)
    }

    fn eval_slots(&self, s: &M::State, ids: &[u32], out: &mut [i64]) -> u64 {
        self.inner.eval_slots(s, ids, out)
    }

    fn describe(&self, s: &M::State) -> String {
        self.inner.describe(s)
    }
}

/// Merge per-shard tune results into one job-level result: the optimum is
/// the minimum over shards (deterministic (time, WG, TS) tie-break), the
/// first trail is the earliest across shards, state/transition work is
/// summed, and per-shard logs are concatenated with shard tags.
/// `peak_bytes` is summed too — shards run concurrently, so their stores
/// are resident together.
pub fn merge_results(parts: Vec<TuneResult>) -> Result<TuneResult> {
    ensure!(!parts.is_empty(), "no shard results to merge");
    let method = parts[0].method;
    let mut optimal = None;
    let mut first_trail: Option<(crate::tuner::TuningWitness, std::time::Duration)> = None;
    let mut states = 0u64;
    let mut bytes = 0u64;
    let mut elapsed = std::time::Duration::ZERO;
    let mut log = Vec::new();
    for (i, part) in parts.into_iter().enumerate() {
        states += part.states_explored;
        bytes += part.peak_bytes;
        elapsed += part.elapsed;
        let better = match &optimal {
            None => true,
            Some(best) => {
                (part.optimal.time, part.optimal.wg, part.optimal.ts)
                    < (best.time, best.wg, best.ts)
            }
        };
        if better {
            optimal = Some(part.optimal);
        }
        if let Some((w, d)) = part.first_trail {
            if first_trail.as_ref().map_or(true, |(_, best_d)| d < *best_d) {
                first_trail = Some((w, d));
            }
        }
        for line in part.log {
            log.push(format!("[shard {}] {}", i, line));
        }
    }
    let optimal = optimal.expect("at least one shard result");
    let t_min = optimal.time;
    Ok(TuneResult {
        method,
        optimal,
        t_min,
        first_trail_optimality: first_trail.as_ref().map(|(w, _)| t_min as f64 / w.time as f64),
        first_trail,
        states_explored: states,
        peak_bytes: bytes,
        elapsed,
        log,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checker::{check, CheckOptions};
    use crate::model::SafetyLtl;
    use crate::platform::{enumerate_tunings, MinModel};

    #[test]
    fn partition_is_exact_cover() {
        for size in [16u32, 64, 256] {
            let tunings = enumerate_tunings(size).unwrap();
            for n in [1u32, 2, 3, 4, 7, 100] {
                let shards = partition(&tunings, n);
                assert!(!shards.is_empty());
                assert!(shards.len() <= n.max(1) as usize, "size {} n {}", size, n);
                for &t in &tunings {
                    let owners = shards.iter().filter(|s| s.contains(t)).count();
                    assert_eq!(owners, 1, "tuning {:?} owned by {} shards (n={})", t, owners, n);
                }
            }
        }
    }

    #[test]
    fn partition_empty_and_oversized() {
        assert!(partition(&[], 4).is_empty());
        let tunings = enumerate_tunings(16).unwrap();
        // more shards than tunings: every shard still owns >= 1 tuning
        let shards = partition(&tunings, 1000);
        assert!(shards.len() <= tunings.len());
    }

    #[test]
    fn shard_model_explores_only_its_sublattice() {
        let m = MinModel::paper(64, 4).unwrap();
        let shard = TuningShard { wg_min: 2, wg_max: 4, ts_min: 0, ts_max: u32::MAX };
        let sm = ShardModel { inner: &m, shard };
        let co = CheckOptions { collect_all: true, ..Default::default() };
        let rep = check(&sm, &SafetyLtl::non_termination(), &co).unwrap();
        assert!(rep.found());
        for v in &rep.violations {
            let wg = m.eval_var(v.trail.last(), "WG").unwrap();
            assert!((2..=4).contains(&wg), "WG {} escaped the shard", wg);
        }
        // the union of two complementary shards covers every tuning
        let rest = TuningShard { wg_min: 8, wg_max: u32::MAX, ts_min: 0, ts_max: u32::MAX };
        let sm2 = ShardModel { inner: &m, shard: rest };
        let rep2 = check(&sm2, &SafetyLtl::non_termination(), &co).unwrap();
        assert_eq!(
            rep.violations.len() + rep2.violations.len(),
            m.tunings().len(),
            "each tuning terminates exactly once across complementary shards"
        );
    }

    #[test]
    fn merge_empty_is_error() {
        assert!(merge_results(Vec::new()).is_err());
    }
}
