//! Parameter-space sharding.
//!
//! The swarm (paper §5) diversifies *search order*: every worker explores
//! the same space with a different seed. Sharding instead partitions the
//! *space*: the (WG, TS) tuning lattice is split into axis-aligned
//! sub-lattices ([`TuningShard`]) that are checked completely
//! independently — each shard sees only the runs whose tuning choice
//! falls inside it — and the per-shard counterexample optima are merged
//! ([`merge_results`]). Because the tuning choice is the model's only
//! nondeterminism, the shard state spaces are disjoint below the choice
//! point, so sharding loses no behaviour and the merged optimum equals
//! the unsharded one.

use crate::checker::CheckOptions;
use crate::model::TransitionSystem;
use crate::platform::Tuning;
use crate::tuner::TuneResult;
use crate::util::error::{ensure, Result};
use std::time::Duration;

/// An axis-aligned sub-lattice of the tuning space (inclusive bounds).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TuningShard {
    pub wg_min: u32,
    pub wg_max: u32,
    pub ts_min: u32,
    pub ts_max: u32,
}

impl TuningShard {
    /// The shard covering every tuning.
    pub fn full() -> Self {
        Self { wg_min: 0, wg_max: u32::MAX, ts_min: 0, ts_max: u32::MAX }
    }

    pub fn contains(&self, t: Tuning) -> bool {
        t.wg >= self.wg_min && t.wg <= self.wg_max && t.ts >= self.ts_min && t.ts <= self.ts_max
    }

    /// These bounds as compile-time constants for the Promela VM
    /// ([`crate::promela::vm::PromelaVm::specialized`]): the compiled
    /// program prunes off-shard (WG, TS) commitments at the choice point
    /// instead of this module's [`ShardModel`] re-filtering every
    /// generated successor. Both paths explore the identical state space
    /// (see the VM module docs for the contract), so results, state
    /// counts and cache entries are unchanged — only the wasted successor
    /// materialization disappears.
    pub fn promela_bounds(&self) -> crate::promela::TuningBounds {
        crate::promela::TuningBounds {
            wg_min: self.wg_min,
            wg_max: self.wg_max,
            ts_min: self.ts_min,
            ts_max: self.ts_max,
        }
    }
}

impl std::fmt::Display for TuningShard {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "WG[{}..{}] TS[{}..{}]", self.wg_min, self.wg_max, self.ts_min, self.ts_max)
    }
}

/// Split sorted distinct values into `k` balanced contiguous chunks,
/// returned as (first, last) inclusive ranges.
fn chunk_ranges(values: &[u32], k: usize) -> Vec<(u32, u32)> {
    let k = k.min(values.len()).max(1);
    let base = values.len() / k;
    let rem = values.len() % k;
    let mut out = Vec::with_capacity(k);
    let mut start = 0usize;
    for i in 0..k {
        let len = base + usize::from(i < rem);
        out.push((values[start], values[start + len - 1]));
        start += len;
    }
    out
}

/// Partition `tunings` into at most `n` non-empty shards: the distinct WG
/// values are split into up to `n` contiguous ranges, and when the WG
/// axis alone cannot supply `n` shards the TS axis is split as well
/// (a rows × cols grid with rows·cols ≤ n). Cells containing no tuning
/// are dropped; every tuning lands in exactly one shard.
pub fn partition(tunings: &[Tuning], n: u32) -> Vec<TuningShard> {
    if tunings.is_empty() {
        return Vec::new();
    }
    let n = n.max(1) as usize;
    let mut wgs: Vec<u32> = tunings.iter().map(|t| t.wg).collect();
    wgs.sort_unstable();
    wgs.dedup();
    let mut tss: Vec<u32> = tunings.iter().map(|t| t.ts).collect();
    tss.sort_unstable();
    tss.dedup();

    let rows = n.min(wgs.len());
    let cols = (n / rows).clamp(1, tss.len());
    let wg_ranges = chunk_ranges(&wgs, rows);
    let ts_ranges = chunk_ranges(&tss, cols);

    let mut shards = Vec::with_capacity(rows * cols);
    for &(wg_min, wg_max) in &wg_ranges {
        for &(ts_min, ts_max) in &ts_ranges {
            let shard = TuningShard { wg_min, wg_max, ts_min, ts_max };
            if tunings.iter().any(|&t| shard.contains(t)) {
                shards.push(shard);
            }
        }
    }
    shards
}

/// A transition system restricted to one shard: successors that commit to
/// a (WG, TS) outside the shard are pruned *after generation*, by
/// re-filtering the successor buffer. Generic over the model — the only
/// requirement is that states expose `WG`/`TS` once the tuning is chosen.
/// "Not chosen yet" is either an *absent* observation (the native models
/// return `None` / a masked slot before the choice) or a *non-positive*
/// value (the Promela engine's globals exist from the start, initialized
/// to 0; real tunings are powers of two >= 2, so 0 is unambiguous).
///
/// Promela batch jobs no longer run through this wrapper: the VM compiles
/// the shard bounds into the program ([`TuningShard::promela_bounds`]) and
/// never generates off-shard states in the first place. This wrapper
/// remains the generic path for the native models (whose successor
/// generation is closed-form cheap) and the reference path the
/// differential suite compares the specialized VM against — plus the
/// fallback for pathological Promela sources whose initial image already
/// commits a tuning (see `promela::vm::tuning_committed_at_init`).
pub struct ShardModel<'a, M: TransitionSystem> {
    pub inner: &'a M,
    pub shard: TuningShard,
    /// pre-resolved (WG, TS) dense-slot ids when the model supports them —
    /// the per-successor prune then skips the string lookups (PromelaSystem
    /// resolves names through a hash map; this is its pruning hot path)
    slots: Option<(u32, u32)>,
}

impl<'a, M: TransitionSystem> ShardModel<'a, M> {
    pub fn new(inner: &'a M, shard: TuningShard) -> Self {
        let slots = match (inner.resolve_slot("WG"), inner.resolve_slot("TS")) {
            (Some(w), Some(t)) => Some((w, t)),
            _ => None,
        };
        Self { inner, shard, slots }
    }

    /// The (WG, TS) a state has committed to, or `None` before the choice.
    fn observed_tuning(&self, s: &M::State) -> Option<Tuning> {
        let (wg, ts) = match self.slots {
            Some((w, t)) => {
                let ids = [w, t];
                let mut out = [0i64; 2];
                if self.inner.eval_slots(s, &ids, &mut out) & 0b11 != 0 {
                    return None;
                }
                (out[0], out[1])
            }
            None => match (self.inner.eval_var(s, "WG"), self.inner.eval_var(s, "TS")) {
                (Some(wg), Some(ts)) => (wg, ts),
                _ => return None,
            },
        };
        if wg > 0 && ts > 0 {
            Some(Tuning { wg: wg as u32, ts: ts as u32 })
        } else {
            None
        }
    }
}

impl<'a, M: TransitionSystem> TransitionSystem for ShardModel<'a, M> {
    type State = M::State;

    fn initial_states(&self) -> Vec<M::State> {
        self.inner.initial_states()
    }

    fn successors(&self, s: &M::State, out: &mut Vec<M::State>) {
        self.inner.successors(s, out);
        // keep states that have not chosen a tuning yet
        out.retain(|n| match self.observed_tuning(n) {
            Some(t) => self.shard.contains(t),
            None => true,
        });
    }

    fn encode(&self, s: &M::State, out: &mut Vec<u8>) {
        self.inner.encode(s, out)
    }

    fn eval_var(&self, s: &M::State, name: &str) -> Option<i64> {
        self.inner.eval_var(s, name)
    }

    fn resolve_slot(&self, name: &str) -> Option<u32> {
        self.inner.resolve_slot(name)
    }

    fn eval_slots(&self, s: &M::State, ids: &[u32], out: &mut [i64]) -> u64 {
        self.inner.eval_slots(s, ids, out)
    }

    fn describe(&self, s: &M::State) -> String {
        self.inner.describe(s)
    }
}

/// One shard's execution plan: the sub-lattice, its estimated state-space
/// weight, and the budgets derived from it (see [`plan_shards`]).
#[derive(Debug, Clone, PartialEq)]
pub struct ShardPlan {
    pub shard: TuningShard,
    /// estimated state-space weight: sum of per-tuning cost estimates of
    /// the tunings this shard owns (see `TuningJob::tuning_costs`)
    pub weight: u64,
    /// initial over-time bound for the shard's bisection: the largest
    /// per-tuning cost in the shard. For closed-form jobs the costs *are*
    /// the terminal times, so `Cex(t_ini)` holds immediately; external
    /// Promela sources are weighted by guided-simulation terminal times
    /// (also achievable, hence also sound), and whenever a walk fell back
    /// to step counts bisection's doubling loop takes over. Either way
    /// the batch runner never needs random simulation on a sharded model
    /// — where a walk can dead-end in a pruned branch (Promela assigns WG
    /// before TS, so a wrong-WG prefix only prunes at the TS choice) and
    /// make `T_ini` discovery flaky.
    pub t_ini: i64,
    /// the shard's verification options — job-level budgets scaled by
    /// `weight / total_weight`, plus `expected_states` for store pre-sizing
    pub check: CheckOptions,
    /// surrogate warm-start observations harvested from the result cache
    /// at plan time (`search=surrogate` jobs only; empty otherwise).
    /// Worker-mode manifests ship them with the plan, so a remote drain
    /// warm-starts exactly like an in-process run; too few seeds simply
    /// mean the shard falls back to exhaustive search — never a wrong
    /// answer (see [`crate::tuner::surrogate`]).
    pub seeds: Vec<crate::tuner::Observation>,
}

/// Estimated state-space weight of one shard under `costs`.
pub fn shard_weight(costs: &[(Tuning, u64)], shard: &TuningShard) -> u64 {
    costs
        .iter()
        .filter(|&&(t, _)| shard.contains(t))
        .map(|&(_, c)| c)
        .sum::<u64>()
        .max(1)
}

/// Split the *job-level* budgets in `base` across `shards` proportionally
/// to each shard's estimated state-space weight, instead of handing every
/// shard the full (or a uniform) budget:
///
/// - `max_states`, `memory_budget` and `time_budget` scale by
///   `weight / total`, floored at a 1/(4·n) share so estimate error can
///   never starve a shard outright (`u64::MAX` max_states and an unset
///   time budget stay unlimited);
/// - `expected_states` is set to the shard's weight, pre-sizing its
///   visited store (`checker`'s arena shards never rehash under lock when
///   the estimate holds).
///
/// Shards run concurrently, so the proportional split makes the *sum* of
/// live budgets equal the job budget — uniform per-shard budgets would
/// multiply it by the shard count. Swarm-method jobs are budgeted by
/// `SwarmConfig` and ignore these knobs.
pub fn plan_shards(
    shards: Vec<TuningShard>,
    costs: &[(Tuning, u64)],
    base: &CheckOptions,
) -> Vec<ShardPlan> {
    let weights: Vec<u64> = shards.iter().map(|sh| shard_weight(costs, sh)).collect();
    let total = weights.iter().sum::<u64>().max(1);
    let n = shards.len().max(1) as u64;
    shards
        .into_iter()
        .zip(weights)
        .map(|(shard, weight)| {
            let share = |budget: u64| -> u64 {
                let scaled = (budget as u128 * weight as u128 / total as u128) as u64;
                scaled.max(budget / (4 * n)).max(1)
            };
            let t_ini = costs
                .iter()
                .filter(|&&(t, _)| shard.contains(t))
                .map(|&(_, c)| c)
                .max()
                .unwrap_or(1)
                .max(1) as i64;
            let mut check = base.clone();
            check.expected_states = weight;
            if base.max_states != u64::MAX {
                check.max_states = share(base.max_states);
            }
            check.memory_budget = share(base.memory_budget);
            if let Some(tb) = base.time_budget {
                check.time_budget = Some(Duration::from_nanos(share(
                    tb.as_nanos().min(u64::MAX as u128) as u64,
                )));
            }
            ShardPlan { shard, weight, t_ini, check, seeds: Vec::new() }
        })
        .collect()
}

/// Derive a default shard count from a job's total estimated state-space
/// weight (used when neither the job spec nor `--shards` pins one): one
/// shard per ~256 weight units, at least 1, at most `2 × workers` (more
/// shards than that only add merge overhead) and never more than the
/// tuning count (a shard must own at least one tuning).
pub fn adaptive_shard_count(total_weight: u64, workers: u32, n_tunings: usize) -> u32 {
    const TARGET_WEIGHT_PER_SHARD: u64 = 256;
    let cap = (workers.max(1) * 2).min(n_tunings.max(1) as u32);
    (total_weight / TARGET_WEIGHT_PER_SHARD).clamp(1, cap as u64) as u32
}

/// Merge per-shard tune results into one job-level result: the optimum is
/// the minimum over shards (deterministic (time, WG, TS) tie-break), the
/// first trail is the earliest across shards, state/transition work is
/// summed, and per-shard logs are concatenated with shard tags.
/// `peak_bytes` is summed too — shards run concurrently, so their stores
/// are resident together.
pub fn merge_results(parts: Vec<TuneResult>) -> Result<TuneResult> {
    ensure!(!parts.is_empty(), "no shard results to merge");
    let method = parts[0].method;
    let mut optimal = None;
    let mut first_trail: Option<(crate::tuner::TuningWitness, std::time::Duration)> = None;
    let mut states = 0u64;
    let mut bytes = 0u64;
    let mut elapsed = std::time::Duration::ZERO;
    let mut log = Vec::new();
    for (i, part) in parts.into_iter().enumerate() {
        states += part.states_explored;
        bytes += part.peak_bytes;
        elapsed += part.elapsed;
        let better = match &optimal {
            None => true,
            Some(best) => {
                (part.optimal.time, part.optimal.wg, part.optimal.ts)
                    < (best.time, best.wg, best.ts)
            }
        };
        if better {
            optimal = Some(part.optimal);
        }
        if let Some((w, d)) = part.first_trail {
            if first_trail.as_ref().map_or(true, |(_, best_d)| d < *best_d) {
                first_trail = Some((w, d));
            }
        }
        for line in part.log {
            log.push(format!("[shard {}] {}", i, line));
        }
    }
    let optimal = optimal.expect("at least one shard result");
    let t_min = optimal.time;
    Ok(TuneResult {
        method,
        optimal,
        t_min,
        first_trail_optimality: first_trail.as_ref().map(|(w, _)| t_min as f64 / w.time as f64),
        first_trail,
        states_explored: states,
        peak_bytes: bytes,
        elapsed,
        log,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checker::{check, CheckOptions};
    use crate::model::SafetyLtl;
    use crate::platform::{enumerate_tunings, MinModel};

    #[test]
    fn partition_is_exact_cover() {
        for size in [16u32, 64, 256] {
            let tunings = enumerate_tunings(size).unwrap();
            for n in [1u32, 2, 3, 4, 7, 100] {
                let shards = partition(&tunings, n);
                assert!(!shards.is_empty());
                assert!(shards.len() <= n.max(1) as usize, "size {} n {}", size, n);
                for &t in &tunings {
                    let owners = shards.iter().filter(|s| s.contains(t)).count();
                    assert_eq!(owners, 1, "tuning {:?} owned by {} shards (n={})", t, owners, n);
                }
            }
        }
    }

    #[test]
    fn partition_empty_and_oversized() {
        assert!(partition(&[], 4).is_empty());
        let tunings = enumerate_tunings(16).unwrap();
        // more shards than tunings: every shard still owns >= 1 tuning
        let shards = partition(&tunings, 1000);
        assert!(shards.len() <= tunings.len());
    }

    #[test]
    fn shard_model_explores_only_its_sublattice() {
        let m = MinModel::paper(64, 4).unwrap();
        let shard = TuningShard { wg_min: 2, wg_max: 4, ts_min: 0, ts_max: u32::MAX };
        let sm = ShardModel::new(&m, shard);
        let co = CheckOptions { collect_all: true, ..Default::default() };
        let rep = check(&sm, &SafetyLtl::non_termination(), &co).unwrap();
        assert!(rep.found());
        for v in &rep.violations {
            let wg = m.eval_var(v.trail.last(), "WG").unwrap();
            assert!((2..=4).contains(&wg), "WG {} escaped the shard", wg);
        }
        // the union of two complementary shards covers every tuning
        let rest = TuningShard { wg_min: 8, wg_max: u32::MAX, ts_min: 0, ts_max: u32::MAX };
        let sm2 = ShardModel::new(&m, rest);
        let rep2 = check(&sm2, &SafetyLtl::non_termination(), &co).unwrap();
        assert_eq!(
            rep.violations.len() + rep2.violations.len(),
            m.tunings().len(),
            "each tuning terminates exactly once across complementary shards"
        );
    }

    #[test]
    fn merge_empty_is_error() {
        assert!(merge_results(Vec::new()).is_err());
    }

    #[test]
    fn plan_shards_budgets_scale_with_weight() {
        let tunings = enumerate_tunings(64).unwrap();
        // synthetic costs: weight grows with WG so shard weights differ
        let costs: Vec<(crate::platform::Tuning, u64)> =
            tunings.iter().map(|&t| (t, (t.wg * 10) as u64)).collect();
        let mut base = CheckOptions::default();
        base.max_states = 1_000_000;
        base.memory_budget = 1 << 30;
        base.time_budget = Some(Duration::from_secs(10));
        let plans = plan_shards(partition(&tunings, 4), &costs, &base);
        assert!(plans.len() >= 2);
        let total: u64 = plans.iter().map(|p| p.weight).sum();
        for p in &plans {
            assert_eq!(p.check.expected_states, p.weight);
            assert!(p.check.max_states <= base.max_states);
            assert!(p.check.memory_budget <= base.memory_budget);
            // t_ini = the largest in-shard cost (a sound over-time bound)
            let max_cost = costs
                .iter()
                .filter(|&&(t, _)| p.shard.contains(t))
                .map(|&(_, c)| c)
                .max()
                .unwrap();
            assert_eq!(p.t_ini, max_cost as i64);
        }
        // monotone: a heavier shard never gets a smaller budget
        let mut sorted = plans.clone();
        sorted.sort_by_key(|p| p.weight);
        for w in sorted.windows(2) {
            assert!(w[1].check.max_states >= w[0].check.max_states);
            assert!(w[1].check.memory_budget >= w[0].check.memory_budget);
            assert!(w[1].check.time_budget.unwrap() >= w[0].check.time_budget.unwrap());
        }
        // proportionality: the heaviest shard's state budget is close to
        // its weight share (floors only lift the small shards)
        let heaviest = sorted.last().unwrap();
        let expect = (base.max_states as u128 * heaviest.weight as u128 / total as u128) as u64;
        assert_eq!(heaviest.check.max_states, expect);
        // unlimited budgets stay unlimited
        let plans = plan_shards(partition(&tunings, 4), &costs, &CheckOptions::default());
        assert!(plans.iter().all(|p| p.check.max_states == u64::MAX));
        assert!(plans.iter().all(|p| p.check.time_budget.is_none()));
    }

    #[test]
    fn adaptive_shard_count_scales_and_clamps() {
        // tiny jobs: one shard; growing weight: more shards; capped
        assert_eq!(adaptive_shard_count(10, 4, 16), 1);
        assert_eq!(adaptive_shard_count(1024, 4, 16), 4);
        assert_eq!(adaptive_shard_count(u64::MAX / 2, 4, 16), 8, "capped at 2x workers");
        assert_eq!(adaptive_shard_count(u64::MAX / 2, 4, 3), 3, "capped at tuning count");
        assert_eq!(adaptive_shard_count(0, 0, 0), 1);
        // monotone in weight
        let mut last = 0;
        for w in [0u64, 300, 600, 1200, 2400, 1 << 40] {
            let n = adaptive_shard_count(w, 8, 1000);
            assert!(n >= last);
            last = n;
        }
    }
}
