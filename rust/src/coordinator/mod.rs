//! The coordinator — the paper's L3 coordination layer grown into a
//! batch tuning *service*.
//!
//! The paper tunes one kernel for one platform and one input size at a
//! time. Production auto-tuning workloads are batches: many input sizes,
//! platform configurations and search methods tuned concurrently, with
//! results reused across jobs. This module supplies that layer:
//!
//! - [`TuningJob`] (in [`job`]) — a declarative job spec (model kind,
//!   size, platform config, granularity, method, sharding degree),
//!   parseable from a plain-text spec file;
//! - [`partition`] / [`ShardModel`] (in [`shard`]) — each job's (WG, TS)
//!   lattice is split into sub-lattices checked independently and merged,
//!   generalizing the swarm's diversified-*seed* workers to
//!   partitioned-*space* workers;
//! - [`JobQueue`] (in [`queue`]) — a work-stealing runner that executes
//!   the (job × shard) task set across std threads;
//! - [`ResultCache`] (in [`cache`]) — a content-addressed result store
//!   keyed by `util::hash` of the job description, persisted to JSON via
//!   `util::manifest::Json`, so repeated and overlapping jobs skip
//!   verification entirely;
//! - [`BatchReport`] (in [`report`]) — per-job optima plus cache/queue
//!   statistics, rendered for the `mcautotune batch` subcommand.
//!
//! [`run_batch`] composes them: cache lookups first (hits and duplicate
//! jobs complete immediately), then one task per remaining (job, shard),
//! then per-job merge + cache write-back.

pub mod cache;
pub mod job;
pub mod queue;
pub mod report;
pub mod shard;

pub use cache::{CacheEntry, ResultCache};
pub use job::{JobModel, JobState, ModelKind, TuningJob};
pub use queue::{JobQueue, QueueStats};
pub use report::{BatchReport, JobOutcome};
pub use shard::{merge_results, partition, ShardModel, TuningShard};

use crate::checker::CheckOptions;
use crate::platform::enumerate_tunings;
use crate::swarm::SwarmConfig;
use crate::tuner::{cached_result, tune, TuneCache, TuneResult};
use crate::util::error::{bail, Context, Result};
use std::collections::HashMap;
use std::time::{Duration, Instant};

/// Batch-wide execution options (per-job knobs live on [`TuningJob`]).
#[derive(Debug, Clone)]
pub struct BatchOptions {
    /// queue worker threads
    pub workers: u32,
    /// shard count for jobs that left `shards` unset (0)
    pub default_shards: u32,
    /// per-shard verification options (store kind, budgets)
    pub check: CheckOptions,
    /// per-shard swarm configuration (Method::Swarm jobs)
    pub swarm: SwarmConfig,
}

impl Default for BatchOptions {
    fn default() -> Self {
        Self {
            workers: 4,
            default_shards: 4,
            check: CheckOptions::default(),
            swarm: SwarmConfig::default(),
        }
    }
}

/// Run a batch of tuning jobs: serve cache hits (and within-batch
/// duplicates) without verifying, shard the rest across the work-stealing
/// queue, merge per-shard optima, write results back to the cache, and
/// persist it.
pub fn run_batch(
    jobs: &[TuningJob],
    opts: &BatchOptions,
    cache: &mut ResultCache,
) -> Result<BatchReport> {
    let start = Instant::now();
    let hits_before = cache.hits;
    let misses_before = cache.misses;

    // Phase 1: cache pass. Hits complete immediately; overlapping jobs
    // (same cache description) run once and the rest resolve in phase 3.
    let mut outcomes: Vec<Option<JobOutcome>> = jobs.iter().map(|_| None).collect();
    let mut tasks: Vec<(usize, TuningShard)> = Vec::new();
    let mut shard_counts = vec![0u32; jobs.len()];
    let mut duplicates: Vec<usize> = Vec::new();
    let mut submitted: HashMap<String, usize> = HashMap::new();
    for (ji, job) in jobs.iter().enumerate() {
        let desc = job.cache_desc_with(&opts.swarm);
        if let Some(hit) = cache.lookup(&desc) {
            outcomes[ji] = Some(JobOutcome {
                job: job.clone(),
                result: cached_result(job.method, hit, &desc),
                cached: true,
                shards: 0,
                wall: Duration::ZERO,
            });
            continue;
        }
        if submitted.contains_key(&desc) {
            duplicates.push(ji);
            continue;
        }
        submitted.insert(desc, ji);
        let tunings = enumerate_tunings(job.size)
            .with_context(|| format!("job `{}`", job.name))?;
        let shards = partition(
            &tunings,
            if job.shards == 0 { opts.default_shards } else { job.shards },
        );
        if shards.is_empty() {
            bail!("job `{}` has an empty tuning space", job.name);
        }
        shard_counts[ji] = shards.len() as u32;
        tasks.extend(shards.into_iter().map(|s| (ji, s)));
    }

    // Phase 2: every (job, shard) task through the work-stealing queue.
    // Dispatch on the concrete model type so the checker's successor
    // buffers are reused as designed (JobModel's uniform interface costs
    // an allocation per expanded state — fine for cold paths, not here).
    let queue = JobQueue::new(opts.workers);
    let (shard_results, qstats) = queue.run_stats(tasks, |(ji, shard)| {
        let job = &jobs[ji];
        let t0 = Instant::now();
        let result = (|| -> Result<TuneResult> {
            match job.build()? {
                JobModel::Abs(m) => {
                    tune(&ShardModel { inner: &m, shard }, job.method, &opts.check, &opts.swarm, None)
                }
                JobModel::Min(m) => {
                    tune(&ShardModel { inner: &m, shard }, job.method, &opts.check, &opts.swarm, None)
                }
            }
        })();
        (ji, t0.elapsed(), result)
    });

    // Phase 3: merge shards per job, write back to the cache. A failing
    // shard fails its *job*, not the batch: every other job's result is
    // still merged, cached and persisted before the error propagates, so
    // completed verification work is never thrown away.
    let mut per_job: Vec<Vec<TuneResult>> = jobs.iter().map(|_| Vec::new()).collect();
    let mut per_job_wall = vec![Duration::ZERO; jobs.len()];
    let mut failures: Vec<(usize, crate::util::error::Error)> = Vec::new();
    for (ji, wall, result) in shard_results {
        match result {
            Ok(r) => {
                per_job[ji].push(r);
                per_job_wall[ji] = per_job_wall[ji].max(wall);
            }
            Err(e) => failures.push((ji, e)),
        }
    }
    let mut completed = 0usize;
    for (ji, parts) in per_job.into_iter().enumerate() {
        if parts.is_empty() || failures.iter().any(|&(fj, _)| fj == ji) {
            continue; // cached, duplicate, or failed
        }
        let merged = merge_results(parts)?;
        cache.store(&jobs[ji].cache_desc_with(&opts.swarm), &merged);
        completed += 1;
        outcomes[ji] = Some(JobOutcome {
            job: jobs[ji].clone(),
            result: merged,
            cached: false,
            shards: shard_counts[ji],
            wall: per_job_wall[ji],
        });
    }
    // overlapping duplicates resolve against the freshly stored results
    // (a duplicate of a failed job stays unresolved and fails with it)
    for ji in duplicates {
        let desc = jobs[ji].cache_desc_with(&opts.swarm);
        if let Some(hit) = cache.lookup(&desc) {
            outcomes[ji] = Some(JobOutcome {
                job: jobs[ji].clone(),
                result: cached_result(jobs[ji].method, hit, &desc),
                cached: true,
                shards: 0,
                wall: Duration::ZERO,
            });
        }
    }
    cache.save()?;
    if let Some((ji, e)) = failures.into_iter().next() {
        return Err(e.context(format!(
            "job `{}`: a parameter-space shard failed ({} completed job(s) were still cached)",
            jobs[ji].name, completed
        )));
    }

    Ok(BatchReport {
        outcomes: outcomes
            .into_iter()
            .map(|o| o.expect("every job resolves to an outcome"))
            .collect(),
        cache_hits: cache.hits - hits_before,
        cache_misses: cache.misses - misses_before,
        stolen_tasks: qstats.stolen,
        total_elapsed: start.elapsed(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_options_defaults() {
        let o = BatchOptions::default();
        assert_eq!(o.workers, 4);
        assert_eq!(o.default_shards, 4);
    }
}
