//! The coordinator — the paper's L3 coordination layer grown into a
//! batch tuning *service*.
//!
//! The paper tunes one kernel for one platform and one input size at a
//! time. Production auto-tuning workloads are batches: many input sizes,
//! platform configurations and search methods tuned concurrently, with
//! results reused across jobs. This module supplies that layer:
//!
//! - [`TuningJob`] (in [`job`]) — a declarative job spec (model kind,
//!   engine, size, platform config, granularity, method, sharding
//!   degree), parseable from a plain-text spec file. `engine: promela`
//!   jobs run the paper's actual artifact — a Promela model with full
//!   process interleaving — through the same batch machinery as the
//!   native engines, cached under a content hash of the Promela source;
//! - [`partition`] / [`ShardModel`] (in [`shard`]) — each job's (WG, TS)
//!   lattice is split into sub-lattices checked independently and merged,
//!   generalizing the swarm's diversified-*seed* workers to
//!   partitioned-*space* workers. [`plan_shards`] turns the job-level
//!   budgets into *shard-aware* budgets: time/memory/max_states scale
//!   with each sub-lattice's estimated state-space size
//!   ([`TuningJob::tuning_costs`]), and the same estimate pre-sizes the
//!   checker's visited stores and — via [`adaptive_shard_count`] — picks
//!   the shard count when neither the job nor `--shards` pins one.
//!   Promela jobs skip the re-filtering wrapper entirely: each shard's
//!   bounds are compiled into a specialized bytecode program
//!   ([`crate::promela::PromelaVm`]) that never generates off-shard
//!   successors (see [`run_shard_task`]);
//! - [`JobQueue`] (in [`queue`]) — a work-stealing runner that executes
//!   the (job × shard) task set across std threads;
//! - [`ResultCache`] (in [`cache`]) — a content-addressed result store
//!   keyed by `util::hash` of the job description, persisted to JSON via
//!   `util::manifest::Json`, so repeated and overlapping jobs skip
//!   verification entirely;
//! - [`BatchReport`] (in [`report`]) — per-job optima, per-shard budget
//!   plans, and cache/queue statistics, rendered for the
//!   `mcautotune batch` subcommand;
//! - [`TaskDir`] (in [`task`]) — **worker mode**: the same plan serialized
//!   as durable JSON task manifests that any number of processes (or
//!   machines sharing the directory) lease with atomic rename-based lock
//!   files, execute, and merge back into the identical [`BatchReport`]
//!   and cache a single-process run produces.
//!
//! [`run_batch`] composes the phases in-process: [`plan_batch`] (cache
//! lookups first — hits and duplicate jobs complete immediately — then
//! one task per remaining (job, shard) with its planned budget),
//! [`run_shard_task`] per task across the queue, then [`finish_batch`]
//! (per-job merge + cache write-back). Worker mode runs the same three
//! phases split across processes: `mcautotune batch --task-dir` plans,
//! `mcautotune worker` executes, `mcautotune merge` finishes.

pub mod cache;
pub mod job;
pub mod queue;
pub mod report;
pub mod shard;
pub mod task;

pub use cache::{CacheEntry, ResultCache};
pub use job::{JobEngine, JobModel, JobState, ModelKind, ShardedExec, TuningJob};
pub use queue::{JobQueue, QueueStats};
pub use report::{BatchReport, DeadTaskInfo, JobOutcome};
pub use shard::{
    adaptive_shard_count, merge_results, partition, plan_shards, shard_weight, ShardModel,
    ShardPlan, TuningShard,
};
pub use task::{DrainStats, LeaseInfo, LeasedTask, PlanSummary, TaskDir, TaskSpec, TaskStatus};

use crate::checker::CheckOptions;
use crate::platform::Tuning;
use crate::swarm::SwarmConfig;
use crate::tuner::{
    cached_result, harvest_observations, surrogate_tune, tune, SearchMode, SurrogateOptions,
    TuneCache, TuneResult,
};
use crate::util::error::{bail, Context, Result};
use std::collections::HashMap;
use std::time::{Duration, Instant};

/// Batch-wide execution options (per-job knobs live on [`TuningJob`]).
#[derive(Debug, Clone)]
pub struct BatchOptions {
    /// queue worker threads
    pub workers: u32,
    /// shard count for jobs that left `shards` unset (0). 0 here too =
    /// adaptive: derive each job's count from its estimated state-space
    /// size ([`adaptive_shard_count`]).
    pub default_shards: u32,
    /// *job-level* verification options. Budgets (time/memory/max_states)
    /// are split across each job's shards proportionally to estimated
    /// sub-lattice size — see [`plan_shards`] — not handed out uniformly.
    pub check: CheckOptions,
    /// per-shard swarm configuration (Method::Swarm jobs)
    pub swarm: SwarmConfig,
}

impl Default for BatchOptions {
    fn default() -> Self {
        Self {
            workers: 4,
            default_shards: 0,
            check: CheckOptions::default(),
            swarm: SwarmConfig::default(),
        }
    }
}

/// The cache-resolved, budget-planned decomposition of a batch — the
/// output of [`plan_batch`] (phase 1 of [`run_batch`]). The in-process
/// runner feeds [`tasks`](Self::tasks) straight into the work-stealing
/// queue; worker mode ([`task::TaskDir::plan`]) serializes them as durable
/// JSON manifests that any process can lease and execute.
#[derive(Debug)]
pub struct BatchPlan {
    /// canonical cache description per job ([`TuningJob::cache_desc_with`])
    pub descs: Vec<String>,
    /// outcomes already resolved at plan time (cache hits); `None` slots
    /// are filled by [`finish_batch`]
    pub outcomes: Vec<Option<JobOutcome>>,
    /// every (job index, shard plan) that still needs verification
    pub tasks: Vec<(usize, ShardPlan)>,
    /// shards per job (0 = cached or duplicate: nothing runs)
    pub shard_counts: Vec<u32>,
    /// indices of jobs that duplicate an earlier job's description and
    /// resolve against its freshly stored result at merge time
    pub duplicates: Vec<usize>,
}

/// Phase 1: cache pass + budget planning. Hits complete immediately;
/// overlapping jobs (same cache description) run once and the rest
/// resolve at merge time. Cache misses are planned: per-tuning cost
/// estimates weight the sub-lattices, the weights derive the shard count
/// (when unset) and scale the job-level budgets into per-shard budgets.
pub fn plan_batch(
    jobs: &[TuningJob],
    opts: &BatchOptions,
    cache: &mut ResultCache,
) -> Result<BatchPlan> {
    // lint-before-plan: surface warning-severity diagnostics for jobs
    // carrying external .pml sources before any budget is spent on them.
    // Warnings only advise (the batch still runs); hard degeneracies —
    // WG/TS never assigned — error later in `TuningJob::build`. Generated
    // templates are lint-clean by construction (tested) and stay quiet.
    for job in jobs {
        if job.engine == JobEngine::Promela && job.source.is_some() {
            let Ok(sys) = crate::promela::PromelaSystem::from_source(&job.promela_source_text())
            else {
                continue; // compile errors surface with context at build time
            };
            for d in crate::promela::analysis::diagnostics(&sys.prog) {
                if d.severity == crate::promela::analysis::Severity::Warn {
                    eprintln!("warning: job `{}`: {}", job.name, d);
                }
            }
        }
    }
    let mut outcomes: Vec<Option<JobOutcome>> = jobs.iter().map(|_| None).collect();
    let mut tasks: Vec<(usize, ShardPlan)> = Vec::new();
    let mut shard_counts = vec![0u32; jobs.len()];
    let mut duplicates: Vec<usize> = Vec::new();
    let mut submitted: HashMap<String, usize> = HashMap::new();
    // one description per job: for Promela jobs cache_desc regenerates
    // and rehashes the template source, so don't recompute it per phase
    let descs: Vec<String> =
        jobs.iter().map(|job| job.cache_desc_with(&opts.swarm)).collect();
    for (ji, job) in jobs.iter().enumerate() {
        let desc = descs[ji].clone();
        if let Some(hit) = cache.lookup(&desc) {
            outcomes[ji] = Some(JobOutcome {
                job: job.clone(),
                result: cached_result(job.method, hit, &desc),
                cached: true,
                shards: 0,
                wall: Duration::ZERO,
                plan: Vec::new(),
                shard_states: Vec::new(),
                lower_bound: false,
            });
            continue;
        }
        if submitted.contains_key(&desc) {
            duplicates.push(ji);
            continue;
        }
        submitted.insert(desc, ji);
        job.validate_modes().with_context(|| format!("job `{}`", job.name))?;
        let costs = job.tuning_costs().with_context(|| format!("job `{}`", job.name))?;
        let tunings: Vec<Tuning> = costs.iter().map(|&(t, _)| t).collect();
        let want = if job.shards != 0 {
            job.shards
        } else if opts.default_shards != 0 {
            opts.default_shards
        } else {
            let total: u64 = costs.iter().map(|&(_, c)| c).sum();
            adaptive_shard_count(total, opts.workers, tunings.len())
        };
        let plans = plan_shards(partition(&tunings, want), &costs, &opts.check);
        if plans.is_empty() {
            bail!("job `{}` has an empty tuning space", job.name);
        }
        // surrogate jobs warm-start from every same-family observation in
        // the cache — including observations other jobs recorded at other
        // input sizes (cross-job neighbor warm-start). Seeds ride on the
        // shard plans so worker-mode manifests ship them too.
        let seeds = if job.search == SearchMode::Surrogate {
            cache.observations(&job.obs_family())
        } else {
            Vec::new()
        };
        shard_counts[ji] = plans.len() as u32;
        tasks.extend(plans.into_iter().map(|mut p| {
            p.seeds = seeds.clone();
            (ji, p)
        }));
    }
    Ok(BatchPlan { descs, outcomes, tasks, shard_counts, duplicates })
}

/// Execute one planned (job, shard) task — the per-task body of phase 2,
/// shared between the in-process queue and cross-process workers
/// ([`task::TaskDir`]). Dispatches on the concrete model type so the
/// checker's successor buffers are reused as designed (JobModel's uniform
/// interface costs an allocation per expanded state — fine for cold
/// paths, not here). Each task builds its own model: that repeats Promela
/// parse+compile once per shard, but keeps build failures scoped to their
/// job (not the batch) and costs microseconds against the shard's
/// verification work.
///
/// Promela jobs compile a **shard-specialized bytecode VM**
/// ([`crate::promela::PromelaVm`]): the sub-lattice bounds the plan
/// carries (and worker-mode manifests ship, see [`task::TaskSpec`]) are
/// baked into the compiled program, which prunes off-shard (WG, TS)
/// commitments at the choice point instead of generating every successor
/// and re-filtering it through [`ShardModel`]. The explored state space —
/// and therefore every result, state count and cache entry — is
/// byte-identical to the re-filtering path; only the wasted successor
/// materialization disappears. Sources whose initial image already
/// commits a tuning fall back to the generic wrapper (the specialization
/// contract needs the choice to happen at runtime).
pub fn run_shard_task(
    job: &TuningJob,
    plan: &ShardPlan,
    swarm: &SwarmConfig,
) -> Result<TuneResult> {
    run_shard_task_inner(job, plan, swarm, None)
}

/// [`run_shard_task`] tagged with its task id (`j###-s###`): when a
/// flight recorder is installed, publishes one deterministic `shard`
/// trace event derived purely from per-run data — the [`TuneResult`],
/// the [`ShardPlan`] and the task's *own* VM counters — never from the
/// global metrics registry, which concurrent shards cross-contaminate.
/// Under `--frontier det` the event content is byte-identical no matter
/// which process (or how many worker processes) executed the task.
pub fn run_shard_task_traced(
    job: &TuningJob,
    plan: &ShardPlan,
    swarm: &SwarmConfig,
    id: &str,
) -> Result<TuneResult> {
    run_shard_task_inner(job, plan, swarm, Some(id))
}

/// Per-shard lattice search, dispatched on the job's [`SearchMode`]:
/// `Exhaustive` is plain [`tune`]; `Surrogate` runs the
/// proposer/oracle/certificate loop ([`surrogate_tune`]) over this
/// shard's sub-lattice, warm-started from the cache observations the
/// plan carries. Both return the identical optimum (see the tuner module
/// docs), so cache write-back downstream is mode-agnostic.
fn search_shard<M>(
    model: &M,
    job: &TuningJob,
    plan: &ShardPlan,
    swarm: &SwarmConfig,
) -> Result<TuneResult>
where
    M: crate::model::TransitionSystem + Sync,
    M::State: Send,
{
    // t_ini comes from the plan, never from random simulation: a sharded
    // model can dead-end a simulation walk in a pruned branch (see
    // ShardPlan::t_ini), and the plan's bound is sound anyway.
    let t_ini = Some(plan.t_ini);
    if job.search == SearchMode::Surrogate && job.method == crate::tuner::Method::Exhaustive {
        // the shard's own sub-lattice; a size outside the power-of-two
        // enumeration (possible for external sources) has no lattice to
        // propose over — degrade to exhaustive rather than error
        let lattice: Vec<Tuning> = match crate::platform::enumerate_tunings(job.size) {
            Ok(all) => all.into_iter().filter(|&t| plan.shard.contains(t)).collect(),
            Err(_) => Vec::new(),
        };
        if !lattice.is_empty() {
            let rep = surrogate_tune(
                model,
                &plan.check,
                swarm,
                t_ini,
                &lattice,
                job.size,
                &plan.seeds,
                &SurrogateOptions::default(),
            )?;
            return Ok(rep.result);
        }
    }
    tune(model, job.method, &plan.check, swarm, t_ini)
}

fn run_shard_task_inner(
    job: &TuningJob,
    plan: &ShardPlan,
    swarm: &SwarmConfig,
    tag: Option<&str>,
) -> Result<TuneResult> {
    // chaos site: a shard body that errors, panics, hangs (delay) or
    // kills its process before any verification work happens
    crate::util::failpoint::hit("shard.exec")?;
    // (generated, pruned) from the Promela VM this task compiled — the
    // per-instance counters are this shard's alone, unlike the globals
    let mut vm_counts: Option<(u64, u64)> = None;
    let result = match job.build_sharded(&plan.shard)? {
        ShardedExec::Abs(m) => {
            let sm = ShardModel::new(&m, plan.shard);
            search_shard(&sm, job, plan, swarm)
        }
        ShardedExec::Min(m) => {
            let sm = ShardModel::new(&m, plan.shard);
            search_shard(&sm, job, plan, swarm)
        }
        ShardedExec::PmlWrapped(vm) => {
            let sm = ShardModel::new(&vm, plan.shard);
            let r = search_shard(&sm, job, plan, swarm);
            vm_counts = Some((vm.generated(), vm.pruned()));
            r
        }
        ShardedExec::PmlSpecialized(vm) => {
            let r = search_shard(&vm, job, plan, swarm);
            vm_counts = Some((vm.generated(), vm.pruned()));
            r
        }
    }?;
    if let Some((g, p)) = vm_counts {
        // one pair of adds per task — the VM hot path itself carries no
        // global-registry traffic
        let m = crate::obs::metrics();
        m.vm_generated.add(g);
        m.vm_pruned.add(p);
    }
    if let (Some(id), Some(rec)) = (tag, crate::obs::active()) {
        use crate::obs::ju64;
        use crate::util::manifest::Json;
        let mut fields: Vec<(&str, Json)> = vec![
            ("id", Json::Str(id.to_string())),
            ("job", Json::Str(job.name.clone())),
            ("wg_min", Json::Int(plan.shard.wg_min as i64)),
            ("wg_max", Json::Int(plan.shard.wg_max as i64)),
            ("ts_min", Json::Int(plan.shard.ts_min as i64)),
            ("ts_max", Json::Int(plan.shard.ts_max as i64)),
            ("est", ju64(plan.weight)),
            ("t_ini", Json::Int(plan.t_ini)),
            ("states", ju64(result.states_explored)),
            ("t_min", Json::Int(result.t_min)),
            ("wg", Json::Int(result.optimal.wg as i64)),
            ("ts", Json::Int(result.optimal.ts as i64)),
            ("steps", ju64(result.optimal.steps as u64)),
        ];
        if let Some((g, p)) = vm_counts {
            fields.push(("vm_generated", ju64(g)));
            fields.push(("vm_pruned", ju64(p)));
        }
        rec.det_event("shard", fields);
    }
    Ok(result)
}

/// What [`finish_batch`] produced: the resolved outcomes plus the
/// degraded-path bookkeeping the report surfaces.
pub(crate) struct FinishedBatch {
    pub(crate) outcomes: Vec<JobOutcome>,
    /// `ResultCache::save` failed — every result above is still valid
    /// and reported, only the persistence is lost (warning, not abort)
    pub(crate) cache_save_error: Option<String>,
}

/// Phase 3: merge per-shard results per job, write back to the cache,
/// resolve within-batch duplicates, and persist. A failing shard fails
/// its *job*, not the batch: every other job's result is still merged,
/// cached and persisted before the error propagates, so completed
/// verification work is never thrown away. `shard_results` must be in
/// task order (the order [`plan_batch`] emitted them) so merge folds —
/// shard log tags, first-trail tie-breaks — are identical no matter which
/// process executed which shard.
///
/// With `partial`, degradation replaces refusal: a job missing shard
/// results (dead-lettered or still outstanding tasks, failed shards)
/// folds the shards it does have into a **lower-bound** outcome — marked
/// in the [`JobOutcome`], never written to the cache, since a partial
/// sub-lattice scan may have missed the true optimum — and shard
/// failures do not propagate as errors. Jobs with no completed shard at
/// all (and duplicates of incomplete jobs) are dropped from the outcome
/// list rather than invented.
#[allow(clippy::too_many_arguments)]
pub(crate) fn finish_batch(
    jobs: &[TuningJob],
    descs: &[String],
    mut outcomes: Vec<Option<JobOutcome>>,
    shard_counts: &[u32],
    duplicates: &[usize],
    shard_results: Vec<(usize, ShardPlan, Duration, Result<TuneResult>)>,
    cache: &mut ResultCache,
    partial: bool,
) -> Result<FinishedBatch> {
    let mut per_job: Vec<Vec<TuneResult>> = jobs.iter().map(|_| Vec::new()).collect();
    let mut per_job_plans: Vec<Vec<(ShardPlan, u64)>> = jobs.iter().map(|_| Vec::new()).collect();
    let mut per_job_wall = vec![Duration::ZERO; jobs.len()];
    let mut failures: Vec<(usize, crate::util::error::Error)> = Vec::new();
    for (ji, plan, wall, result) in shard_results {
        match result {
            Ok(r) => {
                per_job_plans[ji].push((plan, r.states_explored));
                per_job[ji].push(r);
                per_job_wall[ji] = per_job_wall[ji].max(wall);
            }
            Err(e) => failures.push((ji, e)),
        }
    }
    let mut completed = 0usize;
    for (ji, parts) in per_job.into_iter().enumerate() {
        if parts.is_empty() {
            continue; // cached, duplicate, or nothing completed
        }
        if !partial && failures.iter().any(|&(fj, _)| fj == ji) {
            continue; // failed job: skipped here, error propagates below
        }
        // complete = every planned shard delivered a result and none
        // failed; only complete jobs may enter the cache (a partial
        // sub-lattice scan can miss the true optimum, and a poisoned
        // cache would silently corrupt every later run)
        let complete = parts.len() as u32 == shard_counts[ji]
            && !failures.iter().any(|&(fj, _)| fj == ji);
        let merged = merge_results(parts)?;
        if complete {
            cache.store(&descs[ji], &merged);
            // surrogate jobs grow the observation store for future
            // warm-starts (the merged optimum is exact; a distinct first
            // trail is an achievable upper bound)
            if jobs[ji].search == SearchMode::Surrogate {
                let family = jobs[ji].obs_family();
                for o in harvest_observations(&merged, jobs[ji].size) {
                    cache.record_observation(&family, o);
                }
            }
            completed += 1;
        }
        // queue completion order is nondeterministic; report plans (and
        // their actual per-shard state counts) in lattice order
        let mut tagged = std::mem::take(&mut per_job_plans[ji]);
        tagged.sort_by_key(|(p, _)| (p.shard.wg_min, p.shard.ts_min));
        let shard_states = tagged.iter().map(|&(_, s)| s).collect();
        let plan = tagged.into_iter().map(|(p, _)| p).collect();
        outcomes[ji] = Some(JobOutcome {
            job: jobs[ji].clone(),
            result: merged,
            cached: false,
            shards: shard_counts[ji],
            wall: per_job_wall[ji],
            plan,
            shard_states,
            lower_bound: !complete,
        });
    }
    // overlapping duplicates resolve against the freshly stored results
    // (a duplicate of a failed/incomplete job stays unresolved: it fails
    // with it, or in partial mode is dropped with it)
    for &ji in duplicates {
        let desc = &descs[ji];
        if let Some(hit) = cache.lookup(desc) {
            outcomes[ji] = Some(JobOutcome {
                job: jobs[ji].clone(),
                result: cached_result(jobs[ji].method, hit, desc),
                cached: true,
                shards: 0,
                wall: Duration::ZERO,
                plan: Vec::new(),
                shard_states: Vec::new(),
                lower_bound: false,
            });
        }
    }
    // a save failure degrades to a report warning: all results above are
    // already merged and valid, and aborting here used to throw away an
    // entire drained batch over one unwritable cache file
    let cache_save_error = cache.save().err().map(|e| format!("{:#}", e));
    if let Some(e) = &cache_save_error {
        task::fault_event("cache_save", "batch", e, 0, false);
    }
    if !partial {
        if let Some((ji, e)) = failures.into_iter().next() {
            return Err(e.context(format!(
                "job `{}`: a parameter-space shard failed ({} completed job(s) were still cached)",
                jobs[ji].name, completed
            )));
        }
    }
    let outcomes = if partial {
        outcomes.into_iter().flatten().collect()
    } else {
        outcomes
            .into_iter()
            .map(|o| o.expect("every job resolves to an outcome"))
            .collect()
    };
    Ok(FinishedBatch { outcomes, cache_save_error })
}

/// Run a batch of tuning jobs: serve cache hits (and within-batch
/// duplicates) without verifying, shard the rest across the work-stealing
/// queue, merge per-shard optima, write results back to the cache, and
/// persist it. For cross-process draining of the same plan, see
/// [`task::TaskDir`] (worker mode).
pub fn run_batch(
    jobs: &[TuningJob],
    opts: &BatchOptions,
    cache: &mut ResultCache,
) -> Result<BatchReport> {
    let start = Instant::now();
    let hits_before = cache.hits;
    let misses_before = cache.misses;

    let plan = plan_batch(jobs, opts, cache)?;

    // Phase 2: every (job, shard) task through the work-stealing queue,
    // each under its planned budget. Task ids reproduce exactly what
    // [`task::TaskDir::plan`] assigns the same plan — per-job shard
    // counters in task order — so a worker-mode drain of this batch
    // publishes `shard` trace events with identical ids.
    let mut next_shard = vec![0u32; jobs.len()];
    let tasks: Vec<(String, usize, ShardPlan)> = plan
        .tasks
        .into_iter()
        .map(|(ji, p)| {
            let si = next_shard[ji];
            next_shard[ji] += 1;
            (format!("j{:03}-s{:03}", ji, si), ji, p)
        })
        .collect();
    let queue = JobQueue::new(opts.workers);
    let (shard_results, qstats) = queue.run_stats(tasks, |(id, ji, shard_plan)| {
        let t0 = Instant::now();
        let result = run_shard_task_traced(&jobs[ji], &shard_plan, &opts.swarm, &id);
        (ji, shard_plan, t0.elapsed(), result)
    });

    let fin = finish_batch(
        jobs,
        &plan.descs,
        plan.outcomes,
        &plan.shard_counts,
        &plan.duplicates,
        shard_results,
        cache,
        false,
    )?;

    Ok(BatchReport {
        outcomes: fin.outcomes,
        cache_hits: cache.hits - hits_before,
        cache_misses: cache.misses - misses_before,
        stolen_tasks: qstats.stolen,
        total_elapsed: start.elapsed(),
        partial: false,
        pending_tasks: 0,
        dead_tasks: Vec::new(),
        cache_save_error: fin.cache_save_error,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_options_defaults() {
        let o = BatchOptions::default();
        assert_eq!(o.workers, 4);
        assert_eq!(o.default_shards, 0, "0 = adaptive from the state-space estimate");
    }
}
